"""Vmapped sweep vs serial per-point replay on a fig6-style grid.

The PR-5 acceptance benchmark: an (alpha x rho) sensitivity grid of AKPC
points — the exact shape of benchmarks/fig6_sensitivity.py — replayed two
ways on the same machine:

* **serial**: the pre-PR-5 loop — one ``run_policy`` (NumPy engine) per
  grid point, clique generation re-run every time;
* **sweep**:  one ``SweepEngine`` call — points sharing (trace, CGM
  hyperparameters) share a host schedule (every alpha row shares one
  clique-generation pass per rho), and each schedule group replays as a
  single vmapped ``jit``/``lax.scan`` on device.

The sweep is timed twice: **cold** (first call of the process — schedule
build + XLA compile, or a hit in the persistent compile cache that
``SweepEngine`` enables) and **warm** (second call — the steady state of
every realistic sweep workload, where the compiled cohort is cached
across ``SweepEngine.run`` calls).  Cost parity at 1e-9 between serial
and sweep is asserted for EVERY point before any timing is trusted.
Results land in ``experiments/results/BENCH_sweep.json`` so the perf
trajectory records both paths and the measured speedups.

Env knobs:
  REPRO_SWEEP_BENCH_REQUESTS   trace length per point   (default 150000)
  REPRO_SWEEP_BENCH_ALPHAS     alpha-axis size          (default 64)
  REPRO_SWEEP_BENCH_RHOS       rho-axis size            (default 4)

``--smoke`` (CI): 60k-request trace, 32-point grid, parity check + the
warm sweep must BEAT the serial loop (no 5x floor — CI runners are too
noisy to gate on a ratio; the full run asserts >= 5x cold).  Small grids
used to LOSE cold (0.88x at 24 points/40k requests: one ~1s XLA compile
outweighed the vmap win); the compiled-cohort caches fixed that — cold
runs hit the on-disk cache from the second process on, and warm runs
never re-trace.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core import CostParams, SweepEngine, SweepPoint
from repro.traces import paper_trace

from .common import emit, save_json, t_cg_for

INT_FIELDS = ("n_requests", "n_item_requests", "n_misses", "n_hits",
              "items_transferred")
FLOAT_FIELDS = ("transfer", "caching", "keepalive_rent", "total")


def build_grid(trace, n_alphas: int, n_rhos: int) -> list[SweepPoint]:
    """fig6-style grid: alpha x rho sensitivity of the proposed method."""
    alphas = np.linspace(0.6, 1.0, n_alphas)
    rhos = np.linspace(1.0, 6.0, n_rhos)
    pts = []
    for rho in rhos:
        for alpha in alphas:
            params = CostParams(alpha=float(alpha), rho=float(rho))
            pts.append(SweepPoint(
                "akpc", trace,
                dict(params=params, t_cg=t_cg_for(trace, params),
                     top_frac=1.0),
                tag=f"alpha={alpha:.3f}/rho={rho:.2f}"))
    return pts


def assert_parity(pts, serial, swept) -> None:
    for pt, a, b in zip(pts, serial, swept):
        da, db = a.costs.as_dict(), b.costs.as_dict()
        for f in INT_FIELDS:
            assert da[f] == db[f], (pt.tag, f, da[f], db[f])
        for f in FLOAT_FIELDS:
            assert np.isclose(da[f], db[f], rtol=1e-9, atol=1e-9), \
                (pt.tag, f, da[f], db[f])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run: parity + sweep must beat serial")
    args, _ = ap.parse_known_args()

    if args.smoke:
        n = int(os.environ.get("REPRO_SWEEP_BENCH_REQUESTS", "60000"))
        n_alphas = int(os.environ.get("REPRO_SWEEP_BENCH_ALPHAS", "16"))
        n_rhos = int(os.environ.get("REPRO_SWEEP_BENCH_RHOS", "2"))
    else:
        n = int(os.environ.get("REPRO_SWEEP_BENCH_REQUESTS", "150000"))
        n_alphas = int(os.environ.get("REPRO_SWEEP_BENCH_ALPHAS", "64"))
        n_rhos = int(os.environ.get("REPRO_SWEEP_BENCH_RHOS", "4"))

    trace = paper_trace("netflix", n_requests=n, seed=0)
    pts = build_grid(trace, n_alphas, n_rhos)

    # -- serial baseline: the pre-PR-5 per-point loop, same machine --------
    serial_eng = SweepEngine(backend="numpy")
    t0 = time.perf_counter()
    serial = serial_eng.run(pts)
    t_serial = time.perf_counter() - t0

    # -- vmapped sweep: cold (schedule build + compile-or-cache-hit),
    # then warm (compiled cohort reused across SweepEngine.run calls) ------
    sweep_eng = SweepEngine(backend="jax")
    t0 = time.perf_counter()
    swept = sweep_eng.run(pts)
    t_sweep = time.perf_counter() - t0
    t0 = time.perf_counter()
    swept_warm = sweep_eng.run(pts)
    t_warm = time.perf_counter() - t0

    assert_parity(pts, serial, swept)
    assert_parity(pts, serial, swept_warm)
    print(f"# parity check on {len(pts)} points (cold + warm): OK")

    speedup = t_serial / t_sweep
    speedup_warm = t_serial / t_warm
    emit([
        (f"sweep/serial_{len(pts)}pts", int(t_serial / len(pts) * 1e6),
         f"{t_serial:.2f}s total"),
        (f"sweep/vmapped_{len(pts)}pts", int(t_sweep / len(pts) * 1e6),
         f"{t_sweep:.2f}s total;{sweep_eng.last_n_schedules} schedules"),
        (f"sweep/vmapped_warm_{len(pts)}pts", int(t_warm / len(pts) * 1e6),
         f"{t_warm:.2f}s total"),
        ("sweep/speedup", round(speedup, 2), "x cold"),
        ("sweep/speedup_warm", round(speedup_warm, 2), "x warm"),
    ])
    save_json("BENCH_sweep", {
        "n_requests": n,
        "grid": {"alphas": n_alphas, "rhos": n_rhos, "points": len(pts)},
        "policy": "akpc",
        "cost_model": "table1",
        "serial_seconds": t_serial,
        "sweep_seconds": t_sweep,
        "sweep_warm_seconds": t_warm,
        "speedup": speedup,
        "speedup_warm": speedup_warm,
        "n_schedules": sweep_eng.last_n_schedules,
        "smoke": bool(args.smoke),
        "points_per_second_serial": len(pts) / t_serial,
        "points_per_second_sweep": len(pts) / t_sweep,
        "points_per_second_sweep_warm": len(pts) / t_warm,
    })
    if args.smoke:
        assert t_warm < t_serial, (
            f"warm vmapped sweep ({t_warm:.2f}s) no faster than the "
            f"serial loop ({t_serial:.2f}s)")
    else:
        assert speedup >= 5.0, \
            f"vmapped sweep only {speedup:.1f}x faster than serial"


if __name__ == "__main__":
    main()
