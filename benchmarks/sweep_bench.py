"""Vmapped sweep vs serial per-point replay on a fig6-style grid.

The PR-5 acceptance benchmark: an (alpha x rho) sensitivity grid of AKPC
points — the exact shape of benchmarks/fig6_sensitivity.py — replayed two
ways on the same machine:

* **serial**: the pre-PR-5 loop — one ``run_policy`` (NumPy engine) per
  grid point, clique generation re-run every time;
* **sweep**:  one ``SweepEngine`` call — points sharing (trace, CGM
  hyperparameters) share a host schedule (every alpha row shares one
  clique-generation pass per rho), and each schedule group replays as a
  single vmapped ``jit``/``lax.scan`` on device.

The sweep is timed twice: **cold** (first call of the process — schedule
build + XLA compile, or a hit in the persistent compile cache that
``SweepEngine`` enables) and **warm** (second call — the steady state of
every realistic sweep workload, where the compiled cohort is cached
across ``SweepEngine.run`` calls).  Cost parity at 1e-9 between serial
and sweep is asserted for EVERY point before any timing is trusted.
Results land in ``experiments/results/BENCH_sweep.json`` so the perf
trajectory records both paths and the measured speedups.

Env knobs:
  REPRO_SWEEP_BENCH_REQUESTS   trace length per point   (default 150000)
  REPRO_SWEEP_BENCH_ALPHAS     alpha-axis size          (default 64)
  REPRO_SWEEP_BENCH_RHOS       rho-axis size            (default 4)

``--smoke`` (CI): 60k-request trace, 32-point grid, parity check + the
warm sweep must BEAT the serial loop (no 5x floor — CI runners are too
noisy to gate on a ratio; the full run asserts >= 5x cold).  Small grids
used to LOSE cold (0.88x at 24 points/40k requests: one ~1s XLA compile
outweighed the vmap win); the compiled-cohort caches fixed that — cold
runs hit the on-disk cache from the second process on, and warm runs
never re-trace.  Smoke also runs the ISSUE-8 mixed-shape gate: a grid
of four distinct (n, m) points under a ``bucketed`` StateLayout must
compile once per bucket COHORT, not once per point.

``--mesh`` (devices x points): re-times the warm sweep in subprocesses
under ``XLA_FLAGS=--xla_force_host_platform_device_count={1,2,4}`` with
a ``make_sweep_mesh`` scenario mesh, recording the scaling row per
device count in BENCH_sweep.json (CPU virtual devices — the record is
the scaling SHAPE, not a speedup claim).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.core import CostParams, SweepEngine, SweepPoint
from repro.core.state_layout import StateLayout
from repro.traces import paper_trace

from .common import emit, save_json, t_cg_for

INT_FIELDS = ("n_requests", "n_item_requests", "n_misses", "n_hits",
              "items_transferred")
FLOAT_FIELDS = ("transfer", "caching", "keepalive_rent", "total")


def build_grid(trace, n_alphas: int, n_rhos: int) -> list[SweepPoint]:
    """fig6-style grid: alpha x rho sensitivity of the proposed method."""
    alphas = np.linspace(0.6, 1.0, n_alphas)
    rhos = np.linspace(1.0, 6.0, n_rhos)
    pts = []
    for rho in rhos:
        for alpha in alphas:
            params = CostParams(alpha=float(alpha), rho=float(rho))
            pts.append(SweepPoint(
                "akpc", trace,
                dict(params=params, t_cg=t_cg_for(trace, params),
                     top_frac=1.0),
                tag=f"alpha={alpha:.3f}/rho={rho:.2f}"))
    return pts


def assert_parity(pts, serial, swept) -> None:
    for pt, a, b in zip(pts, serial, swept):
        da, db = a.costs.as_dict(), b.costs.as_dict()
        for f in INT_FIELDS:
            assert da[f] == db[f], (pt.tag, f, da[f], db[f])
        for f in FLOAT_FIELDS:
            assert np.isclose(da[f], db[f], rtol=1e-9, atol=1e-9), \
                (pt.tag, f, da[f], db[f])


def state_bytes_telemetry(n: int, m: int) -> dict:
    """Device state-buffer bytes per layout at (n, m) — the catalog-scale
    memory record ISSUE 8 tracks across PRs alongside wall-clock."""
    return {
        "n_items": n, "n_servers": m,
        "dense": StateLayout().state_bytes(n, m),
        "bucketed": StateLayout(kind="bucketed").state_bytes(n, m),
        "row_sharded_x4_per_device": StateLayout(
            kind="row_sharded", shards=4).state_bytes_per_device(n, m),
    }


def mixed_shape_gate() -> dict:
    """Bucketed-compilation contract on a mixed-(n, m) grid: compile
    count (SCAN_TRACES delta) <= #bucket-cohorts, strictly < #points."""
    from repro.core import engine_jax as ej
    from repro.traces import SynthConfig, synth_trace

    lay = StateLayout(kind="bucketed", row_bucket=64, col_bucket=32)
    shapes = [(50, 20), (60, 25), (100, 40), (120, 48)]
    pts = []
    for seed, (n, m) in enumerate(shapes):
        tr = synth_trace(SynthConfig(
            kind="netflix", n_items=n, n_servers=m, n_requests=3000,
            t_max=3.0, bundle_cover=1.0, bundle_zipf=0.7, seed=seed))
        params = CostParams()
        pts.append(SweepPoint(
            "akpc", tr,
            dict(params=params, t_cg=t_cg_for(tr, params), top_frac=1.0),
            tag=f"n={n}/m={m}"))
    cohorts = len({lay.state_dims(n, m) for n, m in shapes})
    traces0 = ej.SCAN_TRACES
    jax_res = SweepEngine(backend="jax", layout=lay).run(pts)
    compiles = ej.SCAN_TRACES - traces0
    ref = SweepEngine(backend="numpy").run(pts)
    assert_parity(pts, ref, jax_res)
    assert cohorts < len(pts), "gate grid must be mixed-shape"
    assert compiles <= cohorts, (
        f"bucketed mixed-shape sweep compiled {compiles}x for "
        f"{cohorts} cohorts ({len(pts)} points)")
    print(f"# mixed-shape gate: {len(pts)} points -> {cohorts} cohorts, "
          f"{compiles} compiles, parity OK")
    return {"points": len(pts), "cohorts": cohorts, "compiles": compiles,
            "layout": {"tag": lay.tag, "row_bucket": lay.row_bucket,
                       "col_bucket": lay.col_bucket}}


def _mesh_worker() -> None:
    """Subprocess body for --mesh: warm-time the sweep on THIS process's
    device count under a scenario mesh, print one JSON line."""
    import jax

    from repro.launch.mesh import make_sweep_mesh

    n = int(os.environ["REPRO_MESH_REQUESTS"])
    n_alphas = int(os.environ["REPRO_MESH_ALPHAS"])
    n_rhos = int(os.environ["REPRO_MESH_RHOS"])
    trace = paper_trace("netflix", n_requests=n, seed=0)
    pts = build_grid(trace, n_alphas, n_rhos)
    eng = SweepEngine(backend="jax", mesh=make_sweep_mesh())
    eng.run(pts)                       # compile / cache-hit pass
    t0 = time.perf_counter()
    eng.run(pts)
    warm = time.perf_counter() - t0
    print(json.dumps({"devices": len(jax.devices()),
                      "points": len(pts), "warm_seconds": warm}))


def bench_mesh(n: int, n_alphas: int, n_rhos: int) -> list[dict]:
    """Devices x points scaling rows (1, 2, 4 virtual CPU devices)."""
    rows = []
    for d in (1, 2, 4):
        env = dict(
            os.environ,
            XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                       f" --xla_force_host_platform_device_count={d}"),
            REPRO_MESH_REQUESTS=str(n), REPRO_MESH_ALPHAS=str(n_alphas),
            REPRO_MESH_RHOS=str(n_rhos),
        )
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.sweep_bench",
             "--mesh-worker"],
            env=env, capture_output=True, text=True, check=True)
        row = json.loads(out.stdout.strip().splitlines()[-1])
        rows.append(row)
        print(f"# mesh: {row['devices']} device(s) -> "
              f"{row['warm_seconds']:.2f}s warm")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run: parity + sweep must beat serial")
    ap.add_argument("--mesh", action="store_true",
                    help="record devices x points mesh scaling rows")
    ap.add_argument("--mesh-worker", action="store_true",
                    help=argparse.SUPPRESS)
    args, _ = ap.parse_known_args()
    if args.mesh_worker:
        _mesh_worker()
        return

    if args.smoke:
        n = int(os.environ.get("REPRO_SWEEP_BENCH_REQUESTS", "60000"))
        n_alphas = int(os.environ.get("REPRO_SWEEP_BENCH_ALPHAS", "16"))
        n_rhos = int(os.environ.get("REPRO_SWEEP_BENCH_RHOS", "2"))
    else:
        n = int(os.environ.get("REPRO_SWEEP_BENCH_REQUESTS", "150000"))
        n_alphas = int(os.environ.get("REPRO_SWEEP_BENCH_ALPHAS", "64"))
        n_rhos = int(os.environ.get("REPRO_SWEEP_BENCH_RHOS", "4"))

    trace = paper_trace("netflix", n_requests=n, seed=0)
    pts = build_grid(trace, n_alphas, n_rhos)

    # -- serial baseline: the pre-PR-5 per-point loop, same machine --------
    serial_eng = SweepEngine(backend="numpy")
    t0 = time.perf_counter()
    serial = serial_eng.run(pts)
    t_serial = time.perf_counter() - t0

    # -- vmapped sweep: cold (schedule build + compile-or-cache-hit),
    # then warm (compiled cohort reused across SweepEngine.run calls) ------
    sweep_eng = SweepEngine(backend="jax")
    t0 = time.perf_counter()
    swept = sweep_eng.run(pts)
    t_sweep = time.perf_counter() - t0
    t0 = time.perf_counter()
    swept_warm = sweep_eng.run(pts)
    t_warm = time.perf_counter() - t0

    assert_parity(pts, serial, swept)
    assert_parity(pts, serial, swept_warm)
    print(f"# parity check on {len(pts)} points (cold + warm): OK")

    speedup = t_serial / t_sweep
    speedup_warm = t_serial / t_warm
    emit([
        (f"sweep/serial_{len(pts)}pts", int(t_serial / len(pts) * 1e6),
         f"{t_serial:.2f}s total"),
        (f"sweep/vmapped_{len(pts)}pts", int(t_sweep / len(pts) * 1e6),
         f"{t_sweep:.2f}s total;{sweep_eng.last_n_schedules} schedules"),
        (f"sweep/vmapped_warm_{len(pts)}pts", int(t_warm / len(pts) * 1e6),
         f"{t_warm:.2f}s total"),
        ("sweep/speedup", round(speedup, 2), "x cold"),
        ("sweep/speedup_warm", round(speedup_warm, 2), "x warm"),
    ])
    payload = {
        "n_requests": n,
        "grid": {"alphas": n_alphas, "rhos": n_rhos, "points": len(pts)},
        "policy": "akpc",
        "cost_model": "table1",
        "serial_seconds": t_serial,
        "sweep_seconds": t_sweep,
        "sweep_warm_seconds": t_warm,
        "speedup": speedup,
        "speedup_warm": speedup_warm,
        "n_schedules": sweep_eng.last_n_schedules,
        "smoke": bool(args.smoke),
        "points_per_second_serial": len(pts) / t_serial,
        "points_per_second_sweep": len(pts) / t_sweep,
        "points_per_second_sweep_warm": len(pts) / t_warm,
        "state_layout": sweep_eng.layout.tag,
        "state_bytes": state_bytes_telemetry(trace.n, trace.m),
    }
    if args.smoke:
        payload["mixed_shape"] = mixed_shape_gate()
    if args.mesh:
        payload["mesh_scaling"] = bench_mesh(n, n_alphas, n_rhos)
    save_json("BENCH_sweep", payload)
    if args.smoke:
        assert t_warm < t_serial, (
            f"warm vmapped sweep ({t_warm:.2f}s) no faster than the "
            f"serial loop ({t_serial:.2f}s)")
    else:
        assert speedup >= 5.0, \
            f"vmapped sweep only {speedup:.1f}x faster than serial"


if __name__ == "__main__":
    main()
