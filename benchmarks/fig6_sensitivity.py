"""Fig. 6 — sensitivity to (a) discount factor alpha, (b) cost ratio
rho = lambda/mu (the paper reuses the symbol gamma for this; we keep rho)."""
from __future__ import annotations

import dataclasses

from .common import N_SWEEP, emit, get_trace, relative_to_opt, run_methods, save_json
from repro.core import CostParams

ALPHAS = [0.6, 0.7, 0.8, 0.85, 0.9, 1.0]
RHOS = [1.0, 2.0, 4.0, 6.0, 10.0]
METHODS = ("no_packing", "packcache", "akpc", "opt")


def main() -> list[tuple]:
    rows, payload = [], {"alpha": {}, "rho": {}, "cost_model": "table1"}
    for kind in ("netflix", "spotify"):
        tr = get_trace(kind, N_SWEEP)
        for a in ALPHAS:
            res = run_methods(tr, CostParams(alpha=a), methods=METHODS,
                              cost_model="table1")
            rel = relative_to_opt(res)
            payload["alpha"].setdefault(kind, {})[a] = rel
            rows.append((f"fig6a/{kind}/alpha={a}", 0,
                         ";".join(f"{m}={rel[m]}" for m in METHODS)))
        for r in RHOS:
            res = run_methods(tr, CostParams(rho=r), methods=METHODS,
                              cost_model="table1")
            rel = relative_to_opt(res)
            payload["rho"].setdefault(kind, {})[r] = rel
            rows.append((f"fig6b/{kind}/rho={r}", 0,
                         ";".join(f"{m}={rel[m]}" for m in METHODS)))
    save_json("fig6_sensitivity", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
