"""Fig. 6 — sensitivity to (a) discount factor alpha, (b) cost ratio
rho = lambda/mu (the paper reuses the symbol gamma for this; we keep rho).

The whole (trace x alpha + trace x rho) grid goes through ONE
``run_method_grid`` sweep call (PR 5): the alpha axis shares a single
clique-generation schedule per trace (alpha never enters the CGM), and
every schedule group replays as one vmapped device scan.
"""
from __future__ import annotations

from .common import (
    N_SWEEP, emit, get_trace_shards, relative_to_opt, run_method_grid,
    save_json,
)
from repro.core import CostParams

ALPHAS = [0.6, 0.7, 0.8, 0.85, 0.9, 1.0]
RHOS = [1.0, 2.0, 4.0, 6.0, 10.0]
METHODS = ("no_packing", "packcache", "akpc", "opt")
KINDS = ("netflix", "spotify")


def main() -> list[tuple]:
    grid, keys = [], []
    for kind in KINDS:
        tr = get_trace_shards(kind, N_SWEEP)
        for a in ALPHAS:
            grid.append({"trace": tr, "params": CostParams(alpha=a),
                         "methods": METHODS, "cost_model": "table1"})
            keys.append(("alpha", kind, a))
        for r in RHOS:
            grid.append({"trace": tr, "params": CostParams(rho=r),
                         "methods": METHODS, "cost_model": "table1"})
            keys.append(("rho", kind, r))
    results = run_method_grid(grid)

    rows, payload = [], {"alpha": {}, "rho": {}, "cost_model": "table1"}
    for (axis, kind, val), res in zip(keys, results):
        rel = relative_to_opt(res)
        payload[axis].setdefault(kind, {})[val] = rel
        tag = "fig6a" if axis == "alpha" else "fig6b"
        rows.append((f"{tag}/{kind}/{axis}={val}", 0,
                     ";".join(f"{m}={rel[m]}" for m in METHODS)))
    save_json("fig6_sensitivity", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
