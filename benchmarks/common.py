"""Shared benchmark machinery: trace cache, method runners, CSV emit.

Every figure benchmark replays the SAME seeded synthetic traces (paper
§V.A setup, see repro.traces.synthetic.paper_trace and EXPERIMENTS.md for
the deviation analysis vs the proprietary Kaggle dumps) through the method
set of Fig. 5, resolved from the unified policy registry
(``repro.core.get_policy`` / ``run_policy``):

  no_packing / dp_greedy (offline 2-pack) / packcache (online 2-pack) /
  akpc_base (w/o CS, w/o ACM) / akpc (proposed) / opt (lower bound)

Costs are reported relative to OPT (paper convention, OPT = 1).
"""
from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

from repro.core import (
    CacheEnvironment, CostParams, get_cost_model, get_policy, opt_lower_bound,
    run_policy,
)
from repro.traces import paper_trace

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "experiments/results")
N_REQUESTS = int(os.environ.get("REPRO_BENCH_REQUESTS", "150000"))
N_SWEEP = int(os.environ.get("REPRO_BENCH_SWEEP_REQUESTS", "40000"))
#: >1 splits each figure's request budget over per-seed trace replicas
#: (the SweepEngine trace-shard vmap axis) so figs report mean +- 95% CI
N_SHARDS = int(os.environ.get("REPRO_BENCH_SHARDS", "1"))

@functools.lru_cache(maxsize=8)
def get_trace(kind: str, n_requests: int, seed: int = 0):
    return paper_trace(kind, n_requests=n_requests, seed=seed)


@functools.lru_cache(maxsize=8)
def get_trace_shards(kind: str, n_requests: int, shards: int | None = None,
                     seed0: int = 0):
    """The figure workload as a trace-shard tuple (or one trace).

    ``shards`` defaults to ``REPRO_BENCH_SHARDS``; above 1 the request
    budget splits across per-seed replicas that replay as extra vmap
    lanes of one sweep call (``SweepPoint`` shard axis), so every
    ``run_method_grid`` entry gains ``shard_stats`` (mean +- 95% CI of
    the per-shard totals) at near-zero marginal device cost.  At the
    default 1 this IS ``get_trace`` — figure payloads stay bitwise."""
    k = N_SHARDS if shards is None else int(shards)
    if k <= 1:
        return get_trace(kind, n_requests, seed0)
    return tuple(
        paper_trace(kind, n_requests=max(1, n_requests // k),
                    seed=seed0 + i)
        for i in range(k))


def t_cg_for(trace, params: CostParams | None = None,
             env: CacheEnvironment | None = None,
             cost_model: str = "table1") -> float:
    """Clique-generation period: a small multiple of the cache TTL dt —
    long enough to observe co-access, short enough to track drift.
    (Regenerating much faster than dt churns partitions and loses cached
    presence; see EXPERIMENTS.md §Fig5 notes.)  The TTL comes from the
    registered cost model (max over servers under heterogeneous prices),
    not from CostParams internals."""
    if env is None:
        env = CacheEnvironment(trace.n, trace.m, params or CostParams())
    dt = float(get_cost_model(cost_model, env).dt().max())
    span = float(trace.times[-1] - trace.times[0])
    return float(min(max(0.3 * dt, span / 50.0), max(span / 4.0, 1e-6)))


def method_policies(params: CostParams, t_cg: float, top_frac: float) -> dict:
    """Fig.-5 method set as (registry name -> policy kwargs)."""
    return {
        "no_packing": {},
        "ttl": dict(t_cg=t_cg),
        "learned": dict(t_cg=t_cg),   # warm-start scorer (no trained params)
        "dp_greedy": dict(top_frac=top_frac),
        "packcache": dict(t_cg=t_cg, top_frac=top_frac),
        "akpc_base": dict(t_cg=t_cg, top_frac=top_frac),
        "akpc": dict(t_cg=t_cg, top_frac=top_frac),
    }


def _result_entry(res) -> dict:
    """One method's payload entry from a RunResult (shared by the serial
    run_methods and the sweep-backed run_method_grid, so both paths emit
    the identical JSON shape)."""
    entry = {
        "total": res.total,
        "transfer": res.costs.transfer,
        "caching": res.costs.caching,
        "seconds": round(res.wall_seconds, 2),
    }
    if (res.clique_sizes > 1).any():
        entry["clique_sizes"] = np.bincount(res.clique_sizes).tolist()
    if getattr(res, "shard_stats", None):
        entry["shard_stats"] = res.shard_stats
    return entry


def _maybe_add_opt(out: dict, trace, params, env, cost_model, methods) -> None:
    """Attach the OPT lower bound when requested and valid for the model.

    For a trace-shard tuple the bound is the SUM of per-shard bounds —
    the same aggregation ``SweepEngine`` applies to the policy costs, so
    opt-relative numbers stay comparable under sharding."""
    if methods is not None and "opt" not in methods:
        return
    from repro.core.baselines import OPT_BOUND_MODELS

    if cost_model not in OPT_BOUND_MODELS:
        # no valid lower bound of this form (e.g. tiered) — callers
        # compare against no_packing instead
        return
    t0 = time.perf_counter()
    shards = trace if isinstance(trace, (list, tuple)) else (trace,)
    totals = np.zeros(3, np.float64)
    for tr in shards:
        costs = opt_lower_bound(tr, params, env=env, cost_model=cost_model)
        totals += (costs.total, costs.transfer, costs.caching)
    out["opt"] = {
        "total": float(totals[0]),
        "transfer": float(totals[1]),
        "caching": float(totals[2]),
        "seconds": round(time.perf_counter() - t0, 2),
    }


def run_methods(trace, params: CostParams, methods=None, top_frac: float = 1.0,
                env: CacheEnvironment | None = None,
                cost_model: str = "table1"):
    """Returns {method: {total, transfer, caching, seconds}}.

    ``env``/``cost_model`` select the pricing scenario (default: the paper's
    homogeneous Table-I regime; fig10 passes heterogeneous environments).
    """
    # one resolution for policies AND the opt bound, so both price the
    # same scenario (threads trace.sizes into a price-only env)
    env = CacheEnvironment.resolve(env, trace, params)
    t_cg = t_cg_for(trace, params, env=env, cost_model=cost_model)
    out = {}
    for name, kw in method_policies(params, t_cg, top_frac).items():
        if methods is not None and name not in methods:
            continue
        res = run_policy(
            get_policy(name, params=params, env=env, cost_model=cost_model,
                       **kw),
            trace,
        )
        out[name] = _result_entry(res)
    _maybe_add_opt(out, trace, params, env, cost_model, methods)
    return out


def run_method_grid(grid: list[dict], backend: str | None = None,
                    layout=None) -> list[dict]:
    """Sweep MANY (trace, params, scenario) points in ONE vmapped call.

    Each grid entry takes the :func:`run_methods` keyword set
    (``trace`` required; ``params``, ``methods``, ``top_frac``, ``env``,
    ``cost_model`` optional, plus ``t_cg`` to OVERRIDE the derived
    clique-gen period — fig8's batch axis sweeps it directly) and each
    returned entry has the same
    ``{method: {total, transfer, caching, seconds}}`` shape — so the fig
    drivers swap a loop of ``run_methods`` calls for one
    ``run_method_grid`` call without changing their payloads.

    ``layout`` is a :class:`repro.core.state_layout.StateLayout` (or
    kind string) for the device state geometry; ``"bucketed"`` lets a
    mixed-(n, m) grid compile per bucket cohort instead of per point.

    All policy replays go through :class:`repro.core.SweepEngine`:
    scenarios sharing (trace x clique-gen hyperparameters) share one
    host schedule, and every group replays as one vmapped device scan
    (``REPRO_SWEEP_BACKEND=numpy`` restores the serial loop; it also
    engages automatically when JAX is missing or a cost model has no JAX
    formula).  OPT lower bounds are closed-form and stay host-side.
    """
    from repro.core import SweepEngine, SweepPoint
    from repro.core.engine_jax import HAS_JAX, JAX_COST_MODELS

    if backend is None:
        backend = os.environ.get("REPRO_SWEEP_BACKEND", "")
        backend = backend or ("jax" if HAS_JAX else "numpy")
    if backend == "jax" and any(
            g.get("cost_model", "table1") not in JAX_COST_MODELS
            for g in grid):
        backend = "numpy"

    pts, slots, resolved = [], [], []
    for gi, g in enumerate(grid):
        trace = g["trace"]
        # a tuple/list of traces is the shard axis (get_trace_shards):
        # scenario resolution reads the representative first shard
        tr0 = trace[0] if isinstance(trace, (list, tuple)) else trace
        params = g.get("params") or CostParams()
        env = CacheEnvironment.resolve(g.get("env"), tr0, params)
        cost_model = g.get("cost_model", "table1")
        methods = g.get("methods")
        t_cg = g.get("t_cg")
        if t_cg is None:
            t_cg = t_cg_for(tr0, params, env=env, cost_model=cost_model)
        resolved.append((trace, params, env, cost_model, methods))
        for name, kw in method_policies(
                params, t_cg, g.get("top_frac", 1.0)).items():
            if methods is not None and name not in methods:
                continue
            pts.append(SweepPoint(
                name, trace,
                dict(params=params, env=env, cost_model=cost_model, **kw)))
            slots.append(gi)

    res = SweepEngine(backend=backend, layout=layout).run(pts)
    out: list[dict] = [{} for _ in grid]
    for pt, gi, r in zip(pts, slots, res):
        out[gi][pt.policy] = _result_entry(r)

    for gi, (trace, params, env, cost_model, methods) in enumerate(resolved):
        _maybe_add_opt(out[gi], trace, params, env, cost_model, methods)
    return out


def relative_to_opt(res: dict, reference: str = "opt") -> dict:
    """Totals relative to ``reference`` (default: the OPT lower bound).

    run_methods omits "opt" for cost models without a valid bound (e.g.
    tiered pricing) — there, pick the reference EXPLICITLY, e.g.
    ``relative_to_opt(res, reference="no_packing")``, so opt-relative and
    baseline-relative numbers can never be confused."""
    if reference not in res:
        raise KeyError(
            f"no {reference!r} entry in results (no valid OPT bound for "
            'this cost model?); pass reference="no_packing" explicitly')
    base = res[reference]["total"]
    return {k: round(v["total"] / base, 4) for k, v in res.items()}


def emit(rows: list[tuple]) -> None:
    """CSV to stdout: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path
