"""Shared benchmark machinery: trace cache, method runners, CSV emit.

Every figure benchmark replays the SAME seeded synthetic traces (paper
§V.A setup, see repro.traces.synthetic.paper_trace and EXPERIMENTS.md for
the deviation analysis vs the proprietary Kaggle dumps) through the method
set of Fig. 5:

  no_packing / dp_greedy (offline 2-pack) / packcache (online 2-pack) /
  akpc_base (w/o CS, w/o ACM) / akpc (proposed) / opt (lower bound)

Costs are reported relative to OPT (paper convention, OPT = 1).
"""
from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

from repro.core import (
    AKPCConfig,
    CostParams,
    opt_lower_bound,
    run_akpc,
    run_akpc_variant,
    run_dp_greedy,
    run_no_packing,
    run_packcache2,
)
from repro.traces import paper_trace

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "experiments/results")
N_REQUESTS = int(os.environ.get("REPRO_BENCH_REQUESTS", "150000"))
N_SWEEP = int(os.environ.get("REPRO_BENCH_SWEEP_REQUESTS", "40000"))

@functools.lru_cache(maxsize=8)
def get_trace(kind: str, n_requests: int, seed: int = 0):
    return paper_trace(kind, n_requests=n_requests, seed=seed)


def t_cg_for(trace, params: CostParams | None = None) -> float:
    """Clique-generation period: a small multiple of the cache TTL dt —
    long enough to observe co-access, short enough to track drift.
    (Regenerating much faster than dt churns partitions and loses cached
    presence; see EXPERIMENTS.md §Fig5 notes.)"""
    dt = (params or CostParams()).dt
    span = float(trace.times[-1] - trace.times[0])
    return float(min(max(0.3 * dt, span / 50.0), max(span / 4.0, 1e-6)))


def run_methods(trace, params: CostParams, methods=None, top_frac: float = 1.0):
    """Returns {method: {total, transfer, caching, seconds}}."""
    t_cg = t_cg_for(trace, params)
    out = {}

    def record(name, fn):
        if methods is not None and name not in methods:
            return
        t0 = time.perf_counter()
        res = fn()
        dt = time.perf_counter() - t0
        costs = res.costs if hasattr(res, "costs") else res
        out[name] = {
            "total": costs.total,
            "transfer": costs.transfer,
            "caching": costs.caching,
            "seconds": round(dt, 2),
        }
        if hasattr(res, "clique_sizes"):
            sizes = res.clique_sizes
            out[name]["clique_sizes"] = np.bincount(sizes).tolist()

    record("no_packing", lambda: run_no_packing(trace, params))
    record("dp_greedy", lambda: run_dp_greedy(trace, params, top_frac=top_frac))
    record("packcache", lambda: run_packcache2(trace, params, t_cg=t_cg,
                                               top_frac=top_frac))
    record("akpc_base", lambda: run_akpc_variant(
        trace, params, split=False, approx_merge=False, t_cg=t_cg,
        top_frac=top_frac))
    record("akpc", lambda: run_akpc(trace, AKPCConfig(
        params=params, t_cg=t_cg, top_frac=top_frac)))
    record("opt", lambda: opt_lower_bound(trace, params))
    return out


def relative_to_opt(res: dict) -> dict:
    opt = res["opt"]["total"]
    return {k: round(v["total"] / opt, 4) for k, v in res.items()}


def emit(rows: list[tuple]) -> None:
    """CSV to stdout: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path
