"""Shared benchmark machinery: trace cache, method runners, CSV emit.

Every figure benchmark replays the SAME seeded synthetic traces (paper
§V.A setup, see repro.traces.synthetic.paper_trace and EXPERIMENTS.md for
the deviation analysis vs the proprietary Kaggle dumps) through the method
set of Fig. 5, resolved from the unified policy registry
(``repro.core.get_policy`` / ``run_policy``):

  no_packing / dp_greedy (offline 2-pack) / packcache (online 2-pack) /
  akpc_base (w/o CS, w/o ACM) / akpc (proposed) / opt (lower bound)

Costs are reported relative to OPT (paper convention, OPT = 1).
"""
from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

from repro.core import CostParams, get_policy, opt_lower_bound, run_policy
from repro.traces import paper_trace

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "experiments/results")
N_REQUESTS = int(os.environ.get("REPRO_BENCH_REQUESTS", "150000"))
N_SWEEP = int(os.environ.get("REPRO_BENCH_SWEEP_REQUESTS", "40000"))

@functools.lru_cache(maxsize=8)
def get_trace(kind: str, n_requests: int, seed: int = 0):
    return paper_trace(kind, n_requests=n_requests, seed=seed)


def t_cg_for(trace, params: CostParams | None = None) -> float:
    """Clique-generation period: a small multiple of the cache TTL dt —
    long enough to observe co-access, short enough to track drift.
    (Regenerating much faster than dt churns partitions and loses cached
    presence; see EXPERIMENTS.md §Fig5 notes.)"""
    dt = (params or CostParams()).dt
    span = float(trace.times[-1] - trace.times[0])
    return float(min(max(0.3 * dt, span / 50.0), max(span / 4.0, 1e-6)))


def method_policies(params: CostParams, t_cg: float, top_frac: float) -> dict:
    """Fig.-5 method set as (registry name -> policy kwargs)."""
    return {
        "no_packing": {},
        "dp_greedy": dict(top_frac=top_frac),
        "packcache": dict(t_cg=t_cg, top_frac=top_frac),
        "akpc_base": dict(t_cg=t_cg, top_frac=top_frac),
        "akpc": dict(t_cg=t_cg, top_frac=top_frac),
    }


def run_methods(trace, params: CostParams, methods=None, top_frac: float = 1.0):
    """Returns {method: {total, transfer, caching, seconds}}."""
    t_cg = t_cg_for(trace, params)
    out = {}
    for name, kw in method_policies(params, t_cg, top_frac).items():
        if methods is not None and name not in methods:
            continue
        res = run_policy(get_policy(name, params=params, **kw), trace)
        out[name] = {
            "total": res.total,
            "transfer": res.costs.transfer,
            "caching": res.costs.caching,
            "seconds": round(res.wall_seconds, 2),
        }
        if (res.clique_sizes > 1).any():
            out[name]["clique_sizes"] = np.bincount(res.clique_sizes).tolist()
    if methods is None or "opt" in methods:
        t0 = time.perf_counter()
        costs = opt_lower_bound(trace, params)
        out["opt"] = {
            "total": costs.total,
            "transfer": costs.transfer,
            "caching": costs.caching,
            "seconds": round(time.perf_counter() - t0, 2),
        }
    return out


def relative_to_opt(res: dict) -> dict:
    opt = res["opt"]["total"]
    return {k: round(v["total"] / opt, 4) for k, v in res.items()}


def emit(rows: list[tuple]) -> None:
    """CSV to stdout: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path
