"""Roofline table from the dry-run artifacts (experiments/dryrun.jsonl)."""
from __future__ import annotations

import json
import os

from .common import emit, save_json

DRYRUN = os.environ.get("REPRO_DRYRUN", "experiments/dryrun.jsonl")


def load_records(path=DRYRUN):
    recs = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    recs.append(json.loads(line))
                except json.JSONDecodeError:
                    pass
    return recs


def main() -> list[tuple]:
    rows = []
    recs = [r for r in load_records() if r.get("mesh") == "single"]
    ok = [r for r in recs if r.get("status") == "ok"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}", 0,
            f"dom={r['dominant']};compute_s={r['compute_s']:.3g};"
            f"memory_s={r['memory_s']:.3g};collective_s={r['collective_s']:.3g};"
            f"useful={r['useful_flops_ratio']};frac={r['roofline_fraction']};"
            f"fits={r['fits_hbm']}"
        ))
    skipped = [r for r in recs if r.get("status") == "skipped"]
    for r in skipped:
        rows.append((f"roofline/{r['arch']}/{r['shape']}", 0, "N/A(sub-quadratic-only)"))
    save_json("roofline_report", {"n_ok": len(ok), "n_skipped": len(skipped)})
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
