"""Kernel benchmarks: Pallas kernels (interpret mode on CPU) vs jnp oracles.

Wall-times on CPU interpret mode are NOT TPU perf — the structural metrics
(DMA descriptor counts, bytes per descriptor, MXU tile utilisation) are the
meaningful output here; they drive the packed-vs-unpacked comparison the
paper's cost model predicts ((1+(p-1)a) vs p per bundle).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from .common import emit, save_json
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)                                 # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    return (time.perf_counter() - t0) / reps, out


def main() -> list[tuple]:
    rng = np.random.default_rng(0)
    rows, payload = [], {}

    # CRM accumulation: B requests x n items
    for B, n in [(200, 60), (2000, 600), (8000, 1024)]:
        H = (rng.random((B, n)) < 0.03).astype(np.float32)
        t_ref, want = _time(lambda h: np.asarray(ref.crm_ref(jnp.array(h))), H)
        t_k, got = _time(lambda h: ops.crm_matmul(jnp.array(h)), H)
        ok = bool(np.allclose(got, want))
        mxu_tiles = (-(-n // 128)) ** 2 * (-(-B // 128))
        rows.append((f"kernel/crm_update/B{B}_n{n}", int(t_k * 1e6),
                     f"allclose={ok};oracle_us={int(t_ref*1e6)};mxu_tiles={mxu_tiles}"))
        payload[f"crm_B{B}_n{n}"] = {"ok": ok, "kernel_s": t_k, "oracle_s": t_ref}

    # clique density
    for k, n in [(60, 60), (200, 512)]:
        M = (rng.random((k, n)) < 0.08).astype(np.float32)
        A = (rng.random((n, n)) < 0.2).astype(np.float32)
        t_ref, want = _time(lambda m, a: np.asarray(
            ref.clique_pair_edges_ref(jnp.array(m), jnp.array(a))), M, A)
        t_k, got = _time(lambda m, a: ops.pair_edges(jnp.array(m), jnp.array(a)), M, A)
        ok = bool(np.allclose(got, want))
        rows.append((f"kernel/clique_density/k{k}_n{n}", int(t_k * 1e6),
                     f"allclose={ok};oracle_us={int(t_ref*1e6)}"))
        payload[f"density_k{k}_n{n}"] = {"ok": ok}

    # packed vs unpacked lookup: descriptor counts tell the story
    omega, d, R, C = 5, 256, 64, 128
    table = rng.normal(size=(C, omega, d)).astype(np.float32)
    items = table.reshape(C * omega, d)
    cids = rng.integers(0, C, R).astype(np.int32)
    iids = (cids[:, None] * omega + np.arange(omega)[None, :]).astype(np.int32)
    t_p, got_p = _time(lambda: np.asarray(ops.gather_packed(jnp.array(table), jnp.array(cids))))
    t_u, got_u = _time(lambda: np.asarray(ops.gather_unpacked(jnp.array(items), jnp.array(iids))))
    ok = bool(np.allclose(got_p, got_u))
    rows.append(("kernel/packed_lookup", int(t_p * 1e6),
                 f"allclose={ok};dma_descriptors={R};bytes_per_dma={omega*d*4}"))
    rows.append(("kernel/unpacked_lookup", int(t_u * 1e6),
                 f"dma_descriptors={R*omega};bytes_per_dma={d*4};descriptor_ratio={omega}x"))
    payload["packed_lookup"] = {"ok": ok, "packed_descr": R,
                                "unpacked_descr": R * omega}
    save_json("kernel_bench", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
