"""Fig. 5 — total cost of every method vs OPT on both traces (stacked
transfer/caching components).

Both traces' full method sets are replayed in ONE ``run_method_grid``
sweep call (vmapped JAX scan backend; PR 5) instead of serial per-method
replays.
"""
from __future__ import annotations

from .common import (
    N_REQUESTS, emit, get_trace_shards, relative_to_opt, run_method_grid,
    save_json,
)
from repro.core import CostParams

KINDS = ("netflix", "spotify")


def main() -> list[tuple]:
    params = CostParams()                     # Table II base values
    # the paper's scenario == the registry's default "table1" model;
    # REPRO_BENCH_SHARDS > 1 adds the trace-shard axis (mean +- CI)
    grid = [
        {"trace": get_trace_shards(kind, N_REQUESTS), "params": params,
         "cost_model": "table1"}
        for kind in KINDS
    ]
    results = run_method_grid(grid)
    rows, payload = [], {}
    for kind, res in zip(KINDS, results):
        rel = relative_to_opt(res)
        payload[kind] = {"raw": res, "relative": rel, "cost_model": "table1"}
        for m, v in rel.items():
            ct = res[m]["transfer"] / res["opt"]["total"]
            rows.append((f"fig5/{kind}/{m}", int(res[m]["seconds"] * 1e6),
                         f"rel_total={v};rel_transfer={round(ct, 4)}"))
        akpc_vs_pc = 1 - res["akpc"]["total"] / res["packcache"]["total"]
        rows.append((f"fig5/{kind}/akpc_vs_packcache_saving", 0,
                     f"{round(100 * akpc_vs_pc, 1)}%"))
    save_json("fig5_cost_comparison", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
