"""Fig. 5 — total cost of every method vs OPT on both traces (stacked
transfer/caching components)."""
from __future__ import annotations

from .common import N_REQUESTS, emit, get_trace, relative_to_opt, run_methods, save_json
from repro.core import CostParams


def main() -> list[tuple]:
    params = CostParams()                     # Table II base values
    rows, payload = [], {}
    for kind in ("netflix", "spotify"):
        tr = get_trace(kind, N_REQUESTS)
        # the paper's scenario == the registry's default "table1" model
        res = run_methods(tr, params, cost_model="table1")
        rel = relative_to_opt(res)
        payload[kind] = {"raw": res, "relative": rel, "cost_model": "table1"}
        for m, v in rel.items():
            ct = res[m]["transfer"] / res["opt"]["total"]
            rows.append((f"fig5/{kind}/{m}", int(res[m]["seconds"] * 1e6),
                         f"rel_total={v};rel_transfer={round(ct, 4)}"))
        akpc_vs_pc = 1 - res["akpc"]["total"] / res["packcache"]["total"]
        rows.append((f"fig5/{kind}/akpc_vs_packcache_saving", 0,
                     f"{round(100 * akpc_vs_pc, 1)}%"))
    save_json("fig5_cost_comparison", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
