"""Fig. 9 — (a) clique-size distribution across AKPC variants,
(b) clique-generation wall time vs number of data items (up to 10k)."""
from __future__ import annotations

import time

import numpy as np

from .common import N_SWEEP, emit, get_trace, save_json, t_cg_for
from repro.core import CostParams, get_policy, run_policy
from repro.core.crm import build_window_crm
from repro.core.cliques import generate_cliques
from repro.traces import SynthConfig, synth_trace

RUNTIME_ITEMS = [100, 1000, 4000, 10000]


def main() -> list[tuple]:
    rows, payload = [], {"dist": {}, "runtime": {}}
    params = CostParams()
    for kind in ("netflix", "spotify"):
        tr = get_trace(kind, N_SWEEP)
        t_cg = t_cg_for(tr, params)
        variants = {
            name: run_policy(
                get_policy(name, params=params, t_cg=t_cg, top_frac=1.0), tr)
            for name in ("akpc", "akpc_no_acm", "akpc_base")
        }
        for name, res in variants.items():
            sizes = np.concatenate(res.size_history) if res.size_history else np.array([])
            hist = np.bincount(sizes.astype(int), minlength=11)[:11].tolist() if sizes.size else []
            mean = float(sizes.mean()) if sizes.size else 0.0
            payload["dist"].setdefault(kind, {})[name] = {
                "hist": hist, "mean": round(mean, 2)}
            rows.append((f"fig9a/{kind}/{name}", 0,
                         f"mean_size={round(mean,2)};hist={hist}"))

    # (b) clique-generation runtime: one window over n items (top-10% mined)
    for n in RUNTIME_ITEMS:
        tr = synth_trace(SynthConfig(
            kind="spotify", n_items=n, n_servers=100, n_requests=20000,
            t_max=20.0, bundle_cover=1.0, bundle_zipf=0.7, seed=0))
        t0 = time.perf_counter()
        crm = build_window_crm(tr.items, n, theta=0.2, top_frac=0.1)
        part = generate_cliques(None, None, crm, n, omega=5, gamma=0.85)
        dt = time.perf_counter() - t0
        payload["runtime"][n] = round(dt, 4)
        rows.append((f"fig9b/items={n}", int(dt * 1e6),
                     f"seconds={round(dt,3)};cliques={sum(1 for c in part.cliques if len(c)>1)}"))
    save_json("fig9_cliques_runtime", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
