"""Fig. 9 — (a) clique-size distribution across AKPC variants,
(b) clique-generation wall time vs number of data items (up to 10k).

``--smoke`` (CI) runs only the (b) runtime sweep on a small item grid and
fails loudly when the vectorized CGM regresses to at or past the pre-PR-3
scalar implementation's wall time (``PRE_VECTORIZATION_BASELINE``).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from .common import N_SWEEP, emit, get_trace, save_json, t_cg_for
from repro.core import CostParams, get_policy, run_policy
from repro.core.crm import build_window_crm
from repro.core.cliques import generate_cliques
from repro.traces import SynthConfig, synth_trace

RUNTIME_ITEMS = [100, 1000, 4000, 10000]
SMOKE_ITEMS = [1000, 4000]

#: catalog sizes for the device-resident CGM timing (BENCH_cgm.json).
#: The compact hot-space carry (DESIGN.md §15) lifted the old 256-item
#: auto-routing ceiling, so this sweep now reaches fig9-scale catalogs.
DEVICE_CGM_ITEMS = [64, 1000, 4000]

#: wall seconds of this same sweep under the pre-vectorization (scalar)
#: CGM, recorded before PR 3 on the reference container — the regression
#: bar for --smoke and the denominator of the reported speedups
PRE_VECTORIZATION_BASELINE = {100: 0.0045, 1000: 0.0232, 4000: 0.1373,
                              10000: 0.6229}


def _runtime_trace(n: int):
    return synth_trace(SynthConfig(
        kind="spotify", n_items=n, n_servers=100, n_requests=20000,
        t_max=20.0, bundle_cover=1.0, bundle_zipf=0.7, seed=0))


def _time_clique_gen(n: int, reps: int = 5) -> tuple[float, int]:
    """One clique-generation event over a 20k-request window on n items.

    Best of ``reps`` repetitions — a single cold pass mostly measures
    allocator/page-cache warmup once the event itself is millisecond-scale.
    ``top_frac_of="catalog"`` pins the pre-PR-3 hot-set semantics so the
    workload is identical to the one PRE_VECTORIZATION_BASELINE timed.
    """
    tr = _runtime_trace(n)
    dt = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        crm = build_window_crm(tr.items, n, theta=0.2, top_frac=0.1,
                               top_frac_of="catalog")
        part = generate_cliques(None, None, crm, n, omega=5, gamma=0.85)
        dt = min(dt, time.perf_counter() - t0)
    return dt, sum(1 for c in part.cliques if len(c) > 1)


def _time_clique_gen_oracle(n: int) -> float:
    """Same event through the frozen scalar oracle (the --smoke yardstick:
    timed on the same machine, so the gate is hardware-independent)."""
    from repro.core import cliques_ref

    tr = _runtime_trace(n)
    t0 = time.perf_counter()
    crm = build_window_crm(tr.items, n, theta=0.2, top_frac=0.1,
                           top_frac_of="catalog")
    cliques_ref.generate_cliques(None, None, crm, n, omega=5, gamma=0.85)
    return time.perf_counter() - t0


def _device_cgm_trace(n: int):
    return synth_trace(SynthConfig(
        kind="spotify", n_items=n, n_servers=20, n_requests=8000,
        t_max=20.0, bundle_cover=1.0, bundle_zipf=0.7, seed=0))


def _time_device_cgm(n: int) -> dict | None:
    """Warm wall time of a fully device-resident windowed replay (CGM
    inside the jit'd scan, DESIGN.md §11) vs the host-CGM jax path on the
    same trace — the PR-6 seam recorded in BENCH_cgm.json.

    Warm times (one compile pass first): the steady state every sweep
    lane pays.  Returns None when jax is unavailable.
    """
    import os

    try:
        from repro.core.engine_jax import HAS_JAX, run_policy_jax
    except Exception:
        return None
    if not HAS_JAX:
        return None
    tr = _device_cgm_trace(n)
    params = CostParams()
    t_cg = t_cg_for(tr, params)

    def timed(mode: str) -> tuple[float, int]:
        old = os.environ.get("REPRO_JAX_CGM")
        os.environ["REPRO_JAX_CGM"] = mode
        try:
            run_policy_jax(
                get_policy("akpc", params=params, t_cg=t_cg,
                           top_frac=0.5), tr)        # compile pass
            t0 = time.perf_counter()
            res = run_policy_jax(
                get_policy("akpc", params=params, t_cg=t_cg,
                           top_frac=0.5), tr)
            return time.perf_counter() - t0, res.n_windows
        finally:
            if old is None:
                os.environ.pop("REPRO_JAX_CGM", None)
            else:
                os.environ["REPRO_JAX_CGM"] = old

    dev, n_windows = timed("force")
    host, _ = timed("off")
    return {
        "device_seconds": round(dev, 4),
        "host_jax_seconds": round(host, 4),
        "n_windows": n_windows,
        "device_us_per_window": round(dev / max(1, n_windows) * 1e6),
    }


def main(smoke: bool = False) -> list[tuple]:
    rows, payload = [], {"dist": {}, "runtime": {}}
    payload["runtime_baseline_pre_vectorization"] = {
        str(k): v for k, v in PRE_VECTORIZATION_BASELINE.items()
    }
    params = CostParams()

    # (b) clique-generation runtime: one window over n items (top-10% mined).
    # Timed before the (a) policy sweeps — their replay allocations fragment
    # the arena enough to skew millisecond-scale timings.
    regressions = []
    for n in (SMOKE_ITEMS if smoke else RUNTIME_ITEMS):
        dt, n_cliques = _time_clique_gen(n)
        base = PRE_VECTORIZATION_BASELINE.get(n)
        speedup = round(base / dt, 1) if base else None
        payload["runtime"][n] = round(dt, 4)
        if base:
            payload.setdefault("speedup_vs_pre_vectorization", {})[n] = speedup
        rows.append((f"fig9b/items={n}", int(dt * 1e6),
                     f"seconds={round(dt,4)};cliques={n_cliques};"
                     f"speedup={speedup}"))
        if smoke:
            # gate against the scalar oracle ON THIS MACHINE — absolute
            # baseline constants would misfire on slow/loaded CI runners
            oracle = _time_clique_gen_oracle(n)
            payload.setdefault("runtime_scalar_oracle", {})[n] = round(oracle, 4)
            if dt >= oracle:
                regressions.append(
                    f"items={n}: vectorized {dt:.4f}s >= scalar oracle "
                    f"{oracle:.4f}s on this machine"
                )

    if not smoke:
        for kind in ("netflix", "spotify"):
            tr = get_trace(kind, N_SWEEP)
            t_cg = t_cg_for(tr, params)
            variants = {
                name: run_policy(
                    get_policy(name, params=params, t_cg=t_cg, top_frac=1.0), tr)
                for name in ("akpc", "akpc_no_acm", "akpc_base")
            }
            for name, res in variants.items():
                sizes = np.concatenate(res.size_history) if res.size_history else np.array([])
                hist = np.bincount(sizes.astype(int), minlength=11)[:11].tolist() if sizes.size else []
                mean = float(sizes.mean()) if sizes.size else 0.0
                payload["dist"].setdefault(kind, {})[name] = {
                    "hist": hist, "mean": round(mean, 2)}
                rows.append((f"fig9a/{kind}/{name}", 0,
                             f"mean_size={round(mean,2)};hist={hist}"))

    # device-resident CGM timing (PR 6): the windowed replay with clique
    # generation inside the scan vs the host-CGM jax path, per catalog size
    cgm_items = {}
    for n in DEVICE_CGM_ITEMS:
        row = _time_device_cgm(n)
        if row is None:
            break
        cgm_items[n] = row
        rows.append((
            f"bench_cgm/items={n}", int(row["device_seconds"] * 1e6),
            f"device={row['device_seconds']}s;"
            f"host_jax={row['host_jax_seconds']}s;"
            f"windows={row['n_windows']};"
            f"us_per_window={row['device_us_per_window']}"))
    if cgm_items:
        # merge-write: fig7's compact_vs_dense_vs_host breakdown lives in
        # the same file, so preserve whatever keys are already there
        import json
        import os

        from .common import RESULTS_DIR

        cgm_payload = {}
        path = os.path.join(RESULTS_DIR, "BENCH_cgm.json")
        if os.path.exists(path):
            with open(path) as f:
                cgm_payload = json.load(f)
        cgm_payload.update({"trace": "spotify/8000req", "items": cgm_items})
        save_json("BENCH_cgm", cgm_payload)

    save_json("fig9_cliques_runtime", payload)
    emit(rows)
    if regressions:
        print("CGM RUNTIME REGRESSION:\n  " + "\n  ".join(regressions),
              file=sys.stderr)
        sys.exit(1)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small item sweep + regression gate (CI)")
    main(smoke=ap.parse_args().smoke)
