"""Fig. 8 — scalability: (a) #servers, (b) #data points, (c) batch size.

Batch size maps to requests per T_CG window (the paper batches 200 requests;
larger windows expose more co-access to the clique miner).

This is the figure that varies (n, m) per point, so it runs the WHOLE
mixed grid — all three axes — through ONE ``SweepEngine`` call on the
JAX backend under a ``bucketed`` :class:`~repro.core.state_layout
.StateLayout`: points whose (n, m) round up to the same padding bucket
share one compiled scan, so the sweep compiles per bucket COHORT, not
per point.  ``--smoke`` (CI) runs a reduced mixed grid and asserts the
two ISSUE-8 contracts: 1e-9 per-point cost parity vs the serial numpy
engine, and compile count <= #bucket-cohorts.
"""
from __future__ import annotations

import numpy as np

from .common import N_SWEEP, emit, save_json
from repro.core.state_layout import StateLayout
from repro.traces import SynthConfig, synth_trace

SERVERS = [60, 150, 300, 600, 1200]
ITEMS = [60, 240, 960, 3600]
BATCHES = [50, 100, 200, 500]
METHODS = ("akpc", "no_packing", "opt")
#: fig8 bucket steps: coarse enough that the servers axis collapses to a
#: few column cohorts and every item count <= 4096 shares one row bucket
LAYOUT = StateLayout(kind="bucketed", row_bucket=1024, col_bucket=256)

SMOKE_SERVERS = [60, 300]
SMOKE_ITEMS = [60, 240]
SMOKE_BATCHES = [50, 200]
SMOKE_REQUESTS = 4000


def _trace(n_items=60, n_servers=600, seed=0, n_requests=N_SWEEP):
    return synth_trace(SynthConfig(
        kind="netflix", n_items=n_items, n_servers=n_servers,
        n_requests=n_requests, t_max=6.0 * n_requests / 100_000.0,
        bundle_cover=1.0, bundle_zipf=0.7, server_affinity=2, seed=seed))


def build_grid(smoke: bool = False):
    """The full mixed-(n, m) fig8 grid as ONE run_method_grid input.

    Returns (grid, labels): labels[i] = ("servers"|"items"|"batch", value)
    names the axis point grid[i] carries.
    """
    nreq = SMOKE_REQUESTS if smoke else N_SWEEP
    grid, labels = [], []
    for m in (SMOKE_SERVERS if smoke else SERVERS):
        grid.append({"trace": _trace(n_servers=m, n_requests=nreq),
                     "methods": METHODS})
        labels.append(("servers", m))
    for n in (SMOKE_ITEMS if smoke else ITEMS):
        grid.append({"trace": _trace(n_items=n, n_requests=nreq),
                     "methods": METHODS})
        labels.append(("items", n))
    tr = _trace(n_requests=nreq)
    span = float(tr.times[-1] - tr.times[0])
    for b in (SMOKE_BATCHES if smoke else BATCHES):
        # batch size -> clique-gen window of b requests on average
        grid.append({"trace": tr, "methods": METHODS,
                     "t_cg": span * b / tr.n_requests})
        labels.append(("batch", b))
    return grid, labels


def n_cohorts(grid) -> int:
    """Bucket cohorts of the grid = distinct padded state dims.  The
    compile-count contract: one scan trace per cohort, not per point."""
    return len({LAYOUT.state_dims(g["trace"].n, g["trace"].m) for g in grid})


def _run(grid, backend: str):
    from .common import run_method_grid
    from repro.core import engine_jax as ej

    traces0 = ej.SCAN_TRACES
    res = run_method_grid(
        grid, backend=backend, layout=LAYOUT if backend == "jax" else None)
    return res, ej.SCAN_TRACES - traces0


def _payload(grid, labels, res, compiles: int) -> dict:
    payload = {"servers": {}, "items": {}, "batch": {}}
    for (axis, val), g, r in zip(labels, grid, res):
        ref = r.get("opt") or r["no_packing"]
        rel = {k: round(v["total"] / ref["total"], 4) for k, v in r.items()}
        payload[axis][val] = {"rel": rel, "akpc_abs": r["akpc"]["total"]}
    tr0 = grid[0]["trace"]
    payload["state_layout"] = {
        "tag": LAYOUT.tag, "row_bucket": LAYOUT.row_bucket,
        "col_bucket": LAYOUT.col_bucket,
        "points": len(grid), "cohorts": n_cohorts(grid),
        "compiles": compiles,
        # catalog-scale memory telemetry: the padding overhead of the
        # coarsest point vs its dense footprint
        "state_bytes": {
            f"{axis}={val}": LAYOUT.state_bytes(g["trace"].n, g["trace"].m)
            for (axis, val), g in zip(labels, grid)},
        "dense_bytes_first_point": StateLayout().state_bytes(tr0.n, tr0.m),
    }
    return payload


def main(smoke: bool = False) -> list[tuple]:
    grid, labels = build_grid(smoke)
    res, compiles = _run(grid, "jax")
    payload = _payload(grid, labels, res, compiles)

    rows = []
    base = {}
    for (axis, val), r in zip(labels, res):
        rel = payload[axis][val]["rel"]
        if axis not in base:
            base[axis] = r["akpc"]["total"]
        rows.append((f"fig8{'abc'['servers items batch'.split().index(axis)]}"
                     f"/{axis}={val}", 0,
                     f"akpc_rel={rel['akpc']};"
                     f"abs_vs_base={round(r['akpc']['total'] / base[axis], 2)}"))
    rows.append(("fig8/compiles", compiles,
                 f"cohorts={n_cohorts(grid)};points={len(grid)}"))

    if smoke:
        # ISSUE-8 gates: compile count <= #cohorts, 1e-9 parity vs numpy
        k = n_cohorts(grid)
        assert 1 < k < len(grid), \
            f"smoke grid must be mixed-shape: {k} cohorts of {len(grid)}"
        assert compiles <= k, \
            f"bucketed sweep compiled {compiles}x for {k} cohorts"
        ref, _ = _run(grid, "numpy")
        for (axis, val), r, rr in zip(labels, res, ref):
            for meth in ("akpc", "no_packing"):
                a, b = r[meth]["total"], rr[meth]["total"]
                assert np.isclose(a, b, rtol=1e-9, atol=1e-9), \
                    f"{axis}={val} {meth}: jax {a} != numpy {b}"
        print(f"fig8 --smoke: {len(grid)} points, {k} cohorts, "
              f"{compiles} compiles, numpy parity 1e-9 OK", flush=True)

    save_json("fig8_scalability", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced mixed grid + parity/compile-count gates")
    main(smoke=ap.parse_args().smoke)
