"""Fig. 8 — scalability: (a) #servers, (b) #data points, (c) batch size.

Batch size maps to requests per T_CG window (the paper batches 200 requests;
larger windows expose more co-access to the clique miner)."""
from __future__ import annotations

from .common import N_SWEEP, emit, relative_to_opt, run_methods, save_json, t_cg_for
from repro.core import CostParams, get_policy, opt_lower_bound, run_policy
from repro.traces import SynthConfig, synth_trace

SERVERS = [60, 150, 300, 600, 1200]
ITEMS = [60, 240, 960, 3600]
BATCHES = [50, 100, 200, 500]
METHODS = ("akpc", "no_packing", "opt")


def _trace(n_items=60, n_servers=600, seed=0):
    return synth_trace(SynthConfig(
        kind="netflix", n_items=n_items, n_servers=n_servers,
        n_requests=N_SWEEP, t_max=6.0 * N_SWEEP / 100_000.0,
        bundle_cover=1.0, bundle_zipf=0.7, server_affinity=2, seed=seed))


def main() -> list[tuple]:
    rows, payload = [], {"servers": {}, "items": {}, "batch": {}}
    params = CostParams()
    base_total = None
    for m in SERVERS:
        tr = _trace(n_servers=m)
        res = run_methods(tr, params, methods=METHODS)
        rel = relative_to_opt(res)
        payload["servers"][m] = {"rel": rel, "akpc_abs": res["akpc"]["total"]}
        if base_total is None:
            base_total = res["akpc"]["total"]
        rows.append((f"fig8a/servers={m}", 0,
                     f"akpc_rel={rel['akpc']};abs_vs_60={round(res['akpc']['total']/base_total,2)}"))
    base_total = None
    for n in ITEMS:
        tr = _trace(n_items=n)
        res = run_methods(tr, params, methods=METHODS)
        rel = relative_to_opt(res)
        payload["items"][n] = {"rel": rel, "akpc_abs": res["akpc"]["total"]}
        if base_total is None:
            base_total = res["akpc"]["total"]
        rows.append((f"fig8b/items={n}", 0,
                     f"akpc_rel={rel['akpc']};abs_vs_60={round(res['akpc']['total']/base_total,2)}"))
    tr = _trace()
    for b in BATCHES:
        # batch size -> clique-gen window of b requests on average
        span = float(tr.times[-1] - tr.times[0])
        t_cg = span * b / tr.n_requests
        res = run_policy(
            get_policy("akpc", params=params, t_cg=t_cg, top_frac=1.0), tr)
        opt = opt_lower_bound(tr, params)
        rel = res.total / opt.total
        payload["batch"][b] = rel
        rows.append((f"fig8c/batch={b}", 0, f"akpc_rel={round(rel,4)}"))
    save_json("fig8_scalability", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
