"""Fig. 11 — NEW scenario axis beyond the paper: non-stationary request
load (Carlsson & Eager's time-varying arrival model, arXiv 1803.03914)
stress-ranking EVERY registered policy, including the ``learned``
keep-or-not policy trained on a held-out trace of the same scenario.

Four load profiles x two pricing models, every policy, three eval-seed
trace shards per point — all replayed in ONE ``SweepEngine`` grid (the
shard axis rides as extra vmap lanes, so per-scenario dispersion CIs come
back at near-zero marginal device cost).  Per (scenario, model) the
payload records the merged totals, the per-shard ``shard_stats`` and the
resulting policy RANKING; results land in ``BENCH_learned.json``.

Scenario economics: ``rho = 4.0`` (the paper's fig6 sensitivity axis)
widens the prepaid-rent stake of every keep decision — the regime where
keep-or-not policies (ttl, learned) separate from always-keep packers.
``load_strength`` per profile is tuned so the arrival-rate swing actually
moves item economics across T_CG windows (regime_shift drops the rate to
0.25x at 40% of the horizon; flash_crowd spikes 4x).

``--smoke`` is the CI gate (small traces, regime_shift/table1 only):

* the trained ``learned`` policy must STRICTLY beat ``no_packing`` AND at
  least one non-AKPC baseline (ttl / packcache / dp_greedy);
* numpy vs jax replay of the trained policy agrees to 1e-9;
* training stays within its compile budget (TRAIN_TRACES delta <= 2 per
  ``train_policy`` call).
"""
from __future__ import annotations

import sys

import numpy as np

from .common import emit, save_json, t_cg_for
from repro.core import (
    CacheEnvironment, CostParams, SweepEngine, SweepPoint, list_policies,
    run_policy,
)
from repro.core.engine_jax import HAS_JAX, JAX_COST_MODELS
from repro.learned import train_policy
from repro.traces import SynthConfig, synth_trace

#: load profile -> load_strength (diurnal amplitude / crowd height x base /
#: regime rate ratio — see repro.traces.synthetic.load_rate)
SCENARIOS = {
    "stationary": 0.0,
    "diurnal": 0.8,
    "flash_crowd": 4.0,
    "regime_shift": 0.25,
}
MODELS = ("table1", "heterogeneous")
#: canonical registry names (aliases like packcache2 resolve to these)
POLICIES = ("no_packing", "ttl", "dp_greedy", "packcache",
            "akpc_base", "akpc_no_acm", "akpc", "learned")
N_ITEMS, N_SERVERS = 60, 12
TRAIN_SEED = 200
EVAL_SEEDS = (101, 102, 103)
#: t_max = 0.1 * n_requests: ~8.3 requests per server per unit time over
#: 60 items — hot-item revisit gaps straddle the rho=4 TTL, so keep/evict
#: is a real decision (denser: everything stays fresh; sparser: nothing).
TIME_PER_REQUEST = 0.1


def stress_trace(profile: str, seed: int, n_requests: int):
    """One non-stationary trace; content is seed-determined, only arrival
    times differ across profiles (inverse-CDF warp of the same draws)."""
    return synth_trace(SynthConfig(
        kind="netflix", n_items=N_ITEMS, n_servers=N_SERVERS,
        n_requests=n_requests, t_max=TIME_PER_REQUEST * n_requests,
        bundle_cover=1.0, bundle_zipf=0.7, server_affinity=2,
        load_profile=profile, load_strength=SCENARIOS[profile],
        load_peak=0.4, seed=seed,
    ))


def env_for(cost_model: str, params: CostParams) -> CacheEnvironment | None:
    """Pricing environment per model: homogeneous Table-I, or skewed
    per-server prices + lognormal item sizes for ``heterogeneous``."""
    if cost_model == "heterogeneous":
        return CacheEnvironment.skewed(
            N_ITEMS, N_SERVERS, params, price_sigma=0.8, size_sigma=0.5,
            seed=1)
    return None


def policy_kwargs(name: str, t_cg: float, lp) -> dict:
    if name == "no_packing":
        return {}
    if name == "dp_greedy":
        return {}
    if name == "learned":
        return dict(t_cg=t_cg, learned=lp)
    return dict(t_cg=t_cg)


def run_grid(n_requests: int, eval_seeds=EVAL_SEEDS,
             scenarios=tuple(SCENARIOS), models=MODELS,
             policies=POLICIES) -> dict:
    """Train per (scenario, model), then rank ALL policies over the
    eval-seed shard axis in ONE SweepEngine call."""
    assert set(policies) <= {  # every canonical registry policy is ranked
        name for name in list_policies()}, (policies, list_policies())
    params = CostParams(rho=4.0)
    backend = ("jax" if HAS_JAX
               and all(m in JAX_COST_MODELS for m in models) else "numpy")

    pts, keys = [], []
    for cm in models:
        env = env_for(cm, params)
        for profile in scenarios:
            train_tr = stress_trace(profile, TRAIN_SEED, n_requests)
            tcg = t_cg_for(train_tr, params, env=env, cost_model=cm)
            lp = train_policy(train_tr, env=env, t_cg=tcg, params=params,
                              cost_model=cm)
            shards = tuple(stress_trace(profile, s, n_requests)
                           for s in eval_seeds)
            for name in policies:
                pts.append(SweepPoint(
                    name, shards,
                    dict(params=params, env=env, cost_model=cm,
                         **policy_kwargs(name, tcg, lp)),
                    tag=f"{profile}/{cm}"))
                keys.append((profile, cm, name))

    res = SweepEngine(backend=backend).run(pts)

    payload: dict = {
        "n_requests": n_requests, "rho": params.rho,
        "eval_seeds": list(eval_seeds), "backend": backend, "grid": {},
    }
    for (profile, cm, name), r in zip(keys, res):
        cell = payload["grid"].setdefault(f"{profile}/{cm}", {})
        cell[name] = {
            "total": r.costs.total, "transfer": r.costs.transfer,
            "caching": r.costs.caching, "shard_stats": r.shard_stats,
        }
    for key, cell in payload["grid"].items():
        ranking = sorted(policies, key=lambda p: cell[p]["total"])
        cell["ranking"] = ranking
        cell["learned_rank"] = ranking.index("learned") + 1
        cell["learned_vs_no_packing_saving_pct"] = round(
            100.0 * (1.0 - cell["learned"]["total"]
                     / cell["no_packing"]["total"]), 2)
    return payload


def smoke() -> int:
    """CI gate on the smallest scenario where the learned ranking signal
    is stable: regime_shift x table1 (see module docstring)."""
    import repro.learned.train as lt

    n_requests, eval_seeds = 2500, (101, 102)
    params = CostParams(rho=4.0)
    train_tr = stress_trace("regime_shift", TRAIN_SEED, n_requests)
    tcg = t_cg_for(train_tr, params, cost_model="table1")

    traces0 = lt.TRAIN_TRACES
    lp = train_policy(train_tr, t_cg=tcg, params=params)
    n_compiles = lt.TRAIN_TRACES - traces0
    print(f"fig11 --smoke: train compiles={n_compiles}")
    if n_compiles > 2:
        print("FAIL: train_policy exceeded its compile budget (<= 2)")
        return 1

    shards = tuple(stress_trace("regime_shift", s, n_requests)
                   for s in eval_seeds)
    rivals = ("ttl", "packcache", "dp_greedy")
    pts = [SweepPoint(name, shards,
                      dict(params=params,
                           **policy_kwargs(name, tcg, lp)))
           for name in ("no_packing", *rivals, "learned")]
    res = {p.policy: r for p, r in zip(pts, SweepEngine().run(pts))}
    totals = {k: r.costs.total for k, r in res.items()}
    print("fig11 --smoke: " + " ".join(
        f"{k}={v:.0f}" for k, v in sorted(totals.items(),
                                          key=lambda kv: kv[1])))
    if totals["learned"] >= totals["no_packing"]:
        print("FAIL: trained policy does not beat no_packing on the "
              "regime-shift stress trace")
        return 1
    if not any(totals["learned"] < totals[r] for r in rivals):
        print(f"FAIL: trained policy beats none of {rivals}")
        return 1

    if HAS_JAX:
        from repro.core import get_policy

        tr = shards[0]
        t_np = run_policy(
            get_policy("learned", params=params, t_cg=tcg, learned=lp),
            tr).costs.total
        t_jx = run_policy(
            get_policy("learned", params=params, t_cg=tcg, learned=lp),
            tr, backend="jax").costs.total
        print(f"fig11 --smoke: parity numpy={t_np:.9f} jax={t_jx:.9f}")
        if abs(t_np - t_jx) > 1e-9:
            print("FAIL: numpy/jax replay of the learned policy disagree")
            return 1
    print("OK")
    return 0


def main() -> list[tuple]:
    payload = run_grid(int(sys.argv[sys.argv.index("--requests") + 1])
                       if "--requests" in sys.argv else 6000)
    rows = []
    for key, cell in payload["grid"].items():
        rows.append((
            f"fig11/{key}", 0,
            "rank=" + ">".join(cell["ranking"])
            + f";learned_saving={cell['learned_vs_no_packing_saving_pct']}%",
        ))
    save_json("BENCH_learned", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(smoke())
    main()
