"""Fig. 7 — hyper-parameter sensitivity: (a) CRM threshold theta,
(b) clique-approximation threshold gamma, (c) max clique size omega.

All three axes over both traces run as ONE ``run_method_grid`` sweep
call (PR 5).  Since PR 6 the clique-generation module itself runs inside
the jit'd scan (DESIGN.md §11), so a theta x gamma x omega grid shares
ONE partition-free schedule and vmaps the CGM knobs as scenario lanes.

``--smoke`` (CI) is the device-CGM oracle gate: the on-device clique
generation must reproduce the frozen ``cliques_ref`` oracle
element-for-element at EVERY chained T_CG boundary over a small
theta x gamma x omega grid, and a fig7-style sweep must perform ZERO
host clique-generation calls (the ``cliques.CGM_CALLS`` counter stays
flat) while sharing one schedule.

It also runs the compact-CGM perf gate (same style as the fig9 gate:
the shipped implementation against its predecessor, timed on the same
machine): on a catalog far above the old 256-item cap, the compact
hot-space boundary's per-window marginal must beat the full
``(n, n)``-workspace layout it replaced, with the host CGM walk
recorded alongside in ``BENCH_cgm.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from .common import (
    N_SWEEP, RESULTS_DIR, emit, get_trace, get_trace_shards,
    relative_to_opt, run_method_grid, save_json, t_cg_for,
)
from repro.core import CostParams

THETAS = [0.05, 0.1, 0.15, 0.2, 0.3, 0.5]
GAMMAS = [0.6, 0.7, 0.8, 0.85, 0.9, 1.0]
OMEGAS = [2, 3, 5, 7, 10]
METHODS = ("akpc", "akpc_base", "opt")
KINDS = ("netflix", "spotify")

SMOKE_THETAS = (0.1, 0.3)
SMOKE_GAMMAS = (0.6, 0.9)
SMOKE_OMEGAS = (3, 5)
SMOKE_TOP_FRAC = 0.5


def main() -> list[tuple]:
    grid, keys = [], []
    for kind in KINDS:
        tr = get_trace_shards(kind, N_SWEEP)
        for axis, values, mk in (
            ("theta", THETAS, lambda v: CostParams(theta=v)),
            ("gamma", GAMMAS, lambda v: CostParams(gamma=v)),
            ("omega", OMEGAS, lambda v: CostParams(omega=v)),
        ):
            for v in values:
                grid.append({"trace": tr, "params": mk(v),
                             "methods": METHODS, "cost_model": "table1"})
                keys.append((axis, kind, v))
    results = run_method_grid(grid)

    rows, payload = [], {"theta": {}, "gamma": {}, "omega": {}}
    tags = {"theta": "fig7a", "gamma": "fig7b", "omega": "fig7c"}
    for (axis, kind, val), res in zip(keys, results):
        rel = relative_to_opt(res)
        payload[axis].setdefault(kind, {})[val] = rel
        rows.append((f"{tags[axis]}/{kind}/{axis}={val}", 0,
                     f"akpc={rel['akpc']};base={rel['akpc_base']}"))
    save_json("fig7_hyperparams", payload)
    emit(rows)
    return rows


def smoke() -> None:
    """CI gate: device-CGM partitions == ``cliques_ref`` oracle, chained."""
    from repro.core import (
        CacheEnvironment, SweepEngine, SweepPoint, get_policy,
    )
    from repro.core import cgm_jax
    from repro.core import cliques as cliques_mod
    from repro.core import cliques_ref
    from repro.core.crm import build_window_crm
    from repro.core.engine_jax import JaxReplayEngine

    tr = get_trace("netflix", 4000)
    t_cg = t_cg_for(tr, CostParams())
    combos = [(th, g, om) for th in SMOKE_THETAS for g in SMOKE_GAMMAS
              for om in SMOKE_OMEGAS]

    def kw(th, g, om):
        return dict(params=CostParams(theta=th, gamma=g, omega=om),
                    t_cg=t_cg, top_frac=SMOKE_TOP_FRAC)

    def oracle_walk(theta, gamma, omega):
        """cliques_ref at every T_CG boundary, the replay engines' walk."""
        times, R = tr.times, tr.n_requests
        next_cg = float(times[0]) + t_cg
        win_start = pos = 0
        prev = prev_crm = None
        parts = []
        while pos < R:
            cut = int(np.searchsorted(times, next_cg, side="left"))
            if cut <= pos:
                t = float(times[pos])
                crm = build_window_crm(
                    tr.items[win_start:pos], tr.n, theta,
                    top_frac=SMOKE_TOP_FRAC)
                prev = cliques_ref.generate_cliques(
                    prev, prev_crm, crm, tr.n, omega, gamma)
                parts.append(prev.clique_of.copy())
                prev_crm = crm
                win_start = pos
                while next_cg <= t:
                    next_cg += t_cg
                continue
            pos = cut
        return parts

    # -- one vmapped device call over the whole grid -----------------------
    pol0 = get_policy("akpc", **kw(*combos[0]))
    pol0.bind(tr.n, tr.m)
    env = CacheEnvironment.resolve(None, tr, pol0.params)
    jeng = JaxReplayEngine(tr.n, tr.m, pol0.params, env=env)
    sched = cgm_jax.build_cgm_schedule(tr, t_cg, uses_sizes=False)
    nbd = int(sched.boundary_steps.size)
    assert nbd >= 3, f"need chained windows, got {nbd}"
    cspecs = []
    for c in combos:
        p = get_policy("akpc", **kw(*c))
        p.bind(tr.n, tr.m)
        cspecs.append(cgm_jax.cgm_spec(p.config, p.config.params, tr.n))
    cspec = {k: np.stack([np.asarray(cs[k]) for cs in cspecs])
             for k in cspecs[0]}
    S = len(combos)
    carry1 = cgm_jax.init_cgm_carry(
        jeng.engine.state, None, None, n=tr.n, m=tr.m,
        uses_sizes=False, item_sizes=None, schedule=sched)
    carry0 = {k: np.stack([v] * S) for k, v in carry1.items()}
    spec = {k: np.stack([v] * S) for k, v in jeng._spec.items()}
    before = cliques_mod.CGM_CALLS
    final, ofs = cgm_jax.run_cgm_schedule(
        sched, spec, jeng._statics, cspec, carry0, None)
    failures = []
    if cliques_mod.CGM_CALLS != before:
        failures.append("device replay performed host CGM calls")
    for lane, (th, g, om) in enumerate(combos):
        want = oracle_walk(th, g, om)
        if len(want) != nbd:
            failures.append(f"theta={th} gamma={g} omega={om}: "
                            f"{len(want)} oracle windows vs {nbd} device")
            continue
        bad = [w for w, (b, ref_of) in
               enumerate(zip(sched.boundary_steps, want))
               if not np.array_equal(ofs[lane, int(b)], ref_of)]
        if bad or not np.array_equal(final["of"][lane], want[-1]):
            failures.append(f"theta={th} gamma={g} omega={om}: partition "
                            f"mismatch at windows {bad or ['final']}")

    # -- a fig7-style sweep: one schedule, zero host CGM calls -------------
    eng = SweepEngine()
    before = cliques_mod.CGM_CALLS
    eng.run([SweepPoint("akpc", tr, kw(*c)) for c in combos])
    if cliques_mod.CGM_CALLS != before:
        failures.append("fig7 sweep performed host CGM calls")
    if eng.last_n_schedules != 1:
        failures.append(f"fig7 sweep built {eng.last_n_schedules} "
                        "schedules, expected 1 shared")

    # -- perf gate (fig9-gate style: the shipped implementation against
    # its predecessor, timed on the same machine).  The compact hot-space
    # boundary must beat the full (n, n)-catalog workspace it replaced
    # per window on a catalog far above the old 256-item cap; both
    # variants compute the SAME partitions, so the timing comparison is
    # also a layout-parity check.  The host CGM walk rides along as the
    # recorded yardstick (BENCH_cgm.json "compact_vs_dense_vs_host").
    perf = _perf_breakdown()
    if perf["compact_us_per_window"] >= perf["dense_us_per_window"]:
        failures.append(
            f"compact device CGM {perf['compact_us_per_window']}us/window "
            f">= dense (n, n) workspace {perf['dense_us_per_window']}"
            "us/window on this machine (the compact hot space must win)")
    if not perf["layouts_agree"]:
        failures.append(
            "compact and dense (n, n) workspaces produced DIFFERENT "
            "partitions — the layouts must be semantics-preserving")

    emit([("fig7/smoke_oracle_gate", 0,
           f"grid={S}pts;windows={nbd};"
           f"status={'FAIL' if failures else 'OK'}"),
          ("fig7/smoke_cgm_perf_gate", perf["compact_us_per_window"],
           f"n={perf['n']};windows={perf['windows']};"
           f"compact_us_per_window={perf['compact_us_per_window']};"
           f"dense_us_per_window={perf['dense_us_per_window']};"
           f"host_us_per_window={perf['host_us_per_window']};"
           f"speedup_vs_dense={perf['speedup_vs_dense']};"
           f"status={'FAIL' if failures else 'OK'}")])
    if failures:
        print("DEVICE-CGM ORACLE GATE FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        sys.exit(1)
    print(f"# device-CGM oracle gate: {S} grid points x {nbd} chained "
          "windows, all partitions identical, zero host CGM calls")
    print(f"# compact-CGM perf gate: n={perf['n']} "
          f"compact={perf['compact_us_per_window']}us/window vs "
          f"dense={perf['dense_us_per_window']}us "
          f"({perf['speedup_vs_dense']}x) vs "
          f"host={perf['host_us_per_window']}us")


#: perf-gate catalog — far above the old MAX_DEVICE_CGM_N = 256 cap, so
#: the compact (h, h) workspace is genuinely smaller than the (n, n)
#: predecessor layout it is timed against
PERF_N_ITEMS = 2000
PERF_N_REQUESTS = 3000
PERF_N_WINDOWS = 12


def _perf_breakdown() -> dict:
    """Per-window wall time of the device-CGM boundary in the compact
    hot space vs the dense ``(n, n)`` predecessor workspace vs the
    vectorized host CGM — all on the same trace and machine.

    Device costs are replay MARGINALS: the same schedule replayed with
    boundaries enabled minus a clique-generation-zeroed replay, so the
    shared scan cost cancels and only the Alg. 2-4 boundary work is
    charged.  The dense variant is the SAME compact machinery with the
    workspace forced to the full catalog (``h = n``) — what every
    boundary paid before the compact carry — and must reproduce the
    compact partitions element-for-element.
    """
    import dataclasses
    import time

    from repro.core import (
        CacheEnvironment, CostParams, cgm_jax, get_policy,
    )
    from repro.core import cliques as cliques_mod
    from repro.core.crm import build_window_crm
    from repro.core.engine_jax import JaxReplayEngine
    from repro.traces import SynthConfig, synth_trace

    tr = synth_trace(SynthConfig(
        kind="spotify", n_items=PERF_N_ITEMS, n_servers=20,
        n_requests=PERF_N_REQUESTS, t_max=20.0, bundle_cover=1.0,
        bundle_zipf=0.7, seed=0))
    span = float(tr.times[-1] - tr.times[0])
    t_cg = span / PERF_N_WINDOWS
    params = CostParams()
    pol = get_policy("akpc", params=params, t_cg=t_cg,
                     top_frac=SMOKE_TOP_FRAC)
    pol.bind(tr.n, tr.m)
    env = CacheEnvironment.resolve(None, tr, pol.params)
    jeng = JaxReplayEngine(tr.n, tr.m, pol.params, env=env)
    sched = cgm_jax.build_cgm_schedule(
        tr, t_cg, uses_sizes=False, hot_dims=cgm_jax.policy_hot_dims(pol))
    nbd = int(sched.boundary_steps.size)
    cspec = cgm_jax.cgm_spec(pol.config, pol.config.params, tr.n)

    def marginal(schedule):
        carry0 = cgm_jax.init_cgm_carry(
            jeng.engine.state, None, None, n=tr.n, m=tr.m,
            uses_sizes=False, item_sizes=None, schedule=schedule)
        zeroed = dataclasses.replace(
            schedule, xs=dict(schedule.xs,
                              cg=np.zeros_like(schedule.xs["cg"])))

        def run(s):
            final, ofs = cgm_jax.run_cgm_schedule(
                s, jeng._spec, jeng._statics, cspec, carry0, None)
            return np.asarray(final["of"]), np.asarray(ofs)

        of, ofs = run(schedule)          # compile + warm
        run(zeroed)
        t_force = t_zero = float("inf")
        for _ in range(3):               # interleaved, min-based
            t0 = time.perf_counter()
            run(schedule)
            t_force = min(t_force, time.perf_counter() - t0)
            t0 = time.perf_counter()
            run(zeroed)
            t_zero = min(t_zero, time.perf_counter() - t0)
        return (t_force - t_zero) / nbd, of, ofs

    compact_pw, of_c, ofs_c = marginal(sched)
    dense_pw, of_d, ofs_d = marginal(dataclasses.replace(sched, h=tr.n))

    def host_walk():
        prev = prev_crm = None
        win_start = pos = 0
        next_cg = float(tr.times[0]) + t_cg
        while pos < tr.n_requests:
            cut = int(np.searchsorted(tr.times, next_cg, side="left"))
            if cut <= pos:
                crm = build_window_crm(
                    tr.items[win_start:pos], tr.n, float(params.theta),
                    top_frac=SMOKE_TOP_FRAC)
                prev = cliques_mod.generate_cliques(
                    prev, prev_crm, crm, tr.n, int(params.omega),
                    float(params.gamma))
                prev_crm = crm
                win_start = pos
                t_now = float(tr.times[pos])
                while next_cg <= t_now:
                    next_cg += t_cg
                continue
            pos = cut

    host_walk()                          # warm caches
    host = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        host_walk()
        host = min(host, time.perf_counter() - t0)
    host_pw = host / nbd

    perf = {
        "n": PERF_N_ITEMS,
        "windows": nbd,
        "compact_h": int(sched.h),
        "compact_us_per_window": round(compact_pw * 1e6),
        "dense_us_per_window": round(dense_pw * 1e6),
        "host_us_per_window": round(host_pw * 1e6),
        "speedup_vs_dense": round(dense_pw / max(compact_pw, 1e-12), 1),
        "layouts_agree": bool(np.array_equal(of_c, of_d)
                              and np.array_equal(ofs_c, ofs_d)),
    }
    payload = {}
    path = os.path.join(RESULTS_DIR, "BENCH_cgm.json")
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["compact_vs_dense_vs_host"] = perf
    save_json("BENCH_cgm", payload)
    return perf


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="device-CGM vs cliques_ref oracle gate (CI)")
    if ap.parse_args().smoke:
        smoke()
    else:
        main()
