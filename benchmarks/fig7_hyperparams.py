"""Fig. 7 — hyper-parameter sensitivity: (a) CRM threshold theta,
(b) clique-approximation threshold gamma, (c) max clique size omega."""
from __future__ import annotations

from .common import N_SWEEP, emit, get_trace, relative_to_opt, run_methods, save_json
from repro.core import CostParams

THETAS = [0.05, 0.1, 0.15, 0.2, 0.3, 0.5]
GAMMAS = [0.6, 0.7, 0.8, 0.85, 0.9, 1.0]
OMEGAS = [2, 3, 5, 7, 10]
METHODS = ("akpc", "akpc_base", "opt")


def main() -> list[tuple]:
    rows, payload = [], {"theta": {}, "gamma": {}, "omega": {}}
    for kind in ("netflix", "spotify"):
        tr = get_trace(kind, N_SWEEP)
        for th in THETAS:
            rel = relative_to_opt(run_methods(
                tr, CostParams(theta=th), methods=METHODS))
            payload["theta"].setdefault(kind, {})[th] = rel
            rows.append((f"fig7a/{kind}/theta={th}", 0,
                         f"akpc={rel['akpc']};base={rel['akpc_base']}"))
        for g in GAMMAS:
            rel = relative_to_opt(run_methods(
                tr, CostParams(gamma=g), methods=METHODS))
            payload["gamma"].setdefault(kind, {})[g] = rel
            rows.append((f"fig7b/{kind}/gamma={g}", 0,
                         f"akpc={rel['akpc']};base={rel['akpc_base']}"))
        for w in OMEGAS:
            rel = relative_to_opt(run_methods(
                tr, CostParams(omega=w), methods=METHODS))
            payload["omega"].setdefault(kind, {})[w] = rel
            rows.append((f"fig7c/{kind}/omega={w}", 0,
                         f"akpc={rel['akpc']};base={rel['akpc_base']}"))
    save_json("fig7_hyperparams", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
