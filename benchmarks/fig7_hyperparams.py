"""Fig. 7 — hyper-parameter sensitivity: (a) CRM threshold theta,
(b) clique-approximation threshold gamma, (c) max clique size omega.

All three axes over both traces run as ONE ``run_method_grid`` sweep
call (PR 5).  Unlike fig6, every point here changes the clique-generation
module itself, so each point keeps its own host schedule — the win is the
vmapped replay of the points that share static shapes.
"""
from __future__ import annotations

from .common import (
    N_SWEEP, emit, get_trace, relative_to_opt, run_method_grid, save_json,
)
from repro.core import CostParams

THETAS = [0.05, 0.1, 0.15, 0.2, 0.3, 0.5]
GAMMAS = [0.6, 0.7, 0.8, 0.85, 0.9, 1.0]
OMEGAS = [2, 3, 5, 7, 10]
METHODS = ("akpc", "akpc_base", "opt")
KINDS = ("netflix", "spotify")


def main() -> list[tuple]:
    grid, keys = [], []
    for kind in KINDS:
        tr = get_trace(kind, N_SWEEP)
        for axis, values, mk in (
            ("theta", THETAS, lambda v: CostParams(theta=v)),
            ("gamma", GAMMAS, lambda v: CostParams(gamma=v)),
            ("omega", OMEGAS, lambda v: CostParams(omega=v)),
        ):
            for v in values:
                grid.append({"trace": tr, "params": mk(v),
                             "methods": METHODS, "cost_model": "table1"})
                keys.append((axis, kind, v))
    results = run_method_grid(grid)

    rows, payload = [], {"theta": {}, "gamma": {}, "omega": {}}
    tags = {"theta": "fig7a", "gamma": "fig7b", "omega": "fig7c"}
    for (axis, kind, val), res in zip(keys, results):
        rel = relative_to_opt(res)
        payload[axis].setdefault(kind, {})[val] = rel
        rows.append((f"{tags[axis]}/{kind}/{axis}={val}", 0,
                     f"akpc={rel['akpc']};base={rel['akpc_base']}"))
    save_json("fig7_hyperparams", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
