"""Sustained serving throughput: LiveServingEngine vs the numpy session.

The PR-7 acceptance benchmark.  A serving front-end does NOT see the
whole trace up front — requests arrive in small batches (a routing step,
an RPC burst), and the cache layer is on the hot path of every one.  This
bench replays paper-style traces as STREAMED ARRIVAL SLICES (default 128
requests per call) through three engines:

* **numpy**  — :class:`repro.core.session.CacheSession`: every ``feed``
  pays the full host pipeline (batch tensors, event walk, window CGM);
* **live (cold)** — :class:`repro.serving.live.LiveServingEngine`, first
  process use: slices buffer into fixed-shape 64k-request device chunks
  dispatched asynchronously over a small ring, so the per-call cost is an
  append; the one-off XLA compile of the donated-buffer step is included;
* **live (warm)** — a second engine in the same process: the compiled
  step is reused (``engine.compiles == 0``), the steady state of a
  long-running server.

Before any timing is trusted, the drained live totals are checked against
the OFFLINE ``run_policy`` replay of the same trace at 1e-9 (integer
counters exact) — the engine may only be fast because it is the same
accounting, on the same partition trajectory, with state held on device.

Load is non-stationary (traces/synthetic.py load profiles): a serving
bench under constant arrival rate would miss exactly the bursts that
stress the chunk ring, so each scenario is one profile — ``diurnal``
(day/night cycle), ``flash_crowd`` (viral surge), ``regime_shift``
(catalog launch step).

Results land in ``experiments/results/BENCH_serve.json`` with cold and
warm numbers, like BENCH_sweep.

Env knobs:
  REPRO_SERVE_BENCH_REQUESTS   trace length per scenario (default 150000)
  REPRO_SERVE_BENCH_SLICE      requests per arrival slice (default 128)

``--smoke`` (CI): one 60k-request flash-crowd scenario; parity + the warm
live engine must BEAT the streamed numpy session's req/s (no ratio floor
— CI runners are too noisy to gate on one; the full run records the
measured speedups for the perf trajectory).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core import CostParams, get_policy, run_policy
from repro.serving import LiveServingEngine
from repro.core.session import CacheSession
from repro.traces import SynthConfig, synth_trace

from .common import emit, save_json, t_cg_for
from .sweep_bench import state_bytes_telemetry

INT_FIELDS = ("n_requests", "n_item_requests", "n_misses", "n_hits",
              "items_transferred")
FLOAT_FIELDS = ("transfer", "caching", "keepalive_rent", "total")

PROFILES = ("diurnal", "flash_crowd", "regime_shift")
PARAMS = CostParams()


def serve_trace(profile: str, n_requests: int, seed: int = 0):
    """Paper-style (Table-II) trace at serving density, arrival times
    warped through the non-stationary load profile."""
    return synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=240, n_requests=n_requests,
        t_max=6.0 * n_requests / 100_000.0, bundle_cover=1.0,
        bundle_zipf=0.7, server_affinity=2, mean_session_len=6.0,
        seed=seed, load_profile=profile,
    ))


def _policy(trace):
    return get_policy("akpc", params=PARAMS,
                      t_cg=t_cg_for(trace, PARAMS), top_frac=1.0)


def stream(sess, trace, slice_n: int) -> float:
    """Feed the trace as arrival slices; returns wall seconds (drained)."""
    items, servers, times = trace.items, trace.servers, trace.times
    t0 = time.perf_counter()
    for lo in range(0, trace.n_requests, slice_n):
        hi = lo + slice_n
        sess.feed(items[lo:hi], servers[lo:hi], times[lo:hi])
    drain = getattr(sess, "drain", None)
    if drain is not None:
        drain()                      # settle in-flight chunks + tail buffer
    return time.perf_counter() - t0


def assert_parity(tag: str, ref, got) -> None:
    a, b = ref.as_dict(), got.as_dict()
    for f in INT_FIELDS:
        assert a[f] == b[f], (tag, f, a[f], b[f])
    for f in FLOAT_FIELDS:
        assert np.isclose(a[f], b[f], rtol=1e-9, atol=1e-9), \
            (tag, f, a[f], b[f])


def bench_profile(profile: str, n_requests: int, slice_n: int) -> dict:
    trace = serve_trace(profile, n_requests)
    ref = run_policy(_policy(trace), trace)      # offline ground truth

    # -- streamed numpy session (the pre-PR-7 serving path) ---------------
    sess = CacheSession(_policy(trace), trace.n, trace.m)
    t_numpy = stream(sess, trace, slice_n)
    assert_parity(f"{profile}/numpy", ref.costs, sess.costs)

    # -- live engine: cold (includes the donated-buffer step compile) -----
    live = LiveServingEngine(_policy(trace), trace.n, trace.m,
                             chunk_size=65536, ring=6)
    t_cold = stream(live, trace, slice_n)
    compiles_cold = live.compiles
    assert_parity(f"{profile}/live", ref.costs, live.costs)

    # -- live engine: warm (compiled step reused across engines) ----------
    live2 = LiveServingEngine(_policy(trace), trace.n, trace.m,
                              chunk_size=65536, ring=6)
    t_warm = stream(live2, trace, slice_n)
    compiles_warm = live2.compiles
    assert_parity(f"{profile}/live_warm", ref.costs, live2.costs)

    return {
        "profile": profile,
        "n_requests": n_requests,
        "slice": slice_n,
        "numpy_seconds": t_numpy,
        "live_cold_seconds": t_cold,
        "live_warm_seconds": t_warm,
        "req_per_s_numpy": n_requests / t_numpy,
        "req_per_s_live_cold": n_requests / t_cold,
        "req_per_s_live_warm": n_requests / t_warm,
        "speedup_cold": t_numpy / t_cold,
        "speedup_warm": t_numpy / t_warm,
        "compiles_cold": compiles_cold,
        "compiles_warm": compiles_warm,
        "state_layout": live.layout.tag,
        "state_bytes": state_bytes_telemetry(trace.n, trace.m),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run: parity + live must beat numpy")
    args, _ = ap.parse_known_args()

    slice_n = int(os.environ.get("REPRO_SERVE_BENCH_SLICE", "128"))
    if args.smoke:
        n = int(os.environ.get("REPRO_SERVE_BENCH_REQUESTS", "60000"))
        profiles = ("flash_crowd",)
    else:
        n = int(os.environ.get("REPRO_SERVE_BENCH_REQUESTS", "150000"))
        profiles = PROFILES

    scenarios = [bench_profile(p, n, slice_n) for p in profiles]
    print(f"# parity vs offline run_policy on {len(scenarios)} scenario(s) "
          "(numpy + live cold + live warm): OK")

    rows = []
    for s in scenarios:
        p = s["profile"]
        rows += [
            (f"serve/{p}/numpy", int(s["numpy_seconds"] / n * 1e6),
             f"{s['req_per_s_numpy']:.0f} req/s"),
            (f"serve/{p}/live_cold", int(s["live_cold_seconds"] / n * 1e6),
             f"{s['req_per_s_live_cold']:.0f} req/s;"
             f"{s['compiles_cold']} compiles"),
            (f"serve/{p}/live_warm", int(s["live_warm_seconds"] / n * 1e6),
             f"{s['req_per_s_live_warm']:.0f} req/s;"
             f"{s['compiles_warm']} compiles"),
            (f"serve/{p}/speedup_warm", round(s["speedup_warm"], 2), "x"),
        ]
    emit(rows)
    save_json("BENCH_serve", {
        "slice": slice_n,
        "n_requests": n,
        "policy": "akpc",
        "cost_model": "table1",
        "smoke": bool(args.smoke),
        "scenarios": scenarios,
    })

    # the gate: the persistent engine must sustain MORE req/s than the
    # batched-numpy session on the same arrival stream (warm = steady
    # state; cold numbers are recorded but not gated — one XLA compile
    # against a short smoke stream is noise, not serving throughput)
    for s in scenarios:
        assert s["live_warm_seconds"] < s["numpy_seconds"], (
            f"{s['profile']}: warm live engine "
            f"({s['req_per_s_live_warm']:.0f} req/s) no faster than the "
            f"numpy session ({s['req_per_s_numpy']:.0f} req/s)")


if __name__ == "__main__":
    main()
