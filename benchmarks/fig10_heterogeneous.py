"""Fig. 10 — NEW scenario axis beyond the paper: heterogeneous server
prices and non-unit item sizes, priced by the ``heterogeneous`` cost model
(per-server ``lam_j``/``mu_j``, size-weighted transfer/rent, per-server
``dt_j = rho*lam_j/mu_j`` — the regime that exercises the engine's
segment-max anchor path, DESIGN.md §9).

Sweeps (a) server-price skew (lognormal sigma of ``lam_j``/``mu_j``) and
(b) the item-size distribution, and records AKPC vs the baselines on both
axes.  ``--smoke`` is the CI gate: heterogeneous AKPC must keep beating
``no_packing`` on a small skewed scenario.
"""
from __future__ import annotations

import sys

from .common import N_SWEEP, emit, get_trace, run_method_grid, run_methods, save_json
from repro.core import CacheEnvironment, CostParams
from repro.traces import SynthConfig, synth_trace

PRICE_SIGMAS = [0.0, 0.5, 1.0]          # lognormal skew of lam_j / mu_j
SIZE_DISTS = ["unit", "lognormal"]      # per-item volume distribution
METHODS = ("no_packing", "packcache", "akpc", "opt")
COST_MODEL = "heterogeneous"
#: fig10 setup: N_SWEEP requests over 150 ESS at ~2.8 requests per server
#: per unit time — hot (clique, server) gaps sit at the TTL crossover
#: (dt ~= 1 at Table-II prices), the regime where packed transfers matter.
#: Much denser and everything stays cached (packing can't help); much
#: sparser and every access misses regardless of packing.
N_SERVERS = 150
REQ_RATE_PER_SERVER = 2.8


def sized_trace(kind: str, n_requests: int, size_dist: str, seed: int = 0,
                n_servers: int = N_SERVERS):
    """Paper-style trace with a chosen item-size distribution (the request
    stream is IDENTICAL across size_dist values — only sizes differ)."""
    t_max = n_requests / (n_servers * REQ_RATE_PER_SERVER)
    return synth_trace(SynthConfig(
        kind=kind, n_items=60, n_servers=n_servers, n_requests=n_requests,
        t_max=t_max, bundle_cover=1.0, bundle_zipf=0.7,
        server_affinity=2, mean_session_len=6.0, seed=seed,
        size_dist=size_dist,
    ))


def env_for(trace, params: CostParams, price_sigma: float,
            seed: int = 1) -> CacheEnvironment:
    sk = CacheEnvironment.skewed(
        trace.n, trace.m, params, price_sigma=price_sigma, seed=seed)
    # from_trace picks up trace.sizes; skewed() contributes the prices
    return CacheEnvironment.from_trace(
        trace, params, lam_j=sk.lam_j, mu_j=sk.mu_j)


def run_grid(n_requests: int, kind: str = "netflix") -> dict:
    """The full (size_dist x price_sigma) grid as ONE sweep call (PR 5):
    each scenario prices the heterogeneous model's per-server dt, so every
    point runs the engine's general anchor path — vmapped on device."""
    params = CostParams()
    payload: dict = {"cost_model": COST_MODEL, "kind": kind,
                     "n_requests": n_requests, "grid": {}}
    grid, keys = [], []
    for size_dist in SIZE_DISTS:
        tr = sized_trace(kind, n_requests, size_dist)
        for sigma in PRICE_SIGMAS:
            grid.append({"trace": tr, "params": params, "methods": METHODS,
                         "env": env_for(tr, params, sigma),
                         "cost_model": COST_MODEL})
            keys.append(f"{size_dist}/sigma={sigma}")
    results = run_method_grid(grid)
    for key, res in zip(keys, results):
        payload["grid"][key] = {
            m: {"total": v["total"], "transfer": v["transfer"],
                "caching": v["caching"]}
            for m, v in res.items()
        }
        payload["grid"][key]["akpc_vs_no_packing_saving_pct"] = round(
            100.0 * (1.0 - res["akpc"]["total"]
                     / res["no_packing"]["total"]), 2)
    return payload


def smoke() -> int:
    """CI gate: AKPC must beat no_packing under skewed prices + sizes.

    Denser per-server traffic than the full grid (100 ESS at ~2.8
    req/server/time vs the grid's N_SERVERS = 150) so the packing signal is
    strong and the gate margin is wide (~10% saving at the time of writing)
    rather than a noise-level win.
    """
    params = CostParams()
    tr = synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=100, n_requests=20_000,
        t_max=72.0, bundle_cover=1.0, bundle_zipf=0.7,
        server_affinity=2, mean_session_len=6.0, seed=0,
        size_dist="lognormal",
    ))
    env = env_for(tr, params, price_sigma=1.0)
    res = run_methods(tr, params, methods=("no_packing", "akpc"), env=env,
                      cost_model=COST_MODEL)
    akpc, nop = res["akpc"]["total"], res["no_packing"]["total"]
    saving = 100.0 * (1.0 - akpc / nop)
    print(f"fig10 --smoke: akpc={akpc:.0f} no_packing={nop:.0f} "
          f"saving={saving:.1f}%")
    if akpc >= nop:
        print("FAIL: heterogeneous AKPC no longer beats no_packing")
        return 1
    print("OK")
    return 0


def main() -> list[tuple]:
    payload = run_grid(N_SWEEP)
    rows = []
    for key, r in payload["grid"].items():
        rows.append((
            f"fig10/{key}", 0,
            ";".join(f"{m}={round(r[m]['total'], 1)}" for m in METHODS)
            + f";akpc_saving={r['akpc_vs_no_packing_saving_pct']}%",
        ))
    save_json("fig10_heterogeneous", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(smoke())
    main()
