"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes JSON payloads to
experiments/results/.  Budget knobs (env):
  REPRO_BENCH_REQUESTS        fig5 trace length   (default 150000)
  REPRO_BENCH_SWEEP_REQUESTS  per-sweep-point     (default 40000)
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (
        fig5_cost_comparison,
        fig6_sensitivity,
        fig7_hyperparams,
        fig8_scalability,
        fig9_cliques_runtime,
        fig10_heterogeneous,
        integration_bench,
        kernel_bench,
        replay_bench,
        roofline_report,
        sweep_bench,
        table1_cost_model,
    )

    suites = [
        ("replay", replay_bench),
        ("sweep", sweep_bench),
        ("table1", table1_cost_model),
        ("fig5", fig5_cost_comparison),
        ("fig6", fig6_sensitivity),
        ("fig7", fig7_hyperparams),
        ("fig8", fig8_scalability),
        ("fig9", fig9_cliques_runtime),
        ("fig10", fig10_heterogeneous),
        ("kernels", kernel_bench),
        ("integration", integration_bench),
        ("roofline", roofline_report),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in suites:
        if only and only != name:
            continue
        t0 = time.time()
        mod.main()
        print(f"suite/{name},{int((time.time()-t0)*1e6)},done", flush=True)


if __name__ == "__main__":
    main()
