"""Scalar vs batched replay throughput on the 1M-request synthetic trace.

The batched engine (core/engine.py handle_batch) replaces the per-request
Python loop with NumPy segment reductions over (request, clique) events.
This benchmark measures both paths on the Table-II "netflix" trace with a
static offline pair partition installed (so it times the replay core, not
clique generation), verifies the acceptance contract along the way:

* cost-for-cost equality (1e-9 rel) between the two paths on the first
  100k requests, and
* >= 5x batched speedup on the full trace.

Env knobs:
  REPRO_REPLAY_REQUESTS   trace length             (default 1_000_000)
  REPRO_REPLAY_BATCH      requests per batch       (default 4096)
  REPRO_REPLAY_SCALAR_CAP scalar path is timed on min(cap, n) requests and
                          extrapolated (default: full n; set a cap to keep
                          smoke runs short)

``--smoke`` (CI): 60k-request trace, capped scalar timing, equality check
only (no speedup floor — CI runners are too noisy to gate on wall time).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core import CacheEnvironment, CostParams, ReplayEngine, get_cost_model
from repro.core.baselines import greedy_pair_matching
from repro.traces import paper_trace

from .common import emit, save_json

#: the scenario this benchmark prices: the paper's homogeneous Table-I
#: regime, resolved through the PR-4 cost-model registry (fig5/fig10
#: convention) instead of constructing CostParams arithmetic directly
COST_MODEL = "table1"


def _env(trace) -> CacheEnvironment:
    return CacheEnvironment.from_trace(trace, CostParams())


def _run(trace, part, batch_size):
    env = _env(trace)
    eng = ReplayEngine(trace.n, trace.m, env=env,
                       cost_model=get_cost_model(COST_MODEL, env))
    eng.install_partition(part, now=0.0)
    t0 = time.perf_counter()
    eng.replay(trace, batch_size=batch_size)
    return eng.costs, time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run: cost-equality check only")
    args, _ = ap.parse_known_args()

    if args.smoke:
        n = int(os.environ.get("REPRO_REPLAY_REQUESTS", "60000"))
        bs = int(os.environ.get("REPRO_REPLAY_BATCH", "4096"))
        scalar_cap = int(os.environ.get("REPRO_REPLAY_SCALAR_CAP", "20000"))
    else:
        n = int(os.environ.get("REPRO_REPLAY_REQUESTS", "1000000"))
        bs = int(os.environ.get("REPRO_REPLAY_BATCH", "4096"))
        scalar_cap = int(os.environ.get("REPRO_REPLAY_SCALAR_CAP", str(n)))

    trace = paper_trace("netflix", n_requests=n, seed=0)
    part = greedy_pair_matching(trace.items, trace.n, 0.2, 1.0)

    # -- acceptance: cost-for-cost equality on the first 100k requests -----
    head = trace.head(min(100_000, n))
    c_s, _ = _run(head, part, 1)
    c_b, _ = _run(head, part, bs)
    eq_fields = {}
    for f in ("transfer", "caching", "keepalive_rent"):
        a, b = getattr(c_s, f), getattr(c_b, f)
        assert np.isclose(a, b, rtol=1e-9, atol=1e-9), (f, a, b)
        eq_fields[f] = a
    for f in ("n_misses", "n_hits", "n_requests", "items_transferred"):
        assert getattr(c_s, f) == getattr(c_b, f), f
    print(f"# equality check on {head.n_requests} requests: OK")

    # -- throughput --------------------------------------------------------
    n_scalar = min(scalar_cap, n)
    _, t_scalar = _run(trace.head(n_scalar), part, 1)
    t_scalar_full = t_scalar * (n / n_scalar)
    costs_b, t_batched = _run(trace, part, bs)

    speedup = t_scalar_full / t_batched
    rps_scalar = n_scalar / t_scalar
    rps_batched = n / t_batched
    emit([
        ("replay/scalar", int(t_scalar_full / n * 1e6 * 1e3) / 1e3,
         f"{rps_scalar:.0f} req/s"),
        (f"replay/batched_{bs}", int(t_batched / n * 1e6 * 1e3) / 1e3,
         f"{rps_batched:.0f} req/s"),
        ("replay/speedup", round(speedup, 1), "x"),
    ])
    if not args.smoke:
        assert speedup >= 5.0, f"batched replay only {speedup:.1f}x faster"
    save_json("replay_bench", {
        "cost_model": COST_MODEL,
        "n_requests": n,
        "batch_size": bs,
        "scalar_seconds": t_scalar_full,
        "scalar_measured_requests": n_scalar,
        "batched_seconds": t_batched,
        "speedup": speedup,
        "requests_per_second_batched": rps_batched,
        "equality_100k": eq_fields,
        "total_cost": costs_b.total,
    })


if __name__ == "__main__":
    main()
