"""Table I — transfer/caching costs for packed vs unpacked bundles."""
from __future__ import annotations

from .common import emit, save_json
from repro.core import CostParams


def main() -> list[tuple]:
    p = CostParams()
    rows, payload = [], {}
    for k in (1, 2, 3, 5):
        unp = p.transfer_cost(k, packed=False)
        pkd = p.transfer_cost(k, packed=True)
        cache = p.caching_cost(k, p.dt)
        payload[k] = {"unpacked": unp, "packed": pkd, "caching": cache}
        rows.append((f"table1/k={k}", 0,
                     f"unpacked={unp};packed={round(pkd,3)};caching={cache}"))
    save_json("table1_cost_model", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
