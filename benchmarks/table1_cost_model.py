"""Table I — transfer/caching costs for packed vs unpacked bundles.

Constructed through the cost-model registry (no ``CostParams`` formula
internals): the ``table1`` model IS Table I, and the ``tiered`` model with
its default schedule (one breakpoint at volume 1, marginal rate alpha)
reproduces it exactly on unit sizes — Table I is the alpha-linear special
case of concave tiered pricing (DESIGN.md §9).
"""
from __future__ import annotations

from .common import emit, save_json
from repro.core import CacheEnvironment, CostParams, get_cost_model


def main() -> list[tuple]:
    env = CacheEnvironment(n=8, m=1, params=CostParams())
    model = get_cost_model("table1", env)
    tiered = get_cost_model("tiered", env)     # default = alpha-linear
    dt = float(model.dt()[0])
    rows, payload = [], {}
    for k in (1, 2, 3, 5):
        unp = model.transfer_cost(k, packed=False)
        pkd = model.transfer_cost(k, packed=True)
        cache = model.caching_cost(k, dt)
        if tiered.transfer_cost(k, packed=True) != pkd:   # survives python -O
            raise RuntimeError(
                "tiered default must reproduce Table I (alpha-linear tier)")
        payload[k] = {"unpacked": unp, "packed": pkd, "caching": cache}
        rows.append((f"table1/k={k}", 0,
                     f"unpacked={unp};packed={round(pkd,3)};caching={cache}"))
    save_json("table1_cost_model", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
