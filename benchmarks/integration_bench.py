"""Beyond-paper framework integrations: AKPC shard-prefetch cache for the
input pipeline and the MoE expert cache (DESIGN.md §4)."""
from __future__ import annotations

import numpy as np

from .common import emit, save_json
from repro.data import PackedDataPipeline, ShardStore
from repro.serving import ExpertCacheManager


def main() -> list[tuple]:
    rows, payload = [], {}
    # data pipeline: AKPC cache vs per-shard fetching
    store = ShardStore(n_shards=256, shard_tokens=1024, vocab=1024, n_domains=8)
    pipe = PackedDataPipeline(store, batch_rows=16, seq_len=256)
    for _ in range(150):
        next(pipe)
    tl = pipe.telemetry
    rows.append(("integration/data_pipeline", 0,
                 f"batches={tl.batches};shard_requests={tl.shards_fetched};"
                 f"akpc_cost={round(tl.akpc_total,1)}"))
    payload["pipeline"] = {"akpc": tl.akpc_total, "fetches": tl.shards_fetched}

    # expert cache: co-activated experts across 4 hosts
    rng = np.random.default_rng(0)
    mgr = ExpertCacheManager(n_experts=64, n_hosts=4, t_cg=32.0)
    groups = [np.arange(8 * g, 8 * g + 8) for g in range(8)]
    w = 1.0 / np.arange(1, 9) ** 1.1
    w /= w.sum()
    for step in range(1200):
        g = groups[rng.choice(8, p=w)]
        mgr.observe(rng.choice(g, size=(8, 2)), host=int(rng.integers(0, 4)))
    st = mgr.stats()
    rows.append(("integration/expert_cache", 0,
                 f"cliques={len(st.cliques)};akpc={round(st.akpc_total,1)};"
                 f"per_expert={round(st.nopack_total,1)};"
                 f"saving={round(st.saving_pct,1)}%"))
    payload["expert_cache"] = {"saving_pct": st.saving_pct,
                               "n_cliques": len(st.cliques)}
    save_json("integration_bench", payload)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
