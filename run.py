#!/usr/bin/env python
"""Repo driver: tier-1 tests and the CI smoke gates in one command.

    python run.py --tests          # tier-1 suite (pytest -x -q)
    python run.py --smoke          # every benchmark smoke gate, in order
    python run.py --tests --smoke  # both (what ci.yml runs)

The smoke gates (each also runnable directly as
``PYTHONPATH=src python -m benchmarks.<name> --smoke``):

* replay_bench          — replay-engine cost equality numpy vs jax
* sweep_bench           — vmapped sweep beats the serial loop (warm)
* fig7_hyperparams      — device-CGM partitions == cliques_ref oracle on
                          a theta x gamma x omega grid, zero host CGM calls
* fig9_cliques_runtime  — vectorized CGM beats the scalar oracle;
                          records device-CGM timing in BENCH_cgm.json
* fig8_scalability      — mixed-(n, m) grid through ONE bucketed-layout
                          SweepEngine call: 1e-9 parity vs numpy,
                          compile count <= #bucket-cohorts
* fig10_heterogeneous   — heterogeneous cost-model smoke
* serve_bench           — persistent live serving engine sustains more
                          req/s than the streamed numpy session at 1e-9
                          cost parity; records BENCH_serve.json
* fig11_stress_rank     — trained learned policy beats no_packing and a
                          non-AKPC baseline on the regime-shift stress
                          trace, numpy/jax parity 1e-9, bounded train
                          compile count; full run records
                          BENCH_learned.json
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

SMOKE_GATES = (
    "benchmarks.replay_bench",
    "benchmarks.sweep_bench",
    "benchmarks.fig7_hyperparams",
    "benchmarks.fig9_cliques_runtime",
    "benchmarks.fig8_scalability",
    "benchmarks.fig10_heterogeneous",
    "benchmarks.serve_bench",
    "benchmarks.fig11_stress_rank",
)


def _env() -> dict:
    env = dict(os.environ)
    root = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = (
        src + os.pathsep + env["PYTHONPATH"]
        if env.get("PYTHONPATH") else src)
    return env


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tests", action="store_true",
                    help="run the tier-1 pytest suite")
    ap.add_argument("--smoke", action="store_true",
                    help="run every benchmark smoke gate")
    args = ap.parse_args()
    if not (args.tests or args.smoke):
        ap.print_help()
        return 2

    env = _env()
    rc = 0
    if args.tests:
        rc |= subprocess.call(
            [sys.executable, "-m", "pytest", "-x", "-q"], env=env)
    if args.smoke:
        for mod in SMOKE_GATES:
            print(f"== {mod} --smoke ==", flush=True)
            rc |= subprocess.call(
                [sys.executable, "-m", mod, "--smoke"], env=env)
    return rc


if __name__ == "__main__":
    sys.exit(main())
