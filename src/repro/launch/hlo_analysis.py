"""Post-SPMD HLO analysis: FLOPs, HBM bytes, collective wire bytes, roofline.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts while-loop
bodies ONCE, so a 60-layer ``lax.scan`` model looks 60x cheaper than it is
(verified empirically — see tests/test_hlo_analysis.py).  We therefore walk
the partitioned HLO text ourselves:

* computations are parsed into (name -> ops) with a symbol table of result
  shapes so operand shapes can be resolved;
* every ``while`` op propagates its ``known_trip_count`` as a multiplier to
  its body/condition computations (nested loops multiply);
* FLOPs: 2 * prod(output dims) * prod(contracting dims) per ``dot``;
* HBM bytes: operands + results of MATERIALISING ops only (dot, conv,
  gather/scatter, dynamic slices, reduce, concat, sort, copy, collectives).
  Elementwise/broadcast/convert/select chains are treated as fused (free),
  approximating the TPU fusion behaviour that the unfused CPU HLO lacks.
  This is a structural estimate — good for identifying the dominant
  roofline term and for measuring optimisation deltas, not a cycle-accurate
  simulator (DESIGN.md §7);
* collective wire bytes per device under ring algorithms:
      all-gather         out/g * (g-1)
      reduce-scatter     out * (g-1)          (out is the shard)
      all-reduce         2 * out/g * (g-1)
      all-to-all         out * (g-1)/g
      collective-permute out

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "u4": 1, "s4": 1,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"(?:body|calls|to_apply|condition)=%?([\w\.\-]+)")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,\s]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "domain", "partition-id", "replica-id", "iota"}
# ops that actually materialise HBM traffic on TPU (everything elementwise
# is assumed fused into its producer/consumer)
_MATERIALIZING = {
    "dot", "convolution", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "reduce", "concatenate", "sort", "copy",
    "transpose", "reduce-window", "cholesky", "triangular-solve",
}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            total += _shape_elems(dims) * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:                                  # {{0,1},{2,3},...}: first group
        return max(1, m.group(1).count(",") + 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:                                  # [n_groups, group_size]<=[N]
        dims = m.group(1).split(",")
        return int(dims[-1]) if dims else default
    return default


@dataclasses.dataclass
class Op:
    name: str
    out: str
    kind: str
    rest: str


@dataclasses.dataclass
class HloStats:
    flops: float                     # per device
    hbm_bytes: float                 # per device
    coll_wire_bytes: float           # per device
    coll_bytes_by_kind: dict
    coll_counts: dict


def parse_hlo(text: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    cur: list[Op] | None = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = comps.setdefault(mc.group(1), [])
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            cur.append(Op(mo.group(1), mo.group(2), mo.group(3), mo.group(4)))
    return comps


def _multipliers(comps: dict[str, list[Op]], entry: str) -> dict[str, float]:
    mult: dict[str, float] = {entry: 1.0}
    stack = [entry]
    while stack:
        c = stack.pop()
        m = mult[c]
        for op in comps.get(c, []):
            callees = _CALLS_RE.findall(op.rest)
            if not callees:
                continue
            k = 1.0
            if op.kind == "while":
                t = _TRIP_RE.search(op.rest)
                k = float(t.group(1)) if t else 1.0
            for callee in callees:
                if callee in comps:
                    prev = mult.get(callee, 0.0)
                    nm = m * k
                    if nm > prev:
                        mult[callee] = nm
                        stack.append(callee)
    return mult


def _fusion_bodies(comps: dict[str, list[Op]]) -> set[str]:
    """Computations called by fusion ops (and reducers) — interiors are free."""
    out: set[str] = set()
    for ops in comps.values():
        for op in ops:
            if op.kind in ("fusion", "reduce", "scatter", "reduce-window",
                           "sort", "map", "reduce-scatter", "all-reduce"):
                out.update(_CALLS_RE.findall(op.rest))
    return out


def analyze_hlo(text: str, n_devices: int) -> HloStats:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
                break
    if entry is None:                       # fall back: main-ish computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    mult = _multipliers(comps, entry)
    fusion_bodies = _fusion_bodies(comps)

    # symbol table: op name -> output type text (per computation is fine
    # since names are unique module-wide in dumped HLO)
    sym: dict[str, str] = {}
    for ops in comps.values():
        for op in ops:
            sym[op.name] = op.out

    flops = 0.0
    hbm = 0.0
    coll_b: dict[str, float] = {}
    coll_c: dict[str, float] = {}

    for cname, ops in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        in_fusion = cname in fusion_bodies
        for op in ops:
            if op.kind in COLLECTIVES or (
                op.kind.endswith("-start") and op.kind[:-6] in COLLECTIVES
            ):
                kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
                ob = _bytes_of(op.out)
                # XLA:CPU promotes bf16 collectives to f32 (all-reduce via
                # AllReducePromotion, all-gathers because CPU computes bf16
                # dots in f32 and sinks the convert below the collective).
                # A TPU moves these in bf16 — count them at native width.
                if "_promoted" in op.rest or (
                    op.out.lstrip("(").startswith("f32")
                    and re.match(r"\s*%\w*convert", op.rest)
                ):
                    ob //= 2
                g = _group_size(op.rest, n_devices)
                if g > 1:
                    if kind == "all-gather":
                        wire = ob * (g - 1) / g
                    elif kind == "reduce-scatter":
                        wire = ob * (g - 1)
                    elif kind == "all-reduce":
                        wire = 2.0 * ob * (g - 1) / g
                    elif kind == "all-to-all":
                        wire = ob * (g - 1) / g
                    else:
                        wire = ob
                    coll_b[kind] = coll_b.get(kind, 0.0) + wire * m
                    coll_c[kind] = coll_c.get(kind, 0) + m
                    hbm += 2.0 * ob * m          # collectives read+write HBM
                continue
            if in_fusion:
                continue
            if op.kind == "dot":
                out_elems = sum(
                    _shape_elems(d) for _, d in _SHAPE_RE.findall(op.out)
                )
                cm = _CONTRACT_RE.search(op.rest)
                contract = 1
                # lhs shape: inline operand type (modern HLO dumps annotate
                # `dot(f32[64,32]{1,0} %lhs, ...)`) or symbol-table lookup
                first = re.match(
                    r"\s*(?:(?P<typ>[a-z0-9]+\[[0-9,]*\])\S*\s+)?%(?P<name>[\w\.\-]+)",
                    op.rest,
                )
                lhs_txt = None
                if first:
                    if first.group("typ"):
                        lhs_txt = first.group("typ")
                    elif first.group("name") in sym:
                        lhs_txt = sym[first.group("name")]
                if cm and lhs_txt:
                    lhs_dims = _SHAPE_RE.findall(lhs_txt)
                    if lhs_dims:
                        dims = [int(x) for x in lhs_dims[0][1].split(",") if x]
                        for ci in cm.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                contract *= dims[int(ci)]
                flops += 2.0 * out_elems * contract * m
            if op.kind not in _MATERIALIZING:
                continue
            # HBM traffic: operands + result of materialising ops
            b = _bytes_of(op.out)
            for oname in re.findall(r"%([\w\.\-]+)", op.rest):
                if oname in sym:
                    b += _bytes_of(sym[oname])
            hbm += b * m

    return HloStats(
        flops=flops,
        hbm_bytes=hbm,
        coll_wire_bytes=float(sum(coll_b.values())),
        coll_bytes_by_kind=coll_b,
        coll_counts=coll_c,
    )


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops: float
    n_devices: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_dev * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """(MODEL_FLOPS / chips / peak) / bound  — 'score' of the cell."""
        if self.bound_s <= 0:
            return 0.0
        ideal = self.model_flops / self.n_devices / PEAK_FLOPS
        return ideal / self.bound_s


def make_roofline(stats: HloStats, n_devices: int, model_flops: float) -> Roofline:
    return Roofline(
        compute_s=stats.flops / PEAK_FLOPS,
        memory_s=stats.hbm_bytes / HBM_BW,
        collective_s=stats.coll_wire_bytes / LINK_BW,
        flops_per_dev=stats.flops,
        hbm_bytes_per_dev=stats.hbm_bytes,
        coll_bytes_per_dev=stats.coll_wire_bytes,
        model_flops=model_flops,
        n_devices=n_devices,
    )
