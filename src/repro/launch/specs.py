"""Abstract input builders for every (arch x shape) dry-run cell.

``input_specs(arch, shape, mesh)`` returns ShapeDtypeStructs (weak-type
correct, sharding-annotated, ZERO device allocation) for the step function
of that cell, plus the step builder itself.  This is the single source of
truth used by dryrun.py, the roofline benches and the launch scripts.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config
from ..models.api import build_model
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init
from . import sharding as shd
from .mesh import dp_size
from .train import choose_accum, make_train_step

# >=100B-class models accumulate gradients in bf16 (halves the largest
# training buffer; §Perf iteration A3 — precision note in EXPERIMENTS.md)
BF16_ACCUM_ARCHS = {"deepseek_v2_236b"}
# 8-bit AdamW (optim/adamw8bit.py) measured a dry-run REGRESSION when
# enabled here: the per-leaf fp32 dequant->update->requant transients
# overlap in XLA's schedule (+5 GB/dev) — §Perf iteration A5 (refuted).
# Sequencing leaf updates / a fused Pallas quantised-Adam kernel is the
# identified follow-up; the module + convergence tests ship regardless.
OPT8_ARCHS: set = set()


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    kind: str                       # train | prefill | decode
    step_fn: Callable               # the function to lower
    args: tuple                     # ShapeDtypeStructs w/ shardings
    donate: tuple = ()
    static: dict = dataclasses.field(default_factory=dict)
    out_shardings: object = None


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _abstract(tree, shardings):
    return jax.tree.map(
        lambda l, s: _sds(l.shape, l.dtype, s), tree, shardings
    )


def _token_batch(cfg: ModelConfig, accum: int, mb: int, S: int, mesh,
                 train: bool):
    """Token/label (+frontend stub) arrays for one microbatch step."""
    shp = (accum, mb) if train else (mb,)
    batch: dict[str, Any] = {}
    if cfg.family == "encdec":
        batch["frames"] = _sds(shp + (S, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = _sds(shp + (S,), jnp.int32)
    elif cfg.vlm is not None:
        n_text = S - cfg.vlm.n_patches
        batch["patches"] = _sds(shp + (cfg.vlm.n_patches, cfg.vlm.d_patch),
                                jnp.bfloat16)
        batch["tokens"] = _sds(shp + (n_text,), jnp.int32)
    else:
        batch["tokens"] = _sds(shp + (S,), jnp.int32)
    if train:
        batch["labels"] = _sds(shp + (batch["tokens"].shape[-1],), jnp.int32)
    shardings = shd.batch_shardings(batch, mesh, leading_accum=train)
    return _abstract(batch, shardings)


def build_cell(arch: str, shape: str, mesh, *, opt_cfg: AdamWConfig | None = None
               ) -> Cell:
    cfg = get_config(arch)
    sh = SHAPES[shape]
    model = build_model(cfg)
    kind = sh["kind"]
    S, B = sh["seq_len"], sh["global_batch"]
    dp = dp_size(mesh)

    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(model.init, key)
    pshard = shd.param_shardings(params_shape, mesh,
                                 serving=(kind == "decode"))
    params_abs = _abstract(params_shape, pshard)

    if kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        accum = choose_accum(cfg, S, B, dp)
        mb = max(1, B // accum)
        batch = _token_batch(cfg, accum, mb, S, mesh, train=True)
        opt_8bit = arch in OPT8_ARCHS
        if opt_8bit:
            from ..optim.adamw8bit import adamw8bit_init

            opt_shape = jax.eval_shape(adamw8bit_init, params_shape)
            oshard = shd.opt8_state_shardings(opt_shape, params_shape, mesh)
        else:
            opt_shape = jax.eval_shape(adamw_init, params_shape)
            oshard = shd.opt_state_shardings(opt_shape, params_shape, mesh)
        opt_abs = _abstract(opt_shape, oshard)
        accum_dtype = jnp.bfloat16 if arch in BF16_ACCUM_ARCHS else jnp.float32
        step = make_train_step(model, opt_cfg, mesh=mesh,
                               accum_dtype=accum_dtype, opt_8bit=opt_8bit)
        return Cell(arch, shape, cfg, kind, step,
                    (params_abs, opt_abs, batch), donate=(0, 1),
                    static={"accum": accum, "microbatch": mb},
                    out_shardings=(pshard, oshard, None))

    if kind == "prefill":
        batch = _token_batch(cfg, 1, B, S, mesh, train=False)
        if cfg.family == "encdec":
            step = functools.partial(model.prefill, mesh=mesh, cache_len=S)
        else:
            step = functools.partial(model.prefill, mesh=mesh)
        return Cell(arch, shape, cfg, kind, step, (params_abs, batch))

    # decode: one new token against a cache of length S
    from ..models.common import dtype_of

    cache_shape = jax.eval_shape(
        functools.partial(model.init_cache, B, S, dtype_of(cfg.kv_cache_dtype))
    )
    cshard = shd.cache_shardings(cache_shape, mesh)
    cache_abs = _abstract(cache_shape, cshard)
    dpspec = shd.batch_spec((B, 1), mesh)
    tokens = _sds((B, 1), jnp.int32, NamedSharding(mesh, dpspec))
    pos = _sds((), jnp.int32, NamedSharding(mesh, P()))
    step = functools.partial(model.decode_step, mesh=mesh)
    return Cell(arch, shape, cfg, kind, step,
                (params_abs, cache_abs, tokens, pos), donate=(1,))


def lower_cell(cell: Cell, mesh):
    """jit + lower with the cell's sharding-annotated abstract inputs."""
    kw = {}
    if cell.out_shardings is not None:
        kw["out_shardings"] = cell.out_shardings
    jitted = jax.jit(cell.step_fn, donate_argnums=cell.donate, **kw)
    # jax.set_mesh only exists from jax 0.6; older releases use the Mesh
    # object itself as the ambient-mesh context manager
    set_mesh = getattr(jax, "set_mesh", None)
    with set_mesh(mesh) if set_mesh is not None else mesh:
        return jitted.lower(*cell.args)
