"""Production mesh builders.  FUNCTIONS ONLY — importing this module never
touches jax device state (required by the dry-run contract)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_test_mesh(n_devices: int | None = None):
    """Small mesh for CPU tests: (data=2, model=n/2)."""
    n = n_devices or len(jax.devices())
    auto = (jax.sharding.AxisType.Auto,) * 2
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"), axis_types=auto)
    return jax.make_mesh((2, n // 2), ("data", "model"), axis_types=auto)


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes that carry data parallelism (pod folds into DP)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s
