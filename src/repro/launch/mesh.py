"""Production mesh builders.  FUNCTIONS ONLY — importing this module never
touches jax device state (required by the dry-run contract)."""
from __future__ import annotations

import jax


def _auto_axis_kwargs(n_axes: int) -> dict:
    """``axis_types=(AxisType.Auto, ...)`` on JAX versions that have it.

    ``jax.sharding.AxisType`` only exists from jax 0.5; older releases treat
    every axis as auto already, so omitting the kwarg is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_axis_kwargs(len(axes)))


def make_test_mesh(n_devices: int | None = None):
    """Small mesh for CPU tests: (data=2, model=n/2)."""
    n = n_devices or len(jax.devices())
    kw = _auto_axis_kwargs(2)
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"), **kw)
    return jax.make_mesh((2, n // 2), ("data", "model"), **kw)


def make_sweep_mesh(n_devices: int | None = None, state_rows: int = 1):
    """Mesh for SweepEngine grid sharding (repro.core.sweep).

    Default: 1-D ("scenario",) — each device replays a slice of the
    stacked scenario axis.  ``state_rows > 1`` splits the devices into a
    2-D ("scenario", "state_row") grid whose second axis carries the
    row-sharded StateLayout: the (n+1, m) expiry/anchor rows of every
    lane are distributed over ``state_rows`` devices — catalogs one chip
    can't hold.  ``state_rows`` must divide the device count.
    On a single-device host this is a trivial mesh and sweeps stay local."""
    n = n_devices or len(jax.devices())
    if state_rows <= 1:
        return jax.make_mesh((n,), ("scenario",), **_auto_axis_kwargs(1))
    if n % state_rows:
        raise ValueError(
            f"state_rows={state_rows} must divide the device count {n}")
    return jax.make_mesh((n // state_rows, state_rows),
                         ("scenario", "state_row"), **_auto_axis_kwargs(2))


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes that carry data parallelism (pod folds into DP)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s
