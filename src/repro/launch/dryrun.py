import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any jax import: jax locks the device
# count at first init.  512 host devices back both production meshes
# (single-pod 16x16 uses the first 256).

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell:
  jit(step).lower(**input_specs).compile()
must succeed on the single-pod (data=16, model=16) mesh AND the 2-pod
(pod=2, data=16, model=16) mesh.  We record memory_analysis (fits-in-HBM
proof), our HLO-walk cost analysis (FLOPs / HBM bytes / collective wire
bytes — see hlo_analysis.py) and the derived roofline terms into a JSONL
file consumed by EXPERIMENTS.md and benchmarks/roofline_report.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --out experiments/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k --mesh single
"""
import argparse
import json
import time
import traceback

HBM_PER_CHIP = 16e9       # TPU v5e


def model_flops_for(cfg, shape_name: str) -> float:
    from ..configs import SHAPES

    sh = SHAPES[shape_name]
    n_act = cfg.param_count(active_only=True) - cfg.vocab * cfg.d_model
    tokens = sh["global_batch"] * (sh["seq_len"] if sh["kind"] != "decode" else 1)
    mult = 6.0 if sh["kind"] == "train" else 2.0
    return mult * n_act * tokens


def run_cell(arch: str, shape: str, mesh_kind: str) -> dict:
    import jax

    from ..configs import SHAPES, get_config, shape_applicable
    from ..launch.hlo_analysis import analyze_hlo, make_roofline
    from ..launch.mesh import make_production_mesh
    from ..launch.specs import build_cell, lower_cell

    cfg = get_config(arch)
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_kind}
    if not shape_applicable(cfg, shape):
        rec.update(status="skipped",
                   reason="long_500k needs sub-quadratic attention "
                          "(DESIGN.md §4)")
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    t0 = time.time()
    try:
        cell = build_cell(arch, shape, mesh)
        lowered = lower_cell(cell, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        per_dev = ma.argument_size_in_bytes + ma.temp_size_in_bytes
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        st = analyze_hlo(txt, n_dev)
        rl = make_roofline(st, n_dev, model_flops_for(cfg, shape))
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            static=cell.static,
            arg_bytes=ma.argument_size_in_bytes,
            temp_bytes=ma.temp_size_in_bytes,
            out_bytes=ma.output_size_in_bytes,
            alias_bytes=ma.alias_size_in_bytes,
            live_bytes_per_dev=per_dev - ma.alias_size_in_bytes,
            fits_hbm=bool(per_dev - ma.alias_size_in_bytes
                          + ma.output_size_in_bytes < HBM_PER_CHIP),
            xla_flops_once=float(ca.get("flops", 0.0)),
            flops_per_dev=st.flops,
            hbm_bytes_per_dev=st.hbm_bytes,
            coll_bytes_per_dev=st.coll_wire_bytes,
            coll_by_kind={k: round(v) for k, v in st.coll_bytes_by_kind.items()},
            coll_counts={k: int(v) for k, v in st.coll_counts.items()},
            compute_s=rl.compute_s,
            memory_s=rl.memory_s,
            collective_s=rl.collective_s,
            dominant=rl.dominant,
            model_flops=rl.model_flops,
            useful_flops_ratio=round(rl.useful_flops_ratio, 4),
            roofline_fraction=round(rl.roofline_fraction, 4),
        )
    except Exception as e:  # noqa: BLE001 — the record IS the result
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   tb=traceback.format_exc()[-2000:],
                   seconds=round(time.time() - t0, 1))
    return rec


def main() -> None:
    from ..configs import ARCHS, SHAPES, resolve

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    ap.add_argument("--redo", action="store_true")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [resolve(args.arch)]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out) and not args.redo:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                key = (arch, shape, mesh_kind)
                if key in done:
                    print(f"[skip-done] {key}", flush=True)
                    continue
                print(f"[run] {arch} x {shape} x {mesh_kind}", flush=True)
                rec = run_cell(arch, shape, mesh_kind)
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                msg = rec.get("status")
                if msg == "ok":
                    print(
                        f"  ok: compile {rec['compile_s']}s, "
                        f"live/dev {(rec['live_bytes_per_dev'])/1e9:.2f}GB, "
                        f"fits={rec['fits_hbm']}, dom={rec['dominant']}, "
                        f"frac={rec['roofline_fraction']}", flush=True)
                else:
                    print(f"  {msg}: {rec.get('reason', rec.get('error'))}",
                          flush=True)


if __name__ == "__main__":
    main()
