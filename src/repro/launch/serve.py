"""Serving launcher: production-mesh prefill/decode step builders + a local
CPU driver for the reduced configs.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --dry
    PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-3b-a800m --local
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--dry", action="store_true",
                    help="lower+compile the serve step on the production mesh")
    ap.add_argument("--local", action="store_true",
                    help="run the reduced config on local devices")
    args = ap.parse_args()

    if args.dry:
        # production-mesh path shares the dry-run machinery (single source
        # of truth for shapes/shardings)
        from .dryrun import run_cell

        rec = run_cell(args.arch, args.shape, "single")
        print({k: rec[k] for k in ("status", "dominant", "roofline_fraction",
                                   "fits_hbm") if k in rec})
        return

    import jax
    import numpy as np

    from ..configs import get_smoke_config
    from ..models.api import build_model
    from ..serving import BatchedServer, Request

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchedServer(model, params, batch_size=4, cache_len=128)
    rng = np.random.default_rng(0)
    for rid in range(8):
        srv.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab, size=4).tolist(), max_new=8))
    done = srv.run(max_steps=400)
    print(f"{cfg.name}: served {len(done)} requests in {srv.steps} steps")


if __name__ == "__main__":
    main()
