"""Sharding rules: parameters (FSDP x TP), optimizer state, KV/SSM caches,
batches.

Scheme (DESIGN.md §5):
* TP ("model" axis):  attention head / ffn / expert / vocab dims;
* FSDP ("data" axis): the d_model-ish contraction dim of every large matrix
  (params are 2-D sharded: deepseek-v2's 472 GB of bf16 weights become
  1.8 GB/chip on a 16x16 mesh); gradients reduce-scatter, params all-gather
  at use — XLA SPMD derives both from these specs;
* the "pod" axis extends data parallelism only (params REPLICATED across
  pods, gradient all-reduce crosses DCN once per step);
* decode caches shard the SEQUENCE dim over "model" (flash-decoding style),
  batch over DP when divisible — a 512k-token KV cache fits one v5e chip.

Every rule falls back to replication when a dim is not divisible by the
axis size, so any (arch x mesh) combination lowers.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import dp_axes

# params whose TP dim is the LAST axis (column-parallel): y = x @ W
_COL = {"wq", "wk", "wv", "wi", "wg", "up", "wx", "wr", "in_proj", "wq_b",
        "wkv_b", "ffn_wi", "w_if", "bq", "bk", "bv"}
# params whose TP dim is the SECOND-TO-LAST axis (row-parallel): y = x @ W
_ROW = {"wo", "down", "out_proj", "ffn_wo"}
# small projections: FSDP only
_FSDP_ONLY = {"wq_a", "wkv_a", "router", "patch_proj", "conv_w"}
_REPL = {"norm", "norm1", "norm2", "norm3", "q_norm", "kv_norm", "gate_norm",
         "out_norm", "final_norm", "enc_final", "dec_final", "A_log",
         "dt_bias", "D", "scale", "bias", "shared_norm1", "shared_norm2",
         "ffn_norm", "step"}


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _axis(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 0


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def _path_has(path, *names) -> bool:
    keys = {str(getattr(e, "key", getattr(e, "name", ""))) for e in path}
    return any(n in keys for n in names)


def param_spec(path, leaf, mesh, serving: bool = False) -> P:
    """PartitionSpec for one parameter.

    ``serving=True`` (decode cells): expert weights switch to the
    WEIGHT-STATIONARY layout (ff over `data` instead of d) so tiny decode
    batches never all-gather expert weights — the paper-era practice of
    disaggregated prefill/decode serving with distinct checkpoint layouts
    (EXPERIMENTS.md §Perf iteration D2)."""
    name = _leaf_name(path)
    shape = leaf.shape
    nd = len(shape)
    dsz, msz = _axis(mesh, "data"), _axis(mesh, "model")
    if name in _REPL or nd <= 1:
        return P()
    spec: list[Any] = [None] * nd
    is_expert = _path_has(path, "mlp") and nd == 4          # (L, E, d, ff)

    if serving and is_expert and name in ("wi", "wg"):       # (L, E, d, ff)
        if _div(shape[1], msz):
            spec[1] = "model"
        if _div(shape[3], dsz):
            spec[3] = "data"
        return P(*spec)
    if serving and is_expert and name == "wo":               # (L, E, ff, d)
        if _div(shape[1], msz):
            spec[1] = "model"
        if _div(shape[2], dsz):
            spec[2] = "data"
        return P(*spec)

    if name == "embed":                                      # (V, d)
        if _div(shape[0], msz):
            spec[0] = "model"
        if _div(shape[1], dsz):
            spec[1] = "data"
    elif name == "lm_head":                                  # (d, V)
        if _div(shape[0], dsz):
            spec[0] = "data"
        if _div(shape[1], msz):
            spec[1] = "model"
    elif is_expert and name in ("wi", "wg"):                 # (L, E, d, ff)
        if _div(shape[1], msz):
            spec[1] = "model"
        if _div(shape[2], dsz):
            spec[2] = "data"
    elif is_expert and name == "wo":                         # (L, E, ff, d)
        if _div(shape[1], msz):
            spec[1] = "model"
        if _div(shape[3], dsz):
            spec[3] = "data"
    elif name in _COL:
        if _div(shape[-1], msz):
            spec[-1] = "model"
        if nd >= 2 and _div(shape[-2], dsz):
            spec[-2] = "data"
    elif name in _ROW:
        if _div(shape[-2], msz):
            spec[-2] = "model"
        if _div(shape[-1], dsz):
            spec[-1] = "data"
    elif name in _FSDP_ONLY:
        if nd >= 2 and _div(shape[-2], dsz):
            spec[-2] = "data"
    else:                                                    # generic fallback
        if _div(shape[-1], msz):
            spec[-1] = "model"
        if nd >= 2 and _div(shape[-2], dsz):
            spec[-2] = "data"
    return P(*spec)


def param_shardings(params_shape, mesh, serving: bool = False):
    """Pytree of NamedShardings matching a params (shape-)pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, mesh, serving=serving)),
        params_shape,
    )


def opt8_state_shardings(opt_shape, params_shape, mesh):
    """Shardings for the 8-bit optimizer tree: int8 moments mirror the
    params; blockwise scales mirror too except the (blocked) last dim falls
    back to replication when indivisible."""
    del opt_shape

    def per_param(path, leaf):
        base = param_spec(path, leaf, mesh)
        spec = list(base) + [None] * (len(leaf.shape) - len(base))
        nb = -(-leaf.shape[-1] // 128) if len(leaf.shape) else 1
        s_spec = list(spec)
        ax = _axis(mesh, "model") if spec and spec[-1] == "model" else (
            _axis(mesh, "data") if spec and spec[-1] == "data" else 0)
        if not (ax and nb % ax == 0):
            s_spec[-1] = None
        return {
            "m_q": NamedSharding(mesh, P(*spec)),
            "m_s": NamedSharding(mesh, P(*s_spec)),
            "v_q": NamedSharding(mesh, P(*spec)),
            "v_s": NamedSharding(mesh, P(*s_spec)),
        }

    mv = jax.tree_util.tree_map_with_path(
        per_param, params_shape,
        is_leaf=lambda x: hasattr(x, "shape"))
    return {"mv": mv, "step": NamedSharding(mesh, P())}


def opt_state_shardings(opt_shape, params_shape, mesh):
    """m/v mirror the params, additionally ZeRO-sharded over the pod axis
    (optimizer state is only touched once per step, so paying a DCN gather
    there is free roofline-wise and halves multi-pod optimizer memory);
    step is replicated."""
    pshard = param_shardings(params_shape, mesh)
    if "pod" in mesh.axis_names:
        def extend(ns):
            spec = list(ns.spec) if ns.spec else []
            out = []
            for entry in spec:
                if entry == "data":
                    out.append(("pod", "data"))
                else:
                    out.append(entry)
            if "pod" not in str(out):
                # no data-sharded dim: put pod on the largest unsharded dim
                pass
            return NamedSharding(mesh, P(*out))

        mshard = jax.tree.map(extend, pshard)
    else:
        mshard = pshard
    return {
        "m": mshard,
        "v": mshard,
        "step": NamedSharding(mesh, P()),
    }


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------
def batch_spec(shape, mesh, *, leading_accum: bool = False) -> P:
    """Shard the batch dim over DP axes (axis 0, or 1 under grad-accum)."""
    dp = dp_axes(mesh)
    dp_n = int(np.prod([mesh.shape[a] for a in dp]))
    bdim = 1 if leading_accum else 0
    spec: list[Any] = [None] * len(shape)
    if len(shape) > bdim and _div(shape[bdim], dp_n):
        spec[bdim] = dp
    return P(*spec)


def batch_shardings(batch_shape, mesh, *, leading_accum: bool = False):
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, batch_spec(leaf.shape, mesh, leading_accum=leading_accum)
        ),
        batch_shape,
    )


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------
_SEQ_AXIS = {"k": 2, "v": 2, "c": 2, "r": 2}     # (L, B, S, ...)


def cache_spec(path, leaf, mesh) -> P:
    name = _leaf_name(path)
    shape = leaf.shape
    nd = len(shape)
    dsz, msz = _axis(mesh, "data"), _axis(mesh, "model")
    dp = dp_axes(mesh)
    dp_n = int(np.prod([mesh.shape[a] for a in dp]))
    spec: list[Any] = [None] * nd

    if name in _SEQ_AXIS and nd >= 4:
        b_ax, s_ax = 1, 2
        if _div(shape[b_ax], dp_n):
            spec[b_ax] = dp
            if _div(shape[s_ax], msz):
                spec[s_ax] = "model"
        elif _div(shape[s_ax], msz * dp_n):
            # tiny batch (long_500k): context-parallel over ALL axes
            spec[s_ax] = dp + ("model",)
        elif _div(shape[s_ax], msz):
            spec[s_ax] = "model"
        return P(*spec)

    # recurrent states (ssm/mlstm/slstm/conv): batch over DP, widest inner
    # dim over model
    if nd >= 2 and _div(shape[1], dp_n):
        spec[1] = "data" if dp == ("data",) else dp
    inner = list(range(2, nd))
    inner.sort(key=lambda i: -shape[i])
    for i in inner:
        if _div(shape[i], msz):
            spec[i] = "model"
            break
    return P(*spec)


def cache_shardings(cache_shape, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, cache_spec(path, leaf, mesh)),
        cache_shape,
    )
