"""Training step factory: grad accumulation (lax.scan over microbatches),
AdamW update, optional int8 error-feedback gradient compression before the
cross-pod reduction.

The batch pytree always carries a leading ``accum`` dim; microbatch size is
chosen by ``choose_accum`` so per-shard saved activations stay under a
budget (per-layer remat means the dominant live term is the L stacked layer
inputs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..models.api import Model
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_update
from .mesh import dp_size

ACT_BUDGET_BYTES = 2.0e9      # saved-activation budget per device


def choose_accum(cfg: ModelConfig, seq_len: int, global_batch: int,
                 dp: int, model_size: int = 16) -> int:
    """Smallest power-of-two accum count keeping remat-saved activations
    under budget.  Beyond the L*mb*S*d layer inputs, per-layer backward
    residuals dominate for some families (chunked-scan states for SSM/xLSTM,
    cross-attention for enc-dec) and tensors whose head dim cannot shard
    over `model` are replicated — both folded in as multipliers."""
    per_dp = max(1, global_batch // dp)
    accum = 1
    L = cfg.n_layers if cfg.family != "encdec" else (
        cfg.encdec.n_encoder_layers + cfg.encdec.n_decoder_layers)
    family_factor = {"ssm": 8.0, "hybrid": 8.0, "encdec": 6.0}.get(cfg.family, 1.0)
    if cfg.xlstm is not None:
        family_factor = 8.0
    rep = 1.0 if cfg.n_heads % model_size == 0 else float(model_size)
    while accum < per_dp:
        mb_local = per_dp // accum
        saved = L * mb_local * seq_len * cfg.d_model * 2 * family_factor * rep
        if saved <= ACT_BUDGET_BYTES:
            break
        accum *= 2
    return accum


def make_train_step(model: Model, opt_cfg: AdamWConfig, mesh=None,
                    compress: bool = False, accum_dtype=jnp.float32,
                    opt_8bit: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, stats).

    ``batch`` leaves: (accum, mb, ...).  With ``compress=True`` the
    accumulated gradient goes through int8 error-feedback quantisation
    (``opt_state["ef"]`` carries the residual) before the update — shrinking
    the cross-pod gradient all-reduce payload 2-4x.

    The fp32 gradient-accumulation carry is EXPLICITLY constrained to the
    parameter shardings: without the constraint XLA partially replicates the
    carry and all-reduces full gradients every microbatch (measured 3.2 TB
    of all-reduce per device per step on deepseek-v2 — EXPERIMENTS.md §Perf
    iteration A).
    """
    from ..optim import compress_gradients, decompress_gradients

    def mb_loss(p, mb):
        return model.loss(p, mb, mesh=mesh)

    def _grad_zeros(params):
        if mesh is None:
            return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        from .sharding import param_shardings

        shards = param_shardings(params, mesh)
        return jax.tree.map(
            lambda p, s: jax.lax.with_sharding_constraint(
                jnp.zeros(p.shape, accum_dtype), s),
            params, shards)

    def train_step(params, opt_state, batch):
        accum = jax.tree.leaves(batch)[0].shape[0]

        def acc_body(carry, mb):
            g, lsum = carry
            loss, grads = jax.value_and_grad(mb_loss)(params, mb)
            g = jax.tree.map(lambda a, b: a + b.astype(accum_dtype), g, grads)
            return (g, lsum + loss), None

        zeros = _grad_zeros(params)
        (g, lsum), _ = jax.lax.scan(acc_body, (zeros, jnp.zeros((), jnp.float32)),
                                    batch)
        g = jax.tree.map(lambda x: x / accum, g)
        if compress:
            q, ef = compress_gradients(g, opt_state["ef"])
            g = decompress_gradients(q, g)
            opt_state = {**opt_state, "ef": ef}
        ostate = {k: v for k, v in opt_state.items() if k != "ef"}
        if opt_8bit:
            from ..optim.adamw8bit import adamw8bit_update

            new_p, new_o, stats = adamw8bit_update(opt_cfg, g, ostate, params)
        else:
            new_p, new_o, stats = adamw_update(opt_cfg, g, ostate, params)
        if compress:
            new_o["ef"] = opt_state["ef"]
        stats["loss"] = lsum / accum
        return new_p, new_o, stats

    return train_step


def make_eval_step(model: Model, mesh=None):
    def eval_step(params, batch):
        return model.loss(params, batch, mesh=mesh)

    return eval_step
