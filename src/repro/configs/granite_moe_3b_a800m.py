"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (kv=8) d_ff(expert)=512
vocab=49155, 40 experts top-8  [hf:ibm-granite]."""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512, n_shared=0),
    tie_embeddings=True,
    attn_impl="chunked",
)

SMOKE = ModelConfig(
    name="granite-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=0),
    tie_embeddings=True,
)
