"""phi-3-vision-4.2b [vlm]: 32L d=3072 32H d_ff=8192 vocab=32064,
phi3-mini backbone + CLIP patch frontend STUB (input_specs provides
precomputed patch embeddings)  [hf:microsoft/Phi-3-vision-128k-instruct]."""
from ..models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    vlm=VLMConfig(n_patches=576, d_patch=1024),
    attn_impl="chunked",
    kv_cache_dtype="int8",
)

SMOKE = ModelConfig(
    name="phi3v-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    vlm=VLMConfig(n_patches=16, d_patch=32),
)
