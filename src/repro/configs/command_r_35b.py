"""command-r-35b [dense]: 40L d=8192 64H (kv=8) d_ff=22528 vocab=256000,
GQA, no bias  [hf:CohereForAI/c4ai-command-r-v01].
Note: upstream uses parallel attn+FFN blocks and LayerNorm; we keep the
assigned dims with a standard sequential pre-norm block (DESIGN.md §3)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    norm="layernorm",
    tie_embeddings=True,
    attn_impl="chunked",
)

SMOKE = ModelConfig(
    name="command-r-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab=512,
    norm="layernorm",
    tie_embeddings=True,
)
