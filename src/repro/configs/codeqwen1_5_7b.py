"""codeqwen1.5-7b [dense]: 32L d=4096 32H (kv=32 -> MHA) d_ff=13440
vocab=92416  [hf:Qwen/CodeQwen1.5-7B]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,
    attn_impl="chunked",
    kv_cache_dtype="int8",
)

SMOKE = ModelConfig(
    name="codeqwen-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
)
