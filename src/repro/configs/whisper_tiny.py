"""whisper-tiny [audio]: 4+4L d=384 6H d_ff=1536 vocab=51865, enc-dec;
conv/mel frontend is a STUB (input_specs provides precomputed frame
embeddings)  [arXiv:2212.04356]."""
from ..models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    norm="layernorm",
    activation="gelu",
    tie_embeddings=True,
    encdec=EncDecConfig(n_encoder_layers=4, n_decoder_layers=4),
    attn_impl="chunked",
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    norm="layernorm",
    activation="gelu",
    tie_embeddings=True,
    encdec=EncDecConfig(n_encoder_layers=2, n_decoder_layers=2),
)
