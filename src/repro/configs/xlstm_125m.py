"""xlstm-125m [ssm]: 12L d=768 4H vocab=50304, sLSTM + mLSTM blocks
(3 mLSTM : 1 sLSTM)  [arXiv:2405.04517]."""
from ..models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    tie_embeddings=True,
    xlstm=XLSTMConfig(mlstm_per_group=3),
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab=256,
    tie_embeddings=True,
    xlstm=XLSTMConfig(mlstm_per_group=3, chunk=16),
)
