"""The paper's own experiment configuration (Table II)."""
from ..core.cost import CostParams

PAPER_PARAMS = CostParams(
    lam=1.0, mu=1.0, rho=1.0, alpha=0.8, omega=5, theta=0.2, gamma=0.85
)
BATCH_SIZE = 200          # requests per batch
N_SERVERS = 600
N_ITEMS = 60              # post top-10% universe
D_MAX = 5
