"""qwen2.5-3b [dense]: 36L d=2048 16H (kv=2) d_ff=11008 vocab=151936,
GQA with QKV bias  [hf:Qwen/Qwen2.5]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    attn_impl="chunked",
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
    tie_embeddings=True,
)
