"""deepseek-v2-236b [moe]: 60L d=5120 128H d_ff(expert)=1536 vocab=102400,
MLA kv_lora=512, 2 shared + 160 routed top-6  [arXiv:2405.04434]."""
from ..models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,            # MLA: latent cache, kv head count unused
    d_ff=12288,                # dense first-layer ffn (HF: intermediate_size)
    vocab=102400,
    d_head=128,
    moe=MoEConfig(
        n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
        first_k_dense=1, d_ff_dense=12288,
    ),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    attn_impl="chunked",
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    d_head=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                  first_k_dense=1, d_ff_dense=128),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                  rope_head_dim=8, nope_head_dim=16, v_head_dim=16),
)
