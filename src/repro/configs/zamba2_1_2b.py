"""zamba2-1.2b [hybrid]: 38L d=2048 32H d_ff=8192 vocab=32000 ssm_state=64,
Mamba2 stack + ONE weight-shared attention block applied every 6 layers
[arXiv:2411.15242]."""
from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  shared_attn_every=6),
    attn_impl="chunked",
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                  shared_attn_every=2, chunk=16),
)
