"""Assigned architecture configs (+ the paper's own AKPC config).

``get_config(arch_id)`` returns the FULL assigned config;
``get_smoke_config(arch_id)`` returns a reduced same-family config for CPU
smoke tests (small layers/width/vocab, few experts).

Shapes (assignment):
  train_4k     seq 4096,   global batch 256   (train_step)
  prefill_32k  seq 32768,  global batch 32    (inference prefill)
  decode_32k   seq 32768,  global batch 128   (serve_step, 1 new token)
  long_500k    seq 524288, global batch 1     (serve_step; sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig

ARCHS = [
    "deepseek_v2_236b",
    "granite_moe_3b_a800m",
    "h2o_danube_1_8b",
    "command_r_35b",
    "qwen2_5_3b",
    "codeqwen1_5_7b",
    "xlstm_125m",
    "whisper_tiny",
    "zamba2_1_2b",
    "phi_3_vision_4_2b",
]

# canonical dash-form ids of the assignment mapped to module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "deepseek-v2-236b": "deepseek_v2_236b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "command-r-35b": "command_r_35b",
    "qwen2.5-3b": "qwen2_5_3b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "xlstm-125m": "xlstm_125m",
    "whisper-tiny": "whisper_tiny",
    "zamba2-1.2b": "zamba2_1_2b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
})

SHAPES = {
    "train_4k": {"kind": "train", "seq_len": 4096, "global_batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32768, "global_batch": 32},
    "decode_32k": {"kind": "decode", "seq_len": 32768, "global_batch": 128},
    "long_500k": {"kind": "decode", "seq_len": 524288, "global_batch": 1},
}


def resolve(arch: str) -> str:
    return ALIASES.get(arch, arch)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{resolve(arch)}", __package__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{resolve(arch)}", __package__)
    return mod.SMOKE


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    if shape == "long_500k":
        return cfg.subquadratic
    return True
