"""Per-window, per-item feature extraction for the learned policy.

The featurizer is the frozen seam between training and serving: the
trainer (``learned.train``) and the serving policy (``learned.policy``)
both build their inputs HERE, from the same running per-item stats and
the same window summaries, so a model trained offline scores exactly the
features the policy computes at a T_CG boundary.

Two implementations of the same math:

* :func:`features_np` — numpy float64, the CANONICAL path.  Keep/evict
  decisions are made from these features on host for every backend
  (numpy replay, jax replay, live serving), which is what makes
  cross-backend cost parity exact — same idea as the cost specs that
  ride the device schedule as data.
* :func:`features_jnp` — a pure-``jnp`` twin (device-ready, used by the
  jit'd training loop; numerically equal to the numpy path at f64,
  tests/test_learned.py).

``FEATURE_SCHEMA_VERSION`` tags trained parameter sets; a policy refuses
params whose schema does not match the featurizer it would serve them
with.
"""
from __future__ import annotations

import numpy as np

#: frozen feature-schema tag, bumped on ANY change to FEATURE_NAMES or
#: the formulas below; LearnedParams carry it and the policy checks it
FEATURE_SCHEMA_VERSION = 1

#: feature column names, in order (F = len(FEATURE_NAMES))
FEATURE_NAMES = (
    "log_window_count",   # log1p(#accesses in the window just ended)
    "recency",            # (now - last-seen boundary) / t_cg, clipped
    "co_degree",          # log1p(binary-CRM row degree in the window)
    "log_size",           # log(item volume)
    "clique_excess",      # log1p(current clique size - 1)
    "gap_ratio",          # EMA inter-arrival estimate / dt, clipped
    "log_total_count",    # log1p(lifetime access count)
)

#: EMA factor for the inter-arrival estimate (higher = more reactive)
EMA_GAP = 0.3

#: clip ceiling for the unbounded ratio features (recency, gap_ratio)
RATIO_CLIP = 8.0


def init_stats(n: int, dt: float) -> dict:
    """Fresh running per-item stats for a catalog of ``n`` items.

    ``last`` is the boundary time of the last window the item appeared
    in (-inf = never seen), ``ema_gap`` an EMA estimate of the item's
    inter-arrival time (seeded at the cache TTL ``dt`` — "unknown items
    re-arrive right at the keep/evict break-even"), ``total`` the
    lifetime access count.  All float64: these arrays travel through
    policy snapshots and must restore bitwise.
    """
    return {
        "last": np.full(n, -np.inf, dtype=np.float64),
        "ema_gap": np.full(n, float(dt), dtype=np.float64),
        "total": np.zeros(n, dtype=np.float64),
    }


def update_stats(stats: dict, counts: np.ndarray, now: float,
                 t_cg: float) -> dict:
    """Fold one finished window into the running stats (in place).

    The window's mean inter-arrival is estimated as ``t_cg / count`` for
    accessed items (count accesses spread over a t_cg-long window) and
    EMA-merged; unaccessed items keep their previous estimate.
    """
    counts = np.asarray(counts, dtype=np.float64)
    acc = counts > 0
    gap_est = t_cg / np.maximum(counts, 1.0)
    stats["ema_gap"] = np.where(
        acc, (1.0 - EMA_GAP) * stats["ema_gap"] + EMA_GAP * gap_est,
        stats["ema_gap"])
    stats["last"] = np.where(acc, float(now), stats["last"])
    stats["total"] = stats["total"] + counts
    return stats


def window_co_degree(crm, n: int) -> np.ndarray:
    """(n,) f64 co-access degree from a window's binary CRM.

    Items outside the CRM's hot set (or windows with no binary edges)
    get degree 0 — the same "cold items carry no co-access signal" rule
    the clique generator applies.
    """
    deg = np.zeros(n, dtype=np.float64)
    if crm is not None and crm.hot_items.size:
        deg[crm.hot_items] = crm.binary.sum(axis=1).astype(np.float64)
    return deg


def features_np(counts, co_deg, stats, sizes, clique_sizes, now: float,
                dt: float, t_cg: float) -> np.ndarray:
    """(n, F) float64 feature matrix — the canonical host path.

    ``counts``/``co_deg`` summarise the window just ended, ``stats`` the
    running history AFTER :func:`update_stats` folded that window in,
    ``clique_sizes`` the per-item size of the item's CURRENT clique.
    """
    counts = np.asarray(counts, dtype=np.float64)
    co_deg = np.asarray(co_deg, dtype=np.float64)
    sizes = np.asarray(sizes, dtype=np.float64)
    csz = np.asarray(clique_sizes, dtype=np.float64)
    t_cg = max(float(t_cg), 1e-12)
    dt = max(float(dt), 1e-12)
    rec = np.clip((float(now) - stats["last"]) / t_cg, 0.0, RATIO_CLIP)
    gap = np.clip(stats["ema_gap"] / dt, 0.0, RATIO_CLIP)
    return np.stack([
        np.log1p(counts),
        rec,
        np.log1p(co_deg),
        np.log(np.maximum(sizes, 1e-12)),
        np.log1p(np.maximum(csz - 1.0, 0.0)),
        gap,
        np.log1p(stats["total"]),
    ], axis=1)


def features_jnp(counts, co_deg, stats, sizes, clique_sizes, now,
                 dt: float, t_cg: float):
    """Pure-``jnp`` twin of :func:`features_np` (same math, same order).

    Traceable: every input may be a traced array; ``dt``/``t_cg`` are
    static floats.  Under x64 this matches the numpy path to f64
    round-off (tests pin 1e-12 relative).
    """
    import jax.numpy as jnp

    t_cg = max(float(t_cg), 1e-12)
    dt = max(float(dt), 1e-12)
    rec = jnp.clip((now - stats["last"]) / t_cg, 0.0, RATIO_CLIP)
    gap = jnp.clip(stats["ema_gap"] / dt, 0.0, RATIO_CLIP)
    return jnp.stack([
        jnp.log1p(counts),
        rec,
        jnp.log1p(co_deg),
        jnp.log(jnp.maximum(sizes, 1e-12)),
        jnp.log1p(jnp.maximum(clique_sizes - 1.0, 0.0)),
        gap,
        jnp.log1p(stats["total"]),
    ], axis=1)
