"""Learned cache-policy subsystem (DESIGN.md §14).

Three layers over the existing caching core:

* :mod:`featurize` — per-window, per-item features (recency, window
  frequency, CRM co-access degree, size, clique size, inter-arrival
  stats) with a frozen schema version, in numpy f64 and pure-``jnp``
  twin implementations;
* :mod:`train` — hindsight-labeled windows (:mod:`labels`) replayed
  through one jit'd AdamW training scan over a small MLP
  (``models/mlp.py`` + ``optim/adamw.py``), ``train_policy(trace, env,
  cfg) -> LearnedParams``, checkpointable via :mod:`repro.checkpoint`;
* :mod:`policy` — the ``learned`` keep-or-not :class:`CachePolicy`
  (registered in ``repro.core.policy``) serving the trained scorer
  inside ``on_window`` through every replay backend.
"""
from .featurize import (
    FEATURE_NAMES,
    FEATURE_SCHEMA_VERSION,
    features_jnp,
    features_np,
    init_stats,
    update_stats,
    window_co_degree,
)
from .model import LearnedParams, forward_jnp, forward_np, init_params, warm_params
from .labels import hindsight_windows
from .policy import LearnedPolicy
from .train import (
    TrainConfig,
    load_learned_params,
    save_learned_params,
    train_policy,
)

__all__ = [
    "FEATURE_NAMES",
    "FEATURE_SCHEMA_VERSION",
    "LearnedParams",
    "LearnedPolicy",
    "TrainConfig",
    "features_jnp",
    "features_np",
    "forward_jnp",
    "forward_np",
    "hindsight_windows",
    "init_params",
    "init_stats",
    "load_learned_params",
    "save_learned_params",
    "train_policy",
    "update_stats",
    "warm_params",
    "window_co_degree",
]
