"""Jit'd training loop: hindsight windows -> AdamW scan -> LearnedParams.

The whole optimisation — AdamW state init, ``cfg.steps`` minibatch steps,
final full-data loss — is ONE jit'd function whose body is a
``lax.scan``, so a ``train_policy`` call costs exactly one traced compile
per fresh problem shape (``TRAIN_TRACES`` counts them, SCAN_TRACES
style, and tests assert the delta stays <= 2).  Example counts are
padded up to a power-of-two bucket so traces of nearby lengths share the
compiled executable.

Minibatches are importance-sampled proportionally to the hindsight cost
delta ``|cost_keep - cost_evict|`` (host rng, seeded — deterministic),
which folds the example weights into the sampling distribution: the scan
loss is a plain mean of BCE-with-logits over the batch, and the
economically irrelevant weight-0 rows (and padding) are simply never
drawn.

Training math runs under ``enable_x64`` with f64 params (AdamW keeps f32
moments); the returned :class:`LearnedParams` is numpy f64 throughout
and round-trips through :mod:`repro.checkpoint` via
:func:`save_learned_params` / :func:`load_learned_params`.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from ..core.cost import CacheEnvironment, CostParams
from .featurize import FEATURE_NAMES, FEATURE_SCHEMA_VERSION
from .labels import hindsight_windows
from .model import LearnedParams, warm_params

#: cumulative count of traced compiles of the training step function
#: (incremented at TRACE time, inside the jit'd body — the SCAN_TRACES
#: pattern).  ``train_policy`` is budgeted at <= 2 per call.
TRAIN_TRACES = 0


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Knobs for :func:`train_policy` (defaults sized for smoke runs)."""

    steps: int = 200          # minibatch steps in the scan
    batch: int = 256          # examples per step
    lr: float = 3e-2
    weight_decay: float = 1e-4
    clip_norm: float = 1.0
    warmup_frac: float = 0.1  # warmup_steps = warmup_frac * steps
    warmup_floor: float = 0.1  # short runs: don't start at lr ~ 0
    min_lr_frac: float = 0.05
    d: int = 8                # scorer trunk width
    d_ff: int = 16            # scorer trunk hidden width
    seed: int = 0             # init + minibatch sampling
    keep_factor: float = 1.0  # TTL warm-start threshold factor
    pad_bucket: int = 512     # min example-count bucket (rounded up pow2)


def _bucket(n: int, floor: int) -> int:
    b = max(int(floor), 1)
    while b < n:
        b *= 2
    return b


@functools.lru_cache(maxsize=8)
def _trainer(n_pad: int, n_feat: int, steps: int, batch: int, acfg):
    """Compile-cached jit'd trainer for one (shape, AdamW-config) key."""
    import jax
    import jax.numpy as jnp

    from ..optim.adamw import adamw_init, adamw_update
    from .model import forward_jnp

    del n_pad, n_feat, steps, batch  # shape key only; shapes ride the args

    def impl(w, mu, sd, X, y, wt, idx):
        global TRAIN_TRACES
        TRAIN_TRACES += 1

        def batch_loss(w, xb, yb):
            s = forward_jnp(w, mu, sd, xb)
            return jnp.mean(jax.nn.softplus(s) - yb * s)

        grad_fn = jax.value_and_grad(batch_loss)
        state = adamw_init(w)

        def step(carry, ib):
            w, st = carry
            loss, g = grad_fn(w, X[ib], y[ib])
            w2, st2, _ = adamw_update(acfg, g, st, w)
            return (w2, st2), loss

        (w_fin, _), losses = jax.lax.scan(step, (w, state), idx)
        s = forward_jnp(w_fin, mu, sd, X)
        final = jnp.sum(wt * (jax.nn.softplus(s) - y * s)) / jnp.maximum(
            jnp.sum(wt), 1e-12)
        return w_fin, losses, final

    return jax.jit(impl)


def train_policy(trace, env: CacheEnvironment | None = None,
                 cfg: TrainConfig | None = None, *, t_cg: float = 50.0,
                 params: CostParams | None = None,
                 cost_model="table1") -> LearnedParams:
    """Hindsight-label ``trace``'s windows and fit the keep/evict scorer.

    Starts from the TTL-equivalent warm init (:func:`model.warm_params`),
    so on degenerate inputs (no windows, or no example with a nonzero
    cost delta) it returns the warm start untouched.
    """
    from jax.experimental import enable_x64

    from ..optim.adamw import AdamWConfig

    cfg = cfg or TrainConfig()
    params = params or (env.params if env is not None else CostParams())
    env = CacheEnvironment.resolve(env, trace, params)
    X, y, wt = hindsight_windows(trace, env, t_cg, params=params,
                                 cost_model=cost_model)
    lp = warm_params(params.lam, params.mu, t_cg, cfg.keep_factor,
                     seed=cfg.seed, d=cfg.d, d_ff=cfg.d_ff)
    n = X.shape[0]
    w_sum = float(wt.sum())
    if n == 0 or w_sum <= 0.0:
        return lp

    lp.mu = X.mean(axis=0)
    lp.sd = np.maximum(X.std(axis=0), 1e-9)
    n_pad = _bucket(n, cfg.pad_bucket)
    Xp = np.zeros((n_pad, X.shape[1]), np.float64)
    yp = np.zeros(n_pad, np.float64)
    wp = np.zeros(n_pad, np.float64)
    Xp[:n], yp[:n], wp[:n] = X, y, wt

    rng = np.random.default_rng(cfg.seed)
    idx = rng.choice(n, size=(cfg.steps, cfg.batch),
                     p=wt / w_sum).astype(np.int32)
    acfg = AdamWConfig(
        lr=cfg.lr, weight_decay=cfg.weight_decay, clip_norm=cfg.clip_norm,
        warmup_steps=max(int(cfg.warmup_frac * cfg.steps), 1),
        total_steps=cfg.steps, min_lr_frac=cfg.min_lr_frac,
        warmup_floor=cfg.warmup_floor)
    fn = _trainer(n_pad, X.shape[1], cfg.steps, cfg.batch, acfg)
    with enable_x64():
        w_fin, _losses, _final = fn(lp.w, lp.mu, lp.sd, Xp, yp, wp, idx)
    import jax

    lp.w = jax.tree.map(lambda a: np.asarray(a, np.float64), w_fin)
    return lp


def save_learned_params(lp: LearnedParams, directory: str,
                        step: int = 0, meta: dict | None = None) -> str:
    """Persist trained params through :mod:`repro.checkpoint`."""
    from ..checkpoint import save_checkpoint

    m = {"kind": "learned_params", "schema": int(lp.schema),
         "feature_names": list(lp.feature_names)}
    if meta:
        m.update(meta)
    return save_checkpoint(directory, step, lp.tree(), m)


def load_learned_params(directory: str,
                        step: int | None = None) -> LearnedParams:
    """Inverse of :func:`save_learned_params` (newest step by default)."""
    from ..checkpoint import latest_step, load_checkpoint_tree

    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"no committed checkpoint under {directory!r}")
    tree, meta = load_checkpoint_tree(directory, step)
    lp = LearnedParams.from_tree(tree)
    names = meta.get("feature_names")
    if names is not None:
        lp.feature_names = tuple(names)
    if lp.schema != FEATURE_SCHEMA_VERSION or lp.feature_names != FEATURE_NAMES:
        raise ValueError(
            f"checkpoint schema v{lp.schema} {lp.feature_names} does not "
            f"match featurizer v{FEATURE_SCHEMA_VERSION} {FEATURE_NAMES}")
    return lp
