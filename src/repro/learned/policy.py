"""The ``learned`` keep-or-not CachePolicy: serve the trained scorer.

Same engine contract as the TTL baseline (``TTLKeepOrNotPolicy``): no
packing — every partition is the singleton partition — and at each T_CG
boundary a per-item keep/evict mask is recomputed, realised by the
replay engines through the :meth:`item_keep` hook (numpy
``set_item_keep``, jax per-event ``nokeep`` tensors, live boundary
evictions).  What changes is HOW the mask is chosen: the window is
featurized (:mod:`featurize`) and scored by the trained model
(:mod:`model`), keep iff score >= 0.

Decisions are computed once, on host, in numpy float64 — every backend
consumes the identical mask, which is what makes cross-backend cost
parity exact rather than approximate.  All three replay drivers call
``on_window`` with the same window contents and the same boundary
timestamp (the crossing request's time), so the recency features agree
too.

With no trained parameters the policy serves the TTL-equivalent warm
start (:func:`model.warm_params`) and reproduces the TTL baseline's
decisions exactly — tests pin this equivalence.
"""
from __future__ import annotations

import time as _time

import numpy as np

from ..core.cliques import CliquePartition
from ..core.cost import CacheEnvironment, CostModel, CostParams, get_cost_model
from ..core.crm import build_window_crm
from ..core.policy import BasePolicy
from .featurize import (
    features_np,
    init_stats,
    update_stats,
    window_co_degree,
)
from .model import LearnedParams, forward_np, warm_params


class LearnedPolicy(BasePolicy):
    """Keep-or-not policy scored by a trained (or warm-start) model.

    ``learned`` is a :class:`LearnedParams` from ``train_policy`` /
    ``load_learned_params``; ``None`` serves the TTL-equivalent warm
    start built from ``params``/``t_cg``/``keep_factor``.  ``top_frac``
    bounds the window CRM used for the co-access-degree feature (1.0 =
    all window items, the keep-or-not default: no packing means no
    hot-set pruning pressure).
    """

    name = "learned"

    def __init__(
        self,
        params: CostParams | None = None,
        t_cg: float = 50.0,
        learned: LearnedParams | None = None,
        keep_factor: float = 1.0,
        top_frac: float = 1.0,
        caching_charge="requested",
        batch_size: int | None = None,
        env: CacheEnvironment | None = None,
        cost_model: str | CostModel = "table1",
    ):
        # bind() (called by super().__init__) reads these
        self.learned = learned
        self.keep_factor = keep_factor
        self.t_cg = t_cg
        self.top_frac = top_frac
        super().__init__(params, env=env, cost_model=cost_model)
        self.caching_charge = caching_charge
        self.batch_size = batch_size

    # -- lifecycle ---------------------------------------------------------
    def bind(self, n: int, m: int) -> None:
        super().bind(n, m)
        self._keep = np.ones(n, dtype=bool)
        p = self.params
        env = self.env
        if env is not None and env.m == m and m > 0:
            self._dt = float(np.max(get_cost_model(self.cost_model, env).dt()))
        else:
            self._dt = p.rho * p.lam / max(p.mu, 1e-12)
        if env is not None and env.n == n:
            self._sizes = env.sizes()
        else:
            self._sizes = np.ones(n, dtype=np.float64)
        self._stats = init_stats(n, self._dt)
        if self.learned is not None:
            self._lp = self.learned
        else:
            self._lp = warm_params(p.lam, p.mu, self.t_cg, self.keep_factor)

    # -- engine hooks ------------------------------------------------------
    def item_keep(self) -> np.ndarray:
        """Engine keep-or-not hook: the current per-item keep mask."""
        return self._keep

    def on_window(self, items, servers, now):
        del servers
        t0 = _time.perf_counter()
        flat = items[items >= 0]
        counts = np.bincount(flat, minlength=self.n).astype(np.float64)
        crm = (build_window_crm(items, self.n, self.params.theta,
                                self.top_frac)
               if flat.size else None)
        co_deg = window_co_degree(crm, self.n)
        update_stats(self._stats, counts, float(now), self.t_cg)
        if self._partition is not None:
            part_prev = self._partition
            csz = part_prev.sizes()[part_prev.clique_of].astype(np.float64)
        else:
            csz = np.ones(self.n, dtype=np.float64)
        X = features_np(counts, co_deg, self._stats, self._sizes, csz,
                        float(now), self._dt, self.t_cg)
        self._keep = forward_np(self._lp, X) >= 0.0
        part = CliquePartition.singletons(self.n)
        self._record(part, _time.perf_counter() - t0)
        return part

    # -- snapshot ----------------------------------------------------------
    def state_dict(self) -> dict:
        d = super().state_dict()
        d["keep"] = self._keep.copy()
        d["feat"] = {k: v.copy() for k, v in self._stats.items()}
        lp = self._lp.tree()
        d["lp"] = {
            "schema": lp["schema"],
            "mu": lp["mu"].copy(),
            "sd": lp["sd"].copy(),
            "w": {
                k: ({kk: vv.copy() for kk, vv in v.items()}
                    if isinstance(v, dict) else np.asarray(v).copy())
                for k, v in lp["w"].items()
            },
        }
        return d

    def load_state_dict(self, state, partition=None) -> None:
        super().load_state_dict(state, partition)
        if "keep" in state:
            self._keep = np.asarray(state["keep"]).astype(bool).copy()
        if "feat" in state:
            self._stats = {
                k: np.asarray(v, np.float64).copy()
                for k, v in state["feat"].items()
            }
        if "lp" in state:
            self._lp = LearnedParams.from_tree(state["lp"])
