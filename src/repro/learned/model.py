"""The learned keep/evict scorer: linear skip + small gated-MLP residual.

    score(x) = z @ w_lin + b + mlp(z @ w_in) @ w_out,   z = (x - mu) / sd

where ``mlp`` is one SwiGLU block built with the seed model stack
(``models.mlp.init_mlp`` / ``mlp_forward``).  An item is KEPT iff its
score is >= 0.

Two forwards over the same parameter tree:

* :func:`forward_np` — numpy float64, the canonical serving path (the
  policy decides keep/evict with it on host, for every backend);
* :func:`forward_jnp` — the ``jnp`` twin the jit'd trainer
  differentiates through (``mlp_forward`` verbatim).

:func:`warm_params` zeroes the MLP head and sets the linear part to the
TTL break-even rule on the ``log_window_count`` feature — so an
UNTRAINED ``learned`` policy reproduces the TTL baseline's decisions
exactly (tests pin this), and training starts from a sane prior instead
of noise.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .featurize import FEATURE_NAMES, FEATURE_SCHEMA_VERSION

#: trunk activation — SwiGLU, matching the seed model zoo's default
ACTIVATION = "silu"


@dataclasses.dataclass
class LearnedParams:
    """Trained scorer: weights + input normalisation + schema tag.

    Everything is a plain numpy-f64 pytree (nested dicts of arrays), so
    the whole object snapshots through ``CacheSession`` checkpoints and
    ``repro.checkpoint`` unchanged.
    """

    schema: int                      # FEATURE_SCHEMA_VERSION at train time
    mu: np.ndarray                   # (F,) feature means
    sd: np.ndarray                   # (F,) feature stds (>= 1e-9)
    w: dict                          # {"w_lin","b","w_in","trunk","w_out"}
    feature_names: tuple = FEATURE_NAMES

    @property
    def n_features(self) -> int:
        return int(self.mu.shape[0])

    def tree(self) -> dict:
        """Checkpointable pure-array pytree (inverse: :meth:`from_tree`)."""
        return {
            "schema": np.int64(self.schema),
            "mu": self.mu,
            "sd": self.sd,
            "w": self.w,
        }

    @classmethod
    def from_tree(cls, tree: dict) -> "LearnedParams":
        w = {k: (dict(v) if isinstance(v, dict) else np.asarray(v, np.float64))
             for k, v in tree["w"].items()}
        if "trunk" in w:
            w["trunk"] = {k: np.asarray(v, np.float64)
                          for k, v in w["trunk"].items()}
        return cls(
            schema=int(tree["schema"]),
            mu=np.asarray(tree["mu"], np.float64),
            sd=np.asarray(tree["sd"], np.float64),
            w=w,
        )


def init_params(seed: int = 0, d: int = 8, d_ff: int = 16,
                n_features: int | None = None) -> LearnedParams:
    """Fresh scorer parameters (zero linear part, ``init_mlp`` trunk).

    The trunk comes from the seed stack's ``models.mlp.init_mlp`` (one
    stacked layer, SwiGLU); the output head ``w_out`` starts at ZERO so
    a fresh scorer is exactly its linear part — see :func:`warm_params`.
    All leaves are cast to numpy float64 (the serving dtype).
    """
    import jax
    import jax.numpy as jnp

    from ..models.common import KeyGen, dense_init
    from ..models.mlp import init_mlp

    F = n_features if n_features is not None else len(FEATURE_NAMES)
    kg = KeyGen(jax.random.PRNGKey(seed))
    trunk = init_mlp(kg, d, d_ff, 1, jnp.float32, ACTIVATION)
    w = {
        "w_lin": np.zeros(F, np.float64),
        "b": np.zeros((), np.float64),
        "w_in": np.asarray(dense_init(kg(), (F, d), jnp.float32, fan_in=F),
                           np.float64),
        "trunk": {k: np.asarray(v, np.float64) for k, v in trunk.items()},
        "w_out": np.zeros(d, np.float64),
    }
    return LearnedParams(
        schema=FEATURE_SCHEMA_VERSION,
        mu=np.zeros(F, np.float64),
        sd=np.ones(F, np.float64),
        w=w,
    )


def warm_params(lam: float, mu_price: float, t_cg: float,
                keep_factor: float = 1.0, seed: int = 0, d: int = 8,
                d_ff: int = 16) -> LearnedParams:
    """TTL-equivalent warm start.

    The TTL baseline keeps item i iff ``count_i * lam >= keep_factor *
    mu * t_cg``.  With the zeroed MLP head the scorer is linear in the
    features, and ``log1p`` is strictly monotone, so

        score = log1p(count) - log1p(keep_factor * mu * t_cg / lam)

    has the same sign as the TTL rule.  Training then refines from the
    baseline instead of from noise (and ``w_out`` is the first gradient
    to move, switching the MLP residual on smoothly).
    """
    p = init_params(seed=seed, d=d, d_ff=d_ff)
    thr = keep_factor * mu_price * t_cg / max(lam, 1e-12)
    p.w["w_lin"][0] = 1.0
    p.w["b"] = np.float64(-np.log1p(thr))
    return p


def _silu_np(x: np.ndarray) -> np.ndarray:
    # branch on sign so exp() never sees a large positive argument
    pos = x >= 0
    e = np.exp(np.where(pos, -x, x))
    return np.where(pos, x / (1.0 + e), x * e / (1.0 + e))


def forward_np(params: LearnedParams, x: np.ndarray) -> np.ndarray:
    """(n, F) features -> (n,) scores; numpy f64, the canonical path."""
    if params.schema != FEATURE_SCHEMA_VERSION:
        raise ValueError(
            f"LearnedParams schema {params.schema} != featurizer schema "
            f"{FEATURE_SCHEMA_VERSION}; retrain or pin the older repro")
    w = params.w
    z = (np.asarray(x, np.float64) - params.mu) / params.sd
    h = z @ w["w_in"]
    t = w["trunk"]
    g = _silu_np(h @ t["wi"][0])
    if "wg" in t:
        g = g * (h @ t["wg"][0])
    y = g @ t["wo"][0]
    return z @ w["w_lin"] + w["b"] + y @ w["w_out"]


def forward_jnp(w: dict, mu, sd, x):
    """``jnp`` twin of :func:`forward_np` over the raw weight tree.

    Takes the weight pytree (not the dataclass) so the trainer can
    differentiate through it; the trunk runs through ``mlp_forward``
    verbatim.  Matches the numpy path to f64 round-off under x64.
    """
    from ..models.mlp import mlp_forward

    z = (x - mu) / sd
    h = z @ w["w_in"]
    t = {k: v[0] for k, v in w["trunk"].items()}
    y = mlp_forward(t, h, ACTIVATION)
    return z @ w["w_lin"] + w["b"] + y @ w["w_out"]
