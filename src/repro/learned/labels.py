"""Hindsight keep/evict labels from cost deltas (the training signal).

For every T_CG window ``w`` and item ``i`` the trainer asks: given the
decision the policy must make at the ``w``-boundary, which choice would
the NEXT window's accesses have made cheaper?

* ``cost_evict`` — every access of ``i`` in window ``w+1`` is a forced
  miss priced as a plain (unpacked) transfer, and nothing is ever
  cached: exactly what the engine's keep-or-not mask charges for a
  "nokeep" item (``ReplayEngine.set_item_keep``).
* ``cost_keep`` — mirrors the engine's Alg.-6 ANCHOR semantics and its
  charged (non-hypothetical) cost fields: the copy at the item's anchor
  server (the server of its most recent access) never truly expires —
  a lapsed anchor copy is ratcheted forward in ``dt`` steps, so the
  access is a HIT whose charged extension is only
  ``(gap - dt) mod dt`` (the ratchet rent itself lands in the
  diagnostic ``keepalive_rent``, which is NOT part of ``total``).  An
  access within ``dt`` of the same item's previous refresh at that
  server pays extension rent ``rate * gap``; an off-anchor access
  whose server copy lapsed pays a transfer plus the prepaid re-cache
  rent ``rate * dt_j``.  (First access of the window treats the
  boundary as the previous anchor touch — a deliberate window-local
  simplification: carry-over state from window ``w`` is not modeled.)

Both sides are priced through the SAME registered CostModel hooks
(``transfer_cost_batch`` / ``caching_rate`` / ``dt``) the replay engine
uses, so labels follow per-server prices and item volumes under the
tiered/heterogeneous models with no extra code.

The label is ``keep iff cost_keep < cost_evict`` and the example weight
is ``|cost_keep - cost_evict|`` — items whose decision is economically
irrelevant (unaccessed next window: both sides 0) drop out of the loss
with weight 0 instead of being filtered.
"""
from __future__ import annotations

import numpy as np

from ..core.cost import CacheEnvironment, CostParams, get_cost_model
from ..core.crm import build_window_crm
from .featurize import features_np, init_stats, update_stats, window_co_degree


def _window_index(times: np.ndarray, t_cg: float) -> np.ndarray:
    """Window id per request, matching the engine's boundary semantics
    (a request exactly AT a boundary opens the next window)."""
    t0 = float(times[0])
    return np.floor((np.asarray(times, np.float64) - t0) / t_cg).astype(
        np.int64)


def hindsight_windows(
    trace,
    env: CacheEnvironment | None = None,
    t_cg: float = 50.0,
    *,
    params: CostParams | None = None,
    cost_model="table1",
    theta: float | None = None,
    top_frac: float = 1.0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Replay ``trace``'s windows into (features X, labels y, weights w).

    Returns ``X (N, F) f64``, ``y (N,) f64`` in {0, 1} and ``w (N,) f64``
    with ``N = (n_windows - 1) * n`` — one labeled example per (window,
    item), the last window unlabeled (no hindsight).  Features are built
    with the same :mod:`featurize` machinery the serving policy uses.
    """
    params = params or (env.params if env is not None else CostParams())
    env = CacheEnvironment.resolve(env, trace, params)
    model = get_cost_model(cost_model, env)
    theta = params.theta if theta is None else theta
    dt_j = np.asarray(model.dt(), np.float64)        # (m,)
    dt_s = float(dt_j.max())
    sizes = env.sizes()
    n, t_cg = trace.n, float(t_cg)
    items2d = trace.items if trace.items.ndim == 2 else trace.items[:, None]
    widx = _window_index(trace.times, t_cg)
    W = int(widx[-1]) + 1
    t0 = float(trace.times[0])

    stats = init_stats(n, dt_s)
    ones_n = np.ones(n, np.float64)
    X_parts, y_parts, w_parts = [], [], []
    for w in range(W - 1):
        sel = widx == w
        it_w = items2d[sel]
        flat = it_w[it_w >= 0]
        counts = np.bincount(flat, minlength=n).astype(np.float64)
        crm = build_window_crm(it_w, n, theta, top_frac) if flat.size else None
        boundary = t0 + (w + 1) * t_cg
        update_stats(stats, counts, boundary, t_cg)
        X_parts.append(features_np(
            counts, window_co_degree(crm, n), stats, sizes, ones_n,
            boundary, dt_s, t_cg))

        # -- hindsight costs from window w+1 -----------------------------
        nxt = np.nonzero(widx == w + 1)[0]
        evict_c = np.zeros(n, np.float64)
        keep_c = np.zeros(n, np.float64)
        if nxt.size:
            it_n = items2d[nxt]
            valid = it_n >= 0
            rr, cc = np.nonzero(valid)
            it = it_n[rr, cc].astype(np.int64)
            tt = np.asarray(trace.times, np.float64)[nxt][rr]
            sv = np.asarray(trace.servers, np.int64)[nxt][rr]
            # anchor order: per item, by time
            oa = np.lexsort((tt, it))
            it, tt, sv = it[oa], tt[oa], sv[oa]
            first = np.ones(it.size, bool)
            first[1:] = it[1:] != it[:-1]
            prev_t = np.empty_like(tt)
            prev_t[first] = boundary
            prev_t[~first] = tt[np.nonzero(~first)[0] - 1]
            prev_sv = np.full(it.size, -1, np.int64)
            prev_sv[~first] = sv[np.nonzero(~first)[0] - 1]
            gap = np.maximum(tt - prev_t, 0.0)
            # per-(item, server) order: gap since this server's own copy
            # was last refreshed (inf = not refreshed this window)
            ob = np.lexsort((tt, sv, it))
            first_js = np.ones(it.size, bool)
            first_js[1:] = (it[ob][1:] != it[ob][:-1]) | (
                sv[ob][1:] != sv[ob][:-1])
            gap_js = np.full(it.size, np.inf)
            nf = np.nonzero(~first_js)[0]
            gap_js[ob[nf]] = tt[ob[nf]] - tt[ob[nf - 1]]
            one = np.ones(it.size, np.int64)
            trans = np.asarray(model.transfer_cost_batch(
                one, sizes[it], sv), np.float64)
            rate = np.asarray(model.caching_rate(
                one, sizes[it], sv), np.float64)
            dt_acc = dt_j[sv]
            # anchor access (same server as previous, or window-first):
            # always a hit — fresh pays extension rent over the gap,
            # lapsed pays only the ratchet remainder (gap - dt) mod dt.
            # Off-anchor: own-copy extension rent within TTL, else a
            # transfer plus the prepaid re-cache rent dt_j.
            at_anchor = first | (sv == prev_sv)
            ratchet = np.mod(np.maximum(gap - dt_acc, 0.0), dt_acc)
            anchor_cost = rate * np.where(gap <= dt_acc, gap, ratchet)
            keep_cost = np.where(
                at_anchor, anchor_cost,
                np.where(gap_js <= dt_acc, rate * gap_js,
                         trans + rate * dt_acc))
            np.add.at(evict_c, it, trans)
            np.add.at(keep_c, it, keep_cost)
        y_parts.append((keep_c < evict_c).astype(np.float64))
        w_parts.append(np.abs(evict_c - keep_c))

    if not X_parts:
        F = features_np(ones_n, ones_n, stats, sizes, ones_n,
                        t0, dt_s, t_cg).shape[1]
        return (np.zeros((0, F)), np.zeros(0), np.zeros(0))
    return (np.concatenate(X_parts, axis=0),
            np.concatenate(y_parts),
            np.concatenate(w_parts))
