"""Streaming CacheSession: online replay with mid-stream costs + snapshots.

The paper's AKPC is an *online* algorithm — the CDN operator sees requests as
they arrive, not as a finished trace.  ``CacheSession`` is the streaming
driver matching that shape: time-ordered request chunks of ANY size are fed
incrementally through the batched replay engine; T_CG windowing (Alg. 1
Event 1) is tracked across chunk boundaries exactly as the offline
``ReplayEngine.replay`` tracks it across batch boundaries, so a session fed
any chunking of a trace reproduces the offline costs (cost-for-cost, up to
float summation order — tests/test_policy_session.py asserts 1e-9 relative).

Mid-stream the session exposes ``costs`` (the live cost breakdown) and
``snapshot()``/``restore()``: a pure-numpy pytree of the FULL replay state —
engine expiries ``E``, Alg.-6 ``anchor``, the installed clique partition, the
cost accumulators, the open T_CG window buffer and the policy state (previous
window's CRM, size history) — such that a restored session resumes
bit-identically.  ``save()``/``load_snapshot()`` persist snapshots through
``repro.checkpoint`` (atomic commit-marker layout, crash-safe).

Typical live-traffic loop::

    sess = CacheSession(get_policy("akpc", params=p, t_cg=32.0), n, m)
    for chunk in request_feed():            # any chunk size, even 1
        sess.feed(chunk.items, chunk.servers, chunk.times)
        if need_checkpoint():
            sess.save("ckpts", step=sess.costs.n_requests)
    print(sess.result().as_dict())
"""
from __future__ import annotations

import dataclasses
import time as _time

import numpy as np

from .cliques import CliquePartition
from .cost import CostBreakdown
from .engine import DEFAULT_BATCH_SIZE, CacheState, ReplayEngine
from .policy import CachePolicy, RunResult, get_policy


# ---------------------------------------------------------------------------
# partition <-> dense array (snapshots hold numpy only)
# ---------------------------------------------------------------------------
def pack_partition(part: CliquePartition) -> np.ndarray:
    """(k, max|c|) int64, -1 padded, rows in clique-index order.

    Shim over :meth:`CliquePartition.packed` — snapshots, the engine and the
    packed-lookup kernels all share that one array-native layout.  Copied so
    mutating a snapshot never corrupts the partition's cache.
    """
    return part.packed().copy()


def unpack_partition(n: int, packed: np.ndarray) -> CliquePartition:
    cliques = [tuple(int(x) for x in row[row >= 0]) for row in np.asarray(packed)]
    clique_of = np.full(n, -1, np.int32)
    for i, c in enumerate(cliques):
        for d in c:
            clique_of[d] = i
    return CliquePartition(n=n, cliques=cliques, clique_of=clique_of)


class CacheSession:
    """Online driver of one :class:`~repro.core.policy.CachePolicy`.

    ``policy`` may be a registry name or an instance; it is (re)bound to this
    session's catalog.  ``trace`` is only needed by offline policies
    (``dp_greedy`` mines its fixed pairs from it); online policies ignore it.
    """

    def __init__(
        self,
        policy: CachePolicy | str,
        n: int,
        m: int,
        *,
        trace=None,
        batch_size: int | None = None,
    ):
        if isinstance(policy, str):
            policy = get_policy(policy)
        self.policy = policy
        self.n = n
        self.m = m
        policy.bind(n, m)
        self.engine = ReplayEngine(
            n,
            m,
            policy.params,
            caching_charge=getattr(policy, "caching_charge", "requested"),
            seed_new_cliques=getattr(policy, "seed_new_cliques", True),
        )
        part0 = policy.initial_partition(trace) if hasattr(
            policy, "initial_partition") else None
        if part0 is not None:
            self.engine.install_partition(part0, now=0.0)
        self.batch_size = int(
            batch_size or getattr(policy, "batch_size", None) or DEFAULT_BATCH_SIZE
        )
        self._t_cg = policy.t_cg
        self._next_cg: float | None = None
        # open-window buffer: list of (items, servers) chunks since last regen
        self._win: list[tuple[np.ndarray, np.ndarray]] = []
        self._last_t = -np.inf
        self._wall = 0.0

    # -- views -------------------------------------------------------------
    @property
    def costs(self) -> CostBreakdown:
        """Live cost breakdown (valid mid-stream)."""
        return self.engine.costs

    @property
    def partition(self) -> CliquePartition:
        """The currently installed clique partition."""
        return self.engine.state.partition

    @property
    def now(self) -> float:
        """Time of the most recently fed request (-inf before any)."""
        return self._last_t

    # -- streaming ---------------------------------------------------------
    def feed(self, items, servers, times) -> CostBreakdown:
        """Feed one time-ordered chunk of requests; returns live costs.

        ``items`` (R, d_max) int, -1 padded (a 1-D row is a single request);
        ``servers`` (R,); ``times`` (R,) non-decreasing and >= every
        previously fed time.  Chunk boundaries are free: T_CG windows are
        carried across them, and any chunking reproduces the offline replay
        costs.
        """
        t0 = _time.perf_counter()
        items = np.atleast_2d(np.asarray(items))
        servers = np.asarray(servers, dtype=np.int64).reshape(-1)
        times = np.asarray(times, dtype=np.float64).reshape(-1)
        R = times.shape[0]
        if R == 0:
            return self.engine.costs
        if items.shape[0] != R or servers.shape[0] != R:
            raise ValueError(
                f"chunk shape mismatch: items {items.shape}, "
                f"servers {servers.shape}, times {times.shape}"
            )
        if (np.diff(times) < 0).any() or times[0] < self._last_t:
            raise ValueError("requests must be fed in non-decreasing time order")
        windowed = self._t_cg is not None
        if windowed and self._next_cg is None:
            self._next_cg = float(times[0]) + self._t_cg

        pos = 0
        while pos < R:
            cut = R
            if windowed:
                cut = int(np.searchsorted(times, self._next_cg, side="left"))
                if cut <= pos:
                    # request at ``pos`` crosses the T_CG boundary: Event 1
                    t = float(times[pos])
                    self._regenerate(t)
                    while self._next_cg <= t:
                        self._next_cg += self._t_cg
                    continue
            stop = min(pos + self.batch_size, cut)
            self.engine.handle_batch(
                items[pos:stop], servers[pos:stop], times[pos:stop]
            )
            if windowed:
                self._win.append((
                    np.array(items[pos:stop], dtype=np.int32, copy=True),
                    np.array(servers[pos:stop], dtype=np.int32, copy=True),
                ))
            pos = stop
        self._last_t = float(times[-1])
        self._wall += _time.perf_counter() - t0
        return self.engine.costs

    def feed_trace(self, trace, chunk_size: int | None = None) -> CostBreakdown:
        """Stream a full trace through :meth:`feed` in ``chunk_size`` pieces."""
        cs = int(chunk_size or self.batch_size)
        for s in range(0, trace.n_requests, cs):
            self.feed(
                trace.items[s : s + cs],
                trace.servers[s : s + cs],
                trace.times[s : s + cs],
            )
        return self.engine.costs

    def _window_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """The open window's requests as one padded (W, d) array pair."""
        if not self._win:
            return np.zeros((0, 1), np.int32), np.zeros(0, np.int32)
        d = max(a.shape[1] for a, _ in self._win)
        W = sum(a.shape[0] for a, _ in self._win)
        its = np.full((W, d), -1, np.int32)
        svs = np.empty(W, np.int32)
        r = 0
        for a, s in self._win:
            its[r : r + a.shape[0], : a.shape[1]] = a
            svs[r : r + a.shape[0]] = s
            r += a.shape[0]
        return its, svs

    def _regenerate(self, t: float) -> None:
        w_it, w_sv = self._window_arrays()
        part = self.policy.on_window(w_it, w_sv, t)
        if part is not None:
            self.engine.install_partition(part, t, w_it, w_sv)
        self._win = []

    # -- results -----------------------------------------------------------
    def result(self) -> RunResult:
        pol = self.policy
        return RunResult(
            policy=pol.name,
            costs=self.engine.costs,
            clique_sizes=self.partition.sizes(),
            size_history=list(getattr(pol, "size_history", [])),
            n_windows=getattr(pol, "n_windows", 0),
            cg_seconds=getattr(pol, "cg_seconds", 0.0),
            wall_seconds=self._wall,
            config=getattr(pol, "config", None),
        )

    # -- snapshot / restore --------------------------------------------------
    def snapshot(self) -> dict:
        """Pure-numpy pytree of the full replay state (engine + window +
        policy), suitable for ``repro.checkpoint`` or in-memory cloning."""
        st = self.engine.state
        c = self.engine.costs
        w_it, w_sv = self._window_arrays()
        return {
            "engine": {
                "E": st.E.copy(),
                "anchor": st.anchor.copy(),
                "partition": pack_partition(st.partition),
                "costs": {
                    f.name: np.asarray(getattr(c, f.name))
                    for f in dataclasses.fields(c)
                },
            },
            "session": {
                "next_cg": np.float64(
                    np.nan if self._next_cg is None else self._next_cg
                ),
                "last_t": np.float64(self._last_t),
                "win_items": w_it,
                "win_servers": w_sv,
                "wall": np.float64(self._wall),
            },
            "policy": self.policy.state_dict()
            if hasattr(self.policy, "state_dict")
            else {},
        }

    def restore(self, snap: dict) -> "CacheSession":
        """Load a :meth:`snapshot`; the session resumes bit-identically."""
        eng = snap["engine"]
        part = unpack_partition(self.n, eng["partition"])
        E = np.array(eng["E"], dtype=np.float64, copy=True)
        anchor = np.array(eng["anchor"], dtype=np.int32, copy=True)
        if E.shape != (part.k, self.m):
            raise ValueError(
                f"snapshot shape mismatch: E {E.shape} vs partition "
                f"k={part.k}, m={self.m}"
            )
        self.engine.state = CacheState(
            partition=part, E=E, anchor=anchor, m=self.m
        )
        self.engine._sizes = part.sizes().astype(np.int64)
        c = self.engine.costs
        for f in dataclasses.fields(c):
            cast = type(getattr(c, f.name))       # int or float field
            setattr(c, f.name, cast(np.asarray(eng["costs"][f.name]).item()))
        ses = snap["session"]
        nc = float(ses["next_cg"])
        self._next_cg = None if np.isnan(nc) else nc
        self._last_t = float(ses["last_t"])
        self._wall = float(ses["wall"])
        w_it = np.asarray(ses["win_items"]).astype(np.int32)
        w_sv = np.asarray(ses["win_servers"]).astype(np.int32)
        self._win = [] if w_it.shape[0] == 0 else [(w_it, w_sv)]
        if hasattr(self.policy, "load_state_dict"):
            self.policy.load_state_dict(snap.get("policy", {}), partition=part)
        return self

    # -- persistence (repro.checkpoint) --------------------------------------
    def save(self, directory: str, step: int = 0) -> str:
        """Persist :meth:`snapshot` via ``repro.checkpoint`` (atomic)."""
        from ..checkpoint import save_checkpoint

        return save_checkpoint(
            directory,
            step,
            self.snapshot(),
            meta={"policy": self.policy.name, "n": self.n, "m": self.m},
        )


def load_snapshot(directory: str, step: int | None = None) -> dict:
    """Read a session snapshot written by :meth:`CacheSession.save`.

    Returns the nested numpy pytree for :meth:`CacheSession.restore` (the
    caller constructs the session with the same policy/catalog first).
    """
    from ..checkpoint import latest_step, load_checkpoint_tree

    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed snapshot under {directory}")
    tree, _ = load_checkpoint_tree(directory, step)
    return tree
