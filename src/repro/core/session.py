"""Streaming CacheSession: online replay with mid-stream costs + snapshots.

The paper's AKPC is an *online* algorithm — the CDN operator sees requests as
they arrive, not as a finished trace.  ``CacheSession`` is the streaming
driver matching that shape: time-ordered request chunks of ANY size are fed
incrementally through the batched replay engine; T_CG windowing (Alg. 1
Event 1) is tracked across chunk boundaries exactly as the offline
``ReplayEngine.replay`` tracks it across batch boundaries, so a session fed
any chunking of a trace reproduces the offline costs (cost-for-cost, up to
float summation order — tests/test_policy_session.py asserts 1e-9 relative).

Mid-stream the session exposes ``costs`` (the live cost breakdown) and
``snapshot()``/``restore()``: a pure-numpy pytree of the FULL replay state —
engine expiries ``E``, Alg.-6 ``anchor``, the installed clique partition, the
cost accumulators, the open T_CG window buffer and the policy state (previous
window's CRM, size history) — such that a restored session resumes
bit-identically.  ``save()``/``load_snapshot()`` persist snapshots through
``repro.checkpoint`` (atomic commit-marker layout, crash-safe).

Typical live-traffic loop::

    sess = CacheSession(get_policy("akpc", params=p, t_cg=32.0), n, m)
    for chunk in request_feed():            # any chunk size, even 1
        sess.feed(chunk.items, chunk.servers, chunk.times)
        if need_checkpoint():
            sess.save("ckpts", step=sess.costs.n_requests)
    print(sess.result().as_dict())
"""
from __future__ import annotations

import dataclasses
import time as _time

import numpy as np

from .cliques import CliquePartition
from .cost import CacheEnvironment, CostBreakdown
from .engine import DEFAULT_BATCH_SIZE, CacheState, ReplayEngine
from .policy import CachePolicy, RunResult, get_policy


def _tag_to_array(tag: str) -> np.ndarray:
    """Cost-model tag as a uint8 byte array (checkpoint stores numerics)."""
    return np.frombuffer(tag.encode("utf-8"), dtype=np.uint8).copy()


def _tag_from_array(a) -> str:
    return bytes(np.asarray(a, dtype=np.uint8)).decode("utf-8")


def _params_array(params) -> np.ndarray:
    """Numeric CostParams fields in declared order (the snapshot wire
    format shared by snapshot() and restore(); cost_mode travels as a
    tag)."""
    return np.array([
        float(getattr(params, f.name))
        for f in dataclasses.fields(params) if f.name != "cost_mode"
    ])


# ---------------------------------------------------------------------------
# partition <-> dense array (snapshots hold numpy only)
# ---------------------------------------------------------------------------
def pack_partition(part: CliquePartition) -> np.ndarray:
    """(k, max|c|) int64, -1 padded, rows in clique-index order.

    Shim over :meth:`CliquePartition.packed` — snapshots, the engine and the
    packed-lookup kernels all share that one array-native layout.  Copied so
    mutating a snapshot never corrupts the partition's cache.
    """
    return part.packed().copy()


def unpack_partition(n: int, packed: np.ndarray) -> CliquePartition:
    cliques = [tuple(int(x) for x in row[row >= 0]) for row in np.asarray(packed)]
    clique_of = np.full(n, -1, np.int32)
    for i, c in enumerate(cliques):
        for d in c:
            clique_of[d] = i
    return CliquePartition(n=n, cliques=cliques, clique_of=clique_of)


class CacheSession:
    """Online driver of one :class:`~repro.core.policy.CachePolicy`.

    ``policy`` may be a registry name or an instance; it is (re)bound to this
    session's catalog.  ``trace`` is only needed by offline policies
    (``dp_greedy`` mines its fixed pairs from it); online policies ignore it.
    """

    def __init__(
        self,
        policy: CachePolicy | str,
        n: int,
        m: int,
        *,
        trace=None,
        batch_size: int | None = None,
        env: CacheEnvironment | None = None,
        backend: str = "numpy",
        layout=None,
    ):
        from .state_layout import StateLayout

        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown replay backend {backend!r}")
        self.backend = backend
        # device state geometry for jax-backed feeds; host state stays
        # dense (k, m) under every layout, so snapshots carry only a tag
        self.layout = StateLayout.resolve(layout)
        if isinstance(policy, str):
            policy = get_policy(policy)
        self.policy = policy
        self.n = n
        self.m = m
        policy.bind(n, m)
        if env is None:
            env = getattr(policy, "env", None)
        if trace is not None:
            # same resolution rule as the offline run_policy driver
            env = CacheEnvironment.resolve(env, trace, policy.params)
        elif env is None:
            env = CacheEnvironment(n=n, m=m, params=policy.params)
        self.env = env
        self.engine = ReplayEngine(
            n,
            m,
            policy.params,
            caching_charge=getattr(policy, "caching_charge", "requested"),
            seed_new_cliques=getattr(policy, "seed_new_cliques", True),
            env=env,
            cost_model=getattr(policy, "cost_model", "table1"),
        )
        part0 = policy.initial_partition(trace) if hasattr(
            policy, "initial_partition") else None
        if part0 is not None:
            self.engine.install_partition(part0, now=0.0)
        self.batch_size = int(
            batch_size or getattr(policy, "batch_size", None) or DEFAULT_BATCH_SIZE
        )
        self._t_cg = policy.t_cg
        self._next_cg: float | None = None
        # open-window buffer: list of (items, servers) chunks since last regen
        self._win: list[tuple[np.ndarray, np.ndarray]] = []
        self._last_t = -np.inf
        self._wall = 0.0

    # -- views -------------------------------------------------------------
    @property
    def costs(self) -> CostBreakdown:
        """Live cost breakdown (valid mid-stream)."""
        return self.engine.costs

    @property
    def partition(self) -> CliquePartition:
        """The currently installed clique partition."""
        return self.engine.state.partition

    @property
    def now(self) -> float:
        """Time of the most recently fed request (-inf before any)."""
        return self._last_t

    # -- streaming ---------------------------------------------------------
    def feed(self, items, servers, times) -> CostBreakdown:
        """Feed one time-ordered chunk of requests; returns live costs.

        ``items`` (R, d_max) int, -1 padded (a 1-D row is a single request);
        ``servers`` (R,); ``times`` (R,) non-decreasing and >= every
        previously fed time.  Chunk boundaries are free: T_CG windows are
        carried across them, and any chunking reproduces the offline replay
        costs.
        """
        t0 = _time.perf_counter()
        items = np.atleast_2d(np.asarray(items))
        servers = np.asarray(servers, dtype=np.int64).reshape(-1)
        times = np.asarray(times, dtype=np.float64).reshape(-1)
        R = times.shape[0]
        if R == 0:
            return self.engine.costs
        if items.shape[0] != R or servers.shape[0] != R:
            raise ValueError(
                f"chunk shape mismatch: items {items.shape}, "
                f"servers {servers.shape}, times {times.shape}"
            )
        if (np.diff(times) < 0).any() or times[0] < self._last_t:
            raise ValueError("requests must be fed in non-decreasing time order")
        windowed = self._t_cg is not None
        if windowed and self._next_cg is None:
            self._next_cg = float(times[0]) + self._t_cg

        pos = 0
        while pos < R:
            cut = R
            if windowed:
                cut = int(np.searchsorted(times, self._next_cg, side="left"))
                if cut <= pos:
                    # request at ``pos`` crosses the T_CG boundary: Event 1
                    t = float(times[pos])
                    self._regenerate(t)
                    while self._next_cg <= t:
                        self._next_cg += self._t_cg
                    continue
            stop = min(pos + self.batch_size, cut)
            self.engine.handle_batch(
                items[pos:stop], servers[pos:stop], times[pos:stop]
            )
            if windowed:
                self._win.append((
                    np.array(items[pos:stop], dtype=np.int32, copy=True),
                    np.array(servers[pos:stop], dtype=np.int32, copy=True),
                ))
            pos = stop
        self._last_t = float(times[-1])
        self._wall += _time.perf_counter() - t0
        return self.engine.costs

    def feed_trace(self, trace, chunk_size: int | None = None,
                   backend: str | None = None) -> CostBreakdown:
        """Stream a full trace through :meth:`feed` in ``chunk_size`` pieces.

        Refuses a sized trace when this session's size-aware model would
        price it with a size-less environment — that would silently break
        the streaming == offline contract (the offline driver derives the
        environment from the trace).  Construct the session with
        ``trace=...`` or ``env=CacheEnvironment.from_trace(...)`` instead.

        ``backend="jax"`` (or a session constructed with ``backend="jax"``)
        replays the whole trace through the device-resident scan engine
        (``repro.core.engine_jax``) and syncs the resulting state, costs
        and T_CG window bookkeeping back into this session — mid-stream
        continuation, :meth:`snapshot`/:meth:`restore` and later numpy
        :meth:`feed` calls all behave as if the trace had been fed
        chunk-by-chunk (costs equal at 1e-9, tests/test_sweep.py).
        """
        sizes = getattr(trace, "sizes", None)
        if sizes is not None and self.engine.model.uses_sizes \
                and self.engine.env.item_sizes is None:
            # (an env with explicit sizes is a deliberate override and wins,
            # exactly as in the offline driver)
            raise ValueError(
                "trace carries item sizes but the session's environment has "
                "none; pass trace= or env= at construction")
        backend = backend or self.backend
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown replay backend {backend!r}")
        if backend == "jax":
            return self._feed_trace_jax(trace)
        cs = int(chunk_size or self.batch_size)
        for s in range(0, trace.n_requests, cs):
            self.feed(
                trace.items[s : s + cs],
                trace.servers[s : s + cs],
                trace.times[s : s + cs],
            )
        return self.engine.costs

    def _feed_trace_jax(self, trace) -> CostBreakdown:
        """One device-scan replay of ``trace``, continuing this session's
        open T_CG window and cache state (DESIGN.md §10)."""
        from .engine_jax import JaxReplayEngine

        R = trace.n_requests
        if R == 0:
            return self.engine.costs
        # same contract as feed(): a Trace validates sortedness at
        # construction, but duck-typed request containers may not
        if (np.diff(trace.times) < 0).any() \
                or float(trace.times[0]) < self._last_t:
            raise ValueError(
                "requests must be fed in non-decreasing time order")
        t0 = _time.perf_counter()
        windowed = self._t_cg is not None
        if windowed and self._next_cg is None:
            self._next_cg = float(trace.times[0]) + self._t_cg
        # one JaxReplayEngine per session: its shape ratchet + jit caches
        # survive across chunks, so ragged tail chunks pad into the fixed
        # chunk shape instead of compiling a fresh scan
        jeng = getattr(self, "_jeng", None)
        if jeng is None:
            jeng = self._jeng = JaxReplayEngine(
                engine=self.engine, layout=self.layout)
        win_prefix = self._window_arrays() if windowed and self._win else None
        jeng.replay(
            trace,
            clique_generator=self.policy.on_window if windowed else None,
            t_cg=self._t_cg,
            batch_size=self.batch_size,
            next_cg0=self._next_cg if windowed else None,
            win_prefix=win_prefix,
        )
        sched = jeng.last_schedule
        if windowed:
            if sched.next_cg is not None:
                self._next_cg = sched.next_cg
            if sched.boundary_hit:
                self._win = []      # prefix was consumed by an Event 1
            if sched.win_start < R:
                self._win.append((
                    np.array(trace.items[sched.win_start:], dtype=np.int32,
                             copy=True),
                    np.array(trace.servers[sched.win_start:], dtype=np.int32,
                             copy=True),
                ))
        self._last_t = float(trace.times[-1])
        self._wall += _time.perf_counter() - t0
        return self.engine.costs

    def _window_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """The open window's requests as one padded (W, d) array pair."""
        if not self._win:
            return np.zeros((0, 1), np.int32), np.zeros(0, np.int32)
        d = max(a.shape[1] for a, _ in self._win)
        W = sum(a.shape[0] for a, _ in self._win)
        its = np.full((W, d), -1, np.int32)
        svs = np.empty(W, np.int32)
        r = 0
        for a, s in self._win:
            its[r : r + a.shape[0], : a.shape[1]] = a
            svs[r : r + a.shape[0]] = s
            r += a.shape[0]
        return its, svs

    def _regenerate(self, t: float) -> None:
        w_it, w_sv = self._window_arrays()
        part = self.policy.on_window(w_it, w_sv, t)
        if part is not None:
            self.engine.install_partition(part, t, w_it, w_sv)
        keep_fn = getattr(self.policy, "item_keep", None)
        if keep_fn is not None:     # keep-or-not boundary sync (TTL)
            self.engine.set_item_keep(keep_fn())
        self._win = []

    # -- results -----------------------------------------------------------
    def result(self) -> RunResult:
        pol = self.policy
        return RunResult(
            policy=pol.name,
            costs=self.engine.costs,
            clique_sizes=self.partition.sizes(),
            size_history=list(getattr(pol, "size_history", [])),
            n_windows=getattr(pol, "n_windows", 0),
            cg_seconds=getattr(pol, "cg_seconds", 0.0),
            wall_seconds=self._wall,
            config=getattr(pol, "config", None),
        )

    # -- snapshot / restore --------------------------------------------------
    def snapshot(self) -> dict:
        """Pure-numpy pytree of the full replay state (engine + window +
        policy), suitable for ``repro.checkpoint`` or in-memory cloning."""
        st = self.engine.state
        c = self.engine.costs
        w_it, w_sv = self._window_arrays()
        env = self.engine.env
        return {
            "engine": {
                "E": st.E.copy(),
                "anchor": st.anchor.copy(),
                "partition": pack_partition(st.partition),
                # cost-model tag + environment arrays: a restored session
                # must price requests under the SAME scenario (restore
                # validates; empty arrays = homogeneous defaults)
                "cost_model": _tag_to_array(self.engine.model.name),
                "model_config": self.engine.model.config_array(),
                # device state layout this session replays under: host
                # state is dense either way, so dense <-> bucketed
                # snapshots interchange freely; a row-sharded restore
                # validates the shard count against the session's mesh
                "layout": _tag_to_array(self.layout.tag),
                "layout_shards": np.int64(self.layout.row_shards),
                "env": {
                    "lam_j": (env.lam_j.copy() if env.lam_j is not None
                              else np.zeros(0)),
                    "mu_j": (env.mu_j.copy() if env.mu_j is not None
                             else np.zeros(0)),
                    "item_sizes": (env.item_sizes.copy()
                                   if env.item_sizes is not None
                                   else np.zeros(0)),
                    # scalar pricing knobs + the cost_mode tag
                    "params": _params_array(env.params),
                    "cost_mode": _tag_to_array(env.params.cost_mode),
                },
                "costs": {
                    f.name: (
                        _tag_to_array(c.model) if f.name == "model"
                        else np.asarray(getattr(c, f.name))
                    )
                    for f in dataclasses.fields(c)
                },
            },
            "session": {
                "next_cg": np.float64(
                    np.nan if self._next_cg is None else self._next_cg
                ),
                "last_t": np.float64(self._last_t),
                "win_items": w_it,
                "win_servers": w_sv,
                "wall": np.float64(self._wall),
            },
            "policy": self.policy.state_dict()
            if hasattr(self.policy, "state_dict")
            else {},
        }

    def restore(self, snap: dict) -> "CacheSession":
        """Load a :meth:`snapshot`; the session resumes bit-identically.

        Refuses snapshots taken under a different cost model or environment
        than this session's — resuming them would silently mix accounting
        regimes (same contract as :meth:`CostBreakdown.merge`).
        """
        eng = snap["engine"]
        if "cost_model" in eng:
            want = _tag_from_array(eng["cost_model"])
            have = self.engine.model.name
            if want != have:
                raise ValueError(
                    f"snapshot was taken under cost model {want!r}, session "
                    f"runs {have!r}")
        if "layout" in eng:       # pre-layout snapshots restore as dense
            self.layout.check_restore(
                _tag_from_array(eng["layout"]),
                int(np.asarray(eng.get("layout_shards", 1)).item()))
        env = self.engine.env
        snap_env = eng.get("env", {})
        if "cost_mode" in snap_env and \
                _tag_from_array(snap_env["cost_mode"]) != env.params.cost_mode:
            raise ValueError(
                f"snapshot cost_mode {_tag_from_array(snap_env['cost_mode'])!r}"
                f" != session {env.params.cost_mode!r}")
        my_params = _params_array(env.params)
        for key, mine in (
            ("lam_j", env.lam_j), ("mu_j", env.mu_j),
            ("item_sizes", env.item_sizes),
            ("params", my_params),
        ):
            if key == "params" and "params" not in snap_env:
                continue                              # pre-PR-4 snapshots
            theirs = np.asarray(snap_env.get(key, np.zeros(0)))
            mine = np.zeros(0) if mine is None else mine
            if theirs.shape != mine.shape:
                raise ValueError(
                    f"snapshot environment mismatch on {key}: shape "
                    f"{theirs.shape} vs {mine.shape}")
            if not np.array_equal(theirs, mine):
                raise ValueError(
                    f"snapshot environment mismatch on {key}: values differ "
                    f"(max abs diff {np.abs(theirs - mine).max():.3g})")
        if "model_config" in eng:
            theirs = np.asarray(eng["model_config"])
            mine = self.engine.model.config_array()
            if theirs.shape != mine.shape or not np.array_equal(theirs, mine):
                raise ValueError(
                    "snapshot was taken under a differently-configured "
                    f"{self.engine.model.name!r} model (e.g. tier schedule)")
        part = unpack_partition(self.n, eng["partition"])
        E = np.array(eng["E"], dtype=np.float64, copy=True)
        anchor = np.array(eng["anchor"], dtype=np.int32, copy=True)
        if E.shape != (part.k, self.m):
            raise ValueError(
                f"snapshot shape mismatch: E {E.shape} vs partition "
                f"k={part.k}, m={self.m}"
            )
        self.engine.state = CacheState(
            partition=part, E=E, anchor=anchor, m=self.m
        )
        self.engine._set_partition_caches(part)   # member counts + volumes
        c = self.engine.costs
        for f in dataclasses.fields(c):
            if f.name == "model":
                if "model" in eng["costs"]:           # pre-PR-4 snapshots
                    c.model = _tag_from_array(eng["costs"]["model"])
                continue
            cast = type(getattr(c, f.name))       # int or float field
            setattr(c, f.name, cast(np.asarray(eng["costs"][f.name]).item()))
        ses = snap["session"]
        nc = float(ses["next_cg"])
        self._next_cg = None if np.isnan(nc) else nc
        self._last_t = float(ses["last_t"])
        self._wall = float(ses["wall"])
        w_it = np.asarray(ses["win_items"]).astype(np.int32)
        w_sv = np.asarray(ses["win_servers"]).astype(np.int32)
        self._win = [] if w_it.shape[0] == 0 else [(w_it, w_sv)]
        if hasattr(self.policy, "load_state_dict"):
            self.policy.load_state_dict(snap.get("policy", {}), partition=part)
        keep_fn = getattr(self.policy, "item_keep", None)
        if keep_fn is not None:
            # snapshotted state already reflects past evictions; only the
            # engine's mask needs re-aligning with the restored policy
            self.engine.set_item_keep(keep_fn(), evict=False)
        return self

    # -- persistence (repro.checkpoint) --------------------------------------
    def save(self, directory: str, step: int = 0) -> str:
        """Persist :meth:`snapshot` via ``repro.checkpoint`` (atomic)."""
        from ..checkpoint import save_checkpoint

        return save_checkpoint(
            directory,
            step,
            self.snapshot(),
            meta={"policy": self.policy.name, "n": self.n, "m": self.m},
        )


def load_snapshot(directory: str, step: int | None = None) -> dict:
    """Read a session snapshot written by :meth:`CacheSession.save`.

    Returns the nested numpy pytree for :meth:`CacheSession.restore` (the
    caller constructs the session with the same policy/catalog first).
    """
    from ..checkpoint import latest_step, load_checkpoint_tree

    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed snapshot under {directory}")
    tree, _ = load_checkpoint_tree(directory, step)
    return tree
