"""Cost model of the K-PackCache problem (paper §III.C, Table I, eqs. 1-5).

Two cost components paid by the CDN operator:

* transfer cost  C_T : paid to the network provider per transfer event.
    unpacked  p items : p * lambda
    packed    p items : (1 + (p-1) * alpha) * lambda          (Table I)
* caching  cost  C_P : storage rental, ``items * mu`` per unit time; every
  access extends the expiry of the cached unit to ``t + dt`` where
  ``dt = rho * lambda / mu``  (Alg. 6 line 1).

``alpha in [0, 1]`` is the packing discount: for alpha < 1 packed transfer is
always cheaper than individual transfers.

The paper's pseudocode (Alg. 5 line 11) literally charges ``alpha*mu*|c|`` for
a packed transfer, which is inconsistent with its own Table I and with the
competitive proof (both use ``(1+(|c|-1)*alpha)*lambda``).  We default to the
Table-I form (``cost_mode="consistent"``) and keep the literal pseudocode form
available (``cost_mode="paper_literal"``) for reproduction of the raw
pseudocode.  See DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

CostMode = Literal["consistent", "paper_literal"]


@dataclasses.dataclass(frozen=True)
class CostParams:
    """All scalar knobs of the cost model + AKPC hyper-parameters (Table II)."""

    lam: float = 1.0          # base transfer cost (lambda)
    mu: float = 1.0           # caching cost per item per unit time
    rho: float = 1.0          # cost ratio; dt = rho * lam / mu
    alpha: float = 0.8        # packing discount factor  (Table II: 0.8)
    omega: int = 5            # max (and target) clique size  (Table II: 5)
    theta: float = 0.2        # CRM binarisation threshold  (Table II: 0.2)
    gamma: float = 0.85       # approximate-merge density threshold (Table II)
    cost_mode: CostMode = "consistent"

    @property
    def dt(self) -> float:
        """Cache lifetime extension Delta-t = rho * lambda / mu (Alg. 6)."""
        return self.rho * self.lam / self.mu

    def transfer_cost(self, p: int, *, packed: bool) -> float:
        """Transfer cost of moving ``p`` items in one event (Table I)."""
        if p <= 0:
            return 0.0
        if not packed or p == 1:
            return p * self.lam
        if self.cost_mode == "paper_literal":
            # Alg. 5 line 11 (literal):  C_T += alpha * mu * |c|
            return self.alpha * self.mu * p
        return (1.0 + (p - 1) * self.alpha) * self.lam

    def caching_cost(self, n_items: int, duration: float) -> float:
        """Rental cost of keeping ``n_items`` cached for ``duration`` time."""
        if duration <= 0.0 or n_items <= 0:
            return 0.0
        return n_items * self.mu * duration


@dataclasses.dataclass
class CostBreakdown:
    """Mutable cost accumulator shared by every engine/baseline."""

    transfer: float = 0.0         # C_T
    caching: float = 0.0          # C_P
    keepalive_rent: float = 0.0   # hypothetical rent of Alg.6 last-copy
    n_requests: int = 0
    n_item_requests: int = 0      # sum |D_i|
    n_misses: int = 0             # clique-transfer events
    n_hits: int = 0
    items_transferred: int = 0    # includes unrequested clique members

    @property
    def total(self) -> float:
        return self.transfer + self.caching

    def merge(self, other: "CostBreakdown") -> "CostBreakdown":
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))
        return self

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total"] = self.total
        return d


def competitive_bound(S: int, omega: int, alpha: float) -> float:
    """Theorem 1's bound AS STATED: (2 + (omega-1)*alpha*S) / (1 + (S-1)*alpha).

    NOTE (paper erratum, see DESIGN.md §7): the paper's own case analysis
    derives C_AKPC = S*(2+(omega-1)*alpha)*lam and C_OPT = (1+(S-1)*alpha)*lam
    but then mis-simplifies the ratio — S*(2+(omega-1)*alpha) was written as
    2+(omega-1)*alpha*S, dropping S from the "2" term (they agree only at
    S=1).  The bound that actually follows from the analysis (and that the
    Thm-2 adversary realises EXACTLY — see tests/test_competitive.py) is
    ``competitive_bound_corrected``.
    """
    if S < 1:
        raise ValueError("S must be >= 1")
    return (2.0 + (omega - 1) * alpha * S) / (1.0 + (S - 1) * alpha)


def competitive_bound_corrected(S: int, omega: int, alpha: float) -> float:
    """The tight bound implied by the paper's case analysis:

        S * (2 + (omega-1)*alpha) / (1 + (S-1)*alpha).

    Matches Thm 1 at S=1; for S>1 it is the ratio the paper's own adversary
    (Thm 2) enforces, hence tight.
    """
    if S < 1:
        raise ValueError("S must be >= 1")
    return S * (2.0 + (omega - 1) * alpha) / (1.0 + (S - 1) * alpha)
