"""Cost layer of the K-PackCache problem (paper §III.C, Table I, eqs. 1-5).

Two cost components paid by the CDN operator:

* transfer cost  C_T : paid to the network provider per transfer event.
    unpacked  p items : p * lambda
    packed    p items : (1 + (p-1) * alpha) * lambda          (Table I)
* caching  cost  C_P : storage rental, ``items * mu`` per unit time; every
  access extends the expiry of the cached unit to ``t + dt`` where
  ``dt = rho * lambda / mu``  (Alg. 6 line 1).

``alpha in [0, 1]`` is the packing discount: for alpha < 1 packed transfer is
always cheaper than individual transfers.

The paper's pseudocode (Alg. 5 line 11) literally charges ``alpha*mu*|c|`` for
a packed transfer, which is inconsistent with its own Table I and with the
competitive proof (both use ``(1+(|c|-1)*alpha)*lambda``).  We default to the
Table-I form (``cost_mode="consistent"``) and keep the literal pseudocode form
available (``cost_mode="paper_literal"``) for reproduction of the raw
pseudocode.  See DESIGN.md §2.

Pluggable cost models (PR 4, DESIGN.md §9)
------------------------------------------

Table I is only ONE pricing regime — a single homogeneous scalar
``(lam, mu)`` over unit-size items.  This module generalises the cost layer
into a registry of **vectorized** :class:`CostModel` implementations bound to
a :class:`CacheEnvironment` (per-server prices ``lam_j``/``mu_j``, per-item
sizes ``s_i``):

* ``table1``        the paper's model, bit-identical to the historical
                    scalar ``CostParams`` path (the default everywhere);
* ``tiered``        piecewise-linear CONCAVE transfer pricing (cloud
                    egress/rental tiers à la Le Scouarnec et al.); Table I
                    is its alpha-linear special case — one breakpoint at
                    volume 1, marginal rate alpha beyond;
* ``heterogeneous`` per-server prices + size-weighted transfer/rent
                    (Qin & Etesami-style files-with-sizes over distributed
                    heterogeneous caches); ``dt_j = rho*lam_j/mu_j`` varies
                    per server, which the replay engine handles with a
                    segment-max anchor scan (engine.py, DESIGN.md §9).

Every model exposes three batched hooks consumed by the replay engine:
``transfer_cost_batch(counts, sizes, servers) -> (E,)`` per-event transfer
cost of a whole-clique fetch, ``caching_rate(counts, sizes, servers) -> (E,)``
rent per unit time, and ``dt() -> (m,)`` the per-server TTL extension.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Literal

import numpy as np

CostMode = Literal["consistent", "paper_literal"]


@dataclasses.dataclass(frozen=True)
class CostParams:
    """All scalar knobs of the cost model + AKPC hyper-parameters (Table II)."""

    lam: float = 1.0          # base transfer cost (lambda)
    mu: float = 1.0           # caching cost per item per unit time
    rho: float = 1.0          # cost ratio; dt = rho * lam / mu
    alpha: float = 0.8        # packing discount factor  (Table II: 0.8)
    omega: int = 5            # max (and target) clique size  (Table II: 5)
    theta: float = 0.2        # CRM binarisation threshold  (Table II: 0.2)
    gamma: float = 0.85       # approximate-merge density threshold (Table II)
    cost_mode: CostMode = "consistent"

    @property
    def dt(self) -> float:
        """Cache lifetime extension Delta-t = rho * lambda / mu (Alg. 6)."""
        return self.rho * self.lam / self.mu

    def transfer_cost(self, p: int, *, packed: bool) -> float:
        """Transfer cost of moving ``p`` items in one event (Table I)."""
        if p <= 0:
            return 0.0
        if not packed or p == 1:
            return p * self.lam
        if self.cost_mode == "paper_literal":
            # Alg. 5 line 11 (literal):  C_T += alpha * mu * |c|
            return self.alpha * self.mu * p
        return (1.0 + (p - 1) * self.alpha) * self.lam

    def caching_cost(self, n_items: int, duration: float) -> float:
        """Rental cost of keeping ``n_items`` cached for ``duration`` time."""
        if duration <= 0.0 or n_items <= 0:
            return 0.0
        return n_items * self.mu * duration


# ---------------------------------------------------------------------------
# environment: WHO pays WHAT — servers, prices, item sizes
# ---------------------------------------------------------------------------
def _as_price_array(x, m: int, what: str) -> np.ndarray | None:
    if x is None:
        return None
    a = np.asarray(x, dtype=np.float64)
    if a.shape != (m,):
        raise ValueError(f"{what} must have shape ({m},), got {a.shape}")
    if not np.all(np.isfinite(a)) or (a <= 0).any():
        raise ValueError(f"{what} must be finite and positive")
    return a


@dataclasses.dataclass(frozen=True, eq=False)
class CacheEnvironment:
    """The scenario a cost model prices: catalog, servers, prices, sizes.

    ``lam_j``/``mu_j`` are per-server (ESS) transfer/storage prices,
    ``item_sizes`` per-item volumes; any of them left ``None`` falls back to
    the homogeneous scalar defaults in ``params`` (unit sizes).  The paper's
    Table-II setup is ``CacheEnvironment(n, m, params)`` with everything
    defaulted.
    """

    n: int                      # catalog size |U|
    m: int                      # number of servers |S|
    params: CostParams = dataclasses.field(default_factory=CostParams)
    lam_j: np.ndarray | None = None     # (m,) per-server transfer price
    mu_j: np.ndarray | None = None      # (m,) per-server storage price
    item_sizes: np.ndarray | None = None  # (n,) per-item sizes (None = unit)

    def __post_init__(self):
        if self.n < 0 or self.m < 0:
            raise ValueError(f"n/m must be >= 0, got n={self.n} m={self.m}")
        object.__setattr__(
            self, "lam_j", _as_price_array(self.lam_j, self.m, "lam_j"))
        object.__setattr__(
            self, "mu_j", _as_price_array(self.mu_j, self.m, "mu_j"))
        if self.item_sizes is not None:
            s = np.asarray(self.item_sizes, dtype=np.float64)
            if s.shape != (self.n,):
                raise ValueError(
                    f"item_sizes must have shape ({self.n},), got {s.shape}")
            if not np.all(np.isfinite(s)) or (s <= 0).any():
                raise ValueError("item_sizes must be finite and positive")
            object.__setattr__(self, "item_sizes", s)

    # -- filled views -------------------------------------------------------
    @property
    def homogeneous(self) -> bool:
        """True iff this is the paper's single-price unit-size scenario."""
        return self.lam_j is None and self.mu_j is None and self.item_sizes is None

    def lam_per_server(self) -> np.ndarray:
        if self.lam_j is not None:
            return self.lam_j
        return np.full(self.m, self.params.lam, dtype=np.float64)

    def mu_per_server(self) -> np.ndarray:
        if self.mu_j is not None:
            return self.mu_j
        return np.full(self.m, self.params.mu, dtype=np.float64)

    def sizes(self) -> np.ndarray:
        if self.item_sizes is not None:
            return self.item_sizes
        return np.ones(self.n, dtype=np.float64)

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_trace(cls, trace, params: CostParams | None = None,
                   lam_j=None, mu_j=None) -> "CacheEnvironment":
        """Environment for a trace; picks up ``trace.sizes`` when present."""
        return cls(
            n=trace.n, m=trace.m, params=params or CostParams(),
            lam_j=lam_j, mu_j=mu_j,
            item_sizes=getattr(trace, "sizes", None),
        )

    @classmethod
    def resolve(cls, env: "CacheEnvironment | None", trace,
                params: CostParams | None = None) -> "CacheEnvironment":
        """The environment a driver should price ``trace`` under — THE one
        place encoding the rule every driver shares: no env -> build one
        from the trace; a price-only env + sized trace -> thread the
        trace's sizes in; an env with EXPLICIT sizes wins over the
        trace's."""
        if env is None:
            return cls.from_trace(trace, params)
        sizes = getattr(trace, "sizes", None)
        if env.item_sizes is None and sizes is not None:
            return dataclasses.replace(env, item_sizes=sizes)
        return env

    @classmethod
    def skewed(cls, n: int, m: int, params: CostParams | None = None,
               price_sigma: float = 0.5, size_sigma: float = 0.0,
               seed: int = 0) -> "CacheEnvironment":
        """Synthetic heterogeneous scenario: lognormal per-server prices
        around the scalar defaults (mean-preserving, sigma ``price_sigma``)
        and lognormal item sizes (mean 1, sigma ``size_sigma``).

        Each field draws from its OWN derived rng, so at a fixed seed the
        scenario axes are independent: sweeping ``price_sigma`` never moves
        the item sizes and vice versa (same pattern as the synthetic
        traces' size stream)."""
        params = params or CostParams()

        def logn(mean, sigma, size, key):
            if sigma <= 0.0:
                return None
            rng = np.random.default_rng((seed, key))
            return mean * np.exp(rng.normal(-0.5 * sigma**2, sigma, size))

        return cls(
            n=n, m=m, params=params,
            lam_j=logn(params.lam, price_sigma, m, 1),
            mu_j=logn(params.mu, price_sigma, m, 2),
            item_sizes=logn(1.0, size_sigma, n, 3),
        )


# ---------------------------------------------------------------------------
# the CostModel protocol + registry (mirrors the PR-2 CachePolicy registry)
# ---------------------------------------------------------------------------
class CostModel:
    """Base class of every registered cost model.

    A model is CONFIG (constructor kwargs) + a bound environment
    (:meth:`bind`).  The replay engine consumes the three batched hooks;
    benchmarks/tests use the scalar conveniences, which are generic wrappers
    over the batched hooks (so "batch of one == scalar path" holds by
    construction unless a subclass overrides them).

    Event conventions (matching the engine): each event is ONE transfer /
    rent charge of a group of items at one server — ``counts`` (E,) int item
    multiplicities, ``sizes`` (E,) float total volumes, ``servers`` (E,) int
    server ids.  An event with ``counts > 1`` is a packed (clique) transfer.
    """

    name = "base"
    #: models that ignore sizes let the engine skip per-event size reductions
    uses_sizes = False

    def __init__(self, env: CacheEnvironment | None = None):
        self._env: CacheEnvironment | None = None
        if env is not None:
            self.bind(env)

    # -- binding ------------------------------------------------------------
    def bind(self, env: CacheEnvironment) -> "CostModel":
        """(Re)bind to an environment; returns self.  Idempotent."""
        self._env = env
        self._rebind()
        return self

    def _rebind(self) -> None:
        """Hook for subclasses to precompute bound arrays."""

    def _check_bound(self) -> None:
        if self._env is None:
            raise RuntimeError(f"cost model {self.name!r} is not bound to an "
                               "environment (call .bind(env) first)")

    @property
    def env(self) -> CacheEnvironment:
        self._check_bound()
        return self._env

    @property
    def params(self) -> CostParams:
        return self.env.params

    # -- batched hooks (the engine's hot path) ------------------------------
    def dt(self) -> np.ndarray:
        """(m,) per-server cache-lifetime extension Delta-t_j (Alg. 6)."""
        raise NotImplementedError

    def transfer_cost_batch(
        self, counts: np.ndarray, sizes: np.ndarray, servers: np.ndarray
    ) -> np.ndarray:
        """(E,) cost of transferring each event's group in ONE event."""
        raise NotImplementedError

    def caching_rate(
        self, counts: np.ndarray, sizes: np.ndarray, servers: np.ndarray
    ) -> np.ndarray:
        """(E,) storage rent per unit time of each event's charged group."""
        raise NotImplementedError

    def config_array(self) -> np.ndarray:
        """Float fingerprint of model-specific config (tier schedules, ...)
        beyond the environment — snapshots store it so a restore under a
        differently-configured model of the same name is refused."""
        return np.zeros(0)

    # -- scalar conveniences (benchmarks / property tests) ------------------
    def transfer_cost(self, p: int, *, packed: bool, sizes=None,
                      server: int = 0) -> float:
        """Transfer cost of ``p`` items: one packed event vs p singles.

        ``sizes``: optional per-item sizes (p,); defaults to unit sizes.
        """
        if p <= 0:
            return 0.0
        s = np.ones(p) if sizes is None else np.asarray(sizes, np.float64)
        if s.shape != (p,):
            raise ValueError(f"sizes must have shape ({p},), got {s.shape}")
        if packed:
            return float(self.transfer_cost_batch(
                np.array([p], dtype=np.int64),
                np.array([float(s.sum())]),
                np.array([server], dtype=np.int64))[0])
        return float(self.transfer_cost_batch(
            np.ones(p, dtype=np.int64), s,
            np.full(p, server, dtype=np.int64)).sum())

    def caching_cost(self, n_items: int, duration: float, sizes=None,
                     server: int = 0) -> float:
        """Rent of keeping ``n_items`` cached for ``duration`` time."""
        if duration <= 0.0 or n_items <= 0:
            return 0.0
        s = float(n_items) if sizes is None else float(np.asarray(sizes).sum())
        rate = self.caching_rate(
            np.array([n_items], dtype=np.int64), np.array([s]),
            np.array([server], dtype=np.int64))[0]
        return float(rate * duration)


_COST_MODELS: dict[str, type] = {}


def register_cost_model(name: str, *aliases: str):
    """Register a cost-model class (usable as a class decorator)."""

    def deco(cls):
        for nm in (name, *aliases):
            if nm in _COST_MODELS:
                raise ValueError(f"cost model {nm!r} already registered")
            _COST_MODELS[nm] = cls
        return cls

    return deco


def get_cost_model(
    model: "str | CostModel", env: CacheEnvironment | None = None, **kwargs
) -> CostModel:
    """Resolve a cost model by name (or pass an instance through), binding it
    to ``env`` when given.  Fresh instance every call for names; an instance
    already bound to a DIFFERENT environment is shallow-copied before
    rebinding, so one instance shared across engines never has its pricing
    arrays repointed under an earlier engine's feet."""
    if isinstance(model, CostModel):
        if env is None or model._env is env:
            return model
        if model._env is not None:
            model = copy.copy(model)
        return model.bind(env)
    try:
        cls = _COST_MODELS[model]
    except KeyError:
        raise KeyError(
            f"unknown cost model {model!r}; registered: {sorted(_COST_MODELS)}"
        ) from None
    return cls(env=env, **kwargs)


def list_cost_models() -> list[str]:
    return sorted(_COST_MODELS)


# ---------------------------------------------------------------------------
# shipped models
# ---------------------------------------------------------------------------
@register_cost_model("table1")
class Table1CostModel(CostModel):
    """The paper's Table-I model — BIT-IDENTICAL to the historical scalar
    ``CostParams`` path (same float ops in the same order; see DESIGN.md §9).

    Ignores per-server prices and item sizes: one ``lam``/``mu``, unit items,
    constant ``dt = rho*lam/mu``.
    """

    name = "table1"
    uses_sizes = False

    def dt(self) -> np.ndarray:
        return np.full(self.env.m, self.params.dt, dtype=np.float64)

    def transfer_cost_batch(self, counts, sizes, servers) -> np.ndarray:
        p = self.params
        if p.cost_mode == "paper_literal":
            packed = p.alpha * p.mu * counts
        else:
            packed = (1.0 + (counts - 1) * p.alpha) * p.lam
        return np.where(counts > 1, packed, counts * p.lam)

    def caching_rate(self, counts, sizes, servers) -> np.ndarray:
        return counts * self.params.mu

    # scalar conveniences delegate to the EXACT pre-PR CostParams formulas
    # (the generic base helpers would sum p singleton events, which differs
    # from ``p * lam`` in the last ulp)
    def transfer_cost(self, p, *, packed, sizes=None, server=0) -> float:
        return self.params.transfer_cost(p, packed=packed)

    def caching_cost(self, n_items, duration, sizes=None, server=0) -> float:
        return self.params.caching_cost(n_items, duration)


@register_cost_model("tiered")
class TieredCostModel(CostModel):
    """Piecewise-linear CONCAVE transfer pricing (cloud rental tiers).

    One transfer event of total volume v costs ``lam_j * phi(v)`` where
    ``phi`` is concave piecewise-linear with marginal rate ``rates[k]`` on
    the k-th tier (``breaks`` are the tier boundaries; ``len(rates) ==
    len(breaks) + 1``; rates non-increasing so phi is concave and therefore
    subadditive: packed <= unpacked for ANY tier schedule).  Rent is
    size-weighted: ``mu_j * volume`` per unit time.

    Defaults reproduce Table I exactly on unit sizes: one breakpoint at
    volume 1 and marginal rate ``alpha`` beyond gives
    ``phi(p) = 1 + (p-1)*alpha`` — the paper's Table I is the alpha-linear
    special case of this model (erratum note, DESIGN.md §9).
    """

    name = "tiered"
    uses_sizes = True

    def __init__(self, env: CacheEnvironment | None = None,
                 breaks=None, rates=None):
        self._breaks_cfg = breaks
        self._rates_cfg = rates
        super().__init__(env)

    def _rebind(self) -> None:
        p = self.params
        breaks = (1.0,) if self._breaks_cfg is None else tuple(self._breaks_cfg)
        rates = (1.0, p.alpha) if self._rates_cfg is None else tuple(self._rates_cfg)
        if len(rates) != len(breaks) + 1:
            raise ValueError(
                f"need len(rates) == len(breaks)+1, got {len(rates)} rates "
                f"for {len(breaks)} breaks")
        b = np.asarray(breaks, dtype=np.float64)
        r = np.asarray(rates, dtype=np.float64)
        if (b <= 0).any() or (np.diff(b) <= 0).any():
            raise ValueError("breaks must be positive and increasing")
        if (r < 0).any() or (np.diff(r) > 0).any():
            raise ValueError("rates must be non-negative and non-increasing "
                             "(concavity — guarantees packed <= unpacked)")
        self.breaks = b
        self.rates = r
        # tier edges [0, b_1, ..., b_K, inf] for the vectorized phi
        self._lo = np.concatenate([[0.0], b])
        self._hi = np.concatenate([b, [np.inf]])
        self._lam = self.env.lam_per_server()
        self._mu = self.env.mu_per_server()

    def phi(self, v: np.ndarray) -> np.ndarray:
        """Concave tier price of one event of volume v (phi(0) = 0)."""
        v = np.asarray(v, dtype=np.float64)[..., None]
        seg = np.clip(np.minimum(v, self._hi) - self._lo, 0.0, None)
        return (seg * self.rates).sum(axis=-1)

    def dt(self) -> np.ndarray:
        p = self.params
        return p.rho * self._lam / self._mu

    def transfer_cost_batch(self, counts, sizes, servers) -> np.ndarray:
        self._check_bound()
        return self._lam[servers] * self.phi(sizes)

    def caching_rate(self, counts, sizes, servers) -> np.ndarray:
        self._check_bound()
        return self._mu[servers] * sizes

    def config_array(self) -> np.ndarray:
        return np.concatenate([self.breaks, self.rates])


@register_cost_model("heterogeneous")
class HeterogeneousCostModel(CostModel):
    """Per-server prices + size-weighted costs (files with sizes over
    distributed heterogeneous caches, Qin & Etesami-style).

    * transfer: one event of p items, total volume v, at server j costs
      ``lam_j * v`` unpacked (p == 1) and ``lam_j * v * (1+(p-1)*alpha)/p``
      packed — the Table-I count discount applied to the size-weighted
      volume (reduces to Table I exactly at unit sizes);
    * rent: ``mu_j * volume`` per unit time;
    * ``dt_j = rho * lam_j / mu_j`` — PER SERVER, which is what forces the
      engine's segment-max anchor resolution (DESIGN.md §9).
    """

    name = "heterogeneous"
    uses_sizes = True

    def _rebind(self) -> None:
        self._lam = self.env.lam_per_server()
        self._mu = self.env.mu_per_server()

    def dt(self) -> np.ndarray:
        p = self.params
        return p.rho * self._lam / self._mu

    def transfer_cost_batch(self, counts, sizes, servers) -> np.ndarray:
        p = self.params
        discount = np.where(
            counts > 1, (1.0 + (counts - 1) * p.alpha) / counts, 1.0)
        return self._lam[servers] * sizes * discount

    def caching_rate(self, counts, sizes, servers) -> np.ndarray:
        self._check_bound()
        return self._mu[servers] * sizes


# ---------------------------------------------------------------------------
# cost accumulator
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CostBreakdown:
    """Mutable cost accumulator shared by every engine/baseline.

    ``model`` tags which cost model produced the numbers; :meth:`merge`
    refuses to mix breakdowns priced under different models (the sums would
    be meaningless).
    """

    transfer: float = 0.0         # C_T
    caching: float = 0.0          # C_P
    keepalive_rent: float = 0.0   # hypothetical rent of Alg.6 last-copy
    n_requests: int = 0
    n_item_requests: int = 0      # sum |D_i|
    n_misses: int = 0             # clique-transfer events
    n_hits: int = 0
    items_transferred: int = 0    # includes unrequested clique members
    model: str = "table1"         # cost model that produced these numbers

    @property
    def total(self) -> float:
        return self.transfer + self.caching

    def merge(self, other: "CostBreakdown") -> "CostBreakdown":
        if self.model != other.model:
            raise ValueError(
                f"cannot merge cost breakdowns from different cost models: "
                f"{self.model!r} vs {other.model!r}")
        for f in dataclasses.fields(self):
            if f.name == "model":
                continue
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))
        return self

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total"] = self.total
        return d


# ---------------------------------------------------------------------------
# competitive bounds (Thm. 1 + heterogeneous generalisation)
# ---------------------------------------------------------------------------
def competitive_bound(S: int, omega: int, alpha: float) -> float:
    """Theorem 1's bound AS STATED: (2 + (omega-1)*alpha*S) / (1 + (S-1)*alpha).

    NOTE (paper erratum, see DESIGN.md §7): the paper's own case analysis
    derives C_AKPC = S*(2+(omega-1)*alpha)*lam and C_OPT = (1+(S-1)*alpha)*lam
    but then mis-simplifies the ratio — S*(2+(omega-1)*alpha) was written as
    2+(omega-1)*alpha*S, dropping S from the "2" term (they agree only at
    S=1).  The bound that actually follows from the analysis (and that the
    Thm-2 adversary realises EXACTLY — see tests/test_competitive.py) is
    ``competitive_bound_corrected``.
    """
    if S < 1:
        raise ValueError("S must be >= 1")
    return (2.0 + (omega - 1) * alpha * S) / (1.0 + (S - 1) * alpha)


def competitive_bound_corrected(S: int, omega: int, alpha: float) -> float:
    """The tight bound implied by the paper's case analysis:

        S * (2 + (omega-1)*alpha) / (1 + (S-1)*alpha).

    Matches Thm 1 at S=1; for S>1 it is the ratio the paper's own adversary
    (Thm 2) enforces, hence tight.
    """
    if S < 1:
        raise ValueError("S must be >= 1")
    return S * (2.0 + (omega - 1) * alpha) / (1.0 + (S - 1) * alpha)


def competitive_bound_env(env: CacheEnvironment, S: int, omega: int) -> float:
    """Heterogeneous generalisation of the corrected Thm-1 bound: the MAX
    over servers of the per-server ratio, scaled by the worst volume skew.

    The adversary pins all requests at one server j, where every price is
    lam_j/mu_j and ``dt_j * mu_j = rho * lam_j`` by construction — so a
    missed item of size s in an omega-clique of per-member size <= s_max
    costs AKPC at most ``lam_j * s_max * (1 + (omega-1)*alpha + rho)``
    (packed transfer share + dt rent) while OPT's one packed transfer of
    the S missed items pays at least ``lam_j * s_min * (1+(S-1)*alpha)/S``
    per item.  lam_j cancels inside a server, so the per-server ratio is

        S * (1 + (omega-1)*alpha + rho) / (1 + (S-1)*alpha) * s_max/s_min

    and the bound is its max over servers (constant here, but kept as a
    max_j so per-server alpha/rho extensions stay one-line).  Reduces to
    ``competitive_bound_corrected`` at rho = 1 with unit sizes.
    """
    if S < 1:
        raise ValueError("S must be >= 1")
    p = env.params
    per_server = np.full(
        max(env.m, 1),
        S * (1.0 + (omega - 1) * p.alpha + p.rho) / (1.0 + (S - 1) * p.alpha),
    )
    sizes = env.sizes()
    skew = float(sizes.max() / sizes.min()) if sizes.size else 1.0
    return float(per_server.max() * skew)
