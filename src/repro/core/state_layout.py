"""Device state layouts: dense / bucketed / row-sharded (DESIGN.md §13).

The JAX replay engine keeps the cache state as an ``(n + 1, m)`` expiry
matrix + ``(n + 1,)`` anchor vector (one row per possible clique id plus
the dump row absorbing masked scatters).  That DENSE geometry bakes the
exact catalog/server shape into every compiled scan, which breaks down
in two places the paper's scalability story (fig8) and the ROADMAP's
catalog targets care about:

* **heterogeneous grids** — fig8 varies (n, m) per point, so no two
  points share a compiled shape and a mixed sweep pays one XLA compile
  per point instead of one per cohort;
* **big catalogs** — at n ~ 10^4-10^5 the state matrix stops being a
  single-chip afterthought and wants to be split across devices.

:class:`StateLayout` makes the geometry an explicit, threadable policy:

``dense``
    Today's ``(n + 1, m)`` layout, bitwise default.  Every existing
    entry point resolves ``layout=None`` to this.

``bucketed``
    Rows (catalog) and columns (servers) round UP to padding buckets:
    state is ``(bucket(n) + 1, bucket(m))`` with the dump row moved to
    the LAST row.  Points whose (n, m) fall in the same bucket share
    one compiled scan — a mixed-shape sweep compiles per bucket COHORT,
    not per point.  Padded rows/columns are inert by the same masking
    rules as padded events: rows above the live prefix are never
    gathered by real events, padded columns hold zeros forever (event
    scatters only touch j < m, install seeding only targets real
    servers).

``row_sharded``
    The dense geometry with rows padded to a multiple of the shard
    count and the state rows distributed over a mesh axis via
    ``NamedSharding`` — for catalogs one chip can't hold.  The scan is
    unchanged; GSPMD partitions the row-indexed gathers/scatters.

The layout owns exactly three decisions — state dims, dump-row index,
device placement — so threading it through a layer means passing it to
``fresh_state_arrays`` / ``state_to_device`` / ``build_schedule`` and
nothing else.  Schedules record the geometry they were built for
(``ReplaySchedule.nrow`` / ``ncol``); host-side :class:`CacheState`
stays dense ``(k, m)`` under every layout, which is what makes
snapshots freely portable between dense and bucketed sessions.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

LAYOUT_KINDS = ("dense", "bucketed", "row_sharded")


def _round_up(x: int, step: int) -> int:
    return -(-int(x) // int(step)) * int(step)


@dataclasses.dataclass(frozen=True)
class StateLayout:
    """Geometry + placement policy for the device cache state.

    Parameters
    ----------
    kind : "dense" | "bucketed" | "row_sharded".
    row_bucket, col_bucket : bucket steps for ``bucketed`` (catalog rows
        round up to ``row_bucket`` multiples, server columns to
        ``col_bucket``).  Ignored by the other kinds.
    mesh : a ``jax.sharding.Mesh`` carrying ``row_axis`` — required for
        ``row_sharded`` placement.  Without a mesh the row-sharded
        GEOMETRY (rows padded to a shard multiple) still applies, so the
        layout can be unit-tested on one device.
    shards : explicit row-shard count; defaults to the mesh's
        ``row_axis`` size (1 without a mesh).
    row_axis : mesh axis name the state rows are distributed over.
    """

    kind: str = "dense"
    row_bucket: int = 1024
    col_bucket: int = 256
    mesh: Any = None
    shards: int | None = None
    row_axis: str = "state_row"

    def __post_init__(self):
        if self.kind not in LAYOUT_KINDS:
            raise ValueError(
                f"unknown state layout {self.kind!r}; choose from "
                f"{LAYOUT_KINDS}")
        if self.kind == "row_sharded" and self.row_shards < 1:
            raise ValueError("row_sharded layout needs shards >= 1")

    # -- construction helpers ---------------------------------------------
    @classmethod
    def resolve(cls, layout) -> "StateLayout":
        """None -> dense; str -> default layout of that kind; pass-through."""
        if layout is None:
            return DENSE
        if isinstance(layout, str):
            if layout == "row_sharded":
                raise ValueError(
                    "row_sharded needs a mesh (or explicit shards); "
                    "construct StateLayout(kind='row_sharded', mesh=...)")
            return cls(kind=layout)
        if not isinstance(layout, StateLayout):
            raise TypeError(f"not a StateLayout: {layout!r}")
        return layout

    # -- geometry ----------------------------------------------------------
    @property
    def row_shards(self) -> int:
        """Number of row shards (1 for dense/bucketed)."""
        if self.kind != "row_sharded":
            return 1
        if self.shards is not None:
            return int(self.shards)
        if self.mesh is not None and self.row_axis in self.mesh.axis_names:
            return int(self.mesh.shape[self.row_axis])
        return 1

    def state_rows(self, n: int) -> int:
        """Device state rows INCLUDING the dump row (always the last)."""
        if self.kind == "dense":
            return n + 1
        if self.kind == "bucketed":
            return _round_up(max(n, 1), self.row_bucket) + 1
        return _round_up(n + 1, self.row_shards)

    def state_cols(self, m: int) -> int:
        if self.kind == "bucketed":
            return _round_up(max(m, 1), self.col_bucket)
        return m

    def state_dims(self, n: int, m: int) -> tuple[int, int]:
        """(rows, cols) of the device expiry matrix for an (n, m) catalog."""
        return self.state_rows(n), self.state_cols(m)

    def dump_row(self, n: int) -> int:
        """Index of the masked-scatter dump row (always rows - 1)."""
        return self.state_rows(n) - 1

    def is_dense_for(self, n: int, m: int) -> bool:
        """True iff this layout reproduces the dense geometry bitwise at
        (n, m) — the eligibility condition for paths whose scan derives
        its dump row from ``n`` rather than the carry."""
        return self.row_shards == 1 and self.state_dims(n, m) == (n + 1, m)

    def supports_device_cgm(self, n: int, m: int) -> bool:
        """True iff the device-resident CGM may back an (n, m) catalog.

        The CGM carry is built DENSE-n regardless of this layout (its
        hot-space embeds and install reductions size themselves from the
        carry, not from the schedule geometry), so any single-shard
        layout qualifies — including ``bucketed``, whose padded generic
        schedules never reach the CGM path.  Row-sharded state does not:
        the in-scan segment reductions need the whole slot map on one
        device."""
        del n, m
        return self.row_shards == 1

    def state_bytes(self, n: int, m: int) -> int:
        """Device bytes of one scenario's state (f64 E + i32 anchor)."""
        rows, cols = self.state_dims(n, m)
        return rows * cols * 8 + rows * 4

    def state_bytes_per_device(self, n: int, m: int) -> int:
        """Per-device state bytes (row-sharded splits rows evenly)."""
        return self.state_bytes(n, m) // self.row_shards

    # -- placement ---------------------------------------------------------
    def place_state(self, E0, anchor0):
        """Commit (E0, anchor0) to the row-sharded mesh placement.

        ``E0``/``anchor0`` may carry a leading scenario axis; the row
        axis is always the second-to-last of E0.  A no-op (returns the
        inputs) unless this layout actually spans > 1 device.
        """
        if self.kind != "row_sharded" or self.mesh is None \
                or self.row_axis not in self.mesh.axis_names \
                or int(self.mesh.shape[self.row_axis]) <= 1:
            return E0, anchor0
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        lead = (None,) * (np.ndim(E0) - 2)
        shE = NamedSharding(self.mesh, P(*lead, self.row_axis, None))
        shA = NamedSharding(self.mesh, P(*lead, self.row_axis))
        return jax.device_put(E0, shE), jax.device_put(anchor0, shA)

    # -- snapshot wire format ---------------------------------------------
    @property
    def tag(self) -> str:
        return self.kind

    def check_restore(self, snap_tag: str, snap_shards: int) -> None:
        """Restore-compatibility rule (ISSUE 8): dense <-> bucketed are
        freely interchangeable (host state is dense either way); a
        row-sharded snapshot restored into a row-sharded session must
        match the mesh's shard count."""
        if snap_tag == "row_sharded" and self.kind == "row_sharded" \
                and int(snap_shards) != self.row_shards:
            raise ValueError(
                f"snapshot state layout is row_sharded over {snap_shards} "
                f"shard(s), session mesh has {self.row_shards}; restore "
                "on a matching mesh (or a dense/bucketed session)")


#: the bitwise-default layout every ``layout=None`` resolves to
DENSE = StateLayout()
