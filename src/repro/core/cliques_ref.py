"""Legacy scalar Clique Generation Module — the parity oracle.

This is the pre-vectorization (PR 3) implementation of Alg. 3/4, kept
verbatim as the ground truth for ``repro.core.cliques``: the rewritten
array-native CGM must return partitions element-for-element identical to
this code on every input (tests/test_cliques_parity.py sweeps an
(omega x gamma x theta) grid over synthetic traces).

Mirrors the ``kernels/ref.py`` convention: the slow, obviously-correct
oracle lives next to the fast path it validates.  Do not optimise this
module — its value is that it never changes.

Known (intentional) limitations, fixed only in the fast path:

* ``split_oversized`` recurses once per split, so groups a few thousand
  members over omega raise ``RecursionError``;
* ``approximate_merge`` re-runs the full two-matmul ``merge_scores`` scan
  after every single merge (O(k^3 h) per window).
"""
from __future__ import annotations

import numpy as np

from .cliques import CliquePartition
from .crm import WindowCRM, edge_diff

Edge = tuple[int, int]


class _CrmView:
    """Frozen copy of the legacy global-id view over a WindowCRM.

    Deliberately NOT shared with ``cliques._CrmView`` — the fast module's
    view methods evolve with the fast path, and an oracle that imports
    them would mask a regression on both sides of the parity assertion.
    """

    def __init__(self, crm: WindowCRM, n: int):
        self._lut = np.full(n, -1, dtype=np.int32)
        self._lut[crm.hot_items] = np.arange(crm.n_hot, dtype=np.int32)
        self._norm = crm.norm
        self._bin = crm.binary

    def weight(self, u: int, v: int) -> float:
        a, b = self._lut[u], self._lut[v]
        if a < 0 or b < 0:
            return 0.0
        return float(self._norm[a, b])

    def connected(self, u: int, v: int) -> bool:
        a, b = self._lut[u], self._lut[v]
        if a < 0 or b < 0:
            return False
        return bool(self._bin[a, b])

    def edges_within(self, group: tuple[int, ...]) -> int:
        idx = self._lut[list(group)]
        idx = idx[idx >= 0]
        if idx.size < 2:
            return 0
        sub = self._bin[np.ix_(idx, idx)]
        return int(np.triu(sub, k=1).sum())

    def fully_connected(self, group: tuple[int, ...]) -> bool:
        g = len(group)
        return self.edges_within(group) == g * (g - 1) // 2


# ---------------------------------------------------------------------------
# Alg. 4 — adjust previous cliques from the edge diff
# ---------------------------------------------------------------------------
def split_clique_on_edge(
    clique: tuple[int, ...], u: int, v: int, view: _CrmView
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Split ``clique`` into two groups seeded at the removed edge (u, v)."""
    left = [u]
    right = [v]
    for d in clique:
        if d == u or d == v:
            continue
        wl = sum(view.weight(d, x) for x in left)
        wr = sum(view.weight(d, x) for x in right)
        (left if wl >= wr else right).append(d)
    return tuple(sorted(left)), tuple(sorted(right))


def adjust_previous_cliques(
    prev: CliquePartition,
    added: set[Edge],
    removed: set[Edge],
    view: _CrmView,
    omega: int,
) -> list[tuple[int, ...]]:
    """Alg. 4: reuse the previous partition, patching it edge by edge."""
    groups: list[set[int]] = [set(c) for c in prev.cliques]
    of = prev.clique_of.copy()

    def _replace(idx: int, parts: list[set[int]]) -> None:
        groups[idx] = parts[0]
        for d in parts[0]:
            of[d] = idx
        for p in parts[1:]:
            j = len(groups)
            groups.append(p)
            for d in p:
                of[d] = j

    for (u, v) in sorted(removed):
        cu = int(of[u])
        if cu == int(of[v]) and len(groups[cu]) > 1:
            a, b = split_clique_on_edge(tuple(sorted(groups[cu])), u, v, view)
            _replace(cu, [set(a), set(b)])

    for (u, v) in sorted(added):
        cu, cv = int(of[u]), int(of[v])
        if cu == cv:
            continue
        union = groups[cu] | groups[cv]
        if len(union) <= omega and view.fully_connected(tuple(sorted(union))):
            keep, drop = (cu, cv) if cu < cv else (cv, cu)
            groups[keep] = union
            groups[drop] = set()
            for d in union:
                of[d] = keep

    return [tuple(sorted(g)) for g in groups if g]


# ---------------------------------------------------------------------------
# Alg. 3 lines 2-3 — recursive weakest-edge splitting
# ---------------------------------------------------------------------------
def split_oversized(
    group: tuple[int, ...], omega: int, view: _CrmView
) -> list[tuple[int, ...]]:
    """Recursively split ``group`` until every part has size <= omega."""
    if len(group) <= omega:
        return [group]
    best: tuple[float, int, int] | None = None
    for i in range(len(group)):
        for j in range(i + 1, len(group)):
            w = view.weight(group[i], group[j])
            if best is None or w < best[0]:
                best = (w, group[i], group[j])
    assert best is not None
    _, u, v = best
    a, b = split_clique_on_edge(group, u, v, view)
    return split_oversized(a, omega, view) + split_oversized(b, omega, view)


# ---------------------------------------------------------------------------
# Alg. 3 lines 4-10 — approximate merging via full rescans
# ---------------------------------------------------------------------------
def hot_membership(
    groups: list[tuple[int, ...]], view: _CrmView
) -> np.ndarray:
    """(k, h) 0/1 membership matrix restricted to the hot index space."""
    h = view._norm.shape[0]
    M = np.zeros((len(groups), h), dtype=np.float32)
    for i, g in enumerate(groups):
        idx = view._lut[list(g)]
        idx = idx[idx >= 0]
        M[i, idx] = 1.0
    return M


def merge_scores(
    groups: list[tuple[int, ...]],
    view: _CrmView,
    omega: int,
    pair_edges=None,
) -> np.ndarray:
    """Density of every pairwise union with |U| == omega; -1 elsewhere."""
    k = len(groups)
    M = hot_membership(groups, view)
    A = view._bin.astype(np.float32)
    if pair_edges is None:
        X = M @ A @ M.T
    else:
        X = np.asarray(pair_edges(M, A))
    within = np.diag(X) / 2.0
    e_u = within[:, None] + within[None, :] + X
    sizes = np.array([len(g) for g in groups], dtype=np.int64)
    ok = (sizes[:, None] + sizes[None, :]) == omega
    np.fill_diagonal(ok, False)
    e_max = omega * (omega - 1) / 2.0
    dens = np.where(ok, e_u / e_max, -1.0).astype(np.float32)
    assert dens.shape == (k, k)
    return dens


def _mergeable_split(
    groups: list[tuple[int, ...]], view: _CrmView, omega: int, gamma: float
) -> tuple[list[tuple[int, ...]], list[tuple[int, ...]]]:
    """Split groups into (merge candidates, pass-through).

    A group with no hot member has zero CRM edges; for
    gamma > (omega-2)/omega it can never reach the density bar and is
    excluded from the O(k^2) scan (exact pruning).
    """
    if omega <= 2 or gamma <= (omega - 2) / omega:
        return list(groups), []
    cand, rest = [], []
    for g in groups:
        if any(view._lut[d] >= 0 for d in g):
            cand.append(g)
        else:
            rest.append(g)
    return cand, rest


def approximate_merge(
    groups: list[tuple[int, ...]],
    view: _CrmView,
    omega: int,
    gamma: float,
    pair_edges=None,
) -> list[tuple[int, ...]]:
    """Greedy best-density-first merging, one full rescan per merge."""
    cand, rest = _mergeable_split(list(groups), view, omega, gamma)
    while len(cand) >= 2:
        dens = merge_scores(cand, view, omega, pair_edges=pair_edges)
        dens = np.where(dens >= gamma, dens, -1.0)
        if dens.max() < 0:
            break
        i, j = np.unravel_index(int(np.argmax(dens)), dens.shape)
        if i > j:
            i, j = j, i
        merged = tuple(sorted(cand[i] + cand[j]))
        cand = [g for t, g in enumerate(cand) if t not in (i, j)]
        cand.append(merged)
    return cand + rest


# ---------------------------------------------------------------------------
# full Alg. 3 pipeline
# ---------------------------------------------------------------------------
def generate_cliques(
    prev: CliquePartition | None,
    prev_crm: WindowCRM | None,
    crm: WindowCRM,
    n: int,
    omega: int,
    gamma: float,
    pair_edges=None,
    enable_split: bool = True,
    enable_approx_merge: bool = True,
) -> CliquePartition:
    """One clique-generation event: adjust -> split -> approximate-merge."""
    view = _CrmView(crm, n)
    if prev is None:
        prev = CliquePartition.singletons(n)
    added, removed = edge_diff(prev_crm, crm)
    groups = adjust_previous_cliques(prev, added, removed, view, omega)
    if enable_split:
        out: list[tuple[int, ...]] = []
        for g in groups:
            out.extend(split_oversized(g, omega, view))
    else:
        out = list(groups)
    if enable_approx_merge:
        out = approximate_merge(out, view, omega, gamma, pair_edges=pair_edges)
    return CliquePartition.from_cliques(n, out)
