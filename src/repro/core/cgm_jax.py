"""Device-resident clique generation: the CGM inside the jit'd scan.

PR 5 moved the replay *state* recurrence on device but left the Clique
Generation Module (Alg. 2-4) on host; PR 6 re-cut that seam (DESIGN.md
§11) so the host ships only RAW request tensors and the scan carry
grows the full CGM state.  This revision re-expresses every boundary
tensor in a COMPACT HOT SPACE (DESIGN.md §15): the paper's CGM only
ever reasons over the window hot set — a ``top_frac`` slice of the
window's distinct items (§V.A) — so the carry holds an ``(h, h)`` CRM
workspace plus an ``(h,)`` hot->catalog index map, with ``h`` the
padded hot-set capacity derived from ``top_frac`` and the window size
(typically ≪ n).  Requests are buffered per window (``wbuf``) and the
CRM is built ONCE per boundary as a rank-``wcap`` update over the hot
incidence — there is no per-step (n, n) matmul and no (n, n) carry at
all.  At each boundary step a ``lax.cond`` branch runs, entirely on
device:

* Alg. 2 — hot set (stable rank of window counts), the ``(h, h)`` CRM
  via ``H^T H`` over the buffered window (``kernels/crm_update.py`` on
  TPU, a fused jnp contraction elsewhere), min-max normalise, binarise
  at theta;
* Alg. 4 — the edge diff vs the previous window's binary CRM via
  cross-space index luts (each side stays ``(h, h)``), then the
  removed-edge splits / added-edge merges as bounded ``fori_loop``s
  over the global slot map with ``(h,)`` side-weight accumulators;
* Alg. 3 — oversized-clique splits as a LIFO worklist over
  fixed-capacity MEMBER LISTS (``gcap`` ≤ a few × omega, not n), and
  the approximate merge as a ``lax.while_loop`` over the thresholded
  density matrix in an ``(S_h, S_h)`` act-compacted slot space using
  the incremental ``X = M A M^T`` patch algebra of PR 3
  (``kernels/merge_step.py`` builds the initial D on TPU);
* the partition install (``install_partition``) as segment reductions
  over the old slot map — matching, member-wise expiry min, Alg.-1
  window seeding.

Because events are CONSTRUCTED in-scan (dedup, sort orders, lags — the
``batch_events`` pipeline as jnp sorts/segment-sums), the schedule is
partition-free: theta / gamma / omega / top_frac are runtime scalars
(``cgm_spec``) and a fig7 hyperparameter grid vmaps over them sharing
ONE schedule and ONE host->device transfer per trace (``h`` is sized
by the MAX hot dimension over the vmapped lanes).

Parity bar: the host path (``core/cliques.py`` + the ``cliques_ref``
oracle) stays frozen; device partitions are element-for-element equal
across chained windows and costs match the numpy engine at 1e-9.  The
proof obligations (op-for-op float semantics, stable-sort
tie-breaking, compact-space vs list-order equivalence) are documented
inline at each step.  The f32 CRM / X counters are exact integers
below 2**24 — ``_window_crm_device`` raises if the window capacity
could overflow that bound, and the eligibility gate
(``wants_device_cgm``) sizes ``h`` before routing.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from .cliques import CliquePartition
from .crm import WindowCRM
from .engine import CacheState
from .engine_jax import (
    HAS_JAX,
    N_ACC,
    NE_TARGET,
    _bucket,
    _rate_hook,
    _require_jax,
    _transfer_hook,
)

if HAS_JAX:  # pragma: no branch
    import functools

    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
else:  # pragma: no cover - jax-less containers never import the scan path
    jax = None
    import functools

#: device CGM is gated on the PADDED HOT CAPACITY h, not the catalog
#: size — the (h, h) workspace and (2h, 2h) merge matrices stay cheap
#: and the f32 edge counters stay exact for any h below this bound
MAX_DEVICE_CGM_HOT = 2048
#: f32 exactness bound for the CRM / X integer counters
_F32_EXACT = 1 << 24


def hot_capacity(n: int, max_slots: int, hot_dims) -> int:
    """Padded hot-set capacity for a window of ``max_slots`` item slots.

    ``hot_dims`` is a list of ``(top_frac, of_catalog)`` pairs — one per
    vmapped scenario lane; the capacity is the max over lanes.  The hot
    set requires a positive window count, so it can never exceed the
    window's distinct support (≤ ``max_slots``) even when ``top_frac``
    is taken of the catalog; the bucket keeps recompiles rare.
    """
    need = 1
    for frac, of_catalog in hot_dims:
        base = n if of_catalog else min(n, int(max_slots))
        need = max(need, min(n, int(max_slots),
                             max(1, int(round(base * float(frac))))))
    return min(n, _bucket(need, 32, 32))


def _max_window_requests(trace, t_cg: float) -> int:
    """Upper bound on request rows in any one T_CG window.

    Every window's requests lie inside a half-open span of length
    ``t_cg`` starting at a request time (boundaries fire at request
    times and the grid advances by ``t_cg``), so the sliding-window
    count over request-aligned starts dominates all real windows —
    including the open tail window.
    """
    times = np.asarray(trace.times, np.float64)
    if times.size == 0:
        return 0
    ends = np.searchsorted(times, times + float(t_cg), side="left")
    return int((ends - np.arange(times.size)).max())


# ---------------------------------------------------------------------------
# the partition-free schedule: raw request tensors + boundary flags
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CGMSchedule:
    """Raw request batches of one trace, cut on the T_CG grid.

    Unlike :class:`~repro.core.engine_jax.ReplaySchedule` there are no
    event tensors and no install records — events and partitions are
    derived ON DEVICE.  ``xs`` leading axis is nb (scan steps); a step
    never straddles a T_CG boundary, and a step whose window begins a
    new T_CG period carries ``cg=True`` + the boundary evaluation time.
    ``h`` / ``wcap`` size the compact boundary workspace: padded hot
    capacity and the window request-row buffer (``win_rows`` /
    ``win_slots`` record the raw per-window maxima they derive from).
    """

    n: int
    m: int
    nb: int
    B: int                      # requests per step (padded)
    d: int                      # item slots per request
    const_dt: bool              # device CGM requires uniform dt
    uses_sizes: bool
    xs: dict
    n_requests: int
    n_item_requests: int
    boundary_steps: np.ndarray  # (n_boundaries,) scan-step indices
    win_start: int              # open-window start index into the trace
    boundary_hit: bool
    next_cg: float | None
    h: int                      # padded hot-set capacity
    wcap: int                   # window request-row buffer capacity
    win_rows: int               # max request rows in any one window
    win_slots: int              # max item slots in any one window (≤ n)


def build_cgm_schedule(
    trace,
    t_cg: float,
    *,
    uses_sizes: bool,
    batch_size: int | None = None,
    next_cg0: float | None = None,
    hot_dims=None,
    prefix_rows: int = 0,
    prefix_slots: int = 0,
) -> CGMSchedule:
    """Cut the trace into boundary-aligned request batches.

    The walk is the same T_CG grid as ``build_schedule`` (and the numpy
    ``ReplayEngine.replay``): a boundary fires when the next request
    lies at/after ``next_cg``, is evaluated at that request's time, and
    empty periods are skipped with a single firing.  No clique
    generation happens here — the boundary merely flags the step.

    ``hot_dims`` is the ``(top_frac, of_catalog)`` list over the lanes
    that will share this schedule (default: a full-support lane, the
    conservative ``h`` = window support); ``prefix_rows`` /
    ``prefix_slots`` account a session's already-open window so the
    head window's buffer capacity covers it.
    """
    times, servers, items = trace.times, trace.servers, trace.items
    R = int(times.shape[0])
    d = int(items.shape[1]) if items.ndim == 2 else 1
    if batch_size is not None:
        bs = max(1, int(batch_size))
    else:
        bs = max(1, NE_TARGET // max(1, d))
    if R > 0:
        next_cg = (float(next_cg0) if next_cg0 is not None
                   else float(times[0]) + t_cg)
    else:
        next_cg = next_cg0 if next_cg0 is not None else np.inf

    slices: list[tuple[int, int, float | None]] = []
    pending_cg: float | None = None
    win_start = 0
    boundary_hit = False
    pos = 0
    while pos < R:
        cut = int(np.searchsorted(times, next_cg, side="left"))
        if cut <= pos:
            t = float(times[pos])
            pending_cg = t
            win_start = pos
            boundary_hit = True
            while next_cg <= t:
                next_cg += t_cg
            continue
        stop = min(pos + bs, cut)
        slices.append((pos, stop, pending_cg))
        pending_cg = None
        pos = stop

    nb_raw = max(1, len(slices))
    nb = _bucket(nb_raw, 4, 4)
    B = _bucket(max((s - p for p, s, _ in slices), default=1), 32, 32)

    # per-window row/slot accounting: a boundary slice CLOSES the window
    # accumulated so far (head window includes the session prefix; the
    # tail window stays open but still occupies the buffer)
    cur_rows, cur_slots = int(prefix_rows), int(prefix_slots)
    max_rows, max_slots = cur_rows, cur_slots
    for p, s, cg_now in slices:
        if cg_now is not None:
            cur_rows, cur_slots = 0, 0
        cur_rows += s - p
        cur_slots += (s - p) * d
        max_rows = max(max_rows, cur_rows)
        max_slots = max(max_slots, cur_slots)
    win_slots = min(trace.n, max_slots)
    # +B headroom: a step writes its whole padded block at offset wlen
    # before the validity mask trims it, so the buffer must absorb one
    # full batch past the worst window
    wcap = _bucket(max_rows + B, 64, 64)
    if hot_dims is None:
        hot_dims = [(1.0, False)]
    h = hot_capacity(trace.n, win_slots, hot_dims)

    t_pad = float(times[-1]) if R else 0.0
    xs = {
        "items": np.full((nb, B, d), -1, np.int32),
        "servers": np.zeros((nb, B), np.int32),
        "times": np.full((nb, B), t_pad, np.float64),
        "cg": np.zeros(nb, bool),
        "now": np.zeros(nb, np.float64),
        "nreq": np.zeros(nb, np.int32),
    }
    boundary_steps = []
    for b, (p, s, cg_now) in enumerate(slices):
        w = s - p
        xs["items"][b, :w] = items[p:s]
        xs["servers"][b, :w] = servers[p:s]
        xs["times"][b, :w] = times[p:s]
        xs["times"][b, w:] = times[s - 1]
        xs["nreq"][b] = w
        if cg_now is not None:
            xs["cg"][b] = True
            xs["now"][b] = cg_now
            boundary_steps.append(b)

    return CGMSchedule(
        n=trace.n, m=trace.m, nb=nb, B=B, d=d, const_dt=True,
        uses_sizes=uses_sizes, xs=xs,
        n_requests=R, n_item_requests=int((items >= 0).sum()),
        boundary_steps=np.asarray(boundary_steps, np.int32),
        win_start=win_start, boundary_hit=boundary_hit,
        next_cg=None if R == 0 else float(next_cg),
        h=h, wcap=wcap, win_rows=max_rows, win_slots=win_slots,
    )


def pad_cgm_schedule(schedule: CGMSchedule, dims: dict) -> CGMSchedule:
    """Pad a CGM schedule's xs + capacities up to shared ``dims``.

    The device-CGM analogue of ``engine_jax.pad_schedule`` — cohort
    alignment (sweep) and the live ratchet reuse ONE compiled scan
    across schedules by padding to the running max dims ``{"nb", "B",
    "d", "h", "W"}``.  Growing B also grows the per-step block write,
    so ``wcap`` is re-derived to keep ``win_rows + B <= wcap``.
    """
    s = schedule
    nb = max(dims.get("nb", s.nb), s.nb)
    B = max(dims.get("B", s.B), s.B)
    d = max(dims.get("d", s.d), s.d)
    h = max(dims.get("h", s.h), s.h)
    wcap = max(dims.get("W", s.wcap), s.wcap,
               _bucket(s.win_rows + B, 64, 64))
    if (nb, B, d) == (s.nb, s.B, s.d) and (h, wcap) == (s.h, s.wcap):
        return s
    xs0 = s.xs
    if (nb, B, d) != (s.nb, s.B, s.d):
        t_pad = float(xs0["times"][-1, -1]) if s.nb else 0.0
        items = np.full((nb, B, d), -1, np.int32)
        items[: s.nb, : s.B, : s.d] = xs0["items"]
        servers = np.zeros((nb, B), np.int32)
        servers[: s.nb, : s.B] = xs0["servers"]
        times = np.full((nb, B), t_pad, np.float64)
        times[: s.nb, : s.B] = xs0["times"]
        # padded request slots reuse the step's last real time so the
        # in-scan dedup keys stay inert
        times[: s.nb, s.B:] = xs0["times"][:, -1:]
        cg = np.zeros(nb, bool)
        cg[: s.nb] = xs0["cg"]
        now = np.zeros(nb, np.float64)
        now[: s.nb] = xs0["now"]
        nreq = np.zeros(nb, np.int32)
        nreq[: s.nb] = xs0["nreq"]
        xs = dict(items=items, servers=servers, times=times, cg=cg,
                  now=now, nreq=nreq)
    else:
        xs = xs0
    return dataclasses.replace(s, nb=nb, B=B, d=d, xs=xs, h=h, wcap=wcap)


def cgm_spec(cfg, params, n: int) -> dict:
    """The CGM hyperparameters as runtime (vmappable) scalars.

    theta / gamma enter f32 comparisons on the host path (NEP-50 weak
    scalars against f32 CRM/density matrices), so both are shipped in
    the dtype each comparison actually runs in.
    """
    omega = int(params.omega) if cfg.enable_split else int(n)
    return {
        "theta": np.float32(params.theta),
        "gamma32": np.float32(params.gamma),
        "gamma": np.float64(params.gamma),
        "omega": np.int32(omega),
        "omega_f": np.float64(omega),
        "top_frac": np.float64(cfg.top_frac),
        "of_catalog": np.bool_(cfg.top_frac_of == "catalog"),
    }


# ---------------------------------------------------------------------------
# device: window accumulation (Alg. 2 running state)
# ---------------------------------------------------------------------------
def _accumulate_window(carry, x, *, n, m):
    """Fold one request batch into the open window's buffers.

    * ``wbuf`` (wcap, dbuf) i32 — the window's raw request rows; the
      whole padded block lands at offset ``wlen`` and ``wlen`` advances
      by the step's VALID row count only, so pad rows are overwritten
      by the next step and anything at/after ``wlen`` is stale by
      construction.  The CRM is built from this buffer ONCE per
      boundary (no per-step (n, n) matmul).
    * ``wcnt`` (n+1,) i32 — per-item access counts WITH duplicates
      (the host hot-set bincount does not dedup within a request).
    * ``seed`` (n+1, m) i32 — (item, server) counts WITH duplicates
      (``window_seed_servers``'s ``np.add.at`` semantics).
    """
    items = x["items"]                              # (B, d) i32
    B, d = items.shape
    dbuf = carry["wbuf"].shape[1]
    if d < dbuf:
        items_b = jnp.pad(items, ((0, 0), (0, dbuf - d)),
                          constant_values=-1)
    else:
        items_b = items
    wbuf = jax.lax.dynamic_update_slice(
        carry["wbuf"], items_b, (carry["wlen"], jnp.int32(0)))
    wlen = carry["wlen"] + x["nreq"]
    valid = items >= 0
    col = jnp.where(valid, items, n)                # invalid -> dump col n
    wcnt = carry["wcnt"].at[col.reshape(-1)].add(1)[: n + 1]
    seed = carry["seed"].at[col, x["servers"][:, None]].add(
        valid.astype(jnp.int32))
    return dict(carry, wbuf=wbuf, wlen=wlen, wcnt=wcnt, seed=seed)


# ---------------------------------------------------------------------------
# device: compact-space primitives
# ---------------------------------------------------------------------------
def _compact_indices(mask, size):
    """Ascending indices of True entries, padded with ``len(mask)``.

    The cumsum/scatter form of ``jnp.nonzero(mask, size=size,
    fill_value=len(mask))`` — nonzero's static-size lowering sorts the
    whole mask (O(n log n) per call, ~260us at n=4096 on CPU), which
    dominates when called inside the per-edge adjust loops; this stays
    O(n).  Entries past ``size`` collapse onto the scatter dump slot.
    """
    n = mask.shape[0]
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    idx = jnp.where(mask & (pos < size), pos, size)
    return jnp.full(size + 1, n, jnp.int32).at[idx].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")[:size]


def _capped_true_indices(mask, cap, bs=128):
    """Flat indices of the first ``cap`` True entries (pads = len(mask)).

    Gather-based two-level stream compaction: per-block popcounts pick
    each target's block by vectorized binary search, then a (cap, bs)
    row gather ranks within the block — O(n + cap*bs) elementwise work
    with NO large scatter (XLA CPU scatter runs ~55ns/element, which
    makes ``_compact_indices`` over an (h, h) mask cost ~80ms at
    h~1200; this path is ~2ms).  Targets past the population count pad
    with ``len(mask)``.
    """
    n = mask.shape[0]
    nb = -(-n // bs)
    pad = nb * bs - n
    if pad:
        mask = jnp.concatenate([mask, jnp.zeros(pad, bool)])
    blk = mask.reshape(nb, bs).astype(jnp.int32)
    coff = jnp.cumsum(blk.sum(axis=1))               # (nb,) inclusive
    k = jnp.arange(1, cap + 1, dtype=jnp.int32)      # 1-based targets
    b = jnp.searchsorted(coff, k, side="left").astype(jnp.int32)
    bc = jnp.minimum(b, nb - 1)
    t = k - jnp.where(bc > 0, coff[jnp.maximum(bc - 1, 0)], 0)
    rcs = jnp.cumsum(blk[bc], axis=1)                # (cap, bs)
    pos = (rcs < t[:, None]).sum(axis=1).astype(jnp.int32)
    return jnp.where(b < nb, bc * bs + pos, n)


def _true_indices(mask, size, cap):
    """``_compact_indices(mask, size)`` with a fast common case.

    ``cap`` is a static bound on the EXPECTED population count: within
    it, the gather-based capped compaction fills the (size,) buffer; a
    rare overflow falls back (``lax.cond``, so only the taken branch
    runs) to the exact O(n)-scatter form.  Returns ``(indices, count)``.
    """
    n = mask.shape[0]
    cnt = mask.sum().astype(jnp.int32)
    if cap >= size:
        return _compact_indices(mask, size), cnt
    idx = jax.lax.cond(
        cnt > cap,
        lambda: _compact_indices(mask, size),
        lambda: jnp.full(size, n, jnp.int32).at[:cap].set(
            _capped_true_indices(mask, cap)))
    return idx, cnt


def _member_lists(of, n, gcap):
    """(n+1, gcap) member lists of every group: ascending ids, pads = n.

    One stable argsort + rank-in-run scatter builds ALL lists at once —
    the per-edge adjust loops then gather a (gcap,) row in O(gcap)
    instead of recomputing ``of == g`` compactions per edge (each of
    which pays an O(n) scatter, ~250us at n=4096 on CPU).  Groups wider
    than ``gcap`` cannot exist here (the ``_split_oversized`` invariant);
    their overflow updates drop defensively.  Row ``n`` stays all-pads —
    the dump row for predicated in-loop updates.
    """
    order = jnp.argsort(of).astype(jnp.int32)        # stable: ids ascend
    og = of[order]
    iota = jnp.arange(n, dtype=jnp.int32)
    newrun = jnp.concatenate([jnp.ones(1, bool), og[1:] != og[:-1]])
    start = jax.lax.cummax(jnp.where(newrun, iota, 0))
    return jnp.full((n + 1, gcap), n, jnp.int32).at[
        og, iota - start].set(order, mode="drop")


def _dense_rank(keys):
    """Dense rank (0..k-1) of each entry by ascending key value."""
    sk = jnp.sort(keys)
    first = jnp.concatenate([jnp.ones(1, bool), sk[1:] != sk[:-1]])
    rnk = (jnp.cumsum(first.astype(jnp.int32)) - 1).astype(jnp.int32)
    pos = jnp.searchsorted(sk, keys)
    return rnk[pos]


def _split_sides_compact(W, member, u, v, cap):
    """``split_clique_on_edge`` over a compact member mask: True = right.

    ``W`` is a (cap, cap) weight matrix in the compact space (hot slots
    or a member-list submatrix); ``u`` / ``v`` are compact indices and
    may be -1 for an endpoint that is COLD in the current window (zero
    weight column on the host's ``_CrmView``) — its side accumulator
    starts at zero and, for ``v``, the caller re-seeds the right side
    in global coordinates.  Bit-exact vs the host: the f64 side-weight
    accumulators update in ascending compact order (ascending item id
    in both spaces), the tie ``wl[p] >= wr[p]`` sends p left, and cold
    members (zero column, zero accumulated weight) tie left with zero
    contribution — the host's in-order no-op.
    """
    wl0 = jnp.where(u >= 0, W[:, jnp.maximum(u, 0)], 0.0)
    wr0 = jnp.where(v >= 0, W[:, jnp.maximum(v, 0)], 0.0)
    right0 = jnp.arange(cap, dtype=jnp.int32) == v

    def body(p, st):
        wl, wr, right = st
        act = member[p] & (p != u) & (p != v)
        go_left = wl[p] >= wr[p]
        right = right.at[p].set(jnp.where(act & ~go_left, True, right[p]))
        colp = W[:, p]
        wl = jnp.where(act & go_left, wl + colp, wl)
        wr = jnp.where(act & ~go_left, wr + colp, wr)
        return (wl, wr, right)

    _, _, right = jax.lax.fori_loop(0, cap, body, (wl0, wr0, right0))
    return right & member


def _window_crm_device(carry, cspec, *, n, h, wcap, use_kernels):
    """Alg. 2 at a boundary: hot set -> compact CRM -> binarise.

    Returns ``(hot_idx, valid_h, lut, raw, norm, binary)`` — the
    ascending hot->catalog index map (pads = n), its validity mask, the
    catalog->hot lut (cold/pad -> -1) and the (h, h) raw/norm/binary
    CRM.  Ascending ``hot_idx`` IS the host's compact hot-space order,
    so every comparison downstream sees the same values in the same
    scan order.  Raw counts are exact f32 integers: each pair count is
    bounded by the window row count ≤ wcap, guarded below.
    """
    if wcap >= _F32_EXACT:
        raise ValueError(
            f"device CGM window capacity wcap={wcap} reaches the f32 "
            f"exact-integer bound 2**24; co-occurrence counts could "
            "silently lose exactness — route this trace to the host CGM "
            "(or lower the clique-generation period t_cg)")
    counts = carry["wcnt"][:n]                       # (n,) i32
    support = (counts > 0).sum()
    base = jnp.where(cspec["of_catalog"], n, support).astype(jnp.float64)
    # host: max(1, int(round(base * top_frac))) — np.round is half-even,
    # same as Python's round
    n_hot = jnp.maximum(
        1, jnp.round(base * cspec["top_frac"])).astype(jnp.int32)
    order = jnp.argsort(-counts)                     # stable: ties -> low id
    rank = jnp.zeros(n, jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    hot = (rank < n_hot) & (counts > 0)
    # ascending hot ids = the host hot_items order (sorted); capacity h
    # dominates every real window by construction (hot_capacity)
    hot_idx = _compact_indices(hot, h)
    valid_h = hot_idx < n
    lut = jnp.full(n + 1, -1, jnp.int32).at[hot_idx].set(
        jnp.arange(h, dtype=jnp.int32)).at[n].set(-1)

    # compact CRM from the buffered window: one rank-wcap update
    wbuf = carry["wbuf"]                             # (wcap, dbuf) i32
    dbuf = wbuf.shape[1]
    rowi = jax.lax.broadcasted_iota(jnp.int32, (wcap, dbuf), 0)
    live = (rowi < carry["wlen"]) & (wbuf >= 0)
    hs = lut[jnp.where(live, wbuf, n)]               # hot slot or -1
    hcol = jnp.where(hs >= 0, hs, h)                 # cold/stale -> dump col
    if use_kernels:
        from ..kernels.crm_update import crm_update_auto

        H = jnp.zeros((wcap, h + 1), jnp.float32).at[rowi, hcol].set(1.0)
        raw = crm_update_auto(H[:, :h])              # (h, h) f32, zero diag
    elif h * h <= 1600 * dbuf * dbuf:
        # small hot space: the dense H^T H contraction beats per-pair
        # scatter updates (XLA CPU scatter runs ~55ns/element serial,
        # SIMD matmul ~0.03ns/flop — crossover near h ~ 40 dbuf).  The
        # equality broadcast dedups in-row repeats for free, and 0/1
        # dots over <= wcap rows stay exact f32 integers.
        Hf = (hcol[:, :, None] == jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, h), 2)).any(axis=1).astype(jnp.float32)
        raw = Hf.T @ Hf
        raw = raw * (1.0 - jnp.eye(h, dtype=jnp.float32))
    else:
        # pair-scatter form of the H^T H contraction: each request row
        # holds <= dbuf items, so scattering its dbuf^2 hot pairs costs
        # O(wcap d^2) instead of the O(wcap h^2) matmul — the big-h
        # CPU/GPU fallback; the Mosaic kernel above keeps the
        # MXU-shaped matmul.  In-row duplicates collapse to the dump
        # column first (the H one-hot .set dedup), so counts stay the
        # exact 0/1 contraction.
        sc = jnp.sort(hcol, axis=1)
        dup = jnp.concatenate(
            [jnp.zeros((wcap, 1), bool), sc[:, 1:] == sc[:, :-1]], axis=1)
        sc = jnp.where(dup | (sc >= h), h, sc)
        raw = jnp.zeros((h + 1, h + 1), jnp.float32).at[
            sc[:, :, None], sc[:, None, :]].add(1.0)[:h, :h]
        raw = raw * (1.0 - jnp.eye(h, dtype=jnp.float32))
    hi = raw.max().astype(jnp.float64)
    # host minmax_normalise: lo is always 0 (zero diagonal), hi<=0 -> 0;
    # int64/int64 true-divide (f64) then cast f32 == f32->f64 exact here
    norm = jnp.where(
        hi > 0.0,
        (raw.astype(jnp.float64) / hi).astype(jnp.float32),
        jnp.zeros((h, h), jnp.float32),
    )
    hm2 = valid_h[:, None] & valid_h[None, :]
    binary = (norm > cspec["theta"]) & hm2 & ~jnp.eye(h, dtype=bool)
    return hot_idx, valid_h, lut, raw, norm, binary


# ---------------------------------------------------------------------------
# device: Alg. 4 adjust + Alg. 3 split/merge in the compact hot space
# ---------------------------------------------------------------------------
def _adjust_partition(of, gsize, binary, W, hot_idx, valid_h, lut,
                      addM, remM, rem_map, cspec, *, n, h, gcap):
    """Alg. 4 (``adjust_previous_cliques``) over slot buffers.

    Slot numbering mirrors the host list exactly: removed-edge splits
    keep the left side in the parent slot and append the right side at
    ``ngroups`` (the host's ``groups.append``); added-edge merges keep
    ``min(cu, cv)`` and kill ``max`` (the host's keep/drop).  Both loop
    bodies stay O(n + h) per edge: splits run on the group's
    fixed-capacity MEMBER LIST (``gcap`` bounds any group size here —
    the ``_split_oversized`` invariant), and the merge probe reads a
    clique-pair edge-count matrix built once after the removals and
    folded row/col per accepted merge instead of re-reducing the (h, h)
    CRM per edge.  Cold members have zero weight columns and tie left
    (the host no-op); edge endpoints always sit in the member list, so
    the right side seeds from ``v`` even when ``v`` went cold.  The
    final compaction ranks alive slots ascending — the host's ``[g for
    g in groups if g]`` order.
    """
    ngroups = (gsize > 0).sum().astype(jnp.int32)
    ml = _member_lists(of, n, gcap)
    pads_g = jnp.full(gcap, n, jnp.int32)
    ecap = max(1, h * (h - 1) // 2)
    dcap = min(ecap, _bucket(2 * h, 256, 256))

    # EXACT no-op prefilter: during the rem phase groups only SPLIT, so
    # an edge whose endpoints sit in different groups now can never be
    # same-group when its turn comes — drop it before the sequential
    # loop.  The host walks those edges too, as no-ops; the survivor
    # subset keeps its lexicographic order, so state updates agree
    # edge for edge.  Flat row-major compaction == nonzero's edge
    # order; pathological diff churn falls back to the exact scatter
    # compaction inside _true_indices.
    og_p = of[jnp.clip(rem_map, 0, n - 1)]           # group per prev slot
    remM = remM & (og_p[:, None] == og_p[None, :])
    rem_f, n_rem = _true_indices(remM.reshape(-1), ecap, dcap)

    def rem_body(i, st):
        of, gsize, ngroups, ml = st
        fi = rem_f[i]                                # flat (h, h) prev-edge
        u = rem_map[fi // h]
        v = rem_map[fi % h]
        cu = of[u]
        do = (cu == of[v]) & (gsize[cu] > 1)
        mem = ml[cu]                                 # (gcap,) ascending ids
        gvalid = mem < n
        gh = lut[mem]                                # hot slot or -1
        ghc = jnp.maximum(gh, 0)
        okw = (gh >= 0)[:, None] & (gh >= 0)[None, :]
        Wsub = jnp.where(okw, W[ghc][:, ghc], 0.0)
        pu = jnp.argmax(mem == u).astype(jnp.int32)
        pv = jnp.argmax(mem == v).astype(jnp.int32)
        right_g = _split_sides_compact(Wsub, gvalid, pu, pv, gcap) & do
        nr = right_g.sum().astype(jnp.int32)
        of = of.at[jnp.where(right_g, mem, n)].set(ngroups, mode="drop")
        g2 = gsize.at[cu].add(-nr).at[ngroups].set(nr)
        gsize = jnp.where(do, g2, gsize)
        lit = jnp.sort(jnp.where(gvalid & ~right_g, mem, n))
        rit = jnp.sort(jnp.where(right_g, mem, n))
        ml = ml.at[jnp.where(do, cu, n)].set(lit)
        ml = ml.at[jnp.where(do, ngroups, n)].set(rit)
        ngroups = ngroups + do.astype(jnp.int32)
        return (of, gsize, ngroups, ml)

    of, gsize, ngroups, ml = jax.lax.fori_loop(
        0, n_rem, rem_body, (of, gsize, ngroups, ml))

    # mirror prefilter for adds: the add phase only MERGES, so an edge
    # whose endpoints already share a group AFTER the rem phase stays
    # same-group forever — a guaranteed no-op on the host walk too
    og_c = of[jnp.clip(hot_idx, 0, n - 1)]           # group per cur slot
    addM = addM & (og_c[:, None] != og_c[None, :])
    add_f, n_add = _true_indices(addM.reshape(-1), ecap, dcap)

    def add_body(i, st):
        of, gsize, ml = st
        fi = add_f[i]                                # flat (h, h) cur-edge
        u = hot_idx[fi // h]
        v = hot_idx[fi % h]
        cu = of[u]
        cv = of[v]
        g = gsize[cu] + gsize[cv]
        # fully_connected: the union's in-edge count must be C(g, 2),
        # probed over the union's MEMBER LISTS (<= 2 gcap slots) in the
        # (h, h) hot space; cold members contribute no edges (lut -> -1
        # rows mask out), so this also rejects unions with cold items —
        # exactly the host probe semantics
        mem = jnp.concatenate([ml[cu], ml[cv]])      # (2 gcap,)
        mh = lut[mem]                                # hot slot or -1
        mhc = jnp.maximum(mh, 0)
        okm = (mh >= 0)[:, None] & (mh >= 0)[None, :]
        ne = (binary[mhc][:, mhc] & okm).sum() // 2
        do = (cu != cv) & (g <= cspec["omega"]) & (ne == g * (g - 1) // 2)
        keep = jnp.minimum(cu, cv)
        drop = jnp.maximum(cu, cv)
        of = of.at[jnp.where(do, mem, n)].set(keep, mode="drop")
        g2 = gsize.at[keep].set(g).at[drop].set(0)
        gsize = jnp.where(do, g2, gsize)
        ml = ml.at[jnp.where(do, keep, n)].set(jnp.sort(mem)[:gcap])
        ml = ml.at[jnp.where(do, drop, n)].set(pads_g)
        return (of, gsize, ml)

    of, gsize, _ = jax.lax.fori_loop(0, n_add, add_body, (of, gsize, ml))

    alive = gsize > 0
    newid = (jnp.cumsum(alive.astype(jnp.int32)) - 1).astype(jnp.int32)
    of = newid[of]
    gsize = jnp.zeros(n + 1, jnp.int32).at[
        jnp.where(alive, newid, n)].add(gsize)[:n]
    return of, gsize


def _split_oversized(of, gsize, W, lut, cspec, *, n, h, gcap):
    """Alg. 3 splits (``split_oversized``) as a bounded LIFO worklist.

    Only oversized slots run the worklist; every other slot keeps its
    pass-through key.  The worklist carries fixed-capacity MEMBER LISTS
    (ascending item ids, pads = n) of width ``gcap`` — an invariant
    bound on any group size at this point (≤ max(initial partition,
    omega) by induction: adjust merges are omega-capped and splits only
    shrink).  Pieces keep the host's IN-PLACE order via the key
    ``slot * (gcap+1) + emit_idx``; the closed-form hot_count<=1 peel
    is subsumed by the generic weakest-edge split: with an all-zero
    weight submatrix the first-min edge is (g[0], g[1]) and every tie
    goes left, which peels exactly the host's ``(g[0],) + g[p+1:]``
    then ``g[p] .. g[1]`` singletons.
    """
    KW = gcap + 1
    triu_g = jnp.triu(jnp.ones((gcap, gcap), bool), k=1)
    over = gsize > cspec["omega"]
    os_idx = _compact_indices(over, n)
    n_os = over.sum()
    ml = _member_lists(of, n, gcap)
    of_key0 = jnp.concatenate(
        [of * KW, jnp.zeros(1, jnp.int32)])          # (n+1,): pass-through

    def slot_body(i, of_key):
        s = os_idx[i]
        mem0 = ml[s]
        stack0 = jnp.full((gcap + 1, gcap), n, jnp.int32).at[0].set(mem0)

        def cond(st):
            return st[0] > 0

        def wbody(st):
            sp, stack, ofk, emit = st
            g = stack[sp - 1]                        # (gcap,) ascending ids
            sp = sp - 1
            gvalid = g < n
            small = gvalid.sum() <= cspec["omega"]
            tgt = jnp.where(gvalid & small, g, n)
            ofk = ofk.at[tgt].set(s * KW + emit)
            emit = emit + small.astype(jnp.int32)
            # weakest edge: first row-major minimum over member pairs —
            # the member list ascends in item id, so this is the host's
            # submatrix argmin scan order; cold members weigh 0
            gh = lut[g]                              # hot slot or -1
            ghc = jnp.maximum(gh, 0)
            okw = (gh >= 0)[:, None] & (gh >= 0)[None, :]
            Wsub = jnp.where(okw, W[ghc][:, ghc], 0.0)
            pairm = gvalid[:, None] & gvalid[None, :] & triu_g
            P = jnp.where(pairm, Wsub, jnp.inf)
            f = jnp.argmin(P.reshape(-1)).astype(jnp.int32)
            u = f // gcap
            v = f % gcap
            right = _split_sides_compact(Wsub, gvalid, u, v, gcap)
            rit = jnp.sort(jnp.where(right, g, n))
            lit = jnp.sort(jnp.where(gvalid & ~right, g, n))
            stack = stack.at[sp].set(jnp.where(small, stack[sp], rit))
            stack = stack.at[sp + 1].set(
                jnp.where(small, stack[sp + 1], lit))
            sp = sp + jnp.where(small, 0, 2)
            return (sp, stack, ofk, emit)

        _, _, of_key, _ = jax.lax.while_loop(
            cond, wbody, (jnp.int32(1), stack0, of_key, jnp.int32(0)))
        return of_key

    of_key = jax.lax.fori_loop(0, n_os, slot_body, of_key0)
    # dense-rank the (slot, emit) keys -> pieces in host list order
    return _dense_rank(of_key[:n])


def _approx_merge(of, binary, hot_idx, valid_h, cspec, *, n, h,
                  use_kernels, full_merge):
    """Alg. 3 approximate merge (``approximate_merge``) as a while_loop.

    The merge works in an ACT-COMPACTED slot space of capacity ``scap``:
    act groups (the host's candidate set with a live hot member) take
    slots 0..n_act-1 in input order, merged groups take tail slots —
    ascending slot order stays the host's compact act-matrix order at
    every iteration, so the row-major first-argmax over D breaks ties
    identically.  Under the pruning regime (omega > 2 and gamma above
    the density bar) at most h groups can be act, so ``scap = 2h``;
    lanes that can fall outside it (the w/o-CS ablation) compile with
    ``full_merge`` -> ``scap = 2n``.  D uses the sentinel -2.0 for
    dead / non-act / diagonal entries; X is patched incrementally, one
    row/col per merge (the PR-3 algebra), with the f32 add order of
    the host (``(X[ai,ai] + X[aj,aj]) + 2.0 * X[ai,aj]``).
    """
    if h * (h - 1) // 2 >= _F32_EXACT:
        raise ValueError(
            f"device CGM hot capacity h={h} puts the pairwise edge "
            f"count h*(h-1)/2 at/above 2**24; the f32 X counters would "
            "lose exactness — route this trace to the host CGM")
    scap = 2 * n if full_merge else 2 * h
    slot = jnp.arange(scap, dtype=jnp.int32)
    hot_c = jnp.clip(hot_idx, 0, n - 1)
    hot_of = of[hot_c]                               # (h,) group per hot slot
    sizes_n = jnp.zeros(n + 1, jnp.int32).at[of].add(1)[:n]
    alive_n = sizes_n > 0
    # host _mergeable_split: the hot filter only engages above the
    # density bar (omega > 2 and gamma > (omega-2)/omega)
    prune = (cspec["omega"] > 2) & (
        cspec["gamma"] > (cspec["omega_f"] - 2.0) / cspec["omega_f"])
    has_hot = (jnp.zeros(n + 1, jnp.int32).at[
        jnp.where(valid_h, hot_of, n)].add(1)[:n]) > 0
    live_h = valid_h & binary.any(axis=1)
    has_live = (jnp.zeros(n + 1, jnp.int32).at[
        jnp.where(live_h, hot_of, n)].add(1)[:n]) > 0
    is_rest = alive_n & prune & ~has_hot
    act_n = alive_n & jnp.where(prune, has_live, True) & ~is_rest

    # act groups -> merge slots 0..n_act-1 (input order preserved)
    msl_n = (jnp.cumsum(act_n.astype(jnp.int32)) - 1).astype(jnp.int32)
    n_act0 = act_n.sum().astype(jnp.int32)
    slot_of_m = _compact_indices(act_n, scap)
    # non-act groups park at scap+slot: inert to the loop, recovered in
    # the final ranking
    of2 = jnp.where(act_n[of], msl_n[of], scap + of)
    sizes_pad = jnp.concatenate([sizes_n, jnp.zeros(1, jnp.int32)])
    sizes = sizes_pad[jnp.clip(slot_of_m, 0, n)]     # (scap,) pads -> 0
    alive = slot < n_act0
    act = alive

    # X = M A M^T over hot membership (f32 exact integer counts);
    # M maps merge slots x hot slots (cold members carry no edges)
    hs = jnp.where(valid_h & act_n[hot_of], msl_n[hot_of], scap)
    A = binary.astype(jnp.float32)
    if use_kernels:
        from ..kernels.clique_density import clique_pair_edges_auto

        M = jnp.zeros((scap + 1, h), jnp.float32).at[
            hs, jnp.arange(h, dtype=jnp.int32)].set(1.0)[:scap]
        X = clique_pair_edges_auto(M, A)
    else:
        # edge-scatter form of M A M^T: only binary's TRUE entries
        # scatter (O(h) edges in practice vs h^2 pair updates — XLA CPU
        # scatter is per-element serial, so the full-pair form costs
        # ~80ms at h~1200); dense windows take the exact full-pair
        # fallback.  Identical exact-integer f32 counts either way
        # (every true (k, l) lands on (hs[k], hs[l]); zeros add zero).
        eb_cap = min(h * h, _bucket(4 * h, 1024, 1024))
        ne2 = binary.sum().astype(jnp.int32)

        def x_sparse():
            ef = _capped_true_indices(binary.reshape(-1), eb_cap)
            ok = ef < h * h
            efc = jnp.minimum(ef, h * h - 1)
            sa = jnp.where(ok, hs[efc // h], scap)
            sb = jnp.where(ok, hs[efc % h], scap)
            return jnp.zeros((scap + 1, scap + 1), jnp.float32).at[
                sa, sb].add(jnp.where(ok, 1.0, 0.0))

        def x_dense():
            return jnp.zeros((scap + 1, scap + 1), jnp.float32).at[
                hs[:, None], hs[None, :]].add(A)

        X = jax.lax.cond(ne2 > eb_cap, x_dense, x_sparse)[:scap, :scap]
    e_max = (cspec["omega_f"] * (cspec["omega_f"] - 1.0) / 2.0).astype(
        jnp.float32)
    eyeS = jnp.eye(scap, dtype=bool)
    if use_kernels:
        from ..kernels.merge_step import merge_density_auto

        D = merge_density_auto(X, sizes, cspec["omega"], cspec["gamma32"])
    else:
        within = jnp.diag(X) / 2.0
        e_u = (within[:, None] + within[None, :]) + X
        okp = ((sizes[:, None] + sizes[None, :]) == cspec["omega"]) & ~eyeS
        dens = jnp.where(okp, e_u / e_max, -1.0)
        D = jnp.where(dens >= cspec["gamma32"], dens, -1.0)
    actp = act[:, None] & act[None, :] & ~eyeS
    D = jnp.where(actp, D, -2.0)

    tail0 = n_act0

    def cond(st):
        D = st[1]
        n_act = st[7]
        return (n_act >= 2) & (D.max() >= 0.0)

    def body(st):
        X, D, of2, sizes, act, alive, tail, n_act = st
        f = jnp.argmax(D.reshape(-1)).astype(jnp.int32)
        ai = f // scap
        aj = f % scap
        ai, aj = jnp.minimum(ai, aj), jnp.maximum(ai, aj)
        t = tail
        mm = (of2 == ai) | (of2 == aj)
        of2 = jnp.where(mm, t, of2)
        row = X[ai, :] + X[aj, :]
        dg = (X[ai, ai] + X[aj, aj]) + 2.0 * X[ai, aj]
        X = X.at[t, :].set(row).at[:, t].set(row).at[t, t].set(dg)
        gnew = sizes[ai] + sizes[aj]
        sizes = sizes.at[t].set(gnew)
        alive = alive.at[ai].set(False).at[aj].set(False).at[t].set(True)
        act = act.at[ai].set(False).at[aj].set(False).at[t].set(True)
        # the new group's density row, host op order:
        # (within[-1] + within[:-1]) + Xn[-1, :-1]
        wt = dg / 2.0
        wl = jnp.diag(X) / 2.0
        e_row = (wt + wl) + X[t, :]
        okr = (gnew + sizes) == cspec["omega"]
        dr = jnp.where(okr, e_row / e_max, -1.0)
        dr = jnp.where(dr >= cspec["gamma32"], dr, -1.0)
        validc = act & alive & (slot != t)
        dr = jnp.where(validc, dr, -2.0)
        D = D.at[ai, :].set(-2.0).at[:, ai].set(-2.0)
        D = D.at[aj, :].set(-2.0).at[:, aj].set(-2.0)
        D = D.at[t, :].set(dr).at[:, t].set(dr).at[t, t].set(-2.0)
        return (X, D, of2, sizes, act, alive, t + 1, n_act - 1)

    _, _, of2, _, _, alive, _, _ = jax.lax.while_loop(
        cond, body, (X, D, of2, sizes, act, alive, tail0, n_act0))

    # host output order: cand-universe groups first (act survivors and
    # untouched non-act cand in INPUT position, merged appended in
    # creation order), rest groups after, both ascending.  Keys over the
    # extended id space [0, scap+n): original merge slot -> its n-slot,
    # merged tail slot ms -> n+ms, parked non-act -> n-slot (cand) or
    # n+scap+slot (rest); distinct groups never collide.
    ms = jnp.arange(scap, dtype=jnp.int32)
    key_m = jnp.where(
        ms < n_act0, slot_of_m, (n + ms).astype(jnp.int32))
    key_p = jnp.where(
        is_rest, (n + scap) + jnp.arange(n, dtype=jnp.int32),
        jnp.arange(n, dtype=jnp.int32))
    keys = jnp.concatenate([key_m, key_p])           # (scap + n,)
    return _dense_rank(keys[of2])


def _install_partition_device(carry, of_new, now, dt, *, n, seed_new):
    """``install_partition`` as segment reductions over the slot maps.

    Matching (``match_partitions``): a new slot matches iff all its
    members came from ONE old slot of the same member count.  Changed
    slots take the member-wise expiry min (fresh iff still beyond
    ``now``), else Alg.-1 window seeding on the seed-count argmax
    server.  The whole (n+1)-row state is rebuilt, which also clears
    any scatter garbage accumulated on the dump row.
    """
    E_old = carry["E"]
    a_old = carry["anchor"]
    of_old = carry["of"]
    cnt_old = carry["cnt"]
    one = jnp.ones(n, jnp.float64)
    cnt_new = jnp.zeros(n + 1, jnp.float64).at[of_new].add(one)
    slot_valid = cnt_new > 0.0
    mn = jax.ops.segment_min(of_old, of_new, num_segments=n + 1)
    mx = jax.ops.segment_max(of_old, of_new, num_segments=n + 1)
    cand = jnp.clip(mn, 0, n)
    matched = slot_valid & (mn == mx) & (cnt_old[cand] == cnt_new)
    item_E = E_old[of_old]                           # (n, m)
    min_E = jax.ops.segment_min(item_E, of_new, num_segments=n + 1)
    fresh = jnp.where(slot_valid[:, None] & (min_E > now), min_E, 0.0)
    row_max = fresh.max(axis=1)
    anew = jnp.where(
        row_max > 0.0, jnp.argmax(fresh, axis=1).astype(jnp.int32), -1)
    if seed_new:
        ssum = jax.ops.segment_sum(
            carry["seed"][:n], of_new, num_segments=n + 1)
        js = jnp.argmax(ssum, axis=1).astype(jnp.int32)
        need = (slot_valid & ~matched & (row_max <= 0.0)
                & (cnt_new > 1.0))
        col = jax.lax.broadcasted_iota(jnp.int32, fresh.shape, 1)
        fresh = jnp.where(
            need[:, None] & (col == js[:, None]),
            now + dt[js][:, None], fresh)
        anew = jnp.where(need, js, anew)
    E_new = jnp.where(matched[:, None], E_old[cand], fresh)
    a_new = jnp.where(matched, a_old[cand], anew)
    return E_new, a_new, cnt_new


def _cgm_boundary(carry, now, cspec, dt, item_sizes, *, n, m, h, wcap,
                  uses_sizes, enable_split, enable_acm, seed_new,
                  use_kernels, gcap, full_merge):
    """One T_CG boundary, fully on device: Alg. 2 -> 4 -> 3 -> install.

    Mirrors ``AKPCPolicy.on_window`` + ``generate_cliques`` + the
    engine's ``install_partition``, then resets the window counters and
    rolls the compact binary CRM + hot index map into the prev-CRM
    carry slots.  All boundary tensors are (h, h) / (scap, scap) —
    nothing n^2 is ever materialised.
    """
    hot_idx, valid_h, lut, raw, norm, binary = _window_crm_device(
        carry, cspec, n=n, h=h, wcap=wcap, use_kernels=use_kernels)
    W = norm.astype(jnp.float64)

    # -- Alg. 4 edge diff vs the previous window, per compact space:
    # removed edges live in the PREV hot space, added edges in the
    # CURRENT one; both index maps ascend in item id, so row-major
    # nonzero order IS the host's lexicographic global edge order
    p_idx = carry["p_idx"]                           # (h,) prev hot -> item
    pbin = carry["pbin"]
    lut_prev = jnp.full(n + 1, -1, jnp.int32).at[p_idx].set(
        jnp.arange(h, dtype=jnp.int32)).at[n].set(-1)
    ci = lut_prev[hot_idx]                           # cur slot -> prev slot
    pc = lut[p_idx]                                  # prev slot -> cur slot
    pcv = pc >= 0
    pcc = jnp.maximum(pc, 0)
    cur_in_prev = binary[pcc][:, pcc] & pcv[:, None] & pcv[None, :]
    civ = ci >= 0
    cic = jnp.maximum(ci, 0)
    prev_in_cur = pbin[cic][:, cic] & civ[:, None] & civ[None, :]
    triu_h = jnp.triu(jnp.ones((h, h), bool), k=1)
    remM = pbin & ~cur_in_prev & triu_h
    addM = binary & ~prev_in_cur & triu_h
    of = carry["of"]
    gsize = carry["cnt"][:n].astype(jnp.int32)
    of, gsize = _adjust_partition(
        of, gsize, binary, W, hot_idx, valid_h, lut,
        addM, remM, p_idx, cspec, n=n, h=h, gcap=gcap)
    if enable_split:
        of = _split_oversized(of, gsize, W, lut, cspec, n=n, h=h, gcap=gcap)
    if enable_acm:
        of = _approx_merge(
            of, binary, hot_idx, valid_h, cspec, n=n, h=h,
            use_kernels=use_kernels, full_merge=full_merge)

    E_new, a_new, cnt_new = _install_partition_device(
        carry, of, now, dt, n=n, seed_new=seed_new)
    out = dict(
        carry, E=E_new, anchor=a_new, of=of, cnt=cnt_new,
        wlen=jnp.zeros((), jnp.int32),
        wcnt=jnp.zeros(n + 1, jnp.int32),
        seed=jnp.zeros((n + 1, m), jnp.int32),
        p_idx=hot_idx, pbin=binary, praw=raw, pnorm=norm,
    )
    if uses_sizes:
        out["vol"] = jnp.zeros(n + 1, jnp.float64).at[of].add(item_sizes)
    return out


# ---------------------------------------------------------------------------
# device: in-scan event construction + the Alg. 5/6 cost step
# ---------------------------------------------------------------------------
def _event_step(carry, x, spec, *, kind, charge, uses_sizes, item_sizes,
                n, m):
    """``batch_events`` + the const-dt replay step, derived in-scan.

    The host dedups (request, clique) keys with ``np.unique`` — sorted
    key order.  Here every (B*d) item slot maps to key ``r*(n+1)+cl``
    (invalid slots -> clique n), a stable argsort groups them, and
    segment sums produce the per-event counts; the event list is the
    host's, interleaved with inert val=False groups (invalid slots and
    request padding) whose writes land on the dump row/col.  The cost
    arithmetic below is copied expression-for-expression from
    ``engine_jax._replay_impl`` (const-dt branch), so the E/anchor
    trajectory stays float-for-float identical and cost sums differ
    only by in-batch summation order (the 1e-9 bar).
    """
    E, anchor, acc = carry["E"], carry["anchor"], carry["acc"]
    of, cnt = carry["of"], carry["cnt"]
    K = n
    items = x["items"]                               # (B, d)
    B, d = items.shape
    NE = B * d
    valid = (items >= 0).reshape(NE)
    item = jnp.clip(items, 0, n - 1).reshape(NE)
    r = jax.lax.broadcasted_iota(jnp.int32, (B, d), 0).reshape(NE)
    cl = jnp.where(valid, of[item], K)
    key = r * (K + 1) + cl
    o = jnp.argsort(key)                             # stable
    sk = key[o]
    first = jnp.concatenate([jnp.ones(1, bool), sk[1:] != sk[:-1]])
    seg = (jnp.cumsum(first.astype(jnp.int32)) - 1).astype(jnp.int32)
    vmask = valid[o]
    n_req_s = jax.ops.segment_sum(
        jnp.where(vmask, 1.0, 0.0), seg, num_segments=NE,
        indices_are_sorted=True)
    if uses_sizes:
        isz = item_sizes[item][o]
        req_size_s = jax.ops.segment_sum(
            jnp.where(vmask, isz, 0.0), seg, num_segments=NE,
            indices_are_sorted=True)
    # compact the unique keys into the event axis; unused tail entries
    # get an inert pad key (last request, dump clique)
    pad_key = (B - 1) * (K + 1) + K
    dst = jnp.where(first, seg, NE)
    ev_key = jnp.full(NE + 1, pad_key, key.dtype).at[dst].set(sk)[:NE]
    ev_r = ev_key // (K + 1)
    ev_c = (ev_key % (K + 1)).astype(jnp.int32)
    ev_j = x["servers"][ev_r]
    ev_t = x["times"][ev_r]
    val = ev_c < K
    n_req = n_req_s
    size = cnt[ev_c]
    if uses_sizes:
        csize = carry["vol"][ev_c]
        req_size = req_size_s
    else:
        csize = size
        req_size = n_req

    # (c, j) view: stable sort keeps ascending request order in-group,
    # exactly the host's o_cj
    key_cj = ev_c * m + ev_j
    o_cj = jnp.argsort(key_cj)
    kcs = key_cj[o_cj]
    first_cj_s = jnp.concatenate([jnp.ones(1, bool), kcs[1:] != kcs[:-1]])
    last_cj_s = jnp.concatenate([kcs[1:] != kcs[:-1], jnp.ones(1, bool)])
    t_cj_s = ev_t[o_cj]
    prev_t_s = jnp.where(
        first_cj_s, 0.0,
        jnp.concatenate([jnp.zeros(1, jnp.float64), t_cj_s[:-1]]))
    first_cj = jnp.zeros(NE, bool).at[o_cj].set(first_cj_s)
    prev_cj_t = jnp.zeros(NE, jnp.float64).at[o_cj].set(prev_t_s)

    # per-clique view (o_c): previous server within the clique group
    o_c = jnp.argsort(ev_c)
    cs = ev_c[o_c]
    first_c_s = jnp.concatenate([jnp.ones(1, bool), cs[1:] != cs[:-1]])
    last_c_s = jnp.concatenate([cs[1:] != cs[:-1], jnp.ones(1, bool)])
    j_c_s = ev_j[o_c]
    prev_j_s = jnp.where(
        first_c_s, -1,
        jnp.concatenate([jnp.full(1, -1, jnp.int32), j_c_s[:-1]]))
    first_c = jnp.zeros(NE, bool).at[o_c].set(first_c_s)
    prev_j = jnp.full(NE, -1, jnp.int32).at[o_c].set(prev_j_s)

    # ---- the replay cost step (engine_jax._replay_impl, const dt) ----
    j, t = ev_j, ev_t
    dt = spec["dt"]
    dt_e = dt[0]
    E_before = jnp.where(first_cj, E[ev_c, j], prev_cj_t + dt_e)
    dep = 0.0 * E_before[0]
    a0 = anchor[ev_c]
    anchor_alive = jnp.where(
        first_c, (a0 == j) & (E_before > 0.0), prev_j == j)
    fresh = E_before > t
    alive = fresh | anchor_alive
    miss = (~alive) & val
    lapsed = alive & (~fresh) & val
    steps = jnp.ceil((t - E_before) / dt_e)
    rr = E_before + steps * dt_e
    rr = jnp.where(rr <= t, rr + dt_e, rr)
    e_eff = jnp.where(fresh, E_before, jnp.where(lapsed, rr, t))
    rate_stored = _rate_hook(kind, spec, size, csize, j)
    rent = jnp.where(lapsed, rate_stored * (e_eff - E_before), 0.0)
    tc = jnp.where(
        miss, _transfer_hook(kind, spec, size, csize, j), 0.0)
    if charge == "requested":
        rate = _rate_hook(kind, spec, n_req, req_size, j)
    else:
        rate = rate_stored
    dur = jnp.maximum((t + dt_e) - jnp.maximum(e_eff, t), 0.0)
    cc = jnp.where(val, rate * dur, 0.0)
    nm = miss.sum()
    acc = acc + jnp.stack([
        tc.sum(), cc.sum(), rent.sum(),
        nm.astype(acc.dtype), (val.sum() - nm).astype(acc.dtype),
        jnp.where(miss, size, 0.0).sum(),
    ])

    # ---- state update on segment-last events (non-lasts -> dump) ----
    uc = jnp.where(last_cj_s, (kcs // m).astype(jnp.int32), K)
    uj = jnp.where(last_cj_s, (kcs % m).astype(jnp.int32), 0)
    E = E.at[uc, uj].set(t_cj_s + dt[0] + dep)
    ac = jnp.where(last_c_s, cs, K)
    a_cur = anchor[ac]
    aE = E[ac, jnp.maximum(a_cur, 0)]                # POST-update E
    t_c_s = ev_t[o_c]
    upd = (a_cur < 0) | (t_c_s + dt[0] >= aE)
    anchor = anchor.at[jnp.where(upd, ac, K)].set(j_c_s)
    return dict(carry, E=E, anchor=anchor, acc=acc)


# ---------------------------------------------------------------------------
# the scan: boundary cond -> window accumulate -> events/costs
# ---------------------------------------------------------------------------
#: times the fused CGM scan body has been TRACED — the device-CGM
#: mirror of ``engine_jax.SCAN_TRACES`` (fresh compiles per new input
#: structure); the live serving engine asserts chunk streams reuse ONE
#: compiled scan (tests/test_serving_live.py)
SCAN_TRACES = 0


def _cgm_replay_impl(spec, cspec, init, xs, item_sizes, *, kind, charge,
                     uses_sizes, enable_split, enable_acm, seed_new,
                     use_kernels, gcap, full_merge):
    global SCAN_TRACES
    SCAN_TRACES += 1
    n = init["of"].shape[0]
    m = init["E"].shape[1]
    h = init["p_idx"].shape[0]
    wcap = init["wbuf"].shape[0]
    dt = spec["dt"]

    def step(carry, x):
        # the boundary fires BEFORE this batch's requests: the step that
        # starts a new T_CG period evaluates the window accumulated by
        # the preceding steps (``x["cg"]`` comes from the shared xs, so
        # under vmap the predicate stays unbatched and cond stays cond)
        carry = jax.lax.cond(
            x["cg"],
            lambda c: _cgm_boundary(
                c, x["now"], cspec, dt, item_sizes, n=n, m=m, h=h,
                wcap=wcap, uses_sizes=uses_sizes,
                enable_split=enable_split, enable_acm=enable_acm,
                seed_new=seed_new, use_kernels=use_kernels, gcap=gcap,
                full_merge=full_merge),
            lambda c: c,
            carry)
        carry = _accumulate_window(carry, x, n=n, m=m)
        carry = _event_step(
            carry, x, spec, kind=kind, charge=charge,
            uses_sizes=uses_sizes, item_sizes=item_sizes, n=n, m=m)
        return carry, carry["of"]

    return jax.lax.scan(step, init, xs)


if HAS_JAX:
    @functools.lru_cache(maxsize=64)
    def _compiled_cgm_replay(kind, charge, uses_sizes, enable_split,
                             enable_acm, seed_new, use_kernels, gcap,
                             full_merge, vmapped):
        f = functools.partial(
            _cgm_replay_impl, kind=kind, charge=charge,
            uses_sizes=uses_sizes, enable_split=enable_split,
            enable_acm=enable_acm, seed_new=seed_new,
            use_kernels=use_kernels, gcap=gcap, full_merge=full_merge)
        if vmapped:
            # scenarios vmap over spec / cgm spec / carry; the schedule
            # tensors and item sizes are shared unbatched
            f = jax.vmap(f, in_axes=(0, 0, 0, None, None))
        return jax.jit(f)


# ---------------------------------------------------------------------------
# host seam: carry init, execution, state/policy sync
# ---------------------------------------------------------------------------
def init_cgm_carry(state, prev_crm, win_prefix, *, n, m, uses_sizes,
                   item_sizes, layout=None, schedule=None, h=None,
                   wcap=None, dbuf=None):
    """Numpy engine/policy state -> the device scan carry (one lane).

    The carry is ALWAYS dense-n (``of``: n slots, ``E``: (n+1, m)) —
    a StateLayout only has to keep rows unsharded for the in-scan
    segment reductions to see the whole state; bucketed catalogs are
    fine because the carry is built independently of the generic
    schedule geometry.  The compact workspace dims come from the
    ``schedule`` (or explicit ``h`` / ``wcap`` for the live ratchet);
    ``h`` is bumped to fit a restored previous-window CRM.
    """
    from .engine_jax import N_ACC, state_to_device
    from .state_layout import StateLayout

    lay = StateLayout.resolve(layout)
    if not lay.supports_device_cgm(n, m):
        raise ValueError(
            f"device CGM needs row-unsharded state at (n={n}, m={m}); "
            f"{lay.kind!r} shards rows across devices — use the generic "
            "schedule path for this catalog")
    if schedule is not None:
        h = schedule.h if h is None else h
        wcap = schedule.wcap if wcap is None else wcap
        dbuf = schedule.d if dbuf is None else dbuf
    if h is None or wcap is None:
        raise ValueError(
            "init_cgm_carry needs a CGM schedule or explicit h/wcap")
    dbuf = 1 if dbuf is None else int(dbuf)
    prev_nh = int(prev_crm.hot_items.size) if prev_crm is not None else 0
    if prev_nh:
        h = min(n, max(h, _bucket(prev_nh, 32, 32)))

    E0, a0 = state_to_device(state, n)
    of0 = np.asarray(state.partition.clique_of, np.int32)
    carry = {
        "E": E0,
        "anchor": a0,
        "acc": np.zeros(N_ACC, np.float64),
        "of": of0,
        "cnt": np.bincount(of0, minlength=n + 1).astype(np.float64),
        "wbuf": np.full((wcap, dbuf), -1, np.int32),
        "wlen": np.zeros((), np.int32),
        "wcnt": np.zeros(n + 1, np.int32),
        "seed": np.zeros((n + 1, m), np.int32),
        "p_idx": np.full(h, n, np.int32),
        "praw": np.zeros((h, h), np.float32),
        "pnorm": np.zeros((h, h), np.float32),
        "pbin": np.zeros((h, h), bool),
    }
    if uses_sizes:
        vol = np.zeros(n + 1, np.float64)
        np.add.at(vol, of0, np.asarray(item_sizes, np.float64))
        carry["vol"] = vol
    if prev_nh:
        # the previous window's CRM in its compact coordinates: hot ids
        # ascend on the host, matching the device's nonzero order
        carry["p_idx"][:prev_nh] = np.asarray(prev_crm.hot_items, np.int32)
        carry["praw"][:prev_nh, :prev_nh] = np.asarray(
            prev_crm.raw, np.float32)
        carry["pnorm"][:prev_nh, :prev_nh] = prev_crm.norm
        carry["pbin"][:prev_nh, :prev_nh] = prev_crm.binary
    if win_prefix is not None:
        p_it, p_sv = win_prefix
        p_it = np.atleast_2d(np.asarray(p_it))
        R0 = int(p_it.shape[0])
        if R0:
            # the open window's already-fed requests (session feed) go
            # straight into the buffer; duplicate-counting item/seed
            # tallies mirror the host window bookkeeping
            if R0 > wcap or p_it.shape[1] > dbuf:
                raise ValueError(
                    f"window prefix ({R0} x {p_it.shape[1]}) exceeds the "
                    f"carry buffer ({wcap} x {dbuf}); build the schedule "
                    "with prefix_rows/prefix_slots")
            carry["wbuf"][:R0, : p_it.shape[1]] = p_it
            carry["wlen"] = np.asarray(R0, np.int32)
            flat = p_it.reshape(-1)
            carry["wcnt"] = np.bincount(
                np.where(flat >= 0, flat, n), minlength=n + 1,
            ).astype(np.int32)
            seed = np.zeros((n + 1, m), np.int64)
            sv = np.repeat(np.asarray(p_sv, np.int64), p_it.shape[1])
            ok = flat >= 0
            np.add.at(seed, (flat[ok], sv[ok]), 1)
            carry["seed"] = seed.astype(np.int32)
    return carry


def cgm_loop_statics(cspec, carry0, *, enable_split, enable_acm):
    """The two compile-time loop capacities derived from runtime spec.

    * ``gcap`` — member-list width for the split worklist AND the
      adjust-phase group lists: no group can exceed max(initial
      partition, omega) (adjust merges are omega-capped; splits only
      shrink), maxed over vmapped lanes and bucketed to keep recompiles
      rare.  ``cgm_spec`` sets omega = n for no-split lanes, so the
      bound stays an invariant there too.
    * ``full_merge`` — True when ANY lane can run the approximate merge
      OUTSIDE the pruning regime (the w/o-CS ablation: omega = n), so
      the act space must hold all n groups (scap = 2n) instead of 2h.
    """
    om = np.atleast_1d(np.asarray(cspec["omega"], np.int64))
    gam = np.atleast_1d(np.asarray(cspec["gamma"], np.float64))
    omf = om.astype(np.float64)
    prune = (om > 2) & (gam > (omf - 2.0) / omf)
    full_merge = bool(enable_acm) and not bool(prune.all())
    cnt_max = int(np.asarray(carry0["cnt"]).max())
    gcap = _bucket(max(int(om.max()), cnt_max, 2), 8, 8)
    del enable_split
    return gcap, full_merge


def run_cgm_schedule(schedule, spec, statics, cspec, carry0, item_sizes, *,
                     charge="requested", enable_split=True, enable_acm=True,
                     seed_new=True, use_kernels=None, block=True):
    """Execute one CGM schedule; returns (final_carry, per-step slot maps).

    ``spec``/``cspec``/``carry0`` may carry a leading scenario axis (the
    fig7 grid); the schedule and item sizes stay shared unbatched.
    """
    _require_jax()
    if use_kernels is None:
        from ..kernels.autowire import default_cgm_hooks

        use_kernels = default_cgm_hooks()[0] is not None
    vmapped = carry0["E"].ndim == 3
    gcap, full_merge = cgm_loop_statics(
        cspec, carry0, enable_split=enable_split, enable_acm=enable_acm)
    fn = _compiled_cgm_replay(
        statics, charge, "vol" in carry0, bool(enable_split),
        bool(enable_acm), bool(seed_new), bool(use_kernels), gcap,
        full_merge, vmapped)
    with enable_x64():
        spec_j = {k: jnp.asarray(v) for k, v in spec.items()}
        cspec_j = {k: jnp.asarray(v) for k, v in cspec.items()}
        init_j = {k: jnp.asarray(v) for k, v in carry0.items()}
        xs_j = {k: jnp.asarray(v) for k, v in schedule.xs.items()}
        sz_j = (
            jnp.asarray(item_sizes, jnp.float64)
            if item_sizes is not None
            else jnp.ones(schedule.n, jnp.float64))
        final, ofs = fn(spec_j, cspec_j, init_j, xs_j, sz_j)
        if not block:
            return final, ofs
        return {k: np.asarray(v) for k, v in final.items()}, np.asarray(ofs)


def partition_from_of(n: int, of: np.ndarray) -> CliquePartition:
    """Dense device slot map -> host partition; slot order IS group order,
    so ``result.clique_of == of`` element for element."""
    of = np.asarray(of)
    k = int(of.max()) + 1 if of.size else 0
    groups = [tuple(np.nonzero(of == g)[0].tolist()) for g in range(k)]
    return CliquePartition.from_cliques(n, groups)


def sync_policy_from_run(policy, schedule, ofs, final, part) -> None:
    """Fold the device run's window bookkeeping back into the policy, as
    if ``on_window`` had run per boundary on the host."""
    nbd = int(schedule.boundary_steps.size)
    if nbd == 0:
        return
    for b in schedule.boundary_steps:
        sizes = np.bincount(np.asarray(ofs[int(b)])).astype(np.int64)
        policy.size_history.append(sizes[sizes > 1])
    policy.n_windows += nbd
    policy._partition = part
    policy._prev_crm = WindowCRM.from_compact(
        final["p_idx"], final["praw"], final["pnorm"], final["pbin"],
        n=schedule.n)


def policy_hot_dims(policy) -> list:
    """The ``(top_frac, of_catalog)`` hot-capacity dims of one policy."""
    cfg = policy.config
    return [(float(cfg.top_frac), cfg.top_frac_of == "catalog")]


def replay_cgm(jeng, policy, trace, *, t_cg, batch_size=None, next_cg0=None,
               win_prefix=None, progress=None):
    """Device-resident AKPC replay: one host->device transfer, zero host
    clique-generation calls.  Drop-in for ``JaxReplayEngine.replay`` when
    ``wants_device_cgm`` approves the (policy, model, trace) triple."""
    eng = jeng.engine
    uses_sizes = bool(eng.model.uses_sizes)
    item_sizes = eng.env.sizes() if uses_sizes else None
    prefix_rows = prefix_slots = 0
    if win_prefix is not None:
        p_it = np.atleast_2d(np.asarray(win_prefix[0]))
        prefix_rows = int(p_it.shape[0])
        prefix_slots = prefix_rows * max(1, int(p_it.shape[1]))
    schedule = build_cgm_schedule(
        trace, t_cg, uses_sizes=uses_sizes, batch_size=batch_size,
        next_cg0=next_cg0, hot_dims=policy_hot_dims(policy),
        prefix_rows=prefix_rows, prefix_slots=prefix_slots)
    jeng.last_schedule = schedule
    cfg = policy.config
    cspec = cgm_spec(cfg, cfg.params, trace.n)
    carry0 = init_cgm_carry(
        eng.state, getattr(policy, "_prev_crm", None), win_prefix,
        n=trace.n, m=trace.m, uses_sizes=uses_sizes, item_sizes=item_sizes,
        layout=getattr(jeng, "layout", None), schedule=schedule)
    final, ofs = run_cgm_schedule(
        schedule, jeng._spec, jeng._statics, cspec, carry0, item_sizes,
        charge=eng.caching_charge,
        enable_split=cfg.enable_split,
        enable_acm=cfg.enable_approx_merge,
        seed_new=eng.seed_new_cliques)
    if progress is not None:
        progress(trace.n_requests)
    nbd = int(schedule.boundary_steps.size)
    part = (eng.state.partition if nbd == 0
            else partition_from_of(trace.n, final["of"]))
    eng.state = CacheState(
        partition=part, E=final["E"][: part.k].copy(),
        anchor=final["anchor"][: part.k].copy(), m=eng.m)
    eng._set_partition_caches(part)
    from .engine_jax import apply_acc

    apply_acc(eng.costs, schedule, final["acc"])
    sync_policy_from_run(policy, schedule, ofs, final, part)
    return eng.costs


def wants_device_cgm(policy, trace, model) -> bool:
    """Eligibility gate for the device-resident CGM path.

    ``REPRO_JAX_CGM`` = ``force`` / ``off`` / ``auto`` (default).  Auto
    requires an unmodified AKPC-family policy (the on-device merge/split
    mirrors ``AKPCPolicy.on_window`` exactly), a uniform keepalive dt
    and no custom CRM hooks.  The CATALOG size no longer gates the path
    — the boundary workspace is sized by the padded hot capacity ``h``
    (window working set x ``top_frac``), so auto admits any catalog
    whose ``h`` stays under ``MAX_DEVICE_CGM_HOT`` and whose window
    request counts keep the f32 co-occurrence counters exact.  Lanes
    that run the approximate merge OUTSIDE the pruning regime (the
    w/o-CS ablation) still need a (2n, 2n) merge space, so those stay
    small-catalog only.
    """
    mode = os.environ.get("REPRO_JAX_CGM", "auto").strip().lower()
    if mode in ("off", "0"):
        return False
    if not HAS_JAX:
        return False
    from .akpc import AKPCConfig
    from .policy import AKPCPolicy

    cfg = getattr(policy, "config", None)
    if not isinstance(cfg, AKPCConfig):
        return False
    if not isinstance(policy, AKPCPolicy) \
            or type(policy).on_window is not AKPCPolicy.on_window:
        return False
    t_cg = getattr(policy, "t_cg", None)
    if t_cg is None:
        return False
    if cfg.crm_matmul is not None or cfg.pair_edges is not None:
        return False
    dt = np.asarray(model.dt(), np.float64)
    if dt.size and not (dt == dt[0]).all():
        return False
    if mode in ("force", "1"):
        return True
    wmax = _max_window_requests(trace, t_cg)
    if wmax + NE_TARGET >= _F32_EXACT:
        return False
    d_max = max(1, int(getattr(trace, "d_max", 1)))
    smax = min(trace.n, wmax * d_max)
    if hot_capacity(trace.n, smax, policy_hot_dims(policy)) \
            > MAX_DEVICE_CGM_HOT:
        return False
    if cfg.enable_approx_merge:
        omega = int(cfg.params.omega) if cfg.enable_split else int(trace.n)
        prune = omega > 2 and float(cfg.params.gamma) > (omega - 2) / omega
        if not prune and trace.n > 256:
            return False
    return True
