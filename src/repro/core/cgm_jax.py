"""Device-resident clique generation: the CGM inside the jit'd scan.

PR 5 moved the replay *state* recurrence on device but left the Clique
Generation Module (Alg. 2-4) on host, so ``build_schedule`` still calls
``policy.on_window`` per T_CG boundary and ships partition-dependent
event tensors.  This module re-cuts that seam (DESIGN.md §11): the host
ships only RAW request tensors (items / servers / times, sliced so no
scan step straddles a T_CG boundary) and the scan carry grows the full
CGM state — window CRM accumulator, hot-set counters, seed counters,
the item->clique slot map and the previous window's binarised CRM.  At
each boundary step a ``lax.cond`` branch runs, entirely on device:

* Alg. 2 — hot set (stable rank of window counts), min-max normalise,
  binarise at theta; the window CRM itself was accumulated step by step
  as the rank-B update ``CRM += H^T H`` (``kernels/crm_update.py`` on
  TPU, a jnp matmul elsewhere);
* Alg. 4 — the edge diff vs the previous window's binary CRM, then the
  removed-edge splits / added-edge merges as bounded ``fori_loop``s
  over fixed-capacity slot buffers;
* Alg. 3 — oversized-clique splits as a LIFO worklist (bounded
  ``fori``+``while``) over member masks, and the approximate merge as a
  ``lax.while_loop`` over the thresholded density matrix using the
  incremental ``X = M A M^T`` patch algebra of PR 3 (one row/col patch
  per merge, ``kernels/merge_step.py`` builds the initial D on TPU);
* the partition install (``install_partition``) as segment reductions
  over the old slot map — matching, member-wise expiry min, Alg.-1
  window seeding.

Because events are now CONSTRUCTED in-scan (dedup, sort orders, lags —
the ``batch_events`` pipeline as jnp sorts/segment-sums), the schedule
is partition-free: theta / gamma / omega / top_frac are runtime scalars
(``cgm_spec``) and a fig7 hyperparameter grid vmaps over them sharing
ONE schedule and ONE host->device transfer per trace.

Parity bar: the host path (``core/cliques.py`` + the ``cliques_ref``
oracle) stays frozen; device partitions are element-for-element equal
across chained windows and costs match the numpy engine at 1e-9.  The
proof obligations (op-for-op float semantics, stable-sort tie-breaking,
slot-order vs list-order equivalence) are documented inline at each
step.  The f32 CRM / X counters are exact integers below 2**24 — the
eligibility gate (``wants_device_cgm``) enforces the bound.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from .cliques import CliquePartition
from .crm import WindowCRM, cooccurrence_counts
from .engine import CacheState
from .engine_jax import (
    HAS_JAX,
    N_ACC,
    NE_TARGET,
    _bucket,
    _rate_hook,
    _require_jax,
    _transfer_hook,
)

if HAS_JAX:  # pragma: no branch
    import functools

    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
else:  # pragma: no cover - jax-less containers never import the scan path
    jax = None
    import functools

#: device CGM is gated to catalogs whose n^2 carries and f32 counters
#: stay cheap and exact; larger catalogs keep the host CGM path
MAX_DEVICE_CGM_N = 256
#: f32 exactness bound for the CRM / X integer counters
_F32_EXACT = 1 << 24


# ---------------------------------------------------------------------------
# the partition-free schedule: raw request tensors + boundary flags
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CGMSchedule:
    """Raw request batches of one trace, cut on the T_CG grid.

    Unlike :class:`~repro.core.engine_jax.ReplaySchedule` there are no
    event tensors and no install records — events and partitions are
    derived ON DEVICE.  ``xs`` leading axis is nb (scan steps); a step
    never straddles a T_CG boundary, and a step whose window begins a
    new T_CG period carries ``cg=True`` + the boundary evaluation time.
    """

    n: int
    m: int
    nb: int
    B: int                      # requests per step (padded)
    d: int                      # item slots per request
    const_dt: bool              # device CGM requires uniform dt
    uses_sizes: bool
    xs: dict
    n_requests: int
    n_item_requests: int
    boundary_steps: np.ndarray  # (n_boundaries,) scan-step indices
    win_start: int              # open-window start index into the trace
    boundary_hit: bool
    next_cg: float | None


def build_cgm_schedule(
    trace,
    t_cg: float,
    *,
    uses_sizes: bool,
    batch_size: int | None = None,
    next_cg0: float | None = None,
) -> CGMSchedule:
    """Cut the trace into boundary-aligned request batches.

    The walk is the same T_CG grid as ``build_schedule`` (and the numpy
    ``ReplayEngine.replay``): a boundary fires when the next request
    lies at/after ``next_cg``, is evaluated at that request's time, and
    empty periods are skipped with a single firing.  No clique
    generation happens here — the boundary merely flags the step.
    """
    times, servers, items = trace.times, trace.servers, trace.items
    R = int(times.shape[0])
    d = int(items.shape[1]) if items.ndim == 2 else 1
    if batch_size is not None:
        bs = max(1, int(batch_size))
    else:
        bs = max(1, NE_TARGET // max(1, d))
    if R > 0:
        next_cg = (float(next_cg0) if next_cg0 is not None
                   else float(times[0]) + t_cg)
    else:
        next_cg = next_cg0 if next_cg0 is not None else np.inf

    slices: list[tuple[int, int, float | None]] = []
    pending_cg: float | None = None
    win_start = 0
    boundary_hit = False
    pos = 0
    while pos < R:
        cut = int(np.searchsorted(times, next_cg, side="left"))
        if cut <= pos:
            t = float(times[pos])
            pending_cg = t
            win_start = pos
            boundary_hit = True
            while next_cg <= t:
                next_cg += t_cg
            continue
        stop = min(pos + bs, cut)
        slices.append((pos, stop, pending_cg))
        pending_cg = None
        pos = stop

    nb_raw = max(1, len(slices))
    nb = _bucket(nb_raw, 4, 4)
    B = _bucket(max((s - p for p, s, _ in slices), default=1), 32, 32)
    t_pad = float(times[-1]) if R else 0.0
    xs = {
        "items": np.full((nb, B, d), -1, np.int32),
        "servers": np.zeros((nb, B), np.int32),
        "times": np.full((nb, B), t_pad, np.float64),
        "cg": np.zeros(nb, bool),
        "now": np.zeros(nb, np.float64),
    }
    boundary_steps = []
    for b, (p, s, cg_now) in enumerate(slices):
        w = s - p
        xs["items"][b, :w] = items[p:s]
        xs["servers"][b, :w] = servers[p:s]
        xs["times"][b, :w] = times[p:s]
        xs["times"][b, w:] = times[s - 1]
        if cg_now is not None:
            xs["cg"][b] = True
            xs["now"][b] = cg_now
            boundary_steps.append(b)

    return CGMSchedule(
        n=trace.n, m=trace.m, nb=nb, B=B, d=d, const_dt=True,
        uses_sizes=uses_sizes, xs=xs,
        n_requests=R, n_item_requests=int((items >= 0).sum()),
        boundary_steps=np.asarray(boundary_steps, np.int32),
        win_start=win_start, boundary_hit=boundary_hit,
        next_cg=None if R == 0 else float(next_cg),
    )


def cgm_spec(cfg, params, n: int) -> dict:
    """The CGM hyperparameters as runtime (vmappable) scalars.

    theta / gamma enter f32 comparisons on the host path (NEP-50 weak
    scalars against f32 CRM/density matrices), so both are shipped in
    the dtype each comparison actually runs in.
    """
    omega = int(params.omega) if cfg.enable_split else int(n)
    return {
        "theta": np.float32(params.theta),
        "gamma32": np.float32(params.gamma),
        "gamma": np.float64(params.gamma),
        "omega": np.int32(omega),
        "omega_f": np.float64(omega),
        "top_frac": np.float64(cfg.top_frac),
        "of_catalog": np.bool_(cfg.top_frac_of == "catalog"),
    }


# ---------------------------------------------------------------------------
# device: window accumulation (Alg. 2 running state)
# ---------------------------------------------------------------------------
def _accumulate_window(carry, x, *, n, m, use_kernels):
    """Fold one request batch into the open window's CGM counters.

    * ``crm``  (n, n) f32 — co-occurrence counts via ``CRM += H^T H``
      with H the 0/1 incidence (in-request duplicates dedup to 1, same
      as the host's pair scatter); counts are exact integers in f32.
    * ``wcnt`` (n+1,) i32 — per-item access counts WITH duplicates
      (the host hot-set bincount does not dedup within a request).
    * ``seed`` (n+1, m) i32 — (item, server) counts WITH duplicates
      (``window_seed_servers``'s ``np.add.at`` semantics).
    """
    items = x["items"]                              # (B, d) i32
    B, d = items.shape
    valid = items >= 0
    col = jnp.where(valid, items, n)                # invalid -> dump col n
    row = jax.lax.broadcasted_iota(jnp.int32, (B, d), 0)
    H = jnp.zeros((B, n + 1), jnp.float32).at[row, col].set(1.0)
    Hv = H[:, :n]
    if use_kernels:
        from ..kernels.crm_update import crm_update
        from ..kernels.ops import INTERPRET

        upd = crm_update(Hv, interpret=INTERPRET)   # (n, n) f32, zero diag
    else:
        upd = Hv.T @ Hv     # f32 0/1 contraction: exact integer counts
    crm = carry["crm"] + upd
    wcnt = carry["wcnt"].at[col.reshape(-1)].add(1)[: n + 1]
    seed = carry["seed"].at[col, x["servers"][:, None]].add(
        valid.astype(jnp.int32))
    return dict(carry, crm=crm, wcnt=wcnt, seed=seed)


# ---------------------------------------------------------------------------
# device: Alg. 3/4 primitives on full-n masks
# ---------------------------------------------------------------------------
def _split_sides(W, member, u, v, n):
    """``split_clique_on_edge`` on a member mask: True = right side (v's).

    Bit-exact vs the host: the f64 side-weight accumulators are updated
    in ascending item order (the host iterates submatrix columns, whose
    order IS ascending member id), and the tie ``wl[p] >= wr[p]`` sends
    p left exactly as the host does.
    """
    wl0 = W[:, u]
    wr0 = W[:, v]
    right0 = jnp.zeros(n, bool).at[v].set(True)

    def body(p, st):
        wl, wr, right = st
        act = member[p] & (p != u) & (p != v)
        go_left = wl[p] >= wr[p]
        right = right.at[p].set(jnp.where(act & ~go_left, True, right[p]))
        colp = W[:, p]
        wl = jnp.where(act & go_left, wl + colp, wl)
        wr = jnp.where(act & ~go_left, wr + colp, wr)
        return (wl, wr, right)

    _, _, right = jax.lax.fori_loop(0, n, body, (wl0, wr0, right0))
    return right & member


def _window_crm_device(carry, cspec, *, n):
    """Alg. 2 at a boundary: hot set -> normalise -> binarise.

    Returns (hot (n,) bool, raw (n, n) f32 masked counts, norm (n, n)
    f32, binary (n, n) bool) — all in GLOBAL item coordinates; the
    host's compact hot space is an order-preserving re-index, so every
    comparison below sees the same values in the same scan order.
    """
    counts = carry["wcnt"][:n]                       # (n,) i32
    support = (counts > 0).sum()
    base = jnp.where(cspec["of_catalog"], n, support).astype(jnp.float64)
    # host: max(1, int(round(base * top_frac))) — np.round is half-even,
    # same as Python's round
    n_hot = jnp.maximum(
        1, jnp.round(base * cspec["top_frac"])).astype(jnp.int32)
    order = jnp.argsort(-counts)                     # stable: ties -> low id
    rank = jnp.zeros(n, jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    hot = (rank < n_hot) & (counts > 0)
    hm2 = hot[:, None] & hot[None, :]
    eye = jnp.eye(n, dtype=bool)
    raw = jnp.where(hm2 & ~eye, carry["crm"], 0.0)   # f32 exact ints
    hi = raw.max().astype(jnp.float64)
    # host minmax_normalise: lo is always 0 (zero diagonal), hi<=0 -> 0;
    # int64/int64 true-divide (f64) then cast f32 == f32->f64 exact here
    norm = jnp.where(
        hi > 0.0,
        (raw.astype(jnp.float64) / hi).astype(jnp.float32),
        jnp.zeros((n, n), jnp.float32),
    )
    binary = (norm > cspec["theta"]) & hm2 & ~eye
    return hot, raw, norm, binary


def _adjust_partition(of, gsize, binary, W, add_u, add_v, n_add,
                      rem_u, rem_v, n_rem, cspec, *, n):
    """Alg. 4 (``adjust_previous_cliques``) over slot buffers.

    Slot numbering mirrors the host list exactly: removed-edge splits
    keep the left side in the parent slot and append the right side at
    ``ngroups`` (the host's ``groups.append``); added-edge merges keep
    ``min(cu, cv)`` and kill ``max`` (the host's keep/drop).  The final
    compaction ranks alive slots ascending — the host's ``[g for g in
    groups if g]`` order.
    """
    ngroups = (gsize > 0).sum().astype(jnp.int32)

    def rem_body(i, st):
        of, gsize, ngroups = st
        u = rem_u[i]
        v = rem_v[i]
        cu = of[u]
        do = (cu == of[v]) & (gsize[cu] > 1)
        member = (of == cu) & do
        right = _split_sides(W, member, u, v, n)
        nr = right.sum().astype(jnp.int32)
        of = jnp.where(right, ngroups, of)
        g2 = gsize.at[cu].add(-nr).at[ngroups].set(nr)
        gsize = jnp.where(do, g2, gsize)
        ngroups = ngroups + do.astype(jnp.int32)
        return (of, gsize, ngroups)

    of, gsize, ngroups = jax.lax.fori_loop(
        0, n_rem, rem_body, (of, gsize, ngroups))

    def add_body(i, st):
        of, gsize = st
        u = add_u[i]
        v = add_v[i]
        cu = of[u]
        cv = of[v]
        g = gsize[cu] + gsize[cv]
        um = (of == cu) | (of == cv)
        # fully_connected: the union's in-edge count must be C(g, 2);
        # cold members contribute no edges, so this also rejects unions
        # with cold items — exactly the host probe semantics
        ne = (binary & um[:, None] & um[None, :]).sum() // 2
        do = (cu != cv) & (g <= cspec["omega"]) & (ne == g * (g - 1) // 2)
        keep = jnp.minimum(cu, cv)
        drop = jnp.maximum(cu, cv)
        of = jnp.where(do & um, keep, of)
        g2 = gsize.at[keep].set(g).at[drop].set(0)
        gsize = jnp.where(do, g2, gsize)
        return (of, gsize)

    of, gsize = jax.lax.fori_loop(0, n_add, add_body, (of, gsize))

    alive = gsize > 0
    newid = (jnp.cumsum(alive.astype(jnp.int32)) - 1).astype(jnp.int32)
    of = newid[of]
    gsize = jnp.zeros(n + 1, jnp.int32).at[
        jnp.where(alive, newid, n)].add(gsize)[:n]
    return of, gsize


def _split_oversized(of, gsize, W, cspec, *, n):
    """Alg. 3 splits (``split_oversized``) as a bounded LIFO worklist.

    Every slot runs the worklist (non-oversized slots emit themselves on
    the first pop, reproducing the host's pass-through).  Pieces keep
    the host's IN-PLACE order via the key ``slot * (n+1) + emit_idx``;
    the closed-form hot_count<=1 peel is subsumed by the generic
    weakest-edge split: with an all-zero weight submatrix the first-min
    edge is (g[0], g[1]) and every tie goes left, which peels exactly
    the host's ``(g[0],) + g[p+1:]`` then ``g[p] .. g[1]`` singletons.
    """
    triu = jnp.triu(jnp.ones((n, n), bool), k=1)
    of_key0 = jnp.zeros(n, jnp.int32)

    def slot_body(s, of_key):
        stack0 = jnp.zeros((n + 1, n), bool).at[0].set(of == s)
        sp0 = (gsize[s] > 0).astype(jnp.int32)

        def cond(st):
            return st[0] > 0

        def wbody(st):
            sp, stack, ofk, emit = st
            g = stack[sp - 1]
            sp = sp - 1
            small = g.sum() <= cspec["omega"]
            ofk = jnp.where(small & g, s * (n + 1) + emit, ofk)
            emit = emit + small.astype(jnp.int32)
            # weakest edge: first row-major minimum over member pairs —
            # the same scan order as the host's submatrix argmin (member
            # ids ascend in both index spaces)
            gm2 = g[:, None] & g[None, :] & triu
            P = jnp.where(gm2, W, jnp.inf)
            f = jnp.argmin(P.reshape(-1)).astype(jnp.int32)
            u = f // n
            v = f % n
            right = _split_sides(W, g, u, v, n)
            left = g & ~right
            stack = stack.at[sp].set(jnp.where(small, stack[sp], right))
            stack = stack.at[sp + 1].set(
                jnp.where(small, stack[sp + 1], left))
            sp = sp + jnp.where(small, 0, 2)
            return (sp, stack, ofk, emit)

        _, _, of_key, _ = jax.lax.while_loop(
            cond, wbody, (sp0, stack0, of_key, jnp.int32(0)))
        return of_key

    of_key = jax.lax.fori_loop(0, n, slot_body, of_key0)
    # dense-rank the (slot, emit) keys -> pieces in host list order
    sk = jnp.sort(of_key)
    firstk = jnp.concatenate(
        [jnp.ones(1, bool), sk[1:] != sk[:-1]])
    rnk = (jnp.cumsum(firstk.astype(jnp.int32)) - 1).astype(jnp.int32)
    pos = jnp.searchsorted(sk, of_key)
    return rnk[pos]


def _approx_merge(of, binary, hot, W, cspec, *, n, use_kernels):
    """Alg. 3 approximate merge (``approximate_merge``) as a while_loop.

    Slots 0..k-1 hold the adjusted/split groups (host list order);
    merged groups take tail slots k, k+1, ... — ascending slot order
    stays the host's compact act-matrix order at every iteration, so
    the row-major first-argmax over D breaks ties identically.  D uses
    the sentinel -2.0 for dead / non-act / diagonal entries (the host
    simply has no such rows; any value < 0 is equivalent under the
    ``max < 0 -> stop`` rule).  X is patched incrementally: one
    row/col per merge (the PR-3 algebra), with the f32 add order of the
    host (``(X[ai,ai] + X[aj,aj]) + 2.0 * X[ai,aj]``).
    """
    S = 2 * n
    slot = jnp.arange(S, dtype=jnp.int32)
    sizes = jnp.zeros(S, jnp.int32).at[of].add(1)
    alive = sizes > 0
    # host _mergeable_split: the hot filter only engages above the
    # density bar (omega > 2 and gamma > (omega-2)/omega)
    prune = (cspec["omega"] > 2) & (
        cspec["gamma"] > (cspec["omega_f"] - 2.0) / cspec["omega_f"])
    hot_i = hot.astype(jnp.int32)
    has_hot = jax.ops.segment_max(hot_i, of, num_segments=S) > 0
    live_item = hot & binary.any(axis=1)
    has_live = jax.ops.segment_max(
        live_item.astype(jnp.int32), of, num_segments=S) > 0
    is_rest = alive & prune & ~has_hot
    act = alive & jnp.where(prune, has_live, True) & ~is_rest

    # X = M A M^T over hot membership (f32 exact integer counts)
    M = jnp.zeros((S, n), jnp.float32).at[
        of, jnp.arange(n, dtype=jnp.int32)].set(hot.astype(jnp.float32))
    A = binary.astype(jnp.float32)
    if use_kernels:
        from ..kernels.clique_density import clique_pair_edges
        from ..kernels.ops import INTERPRET

        X = clique_pair_edges(M, A, interpret=INTERPRET)
    else:
        X = M @ A @ M.T
    e_max = (cspec["omega_f"] * (cspec["omega_f"] - 1.0) / 2.0).astype(
        jnp.float32)
    eyeS = jnp.eye(S, dtype=bool)
    if use_kernels:
        from ..kernels.merge_step import merge_density
        from ..kernels.ops import INTERPRET

        D = merge_density(
            X, sizes, cspec["omega"], cspec["gamma32"], interpret=INTERPRET)
    else:
        within = jnp.diag(X) / 2.0
        e_u = (within[:, None] + within[None, :]) + X
        okp = ((sizes[:, None] + sizes[None, :]) == cspec["omega"]) & ~eyeS
        dens = jnp.where(okp, e_u / e_max, -1.0)
        D = jnp.where(dens >= cspec["gamma32"], dens, -1.0)
    actp = act[:, None] & act[None, :] & ~eyeS
    D = jnp.where(actp, D, -2.0)

    tail0 = alive.sum().astype(jnp.int32)
    n_act0 = act.sum().astype(jnp.int32)

    def cond(st):
        D = st[1]
        n_act = st[7]
        return (n_act >= 2) & (D.max() >= 0.0)

    def body(st):
        X, D, of, sizes, act, alive, tail, n_act = st
        f = jnp.argmax(D.reshape(-1)).astype(jnp.int32)
        ai = f // S
        aj = f % S
        ai, aj = jnp.minimum(ai, aj), jnp.maximum(ai, aj)
        t = tail
        mm = (of == ai) | (of == aj)
        of = jnp.where(mm, t, of)
        row = X[ai, :] + X[aj, :]
        dg = (X[ai, ai] + X[aj, aj]) + 2.0 * X[ai, aj]
        X = X.at[t, :].set(row).at[:, t].set(row).at[t, t].set(dg)
        gnew = sizes[ai] + sizes[aj]
        sizes = sizes.at[t].set(gnew)
        alive = alive.at[ai].set(False).at[aj].set(False).at[t].set(True)
        act = act.at[ai].set(False).at[aj].set(False).at[t].set(True)
        # the new group's density row, host op order:
        # (within[-1] + within[:-1]) + Xn[-1, :-1]
        wt = dg / 2.0
        wl = jnp.diag(X) / 2.0
        e_row = (wt + wl) + X[t, :]
        okr = (gnew + sizes) == cspec["omega"]
        dr = jnp.where(okr, e_row / e_max, -1.0)
        dr = jnp.where(dr >= cspec["gamma32"], dr, -1.0)
        validc = act & alive & (slot != t)
        dr = jnp.where(validc, dr, -2.0)
        D = D.at[ai, :].set(-2.0).at[:, ai].set(-2.0)
        D = D.at[aj, :].set(-2.0).at[:, aj].set(-2.0)
        D = D.at[t, :].set(dr).at[:, t].set(dr).at[t, t].set(-2.0)
        return (X, D, of, sizes, act, alive, t + 1, n_act - 1)

    _, _, of, _, _, alive, _, _ = jax.lax.while_loop(
        cond, body, (X, D, of, sizes, act, alive, tail0, n_act0))

    # host output order: cand (act-universe, originals then merged) first,
    # rest groups after, both in slot order
    is_rest_s = is_rest                              # tail slots: never rest
    okey = jnp.where(
        alive, slot + jnp.where(is_rest_s, S, 0), 2 * S)
    order = jnp.argsort(okey)
    rnk = jnp.zeros(S, jnp.int32).at[order].set(
        jnp.arange(S, dtype=jnp.int32))
    return rnk[of]


def _install_partition_device(carry, of_new, now, dt, *, n, seed_new):
    """``install_partition`` as segment reductions over the slot maps.

    Matching (``match_partitions``): a new slot matches iff all its
    members came from ONE old slot of the same member count.  Changed
    slots take the member-wise expiry min (fresh iff still beyond
    ``now``), else Alg.-1 window seeding on the seed-count argmax
    server.  The whole (n+1)-row state is rebuilt, which also clears
    any scatter garbage accumulated on the dump row.
    """
    E_old = carry["E"]
    a_old = carry["anchor"]
    of_old = carry["of"]
    cnt_old = carry["cnt"]
    one = jnp.ones(n, jnp.float64)
    cnt_new = jnp.zeros(n + 1, jnp.float64).at[of_new].add(one)
    slot_valid = cnt_new > 0.0
    mn = jax.ops.segment_min(of_old, of_new, num_segments=n + 1)
    mx = jax.ops.segment_max(of_old, of_new, num_segments=n + 1)
    cand = jnp.clip(mn, 0, n)
    matched = slot_valid & (mn == mx) & (cnt_old[cand] == cnt_new)
    item_E = E_old[of_old]                           # (n, m)
    min_E = jax.ops.segment_min(item_E, of_new, num_segments=n + 1)
    fresh = jnp.where(slot_valid[:, None] & (min_E > now), min_E, 0.0)
    row_max = fresh.max(axis=1)
    anew = jnp.where(
        row_max > 0.0, jnp.argmax(fresh, axis=1).astype(jnp.int32), -1)
    if seed_new:
        ssum = jax.ops.segment_sum(
            carry["seed"][:n], of_new, num_segments=n + 1)
        js = jnp.argmax(ssum, axis=1).astype(jnp.int32)
        need = (slot_valid & ~matched & (row_max <= 0.0)
                & (cnt_new > 1.0))
        col = jax.lax.broadcasted_iota(jnp.int32, fresh.shape, 1)
        fresh = jnp.where(
            need[:, None] & (col == js[:, None]),
            now + dt[js][:, None], fresh)
        anew = jnp.where(need, js, anew)
    E_new = jnp.where(matched[:, None], E_old[cand], fresh)
    a_new = jnp.where(matched, a_old[cand], anew)
    return E_new, a_new, cnt_new


def _cgm_boundary(carry, now, cspec, dt, item_sizes, *, n, m, uses_sizes,
                  enable_split, enable_acm, seed_new, use_kernels):
    """One T_CG boundary, fully on device: Alg. 2 -> 4 -> 3 -> install.

    Mirrors ``AKPCPolicy.on_window`` + ``generate_cliques`` + the
    engine's ``install_partition``, then resets the window counters and
    rolls the binary CRM into the prev-CRM carry slots.
    """
    hot, raw, norm, binary = _window_crm_device(carry, cspec, n=n)
    W = norm.astype(jnp.float64)

    # -- Alg. 4 edge diff vs the previous window (u < v, row-major =
    # the lexicographic order the host oracle iterates its edges in)
    pbin = carry["pbin"]
    triu = jnp.triu(jnp.ones((n, n), bool), k=1)
    remM = pbin & ~binary & triu
    addM = binary & ~pbin & triu
    ecap = max(1, n * (n - 1) // 2)
    rem_u, rem_v = jnp.nonzero(remM, size=ecap, fill_value=0)
    add_u, add_v = jnp.nonzero(addM, size=ecap, fill_value=0)
    n_rem = remM.sum()
    n_add = addM.sum()

    of = carry["of"]
    gsize = carry["cnt"][:n].astype(jnp.int32)
    of, gsize = _adjust_partition(
        of, gsize, binary, W,
        add_u.astype(jnp.int32), add_v.astype(jnp.int32), n_add,
        rem_u.astype(jnp.int32), rem_v.astype(jnp.int32), n_rem,
        cspec, n=n)
    if enable_split:
        of = _split_oversized(of, gsize, W, cspec, n=n)
    if enable_acm:
        of = _approx_merge(
            of, binary, hot, W, cspec, n=n, use_kernels=use_kernels)

    E_new, a_new, cnt_new = _install_partition_device(
        carry, of, now, dt, n=n, seed_new=seed_new)
    out = dict(
        carry, E=E_new, anchor=a_new, of=of, cnt=cnt_new,
        crm=jnp.zeros((n, n), jnp.float32),
        wcnt=jnp.zeros(n + 1, jnp.int32),
        seed=jnp.zeros((n + 1, m), jnp.int32),
        pbin=binary, praw=raw, pnorm=norm, phot=hot,
    )
    if uses_sizes:
        out["vol"] = jnp.zeros(n + 1, jnp.float64).at[of].add(item_sizes)
    return out


# ---------------------------------------------------------------------------
# device: in-scan event construction + the Alg. 5/6 cost step
# ---------------------------------------------------------------------------
def _event_step(carry, x, spec, *, kind, charge, uses_sizes, item_sizes,
                n, m):
    """``batch_events`` + the const-dt replay step, derived in-scan.

    The host dedups (request, clique) keys with ``np.unique`` — sorted
    key order.  Here every (B*d) item slot maps to key ``r*(n+1)+cl``
    (invalid slots -> clique n), a stable argsort groups them, and
    segment sums produce the per-event counts; the event list is the
    host's, interleaved with inert val=False groups (invalid slots and
    request padding) whose writes land on the dump row/col.  The cost
    arithmetic below is copied expression-for-expression from
    ``engine_jax._replay_impl`` (const-dt branch), so the E/anchor
    trajectory stays float-for-float identical and cost sums differ
    only by in-batch summation order (the 1e-9 bar).
    """
    E, anchor, acc = carry["E"], carry["anchor"], carry["acc"]
    of, cnt = carry["of"], carry["cnt"]
    K = n
    items = x["items"]                               # (B, d)
    B, d = items.shape
    NE = B * d
    valid = (items >= 0).reshape(NE)
    item = jnp.clip(items, 0, n - 1).reshape(NE)
    r = jax.lax.broadcasted_iota(jnp.int32, (B, d), 0).reshape(NE)
    cl = jnp.where(valid, of[item], K)
    key = r * (K + 1) + cl
    o = jnp.argsort(key)                             # stable
    sk = key[o]
    first = jnp.concatenate([jnp.ones(1, bool), sk[1:] != sk[:-1]])
    seg = (jnp.cumsum(first.astype(jnp.int32)) - 1).astype(jnp.int32)
    vmask = valid[o]
    n_req_s = jax.ops.segment_sum(
        jnp.where(vmask, 1.0, 0.0), seg, num_segments=NE,
        indices_are_sorted=True)
    if uses_sizes:
        isz = item_sizes[item][o]
        req_size_s = jax.ops.segment_sum(
            jnp.where(vmask, isz, 0.0), seg, num_segments=NE,
            indices_are_sorted=True)
    # compact the unique keys into the event axis; unused tail entries
    # get an inert pad key (last request, dump clique)
    pad_key = (B - 1) * (K + 1) + K
    dst = jnp.where(first, seg, NE)
    ev_key = jnp.full(NE + 1, pad_key, key.dtype).at[dst].set(sk)[:NE]
    ev_r = ev_key // (K + 1)
    ev_c = (ev_key % (K + 1)).astype(jnp.int32)
    ev_j = x["servers"][ev_r]
    ev_t = x["times"][ev_r]
    val = ev_c < K
    n_req = n_req_s
    size = cnt[ev_c]
    if uses_sizes:
        csize = carry["vol"][ev_c]
        req_size = req_size_s
    else:
        csize = size
        req_size = n_req

    # (c, j) view: stable sort keeps ascending request order in-group,
    # exactly the host's o_cj
    key_cj = ev_c * m + ev_j
    o_cj = jnp.argsort(key_cj)
    kcs = key_cj[o_cj]
    first_cj_s = jnp.concatenate([jnp.ones(1, bool), kcs[1:] != kcs[:-1]])
    last_cj_s = jnp.concatenate([kcs[1:] != kcs[:-1], jnp.ones(1, bool)])
    t_cj_s = ev_t[o_cj]
    prev_t_s = jnp.where(
        first_cj_s, 0.0,
        jnp.concatenate([jnp.zeros(1, jnp.float64), t_cj_s[:-1]]))
    first_cj = jnp.zeros(NE, bool).at[o_cj].set(first_cj_s)
    prev_cj_t = jnp.zeros(NE, jnp.float64).at[o_cj].set(prev_t_s)

    # per-clique view (o_c): previous server within the clique group
    o_c = jnp.argsort(ev_c)
    cs = ev_c[o_c]
    first_c_s = jnp.concatenate([jnp.ones(1, bool), cs[1:] != cs[:-1]])
    last_c_s = jnp.concatenate([cs[1:] != cs[:-1], jnp.ones(1, bool)])
    j_c_s = ev_j[o_c]
    prev_j_s = jnp.where(
        first_c_s, -1,
        jnp.concatenate([jnp.full(1, -1, jnp.int32), j_c_s[:-1]]))
    first_c = jnp.zeros(NE, bool).at[o_c].set(first_c_s)
    prev_j = jnp.full(NE, -1, jnp.int32).at[o_c].set(prev_j_s)

    # ---- the replay cost step (engine_jax._replay_impl, const dt) ----
    j, t = ev_j, ev_t
    dt = spec["dt"]
    dt_e = dt[0]
    E_before = jnp.where(first_cj, E[ev_c, j], prev_cj_t + dt_e)
    dep = 0.0 * E_before[0]
    a0 = anchor[ev_c]
    anchor_alive = jnp.where(
        first_c, (a0 == j) & (E_before > 0.0), prev_j == j)
    fresh = E_before > t
    alive = fresh | anchor_alive
    miss = (~alive) & val
    lapsed = alive & (~fresh) & val
    steps = jnp.ceil((t - E_before) / dt_e)
    rr = E_before + steps * dt_e
    rr = jnp.where(rr <= t, rr + dt_e, rr)
    e_eff = jnp.where(fresh, E_before, jnp.where(lapsed, rr, t))
    rate_stored = _rate_hook(kind, spec, size, csize, j)
    rent = jnp.where(lapsed, rate_stored * (e_eff - E_before), 0.0)
    tc = jnp.where(
        miss, _transfer_hook(kind, spec, size, csize, j), 0.0)
    if charge == "requested":
        rate = _rate_hook(kind, spec, n_req, req_size, j)
    else:
        rate = rate_stored
    dur = jnp.maximum((t + dt_e) - jnp.maximum(e_eff, t), 0.0)
    cc = jnp.where(val, rate * dur, 0.0)
    nm = miss.sum()
    acc = acc + jnp.stack([
        tc.sum(), cc.sum(), rent.sum(),
        nm.astype(acc.dtype), (val.sum() - nm).astype(acc.dtype),
        jnp.where(miss, size, 0.0).sum(),
    ])

    # ---- state update on segment-last events (non-lasts -> dump) ----
    uc = jnp.where(last_cj_s, (kcs // m).astype(jnp.int32), K)
    uj = jnp.where(last_cj_s, (kcs % m).astype(jnp.int32), 0)
    E = E.at[uc, uj].set(t_cj_s + dt[0] + dep)
    ac = jnp.where(last_c_s, cs, K)
    a_cur = anchor[ac]
    aE = E[ac, jnp.maximum(a_cur, 0)]                # POST-update E
    t_c_s = ev_t[o_c]
    upd = (a_cur < 0) | (t_c_s + dt[0] >= aE)
    anchor = anchor.at[jnp.where(upd, ac, K)].set(j_c_s)
    return dict(carry, E=E, anchor=anchor, acc=acc)


# ---------------------------------------------------------------------------
# the scan: boundary cond -> window accumulate -> events/costs
# ---------------------------------------------------------------------------
#: times the fused CGM scan body has been TRACED — the device-CGM
#: mirror of ``engine_jax.SCAN_TRACES`` (fresh compiles per new input
#: structure); the live serving engine asserts chunk streams reuse ONE
#: compiled scan (tests/test_serving_live.py)
SCAN_TRACES = 0


def _cgm_replay_impl(spec, cspec, init, xs, item_sizes, *, kind, charge,
                     uses_sizes, enable_split, enable_acm, seed_new,
                     use_kernels):
    global SCAN_TRACES
    SCAN_TRACES += 1
    n = init["of"].shape[0]
    m = init["E"].shape[1]
    dt = spec["dt"]

    def step(carry, x):
        # the boundary fires BEFORE this batch's requests: the step that
        # starts a new T_CG period evaluates the window accumulated by
        # the preceding steps (``x["cg"]`` comes from the shared xs, so
        # under vmap the predicate stays unbatched and cond stays cond)
        carry = jax.lax.cond(
            x["cg"],
            lambda c: _cgm_boundary(
                c, x["now"], cspec, dt, item_sizes, n=n, m=m,
                uses_sizes=uses_sizes, enable_split=enable_split,
                enable_acm=enable_acm, seed_new=seed_new,
                use_kernels=use_kernels),
            lambda c: c,
            carry)
        carry = _accumulate_window(
            carry, x, n=n, m=m, use_kernels=use_kernels)
        carry = _event_step(
            carry, x, spec, kind=kind, charge=charge,
            uses_sizes=uses_sizes, item_sizes=item_sizes, n=n, m=m)
        return carry, carry["of"]

    return jax.lax.scan(step, init, xs)


if HAS_JAX:
    @functools.lru_cache(maxsize=64)
    def _compiled_cgm_replay(kind, charge, uses_sizes, enable_split,
                             enable_acm, seed_new, use_kernels, vmapped):
        f = functools.partial(
            _cgm_replay_impl, kind=kind, charge=charge,
            uses_sizes=uses_sizes, enable_split=enable_split,
            enable_acm=enable_acm, seed_new=seed_new,
            use_kernels=use_kernels)
        if vmapped:
            # scenarios vmap over spec / cgm spec / carry; the schedule
            # tensors and item sizes are shared unbatched
            f = jax.vmap(f, in_axes=(0, 0, 0, None, None))
        return jax.jit(f)


# ---------------------------------------------------------------------------
# host seam: carry init, execution, state/policy sync
# ---------------------------------------------------------------------------
def init_cgm_carry(state, prev_crm, win_prefix, *, n, m, uses_sizes,
                   item_sizes, layout=None):
    """Numpy engine/policy state -> the device scan carry (one lane).

    The fused scan's hot-space embed and install reductions are sized by
    the carry shapes themselves (``of``: n slots, ``E``: (n+1, m)), so
    only a StateLayout that is dense-equivalent at (n, m) may back the
    carry — callers route bucketed/sharded catalogs to the generic
    schedule path (`JaxReplayEngine.replay`, `SweepEngine._run_jax`).
    """
    from .engine_jax import N_ACC, state_to_device
    from .state_layout import StateLayout

    lay = StateLayout.resolve(layout)
    if not lay.is_dense_for(n, m):
        raise ValueError(
            f"device CGM needs a dense-equivalent state layout at "
            f"(n={n}, m={m}); {lay.kind!r} gives {lay.state_dims(n, m)} — "
            "use the generic schedule path for this catalog")
    E0, a0 = state_to_device(state, n)
    of0 = np.asarray(state.partition.clique_of, np.int32)
    carry = {
        "E": E0,
        "anchor": a0,
        "acc": np.zeros(N_ACC, np.float64),
        "of": of0,
        "cnt": np.bincount(of0, minlength=n + 1).astype(np.float64),
        "crm": np.zeros((n, n), np.float32),
        "wcnt": np.zeros(n + 1, np.int32),
        "seed": np.zeros((n + 1, m), np.int32),
        "pbin": np.zeros((n, n), bool),
        "praw": np.zeros((n, n), np.float32),
        "pnorm": np.zeros((n, n), np.float32),
        "phot": np.zeros(n, bool),
    }
    if uses_sizes:
        vol = np.zeros(n + 1, np.float64)
        np.add.at(vol, of0, np.asarray(item_sizes, np.float64))
        carry["vol"] = vol
    if prev_crm is not None and prev_crm.hot_items.size:
        hot, raw, norm, binary = prev_crm.embed(n)
        carry["phot"], carry["praw"] = hot, raw
        carry["pnorm"], carry["pbin"] = norm, binary
    if win_prefix is not None:
        p_it, p_sv = win_prefix
        p_it = np.atleast_2d(np.asarray(p_it))
        if p_it.shape[0]:
            # the open window's already-fed requests (session feed):
            # deduped co-occurrence, duplicate-counting item/seed tallies
            carry["crm"] = cooccurrence_counts(p_it, n).astype(np.float32)
            flat = p_it.reshape(-1)
            carry["wcnt"] = np.bincount(
                np.where(flat >= 0, flat, n), minlength=n + 1,
            ).astype(np.int32)
            seed = np.zeros((n + 1, m), np.int64)
            sv = np.repeat(np.asarray(p_sv, np.int64), p_it.shape[1])
            ok = flat >= 0
            np.add.at(seed, (flat[ok], sv[ok]), 1)
            carry["seed"] = seed.astype(np.int32)
    return carry


def run_cgm_schedule(schedule, spec, statics, cspec, carry0, item_sizes, *,
                     charge="requested", enable_split=True, enable_acm=True,
                     seed_new=True, use_kernels=None, block=True):
    """Execute one CGM schedule; returns (final_carry, per-step slot maps).

    ``spec``/``cspec``/``carry0`` may carry a leading scenario axis (the
    fig7 grid); the schedule and item sizes stay shared unbatched.
    """
    _require_jax()
    if use_kernels is None:
        from ..kernels.autowire import default_cgm_hooks

        use_kernels = default_cgm_hooks()[0] is not None
    vmapped = carry0["E"].ndim == 3
    fn = _compiled_cgm_replay(
        statics, charge, "vol" in carry0, bool(enable_split),
        bool(enable_acm), bool(seed_new), bool(use_kernels), vmapped)
    with enable_x64():
        spec_j = {k: jnp.asarray(v) for k, v in spec.items()}
        cspec_j = {k: jnp.asarray(v) for k, v in cspec.items()}
        init_j = {k: jnp.asarray(v) for k, v in carry0.items()}
        xs_j = {k: jnp.asarray(v) for k, v in schedule.xs.items()}
        sz_j = (
            jnp.asarray(item_sizes, jnp.float64)
            if item_sizes is not None
            else jnp.ones(schedule.n, jnp.float64))
        final, ofs = fn(spec_j, cspec_j, init_j, xs_j, sz_j)
        if not block:
            return final, ofs
        return {k: np.asarray(v) for k, v in final.items()}, np.asarray(ofs)


def partition_from_of(n: int, of: np.ndarray) -> CliquePartition:
    """Dense device slot map -> host partition; slot order IS group order,
    so ``result.clique_of == of`` element for element."""
    of = np.asarray(of)
    k = int(of.max()) + 1 if of.size else 0
    groups = [tuple(np.nonzero(of == g)[0].tolist()) for g in range(k)]
    return CliquePartition.from_cliques(n, groups)


def sync_policy_from_run(policy, schedule, ofs, final, part) -> None:
    """Fold the device run's window bookkeeping back into the policy, as
    if ``on_window`` had run per boundary on the host."""
    nbd = int(schedule.boundary_steps.size)
    if nbd == 0:
        return
    for b in schedule.boundary_steps:
        sizes = np.bincount(np.asarray(ofs[int(b)])).astype(np.int64)
        policy.size_history.append(sizes[sizes > 1])
    policy.n_windows += nbd
    policy._partition = part
    policy._prev_crm = WindowCRM.from_full(
        final["phot"], final["praw"], final["pnorm"], final["pbin"])


def replay_cgm(jeng, policy, trace, *, t_cg, batch_size=None, next_cg0=None,
               win_prefix=None, progress=None):
    """Device-resident AKPC replay: one host->device transfer, zero host
    clique-generation calls.  Drop-in for ``JaxReplayEngine.replay`` when
    ``wants_device_cgm`` approves the (policy, model, trace) triple."""
    eng = jeng.engine
    uses_sizes = bool(eng.model.uses_sizes)
    item_sizes = eng.env.sizes() if uses_sizes else None
    schedule = build_cgm_schedule(
        trace, t_cg, uses_sizes=uses_sizes, batch_size=batch_size,
        next_cg0=next_cg0)
    jeng.last_schedule = schedule
    cfg = policy.config
    cspec = cgm_spec(cfg, cfg.params, trace.n)
    carry0 = init_cgm_carry(
        eng.state, getattr(policy, "_prev_crm", None), win_prefix,
        n=trace.n, m=trace.m, uses_sizes=uses_sizes, item_sizes=item_sizes)
    final, ofs = run_cgm_schedule(
        schedule, jeng._spec, jeng._statics, cspec, carry0, item_sizes,
        charge=eng.caching_charge,
        enable_split=cfg.enable_split,
        enable_acm=cfg.enable_approx_merge,
        seed_new=eng.seed_new_cliques)
    if progress is not None:
        progress(trace.n_requests)
    nbd = int(schedule.boundary_steps.size)
    part = (eng.state.partition if nbd == 0
            else partition_from_of(trace.n, final["of"]))
    eng.state = CacheState(
        partition=part, E=final["E"][: part.k].copy(),
        anchor=final["anchor"][: part.k].copy(), m=eng.m)
    eng._set_partition_caches(part)
    from .engine_jax import apply_acc

    apply_acc(eng.costs, schedule, final["acc"])
    sync_policy_from_run(policy, schedule, ofs, final, part)
    return eng.costs


def wants_device_cgm(policy, trace, model) -> bool:
    """Eligibility gate for the device-resident CGM path.

    ``REPRO_JAX_CGM`` = ``force`` / ``off`` / ``auto`` (default).  Auto
    requires an unmodified AKPC-family policy (the on-device merge/split
    mirrors ``AKPCPolicy.on_window`` exactly), a uniform keepalive dt,
    no custom CRM hooks, and a catalog small enough that the n^2 carry
    is cheap and the f32 co-occurrence counters stay exact integers.
    """
    mode = os.environ.get("REPRO_JAX_CGM", "auto").strip().lower()
    if mode in ("off", "0"):
        return False
    if not HAS_JAX:
        return False
    from .akpc import AKPCConfig
    from .policy import AKPCPolicy

    cfg = getattr(policy, "config", None)
    if not isinstance(cfg, AKPCConfig):
        return False
    if not isinstance(policy, AKPCPolicy) \
            or type(policy).on_window is not AKPCPolicy.on_window:
        return False
    if getattr(policy, "t_cg", None) is None:
        return False
    if cfg.crm_matmul is not None or cfg.pair_edges is not None:
        return False
    dt = np.asarray(model.dt(), np.float64)
    if dt.size and not (dt == dt[0]).all():
        return False
    if mode in ("force", "1"):
        return True
    return (trace.n <= MAX_DEVICE_CGM_N
            and trace.n_requests * max(1, trace.d_max) < _F32_EXACT)
