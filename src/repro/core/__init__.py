"""AKPC core: the paper's contribution (Algorithms 1-6, Theorems 1-2)."""
from .akpc import AKPC, AKPCConfig, AKPCResult, run_akpc, run_akpc_variant
from .baselines import (
    greedy_pair_matching,
    opt_lower_bound,
    run_dp_greedy,
    run_no_packing,
    run_packcache2,
)
from .cliques import CliquePartition, generate_cliques
from .competitive import adversarial_trace, per_request_ratio_check, replay_adversary
from .cost import CostBreakdown, CostParams, competitive_bound, competitive_bound_corrected
from .crm import WindowCRM, build_window_crm
from .engine import DEFAULT_BATCH_SIZE, BatchOutcome, CacheState, ReplayEngine

__all__ = [
    "AKPC",
    "AKPCConfig",
    "AKPCResult",
    "BatchOutcome",
    "CacheState",
    "DEFAULT_BATCH_SIZE",
    "CliquePartition",
    "CostBreakdown",
    "CostParams",
    "ReplayEngine",
    "WindowCRM",
    "adversarial_trace",
    "build_window_crm",
    "competitive_bound",
    "competitive_bound_corrected",
    "generate_cliques",
    "greedy_pair_matching",
    "opt_lower_bound",
    "per_request_ratio_check",
    "replay_adversary",
    "run_akpc",
    "run_akpc_variant",
    "run_dp_greedy",
    "run_no_packing",
    "run_packcache2",
]
