"""AKPC core: the paper's contribution (Algorithms 1-6, Theorems 1-2).

Public surface (PR 2 API redesign):

* policy layer — ``CachePolicy`` protocol, ``get_policy``/``list_policies``
  registry, unified ``RunResult``, offline ``run_policy`` driver;
* streaming  — ``CacheSession`` (online replay, mid-stream costs, snapshots);
* legacy shims — ``run_akpc`` / ``run_packcache2`` / ``run_dp_greedy`` /
  ``run_no_packing`` (thin wrappers over the registry, batch API).
"""
from .akpc import AKPCConfig, AKPCResult, run_akpc, run_akpc_variant
from .baselines import (
    greedy_pair_matching,
    opt_lower_bound,
    run_dp_greedy,
    run_no_packing,
    run_packcache2,
)
from .cliques import CliquePartition, generate_cliques
from .competitive import (
    adversarial_trace,
    generalized_bound,
    generalized_per_request_ratio_check,
    per_request_ratio_check,
    replay_adversary,
)
from .cost import (
    CacheEnvironment,
    CostBreakdown,
    CostModel,
    CostParams,
    HeterogeneousCostModel,
    Table1CostModel,
    TieredCostModel,
    competitive_bound,
    competitive_bound_corrected,
    competitive_bound_env,
    get_cost_model,
    list_cost_models,
    register_cost_model,
)
from .crm import WindowCRM, build_window_crm
from .engine import (
    DEFAULT_BATCH_SIZE,
    BatchEvents,
    BatchOutcome,
    CacheState,
    ReplayEngine,
    batch_events,
    match_partitions,
)
from .engine_jax import JAX_COST_MODELS, JaxReplayEngine, run_policy_jax
from .policy import (
    AKPCPolicy,
    BasePolicy,
    CachePolicy,
    DPGreedyPolicy,
    NoPackingPolicy,
    PackCache2Policy,
    RunResult,
    get_policy,
    list_policies,
    register_policy,
    run_policy,
)
from .session import CacheSession, load_snapshot
from .sweep import SweepEngine, SweepPoint, sweep_points

__all__ = [
    "AKPCConfig",
    "AKPCPolicy",
    "AKPCResult",
    "BasePolicy",
    "BatchOutcome",
    "CacheEnvironment",
    "CachePolicy",
    "CacheSession",
    "CacheState",
    "CliquePartition",
    "CostBreakdown",
    "CostModel",
    "CostParams",
    "DEFAULT_BATCH_SIZE",
    "BatchEvents",
    "HeterogeneousCostModel",
    "JAX_COST_MODELS",
    "JaxReplayEngine",
    "Table1CostModel",
    "TieredCostModel",
    "DPGreedyPolicy",
    "NoPackingPolicy",
    "PackCache2Policy",
    "ReplayEngine",
    "RunResult",
    "SweepEngine",
    "SweepPoint",
    "WindowCRM",
    "batch_events",
    "match_partitions",
    "run_policy_jax",
    "sweep_points",
    "adversarial_trace",
    "build_window_crm",
    "competitive_bound",
    "competitive_bound_corrected",
    "competitive_bound_env",
    "generalized_bound",
    "generalized_per_request_ratio_check",
    "generate_cliques",
    "get_cost_model",
    "get_policy",
    "greedy_pair_matching",
    "list_cost_models",
    "list_policies",
    "load_snapshot",
    "opt_lower_bound",
    "per_request_ratio_check",
    "register_cost_model",
    "register_policy",
    "replay_adversary",
    "run_akpc",
    "run_akpc_variant",
    "run_dp_greedy",
    "run_no_packing",
    "run_packcache2",
    "run_policy",
]
