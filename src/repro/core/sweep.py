"""Vmapped multi-scenario sweep engine over the JAX replay backend.

The paper's headline results (Figs. 5-10) are cost curves swept over
hyperparameter x cost-model x trace grids; PR 1-4 replayed every grid
point serially.  :class:`SweepEngine` makes the SCENARIO the batch axis:

1. every grid point is a :class:`SweepPoint` (policy + trace + pricing
   scenario);
2. points that share (trace, clique-generation hyperparameters, batch
   size) share ONE host-built :class:`~repro.core.engine_jax.ReplaySchedule`
   — an alpha sweep runs clique generation once, not once per alpha,
   because the partition trajectory is a pure function of the trace and
   the CGM knobs (never of prices or cache state, DESIGN.md §10);
3. scenarios sharing a schedule are stacked along a leading axis (cost
   spec + initial state) and replayed by ONE ``jax.vmap``'d call of the
   compiled scan, with the schedule's event tensors shared UNBATCHED
   across the lanes (``in_axes=None`` — no per-scenario copies);
4. each point comes back as the same :class:`~repro.core.policy.RunResult`
   the serial ``run_policy`` driver returns, cost-for-cost at 1e-9
   (tests/test_sweep.py).

``backend="numpy"`` degrades to the serial per-point loop (the honest
baseline ``benchmarks/sweep_bench.py`` times against, and the fallback
for cost models the JAX backend cannot express).  ``mesh=`` optionally
shards the scenario axis of each stacked group over a device mesh
(``repro.launch.mesh.make_sweep_mesh``) — a no-op on single-device hosts.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Any, Callable, Sequence

import numpy as np

from .cost import CacheEnvironment, get_cost_model
from .policy import RunResult, get_policy, run_policy
from .state_layout import StateLayout

#: registry policies whose clique-generation trajectory is fully determined
#: by (trace, t_cg, top_frac, top_frac_of, theta, gamma, omega, split/merge
#: flags) — the key under which SweepEngine shares schedules.  Unknown /
#: custom policies always get a private schedule.
SHAREABLE_POLICIES = (
    "no_packing", "packcache", "dp_greedy",
    "akpc", "akpc_no_acm", "akpc_base",
)


@dataclasses.dataclass
class SweepPoint:
    """One grid point: a registered policy replayed over one scenario.

    ``policy_kwargs`` are passed to :func:`~repro.core.policy.get_policy`
    verbatim (``params``, ``t_cg``, ``top_frac``, ``env``, ``cost_model``,
    ...); ``tag`` is an arbitrary caller label carried through to the
    result order (results come back in input order regardless).

    ``trace`` may also be a SEQUENCE of traces — the trace-shard axis:
    shards of one long trace, or per-seed replicas of one workload.  The
    point then replays every shard as an extra vmap lane of the same
    device call (schedules stacked batched, ``engine_jax.run_schedules``)
    and comes back as ONE :class:`~repro.core.policy.RunResult` with the
    per-shard :class:`~repro.core.cost.CostBreakdown`s merged and
    ``shard_stats`` carrying the mean +- CI of the per-shard totals —
    dispersion estimates at near-zero marginal device cost.  All shards
    must share the catalog/server shape ``(n, m)``.
    """

    policy: str
    trace: Any
    policy_kwargs: dict = dataclasses.field(default_factory=dict)
    batch_size: int | None = None
    tag: str = ""


def _shards_of(trace) -> tuple | None:
    """The shard tuple of a sharded ``SweepPoint.trace`` (else None)."""
    if isinstance(trace, (list, tuple)):
        shards = tuple(trace)
        if not shards:
            raise ValueError("SweepPoint.trace sequence is empty")
        n, m = shards[0].n, shards[0].m
        for tr in shards[1:]:
            if tr.n != n or tr.m != m:
                raise ValueError(
                    "trace shards must share the catalog/server shape "
                    f"(n, m): got ({n}, {m}) vs ({tr.n}, {tr.m})")
        return shards
    return None


def _shard_stats(totals: list) -> dict:
    """mean +- 95% CI (normal approx) of the per-shard total costs."""
    a = np.asarray(totals, np.float64)
    std = float(a.std(ddof=1)) if a.size > 1 else 0.0
    return {
        "n": int(a.size),
        "totals": [float(t) for t in totals],
        "mean": float(a.mean()),
        "std": std,
        "ci95": 1.96 * std / float(np.sqrt(a.size)),
    }


def _merge_shard_results(subs: list) -> RunResult:
    """Fold per-shard RunResults into one (the numpy-backend shard path)."""
    merged = dataclasses.replace(subs[0].costs)
    for r in subs[1:]:
        merged.merge(r.costs)
    return dataclasses.replace(
        subs[0], costs=merged,
        cg_seconds=sum(r.cg_seconds for r in subs),
        wall_seconds=sum(r.wall_seconds for r in subs),
        shard_stats=_shard_stats([r.costs.total for r in subs]))


#: across-run cohort shape ratchet: the largest padded dims this process
#: has seen per (n, m, dt-mode, uses-sizes) cohort.  Padding every later
#: schedule of the same cohort up to these dims makes the compiled scan's
#: shapes REPEAT across ``SweepEngine.run`` calls — the jit cache (and the
#: persistent compile cache) hit instead of re-tracing each slightly
#: different grid.  Padded steps/slots are inert, so ratcheting up is
#: semantics-free; a retrace costs ~1s, the extra padding microseconds.
_COHORT_DIMS: dict[tuple, dict] = {}


def _cgm_key(policy) -> tuple:
    """The clique-generation-relevant knobs of a registry policy."""
    p = policy.params
    cfg = getattr(policy, "config", None)
    if cfg is not None:                     # AKPCPolicy variants
        return (cfg.t_cg, cfg.top_frac, cfg.top_frac_of, cfg.enable_split,
                cfg.enable_approx_merge, cfg.params.theta, cfg.params.gamma,
                cfg.params.omega)
    user_part = getattr(policy, "_user_partition", None)
    return (policy.t_cg, getattr(policy, "top_frac", None),
            getattr(policy, "top_frac_of", None), p.theta,
            None if user_part is None else id(user_part))


class SweepEngine:
    """Replay a grid of scenarios with one vmapped device call per group."""

    def __init__(
        self,
        backend: str = "jax",
        batch_size: int | None = None,
        mesh=None,
        layout: StateLayout | str | None = None,
    ):
        if backend not in ("jax", "numpy"):
            raise ValueError(f"unknown sweep backend {backend!r}")
        if backend == "jax":
            from . import engine_jax

            if not engine_jax.HAS_JAX:
                raise ImportError(
                    "SweepEngine(backend='jax') needs jax; use "
                    "backend='numpy'")
            engine_jax.enable_compile_cache()
        self.backend = backend
        self.batch_size = batch_size
        self.mesh = mesh
        layout = StateLayout.resolve(layout)
        if (layout.kind == "row_sharded" and layout.mesh is None
                and mesh is not None
                and layout.row_axis in mesh.axis_names):
            # a bare row_sharded layout adopts the engine's mesh (the
            # make_sweep_mesh(..., state_rows=) two-axis form)
            layout = dataclasses.replace(layout, mesh=mesh)
        self.layout = layout
        #: wall seconds of the most recent :meth:`run` (schedules + device)
        self.last_wall = 0.0
        #: schedule-dedup stats of the most recent run
        self.last_n_schedules = 0

    # ------------------------------------------------------------------
    def run(
        self,
        points: Sequence[SweepPoint],
        progress: Callable[[str], None] | None = None,
    ) -> list[RunResult]:
        t0 = _time.perf_counter()
        if self.backend == "numpy":
            out = [self._run_numpy(pt) for pt in points]
            self.last_wall = _time.perf_counter() - t0
            self.last_n_schedules = len(points)
            return out
        out = self._run_jax(points, progress)
        self.last_wall = _time.perf_counter() - t0
        return out

    def _run_numpy(self, pt: SweepPoint) -> RunResult:
        shards = _shards_of(pt.trace)
        if shards is not None:
            return _merge_shard_results([
                run_policy(
                    get_policy(pt.policy, **pt.policy_kwargs), tr,
                    batch_size=pt.batch_size or self.batch_size)
                for tr in shards])
        return run_policy(
            get_policy(pt.policy, **pt.policy_kwargs), pt.trace,
            batch_size=pt.batch_size or self.batch_size)

    # ------------------------------------------------------------------
    def _run_jax(self, points, progress) -> list[RunResult]:
        from . import engine_jax as ej
        from .cliques import CliquePartition
        from .cost import CostBreakdown

        # -- prepare points + share keys (no schedule builds yet) -----------
        prepared = []
        for pt in points:
            shards = _shards_of(pt.trace)
            tr0 = shards[0] if shards is not None else pt.trace
            policy = get_policy(pt.policy, **pt.policy_kwargs)
            policy.bind(tr0.n, tr0.m)
            env = CacheEnvironment.resolve(
                getattr(policy, "env", None), tr0, policy.params)
            model = get_cost_model(
                getattr(policy, "cost_model", "table1"), env)
            spec, statics = ej.cost_spec(model, env)
            dt = spec["dt"]
            const_dt = env.m == 0 or bool((dt == dt[0]).all())
            ncol = self.layout.state_cols(env.m)
            if ncol != env.m:
                # bucketed columns: pad the per-server spec arrays so
                # every point of one column bucket shares a compiled shape
                spec = ej.pad_spec_cols(spec, ncol)
            bs = pt.batch_size or self.batch_size
            seed = getattr(policy, "seed_new_cliques", True)
            sizes_fp = (None if not model.uses_sizes
                        else (id(env.item_sizes)
                              if env.item_sizes is not None else "unit"))
            if pt.policy in SHAREABLE_POLICIES:
                tid = (tuple(id(tr) for tr in shards)
                       if shards is not None else id(pt.trace))
                skey = (tid, pt.policy, _cgm_key(policy), bs,
                        const_dt, model.uses_sizes, sizes_fp, seed)
            else:
                skey = object()          # never shared
            prepared.append({
                "pt": pt, "policy": policy, "spec": spec,
                "statics": statics, "skey": skey, "sizes_fp": sizes_fp,
                "model": model, "env": env, "bs": bs, "seed": seed,
                "shards": shards,
                "charge": getattr(policy, "caching_charge", "requested"),
            })

        # -- device-CGM super-groups (DESIGN.md §11): AKPC points that
        # differ ONLY in CGM knobs (the fig7 theta/gamma/omega/top_frac
        # axes, plus any pricing axes) share ONE partition-free schedule
        # and vmap the clique generation itself — zero host CGM calls.
        # A group needs >= 2 distinct CGM keys to beat the host path
        # (with one key the host builds one shared schedule anyway).
        from . import cgm_jax

        dev_groups: dict = {}
        for i, pr in enumerate(prepared):
            pt, policy = pr["pt"], pr["policy"]
            cfg = getattr(policy, "config", None)
            if (pr["shards"] is not None
                    or pt.policy not in SHAREABLE_POLICIES or cfg is None
                    # the fused CGM carry is dense-n on its own, whatever
                    # the session layout — only row-sharded state (which
                    # splits the slot maps across devices) falls back
                    or not self.layout.supports_device_cgm(
                        pt.trace.n, pt.trace.m)
                    or not cgm_jax.wants_device_cgm(
                        policy, pt.trace, pr["model"])):
                continue
            dkey = (id(pt.trace), cfg.t_cg, pr["bs"], pr["statics"],
                    pr["charge"], pr["model"].uses_sizes, pr["sizes_fp"],
                    pr["seed"], cfg.enable_split, cfg.enable_approx_merge)
            dev_groups.setdefault(dkey, []).append(i)
        dev_groups = {
            k: v for k, v in dev_groups.items()
            if len({_cgm_key(prepared[i]["policy"]) for i in v}) >= 2
        }
        on_device = {i for v in dev_groups.values() for i in v}

        groups: dict = {}
        sh_groups: dict = {}
        for i, pr in enumerate(prepared):
            if i in on_device:
                continue
            dst = sh_groups if pr["shards"] is not None else groups
            dst.setdefault((pr["skey"], pr["statics"], pr["charge"]),
                           []).append(i)

        # -- build every distinct schedule on host --------------------------
        schedules: dict = {}
        for (skey, statics, charge), idxs in groups.items():
            g0 = prepared[idxs[0]]
            if skey in schedules:
                continue
            policy = g0["policy"]
            part0 = (policy.initial_partition(g0["pt"].trace)
                     if hasattr(policy, "initial_partition") else None)
            if part0 is None:
                part0 = CliquePartition.singletons(g0["pt"].trace.n)
            gen = policy.on_window if policy.t_cg is not None else None
            schedule = ej.build_schedule(
                part0, g0["pt"].trace, gen, policy.t_cg,
                model=g0["model"], env=g0["env"], batch_size=g0["bs"],
                seed_new_cliques=g0["seed"], layout=self.layout,
            )
            schedules[skey] = {
                "schedule": schedule,
                "n_windows": getattr(policy, "n_windows", 0),
                "cg_seconds": getattr(policy, "cg_seconds", 0.0),
                "size_history": list(getattr(policy, "size_history", [])),
                "clique_sizes": schedule.final_partition.sizes(),
            }
            if progress is not None:
                progress(f"schedule built: {g0['pt'].policy} "
                         f"({schedule.nb} steps x {schedule.ne} events)")

        # -- align schedule shapes so each (n, m, path) cohort compiles the
        # device scan exactly once, then dispatch every group WITHOUT
        # blocking (XLA chews in the background, results collected below)
        cohorts: dict = {}
        for rec in schedules.values():
            s = rec["schedule"]
            # cohorts key on the STATE geometry, not the raw (n, m): under
            # a bucketed layout, points whose shapes round to the same
            # bucket land in one cohort and share one compiled scan
            cohorts.setdefault(
                (s.state_rows, s.state_cols, s.const_dt, s.uses_sizes),
                []).append(rec)
        for ckey, recs in cohorts.items():
            dims_list = [ej.schedule_dims(r["schedule"]) for r in recs]
            dims = {k: max(d[k] for d in dims_list) for k in dims_list[0]}
            cached = _COHORT_DIMS.get(ckey)
            if cached is not None:
                dims = {k: max(dims[k], cached[k]) for k in dims}
            _COHORT_DIMS[ckey] = dims
            for r, d0 in zip(recs, dims_list):
                if d0 != dims:   # shared shapes: skip the pad entirely
                    r["schedule"] = ej.pad_schedule(r["schedule"], dims)

        # -- trace-shard groups: one schedule PER SHARD, stacked batched ----
        # lanes = scenarios x shards of one vmapped call (run_schedules);
        # per-shard costs are merged per scenario at collection time.
        sh_pending = []
        n_shard_schedules = 0
        for (skey, statics, charge), idxs in sh_groups.items():
            g0 = prepared[idxs[0]]
            policy = g0["policy"]
            shards = g0["shards"]
            gen = policy.on_window if policy.t_cg is not None else None
            recs = []
            for tr in shards:
                policy.bind(tr.n, tr.m)       # fresh CGM state per shard
                part0 = (policy.initial_partition(tr)
                         if hasattr(policy, "initial_partition") else None)
                if part0 is None:
                    part0 = CliquePartition.singletons(tr.n)
                schedule = ej.build_schedule(
                    part0, tr, gen, policy.t_cg,
                    model=g0["model"], env=g0["env"], batch_size=g0["bs"],
                    seed_new_cliques=g0["seed"], layout=self.layout)
                recs.append({
                    "schedule": schedule,
                    "n_windows": getattr(policy, "n_windows", 0),
                    "cg_seconds": getattr(policy, "cg_seconds", 0.0),
                    "size_history":
                        list(getattr(policy, "size_history", [])),
                    "clique_sizes": schedule.final_partition.sizes(),
                })
            n_shard_schedules += len(recs)
            s0 = recs[0]["schedule"]
            ckey = (s0.state_rows, s0.state_cols, s0.const_dt,
                    s0.uses_sizes, "xs")
            dims_list = [ej.schedule_dims(r["schedule"]) for r in recs]
            dims = {k: max(d[k] for d in dims_list) for k in dims_list[0]}
            cached = _COHORT_DIMS.get(ckey)
            if cached is not None:
                dims = {k: max(dims[k], cached[k]) for k in dims}
            _COHORT_DIMS[ckey] = dims
            for r, d0 in zip(recs, dims_list):
                if d0 != dims:
                    r["schedule"] = ej.pad_schedule(r["schedule"], dims)
            S_sh = len(recs)
            lanes = [recs[j]["schedule"]
                     for _ in idxs for j in range(S_sh)]
            spec = {
                k: np.stack([prepared[i]["spec"][k]
                             for i in idxs for _ in range(S_sh)])
                for k in g0["spec"]
            }
            L = len(lanes)
            E0 = np.zeros((L, s0.state_rows, s0.state_cols), np.float64)
            a0 = np.full((L, s0.state_rows), -1, np.int32)
            if self.mesh is not None:
                spec, E0, a0 = self._shard(spec, E0, a0, L)
            t0 = _time.perf_counter()
            _, _, acc = ej.run_schedules(
                lanes, spec, statics, E0, a0, charge=charge, block=False,
                layout=self.layout)
            sh_pending.append((idxs, recs, acc, t0))
            if progress is not None:
                progress(f"shard group of {len(idxs)} scenario(s) x "
                         f"{S_sh} shard(s) dispatched")

        # -- dispatch device-CGM groups first (non-blocking) ----------------
        dev_pending = []
        for idxs in dev_groups.values():
            g0 = prepared[idxs[0]]
            trace = g0["pt"].trace
            n, m_srv = trace.n, trace.m
            cfg0 = g0["policy"].config
            uses_sizes = bool(g0["model"].uses_sizes)
            item_sizes = g0["env"].sizes() if uses_sizes else None
            hot_dims = [cgm_jax.policy_hot_dims(prepared[i]["policy"])[0]
                        for i in idxs]
            sched = cgm_jax.build_cgm_schedule(
                trace, cfg0.t_cg, uses_sizes=uses_sizes,
                batch_size=g0["bs"], hot_dims=hot_dims)
            # compact-workspace cohort: repeated sweep calls over the same
            # catalog ratchet (nb, B, d, h, W) through _COHORT_DIMS so the
            # CGM scan compiles once per cohort, not once per call shape
            ckey_cgm = ("cgm", n, m_srv, sched.uses_sizes)
            dims = ej.schedule_dims(sched)
            cached = _COHORT_DIMS.get(ckey_cgm)
            if cached is not None:
                dims = {k: max(dims[k], cached[k]) for k in dims}
            _COHORT_DIMS[ckey_cgm] = dims
            sched = ej.pad_schedule(sched, dims)
            from .engine import CacheState

            carry1 = cgm_jax.init_cgm_carry(
                CacheState.fresh(CliquePartition.singletons(n), m_srv),
                None, None, n=n, m=m_srv, uses_sizes=uses_sizes,
                item_sizes=item_sizes, layout=self.layout, schedule=sched)
            S = len(idxs)
            spec = {
                k: np.stack([prepared[i]["spec"][k] for i in idxs])
                for k in g0["spec"]
            }
            cspecs = [
                cgm_jax.cgm_spec(prepared[i]["policy"].config,
                                 prepared[i]["policy"].config.params, n)
                for i in idxs
            ]
            cspec = {k: np.stack([np.asarray(c[k]) for c in cspecs])
                     for k in cspecs[0]}
            carry0 = {k: np.stack([v] * S) for k, v in carry1.items()}
            t0g = _time.perf_counter()
            final, ofs = cgm_jax.run_cgm_schedule(
                sched, spec, g0["statics"], cspec, carry0, item_sizes,
                charge=g0["charge"], enable_split=cfg0.enable_split,
                enable_acm=cfg0.enable_approx_merge, seed_new=g0["seed"],
                block=False)
            dev_pending.append((idxs, sched, final, ofs, t0g))
            if progress is not None:
                progress(f"device-CGM group of {S} scenario(s) dispatched "
                         f"({sched.nb} steps, {sched.boundary_steps.size} "
                         "windows on device)")

        # groups sharing (padded state geometry, statics, charge) stack as
        # lanes of ONE run_schedules call, so a mixed-shape sweep compiles
        # once per bucket COHORT — not once per (schedule, group-width)
        # combination.  Single-group cohorts keep the run_schedule path:
        # one shared schedule vmapped over S specs, no per-lane xs copies.
        cohort_groups: dict = {}
        for (skey, statics, charge), idxs in groups.items():
            s = schedules[skey]["schedule"]
            # the xs key SET is part of the compiled scan's signature
            # (e.g. TTL's "nokeep" mask): only schedules carrying the
            # same event tensors can share one lane-stacked call
            cohort_groups.setdefault(
                ((s.state_rows, s.state_cols, s.const_dt, s.uses_sizes),
                 frozenset(s.xs), statics, charge),
                []).append((skey, idxs))

        pending = []
        for (ckey, _xs_keys, statics, charge), members in \
                cohort_groups.items():
            g0 = prepared[members[0][1][0]]
            if len(members) == 1:
                skey, idxs = members[0]
                rec = schedules[skey]
                schedule = rec["schedule"]
                S = len(idxs)
                spec = {
                    k: np.stack([prepared[i]["spec"][k] for i in idxs])
                    for k in g0["spec"]
                }
                E0 = np.zeros(
                    (S, schedule.state_rows, schedule.state_cols),
                    np.float64)
                a0 = np.full((S, schedule.state_rows), -1, np.int32)
                if S == 1:       # no vmap lane for a singleton group
                    spec = {k: v[0] for k, v in spec.items()}
                    E0, a0 = E0[0], a0[0]
                if self.mesh is not None:
                    spec, E0, a0 = self._shard(spec, E0, a0, S)
                t0 = _time.perf_counter()
                _, _, acc = ej.run_schedule(
                    schedule, spec, statics, E0, a0, charge=charge,
                    block=False, layout=self.layout)
                pending.append((idxs, [rec] * S, acc, t0))
                continue
            lane_idx, lanes, lane_recs = [], [], []
            for skey, idxs in members:
                rec = schedules[skey]
                for i in idxs:
                    lane_idx.append(i)
                    lanes.append(rec["schedule"])
                    lane_recs.append(rec)
            spec = {
                k: np.stack([prepared[i]["spec"][k] for i in lane_idx])
                for k in g0["spec"]
            }
            L = len(lanes)
            s0 = lanes[0]
            E0 = np.zeros((L, s0.state_rows, s0.state_cols), np.float64)
            a0 = np.full((L, s0.state_rows), -1, np.int32)
            if self.mesh is not None:
                spec, E0, a0 = self._shard(spec, E0, a0, L)
            t0 = _time.perf_counter()
            _, _, acc = ej.run_schedules(
                lanes, spec, statics, E0, a0, charge=charge, block=False,
                layout=self.layout)
            pending.append((lane_idx, lane_recs, acc, t0))
        self.last_n_schedules = (len(schedules) + len(dev_pending)
                                 + n_shard_schedules)

        # -- collect (blocks on the device results) -------------------------
        results: list[RunResult | None] = [None] * len(prepared)
        for idxs, sched, final, ofs, t0g in dev_pending:
            final = {k: np.asarray(v) for k, v in final.items()}
            ofs = np.asarray(ofs)
            wall = _time.perf_counter() - t0g
            nbd = int(sched.boundary_steps.size)
            if progress is not None:
                progress(f"device-CGM group of {len(idxs)} scenario(s) "
                         f"replayed in {wall:.2f}s")
            for lane, i in enumerate(idxs):
                pr = prepared[i]
                costs = CostBreakdown(model=pr["statics"][0])
                ej.apply_acc(costs, sched, final["acc"][lane])
                part = cgm_jax.partition_from_of(
                    sched.n, final["of"][lane])
                hist = []
                for b in sched.boundary_steps:
                    sz = np.bincount(ofs[lane, int(b)]).astype(np.int64)
                    hist.append(sz[sz > 1])
                results[i] = RunResult(
                    policy=pr["policy"].name,
                    costs=costs,
                    clique_sizes=part.sizes(),
                    size_history=hist,
                    n_windows=nbd,
                    cg_seconds=0.0,
                    wall_seconds=wall / len(idxs),
                    config=getattr(pr["policy"], "config", None),
                )
        for idxs, recs, acc, t0 in sh_pending:
            acc = np.asarray(acc)
            wall = _time.perf_counter() - t0
            S_sh = len(recs)
            if progress is not None:
                progress(f"shard group of {len(idxs)} scenario(s) x "
                         f"{S_sh} shard(s) replayed in {wall:.2f}s")
            for li, i in enumerate(idxs):
                pr = prepared[i]
                merged = CostBreakdown(model=pr["statics"][0])
                totals = []
                for j, rec in enumerate(recs):
                    cb = CostBreakdown(model=pr["statics"][0])
                    ej.apply_acc(cb, rec["schedule"], acc[li * S_sh + j])
                    totals.append(cb.total)
                    merged.merge(cb)
                results[i] = RunResult(
                    policy=pr["policy"].name,
                    costs=merged,
                    clique_sizes=recs[0]["clique_sizes"],
                    size_history=list(recs[0]["size_history"]),
                    n_windows=recs[0]["n_windows"],
                    cg_seconds=sum(r["cg_seconds"] for r in recs),
                    wall_seconds=wall / len(idxs),
                    config=getattr(pr["policy"], "config", None),
                    shard_stats=_shard_stats(totals),
                )
        for idxs, lane_recs, acc, t0 in pending:
            acc = np.atleast_2d(np.asarray(acc))
            wall = _time.perf_counter() - t0
            if progress is not None:
                progress(f"group of {len(idxs)} scenario(s) replayed "
                         f"in {wall:.2f}s")
            for lane, i in enumerate(idxs):
                pr = prepared[i]
                rec = lane_recs[lane]
                costs = CostBreakdown(model=pr["statics"][0])
                ej.apply_acc(costs, rec["schedule"], acc[lane])
                results[i] = RunResult(
                    policy=pr["policy"].name,
                    costs=costs,
                    clique_sizes=rec["clique_sizes"],
                    size_history=list(rec["size_history"]),
                    n_windows=rec["n_windows"],
                    cg_seconds=rec["cg_seconds"],
                    wall_seconds=wall / len(idxs),
                    config=getattr(pr["policy"], "config", None),
                )
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    def _shard(self, spec, E0, a0, S):
        """Spread the lanes over ``self.mesh``: the scenario axis over the
        mesh's first axis (no-op if it does not divide evenly or the mesh
        axis has one device) and, under a row-sharded layout, the STATE
        ROWS over the mesh's ``state_row`` axis — the two compose on a
        2-D ``make_sweep_mesh(..., state_rows=)`` mesh."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = self.mesh
        axis = mesh.axis_names[0]
        lead = E0.ndim - 2               # 1 with a scenario axis, 0 squeezed
        n_sc = int(mesh.shape[axis])
        sc = axis if (lead and n_sc > 1 and S % n_sc == 0) else None
        lay = self.layout
        row = (lay.row_axis
               if lay.kind == "row_sharded" and lay.mesh is mesh
               and lay.row_axis in mesh.axis_names
               and int(mesh.shape[lay.row_axis]) > 1 else None)
        if sc is None and row is None:
            return spec, E0, a0
        from jax.experimental import enable_x64

        pfx = (sc,) * lead
        sh = NamedSharding(mesh, P(*pfx))
        shE = NamedSharding(mesh, P(*pfx, row, None))
        shA = NamedSharding(mesh, P(*pfx, row))
        with enable_x64():    # keep f64 spec/state dtypes across the put
            spec = {k: jax.device_put(v, sh) for k, v in spec.items()}
            return spec, jax.device_put(E0, shE), jax.device_put(a0, shA)


def sweep_points(
    grid: Sequence[dict],
    backend: str | None = None,
    batch_size: int | None = None,
    mesh=None,
    layout: StateLayout | str | None = None,
) -> list[RunResult]:
    """One-shot convenience: each grid entry is SweepPoint kwargs.

    With ``backend`` unset, picks ``REPRO_SWEEP_BACKEND`` (default jax)
    and degrades to the serial numpy loop when JAX is unavailable or any
    point's cost model has no JAX formula (same rule as
    ``benchmarks.common.run_method_grid``)."""
    import os

    pts = [SweepPoint(**g) for g in grid]
    if backend is None:
        backend = os.environ.get("REPRO_SWEEP_BACKEND", "jax")
        if backend == "jax":
            from . import engine_jax

            if not engine_jax.HAS_JAX or not all(
                    pt.policy_kwargs.get("cost_model", "table1")
                    in engine_jax.JAX_COST_MODELS
                    for pt in pts):
                backend = "numpy"
    eng = SweepEngine(backend=backend, batch_size=batch_size, mesh=mesh,
                      layout=layout)
    return eng.run(pts)
