"""Vmapped multi-scenario sweep engine over the JAX replay backend.

The paper's headline results (Figs. 5-10) are cost curves swept over
hyperparameter x cost-model x trace grids; PR 1-4 replayed every grid
point serially.  :class:`SweepEngine` makes the SCENARIO the batch axis:

1. every grid point is a :class:`SweepPoint` (policy + trace + pricing
   scenario);
2. points that share (trace, clique-generation hyperparameters, batch
   size) share ONE host-built :class:`~repro.core.engine_jax.ReplaySchedule`
   — an alpha sweep runs clique generation once, not once per alpha,
   because the partition trajectory is a pure function of the trace and
   the CGM knobs (never of prices or cache state, DESIGN.md §10);
3. scenarios sharing a schedule are stacked along a leading axis (cost
   spec + initial state) and replayed by ONE ``jax.vmap``'d call of the
   compiled scan, with the schedule's event tensors shared UNBATCHED
   across the lanes (``in_axes=None`` — no per-scenario copies);
4. each point comes back as the same :class:`~repro.core.policy.RunResult`
   the serial ``run_policy`` driver returns, cost-for-cost at 1e-9
   (tests/test_sweep.py).

``backend="numpy"`` degrades to the serial per-point loop (the honest
baseline ``benchmarks/sweep_bench.py`` times against, and the fallback
for cost models the JAX backend cannot express).  ``mesh=`` optionally
shards the scenario axis of each stacked group over a device mesh
(``repro.launch.mesh.make_sweep_mesh``) — a no-op on single-device hosts.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Any, Callable, Sequence

import numpy as np

from .cost import CacheEnvironment, get_cost_model
from .policy import RunResult, get_policy, run_policy

#: registry policies whose clique-generation trajectory is fully determined
#: by (trace, t_cg, top_frac, top_frac_of, theta, gamma, omega, split/merge
#: flags) — the key under which SweepEngine shares schedules.  Unknown /
#: custom policies always get a private schedule.
SHAREABLE_POLICIES = (
    "no_packing", "packcache", "dp_greedy",
    "akpc", "akpc_no_acm", "akpc_base",
)


@dataclasses.dataclass
class SweepPoint:
    """One grid point: a registered policy replayed over one scenario.

    ``policy_kwargs`` are passed to :func:`~repro.core.policy.get_policy`
    verbatim (``params``, ``t_cg``, ``top_frac``, ``env``, ``cost_model``,
    ...); ``tag`` is an arbitrary caller label carried through to the
    result order (results come back in input order regardless).
    """

    policy: str
    trace: Any
    policy_kwargs: dict = dataclasses.field(default_factory=dict)
    batch_size: int | None = None
    tag: str = ""


def _cgm_key(policy) -> tuple:
    """The clique-generation-relevant knobs of a registry policy."""
    p = policy.params
    cfg = getattr(policy, "config", None)
    if cfg is not None:                     # AKPCPolicy variants
        return (cfg.t_cg, cfg.top_frac, cfg.top_frac_of, cfg.enable_split,
                cfg.enable_approx_merge, cfg.params.theta, cfg.params.gamma,
                cfg.params.omega)
    user_part = getattr(policy, "_user_partition", None)
    return (policy.t_cg, getattr(policy, "top_frac", None),
            getattr(policy, "top_frac_of", None), p.theta,
            None if user_part is None else id(user_part))


class SweepEngine:
    """Replay a grid of scenarios with one vmapped device call per group."""

    def __init__(
        self,
        backend: str = "jax",
        batch_size: int | None = None,
        mesh=None,
    ):
        if backend not in ("jax", "numpy"):
            raise ValueError(f"unknown sweep backend {backend!r}")
        if backend == "jax":
            from . import engine_jax

            if not engine_jax.HAS_JAX:
                raise ImportError(
                    "SweepEngine(backend='jax') needs jax; use "
                    "backend='numpy'")
        self.backend = backend
        self.batch_size = batch_size
        self.mesh = mesh
        #: wall seconds of the most recent :meth:`run` (schedules + device)
        self.last_wall = 0.0
        #: schedule-dedup stats of the most recent run
        self.last_n_schedules = 0

    # ------------------------------------------------------------------
    def run(
        self,
        points: Sequence[SweepPoint],
        progress: Callable[[str], None] | None = None,
    ) -> list[RunResult]:
        t0 = _time.perf_counter()
        if self.backend == "numpy":
            out = [self._run_numpy(pt) for pt in points]
            self.last_wall = _time.perf_counter() - t0
            self.last_n_schedules = len(points)
            return out
        out = self._run_jax(points, progress)
        self.last_wall = _time.perf_counter() - t0
        return out

    def _run_numpy(self, pt: SweepPoint) -> RunResult:
        return run_policy(
            get_policy(pt.policy, **pt.policy_kwargs), pt.trace,
            batch_size=pt.batch_size or self.batch_size)

    # ------------------------------------------------------------------
    def _run_jax(self, points, progress) -> list[RunResult]:
        from . import engine_jax as ej
        from .cliques import CliquePartition
        from .cost import CostBreakdown

        # -- prepare points + share keys (no schedule builds yet) -----------
        prepared = []
        for pt in points:
            policy = get_policy(pt.policy, **pt.policy_kwargs)
            policy.bind(pt.trace.n, pt.trace.m)
            env = CacheEnvironment.resolve(
                getattr(policy, "env", None), pt.trace, policy.params)
            model = get_cost_model(
                getattr(policy, "cost_model", "table1"), env)
            spec, statics = ej.cost_spec(model, env)
            dt = spec["dt"]
            const_dt = env.m == 0 or bool((dt == dt[0]).all())
            bs = pt.batch_size or self.batch_size
            seed = getattr(policy, "seed_new_cliques", True)
            sizes_fp = (None if not model.uses_sizes
                        else (id(env.item_sizes)
                              if env.item_sizes is not None else "unit"))
            if pt.policy in SHAREABLE_POLICIES:
                skey = (id(pt.trace), pt.policy, _cgm_key(policy), bs,
                        const_dt, model.uses_sizes, sizes_fp, seed)
            else:
                skey = object()          # never shared
            prepared.append({
                "pt": pt, "policy": policy, "spec": spec,
                "statics": statics, "skey": skey,
                "model": model, "env": env, "bs": bs, "seed": seed,
                "charge": getattr(policy, "caching_charge", "requested"),
            })

        groups: dict = {}
        for i, pr in enumerate(prepared):
            groups.setdefault((pr["skey"], pr["statics"], pr["charge"]),
                              []).append(i)

        # -- build every distinct schedule on host --------------------------
        schedules: dict = {}
        for (skey, statics, charge), idxs in groups.items():
            g0 = prepared[idxs[0]]
            if skey in schedules:
                continue
            policy = g0["policy"]
            part0 = (policy.initial_partition(g0["pt"].trace)
                     if hasattr(policy, "initial_partition") else None)
            if part0 is None:
                part0 = CliquePartition.singletons(g0["pt"].trace.n)
            gen = policy.on_window if policy.t_cg is not None else None
            schedule = ej.build_schedule(
                part0, g0["pt"].trace, gen, policy.t_cg,
                model=g0["model"], env=g0["env"], batch_size=g0["bs"],
                seed_new_cliques=g0["seed"],
            )
            schedules[skey] = {
                "schedule": schedule,
                "n_windows": getattr(policy, "n_windows", 0),
                "cg_seconds": getattr(policy, "cg_seconds", 0.0),
                "size_history": list(getattr(policy, "size_history", [])),
                "clique_sizes": schedule.final_partition.sizes(),
            }
            if progress is not None:
                progress(f"schedule built: {g0['pt'].policy} "
                         f"({schedule.nb} steps x {schedule.ne} events)")

        # -- align schedule shapes so each (n, m, path) cohort compiles the
        # device scan exactly once, then dispatch every group WITHOUT
        # blocking (XLA chews in the background, results collected below)
        cohorts: dict = {}
        for rec in schedules.values():
            s = rec["schedule"]
            cohorts.setdefault(
                (s.n, s.m, s.const_dt, s.uses_sizes), []).append(rec)
        for recs in cohorts.values():
            dims_list = [ej.schedule_dims(r["schedule"]) for r in recs]
            dims = {k: max(d[k] for d in dims_list) for k in dims_list[0]}
            for r in recs:
                r["schedule"] = ej.pad_schedule(r["schedule"], dims)

        pending = []
        for (skey, statics, charge), idxs in groups.items():
            g0 = prepared[idxs[0]]
            rec = schedules[skey]
            schedule = rec["schedule"]
            S = len(idxs)
            spec = {
                k: np.stack([prepared[i]["spec"][k] for i in idxs])
                for k in g0["spec"]
            }
            E0 = np.zeros((S, schedule.n + 1, schedule.m), np.float64)
            a0 = np.full((S, schedule.n + 1), -1, np.int32)
            if S == 1:       # no vmap lane for a singleton group
                spec = {k: v[0] for k, v in spec.items()}
                E0, a0 = E0[0], a0[0]
            if self.mesh is not None:
                spec, E0, a0 = self._shard(spec, E0, a0, S)
            t0 = _time.perf_counter()
            _, _, acc = ej.run_schedule(
                schedule, spec, statics, E0, a0, charge=charge, block=False)
            pending.append((idxs, rec, acc, t0))
        self.last_n_schedules = len(schedules)

        # -- collect (blocks on the device results) -------------------------
        results: list[RunResult | None] = [None] * len(prepared)
        for idxs, rec, acc, t0 in pending:
            acc = np.atleast_2d(np.asarray(acc))
            wall = _time.perf_counter() - t0
            if progress is not None:
                progress(f"group of {len(idxs)} scenario(s) replayed "
                         f"in {wall:.2f}s")
            for lane, i in enumerate(idxs):
                pr = prepared[i]
                costs = CostBreakdown(model=pr["statics"][0])
                ej.apply_acc(costs, rec["schedule"], acc[lane])
                results[i] = RunResult(
                    policy=pr["policy"].name,
                    costs=costs,
                    clique_sizes=rec["clique_sizes"],
                    size_history=list(rec["size_history"]),
                    n_windows=rec["n_windows"],
                    cg_seconds=rec["cg_seconds"],
                    wall_seconds=wall / len(idxs),
                    config=getattr(pr["policy"], "config", None),
                )
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    def _shard(self, spec, E0, a0, S):
        """Spread the scenario axis over ``self.mesh`` (no-op if it does
        not divide evenly or the mesh has one device)."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = self.mesh
        axis = mesh.axis_names[0]
        ndev = int(np.prod(list(mesh.shape.values())))
        if ndev <= 1 or S % ndev != 0 or E0.ndim != 3:
            return spec, E0, a0
        sh = NamedSharding(mesh, P(axis))
        spec = {k: jax.device_put(v, sh) for k, v in spec.items()}
        return spec, jax.device_put(E0, sh), jax.device_put(a0, sh)


def sweep_points(
    grid: Sequence[dict],
    backend: str | None = None,
    batch_size: int | None = None,
    mesh=None,
) -> list[RunResult]:
    """One-shot convenience: each grid entry is SweepPoint kwargs.

    With ``backend`` unset, picks ``REPRO_SWEEP_BACKEND`` (default jax)
    and degrades to the serial numpy loop when JAX is unavailable or any
    point's cost model has no JAX formula (same rule as
    ``benchmarks.common.run_method_grid``)."""
    import os

    pts = [SweepPoint(**g) for g in grid]
    if backend is None:
        backend = os.environ.get("REPRO_SWEEP_BACKEND", "jax")
        if backend == "jax":
            from . import engine_jax

            if not engine_jax.HAS_JAX or not all(
                    pt.policy_kwargs.get("cost_model", "table1")
                    in engine_jax.JAX_COST_MODELS
                    for pt in pts):
                backend = "numpy"
    eng = SweepEngine(backend=backend, batch_size=batch_size, mesh=mesh)
    return eng.run(pts)
