"""Disjoint clique construction with reuse, splitting and approximate merging.

Implements the paper's Clique Generation Module:

* Alg. 4  — incremental adjustment of the previous window's cliques from the
            binary-CRM edge diff (remove -> split along the edge, add -> merge
            when the union stays a valid clique);
* Alg. 3  — splitting of cliques larger than omega along weakest
            co-utilisation edges, and APPROXIMATE merging: two cliques are
            merged when their union has size exactly omega and edge density
            >= gamma (near-cliques are accepted).

Every item always belongs to exactly one clique (singleton by default), so a
clique set is a partition of [0, n).  This makes the cache bookkeeping dense
and vectorisable: cliques are rows of an (k, m) expiry matrix.

Vectorised hot path (PR 3; DESIGN.md §8)
----------------------------------------

The Alg.-3 merge scan is, in matrix form, ``X = M A M^T`` with M the (k, h)
clique membership matrix over the hot index space and A the binary CRM — two
matmuls (``repro.kernels.clique_density`` on the MXU, numpy elsewhere).
``approximate_merge`` computes X ONCE and maintains it incrementally across
merges: memberships are disjoint, so merging (i, j) into row m is additive,

    X[m, l] = X[i, l] + X[j, l]            (l != m)
    X[m, m] = X[i, i] + X[j, j] + 2 X[i, j]

All entries that can gate a merge are exact small integers in fp32, so the
incremental update is bit-identical to a full rescan.  Edge diffs, weakest
edges and split seeds come from boolean/weight submatrix reductions in the
hot index space instead of Python sets of tuples.

``repro.core.cliques_ref`` preserves the scalar implementation as the parity
oracle; tests/test_cliques_parity.py asserts element-for-element identical
partitions over an (omega x gamma x theta) grid.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from .crm import WindowCRM

#: host clique-generation call counter — the device-CGM path (cgm_jax)
#: asserts this stays flat across a replay: zero host CGM calls
CGM_CALLS = 0

Edge = tuple[int, int]


@dataclasses.dataclass
class CliquePartition:
    """Partition of items [0, n) into disjoint cliques.

    ``cliques``    list of sorted int tuples (includes singletons)
    ``clique_of``  (n,) int32: item id -> clique index

    The array-native views (``sizes``, ``packed``, ``membership_matrix``) are
    derived from ``clique_of`` and cached — the engine, the session snapshots
    and the kernels all share the same (k, max|c|) packed layout.
    """

    n: int
    cliques: list[tuple[int, ...]]
    clique_of: np.ndarray

    # -- constructors ------------------------------------------------------
    @classmethod
    def singletons(cls, n: int) -> "CliquePartition":
        return cls(
            n=n,
            cliques=[(i,) for i in range(n)],
            clique_of=np.arange(n, dtype=np.int32),
        )

    @classmethod
    def from_cliques(cls, n: int, groups: list[tuple[int, ...]]) -> "CliquePartition":
        """Build a full partition from (disjoint, non-empty) groups.

        Items not covered by ``groups`` become singletons.  Raises
        ``ValueError`` on empty groups, out-of-range item ids and items
        appearing twice — zero-size or aliased clique rows would silently
        corrupt the engine's transfer/rent accounting downstream.
        """
        k = len(groups)
        lens, flat, gidx = _flatten_groups(groups)
        if k and (lens == 0).any():
            raise ValueError(
                f"empty clique group at index {int(np.argmax(lens == 0))}"
            )
        if flat.size:
            bad = (flat < 0) | (flat >= n)
            if bad.any():
                raise ValueError(
                    f"item id {int(flat[bad][0])} outside [0, {n})"
                )
            counts = np.bincount(flat, minlength=n)
            if (counts > 1).any():
                raise ValueError(
                    f"item {int(np.argmax(counts > 1))} in two cliques"
                )
        clique_of = np.full(n, -1, dtype=np.int32)
        clique_of[flat] = gidx.astype(np.int32)
        cliques = [tuple(sorted(g)) for g in groups]
        missing = np.nonzero(clique_of < 0)[0]
        clique_of[missing] = k + np.arange(missing.size, dtype=np.int32)
        cliques.extend((int(d),) for d in missing)
        return cls(n=n, cliques=cliques, clique_of=clique_of)

    # -- views -------------------------------------------------------------
    @property
    def k(self) -> int:
        return len(self.cliques)

    def sizes(self) -> np.ndarray:
        """(k,) int32 clique sizes (cached)."""
        s = getattr(self, "_sizes", None)
        if s is None:
            s = np.bincount(self.clique_of, minlength=self.k).astype(np.int32)
            self._sizes = s
        return s

    def packed(self) -> np.ndarray:
        """(k, max|c|) int64 member ids, -1 padded, rows in clique order.

        The shared array-native layout: ``session.pack_partition`` snapshots
        it, the engine segment-reduces over it, and each row lists members in
        ascending id order (same order as the ``cliques`` tuples).
        """
        p = getattr(self, "_packed", None)
        if p is None:
            k = self.k
            sizes = self.sizes().astype(np.int64)
            w = int(sizes.max()) if k else 1
            order = np.argsort(self.clique_of, kind="stable")
            starts = np.zeros(k, np.int64)
            np.cumsum(sizes[:-1], out=starts[1:])
            rows = self.clique_of[order].astype(np.int64)
            col = np.arange(self.n, dtype=np.int64) - starts[rows]
            p = np.full((k, max(w, 1)), -1, dtype=np.int64)
            p[rows, col] = order
            self._packed = p
        return p

    def member_order(self) -> np.ndarray:
        """(n,) int64 item ids sorted by (clique index, item id).

        ``packed()`` without the padding: row boundaries are at
        ``cumsum(sizes())`` — the layout segment reductions run over.
        """
        return np.argsort(self.clique_of, kind="stable")

    def membership_matrix(self) -> np.ndarray:
        """(k, n) float32 0/1 membership matrix M."""
        M = np.zeros((self.k, self.n), dtype=np.float32)
        M[self.clique_of, np.arange(self.n)] = 1.0
        return M

    def non_singletons(self) -> list[tuple[int, ...]]:
        return [c for c in self.cliques if len(c) > 1]

    def canonical(self) -> list[tuple[int, ...]]:
        return sorted(self.non_singletons())


def _flatten_groups(
    groups: list[tuple[int, ...]],
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(lens, flat member ids, group index per member) for a group list."""
    k = len(groups)
    lens = np.fromiter(map(len, groups), np.int64, count=k)
    flat = np.fromiter(
        itertools.chain.from_iterable(groups), np.int64, count=int(lens.sum())
    )
    return lens, flat, np.repeat(np.arange(k), lens)


# ---------------------------------------------------------------------------
# weight lookup helpers: CRM matrices are restricted to hot items, items
# outside get weight 0 / no edge.
# ---------------------------------------------------------------------------
class _CrmView:
    """Global-id view over a WindowCRM (cold items have no edges)."""

    def __init__(self, crm: WindowCRM, n: int):
        self._lut = np.full(n, -1, dtype=np.int32)
        self._lut[crm.hot_items] = np.arange(crm.n_hot, dtype=np.int32)
        self._norm = crm.norm
        self._bin = crm.binary

    def weight(self, u: int, v: int) -> float:
        a, b = self._lut[u], self._lut[v]
        if a < 0 or b < 0:
            return 0.0
        return float(self._norm[a, b])

    def connected(self, u: int, v: int) -> bool:
        a, b = self._lut[u], self._lut[v]
        if a < 0 or b < 0:
            return False
        return bool(self._bin[a, b])

    def weights_submatrix(self, members: np.ndarray) -> np.ndarray:
        """(s, s) float64 normalised weights; cold rows/cols are 0."""
        idx = self._lut[np.asarray(members, dtype=np.int64)]
        s = idx.shape[0]
        W = np.zeros((s, s), dtype=np.float64)
        hot = np.nonzero(idx >= 0)[0]
        if hot.size >= 2:
            W[np.ix_(hot, hot)] = self._norm[np.ix_(idx[hot], idx[hot])]
        return W

    def hot_count(self, members) -> int:
        """Number of hot members of a group."""
        return int((self._lut[np.asarray(members, dtype=np.int64)] >= 0).sum())

    def edges_within(self, group: tuple[int, ...]) -> int:
        idx = self._lut[list(group)]
        idx = idx[idx >= 0]
        if idx.size < 2:
            return 0
        # binary is symmetric with a False diagonal: sum/2 == triu sum
        return int(self._bin[np.ix_(idx, idx)].sum()) // 2

    def fully_connected(self, group: tuple[int, ...]) -> bool:
        g = len(group)
        if g <= 8:
            # tiny unions (the Alg.-4 merge check) are faster as direct
            # element probes than as an np.ix_ submatrix
            lut, bin_ = self._lut, self._bin
            idx = [lut[d] for d in group]
            if any(a < 0 for a in idx):
                return g < 2
            return all(
                bin_[idx[i], idx[j]]
                for i in range(g) for j in range(i + 1, g)
            )
        return self.edges_within(group) == g * (g - 1) // 2


# ---------------------------------------------------------------------------
# Alg. 4 — adjust previous cliques from the edge diff
# ---------------------------------------------------------------------------
def split_clique_on_edge(
    clique: tuple[int, ...], u: int, v: int, view: _CrmView
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Split ``clique`` into two groups seeded at the removed edge (u, v).

    Each remaining member joins the side it is more strongly co-utilised
    with (sum of normalised CRM weights) — the "two newly formed cliques
    generated from removing edge (u, v)" of Alg. 4 line 7.  The running
    side weights are accumulated as vectors over the group's weight
    submatrix, in the member order the scalar oracle sums them.
    """
    members = np.asarray(clique, dtype=np.int64)
    W = view.weights_submatrix(members)
    pu = int(np.nonzero(members == u)[0][0])
    pv = int(np.nonzero(members == v)[0][0])
    left = [int(u)]
    right = [int(v)]
    wl = W[:, pu].copy()                 # wl[d] = sum of weights d -> left
    wr = W[:, pv].copy()
    for p in range(members.size):
        if p == pu or p == pv:
            continue
        if wl[p] >= wr[p]:
            left.append(int(members[p]))
            wl += W[:, p]
        else:
            right.append(int(members[p]))
            wr += W[:, p]
    return tuple(sorted(left)), tuple(sorted(right))


def adjust_previous_cliques(
    prev: CliquePartition,
    added: np.ndarray,
    removed: np.ndarray,
    view: _CrmView,
    omega: int,
) -> list[tuple[int, ...]]:
    """Alg. 4: reuse the previous partition, patching it edge by edge.

    ``added`` / ``removed`` are (e, 2) int arrays of global-id edges in
    lexicographic order (``crm.edge_diff_arrays``) — same processing order
    as the scalar oracle's ``sorted(set)`` loops.
    """
    groups: list[tuple[int, ...] | None] = list(prev.cliques)
    of = prev.clique_of.astype(np.int64, copy=True)

    for u, v in np.asarray(removed, dtype=np.int64).tolist():
        cu = int(of[u])
        if cu == int(of[v]) and len(groups[cu]) > 1:
            a, b = split_clique_on_edge(groups[cu], u, v, view)
            groups[cu] = a
            of[list(a)] = cu
            j = len(groups)
            groups.append(b)
            of[list(b)] = j

    for u, v in np.asarray(added, dtype=np.int64).tolist():
        cu, cv = int(of[u]), int(of[v])
        if cu == cv:
            continue
        gu, gv = groups[cu], groups[cv]
        if len(gu) + len(gv) > omega:        # disjoint: |union| = |gu|+|gv|
            continue
        union = tuple(sorted(gu + gv))
        if view.fully_connected(union):
            # a new exact clique is formed (Alg. 4 lines 8-9)
            keep, drop = (cu, cv) if cu < cv else (cv, cu)
            groups[keep] = union
            groups[drop] = None
            of[list(union)] = keep

    return [g for g in groups if g]


# ---------------------------------------------------------------------------
# Alg. 3 lines 2-3 — weakest-edge splitting of oversized cliques
# ---------------------------------------------------------------------------
def split_oversized(
    group: tuple[int, ...], omega: int, view: _CrmView
) -> list[tuple[int, ...]]:
    """Split ``group`` until every part has size <= omega (iterative).

    The cut is seeded at the weakest co-utilisation edge of the group
    (paper: "using weakest co-utilization edges from CRM_Norm(W)").  A
    worklist replaces the oracle's one-level-per-split recursion, which
    overflows the interpreter stack on groups a few thousand members over
    omega (reachable via ``run_policy(initial_partition=...)`` or an omega
    decrease between sessions).
    """
    out: list[tuple[int, ...]] = []
    stack: list[tuple[int, ...]] = [tuple(group)]
    while stack:
        g = stack.pop()
        if len(g) <= omega:
            out.append(g)
            continue
        if view.hot_count(g) <= 1:
            # Every pairwise weight is 0: the weakest edge is always
            # (g[0], g[1]) and ties send every member left, so each level
            # peels g[1] off.  Emit that peel sequence in closed form
            # instead of O(|g|^2) per singleton split.
            p = len(g) - omega
            out.append((g[0],) + g[p + 1:])
            out.extend((g[i],) for i in range(p, 0, -1))
            continue
        W = view.weights_submatrix(np.asarray(g, dtype=np.int64))
        W[np.tril_indices(len(g))] = np.inf
        pu, pv = divmod(int(np.argmin(W)), len(g))
        a, b = split_clique_on_edge(g, g[pu], g[pv], view)
        stack.append(b)                  # LIFO: a's splits emit before b's,
        stack.append(a)                  # matching the recursive order
    return out


# ---------------------------------------------------------------------------
# Alg. 3 lines 4-10 — approximate clique merging
# ---------------------------------------------------------------------------
def hot_membership(
    groups: list[tuple[int, ...]], view: _CrmView
) -> np.ndarray:
    """(k, h) 0/1 membership matrix restricted to the hot index space."""
    h = view._norm.shape[0]
    k = len(groups)
    M = np.zeros((k, h), dtype=np.float32)
    if k:
        _, flat, gidx = _flatten_groups(groups)
        idx = view._lut[flat]
        hot = idx >= 0
        M[gidx[hot], idx[hot]] = 1.0
    return M


def merge_scores(
    groups: list[tuple[int, ...]],
    view: _CrmView,
    omega: int,
    pair_edges=None,
) -> np.ndarray:
    """Density of every pairwise union with |U| == omega; -1 elsewhere.

    One-shot matrix form of the Alg.-3 scan: with M (k, h) hot membership
    and A the binary CRM, ``X = M A M^T`` holds cross-edge counts
    off-diagonal and 2x within-edge counts on the diagonal, so
    ``E_U(i, j) = X[i,i]/2 + X[j,j]/2 + X[i,j]``.
    ``pair_edges``: optional accelerated ``(M, A) -> M A M^T`` callable (the
    Pallas ``clique_density`` wrapper); defaults to numpy matmuls.
    ``approximate_merge`` maintains X incrementally instead of re-calling
    this per merge.
    """
    k = len(groups)
    M = hot_membership(groups, view)
    A = view._bin.astype(np.float32)
    if pair_edges is None:
        X = M @ A @ M.T
    else:
        X = np.asarray(pair_edges(M, A))
    sizes = np.array([len(g) for g in groups], dtype=np.int64)
    dens = _densities(X, sizes, omega)
    assert dens.shape == (k, k)
    return dens


def _densities(X: np.ndarray, sizes: np.ndarray, omega: int) -> np.ndarray:
    """(k, k) float32 union densities from the pair-edge matrix X."""
    within = np.diag(X) / 2.0
    e_u = within[:, None] + within[None, :] + X
    ok = (sizes[:, None] + sizes[None, :]) == omega
    np.fill_diagonal(ok, False)
    e_max = omega * (omega - 1) / 2.0
    return np.where(ok, e_u / e_max, -1.0).astype(np.float32)


def _mergeable_split(
    groups: list[tuple[int, ...]], view: _CrmView, omega: int, gamma: float
) -> tuple[list[tuple[int, ...]], list[tuple[int, ...]]]:
    """Split groups into (merge candidates, pass-through).

    A group with no hot member has zero CRM edges; its union with any partner
    of size <= omega-1 has at most (omega-1)(omega-2)/2 edges, so for
    gamma > (omega-2)/omega it can never reach the density bar and is excluded
    from the O(k^2) scan (exact pruning, see tests).
    """
    if omega <= 2 or gamma <= (omega - 2) / omega:
        return list(groups), []
    k = len(groups)
    if not k:
        return [], []
    _, flat, gidx = _flatten_groups(groups)
    has_hot = np.bincount(gidx[view._lut[flat] >= 0], minlength=k) > 0
    cand = [g for g, hh in zip(groups, has_hot) if hh]
    rest = [g for g, hh in zip(groups, has_hot) if not hh]
    return cand, rest


def approximate_merge(
    groups: list[tuple[int, ...]],
    view: _CrmView,
    omega: int,
    gamma: float,
    pair_edges=None,
) -> list[tuple[int, ...]]:
    """Greedy best-density-first merging of clique pairs with |U| == omega.

    ``X = M A M^T`` is computed once (numpy or the Pallas ``pair_edges``
    hook) over the ACTIVE candidates — groups with at least one incident
    binary-CRM edge; an edge-less group's unions are bounded by the same
    (omega-1)(omega-2)/2 < gamma * e_max argument as the no-hot-member
    pruning, and its X row is identically zero, so skipping it changes no
    value of the full matmul.  After each merge X and the thresholded
    density matrix D are updated additively (module docstring): the merged
    row/col is the sum of its parents, every other entry is untouched.  All
    decisions match the oracle's per-merge rescan exactly, including argmax
    tie-breaking (candidate order: survivors in place, merged appended).
    """
    cand, rest = _mergeable_split(list(groups), view, omega, gamma)
    k = len(cand)
    if k < 2:
        return cand + rest
    lens, flat, gidx = _flatten_groups(cand)
    idx = view._lut[flat]
    if omega <= 2 or gamma <= (omega - 2) / omega:
        act = np.arange(k)              # low bar: no pruning is sound
    else:
        has_edge = view._bin.any(axis=1)          # (h,) hot item has a peer
        live = (idx >= 0) & has_edge[np.maximum(idx, 0)]
        act = np.nonzero(np.bincount(gidx[live], minlength=k) > 0)[0]
    # X over the active subspace only — inert rows of the full M A M^T are
    # identically zero, and every entry is an exact small integer, so the
    # submatrix reduction reproduces the full matmul bit-for-bit
    act_of = np.full(k, -1, dtype=np.int64)
    act_of[act] = np.arange(act.size)
    a = int(act.size)
    if pair_edges is not None:
        M = hot_membership([cand[int(t)] for t in act], view)
        A = view._bin.astype(np.float32)
        X = np.asarray(pair_edges(M, A), dtype=np.float32)
    else:
        mem = (act_of[gidx] >= 0) & (idx >= 0)    # hot members of act groups
        fi = idx[mem]
        ga = act_of[gidx[mem]]
        t = fi.size
        S = np.zeros((a, t), dtype=np.float32)
        S[ga, np.arange(t)] = 1.0
        sub = view._bin[np.ix_(fi, fi)].astype(np.float32)
        X = S @ sub @ S.T
    sizes = lens[act]
    act_idx = act                       # cand position of each X/D row
    dens = _densities(X, sizes, omega)
    D = np.where(dens >= gamma, dens, -1.0).astype(np.float32)
    e_max = omega * (omega - 1) / 2.0
    while a >= 2:
        f = int(np.argmax(D))
        ai, aj = divmod(f, a)
        if D[ai, aj] < 0:
            break
        if ai > aj:
            ai, aj = aj, ai
        i, j = int(act_idx[ai]), int(act_idx[aj])     # i < j: idx ascending
        merged = tuple(sorted(cand[i] + cand[j]))
        del cand[j]
        del cand[i]
        cand.append(merged)
        keep = np.ones(a, dtype=bool)
        keep[[ai, aj]] = False
        pos = act_idx[keep]
        act_idx = np.append(pos - (pos > i) - (pos > j), len(cand) - 1)
        row = (X[ai, :] + X[aj, :])[keep]
        diag = X[ai, ai] + X[aj, aj] + 2.0 * X[ai, aj]
        a -= 1
        Xn = np.empty((a, a), dtype=np.float32)
        Xn[:-1, :-1] = X[np.ix_(keep, keep)]
        Xn[-1, :-1] = row
        Xn[:-1, -1] = row
        Xn[-1, -1] = diag
        sizes = np.concatenate([sizes[keep], [sizes[ai] + sizes[aj]]])
        # merged group's density row, same float ops as a full recompute
        within = np.diag(Xn) / 2.0
        e_row = (within[-1] + within[:-1]) + Xn[-1, :-1]
        ok_row = (sizes[-1] + sizes[:-1]) == omega
        d_row = np.where(ok_row, e_row / e_max, -1.0).astype(np.float32)
        d_row = np.where(d_row >= gamma, d_row, -1.0)
        Dn = np.empty((a, a), dtype=np.float32)
        Dn[:-1, :-1] = D[np.ix_(keep, keep)]
        Dn[-1, :-1] = d_row
        Dn[:-1, -1] = d_row
        Dn[-1, -1] = -1.0
        X, D = Xn, Dn
    return cand + rest


# ---------------------------------------------------------------------------
# full Alg. 3 pipeline
# ---------------------------------------------------------------------------
def generate_cliques(
    prev: CliquePartition | None,
    prev_crm: WindowCRM | None,
    crm: WindowCRM,
    n: int,
    omega: int,
    gamma: float,
    pair_edges=None,
    enable_split: bool = True,
    enable_approx_merge: bool = True,
) -> CliquePartition:
    """One clique-generation event: adjust -> split -> approximate-merge.

    ``enable_split`` / ``enable_approx_merge`` implement the paper's ablation
    variants (AKPC w/o CS, w/o ACM).
    """
    from .crm import edge_diff_arrays

    global CGM_CALLS
    CGM_CALLS += 1

    view = _CrmView(crm, n)
    if prev is None:
        prev = CliquePartition.singletons(n)
    added, removed = edge_diff_arrays(prev_crm, crm)
    groups = adjust_previous_cliques(prev, added, removed, view, omega)
    if enable_split:
        out: list[tuple[int, ...]] = []
        for g in groups:
            if len(g) <= omega:
                out.append(g)
            else:
                out.extend(split_oversized(g, omega, view))
    else:
        out = list(groups)
    if enable_approx_merge:
        out = approximate_merge(out, view, omega, gamma, pair_edges=pair_edges)
    return CliquePartition.from_cliques(n, out)
