"""Disjoint clique construction with reuse, splitting and approximate merging.

Implements the paper's Clique Generation Module:

* Alg. 4  — incremental adjustment of the previous window's cliques from the
            binary-CRM edge diff (remove -> split along the edge, add -> merge
            when the union stays a valid clique);
* Alg. 3  — splitting of cliques larger than omega along weakest
            co-utilisation edges, and APPROXIMATE merging: two cliques are
            merged when their union has size exactly omega and edge density
            >= gamma (near-cliques are accepted);

Every item always belongs to exactly one clique (singleton by default), so a
clique set is a partition of [0, n).  This makes the cache bookkeeping dense
and vectorisable: cliques are rows of an (k, m) expiry matrix.

The all-pairs merge scoring used by Alg. 3 lines 4-10 is, in matrix form,
``X = M A M^T`` with M the (k, n) clique membership matrix and A the binary
CRM — two matmuls, which is what ``repro.kernels.clique_density`` computes on
the MXU.  The numpy implementation below is the oracle.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .crm import WindowCRM

Edge = tuple[int, int]


@dataclasses.dataclass
class CliquePartition:
    """Partition of items [0, n) into disjoint cliques.

    ``cliques``    list of sorted int tuples (includes singletons)
    ``clique_of``  (n,) int32: item id -> clique index
    """

    n: int
    cliques: list[tuple[int, ...]]
    clique_of: np.ndarray

    # -- constructors ------------------------------------------------------
    @classmethod
    def singletons(cls, n: int) -> "CliquePartition":
        return cls(
            n=n,
            cliques=[(i,) for i in range(n)],
            clique_of=np.arange(n, dtype=np.int32),
        )

    @classmethod
    def from_cliques(cls, n: int, groups: list[tuple[int, ...]]) -> "CliquePartition":
        clique_of = np.full(n, -1, dtype=np.int32)
        cliques: list[tuple[int, ...]] = []
        for g in groups:
            g = tuple(sorted(g))
            idx = len(cliques)
            cliques.append(g)
            for d in g:
                if clique_of[d] != -1:
                    raise ValueError(f"item {d} in two cliques")
                clique_of[d] = idx
        for d in range(n):
            if clique_of[d] == -1:
                clique_of[d] = len(cliques)
                cliques.append((d,))
        return cls(n=n, cliques=cliques, clique_of=clique_of)

    # -- views -------------------------------------------------------------
    @property
    def k(self) -> int:
        return len(self.cliques)

    def sizes(self) -> np.ndarray:
        return np.array([len(c) for c in self.cliques], dtype=np.int32)

    def membership_matrix(self) -> np.ndarray:
        """(k, n) float32 0/1 membership matrix M."""
        M = np.zeros((self.k, self.n), dtype=np.float32)
        for i, c in enumerate(self.cliques):
            M[i, list(c)] = 1.0
        return M

    def non_singletons(self) -> list[tuple[int, ...]]:
        return [c for c in self.cliques if len(c) > 1]

    def canonical(self) -> list[tuple[int, ...]]:
        return sorted(self.non_singletons())


# ---------------------------------------------------------------------------
# weight lookup helpers: CRM matrices are restricted to hot items, items
# outside get weight 0 / no edge.
# ---------------------------------------------------------------------------
class _CrmView:
    """Global-id view over a WindowCRM (cold items have no edges)."""

    def __init__(self, crm: WindowCRM, n: int):
        self._lut = np.full(n, -1, dtype=np.int32)
        self._lut[crm.hot_items] = np.arange(crm.n_hot, dtype=np.int32)
        self._norm = crm.norm
        self._bin = crm.binary

    def weight(self, u: int, v: int) -> float:
        a, b = self._lut[u], self._lut[v]
        if a < 0 or b < 0:
            return 0.0
        return float(self._norm[a, b])

    def connected(self, u: int, v: int) -> bool:
        a, b = self._lut[u], self._lut[v]
        if a < 0 or b < 0:
            return False
        return bool(self._bin[a, b])

    def edges_within(self, group: tuple[int, ...]) -> int:
        idx = self._lut[list(group)]
        idx = idx[idx >= 0]
        if idx.size < 2:
            return 0
        sub = self._bin[np.ix_(idx, idx)]
        return int(np.triu(sub, k=1).sum())

    def fully_connected(self, group: tuple[int, ...]) -> bool:
        g = len(group)
        return self.edges_within(group) == g * (g - 1) // 2


# ---------------------------------------------------------------------------
# Alg. 4 — adjust previous cliques from the edge diff
# ---------------------------------------------------------------------------
def split_clique_on_edge(
    clique: tuple[int, ...], u: int, v: int, view: _CrmView
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Split ``clique`` into two groups seeded at the removed edge (u, v).

    Each remaining member joins the side it is more strongly co-utilised
    with (sum of normalised CRM weights) — the "two newly formed cliques
    generated from removing edge (u, v)" of Alg. 4 line 7.
    """
    left = [u]
    right = [v]
    for d in clique:
        if d == u or d == v:
            continue
        wl = sum(view.weight(d, x) for x in left)
        wr = sum(view.weight(d, x) for x in right)
        (left if wl >= wr else right).append(d)
    return tuple(sorted(left)), tuple(sorted(right))


def adjust_previous_cliques(
    prev: CliquePartition,
    added: set[Edge],
    removed: set[Edge],
    view: _CrmView,
    omega: int,
) -> list[tuple[int, ...]]:
    """Alg. 4: reuse the previous partition, patching it edge by edge."""
    groups: list[set[int]] = [set(c) for c in prev.cliques]
    of = prev.clique_of.copy()

    def _replace(idx: int, parts: list[set[int]]) -> None:
        groups[idx] = parts[0]
        for d in parts[0]:
            of[d] = idx
        for p in parts[1:]:
            j = len(groups)
            groups.append(p)
            for d in p:
                of[d] = j

    for (u, v) in sorted(removed):
        cu = int(of[u])
        if cu == int(of[v]) and len(groups[cu]) > 1:
            a, b = split_clique_on_edge(tuple(sorted(groups[cu])), u, v, view)
            _replace(cu, [set(a), set(b)])

    for (u, v) in sorted(added):
        cu, cv = int(of[u]), int(of[v])
        if cu == cv:
            continue
        union = groups[cu] | groups[cv]
        if len(union) <= omega and view.fully_connected(tuple(sorted(union))):
            # a new exact clique is formed (Alg. 4 lines 8-9)
            keep, drop = (cu, cv) if cu < cv else (cv, cu)
            groups[keep] = union
            groups[drop] = set()
            for d in union:
                of[d] = keep

    return [tuple(sorted(g)) for g in groups if g]


# ---------------------------------------------------------------------------
# Alg. 3 lines 2-3 — recursive weakest-edge splitting of oversized cliques
# ---------------------------------------------------------------------------
def split_oversized(
    group: tuple[int, ...], omega: int, view: _CrmView
) -> list[tuple[int, ...]]:
    """Recursively split ``group`` until every part has size <= omega.

    The cut is seeded at the weakest co-utilisation edge of the group
    (paper: "using weakest co-utilization edges from CRM_Norm(W)").
    """
    if len(group) <= omega:
        return [group]
    # find the weakest (possibly zero-weight) pair
    best: tuple[float, int, int] | None = None
    for i in range(len(group)):
        for j in range(i + 1, len(group)):
            w = view.weight(group[i], group[j])
            if best is None or w < best[0]:
                best = (w, group[i], group[j])
    assert best is not None
    _, u, v = best
    a, b = split_clique_on_edge(group, u, v, view)
    return split_oversized(a, omega, view) + split_oversized(b, omega, view)


# ---------------------------------------------------------------------------
# Alg. 3 lines 4-10 — approximate clique merging
# ---------------------------------------------------------------------------
def hot_membership(
    groups: list[tuple[int, ...]], view: _CrmView
) -> np.ndarray:
    """(k, h) 0/1 membership matrix restricted to the hot index space."""
    h = view._norm.shape[0]
    M = np.zeros((len(groups), h), dtype=np.float32)
    for i, g in enumerate(groups):
        idx = view._lut[list(g)]
        idx = idx[idx >= 0]
        M[i, idx] = 1.0
    return M


def merge_scores(
    groups: list[tuple[int, ...]],
    view: _CrmView,
    omega: int,
    pair_edges=None,
) -> np.ndarray:
    """Density of every pairwise union with |U| == omega; -1 elsewhere.

    Matrix form of the Alg.-3 scan: with M (k, h) hot membership and A the
    binary CRM, ``X = M A M^T`` holds cross-edge counts off-diagonal and
    2x within-edge counts on the diagonal, so
    ``E_U(i, j) = X[i,i]/2 + X[j,j]/2 + X[i,j]``.
    ``pair_edges``: optional accelerated ``(M, A) -> M A M^T`` callable (the
    Pallas ``clique_density`` wrapper); defaults to numpy matmuls.
    """
    k = len(groups)
    M = hot_membership(groups, view)
    A = view._bin.astype(np.float32)
    if pair_edges is None:
        X = M @ A @ M.T
    else:
        X = np.asarray(pair_edges(M, A))
    within = np.diag(X) / 2.0
    e_u = within[:, None] + within[None, :] + X
    sizes = np.array([len(g) for g in groups], dtype=np.int64)
    ok = (sizes[:, None] + sizes[None, :]) == omega
    np.fill_diagonal(ok, False)
    e_max = omega * (omega - 1) / 2.0
    dens = np.where(ok, e_u / e_max, -1.0).astype(np.float32)
    assert dens.shape == (k, k)
    return dens


def _mergeable_split(
    groups: list[tuple[int, ...]], view: _CrmView, omega: int, gamma: float
) -> tuple[list[tuple[int, ...]], list[tuple[int, ...]]]:
    """Split groups into (merge candidates, pass-through).

    A group with no hot member has zero CRM edges; its union with any partner
    of size <= omega-1 has at most (omega-1)(omega-2)/2 edges, so for
    gamma > (omega-2)/omega it can never reach the density bar and is excluded
    from the O(k^2) scan (exact pruning, see tests).
    """
    if omega <= 2 or gamma <= (omega - 2) / omega:
        return list(groups), []
    cand, rest = [], []
    for g in groups:
        if any(view._lut[d] >= 0 for d in g):
            cand.append(g)
        else:
            rest.append(g)
    return cand, rest


def approximate_merge(
    groups: list[tuple[int, ...]],
    view: _CrmView,
    omega: int,
    gamma: float,
    pair_edges=None,
) -> list[tuple[int, ...]]:
    """Greedy best-density-first merging of clique pairs with |U| == omega."""
    cand, rest = _mergeable_split(list(groups), view, omega, gamma)
    while len(cand) >= 2:
        dens = merge_scores(cand, view, omega, pair_edges=pair_edges)
        dens = np.where(dens >= gamma, dens, -1.0)
        if dens.max() < 0:
            break
        i, j = np.unravel_index(int(np.argmax(dens)), dens.shape)
        if i > j:
            i, j = j, i
        merged = tuple(sorted(cand[i] + cand[j]))
        cand = [g for t, g in enumerate(cand) if t not in (i, j)]
        cand.append(merged)
    return cand + rest


# ---------------------------------------------------------------------------
# full Alg. 3 pipeline
# ---------------------------------------------------------------------------
def generate_cliques(
    prev: CliquePartition | None,
    prev_crm: WindowCRM | None,
    crm: WindowCRM,
    n: int,
    omega: int,
    gamma: float,
    pair_edges=None,
    enable_split: bool = True,
    enable_approx_merge: bool = True,
) -> CliquePartition:
    """One clique-generation event: adjust -> split -> approximate-merge.

    ``enable_split`` / ``enable_approx_merge`` implement the paper's ablation
    variants (AKPC w/o CS, w/o ACM).
    """
    from .crm import edge_diff

    view = _CrmView(crm, n)
    if prev is None:
        prev = CliquePartition.singletons(n)
    added, removed = edge_diff(prev_crm, crm)
    groups = adjust_previous_cliques(prev, added, removed, view, omega)
    if enable_split:
        out: list[tuple[int, ...]] = []
        for g in groups:
            out.extend(split_oversized(g, omega, view))
    else:
        out = list(groups)
    if enable_approx_merge:
        out = approximate_merge(out, view, omega, gamma, pair_edges=pair_edges)
    return CliquePartition.from_cliques(n, out)
