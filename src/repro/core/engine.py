"""Cache replay engine shared by AKPC and every baseline (Alg. 1, 5, 6).

State per clique c and edge storage server (ESS) j:

* ``E[c, j]``  nominal expiry of the packed copy of c at j (0 = never cached)
* ``anchor[c]`` the server whose copy Alg. 6 keeps alive:  when a copy
  expires and it is the system's last alive copy (G[c] == 1), its expiry is
  extended by dt — recursively, so the copy with the LATEST nominal expiry
  ratchets forever until some other server fetches a fresher copy.  Hence at
  any time the alive set is ``{j : E[c,j] > t} ∪ {argmax_j E[c,j]}`` and we
  only need to remember the argmax ("anchor").  See DESIGN.md §2.

Cost accounting (Alg. 5 made consistent — see cost.py):

* miss at j   ->  C_T += transfer_cost(|c|, packed=|c|>1)
* every access->  C_P += n_charged * mu * ((t + dt) - max(E_eff, t))
  where ``n_charged`` is |D_i ∩ c| under the paper's accounting (the
  competitive proof and Alg. 5 line 5 charge rent for requested items only),
  or |c| under "stored" accounting (rent for what is actually stored).
* afterwards  ->  E[c, j] = t + dt

Batched state-update semantics (the vectorised hot path)
--------------------------------------------------------

``handle_batch`` replays a whole time-slice of requests with NumPy segment
reductions instead of per-request Python.  Correctness rests on two facts
about the scalar recurrence, both relying on request times being
non-decreasing (guaranteed by ``Trace``):

1. **Anchor resolution order within a batch.**  Every access touches its
   clique with expiry ``t + dt`` and ``dt`` is constant, so ``t + dt`` is the
   row maximum the moment it is written (every earlier expiry was set from an
   earlier time).  Hence after the first access of a clique inside a batch,
   the anchor is simply *the server of the clique's most recent access* —
   the per-event anchor lookup collapses to a lag over events grouped by
   clique (first event of a group checks the pre-batch ``anchor`` array,
   later events compare against the previous event's server).

2. **Segment-max expiry.**  For the same reason, the post-batch expiry of a
   (clique, server) pair is ``t_last + dt`` of its *last* access in the
   batch, and the pre-access expiry seen by any event is ``t_prev + dt`` of
   the previous access of the same pair (or the pre-batch ``E[c, j]`` for the
   pair's first event).  Both are lags/segment-ends over events sorted by
   (clique, server) — no sequential dict updates needed.

Alive-mask, miss transfer costs, Alg.-6 ratcheting/keepalive rent and the
Alg.-5 caching charge are then straight elementwise array math over the
(request, clique) "events" of the batch (deduplicated with multiplicity
|D_i ∩ c| via one ``np.unique`` over packed keys).

**Scalar-wrapper compatibility guarantee:** ``handle_request`` is a thin
wrapper over ``handle_batch`` with a batch of one, and a batch of one
performs exactly the scalar recurrence's float operations in the scalar
order — so per-request replay (``replay(..., batch_size=1)``) is
bit-compatible with the historical per-request Python loop, and larger
batches agree cost-for-cost up to float summation order (see
tests/test_engine_batched.py).

Pluggable cost models + per-server dt (PR 4, DESIGN.md §9)
----------------------------------------------------------

All cost arithmetic is routed through the three batched hooks of a
registered :class:`~repro.core.cost.CostModel` bound to a
:class:`~repro.core.cost.CacheEnvironment` (per-server prices, per-item
sizes).  The default ``table1`` model performs the identical float ops of
the historical inline ``CostParams`` formulas, so default replays stay
bit-identical.

Fact 1 above ("anchor = server of the most recent access") holds ONLY for a
server-constant dt.  When the model's ``dt()`` varies per server
(``heterogeneous``: dt_j = rho*lam_j/mu_j), an earlier access at a
long-dt server can outlive a later access at a short-dt server, so anchor
resolution becomes a RUNNING SEGMENT-MAX over the (clique)-sorted events of
the written expiries ``t_e + dt_{j_e}`` (ties -> latest, matching the
scalar ``touch`` rule's ``>=`` update), seeded per clique with the
pre-batch ``(anchor, E[c, anchor])`` pair.  The scan is a vectorised
Hillis-Steele doubling over the event axis (O(E log E)); the constant-dt
lag fast path is preserved and picked automatically.  Fact 2 is unaffected:
within one (clique, server) pair dt is constant, so pair expiries stay
lags/segment-ends.

The per-batch item->clique membership lookup is routed through
``repro.kernels.packed_lookup.clique_lookup``: the Pallas scalar-prefetch
gather on TPU backends, a NumPy fancy-index everywhere else (including when
JAX is not importable at all).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Literal

import numpy as np

from .cliques import CliquePartition
from .cost import (
    CacheEnvironment,
    CostBreakdown,
    CostModel,
    CostParams,
    get_cost_model,
)

CachingCharge = Literal["requested", "stored"]

#: default time-slice size for batched replay (requests per handle_batch)
DEFAULT_BATCH_SIZE = 4096


def _numpy_clique_lookup(clique_of: np.ndarray, items: np.ndarray) -> np.ndarray:
    """Fallback membership gather used when the kernels package is absent."""
    return np.asarray(clique_of)[np.asarray(items)]


@dataclasses.dataclass
class CacheState:
    """Dense per-(clique, server) cache bookkeeping."""

    partition: CliquePartition
    E: np.ndarray               # (k, m) float64 nominal expiries
    anchor: np.ndarray          # (k,) int32, -1 if clique never cached
    m: int

    @classmethod
    def fresh(cls, partition: CliquePartition, m: int) -> "CacheState":
        k = partition.k
        return cls(
            partition=partition,
            E=np.zeros((k, m), dtype=np.float64),
            anchor=np.full(k, -1, dtype=np.int32),
            m=m,
        )

    @classmethod
    def from_device(cls, partition: CliquePartition, E, anchor,
                    m: int) -> "CacheState":
        """Slice device-layout state arrays (any StateLayout: dense
        ``(n+1, m)``, bucketed or row-sharded padding) back to the live
        ``(k, m)`` host prefix — host state is dense under every layout."""
        k = partition.k
        return cls(
            partition=partition,
            E=np.asarray(E)[:k, :m].astype(np.float64, copy=True),
            anchor=np.asarray(anchor)[:k].astype(np.int32, copy=True),
            m=m,
        )

    # -- aliveness ---------------------------------------------------------
    def is_alive(self, c: int, j: int, t: float) -> bool:
        if self.E[c, j] > t:
            return True
        return self.anchor[c] == j and self.E[c, j] > 0.0

    def ratcheted_expiry(self, c: int, j: int, t: float, dt: float) -> float:
        """Effective expiry of an alive copy at time t (Alg. 6 ratcheting)."""
        e = self.E[c, j]
        if e > t:
            return e
        # anchor copy whose nominal expiry lapsed: extended in dt steps
        steps = np.ceil((t - e) / dt)
        r = e + steps * dt
        if r <= t:                       # t exactly on a step boundary
            r += dt
        return float(r)

    def alive_copies(self, c: int, t: float) -> int:
        """G[c]: number of alive copies of clique c."""
        g = int((self.E[c] > t).sum())
        a = self.anchor[c]
        if a >= 0 and self.E[c, a] <= t and self.E[c, a] > 0.0:
            g += 1
        return g

    def touch(self, c: int, j: int, new_expiry: float) -> None:
        self.E[c, j] = new_expiry
        a = self.anchor[c]
        if a < 0 or new_expiry >= self.E[c, a]:
            self.anchor[c] = j


@dataclasses.dataclass
class RequestOutcome:
    """Per-request outcome (used by tests and the competitive checker)."""

    cliques: list[int]
    misses: list[int]
    transfer: float
    caching: float
    caching_miss: float = 0.0     # caching charged on missed cliques
    n_missed_items: int = 0       # |D_i| items whose clique was not cached (S)


@dataclasses.dataclass
class BatchOutcome:
    """Per-(request, clique) event arrays of one handle_batch call.

    Events are sorted by (request index, clique id) — the same order the
    scalar loop visits them.  All arrays share the event axis.
    """

    req: np.ndarray            # (e,) int64 request index within the batch
    cliques: np.ndarray        # (e,) int64 clique id
    n_req: np.ndarray          # (e,) int64 |D_i ∩ c| multiplicity
    miss: np.ndarray           # (e,) bool
    transfer: np.ndarray       # (e,) float64 (0 for hits)
    caching: np.ndarray        # (e,) float64 Alg.-5 caching charge

    @property
    def n_events(self) -> int:
        return int(self.req.shape[0])


@dataclasses.dataclass
class BatchEvents:
    """STATE-FREE event construction of one request batch.

    Everything here is a pure function of (partition, batch requests) — no
    cache state enters — which is what lets the JAX backend
    (``core/engine_jax.py``) hoist the whole construction into a host-built
    replay schedule and keep only the state recurrence on device.  The
    arrays are exactly the intermediates ``handle_batch`` historically
    computed inline, in the same NumPy op order (bit-compat contract).
    """

    ev_r: np.ndarray           # (e,) int64 request index within the batch
    ev_c: np.ndarray           # (e,) int64 clique id
    ev_j: np.ndarray           # (e,) int64 server of the event's request
    ev_t: np.ndarray           # (e,) float64 request time
    n_req: np.ndarray          # (e,) int64 |D_i ∩ c| multiplicity
    req_size: np.ndarray | None  # (e,) float64 requested-member volume
    # (clique)-sorted view: events grouped by clique, time order inside
    o_c: np.ndarray            # (e,) argsort by clique (stable)
    cs: np.ndarray             # (e,) ev_c[o_c]
    first_c_s: np.ndarray      # (e,) bool segment starts in sorted order
    last_c_s: np.ndarray       # (e,) bool segment ends in sorted order
    # (clique, server)-sorted view
    o_cj: np.ndarray           # (e,) argsort by (clique, server) (stable)
    first_cj_s: np.ndarray     # (e,) bool pair-segment starts (sorted)
    last_cj_s: np.ndarray      # (e,) bool pair-segment ends (sorted)
    first_cj: np.ndarray       # (e,) bool first event of its pair (dense)
    prev_cj_t: np.ndarray      # (e,) float64 previous same-pair event time
    # constant-dt fast-path lags (module docstring fact 1)
    first_c: np.ndarray        # (e,) bool first event of its clique (dense)
    prev_j: np.ndarray         # (e,) int64 previous same-clique server
    n_valid: int               # number of valid (non-padding) item slots

    @property
    def n_events(self) -> int:
        return int(self.ev_c.shape[0])


def batch_events(
    clique_of: np.ndarray,
    k: int,
    m: int,
    items: np.ndarray,
    servers: np.ndarray,
    times: np.ndarray,
    lookup: Callable[[np.ndarray, np.ndarray], np.ndarray],
    item_sizes: np.ndarray | None,
) -> BatchEvents:
    """Construct the deduplicated (request, clique) events of one batch.

    ``items`` (B, d_max) int -1-padded, ``servers`` (B,), ``times`` (B,)
    as in :meth:`ReplayEngine.handle_batch` (already atleast_2d/reshaped).
    Performs the identical float/int NumPy ops the engine's inline
    construction performed, in the same order.
    """
    B = items.shape[0]
    valid = items >= 0
    n_valid = int(valid.sum())
    if n_valid == 0:
        z64 = np.zeros(0, np.int64)
        zf = np.zeros(0, np.float64)
        zb = np.zeros(0, bool)
        return BatchEvents(
            ev_r=z64, ev_c=z64, ev_j=z64, ev_t=zf, n_req=z64,
            req_size=zf if item_sizes is not None and k > 0 else None,
            o_c=z64, cs=z64, first_c_s=zb, last_c_s=zb,
            o_cj=z64, first_cj_s=zb, last_cj_s=zb,
            first_cj=zb, prev_cj_t=zf, first_c=zb, prev_j=z64,
            n_valid=0,
        )

    # --- items -> cliques (Pallas gather on TPU, numpy otherwise) ---------
    flat_r = np.broadcast_to(np.arange(B)[:, None], items.shape)[valid]
    cl = np.asarray(lookup(clique_of, items[valid]), dtype=np.int64)

    # --- dedupe (request, clique) pairs, keep |D_i ∩ c| counts ------------
    # unique over packed keys sorts by (request, clique) — the order the
    # scalar loop visits cliques
    if item_sizes is not None and k > 0:
        ev_key, inv, n_req = np.unique(
            flat_r * k + cl, return_inverse=True, return_counts=True)
        # summed sizes of the REQUESTED items of each event (|D_i ∩ c|)
        req_size = np.bincount(
            inv.reshape(-1), weights=item_sizes[items[valid]],
            minlength=ev_key.shape[0])
    else:
        ev_key, n_req = np.unique(flat_r * k + cl, return_counts=True)
        req_size = None
    ev_r = ev_key // k
    ev_c = ev_key % k
    ev_j = servers[ev_r]
    ev_t = times[ev_r]
    ne = ev_key.shape[0]

    # --- within-batch lags (module docstring, facts 1 and 2) --------------
    o_c = np.argsort(ev_c, kind="stable")          # (clique, time) order
    cs = ev_c[o_c]
    first_c_s = np.ones(ne, dtype=bool)
    first_c_s[1:] = cs[1:] != cs[:-1]
    last_c_s = np.ones(ne, dtype=bool)
    last_c_s[:-1] = cs[1:] != cs[:-1]

    # per (clique, server): previous event's time -> pre-access expiry
    key_cj = ev_c * m + ev_j
    o_cj = np.argsort(key_cj, kind="stable")
    kcs = key_cj[o_cj]
    first_cj_s = np.ones(ne, dtype=bool)
    first_cj_s[1:] = kcs[1:] != kcs[:-1]
    last_cj_s = np.ones(ne, dtype=bool)
    last_cj_s[:-1] = kcs[1:] != kcs[:-1]
    prev_t_s = np.zeros(ne, dtype=np.float64)
    prev_t_s[1:] = ev_t[o_cj][:-1]
    prev_t_s[first_cj_s] = 0.0
    first_cj = np.empty(ne, dtype=bool)
    first_cj[o_cj] = first_cj_s
    prev_cj_t = np.empty(ne, dtype=np.float64)
    prev_cj_t[o_cj] = prev_t_s

    # constant-dt fast path lags (fact 1): previous same-clique server
    prev_j_s = np.full(ne, -1, dtype=np.int64)
    prev_j_s[1:] = ev_j[o_c][:-1]
    prev_j_s[first_c_s] = -1
    first_c = np.empty(ne, dtype=bool)
    first_c[o_c] = first_c_s
    prev_j = np.empty(ne, dtype=np.int64)
    prev_j[o_c] = prev_j_s

    return BatchEvents(
        ev_r=ev_r, ev_c=ev_c, ev_j=ev_j, ev_t=ev_t, n_req=n_req,
        req_size=req_size,
        o_c=o_c, cs=cs, first_c_s=first_c_s, last_c_s=last_c_s,
        o_cj=o_cj, first_cj_s=first_cj_s, last_cj_s=last_cj_s,
        first_cj=first_cj, prev_cj_t=prev_cj_t,
        first_c=first_c, prev_j=prev_j, n_valid=n_valid,
    )


def match_partitions(
    old_partition: CliquePartition, new_partition: CliquePartition
) -> tuple[np.ndarray, np.ndarray]:
    """(matched, cand): which new cliques equal an old clique, and which.

    State-free half of :meth:`ReplayEngine.install_partition` (shared with
    the JAX schedule builder).  A new clique equals an old one iff all its
    members map to one old clique of the same size.
    """
    k = new_partition.k
    new_sizes = new_partition.sizes().astype(np.int64)
    old_sizes = old_partition.sizes().astype(np.int64)
    old_of = old_partition.clique_of
    packed = new_partition.packed()                  # (k, w) -1 padded
    if k == 0:
        return np.zeros(0, bool), np.zeros(0, np.int64)
    cand = old_of[packed[:, 0]].astype(np.int64)     # old clique of 1st member
    same = (old_of[np.maximum(packed, 0)] == cand[:, None]) | (packed < 0)
    matched = same.all(axis=1) & (old_sizes[cand] == new_sizes)
    return matched, cand


def window_seed_servers(
    n: int,
    m: int,
    partition: CliquePartition,
    window_items: np.ndarray,
    window_servers: np.ndarray,
) -> np.ndarray:
    """(k,) the server that accessed each clique's members most during the
    window (Alg. 1 line 5 seeding target).  State-free half of the
    ``install_partition`` seed path."""
    order = partition.member_order()
    sizes = partition.sizes().astype(np.int64)
    starts = np.zeros(partition.k, np.int64)
    np.cumsum(sizes[:-1], out=starts[1:])
    seed_counts = np.zeros((n, m), dtype=np.int64)
    reps = (window_items >= 0).sum(axis=1)
    srv = np.repeat(window_servers, reps)
    itm = window_items[window_items >= 0]
    np.add.at(seed_counts, (itm, srv), 1)
    seed_sum = np.add.reduceat(seed_counts[order], starts, axis=0)
    return np.argmax(seed_sum, axis=1)


class ReplayEngine:
    """Replays a request trace against an evolving clique partition.

    The replay core is batched: ``handle_batch`` vectorises Alg. 5/6 over a
    time-slice of requests (see module docstring for the exact semantics);
    ``handle_request`` wraps it for single requests and ``replay`` slices the
    trace into batches that never straddle a T_CG boundary.
    """

    def __init__(
        self,
        n: int,
        m: int,
        params: CostParams | None = None,
        caching_charge: CachingCharge = "requested",
        seed_new_cliques: bool = True,
        lookup: Callable[[np.ndarray, np.ndarray], np.ndarray] | None = None,
        env: CacheEnvironment | None = None,
        cost_model: str | CostModel = "table1",
    ):
        self.n = n
        self.m = m
        if env is None:
            env = CacheEnvironment(n=n, m=m, params=params or CostParams())
        elif (env.n, env.m) != (n, m):
            raise ValueError(
                f"environment shape ({env.n}, {env.m}) != engine ({n}, {m})")
        elif params is not None and params != env.params:
            # the bound cost model prices via env.params; a conflicting
            # explicit params would be silently ignored otherwise
            raise ValueError(
                "params and env.params disagree; build the environment with "
                "the same CostParams you pass to the engine/policy")
        self.env = env
        self.params = params if params is not None else env.params
        self.model = get_cost_model(cost_model, env)
        self._dt_arr = np.asarray(self.model.dt(), dtype=np.float64)
        self._dt_const = m == 0 or bool((self._dt_arr == self._dt_arr[0]).all())
        self._item_sizes = env.sizes() if self.model.uses_sizes else None
        self.caching_charge = caching_charge
        self.seed_new_cliques = seed_new_cliques
        if lookup is None:
            try:
                from ..kernels.packed_lookup import clique_lookup as lookup
            except Exception:           # kernels layer unavailable: pure numpy
                lookup = _numpy_clique_lookup
        self._lookup = lookup
        self._item_keep: np.ndarray | None = None
        self._clique_nk: np.ndarray | None = None
        self.state = CacheState.fresh(CliquePartition.singletons(n), m)
        self._set_partition_caches(self.state.partition)
        self.costs = CostBreakdown(model=self.model.name)

    def _set_partition_caches(self, partition: CliquePartition) -> None:
        """Per-clique member counts + (for size-aware models) total volumes."""
        self._sizes = partition.sizes().astype(np.int64)
        if self._item_sizes is None or partition.k == 0:
            self._csizes = None
        else:
            order = partition.member_order()
            starts = np.zeros(partition.k, np.int64)
            np.cumsum(self._sizes[:-1], out=starts[1:])
            self._csizes = np.add.reduceat(self._item_sizes[order], starts)
        self._refresh_clique_nk(partition)

    def _refresh_clique_nk(self, partition: CliquePartition) -> None:
        """Clique-level keep-or-not mask: nokeep iff ANY member is nokeep."""
        if self._item_keep is None or partition.k == 0:
            self._clique_nk = None
            return
        order = partition.member_order()
        starts = np.zeros(partition.k, np.int64)
        np.cumsum(self._sizes[:-1], out=starts[1:])
        nk = (~self._item_keep).astype(np.int64)
        self._clique_nk = np.add.reduceat(nk[order], starts) > 0

    # ------------------------------------------------------------------
    # keep-or-not masks (TTL baseline, arXiv 1312.0499)
    # ------------------------------------------------------------------
    def set_item_keep(
        self, keep: np.ndarray | None, evict: bool = True
    ) -> None:
        """Install a per-item keep-or-not mask.

        Items with ``keep[i] == False`` are never cached: every access of a
        clique containing one is a forced miss priced as a full transfer
        with zero caching/keepalive charge, and the clique's state writes
        are suppressed.  With ``evict=True`` (the window-boundary sync),
        cliques containing an item that JUST flipped keep->nokeep drop
        their cached copies (E row zeroed, anchor cleared); cliques that
        stayed nokeep already hold no state — the invariant "nokeep clique
        => zero state" is maintained at every boundary.  ``None`` removes
        the mask entirely.
        """
        if keep is None:
            self._item_keep = None
            self._clique_nk = None
            return
        keep = np.asarray(keep, dtype=bool)
        if keep.shape != (self.n,):
            raise ValueError(f"keep mask shape {keep.shape} != ({self.n},)")
        old = self._item_keep
        self._item_keep = keep.copy()
        self._refresh_clique_nk(self.state.partition)
        if not evict or self._clique_nk is None:
            return
        newly_nk = ~keep if old is None else (old & ~keep)
        if newly_nk.any():
            rows = np.unique(
                self.state.partition.clique_of[np.nonzero(newly_nk)[0]])
            self.state.E[rows] = 0.0
            self.state.anchor[rows] = -1

    # ------------------------------------------------------------------
    # Alg. 1 Event 1 — install a freshly generated partition
    # ------------------------------------------------------------------
    def install_partition(
        self,
        partition: CliquePartition,
        now: float,
        window_items: np.ndarray | None = None,
        window_servers: np.ndarray | None = None,
    ) -> None:
        """Translate cache state onto the new partition (vectorised).

        * cliques identical to a previous clique keep their row (and anchor):
          matched without hashing tuples — a new clique equals an old one iff
          all its members map to one old clique of the same size;
        * changed cliques are present at j iff EVERY member was nominally
          alive at j (presence = segment-min of member expiries over the
          partition's packed member order);
        * newly formed multi-item cliques are seeded with one packed copy at
          the server that accessed their members most during the window
          (Alg. 1 line 5), free of charge (packing runs in the background,
          §III.C).
        """
        old = self.state
        k = partition.k
        if k == 0:
            self.state = CacheState.fresh(partition, self.m)
            self._set_partition_caches(partition)
            return
        E = np.zeros((k, self.m), dtype=np.float64)
        anchor = np.full(k, -1, dtype=np.int32)
        new_sizes = partition.sizes().astype(np.int64)
        old_of = old.partition.clique_of

        # -- set-equality match against the old partition ------------------
        matched, cand = match_partitions(old.partition, partition)
        E[matched] = old.E[cand[matched]]
        anchor[matched] = old.anchor[cand[matched]]

        changed = ~matched
        if changed.any():
            # nominal per-item expiry under the old partition
            item_E = old.E[old_of]                       # (n, m)
            order = partition.member_order()             # grouped by clique
            starts = np.zeros(k, np.int64)
            np.cumsum(new_sizes[:-1], out=starts[1:])
            min_E = np.minimum.reduceat(item_E[order], starts, axis=0)
            fresh = np.where(min_E > now, min_E, 0.0)    # (k, m)
            E[changed] = fresh[changed]
            row_max = fresh.max(axis=1)
            present = changed & (row_max > 0)
            anchor[present] = np.argmax(fresh, axis=1)[present].astype(np.int32)

            need_seed = changed & (row_max <= 0) & (new_sizes > 1)
            if self._item_keep is not None and need_seed.any():
                # never seed a clique holding a keep-or-not evicted item:
                # its state must stay zero until the mask flips back
                has_nk = np.add.reduceat(
                    (~self._item_keep)[order].astype(np.int64), starts) > 0
                need_seed &= ~has_nk
            if (
                self.seed_new_cliques
                and window_items is not None
                and window_servers is not None
                and need_seed.any()
            ):
                # item -> per-server access counts over the window
                js = window_seed_servers(
                    self.n, self.m, partition, window_items, window_servers)
                rows = np.nonzero(need_seed)[0]
                E[rows, js[rows]] = now + self._dt_arr[js[rows]]
                anchor[rows] = js[rows].astype(np.int32)
        self.state = CacheState(partition=partition, E=E, anchor=anchor, m=self.m)
        self._set_partition_caches(partition)

    # ------------------------------------------------------------------
    # Alg. 5 — request handling, one batch at a time
    # ------------------------------------------------------------------
    def handle_batch(
        self,
        items: np.ndarray,
        servers: np.ndarray,
        times: np.ndarray,
    ) -> BatchOutcome:
        """Vectorised Alg. 5/6 over a batch of requests.

        ``items``  (B, d_max) int, -1 padded;  ``servers`` (B,) int;
        ``times``  (B,) float, non-decreasing and >= every earlier request.
        Rows whose items are all -1 are counted as (empty) requests but
        produce no events.
        """
        st = self.state
        model = self.model
        items = np.atleast_2d(np.asarray(items))
        B = items.shape[0]
        servers = np.asarray(servers, dtype=np.int64).reshape(B)
        times = np.asarray(times, dtype=np.float64).reshape(B)

        self.costs.n_requests += B
        k = st.partition.k
        ev = batch_events(
            st.partition.clique_of, k, self.m, items, servers, times,
            self._lookup, self._item_sizes if self._csizes is not None else None,
        )
        self.costs.n_item_requests += ev.n_valid
        if ev.n_valid == 0:
            z = np.zeros(0)
            return BatchOutcome(
                req=z.astype(np.int64), cliques=z.astype(np.int64),
                n_req=z.astype(np.int64), miss=z.astype(bool),
                transfer=z, caching=z,
            )
        ev_r, ev_c, ev_j, ev_t = ev.ev_r, ev.ev_c, ev.ev_j, ev.ev_t
        n_req, req_size = ev.n_req, ev.req_size
        ne = ev.n_events
        o_c, cs, first_c_s = ev.o_c, ev.cs, ev.first_c_s
        o_cj = ev.o_cj

        # per-event dt: scalar on the constant-dt fast path (bit-identical
        # broadcasting), per-server gather otherwise
        if self._dt_const:
            dt_e: np.ndarray | float = (
                float(self._dt_arr[0]) if self._dt_arr.size else self.params.dt
            )
        else:
            dt_e = self._dt_arr[ev_j]

        E_before = np.where(ev.first_cj, st.E[ev_c, ev_j], ev.prev_cj_t + dt_e)

        # --- anchor resolution --------------------------------------------
        if self._dt_const:
            # fast path (fact 1): anchor == server of the clique's previous
            # event; first events consult the pre-batch anchor array
            anchor_alive = np.where(
                ev.first_c,
                (st.anchor[ev_c] == ev_j) & (E_before > 0.0),
                ev.prev_j == ev_j,
            )
        else:
            anchor_seen, final_lc, final_anchor = self._anchor_scan(
                ev_t, ev_j, ev_c, dt_e, o_c, cs, first_c_s)
            anchor_alive = (anchor_seen == ev_j) & (E_before > 0.0)

        fresh = E_before > ev_t
        if self._clique_nk is not None:
            # keep-or-not (TTL) cliques are forced misses — the in-batch
            # lag chains would otherwise fabricate hits from state writes
            # the nokeep mask suppresses below
            nk_ev = self._clique_nk[ev_c]
            fresh = fresh & ~nk_ev
            anchor_alive = anchor_alive & ~nk_ev
        else:
            nk_ev = None
        alive = fresh | anchor_alive
        miss = ~alive

        # Alg. 6 ratcheting of lapsed anchor copies (+ lazily accounted rent)
        lapsed = alive & ~fresh
        steps = np.ceil((ev_t - E_before) / dt_e)
        r = E_before + steps * dt_e
        r = np.where(r <= ev_t, r + dt_e, r)
        e_eff = np.where(fresh, E_before, np.where(lapsed, r, ev_t))

        # --- costs (vectorized CostModel hooks) ---------------------------
        size = self._sizes[ev_c]
        csize = self._csizes[ev_c] if self._csizes is not None else size
        rate_stored = model.caching_rate(size, csize, ev_j)
        rent = np.where(lapsed, rate_stored * (e_eff - E_before), 0.0)

        tc = np.where(miss, model.transfer_cost_batch(size, csize, ev_j), 0.0)

        if self.caching_charge == "requested":
            rate = model.caching_rate(
                n_req, req_size if req_size is not None else n_req, ev_j)
        else:
            rate = rate_stored
        dur = np.maximum((ev_t + dt_e) - np.maximum(e_eff, ev_t), 0.0)
        ccost = rate * dur
        if nk_ev is not None:
            ccost = np.where(nk_ev, 0.0, ccost)   # nokeep: nothing is stored

        self.costs.transfer += float(tc.sum())
        self.costs.caching += float(ccost.sum())
        self.costs.keepalive_rent += float(rent.sum())
        nm = int(miss.sum())
        self.costs.n_misses += nm
        self.costs.n_hits += ne - nm
        self.costs.items_transferred += int(size[miss].sum())

        # --- state update: segment-last expiry + final anchor -------------
        # (nokeep cliques never store state: their writes are filtered out)
        li = o_cj[ev.last_cj_s]
        if nk_ev is not None:
            li = li[~nk_ev[li]]
        if self._dt_const:
            st.E[ev_c[li], ev_j[li]] = ev_t[li] + dt_e
        else:
            st.E[ev_c[li], ev_j[li]] = ev_t[li] + self._dt_arr[ev_j[li]]

        if self._dt_const:
            lc = o_c[ev.last_c_s]
            if nk_ev is not None:
                lc = lc[~nk_ev[lc]]
            # guard (matters only for out-of-order manual calls): keep the
            # old anchor when its expiry still beats the batch's last touch
            a_cur = st.anchor[ev_c[lc]].astype(np.int64)
            a_E = st.E[ev_c[lc], np.maximum(a_cur, 0)]
            upd = (a_cur < 0) | (ev_t[lc] + dt_e >= a_E)
            st.anchor[ev_c[lc[upd]]] = ev_j[lc[upd]]
        else:
            if nk_ev is not None:
                keepc = ~self._clique_nk[final_lc]
                final_lc, final_anchor = final_lc[keepc], final_anchor[keepc]
            st.anchor[final_lc] = final_anchor

        return BatchOutcome(
            req=ev_r, cliques=ev_c, n_req=n_req, miss=miss,
            transfer=tc, caching=ccost,
        )

    def _anchor_scan(
        self,
        ev_t: np.ndarray,
        ev_j: np.ndarray,
        ev_c: np.ndarray,
        dt_e: np.ndarray,
        o_c: np.ndarray,
        cs: np.ndarray,
        first_c_s: np.ndarray,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-server-dt anchor resolution (general path, DESIGN.md §9).

        Replays the scalar ``touch`` anchor recurrence — ``anchor := j`` iff
        ``t + dt_j >= E[c, anchor]`` — as a segmented RUNNING ARGMAX (ties ->
        latest) over the written expiries ``e = t + dt_j`` of each clique's
        events, seeded with the pre-batch ``(anchor, E[c, anchor])``.
        Returns ``(anchor_seen, final_cliques, final_anchor)``: the anchor
        each event observes BEFORE it touches, and the post-batch anchor per
        touched clique.
        """
        st = self.state
        ne = ev_t.shape[0]
        e_val = ev_t + dt_e
        js = ev_j[o_c]
        v = e_val[o_c].copy()
        bidx = np.arange(ne, dtype=np.int64)
        # Hillis-Steele doubling: after each round, (v, bidx)[i] is the max
        # written expiry (and its latest writer) over a suffix window of the
        # clique segment ending at i; segments are contiguous in `cs`, so
        # rounds beyond the longest segment are no-ops — bound d by it
        starts = np.nonzero(first_c_s)[0]
        max_run = int(np.diff(np.append(starts, ne)).max())
        d = 1
        while d < max_run:
            same = cs[d:] == cs[:-d]
            take = same & (v[:-d] > v[d:])      # earlier wins only if STRICTLY
            v[d:] = np.where(take, v[:-d], v[d:])
            bidx[d:] = np.where(take, bidx[:-d], bidx[d:])
            d <<= 1

        # pre-batch seed per event (clique-constant): (anchor, E[c, anchor])
        a0 = st.anchor[ev_c].astype(np.int64)
        Ea0 = np.where(
            a0 >= 0, st.E[ev_c, np.maximum(a0, 0)], -np.inf)
        a0_s = a0[o_c]
        Ea0_s = Ea0[o_c]

        # anchor seen by event i = combine(seed, prefix up to i-1)
        prev_v = np.full(ne, -np.inf)
        prev_v[1:] = v[:-1]
        prev_v[first_c_s] = -np.inf
        prev_b = np.zeros(ne, dtype=np.int64)
        prev_b[1:] = bidx[:-1]
        prev_b[first_c_s] = 0
        inbatch = ~first_c_s & (prev_v >= Ea0_s)
        anchor_seen_s = np.where(inbatch, js[prev_b], a0_s)
        anchor_seen = np.empty(ne, dtype=np.int64)
        anchor_seen[o_c] = anchor_seen_s

        # post-batch anchor per clique = combine(seed, full segment)
        last_c_s = np.ones(ne, dtype=bool)
        last_c_s[:-1] = cs[1:] != cs[:-1]
        lasts = np.nonzero(last_c_s)[0]
        win = v[lasts] >= Ea0_s[lasts]
        final_anchor = np.where(
            win, js[bidx[lasts]], a0_s[lasts]).astype(np.int32)
        return anchor_seen, cs[lasts], final_anchor

    # ------------------------------------------------------------------
    # thin single-request wrapper (bit-compatible with the old scalar loop)
    # ------------------------------------------------------------------
    def handle_request(
        self, items: Iterable[int], server: int, t: float
    ) -> RequestOutcome:
        row = np.asarray([int(d) for d in items], dtype=np.int64)
        if row.size == 0:
            row = np.full(1, -1, dtype=np.int64)
        out = self.handle_batch(
            row.reshape(1, -1),
            np.asarray([server], dtype=np.int64),
            np.asarray([t], dtype=np.float64),
        )
        miss = out.miss
        return RequestOutcome(
            cliques=[int(c) for c in out.cliques],
            misses=[int(c) for c in out.cliques[miss]],
            transfer=float(out.transfer.sum()),
            caching=float(out.caching.sum()),
            caching_miss=float(out.caching[miss].sum()),
            n_missed_items=int(out.n_req[miss].sum()),
        )

    # ------------------------------------------------------------------
    def replay(
        self,
        trace,
        clique_generator: Callable[[np.ndarray, np.ndarray, float], CliquePartition | None]
        | None = None,
        t_cg: float | None = None,
        progress: Callable[[int], None] | None = None,
        batch_size: int | None = None,
    ) -> CostBreakdown:
        """Replay a full trace in T_CG-boundary-aligned batches.

        ``clique_generator(window_items, window_servers, now)`` is invoked at
        every T_CG boundary with the PREVIOUS window's requests (Alg. 1
        Event 1, Fig. 3 timeline) and returns the new partition (or None to
        keep the current one).  Batches never straddle a boundary, so
        regeneration happens at exactly the same request index as the scalar
        per-request loop.  ``batch_size=1`` recovers the historical scalar
        replay bit-for-bit; the default vectorises ``DEFAULT_BATCH_SIZE``
        requests per state update.
        """
        bs = DEFAULT_BATCH_SIZE if batch_size is None else max(1, int(batch_size))
        times, servers, items = trace.times, trace.servers, trace.items
        R = int(times.shape[0])
        if R == 0:
            return self.costs
        use_cg = clique_generator is not None and t_cg is not None
        # keep-or-not policies (TTL) expose an `item_keep()` hook on the
        # object whose bound method was passed as the generator; sync the
        # engine's mask with it at start and after every regeneration
        keep_fn = None
        if use_cg:
            pol = getattr(clique_generator, "__self__", None)
            keep_fn = getattr(pol, "item_keep", None)
            if keep_fn is not None:
                self.set_item_keep(keep_fn(), evict=False)
        next_cg = float(times[0]) + t_cg if t_cg is not None else np.inf
        win_start = 0
        pos = 0
        next_prog = 0                 # throttle progress to every 64Ki reqs
        while pos < R:
            cut = R
            if use_cg:
                cut = int(np.searchsorted(times, next_cg, side="left"))
                if cut <= pos:
                    # request at ``pos`` crosses the boundary: Event 1 first
                    t = float(times[pos])
                    w_it = items[win_start:pos]
                    w_sv = servers[win_start:pos]
                    part = clique_generator(w_it, w_sv, t)
                    if part is not None:
                        self.install_partition(part, t, w_it, w_sv)
                    if keep_fn is not None:
                        self.set_item_keep(keep_fn())
                    win_start = pos
                    while next_cg <= t:
                        next_cg += t_cg
                    continue
            stop = min(pos + bs, cut)
            self.handle_batch(items[pos:stop], servers[pos:stop], times[pos:stop])
            pos = stop
            if progress is not None and pos >= next_prog:
                progress(pos)
                next_prog = (pos | 0xFFFF) + 1
        return self.costs
