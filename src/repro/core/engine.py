"""Cache replay engine shared by AKPC and every baseline (Alg. 1, 5, 6).

State per clique c and edge storage server (ESS) j:

* ``E[c, j]``  nominal expiry of the packed copy of c at j (0 = never cached)
* ``anchor[c]`` the server whose copy Alg. 6 keeps alive:  when a copy
  expires and it is the system's last alive copy (G[c] == 1), its expiry is
  extended by dt — recursively, so the copy with the LATEST nominal expiry
  ratchets forever until some other server fetches a fresher copy.  Hence at
  any time the alive set is ``{j : E[c,j] > t} ∪ {argmax_j E[c,j]}`` and we
  only need to remember the argmax ("anchor").  See DESIGN.md §2.

Cost accounting (Alg. 5 made consistent — see cost.py):

* miss at j   ->  C_T += transfer_cost(|c|, packed=|c|>1)
* every access->  C_P += n_charged * mu * ((t + dt) - max(E_eff, t))
  where ``n_charged`` is |D_i ∩ c| under the paper's accounting (the
  competitive proof and Alg. 5 line 5 charge rent for requested items only),
  or |c| under "stored" accounting (rent for what is actually stored).
* afterwards  ->  E[c, j] = t + dt
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Literal

import numpy as np

from .cliques import CliquePartition
from .cost import CostBreakdown, CostParams

CachingCharge = Literal["requested", "stored"]


@dataclasses.dataclass
class CacheState:
    """Dense per-(clique, server) cache bookkeeping."""

    partition: CliquePartition
    E: np.ndarray               # (k, m) float64 nominal expiries
    anchor: np.ndarray          # (k,) int32, -1 if clique never cached
    m: int

    @classmethod
    def fresh(cls, partition: CliquePartition, m: int) -> "CacheState":
        k = partition.k
        return cls(
            partition=partition,
            E=np.zeros((k, m), dtype=np.float64),
            anchor=np.full(k, -1, dtype=np.int32),
            m=m,
        )

    # -- aliveness ---------------------------------------------------------
    def is_alive(self, c: int, j: int, t: float) -> bool:
        if self.E[c, j] > t:
            return True
        return self.anchor[c] == j and self.E[c, j] > 0.0

    def ratcheted_expiry(self, c: int, j: int, t: float, dt: float) -> float:
        """Effective expiry of an alive copy at time t (Alg. 6 ratcheting)."""
        e = self.E[c, j]
        if e > t:
            return e
        # anchor copy whose nominal expiry lapsed: extended in dt steps
        steps = np.ceil((t - e) / dt)
        r = e + steps * dt
        if r <= t:                       # t exactly on a step boundary
            r += dt
        return float(r)

    def alive_copies(self, c: int, t: float) -> int:
        """G[c]: number of alive copies of clique c."""
        g = int((self.E[c] > t).sum())
        a = self.anchor[c]
        if a >= 0 and self.E[c, a] <= t and self.E[c, a] > 0.0:
            g += 1
        return g

    def touch(self, c: int, j: int, new_expiry: float) -> None:
        self.E[c, j] = new_expiry
        a = self.anchor[c]
        if a < 0 or new_expiry >= self.E[c, a]:
            self.anchor[c] = j


@dataclasses.dataclass
class RequestOutcome:
    """Per-request outcome (used by tests and the competitive checker)."""

    cliques: list[int]
    misses: list[int]
    transfer: float
    caching: float
    caching_miss: float = 0.0     # caching charged on missed cliques
    n_missed_items: int = 0       # |D_i| items whose clique was not cached (S)


class ReplayEngine:
    """Replays a request trace against an evolving clique partition."""

    def __init__(
        self,
        n: int,
        m: int,
        params: CostParams,
        caching_charge: CachingCharge = "requested",
        seed_new_cliques: bool = True,
    ):
        self.n = n
        self.m = m
        self.params = params
        self.caching_charge = caching_charge
        self.seed_new_cliques = seed_new_cliques
        self.state = CacheState.fresh(CliquePartition.singletons(n), m)
        self.costs = CostBreakdown()

    # ------------------------------------------------------------------
    # Alg. 1 Event 1 — install a freshly generated partition
    # ------------------------------------------------------------------
    def install_partition(
        self,
        partition: CliquePartition,
        now: float,
        window_items: np.ndarray | None = None,
        window_servers: np.ndarray | None = None,
    ) -> None:
        """Translate cache state onto the new partition.

        * cliques identical to a previous clique keep their row (and anchor);
        * changed cliques are present at j iff EVERY member was nominally
          alive at j (presence = min of member expiries);
        * newly formed multi-item cliques are seeded with one packed copy at
          the server that accessed their members most during the window
          (Alg. 1 line 5), free of charge (packing runs in the background,
          §III.C).
        """
        old = self.state
        old_index: dict[tuple[int, ...], int] = {
            c: i for i, c in enumerate(old.partition.cliques)
        }
        # nominal per-item expiry under the old partition
        item_E = old.E[old.partition.clique_of]          # (n, m)
        k = partition.k
        E = np.zeros((k, self.m), dtype=np.float64)
        anchor = np.full(k, -1, dtype=np.int32)

        seed_counts = None
        if (
            self.seed_new_cliques
            and window_items is not None
            and window_servers is not None
        ):
            # item -> per-server access counts over the window
            seed_counts = np.zeros((self.n, self.m), dtype=np.int64)
            reps = (window_items >= 0).sum(axis=1)
            srv = np.repeat(window_servers, reps)
            itm = window_items[window_items >= 0]
            np.add.at(seed_counts, (itm, srv), 1)

        for i, c in enumerate(partition.cliques):
            prev_i = old_index.get(c)
            if prev_i is not None:
                E[i] = old.E[prev_i]
                anchor[i] = old.anchor[prev_i]
                continue
            members = list(c)
            rows = item_E[members]                       # (|c|, m)
            present = (rows > now).all(axis=0)
            E[i] = np.where(present, rows.min(axis=0), 0.0)
            if E[i].max() > 0:
                anchor[i] = int(np.argmax(E[i]))
            elif len(c) > 1 and seed_counts is not None:
                j = int(np.argmax(seed_counts[members].sum(axis=0)))
                E[i, j] = now + self.params.dt
                anchor[i] = j
        self.state = CacheState(partition=partition, E=E, anchor=anchor, m=self.m)

    # ------------------------------------------------------------------
    # Alg. 5 — request handling
    # ------------------------------------------------------------------
    def handle_request(
        self, items: Iterable[int], server: int, t: float
    ) -> RequestOutcome:
        p = self.params
        st = self.state
        items = [int(d) for d in items if d >= 0]
        cids: dict[int, int] = {}                 # clique id -> |D_i ∩ c|
        for d in items:
            c = int(st.partition.clique_of[d])
            cids[c] = cids.get(c, 0) + 1
        out = RequestOutcome(cliques=sorted(cids), misses=[], transfer=0.0, caching=0.0)
        for c, n_req in sorted(cids.items()):
            size = len(st.partition.cliques[c])
            alive = st.is_alive(c, server, t)
            if not alive:
                ct = p.transfer_cost(size, packed=size > 1)
                out.transfer += ct
                out.misses.append(c)
                out.n_missed_items += n_req
                self.costs.n_misses += 1
                self.costs.items_transferred += size
                e_eff = t
            else:
                self.costs.n_hits += 1
                e_eff = st.ratcheted_expiry(c, server, t, p.dt)
                if st.E[c, server] <= t:          # lazily account Alg.6 rent
                    self.costs.keepalive_rent += p.caching_cost(
                        size, e_eff - st.E[c, server]
                    )
            n_charged = n_req if self.caching_charge == "requested" else size
            new_e = t + p.dt
            ccost = p.caching_cost(n_charged, max(0.0, new_e - max(e_eff, t)))
            out.caching += ccost
            if not alive:
                out.caching_miss += ccost
            st.touch(c, server, new_e)
        self.costs.transfer += out.transfer
        self.costs.caching += out.caching
        self.costs.n_requests += 1
        self.costs.n_item_requests += len(items)
        return out

    # ------------------------------------------------------------------
    def replay(
        self,
        trace,
        clique_generator: Callable[[np.ndarray, np.ndarray, float], CliquePartition | None]
        | None = None,
        t_cg: float | None = None,
        progress: Callable[[int], None] | None = None,
    ) -> CostBreakdown:
        """Replay a full trace.

        ``clique_generator(window_items, window_servers, now)`` is invoked at
        every T_CG boundary with the PREVIOUS window's requests (Alg. 1
        Event 1, Fig. 3 timeline) and returns the new partition (or None to
        keep the current one).
        """
        times, servers, items = trace.times, trace.servers, trace.items
        next_cg = times[0] + t_cg if (t_cg is not None) else np.inf
        win_start = 0
        for i in range(times.shape[0]):
            t = float(times[i])
            if clique_generator is not None and t >= next_cg:
                w_it = items[win_start:i]
                w_sv = servers[win_start:i]
                part = clique_generator(w_it, w_sv, t)
                if part is not None:
                    self.install_partition(part, t, w_it, w_sv)
                win_start = i
                while next_cg <= t:
                    next_cg += t_cg
            self.handle_request(items[i], int(servers[i]), t)
            if progress is not None and (i & 0xFFFF) == 0:
                progress(i)
        return self.costs
