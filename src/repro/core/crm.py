"""Normalised co-access correlation matrix (paper Alg. 2).

For every request window ``W`` (the requests of the last ``T_CG`` period), the
CDN builds a raw co-occurrence matrix ``CRM[i1, i2] = #requests containing
both i1 and i2``, min-max normalises it and binarises at threshold ``theta``.

To bound the cost of this (the paper limits the matrix to the top-x% hottest
items of the window) we map the window's hot items into a compact index space
first; items outside the hot set never receive CRM edges and therefore stay
singleton cliques.

TPU path: counting co-occurrences is a rank-B update ``CRM += H^T @ H`` with
``H`` the one-hot request/item incidence matrix, i.e. a matmul, which is what
``repro.kernels.crm_update`` implements on the MXU.  The numpy path below is
the oracle used by the simulator and the tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class WindowCRM:
    """CRM of one window restricted to that window's hot items."""

    hot_items: np.ndarray       # (h,) int32 global item ids, sorted
    raw: np.ndarray             # (h, h) int32 co-occurrence counts
    norm: np.ndarray            # (h, h) float32 min-max normalised
    binary: np.ndarray          # (h, h) bool   norm > theta

    @property
    def n_hot(self) -> int:
        return int(self.hot_items.shape[0])

    def edge_set(self) -> set[tuple[int, int]]:
        """Binary edges as a set of (global_u, global_v), u < v."""
        iu, iv = np.nonzero(np.triu(self.binary, k=1))
        gu = self.hot_items[iu]
        gv = self.hot_items[iv]
        return {(int(a), int(b)) for a, b in zip(gu, gv)}


def incidence_matrix(items: np.ndarray, n: int) -> np.ndarray:
    """One-hot request/item incidence H (B, n) from padded item ids.

    ``items``: (B, d_max) int32, padded with -1.
    """
    B = items.shape[0]
    H = np.zeros((B, n), dtype=np.float32)
    req_idx, col = np.nonzero(items >= 0)
    H[req_idx, items[req_idx, col]] = 1.0
    return H


def cooccurrence_counts(items: np.ndarray, n: int) -> np.ndarray:
    """Raw CRM(W): symmetric co-occurrence counts with zero diagonal.

    Exactly Alg. 2 lines 1-4: for every request, every unordered item pair
    increments both symmetric entries once.
    """
    H = incidence_matrix(items, n)
    crm = (H.T @ H).astype(np.int64)
    np.fill_diagonal(crm, 0)
    return crm


def minmax_normalise(crm: np.ndarray) -> np.ndarray:
    """Min-max scaling to [0, 1] (Alg. 2 line 5)."""
    lo = crm.min()
    hi = crm.max()
    if hi <= lo:
        return np.zeros_like(crm, dtype=np.float32)
    return ((crm - lo) / (hi - lo)).astype(np.float32)


def hot_items_of_window(
    items: np.ndarray, n: int, top_frac: float
) -> np.ndarray:
    """ids of the ``top_frac`` most frequently accessed items of the window."""
    flat = items[items >= 0]
    counts = np.bincount(flat, minlength=n)
    n_hot = max(1, int(round(n * top_frac)))
    order = np.argsort(-counts, kind="stable")
    hot = order[:n_hot]
    hot = hot[counts[hot] > 0]          # never include never-accessed items
    return np.sort(hot).astype(np.int32)


def build_window_crm(
    items: np.ndarray,
    n: int,
    theta: float,
    top_frac: float = 0.1,
    crm_matmul=None,
) -> WindowCRM:
    """Alg. 2 end to end for one window.

    ``crm_matmul``: optional accelerated ``(H) -> H^T H`` implementation
    (e.g. the Pallas kernel wrapper); defaults to numpy.
    """
    hot = hot_items_of_window(items, n, top_frac)
    h = hot.shape[0]
    # remap window items into the compact hot index space; cold items -> -1
    lut = np.full(n, -1, dtype=np.int32)
    lut[hot] = np.arange(h, dtype=np.int32)
    compact = np.where(items >= 0, lut[np.clip(items, 0, n - 1)], -1)
    if crm_matmul is None:
        raw = cooccurrence_counts(compact, h)
    else:
        H = incidence_matrix(compact, h)
        raw = np.asarray(crm_matmul(H)).astype(np.int64)
        np.fill_diagonal(raw, 0)
    norm = minmax_normalise(raw)
    binary = norm > theta
    np.fill_diagonal(binary, False)
    return WindowCRM(hot_items=hot, raw=raw, norm=norm, binary=binary)


def edge_diff(
    prev: WindowCRM | None, cur: WindowCRM
) -> tuple[set[tuple[int, int]], set[tuple[int, int]]]:
    """Delta-E between consecutive binary CRMs in GLOBAL item ids (Alg. 4 input).

    Returns (added_edges, removed_edges).
    """
    cur_edges = cur.edge_set()
    prev_edges = prev.edge_set() if prev is not None else set()
    return cur_edges - prev_edges, prev_edges - cur_edges
