"""Normalised co-access correlation matrix (paper Alg. 2).

For every request window ``W`` (the requests of the last ``T_CG`` period), the
CDN builds a raw co-occurrence matrix ``CRM[i1, i2] = #requests containing
both i1 and i2``, min-max normalises it and binarises at threshold ``theta``.

To bound the cost of this, the paper limits the matrix to the top-x% hottest
items *of the window* (§V.A).  ``top_frac`` is therefore taken over the
window's accessed-item support by default; ``top_frac_of="catalog"`` keeps
the historical fraction-of-n semantics for cost parity with earlier runs.
Hot items are mapped into a compact index space first; items outside the hot
set never receive CRM edges and therefore stay singleton cliques.

TPU path: counting co-occurrences is a rank-B update ``CRM += H^T @ H`` with
``H`` the one-hot request/item incidence matrix, i.e. a matmul, which is what
``repro.kernels.crm_update`` implements on the MXU.  The numpy path
accumulates the same counts from the window's item pairs directly (requests
are short, so the pair list is ~d_max^2 per request — far smaller than the
dense (B, h) incidence product) and is bit-identical to the matmul form.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: padded-row width above which the pairwise scatter would materialise more
#: index pairs than the dense incidence product it replaces
_SCATTER_MAX_WIDTH = 128


@dataclasses.dataclass(frozen=True)
class WindowCRM:
    """CRM of one window restricted to that window's hot items."""

    hot_items: np.ndarray       # (h,) int32 global item ids, sorted
    raw: np.ndarray             # (h, h) int32 co-occurrence counts
    norm: np.ndarray            # (h, h) float32 min-max normalised
    binary: np.ndarray          # (h, h) bool   norm > theta

    @property
    def n_hot(self) -> int:
        return int(self.hot_items.shape[0])

    def edge_set(self) -> set[tuple[int, int]]:
        """Binary edges as a set of (global_u, global_v), u < v."""
        iu, iv = np.nonzero(np.triu(self.binary, k=1))
        gu = self.hot_items[iu]
        gv = self.hot_items[iv]
        return {(int(a), int(b)) for a, b in zip(gu, gv)}

    def embed(
        self, n: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Embed the compact hot-space CRM into full ``(n, n)`` catalog
        space: ``(hot_mask (n,), raw f32, norm f32, binary bool)``.

        Zeros everywhere outside the hot set, so an Alg.-4 edge diff of
        two full-space binaries equals the host's union-hot-space diff —
        the static-shape carry layout of the device-resident CGM
        (``core.cgm_jax``).  Raw counts stay exact in f32 (they are small
        integers, bounded by the window request count).
        """
        hot = np.zeros(n, bool)
        raw = np.zeros((n, n), np.float32)
        norm = np.zeros((n, n), np.float32)
        binary = np.zeros((n, n), bool)
        if self.hot_items.size:
            hi = np.asarray(self.hot_items)
            ix = np.ix_(hi, hi)
            hot[hi] = True
            raw[ix] = self.raw.astype(np.float32)
            norm[ix] = self.norm
            binary[ix] = self.binary
        return hot, raw, norm, binary

    @classmethod
    def from_full(cls, hot_mask, raw, norm, binary) -> "WindowCRM":
        """Inverse of :meth:`embed`: compact full-space arrays back to the
        hot index space (device carry -> host ``WindowCRM``)."""
        hot = np.nonzero(np.asarray(hot_mask))[0].astype(np.int32)
        ix = np.ix_(hot, hot)
        return cls(
            hot_items=hot,
            raw=np.asarray(raw)[ix].astype(np.int64),
            norm=np.asarray(norm)[ix].astype(np.float32),
            binary=np.asarray(binary)[ix].astype(bool),
        )

    @classmethod
    def from_compact(cls, p_idx, raw, norm, binary, *, n: int) -> "WindowCRM":
        """Device compact carry -> host ``WindowCRM``.

        ``p_idx`` is the padded (h,) hot->catalog index map (ascending
        real ids first, pads = n); ``raw``/``norm``/``binary`` are the
        (h, h) workspace matrices.  Trims the pad tail — the device
        keeps pad rows/cols zeroed, so the leading (nh, nh) block IS the
        host hot-space CRM (raw counts are exact f32 integers, restored
        to int64 here).
        """
        p_idx = np.asarray(p_idx)
        nh = int((p_idx < n).sum())
        return cls(
            hot_items=p_idx[:nh].astype(np.int32),
            raw=np.asarray(raw)[:nh, :nh].astype(np.int64),
            norm=np.asarray(norm)[:nh, :nh].astype(np.float32),
            binary=np.asarray(binary)[:nh, :nh].astype(bool),
        )


def incidence_matrix(items: np.ndarray, n: int) -> np.ndarray:
    """One-hot request/item incidence H (B, n) from padded item ids.

    ``items``: (B, d_max) int32, padded with -1.
    """
    B = items.shape[0]
    H = np.zeros((B, n), dtype=np.float32)
    req_idx, col = np.nonzero(items >= 0)
    H[req_idx, items[req_idx, col]] = 1.0
    return H


def cooccurrence_counts(items: np.ndarray, n: int) -> np.ndarray:
    """Raw CRM(W): symmetric co-occurrence counts with zero diagonal.

    Exactly Alg. 2 lines 1-4: for every request, every unordered item pair
    increments both symmetric entries once.  Counts come from a unique-key
    reduction over the window's (request-deduplicated) item pairs — the
    sparse equivalent of ``H^T @ H`` with 0/1 incidence, identical output.
    """
    items = np.asarray(items)
    crm = np.zeros((n, n), dtype=np.int64)
    if items.ndim != 2 or 0 in items.shape:
        return crm
    B, d = items.shape
    if d > _SCATTER_MAX_WIDTH or B * n * n <= (1 << 25):
        # wide rows, or an index space so small the dense product is cheaper
        # than sorting the window
        H = incidence_matrix(items, n)
        crm[...] = (H.T @ H).astype(np.int64)
        np.fill_diagonal(crm, 0)
        return crm
    # incidence is 0/1: an item repeated inside one request counts once
    s = np.sort(items, axis=1)
    dup = s[:, 1:] == s[:, :-1]
    if dup.any():
        s[:, 1:][dup] = -1
        s = np.sort(s, axis=1)          # re-pack valid ids into the tail
    c = (s >= 0).sum(axis=1)            # distinct items per request
    key_parts = []
    for cc in np.unique(c):             # group rows by cardinality: the pair
        if cc < 2:                      # grid is sum(c_r^2), not B * d^2
            continue
        rows = s[c == cc, d - cc:].astype(np.int64)
        ii, jj = np.nonzero(~np.eye(cc, dtype=bool))
        key_parts.append((rows[:, ii] * n + rows[:, jj]).ravel())
    if key_parts:
        keys = np.concatenate(key_parts)
        if n * n <= (1 << 22):          # count in place: O(keys + n^2)
            crm.reshape(-1)[:] = np.bincount(keys, minlength=n * n)
        else:
            uk, uc = np.unique(keys, return_counts=True)
            crm.reshape(-1)[uk] = uc
    return crm


def minmax_normalise(crm: np.ndarray) -> np.ndarray:
    """Min-max scaling to [0, 1] (Alg. 2 line 5)."""
    lo = crm.min()
    hi = crm.max()
    if hi <= lo:
        return np.zeros_like(crm, dtype=np.float32)
    if lo == 0:                         # the common case: skip the subtract
        return (crm / hi).astype(np.float32)
    return ((crm - lo) / (hi - lo)).astype(np.float32)


def hot_items_of_window(
    items: np.ndarray, n: int, top_frac: float, top_frac_of: str = "window"
) -> np.ndarray:
    """ids of the ``top_frac`` most frequently accessed items of the window.

    ``top_frac_of="window"`` (default, paper §V.A) takes the fraction over
    the window's distinct accessed items, so a sparse window on a huge
    catalog yields a proportionally small CRM.  ``"catalog"`` reproduces the
    historical fraction-of-n hot-set size (every accessed item is hot
    whenever the window support is below ``n * top_frac``).
    """
    if top_frac_of not in ("window", "catalog"):
        raise ValueError(
            f"top_frac_of must be 'window' or 'catalog', got {top_frac_of!r}"
        )
    flat = items[items >= 0]
    counts = np.bincount(flat, minlength=n)
    base = n if top_frac_of == "catalog" else int((counts > 0).sum())
    n_hot = max(1, int(round(base * top_frac)))
    order = np.argsort(-counts, kind="stable")
    hot = order[:n_hot]
    hot = hot[counts[hot] > 0]          # never include never-accessed items
    return np.sort(hot).astype(np.int32)


def build_window_crm(
    items: np.ndarray,
    n: int,
    theta: float,
    top_frac: float = 0.1,
    crm_matmul=None,
    top_frac_of: str = "window",
) -> WindowCRM:
    """Alg. 2 end to end for one window.

    ``crm_matmul``: optional accelerated ``(H) -> H^T H`` implementation
    (e.g. the Pallas kernel wrapper); defaults to the numpy pair scatter.
    ``top_frac_of``: hot-set denominator, see :func:`hot_items_of_window`.
    """
    hot = hot_items_of_window(items, n, top_frac, top_frac_of)
    h = hot.shape[0]
    # remap window items into the compact hot index space; cold items -> -1
    lut = np.full(n, -1, dtype=np.int32)
    lut[hot] = np.arange(h, dtype=np.int32)
    compact = np.where(items >= 0, lut[np.clip(items, 0, n - 1)], -1)
    if crm_matmul is None:
        raw = cooccurrence_counts(compact, h)
    else:
        H = incidence_matrix(compact, h)
        raw = np.asarray(crm_matmul(H)).astype(np.int64)
        np.fill_diagonal(raw, 0)
    norm = minmax_normalise(raw)
    binary = norm > theta
    np.fill_diagonal(binary, False)
    return WindowCRM(hot_items=hot, raw=raw, norm=norm, binary=binary)


def edge_diff(
    prev: WindowCRM | None, cur: WindowCRM
) -> tuple[set[tuple[int, int]], set[tuple[int, int]]]:
    """Delta-E between consecutive binary CRMs as Python sets (legacy form).

    Returns (added_edges, removed_edges) in GLOBAL item ids.  The CGM hot
    path uses :func:`edge_diff_arrays`; this set form remains for tests and
    the scalar oracle.
    """
    cur_edges = cur.edge_set()
    prev_edges = prev.edge_set() if prev is not None else set()
    return cur_edges - prev_edges, prev_edges - cur_edges


def edge_diff_arrays(
    prev: WindowCRM | None, cur: WindowCRM
) -> tuple[np.ndarray, np.ndarray]:
    """Delta-E between consecutive binary CRMs as (e, 2) int64 arrays.

    Boolean-matrix diff over the union hot index space (Alg. 4 input):
    rows are (global_u, global_v) with u < v, lexicographically sorted —
    the same order the scalar oracle iterates its edge sets in.
    """
    if prev is None:
        iu, iv = np.nonzero(np.triu(cur.binary, k=1))
        added = np.stack(
            [cur.hot_items[iu], cur.hot_items[iv]], axis=1
        ).astype(np.int64)
        return added, np.zeros((0, 2), dtype=np.int64)
    union = np.union1d(prev.hot_items, cur.hot_items)
    U = union.shape[0]
    P = np.zeros((U, U), dtype=bool)
    C = np.zeros((U, U), dtype=bool)
    pi = np.searchsorted(union, prev.hot_items)
    ci = np.searchsorted(union, cur.hot_items)
    P[np.ix_(pi, pi)] = prev.binary
    C[np.ix_(ci, ci)] = cur.binary
    au, av = np.nonzero(np.triu(C & ~P, k=1))
    ru, rv = np.nonzero(np.triu(P & ~C, k=1))
    added = np.stack([union[au], union[av]], axis=1).astype(np.int64)
    removed = np.stack([union[ru], union[rv]], axis=1).astype(np.int64)
    return added, removed
