"""Competitive analysis machinery (paper §IV.D, Theorems 1 and 2).

* ``competitive_bound(S, omega, alpha)`` (cost.py) is the Thm-1 ratio.
* ``adversarial_trace`` realises the Thm-2 adversary: phases of requests for
  S always-fresh items, each belonging to a DISTINCT pre-established clique
  of size exactly omega, issued > dt apart so every phase misses.
* ``per_request_ratio_check`` replays any trace and verifies Thm-1 request by
  request: AKPC's realised cost for r_i divided by the theorem's OPT model
  for r_i (one packed transfer of the S missed items; pure caching on full
  hits) never exceeds the bound.  Used by the hypothesis property tests.
* ``generalized_bound`` / ``generalized_per_request_ratio_check`` are the
  file-bundle generalisation (Qin & Etesami's optimal-online framework,
  arXiv 2011.03212): both sides of the worst request are priced through the
  registered CostModel HOOKS instead of the Table-I closed form, so the
  bound follows per-server prices, item volumes and nonlinear (tiered)
  transfer schedules with no per-model algebra.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..traces.loader import Trace
from .cliques import CliquePartition
from .cost import (
    CacheEnvironment,
    CostParams,
    competitive_bound,
    competitive_bound_corrected,
    competitive_bound_env,
    get_cost_model,
)
from .engine import ReplayEngine


@dataclasses.dataclass(frozen=True)
class AdversarySetup:
    trace: Trace
    partition: CliquePartition
    S: int
    omega: int


def adversarial_trace(
    S: int,
    omega: int,
    n_phases: int,
    params: CostParams,
    server: int = 0,
    m: int = 4,
) -> AdversarySetup:
    """Thm-2 adversary: phase l_i requests S uncached items of distinct
    omega-cliques at one server, spaced > dt so earlier caches expired."""
    n = n_phases * S * omega
    cliques = [
        tuple(range(c * omega, (c + 1) * omega)) for c in range(n_phases * S)
    ]
    part = CliquePartition.from_cliques(n, cliques)
    d_max = S
    items = np.full((n_phases, d_max), -1, dtype=np.int32)
    for ph in range(n_phases):
        # one item from each of S distinct, never-seen cliques
        ids = [(ph * S + s) * omega for s in range(S)]
        items[ph, :S] = ids
    gap = 2.0 * params.dt
    times = (1.0 + np.arange(n_phases) * gap).astype(np.float64)
    servers = np.full(n_phases, server, dtype=np.int32)
    trace = Trace(times=times, servers=servers, items=items, n=n, m=m,
                  name=f"adversary-S{S}-w{omega}")
    return AdversarySetup(trace=trace, partition=part, S=S, omega=omega)


def replay_adversary(
    setup: AdversarySetup,
    params: CostParams,
    env: CacheEnvironment | None = None,
    cost_model="table1",
) -> tuple[float, float, float]:
    """Returns (akpc_cost, opt_cost_model, bound).

    Thm 2: the realised ratio equals the bound EXACTLY — for the bound that
    actually follows from the paper's case analysis (competitive_bound_
    corrected; the paper's printed closed form has an algebra slip, see
    cost.py).  With a heterogeneous ``env`` the OPT model prices each
    phase's packed transfer under the SAME cost model and the bound is the
    max-over-servers generalisation ``competitive_bound_env``.
    """
    eng = ReplayEngine(setup.trace.n, setup.trace.m, params,
                       caching_charge="requested", seed_new_cliques=False,
                       env=env, cost_model=cost_model)
    eng.install_partition(setup.partition, now=0.0)
    eng.replay(setup.trace, clique_generator=None)
    akpc = eng.costs.total
    S = setup.S
    # resolved model name (so CostModel instances hit the same branch as
    # their registry names); the Table-I closed form requires BOTH a
    # homogeneous scenario and Table-I pricing — a custom model with a
    # default env must still price OPT under its own hooks
    homogeneous = env is None or env.homogeneous
    if homogeneous and eng.model.name == "table1":
        per_phase_opt = (1.0 + (S - 1) * params.alpha) * params.lam
        opt = per_phase_opt * setup.trace.n_requests
        bound = competitive_bound_corrected(S, setup.omega, params.alpha)
    else:
        tr = setup.trace
        sizes = eng.env.sizes()
        mask = tr.items >= 0
        vols = np.where(mask, sizes[np.maximum(tr.items, 0)], 0.0).sum(axis=1)
        opt = float(eng.model.transfer_cost_batch(
            np.full(tr.n_requests, S, dtype=np.int64), vols,
            tr.servers.astype(np.int64)).sum())
        bound = competitive_bound_env(eng.env, S, setup.omega)
    return akpc, opt, bound


def generalized_bound(
    env: CacheEnvironment,
    S: int,
    omega: int,
    cost_model="table1",
) -> float:
    """File-bundle generalisation of the corrected Thm-1 bound, priced
    through the registered CostModel hooks (Qin & Etesami, arXiv
    2011.03212, adapted to the keep-while-rented cache of this paper).

    Worst request at server j, S missed items: the online algorithm pays,
    per missed item, at most one full omega-clique transfer of the
    largest items plus the prepaid ``dt_j`` rent for the item itself —

        C_on(j)  = S * [ T(omega, omega*s_max, j) + R(1, s_max, j)*dt_j ]

    while the offline optimum's request model pays a single packed
    transfer of the S missed items at the smallest volumes —

        C_opt(j) = T(S, S*s_min, j)

    with ``T``/``R`` the model's ``transfer_cost_batch``/``caching_rate``.
    The bound is ``max_j C_on(j)/C_opt(j)``.  Under ``table1`` this
    collapses to ``S*(1+(omega-1)*alpha+rho)/(1+(S-1)*alpha)`` — i.e.
    ``competitive_bound_corrected`` at rho = 1 — and under
    ``heterogeneous`` it reproduces ``competitive_bound_env`` exactly
    (tests pin both reductions); for tiered schedules it yields a bound
    no closed form covers.
    """
    if S < 1:
        raise ValueError("S must be >= 1")
    if omega < 1:
        raise ValueError("omega must be >= 1")
    model = get_cost_model(cost_model, env)
    m = max(env.m, 1)
    srv = np.arange(m, dtype=np.int64)
    sizes = env.sizes()
    s_max = float(sizes.max()) if sizes.size else 1.0
    s_min = float(sizes.min()) if sizes.size else 1.0
    dt_j = np.broadcast_to(
        np.asarray(model.dt(), np.float64), (m,))
    trans_on = np.asarray(model.transfer_cost_batch(
        np.full(m, omega, np.int64), np.full(m, omega * s_max), srv),
        np.float64)
    rent_on = np.asarray(model.caching_rate(
        np.ones(m, np.int64), np.full(m, s_max), srv), np.float64) * dt_j
    c_on = S * (trans_on + rent_on)
    c_opt = np.asarray(model.transfer_cost_batch(
        np.full(m, S, np.int64), np.full(m, S * s_min), srv), np.float64)
    return float(np.max(c_on / np.maximum(c_opt, 1e-300)))


def generalized_per_request_ratio_check(
    trace: Trace,
    partition: CliquePartition,
    params: CostParams,
    env: CacheEnvironment | None = None,
    cost_model="table1",
) -> float:
    """:func:`per_request_ratio_check` under the generalized bound: max
    over requests of (realised miss cost / hook-priced OPT request model),
    normalised by :func:`generalized_bound` at that request's S.  Returns
    the worst slack ratio (<= 1.0 iff the generalized bound holds on this
    trace under this cost model).
    """
    eng = ReplayEngine(trace.n, trace.m, params,
                       caching_charge="requested", seed_new_cliques=False,
                       env=env, cost_model=cost_model)
    eng.install_partition(partition, now=0.0)
    omega = max(len(c) for c in partition.cliques)
    sizes = eng.env.sizes()
    s_min = float(sizes.min()) if sizes.size else 1.0
    bounds: dict[int, float] = {}
    worst = 0.0
    for i in range(trace.n_requests):
        out = eng.handle_request(
            trace.items[i], int(trace.servers[i]), float(trace.times[i]))
        S = out.n_missed_items
        if S == 0:
            continue                       # cases 1.2/2.2: identical costs
        cost_i = out.transfer + out.caching_miss
        srv = np.array([int(trace.servers[i])], np.int64)
        opt_i = float(eng.model.transfer_cost_batch(
            np.array([S], np.int64), np.array([S * s_min]), srv)[0])
        if S not in bounds:
            bounds[S] = generalized_bound(eng.env, S, omega, eng.model)
        worst = max(worst, (cost_i / opt_i) / bounds[S])
    return worst


def per_request_ratio_check(
    trace: Trace,
    partition: CliquePartition,
    params: CostParams,
) -> float:
    """Max over requests of (AKPC miss cost) / (Thm-1 OPT request model),
    normalised by the corrected Thm-1 bound.

    Per the theorem's case analysis, a request with S uncached items costs
    AKPC at most S*(2+(omega-1)*alpha)*lam (clique transfers + dt rent for
    the missed items) while the OPT model pays one packed transfer
    (1+(S-1)*alpha)*lam; full-hit requests costs are identical (caching
    only).  Returns the worst slack ratio realised/bound (<= 1.0 iff the
    corrected theorem holds on this trace).
    """
    eng = ReplayEngine(trace.n, trace.m, params,
                       caching_charge="requested", seed_new_cliques=False)
    eng.install_partition(partition, now=0.0)
    omega = max(len(c) for c in partition.cliques)
    worst = 0.0
    for i in range(trace.n_requests):
        t = float(trace.times[i])
        out = eng.handle_request(trace.items[i], int(trace.servers[i]), t)
        S = out.n_missed_items
        if S == 0:
            continue                       # cases 1.2/2.2: identical costs
        cost_i = out.transfer + out.caching_miss
        opt_i = (1.0 + (S - 1) * params.alpha) * params.lam
        bound = competitive_bound_corrected(S, omega, params.alpha)
        worst = max(worst, (cost_i / opt_i) / bound)
    return worst
