"""Unified cache-policy layer: protocol, registry, result type, offline driver.

The paper's algorithms (AKPC and the evaluation baselines of §V.B) used to be
exposed as bespoke ``run_*`` functions with divergent result types
(``CostBreakdown`` vs ``AKPCResult``) that all demanded the full ``Trace`` up
front.  This module redesigns that surface around one abstraction:

* ``CachePolicy`` — the protocol every caching method implements:

  - ``on_window(items, servers, now)``  the clique-generation hook invoked at
    every T_CG boundary with the previous window's requests (Alg. 1 Event 1);
    returns the new :class:`CliquePartition` or ``None`` to keep the current
    one.  Policies without a regeneration loop set ``t_cg = None`` and the
    hook is never called.
  - ``initial_partition(trace)``  optional full-trace-knowledge hook for
    OFFLINE methods (DP_Greedy); online policies return ``None``.
  - ``state_dict()`` / ``load_state_dict()``  snapshotable policy state (the
    previous window's CRM, window counters, ...) for mid-stream
    checkpointing by :class:`repro.core.session.CacheSession`.

* a registry — :func:`register_policy` / :func:`get_policy` /
  :func:`list_policies` — naming the paper's method set: ``akpc`` (plus the
  ablations ``akpc_no_acm`` and ``akpc_base``), ``packcache`` (online
  2-packing), ``dp_greedy`` (offline 2-packing), ``no_packing``.

* ``RunResult`` — one result type subsuming the old split: cost breakdown,
  final clique sizes, per-window size history, window count, clique-gen
  seconds and wall seconds.

* ``run_policy`` — the offline driver (full-``Trace`` batched replay).  The
  streaming driver is ``repro.core.session.CacheSession``; both reproduce the
  same costs (tests/test_policy_session.py).

The legacy ``run_*`` functions in ``akpc.py`` / ``baselines.py`` are thin
shims over this registry and stay cost-for-cost identical.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Any, Callable, Protocol, runtime_checkable

import numpy as np

from .akpc import AKPCConfig
from .cliques import CliquePartition, generate_cliques
from .cost import CacheEnvironment, CostBreakdown, CostModel, CostParams
from .crm import WindowCRM, build_window_crm
from .engine import CachingCharge, ReplayEngine


# ---------------------------------------------------------------------------
# unified result
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RunResult:
    """What any policy run returns (subsumes CostBreakdown + AKPCResult)."""

    policy: str
    costs: CostBreakdown
    clique_sizes: np.ndarray         # sizes of all cliques, final partition
    size_history: list[np.ndarray]   # per-window non-singleton size arrays
    n_windows: int
    cg_seconds: float                # clique-generation wall time
    wall_seconds: float              # end-to-end replay wall time
    config: Any = None               # the policy's config object (if any)
    #: per-shard dispersion when the point carried a trace-shard axis
    #: (SweepPoint with a sequence of traces): {"n", "totals", "mean",
    #: "std", "ci95"} over the per-shard total costs; None otherwise
    shard_stats: dict | None = None

    @property
    def total(self) -> float:
        return self.costs.total

    @property
    def transfer(self) -> float:
        return self.costs.transfer

    @property
    def caching(self) -> float:
        return self.costs.caching

    def as_dict(self) -> dict:
        d = self.costs.as_dict()
        d.update(
            policy=self.policy,
            n_windows=self.n_windows,
            cg_seconds=self.cg_seconds,
            wall_seconds=self.wall_seconds,
        )
        return d


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------
@runtime_checkable
class CachePolicy(Protocol):
    """Structural protocol implemented by every registered policy."""

    name: str
    params: CostParams
    t_cg: float | None               # regeneration period; None = never

    def bind(self, n: int, m: int) -> None:
        """Reset per-run state for a catalog of n items and m servers."""
        ...

    def on_window(
        self, items: np.ndarray, servers: np.ndarray, now: float
    ) -> CliquePartition | None:
        """Alg. 1 Event 1: mine the window, return the new partition."""
        ...


class BasePolicy:
    """Shared plumbing: window bookkeeping + snapshotable state.

    Subclasses set ``name``/``t_cg`` and implement ``on_window`` (calling
    :meth:`_record` with the produced partition) and, for offline methods,
    :meth:`initial_partition`.
    """

    name = "base"
    t_cg: float | None = None
    caching_charge: CachingCharge = "requested"
    seed_new_cliques: bool = True
    batch_size: int | None = None
    config: Any = None

    def __init__(
        self,
        params: CostParams | None = None,
        env: CacheEnvironment | None = None,
        cost_model: str | CostModel = "table1",
    ):
        if params is None:
            params = env.params if env is not None else CostParams()
        self.params = params
        self.env = env                  # None = derive from the trace/catalog
        self.cost_model = cost_model
        self.bind(0, 0)

    # -- lifecycle ---------------------------------------------------------
    def bind(self, n: int, m: int) -> None:
        self.n = n
        self.m = m
        self._partition: CliquePartition | None = None
        self.size_history: list[np.ndarray] = []
        self.n_windows = 0
        self.cg_seconds = 0.0

    # -- hooks -------------------------------------------------------------
    def initial_partition(self, trace=None) -> CliquePartition | None:
        return None

    def on_window(
        self, items: np.ndarray, servers: np.ndarray, now: float
    ) -> CliquePartition | None:
        return None

    def _record(self, part: CliquePartition, seconds: float) -> None:
        self._partition = part
        self.cg_seconds += seconds
        self.n_windows += 1
        sizes = part.sizes()
        self.size_history.append(sizes[sizes > 1])

    # -- snapshot ----------------------------------------------------------
    def state_dict(self) -> dict:
        """Pure-numpy pytree of the policy's mutable state."""
        hist = self.size_history
        return {
            "n_windows": np.int64(self.n_windows),
            "cg_seconds": np.float64(self.cg_seconds),
            "size_hist": (
                np.concatenate(hist).astype(np.int64)
                if hist else np.zeros(0, np.int64)
            ),
            "size_hist_lens": np.array([len(a) for a in hist], np.int64),
        }

    def load_state_dict(
        self, state: dict, partition: CliquePartition | None = None
    ) -> None:
        self.n_windows = int(state["n_windows"])
        self.cg_seconds = float(state["cg_seconds"])
        flat = np.asarray(state["size_hist"])
        lens = np.asarray(state["size_hist_lens"]).astype(np.int64)
        self.size_history = [
            a.astype(np.int32) for a in np.split(flat, np.cumsum(lens)[:-1])
        ] if lens.size else []
        if partition is not None:
            self._partition = partition


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[..., CachePolicy]] = {}


def register_policy(name: str, *aliases: str):
    """Register a policy factory (usable as a class decorator)."""

    def deco(factory):
        for nm in (name, *aliases):
            if nm in _REGISTRY:
                raise ValueError(f"policy {nm!r} already registered")
            _REGISTRY[nm] = factory
        return factory

    return deco


def get_policy(name: str, **kwargs) -> CachePolicy:
    """Instantiate a registered policy by name (fresh state every call)."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None
    return factory(**kwargs)


def list_policies() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# pairwise matching shared by PackCache / DP_Greedy (moved from baselines.py)
# ---------------------------------------------------------------------------
def greedy_pair_matching(
    items: np.ndarray, n: int, theta: float, top_frac: float,
    top_frac_of: str = "window",
) -> CliquePartition:
    """Greedy max-weight matching of items into disjoint pairs.

    Edges come from the binary CRM of ``items`` (same Alg.-2 machinery the
    proposed method uses), weights from the normalised CRM; items left
    unmatched stay singletons.
    """
    crm = build_window_crm(items, n, theta, top_frac, top_frac_of=top_frac_of)
    w = np.where(crm.binary, crm.norm, 0.0)
    iu, iv = np.nonzero(np.triu(w, k=1))
    order = np.argsort(-w[iu, iv], kind="stable")
    used = np.zeros(crm.n_hot, dtype=bool)
    pairs: list[tuple[int, ...]] = []
    for e in order:
        a, b = int(iu[e]), int(iv[e])
        if used[a] or used[b]:
            continue
        used[a] = used[b] = True
        pairs.append((int(crm.hot_items[a]), int(crm.hot_items[b])))
    return CliquePartition.from_cliques(n, pairs)


# ---------------------------------------------------------------------------
# the paper's method set as registered policies
# ---------------------------------------------------------------------------
@register_policy("no_packing")
class NoPackingPolicy(BasePolicy):
    """Wang et al. [6]-style online TTL caching: no packing component."""

    name = "no_packing"
    t_cg = None

    def __init__(
        self,
        params: CostParams | None = None,
        caching_charge: CachingCharge = "requested",
        batch_size: int | None = None,
        env: CacheEnvironment | None = None,
        cost_model: str | CostModel = "table1",
    ):
        super().__init__(params, env=env, cost_model=cost_model)
        self.caching_charge = caching_charge
        self.batch_size = batch_size


@register_policy("ttl")
class TTLKeepOrNotPolicy(BasePolicy):
    """Keep-or-not TTL baseline (Le Scouarnec et al., arXiv 1312.0499).

    No packing: the partition is always the singleton partition.  At every
    T_CG boundary the previous window's request counts decide, per item,
    whether a cached copy pays for itself over the next window: item i is
    KEPT iff its window demand covers the rent of one copy,
    ``count_i * lam >= keep_factor * mu * t_cg``.  Items voted "nokeep"
    are never cached — every access is a forced miss priced as a plain
    transfer, realised through the engine's keep-or-not mask
    (:meth:`repro.core.engine.ReplayEngine.set_item_keep`), which the
    replay drivers sync via the :meth:`item_keep` hook.

    ``on_window`` always returns a partition (even though it never
    changes): keep-or-not policies must produce an install record at every
    boundary so the device schedule has a row to hang evictions on.
    """

    name = "ttl"

    def __init__(
        self,
        params: CostParams | None = None,
        t_cg: float = 50.0,
        keep_factor: float = 1.0,
        caching_charge: CachingCharge = "requested",
        batch_size: int | None = None,
        env: CacheEnvironment | None = None,
        cost_model: str | CostModel = "table1",
    ):
        super().__init__(params, env=env, cost_model=cost_model)
        self.t_cg = t_cg
        self.keep_factor = keep_factor
        self.caching_charge = caching_charge
        self.batch_size = batch_size

    def bind(self, n: int, m: int) -> None:
        super().bind(n, m)
        self._keep = np.ones(n, dtype=bool)

    def item_keep(self) -> np.ndarray:
        """Engine keep-or-not hook: the current per-item keep mask."""
        return self._keep

    def on_window(self, items, servers, now):
        del servers, now
        t0 = _time.perf_counter()
        flat = items[items >= 0]
        counts = np.bincount(flat, minlength=self.n).astype(np.float64)
        p = self.params
        self._keep = counts * p.lam >= self.keep_factor * p.mu * self.t_cg
        part = CliquePartition.singletons(self.n)
        self._record(part, _time.perf_counter() - t0)
        return part

    def state_dict(self) -> dict:
        d = super().state_dict()
        d["keep"] = self._keep.copy()
        return d

    def load_state_dict(self, state, partition=None) -> None:
        super().load_state_dict(state, partition)
        if "keep" in state:
            self._keep = np.asarray(state["keep"]).astype(bool).copy()


@register_policy("packcache", "packcache2")
class PackCache2Policy(BasePolicy):
    """Wu et al. [2]: ONLINE pairwise (2-)packing; FP-tree pair mining
    realised as max-weight greedy matching on the window CRM."""

    name = "packcache"

    def __init__(
        self,
        params: CostParams | None = None,
        t_cg: float = 50.0,
        top_frac: float = 0.1,
        top_frac_of: str = "window",
        caching_charge: CachingCharge = "requested",
        batch_size: int | None = None,
        env: CacheEnvironment | None = None,
        cost_model: str | CostModel = "table1",
    ):
        super().__init__(params, env=env, cost_model=cost_model)
        self.t_cg = t_cg
        self.top_frac = top_frac
        self.top_frac_of = top_frac_of
        self.caching_charge = caching_charge
        self.batch_size = batch_size

    def on_window(self, items, servers, now):
        del servers, now
        t0 = _time.perf_counter()
        part = greedy_pair_matching(items, self.n, self.params.theta,
                                    self.top_frac, self.top_frac_of)
        self._record(part, _time.perf_counter() - t0)
        return part


@register_policy("dp_greedy")
class DPGreedyPolicy(BasePolicy):
    """Huang et al. [4]: OFFLINE pairwise packing.  Pairs are matched on the
    CRM of the FULL trace (complete request knowledge) and kept fixed.

    For streaming use without a full trace, pass a precomputed ``partition``
    (e.g. mined from historical traffic)."""

    name = "dp_greedy"
    t_cg = None

    def __init__(
        self,
        params: CostParams | None = None,
        top_frac: float = 0.1,
        top_frac_of: str = "window",
        partition: CliquePartition | None = None,
        caching_charge: CachingCharge = "requested",
        batch_size: int | None = None,
        env: CacheEnvironment | None = None,
        cost_model: str | CostModel = "table1",
    ):
        self._user_partition = partition
        super().__init__(params, env=env, cost_model=cost_model)
        self.top_frac = top_frac
        self.top_frac_of = top_frac_of
        self.caching_charge = caching_charge
        self.batch_size = batch_size

    def bind(self, n: int, m: int) -> None:
        super().bind(n, m)
        self._fixed = self._user_partition

    def initial_partition(self, trace=None) -> CliquePartition | None:
        t0 = _time.perf_counter()
        if self._fixed is None:
            if trace is None:
                raise ValueError(
                    "dp_greedy is offline: construct it with a precomputed "
                    "`partition` or give the session/driver a full trace"
                )
            self._fixed = greedy_pair_matching(
                trace.items, trace.n, self.params.theta, self.top_frac,
                self.top_frac_of,
            )
        self._record(self._fixed, _time.perf_counter() - t0)
        return self._fixed


@register_policy("akpc")
class AKPCPolicy(BasePolicy):
    """Adaptive K-PackCache (the paper's proposed online algorithm, Alg. 1).

    The three ablation variants of Fig. 5/7/9 are registered separately:
    ``akpc`` (split + approximate merge), ``akpc_no_acm`` (split only) and
    ``akpc_base`` (neither; omega unused).
    """

    name = "akpc"

    def __init__(
        self,
        config: AKPCConfig | None = None,
        *,
        params: CostParams | None = None,
        t_cg: float | None = None,
        top_frac: float | None = None,
        top_frac_of: str | None = None,
        split: bool | None = None,
        approx_merge: bool | None = None,
        caching_charge: CachingCharge | None = None,
        seed_new_cliques: bool | None = None,
        batch_size: int | None = None,
        crm_matmul: Callable | None = None,
        pair_edges: Callable | None = None,
        kernels: str | None = None,
        name: str | None = None,
        env: CacheEnvironment | None = None,
        cost_model: str | CostModel = "table1",
    ):
        cfg = config or AKPCConfig()
        if params is None and env is not None:
            if cfg.params == CostParams():
                # a default-params config is "params unset": the env's
                # prices drive the algorithm too
                params = env.params
            elif cfg.params != env.params:
                # a CUSTOMIZED config params must not be silently clobbered
                # (nor silently ignored by the env-priced engine) — same
                # loud contract as ReplayEngine/opt_lower_bound
                raise ValueError(
                    "config.params and env.params disagree; build the "
                    "environment with the config's CostParams (or pass "
                    "params= explicitly)")
        over = {
            "params": params,
            "t_cg": t_cg,
            "top_frac": top_frac,
            "top_frac_of": top_frac_of,
            "enable_split": split,
            "enable_approx_merge": approx_merge,
            "caching_charge": caching_charge,
            "seed_new_cliques": seed_new_cliques,
            "batch_size": batch_size,
            "crm_matmul": crm_matmul,
            "pair_edges": pair_edges,
            "kernels": kernels,
        }
        cfg = dataclasses.replace(
            cfg, **{k: v for k, v in over.items() if v is not None}
        )
        self.config = cfg
        if name is not None:
            self.name = name
        super().__init__(cfg.params, env=env, cost_model=cost_model)
        self.t_cg = cfg.t_cg
        self.caching_charge = cfg.caching_charge
        self.seed_new_cliques = cfg.seed_new_cliques
        self.batch_size = cfg.batch_size

    def bind(self, n: int, m: int) -> None:
        super().bind(n, m)
        self._prev_crm: WindowCRM | None = None
        # kernel hooks: explicit config wins; "auto" wires the Pallas TPU
        # kernels in as defaults whenever a TPU backend is attached
        cfg = self.config
        mm, pe = cfg.crm_matmul, cfg.pair_edges
        if cfg.kernels == "auto" and (mm is None or pe is None):
            from ..kernels.autowire import default_cgm_hooks

            auto_mm, auto_pe = default_cgm_hooks()
            mm = mm if mm is not None else auto_mm
            pe = pe if pe is not None else auto_pe
        self._crm_matmul, self._pair_edges = mm, pe

    # -- Event 1: clique generation on a window of requests ----------------
    def on_window(self, items, servers, now):
        del servers, now
        cfg = self.config
        t0 = _time.perf_counter()
        crm = build_window_crm(
            items, self.n, cfg.params.theta, cfg.top_frac,
            crm_matmul=self._crm_matmul,
            top_frac_of=cfg.top_frac_of,
        )
        omega = cfg.params.omega if cfg.enable_split else self.n
        part = generate_cliques(
            self._partition,
            self._prev_crm,
            crm,
            self.n,
            omega,
            cfg.params.gamma,
            pair_edges=self._pair_edges,
            enable_split=cfg.enable_split,
            enable_approx_merge=cfg.enable_approx_merge,
        )
        self._prev_crm = crm
        self._record(part, _time.perf_counter() - t0)
        return part

    # -- snapshot (adds the previous window's CRM) -------------------------
    def state_dict(self) -> dict:
        d = super().state_dict()
        crm = self._prev_crm
        if crm is None:
            d["crm"] = {
                "present": np.int64(0),
                "hot_items": np.zeros(0, np.int32),
                "raw": np.zeros((0, 0), np.int64),
                "norm": np.zeros((0, 0), np.float32),
                "binary": np.zeros((0, 0), bool),
            }
        else:
            d["crm"] = {
                "present": np.int64(1),
                "hot_items": crm.hot_items.copy(),
                "raw": crm.raw.copy(),
                "norm": crm.norm.copy(),
                "binary": crm.binary.copy(),
            }
        return d

    def load_state_dict(self, state, partition=None) -> None:
        super().load_state_dict(state, partition)
        c = state["crm"]
        if int(c["present"]):
            self._prev_crm = WindowCRM(
                hot_items=np.asarray(c["hot_items"]).astype(np.int32),
                raw=np.asarray(c["raw"]).astype(np.int64),
                norm=np.asarray(c["norm"]).astype(np.float32),
                binary=np.asarray(c["binary"]).astype(bool),
            )
        else:
            self._prev_crm = None


register_policy("akpc_no_acm")(
    lambda **kw: AKPCPolicy(
        **{"split": True, "approx_merge": False, "name": "akpc_no_acm", **kw}
    )
)
register_policy("akpc_base")(
    lambda **kw: AKPCPolicy(
        **{"split": False, "approx_merge": False, "name": "akpc_base", **kw}
    )
)


def _learned_factory(**kw):
    # deferred: repro.learned.policy imports this module
    from ..learned.policy import LearnedPolicy

    return LearnedPolicy(**kw)


register_policy("learned")(_learned_factory)


# ---------------------------------------------------------------------------
# offline driver
# ---------------------------------------------------------------------------
def run_policy(
    policy: CachePolicy | str,
    trace,
    *,
    batch_size: int | None = None,
    progress: Callable[[int], None] | None = None,
    backend: str = "numpy",
) -> RunResult:
    """Replay a full trace under ``policy`` and return the unified result.

    Equivalent to driving a fresh :class:`~repro.core.session.CacheSession`
    with the whole trace, but runs through ``ReplayEngine.replay`` directly
    so the legacy ``run_*`` shims stay bit-identical to their pre-registry
    behaviour.

    ``backend="jax"`` swaps the replay core for the device-resident
    jit/scan engine (``repro.core.engine_jax``) — same RunResult, costs
    equal at 1e-9 (tests/test_sweep.py); grids of runs are faster still
    through :class:`repro.core.sweep.SweepEngine`.
    """
    if backend == "jax":
        from .engine_jax import run_policy_jax

        return run_policy_jax(
            policy, trace, batch_size=batch_size, progress=progress)
    if backend != "numpy":
        raise ValueError(f"unknown replay backend {backend!r}")
    if isinstance(policy, str):
        policy = get_policy(policy)
    t0 = _time.perf_counter()
    policy.bind(trace.n, trace.m)
    env = CacheEnvironment.resolve(
        getattr(policy, "env", None), trace, policy.params)
    eng = ReplayEngine(
        trace.n,
        trace.m,
        policy.params,
        caching_charge=getattr(policy, "caching_charge", "requested"),
        seed_new_cliques=getattr(policy, "seed_new_cliques", True),
        env=env,
        cost_model=getattr(policy, "cost_model", "table1"),
    )
    part0 = (
        policy.initial_partition(trace)
        if hasattr(policy, "initial_partition") else None
    )
    if part0 is not None:
        eng.install_partition(part0, now=0.0)
    gen = policy.on_window if policy.t_cg is not None else None
    bs = batch_size if batch_size is not None else getattr(policy, "batch_size", None)
    eng.replay(
        trace, clique_generator=gen, t_cg=policy.t_cg, progress=progress,
        batch_size=bs,
    )
    return RunResult(
        policy=policy.name,
        costs=eng.costs,
        clique_sizes=eng.state.partition.sizes(),
        size_history=list(getattr(policy, "size_history", [])),
        n_windows=getattr(policy, "n_windows", 0),
        cg_seconds=getattr(policy, "cg_seconds", 0.0),
        wall_seconds=_time.perf_counter() - t0,
        config=getattr(policy, "config", None),
    )
