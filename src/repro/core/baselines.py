"""Baselines of the paper's evaluation (§V.B).

* ``No Packing``  — every item transferred/cached individually (Wang et al.
  [6] style online TTL caching; no packing component).
* ``PackCache``   — Wu et al. [2]: ONLINE pairwise (2-)packing; we realise the
  FP-tree pair mining as max-weight greedy matching on the window CRM, which
  selects the same top co-accessed pairs, and reuse the shared replay engine.
* ``DP_Greedy``   — Huang et al. [4]: OFFLINE pairwise packing; pairs are
  matched on the CRM of the FULL trace (complete request knowledge) and kept
  fixed during replay.
* ``OPT``         — offline optimal.  True OPT is intractable; we compute a
  rigorous LOWER BOUND (every feasible schedule pays at least this much):
  per (item, server) access sequence, each first access costs at least the
  cheapest per-item packed transfer share  c_min = (alpha + (1-alpha)/omega)*lam
  and each re-access after gap g costs at least min(mu*g, c_min)  (either the
  item was kept cached over the gap, or it was re-transferred).  Costs ratios
  "vs OPT" reported by the benchmarks are therefore conservative (the real
  OPT can only be larger).
"""
from __future__ import annotations

import numpy as np

from ..traces.loader import Trace
from .cliques import CliquePartition
from .cost import CostBreakdown, CostParams
from .crm import build_window_crm
from .engine import CachingCharge, ReplayEngine


# ---------------------------------------------------------------------------
# No Packing
# ---------------------------------------------------------------------------
def run_no_packing(
    trace: Trace,
    params: CostParams,
    caching_charge: CachingCharge = "requested",
    batch_size: int | None = None,
) -> CostBreakdown:
    eng = ReplayEngine(trace.n, trace.m, params, caching_charge=caching_charge)
    return eng.replay(trace, clique_generator=None, batch_size=batch_size)


# ---------------------------------------------------------------------------
# pairwise matching shared by PackCache / DP_Greedy
# ---------------------------------------------------------------------------
def greedy_pair_matching(
    items: np.ndarray, n: int, theta: float, top_frac: float
) -> CliquePartition:
    """Greedy max-weight matching of items into disjoint pairs.

    Edges come from the binary CRM of ``items`` (same Alg.-2 machinery the
    proposed method uses), weights from the normalised CRM; items left
    unmatched stay singletons.
    """
    crm = build_window_crm(items, n, theta, top_frac)
    w = np.where(crm.binary, crm.norm, 0.0)
    iu, iv = np.nonzero(np.triu(w, k=1))
    order = np.argsort(-w[iu, iv], kind="stable")
    used = np.zeros(crm.n_hot, dtype=bool)
    pairs: list[tuple[int, ...]] = []
    for e in order:
        a, b = int(iu[e]), int(iv[e])
        if used[a] or used[b]:
            continue
        used[a] = used[b] = True
        pairs.append((int(crm.hot_items[a]), int(crm.hot_items[b])))
    return CliquePartition.from_cliques(n, pairs)


def run_packcache2(
    trace: Trace,
    params: CostParams,
    t_cg: float = 50.0,
    top_frac: float = 0.1,
    caching_charge: CachingCharge = "requested",
    batch_size: int | None = None,
) -> CostBreakdown:
    """Online 2-packing (PackCache, Wu et al. [2])."""
    eng = ReplayEngine(trace.n, trace.m, params, caching_charge=caching_charge)

    def gen(items: np.ndarray, servers: np.ndarray, now: float):
        del servers, now
        return greedy_pair_matching(items, trace.n, params.theta, top_frac)

    return eng.replay(trace, clique_generator=gen, t_cg=t_cg, batch_size=batch_size)


def run_dp_greedy(
    trace: Trace,
    params: CostParams,
    top_frac: float = 0.1,
    caching_charge: CachingCharge = "requested",
    batch_size: int | None = None,
) -> CostBreakdown:
    """Offline 2-packing (DP_Greedy, Huang et al. [4]).

    Pairs are derived from the FULL trace (offline knowledge) and installed
    before replay starts; they never change.
    """
    part = greedy_pair_matching(trace.items, trace.n, params.theta, top_frac)
    eng = ReplayEngine(trace.n, trace.m, params, caching_charge=caching_charge)
    eng.install_partition(part, now=0.0)
    return eng.replay(trace, clique_generator=None, batch_size=batch_size)


# ---------------------------------------------------------------------------
# OPT lower bound
# ---------------------------------------------------------------------------
def opt_lower_bound(trace: Trace, params: CostParams) -> CostBreakdown:
    """Rigorous lower bound on the offline optimal cost (see module doc)."""
    c_min = (params.alpha + (1.0 - params.alpha) / params.omega) * params.lam
    # flatten to (item, server, time) triplets
    mask = trace.items >= 0
    reps = mask.sum(axis=1)
    it = trace.items[mask]
    sv = np.repeat(trace.servers, reps)
    tm = np.repeat(trace.times, reps)
    key = it.astype(np.int64) * trace.m + sv
    order = np.lexsort((tm, key))
    key_s, tm_s = key[order], tm[order]
    new_seq = np.ones(key_s.shape[0], dtype=bool)
    new_seq[1:] = key_s[1:] != key_s[:-1]
    gaps = np.empty_like(tm_s)
    gaps[new_seq] = np.inf                 # first access of each (d, j)
    cont = ~new_seq
    gaps[cont] = tm_s[cont] - tm_s[np.nonzero(cont)[0] - 1]

    costs = CostBreakdown()
    first = new_seq
    costs.transfer += float(first.sum()) * c_min
    keep = params.mu * gaps[cont]
    refetch = np.minimum(keep, c_min)
    costs.transfer += float(refetch[keep >= c_min].sum())
    costs.caching += float(refetch[keep < c_min].sum())
    costs.n_requests = trace.n_requests
    costs.n_item_requests = int(mask.sum())
    costs.n_misses = int(first.sum() + (keep >= c_min).sum())
    return costs
