"""Baselines of the paper's evaluation (§V.B) — legacy batch entry points.

The methods themselves are registered ``CachePolicy`` implementations in
``repro.core.policy``:

* ``no_packing``  — every item transferred/cached individually (Wang et al.
  [6] style online TTL caching; no packing component).
* ``packcache``   — Wu et al. [2]: ONLINE pairwise (2-)packing; we realise the
  FP-tree pair mining as max-weight greedy matching on the window CRM, which
  selects the same top co-accessed pairs, and reuse the shared replay engine.
* ``dp_greedy``   — Huang et al. [4]: OFFLINE pairwise packing; pairs are
  matched on the CRM of the FULL trace (complete request knowledge) and kept
  fixed during replay.

The ``run_*`` functions below are thin shims over the registry (kept for the
original batch API; cost-for-cost identical).  ``OPT`` stays here:

* ``opt_lower_bound`` — offline optimal.  True OPT is intractable; we compute
  a rigorous LOWER BOUND (every feasible schedule pays at least this much):
  per (item, server) access sequence, each first access costs at least the
  cheapest per-item packed transfer share  c_min = (alpha + (1-alpha)/omega)*lam
  and each re-access after gap g costs at least min(mu*g, c_min)  (either the
  item was kept cached over the gap, or it was re-transferred).  Costs ratios
  "vs OPT" reported by the benchmarks are therefore conservative (the real
  OPT can only be larger).
"""
from __future__ import annotations

import numpy as np

from ..traces.loader import Trace
from .cost import CacheEnvironment, CostBreakdown, CostParams
from .engine import CachingCharge
from .policy import get_policy, greedy_pair_matching, run_policy

__all__ = [
    "OPT_BOUND_MODELS",
    "greedy_pair_matching",
    "opt_lower_bound",
    "run_dp_greedy",
    "run_no_packing",
    "run_packcache2",
]

#: cost models whose pricing admits the opt_lower_bound argument
OPT_BOUND_MODELS = ("table1", "heterogeneous")


def run_no_packing(
    trace: Trace,
    params: CostParams,
    caching_charge: CachingCharge = "requested",
    batch_size: int | None = None,
    env: CacheEnvironment | None = None,
    cost_model: str = "table1",
) -> CostBreakdown:
    pol = get_policy("no_packing", params=params, caching_charge=caching_charge,
                     env=env, cost_model=cost_model)
    return run_policy(pol, trace, batch_size=batch_size).costs


def run_packcache2(
    trace: Trace,
    params: CostParams,
    t_cg: float = 50.0,
    top_frac: float = 0.1,
    caching_charge: CachingCharge = "requested",
    batch_size: int | None = None,
    env: CacheEnvironment | None = None,
    cost_model: str = "table1",
) -> CostBreakdown:
    """Online 2-packing (PackCache, Wu et al. [2])."""
    pol = get_policy("packcache", params=params, t_cg=t_cg, top_frac=top_frac,
                     caching_charge=caching_charge, env=env,
                     cost_model=cost_model)
    return run_policy(pol, trace, batch_size=batch_size).costs


def run_dp_greedy(
    trace: Trace,
    params: CostParams,
    top_frac: float = 0.1,
    caching_charge: CachingCharge = "requested",
    batch_size: int | None = None,
    env: CacheEnvironment | None = None,
    cost_model: str = "table1",
) -> CostBreakdown:
    """Offline 2-packing (DP_Greedy, Huang et al. [4])."""
    pol = get_policy("dp_greedy", params=params, top_frac=top_frac,
                     caching_charge=caching_charge, env=env,
                     cost_model=cost_model)
    return run_policy(pol, trace, batch_size=batch_size).costs


# ---------------------------------------------------------------------------
# OPT lower bound
# ---------------------------------------------------------------------------
def opt_lower_bound(
    trace: Trace,
    params: CostParams | None = None,
    env: CacheEnvironment | None = None,
    cost_model: str = "table1",
) -> CostBreakdown:
    """Rigorous lower bound on the offline optimal cost (see module doc).

    With a heterogeneous ``env`` (per-server prices / item sizes) the same
    argument holds per (item, server) sequence at THAT server's prices and
    THAT item's volume: every first access pays at least the cheapest
    per-item packed share ``(alpha + (1-alpha)/omega) * lam_j * s_d`` and
    every re-access after gap g at least ``min(mu_j * s_d * g, share)``.
    The homogeneous path is kept verbatim (bit-identical to pre-PR-4 runs).

    ONLY valid for the ``table1`` and ``heterogeneous`` cost models (their
    packed per-item share is bounded below by the omega-pack share) —
    enforced with a ValueError.  ``tiered`` schedules with marginal rates
    below alpha can undercut the share, so no lower bound of this form
    exists; fig10-style comparisons there use ``no_packing`` as the
    reference instead.
    """
    if cost_model not in OPT_BOUND_MODELS:
        raise ValueError(
            f"opt_lower_bound is only valid for {OPT_BOUND_MODELS}; "
            f"{cost_model!r} pricing can undercut the per-item packed share")
    if params is None:
        params = env.params if env is not None else CostParams()
    elif env is not None and params != env.params:
        # same contract as ReplayEngine: a conflicting explicit params
        # would silently skew the packed share / rent rates
        raise ValueError(
            "params and env.params disagree; build the environment with "
            "the same CostParams you pass to opt_lower_bound")
    # flatten to (item, server, time) triplets
    mask = trace.items >= 0
    reps = mask.sum(axis=1)
    it = trace.items[mask]
    sv = np.repeat(trace.servers, reps)
    tm = np.repeat(trace.times, reps)
    key = it.astype(np.int64) * trace.m + sv
    order = np.lexsort((tm, key))
    key_s, tm_s = key[order], tm[order]
    new_seq = np.ones(key_s.shape[0], dtype=bool)
    new_seq[1:] = key_s[1:] != key_s[:-1]
    gaps = np.empty_like(tm_s)
    gaps[new_seq] = np.inf                 # first access of each (d, j)
    cont = ~new_seq
    gaps[cont] = tm_s[cont] - tm_s[np.nonzero(cont)[0] - 1]

    costs = CostBreakdown(model=cost_model)
    first = new_seq
    share = params.alpha + (1.0 - params.alpha) / params.omega
    # per-server/size pricing applies only when the MODEL prices that way:
    # table1 ignores env prices/sizes by design, so its bound must too (the
    # env branch would otherwise exceed the achievable table1 costs)
    if cost_model != "heterogeneous" or env is None or env.homogeneous:
        c_min = share * params.lam
        costs.transfer += float(first.sum()) * c_min
        keep = params.mu * gaps[cont]
        refetch = np.minimum(keep, c_min)
        costs.transfer += float(refetch[keep >= c_min].sum())
        costs.caching += float(refetch[keep < c_min].sum())
        costs.n_misses = int(first.sum() + (keep >= c_min).sum())
    else:
        lam = env.lam_per_server()
        mu = env.mu_per_server()
        s = env.sizes()
        it_s, sv_s = it[order], sv[order]
        c_min = share * lam[sv_s] * s[it_s]
        costs.transfer += float(c_min[first].sum())
        keep = mu[sv_s[cont]] * s[it_s[cont]] * gaps[cont]
        cm = c_min[cont]
        refetch = np.minimum(keep, cm)
        costs.transfer += float(refetch[keep >= cm].sum())
        costs.caching += float(refetch[keep < cm].sum())
        costs.n_misses = int(first.sum() + (keep >= cm).sum())
    costs.n_requests = trace.n_requests
    costs.n_item_requests = int(mask.sum())
    return costs
