"""AKPC orchestrator (paper Alg. 1): the three modules wired together.

* Event 1 (every T_CG): Clique Generation Module — Alg. 2 (CRM), Alg. 4
  (adjust previous cliques), Alg. 3 (split oversized + approximate merge);
* Event 2 (per request): Data Request Handling — Alg. 5 via ReplayEngine;
* Event 3 (expiry): Alg. 6 last-copy keepalive — folded into the engine's
  anchor invariant (see engine.py docstring).

Ablation variants of the paper (Fig. 5/7/9):
* ``AKPC``                     split=True,  approx_merge=True
* ``AKPC w/o ACM``             split=True,  approx_merge=False
* ``AKPC w/o CS, w/o ACM``     split=False, approx_merge=False  (omega unused)
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable

import numpy as np

from ..traces.loader import Trace
from .cliques import CliquePartition, generate_cliques
from .cost import CostBreakdown, CostParams
from .crm import WindowCRM, build_window_crm
from .engine import CachingCharge, ReplayEngine


@dataclasses.dataclass
class AKPCConfig:
    params: CostParams = dataclasses.field(default_factory=CostParams)
    t_cg: float = 50.0               # clique-generation period (Fig. 3)
    top_frac: float = 0.1            # CRM restricted to top-10% items (§V.A)
    enable_split: bool = True        # CS  module
    enable_approx_merge: bool = True # ACM module
    caching_charge: CachingCharge = "requested"
    seed_new_cliques: bool = True
    # requests per vectorised engine batch; None = engine default, 1 = the
    # historical per-request scalar replay (bit-compatible)
    batch_size: int | None = None
    # accelerated hooks (Pallas kernel wrappers); None = numpy oracles
    crm_matmul: Callable | None = None
    pair_edges: Callable | None = None


@dataclasses.dataclass
class AKPCResult:
    costs: CostBreakdown
    clique_sizes: np.ndarray         # sizes of all cliques, final window
    size_history: list[np.ndarray]   # per-window non-singleton size arrays
    n_windows: int
    cg_seconds: float                # total clique-generation wall time
    config: AKPCConfig

    @property
    def total(self) -> float:
        return self.costs.total


class AKPC:
    """Adaptive K-PackCache (the paper's proposed online algorithm)."""

    def __init__(self, n: int, m: int, cfg: AKPCConfig):
        self.cfg = cfg
        self.engine = ReplayEngine(
            n,
            m,
            cfg.params,
            caching_charge=cfg.caching_charge,
            seed_new_cliques=cfg.seed_new_cliques,
        )
        self._prev_crm: WindowCRM | None = None
        self._partition: CliquePartition | None = None
        self.size_history: list[np.ndarray] = []
        self.cg_seconds = 0.0
        self.n_windows = 0

    # -- Event 1: clique generation on a window of requests -----------------
    def _generate(self, items: np.ndarray, servers: np.ndarray, now: float):
        del servers, now
        cfg = self.cfg
        t0 = _time.perf_counter()
        n = self.engine.n
        crm = build_window_crm(
            items, n, cfg.params.theta, cfg.top_frac, crm_matmul=cfg.crm_matmul
        )
        omega = cfg.params.omega if cfg.enable_split else n
        part = generate_cliques(
            self._partition,
            self._prev_crm,
            crm,
            n,
            omega,
            cfg.params.gamma,
            pair_edges=cfg.pair_edges,
            enable_split=cfg.enable_split,
            enable_approx_merge=cfg.enable_approx_merge,
        )
        self._prev_crm = crm
        self._partition = part
        self.cg_seconds += _time.perf_counter() - t0
        self.n_windows += 1
        sizes = part.sizes()
        self.size_history.append(sizes[sizes > 1])
        return part

    def run(self, trace: Trace) -> AKPCResult:
        costs = self.engine.replay(
            trace,
            clique_generator=self._generate,
            t_cg=self.cfg.t_cg,
            batch_size=self.cfg.batch_size,
        )
        final = (
            self._partition.sizes()
            if self._partition is not None
            else np.ones(self.engine.n, dtype=np.int32)
        )
        return AKPCResult(
            costs=costs,
            clique_sizes=final,
            size_history=self.size_history,
            n_windows=self.n_windows,
            cg_seconds=self.cg_seconds,
            config=self.cfg,
        )


def run_akpc(trace: Trace, cfg: AKPCConfig | None = None) -> AKPCResult:
    cfg = cfg or AKPCConfig()
    return AKPC(trace.n, trace.m, cfg).run(trace)


def run_akpc_variant(
    trace: Trace,
    params: CostParams,
    *,
    split: bool = True,
    approx_merge: bool = True,
    t_cg: float = 50.0,
    top_frac: float = 0.1,
    caching_charge: CachingCharge = "requested",
) -> AKPCResult:
    """Convenience wrapper for the paper's ablation variants."""
    return run_akpc(
        trace,
        AKPCConfig(
            params=params,
            t_cg=t_cg,
            top_frac=top_frac,
            enable_split=split,
            enable_approx_merge=approx_merge,
            caching_charge=caching_charge,
        ),
    )
