"""AKPC configuration + legacy entry points (paper Alg. 1).

The algorithm itself lives in the unified policy layer: ``repro.core.policy``
registers AKPC (and its Fig.-5/7/9 ablation variants) as ``CachePolicy``
implementations driven either offline (``run_policy``) or online
(``repro.core.session.CacheSession``).

* Event 1 (every T_CG): Clique Generation Module — Alg. 2 (CRM), Alg. 4
  (adjust previous cliques), Alg. 3 (split oversized + approximate merge);
* Event 2 (per request): Data Request Handling — Alg. 5 via ReplayEngine;
* Event 3 (expiry): Alg. 6 last-copy keepalive — folded into the engine's
  anchor invariant (see engine.py docstring and DESIGN.md §2).

Ablation variants of the paper (Fig. 5/7/9), as registry names:
* ``akpc``          AKPC                    split=True,  approx_merge=True
* ``akpc_no_acm``   AKPC w/o ACM            split=True,  approx_merge=False
* ``akpc_base``     AKPC w/o CS, w/o ACM    split=False, approx_merge=False

``run_akpc`` / ``run_akpc_variant`` below are thin shims over the registry,
kept for the original batch API; they reproduce the historical costs exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from ..traces.loader import Trace
from .cost import CostBreakdown, CostParams
from .engine import CachingCharge


@dataclasses.dataclass
class AKPCConfig:
    params: CostParams = dataclasses.field(default_factory=CostParams)
    t_cg: float = 50.0               # clique-generation period (Fig. 3)
    top_frac: float = 0.1            # CRM restricted to top-10% items (§V.A)
    # hot-set denominator: "window" = fraction of the window's distinct
    # accessed items (paper §V.A), "catalog" = historical fraction of n
    top_frac_of: str = "window"
    enable_split: bool = True        # CS  module
    enable_approx_merge: bool = True # ACM module
    caching_charge: CachingCharge = "requested"
    seed_new_cliques: bool = True
    # requests per vectorised engine batch; None = engine default, 1 = the
    # historical per-request scalar replay (bit-compatible)
    batch_size: int | None = None
    # accelerated hooks (Pallas kernel wrappers); None + kernels="auto"
    # autowires the TPU kernels when a TPU backend is attached
    crm_matmul: Callable | None = None
    pair_edges: Callable | None = None
    kernels: str = "auto"            # "auto" | "off"


@dataclasses.dataclass
class AKPCResult:
    """Legacy result type of ``run_akpc`` (RunResult subsumes it)."""

    costs: CostBreakdown
    clique_sizes: np.ndarray         # sizes of all cliques, final window
    size_history: list[np.ndarray]   # per-window non-singleton size arrays
    n_windows: int
    cg_seconds: float                # total clique-generation wall time
    config: AKPCConfig

    @property
    def total(self) -> float:
        return self.costs.total


def run_akpc(trace: Trace, cfg: AKPCConfig | None = None) -> AKPCResult:
    """Batch-API shim over ``get_policy("akpc")`` + ``run_policy``."""
    from .policy import AKPCPolicy, run_policy

    cfg = cfg or AKPCConfig()
    res = run_policy(AKPCPolicy(cfg), trace)
    return AKPCResult(
        costs=res.costs,
        clique_sizes=res.clique_sizes,
        size_history=res.size_history,
        n_windows=res.n_windows,
        cg_seconds=res.cg_seconds,
        config=cfg,
    )


def run_akpc_variant(
    trace: Trace,
    params: CostParams,
    *,
    split: bool = True,
    approx_merge: bool = True,
    t_cg: float = 50.0,
    top_frac: float = 0.1,
    caching_charge: CachingCharge = "requested",
) -> AKPCResult:
    """Convenience wrapper for the paper's ablation variants."""
    return run_akpc(
        trace,
        AKPCConfig(
            params=params,
            t_cg=t_cg,
            top_frac=top_frac,
            enable_split=split,
            enable_approx_merge=approx_merge,
            caching_charge=caching_charge,
        ),
    )
