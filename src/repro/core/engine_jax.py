"""Device-resident JAX replay backend: one jit'd ``lax.scan`` per trace.

The NumPy engine (``core/engine.py``) replays a trace as a Python loop of
``handle_batch`` calls — vectorised inside a batch, but dispatching dozens
of NumPy ops per batch and re-deriving the same event structure every run.
This module splits the replay in two (DESIGN.md §10):

* **Host schedule** (``build_schedule``): everything that is a pure
  function of (trace, clique-generation) and NOT of cache state — the
  T_CG window walk, the policy's clique generation, the per-batch
  (request, clique) event construction of :func:`~repro.core.engine.batch_events`
  (dedup, sort orders, lags, segment flags) and the partition-install
  matching of :func:`~repro.core.engine.match_partitions` — is computed
  once on host and packed into fixed-shape, -padded event tensors.
  Reusing the NumPy engine's own construction helpers makes the schedule
  bit-identical to what ``handle_batch`` would have derived inline.

* **Device scan** (``_replay_impl``): the state recurrence — expiries
  ``E``, Alg.-6 ``anchor``, ratcheting, Alg.-5 cost accounting, and the
  partition-install state translation — runs as one ``jax.lax.scan`` over
  the schedule's batches inside a single ``jit``, with ``CacheState``
  living on device for the whole trace.  Under per-server dt the anchor
  resolution and the pair-expiry update are segmented running
  (arg)max scans routed through ``kernels/segment_reduce.py`` (Pallas on
  accelerators via ``kernels/autowire.py``, pure-jnp fallback on CPU).

The state trajectory is float-for-float identical to the NumPy engine
(same f64 ops on the same operands); cost totals differ only by summation
order inside a batch, which is why parity holds at 1e-9 relative
(tests/test_sweep.py) on every chunking.

Everything runs under ``jax.experimental.enable_x64`` so the engine's
float64 semantics survive; the rest of the repo stays on default x32.

Because the schedule is state-free, ``core/sweep.py`` can share ONE
schedule across every scenario that prices the same (trace x clique-gen
hyperparameters) point and ``vmap`` the compiled replay over stacked
cost-model parameters and initial states — the grid sweep the paper's
Figs. 5-10 need.

State layout: by default the device ``E`` is ``(n + 1, m)`` — one row
per POSSIBLE clique id (a partition of n items has k <= n cliques) plus
a dump row that absorbs masked scatter writes and padding-event gathers;
the NumPy engine's ``(k, m)`` state is the live prefix ``E[:k]``.  The
geometry is owned by :class:`repro.core.state_layout.StateLayout`
(``layout=`` on every entry point): ``bucketed`` rounds the state dims
up to padding buckets so mixed-(n, m) sweeps compile per bucket cohort,
``row_sharded`` distributes the state rows over a mesh axis.  The dump
row is ALWAYS the last state row (``schedule.nrow - 1``); the scan body
derives it from the carry shape, so one compiled scan serves every
catalog sharing a bucket.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import numpy as np

from .cliques import CliquePartition
from .cost import (
    CacheEnvironment,
    CostBreakdown,
    CostModel,
    HeterogeneousCostModel,
    Table1CostModel,
    TieredCostModel,
)
from .engine import (
    CacheState,
    CachingCharge,
    ReplayEngine,
    batch_events,
    match_partitions,
    window_seed_servers,
)
from .state_layout import StateLayout

try:  # the accelerator layer stays optional (pure-numpy containers)
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    HAS_JAX = True
except Exception:  # pragma: no cover - exercised only in jax-less containers
    jax = None
    HAS_JAX = False


def _require_jax() -> None:
    if not HAS_JAX:
        raise ImportError(
            "the JAX replay backend needs jax; install jax[cpu] or use "
            "backend='numpy'")


_COMPILE_CACHE_SET = False


def enable_compile_cache() -> None:
    """Point XLA's persistent compilation cache at a stable directory.

    Compiling the replay scan costs ~1s per cohort shape — on small grids
    that one compile used to outweigh the whole vmap win (BENCH_sweep.json
    recorded the 24-point/40k grid at 0.88x serial).  Caching compiled
    cohorts on disk makes every later process start warm, so sweeps win at
    every size, not just when the compile amortises over a big grid.

    ``REPRO_JAX_COMPILE_CACHE`` overrides the directory; ``off``/``0``
    disables.  A ``jax_compilation_cache_dir`` the caller already set
    always wins.  Idempotent, cheap, safe to call per SweepEngine.
    """
    global _COMPILE_CACHE_SET
    if _COMPILE_CACHE_SET or not HAS_JAX:
        return
    _COMPILE_CACHE_SET = True
    import os

    env = os.environ.get("REPRO_JAX_COMPILE_CACHE", "")
    if env.lower() in ("off", "0", "none"):
        return
    if jax.config.jax_compilation_cache_dir:
        return  # caller owns the cache config
    path = env or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "jax")
    try:
        jax.config.update("jax_compilation_cache_dir", path)
        # the scan compiles in ~1s and serialises small; the defaults
        # (1s floor) would skip borderline cohorts on fast machines
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    except Exception:  # pragma: no cover - ancient jax without the knobs
        pass


# ---------------------------------------------------------------------------
# cost spec: the three batched CostModel hooks as data + static kind
# ---------------------------------------------------------------------------
#: cost models the JAX backend can express as jnp hooks
JAX_COST_MODELS = ("table1", "tiered", "heterogeneous")


def cost_spec(model: CostModel, env: CacheEnvironment) -> tuple[dict, tuple]:
    """(spec arrays, static key) reproducing ``model``'s batched hooks.

    ``spec`` is a dict of numpy arrays (vmap-stackable per scenario);
    the static key ``(kind, literal, n_tiers)`` selects the jnp formula.
    """
    p = env.params
    m = env.m
    spec = {
        "dt": np.asarray(model.dt(), dtype=np.float64),
        "alpha": np.float64(p.alpha),
        "lam": np.float64(p.lam),
        "mu": np.float64(p.mu),
        "lam_j": env.lam_per_server(),
        "mu_j": env.mu_per_server(),
        "tier_lo": np.zeros(0),
        "tier_hi": np.zeros(0),
        "tier_rates": np.zeros(0),
    }
    literal = p.cost_mode == "paper_literal"
    if isinstance(model, TieredCostModel):
        spec["tier_lo"] = model._lo.astype(np.float64)
        spec["tier_hi"] = model._hi.astype(np.float64)
        spec["tier_rates"] = model.rates.astype(np.float64)
        return spec, ("tiered", literal, int(model.rates.shape[0]))
    if isinstance(model, HeterogeneousCostModel):
        return spec, ("heterogeneous", literal, 0)
    if isinstance(model, Table1CostModel):
        return spec, ("table1", literal, 0)
    raise NotImplementedError(
        f"cost model {model.name!r} has no JAX formula; the JAX backend "
        f"supports {JAX_COST_MODELS} — run it with the numpy engine")


def _transfer_hook(kind, spec, counts, sizes, j):
    if kind[0] == "table1":
        if kind[1]:  # paper_literal: Alg. 5 line 11 as written
            packed = spec["alpha"] * spec["mu"] * counts
        else:
            packed = (1.0 + (counts - 1.0) * spec["alpha"]) * spec["lam"]
        return jnp.where(counts > 1, packed, counts * spec["lam"])
    if kind[0] == "tiered":
        v = sizes[:, None]
        seg = jnp.clip(
            jnp.minimum(v, spec["tier_hi"]) - spec["tier_lo"], 0.0, None)
        return spec["lam_j"][j] * (seg * spec["tier_rates"]).sum(axis=-1)
    # heterogeneous
    disc = jnp.where(
        counts > 1, (1.0 + (counts - 1.0) * spec["alpha"]) / counts, 1.0)
    return spec["lam_j"][j] * sizes * disc


def _rate_hook(kind, spec, counts, sizes, j):
    if kind[0] == "table1":
        return counts * spec["mu"]
    return spec["mu_j"][j] * sizes


# ---------------------------------------------------------------------------
# the host-built replay schedule
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ReplaySchedule:
    """Fixed-shape padded event tensors of one trace replay (host numpy).

    ``xs[key]`` has leading axis nb (scan steps); event axis padded to
    ``ne``; install arrays padded to n rows (+ dump).  The same schedule
    serves every scenario sharing (trace, clique-gen hyperparameters) —
    see :mod:`repro.core.sweep`.
    """

    n: int
    m: int
    nb: int
    ne: int
    const_dt: bool
    uses_sizes: bool
    xs: dict
    n_requests: int
    n_item_requests: int
    partition0: CliquePartition
    final_partition: CliquePartition
    win_start: int              # open-window start index into the trace
    boundary_hit: bool          # did any Event-1 boundary fire in this trace
    next_cg: float | None       # T_CG boundary after the last request
    # state geometry the index fills were built for (StateLayout.state_dims;
    # dense default = (n + 1, m)); the dump row is always nrow - 1
    nrow: int = 0
    ncol: int = 0

    @property
    def state_rows(self) -> int:
        return self.nrow if self.nrow else self.n + 1

    @property
    def state_cols(self) -> int:
        return self.ncol if self.ncol else self.m


def _bucket(x: int, step: int, floor: int) -> int:
    """Round up to a multiple of ``step`` (>= floor) — shape buckets keep
    jit cache hits across schedules without pow2-level padding waste."""
    return max(floor, -(-x // step) * step)


#: target deduplicated events per scan step under default (event-balanced)
#: slicing: windows are split into equal-event batches instead of fixed
#: request counts, which keeps the padded (nb, ne) tensors dense
NE_TARGET = 8192


def _part_cost_arrays(part: CliquePartition, item_sizes: np.ndarray | None):
    """Per-clique member counts + total volumes (engine _set_partition_caches)."""
    sizes = part.sizes().astype(np.int64)
    if item_sizes is None or part.k == 0:
        return sizes, None
    order = part.member_order()
    starts = np.zeros(part.k, np.int64)
    np.cumsum(sizes[:-1], out=starts[1:])
    return sizes, np.add.reduceat(item_sizes[order], starts)


def build_schedule(
    partition0: CliquePartition,
    trace,
    clique_generator: Callable | None,
    t_cg: float | None,
    *,
    model: CostModel,
    env: CacheEnvironment,
    batch_size: int | None = None,
    seed_new_cliques: bool = True,
    next_cg0: float | None = None,
    win_prefix: tuple[np.ndarray, np.ndarray] | None = None,
    lookup: Callable | None = None,
    progress: Callable[[int], None] | None = None,
    layout: StateLayout | str | None = None,
) -> ReplaySchedule:
    """Walk the trace exactly as ``ReplayEngine.replay`` does and emit the
    padded event tensors + install records of every batch.

    ``next_cg0``/``win_prefix`` support mid-stream continuation (a
    :class:`~repro.core.session.CacheSession` that already has an open
    T_CG window); fresh replays leave them None.
    """
    from .engine import DEFAULT_BATCH_SIZE, _numpy_clique_lookup

    n, m = env.n, env.m
    lay = StateLayout.resolve(layout)
    nrow, ncol = lay.state_dims(n, m)
    K = nrow - 1                                # dump row index (last row)
    bs = DEFAULT_BATCH_SIZE if batch_size is None else max(1, int(batch_size))
    lookup = lookup or _numpy_clique_lookup
    uses_sizes = bool(model.uses_sizes)
    item_sizes = env.sizes() if uses_sizes else None
    dt_arr = np.asarray(model.dt(), dtype=np.float64)
    const_dt = m == 0 or bool((dt_arr == dt_arr[0]).all())

    times, servers, items = trace.times, trace.servers, trace.items
    R = int(times.shape[0])
    cur = partition0
    sizes_c, csizes_c = _part_cost_arrays(cur, item_sizes)

    # keep-or-not (TTL) hook: a policy exposing ``item_keep()`` on the
    # generator's bound object ships a per-event nokeep mask plus boundary
    # eviction rows through the schedule — the device mirror of
    # ``ReplayEngine.set_item_keep`` (engine.py)
    keep_fn = None
    if clique_generator is not None:
        pol = getattr(clique_generator, "__self__", None)
        keep_fn = getattr(pol, "item_keep", None)

    def _clique_nk_of(part: CliquePartition, keep: np.ndarray) -> np.ndarray:
        """Clique-level nokeep mask: nokeep iff ANY member is nokeep."""
        if part.k == 0:
            return np.zeros(0, bool)
        psz = part.sizes().astype(np.int64)
        order = part.member_order()
        starts = np.zeros(part.k, np.int64)
        np.cumsum(psz[:-1], out=starts[1:])
        return np.add.reduceat((~keep)[order].astype(np.int64), starts) > 0

    cur_keep = (np.asarray(keep_fn(), bool).copy()
                if keep_fn is not None else None)
    cur_nk = _clique_nk_of(cur, cur_keep) if cur_keep is not None else None

    batches: list[dict] = []
    pending_install: dict | None = None
    n_requests = 0
    n_item_requests = 0

    def _emit(pos: int, stop: int) -> None:
        nonlocal pending_install, n_requests, n_item_requests
        ev = batch_events(
            cur.clique_of, cur.k, m,
            np.atleast_2d(items[pos:stop]), servers[pos:stop],
            times[pos:stop], lookup,
            item_sizes if csizes_c is not None else None,
        )
        n_requests += stop - pos
        n_item_requests += ev.n_valid
        size_e = sizes_c[ev.ev_c].astype(np.float64)
        csize_e = (csizes_c[ev.ev_c] if csizes_c is not None else size_e)
        n_req = ev.n_req.astype(np.float64)
        req_size = (ev.req_size if ev.req_size is not None else n_req)
        rec = {
            "ev": ev, "size": size_e, "csize": csize_e,
            "n_req": n_req, "req_size": np.asarray(req_size, np.float64),
            "install": pending_install,
        }
        if cur_nk is not None:
            rec["nk"] = (cur_nk[ev.ev_c] if ev.n_events
                         else np.zeros(0, bool))
        pending_install = None
        batches.append(rec)

    def _record_install(part: CliquePartition, now: float,
                        w_it: np.ndarray, w_sv: np.ndarray) -> None:
        nonlocal pending_install, cur, sizes_c, csizes_c, cur_keep, cur_nk
        if pending_install is not None:     # two Event-1s with no requests
            _emit(0, 0)                     # between them: flush on an
            # empty batch so installs stay one-per-scan-step
        matched, cand = match_partitions(cur, part)
        k = part.k
        new_sizes = part.sizes().astype(np.int64)
        # COMPACT translation: only CHANGED cliques need the member-wise
        # segment-min / seeding — matched rows are a plain row gather via
        # ``cand``.  Windows drift slowly, so the device install touches
        # O(changed x m), not O(n x m).
        chg = np.nonzero(~matched)[0]
        order = part.member_order()
        starts = np.zeros(k, np.int64)
        np.cumsum(new_sizes[:-1], out=starts[1:])
        chg_item = (
            np.concatenate(
                [order[starts[c]: starts[c] + new_sizes[c]] for c in chg])
            if chg.size else np.zeros(0, np.int64))
        chg_seg = np.repeat(np.arange(chg.size), new_sizes[chg])
        seed_j = np.zeros(chg.size, np.int32)
        seed_ok = np.zeros(chg.size, bool)
        if seed_new_cliques and w_it is not None and k > 0 and chg.size:
            js = window_seed_servers(n, m, part, w_it, w_sv)
            seed_j = js[chg].astype(np.int32)
            seed_ok = new_sizes[chg] > 1
            if cur_keep is not None:
                # OLD-mask guard (engine install_partition): never seed a
                # clique holding a keep-or-not evicted item
                has_nk = np.bincount(
                    chg_seg,
                    weights=(~cur_keep)[chg_item].astype(np.float64),
                    minlength=chg.size) > 0
                seed_ok &= ~has_nk
        # matched cliques that KEPT their index need no write at all — in
        # the steady state (partition drifting slowly) the whole install
        # reduces to a handful of row scatters
        mov = np.nonzero(matched & (cand != np.arange(k)))[0]
        chg_ok = np.ones(chg.size, bool)
        if keep_fn is not None:
            # NEW-mask boundary eviction (engine set_item_keep): cliques
            # holding an item that just flipped keep->nokeep drop their
            # copies.  Rows already in chg flip ok=False (the install step
            # turns ok=False rows into E=0 / anchor=-1); other evicted
            # rows join chg as member-less ok=False rows; moved copies of
            # evicted cliques are dropped from the row-move list.
            new_keep = np.asarray(keep_fn(), bool).copy()
            newly_nk = cur_keep & ~new_keep
            if newly_nk.any():
                ev_rows = np.unique(
                    part.clique_of[np.nonzero(newly_nk)[0]]).astype(np.int64)
                evict = np.zeros(k, bool)
                evict[ev_rows] = True
                chg_ok[evict[chg]] = False
                mov = mov[~evict[mov]]
                extra = ev_rows[~np.isin(ev_rows, chg)]
                chg = np.concatenate([chg, extra])
                chg_ok = np.concatenate(
                    [chg_ok, np.zeros(extra.size, bool)])
                seed_j = np.concatenate(
                    [seed_j, np.zeros(extra.size, np.int32)])
                seed_ok = np.concatenate(
                    [seed_ok, np.zeros(extra.size, bool)])
            cur_keep = new_keep
            cur_nk = _clique_nk_of(part, new_keep)
        pending_install = {
            "now": np.float64(now),
            "mov_dst": mov.astype(np.int32),
            "mov_src": cand[mov].astype(np.int32),
            "chg_rows": chg.astype(np.int32),
            "chg_ok": chg_ok,
            "chg_src": cur.clique_of[chg_item].astype(np.int32),
            "chg_seg": chg_seg.astype(np.int32),
            "seed_j": seed_j,
            "seed_ok": seed_ok,
        }
        cur = part
        sizes_c, csizes_c = _part_cost_arrays(cur, item_sizes)

    # -- the T_CG boundary walk (mirrors ReplayEngine.replay) --------------
    use_cg = clique_generator is not None and t_cg is not None
    balanced = batch_size is None      # event-balanced default slicing
    if balanced and R > 0:
        cum = np.zeros(R + 1, np.int64)
        np.cumsum((items >= 0).sum(axis=1), out=cum[1:])
    if R > 0:
        if next_cg0 is not None:
            next_cg = float(next_cg0)
        else:
            next_cg = float(times[0]) + t_cg if t_cg is not None else np.inf
    else:
        next_cg = next_cg0 if next_cg0 is not None else np.inf
    win_start = 0
    boundary_hit = False
    pos = 0
    next_prog = 0
    while pos < R:
        cut = R
        if use_cg:
            cut = int(np.searchsorted(times, next_cg, side="left"))
            if cut <= pos:
                t = float(times[pos])
                w_it = items[win_start:pos]
                w_sv = servers[win_start:pos]
                if win_prefix is not None:
                    p_it, p_sv = win_prefix
                    if p_it.shape[0]:
                        d = max(int(p_it.shape[1]), int(w_it.shape[1]))
                        full = np.full(
                            (p_it.shape[0] + w_it.shape[0], d), -1, np.int64)
                        full[: p_it.shape[0], : p_it.shape[1]] = p_it
                        if w_it.shape[0]:
                            full[p_it.shape[0]:, : w_it.shape[1]] = w_it
                        w_it = full
                        w_sv = np.concatenate(
                            [np.asarray(p_sv, np.int64),
                             np.asarray(w_sv, np.int64)])
                    win_prefix = None
                part = clique_generator(w_it, w_sv, t)
                if part is not None:
                    _record_install(part, t, w_it, w_sv)
                elif keep_fn is not None and not np.array_equal(
                        cur_keep, np.asarray(keep_fn(), bool)):
                    # mask moved without a new partition: identity install
                    # record carrying only the boundary evictions
                    _record_install(cur, t, w_it, w_sv)
                win_start = pos
                boundary_hit = True
                while next_cg <= t:
                    next_cg += t_cg
                continue
        if balanced:
            # split [pos, cut) into equal-EVENT batches (any chunking
            # reproduces the costs at 1e-9 — the PR-2 invariant — so the
            # device schedule is free to pick dense slices)
            est = int(cum[cut] - cum[pos])
            nbat = max(1, -(-est // NE_TARGET))
            prev = pos
            for kb in range(1, nbat + 1):
                if kb == nbat:
                    stop = cut
                else:
                    target = cum[pos] + (est * kb) // nbat
                    stop = int(np.searchsorted(cum, target, side="left"))
                    stop = min(max(stop, prev + 1), cut)
                if stop > prev:
                    _emit(prev, stop)
                    prev = stop
            pos = cut
        else:
            stop = min(pos + bs, cut)
            _emit(pos, stop)
            pos = stop
        if progress is not None and pos >= next_prog:
            progress(pos)
            next_prog = (pos | 0xFFFF) + 1
    if pending_install is not None:         # trailing Event 1, no requests
        _emit(0, 0)

    # -- stack + pad into fixed-shape tensors -------------------------------
    # nu / na: compacted per-step state-update widths — scatters touch only
    # the segment-last events ((c,j) pairs / cliques), not the full event
    # axis, which is what keeps XLA's serialized CPU scatters off the
    # critical path
    nb_raw = len(batches)
    nb = _bucket(nb_raw, 4, 4)
    ne = _bucket(max((r["ev"].n_events for r in batches), default=1), 256, 64)
    nu = _bucket(
        max((int(r["ev"].last_cj_s.sum()) for r in batches), default=1),
        128, 32)
    na = _bucket(
        max((int(r["ev"].last_c_s.sum()) for r in batches), default=1),
        32, 32)
    installs = [r["install"] for r in batches if r["install"] is not None]
    # +1 slack: the last compact row/segment is always padding, so padded
    # items can never corrupt a real segment's min
    ncr = _bucket(
        max((i["chg_rows"].size for i in installs), default=0) + 1, 8, 8)
    nci = _bucket(
        max((i["chg_src"].size for i in installs), default=0) + 1, 16, 16)
    nmv = _bucket(
        max((i["mov_dst"].size for i in installs), default=0), 8, 8)

    def zeros(dtype, *shape):
        return np.zeros((nb, *shape), dtype)

    xs = {
        "ev_c": np.full((nb, ne), K, np.int32),
        "ev_j": zeros(np.int32, ne),
        "ev_t": zeros(np.float64, ne),
        "n_req": zeros(np.float64, ne),
        "size": zeros(np.float64, ne),
        "val": zeros(bool, ne),
        "first_cj": zeros(bool, ne),
        "prev_cj_t": zeros(np.float64, ne),
        # compacted (c, j) expiry writes + per-clique anchor writes
        "upd_c": np.full((nb, nu), K, np.int32),
        "upd_j": zeros(np.int32, nu),
        "anc_c": np.full((nb, na), K, np.int32),
        "inst": zeros(bool),
        "inst_now": zeros(np.float64),
        "inst_mov_dst": np.full((nb, nmv), K, np.int32),
        "inst_mov_src": np.full((nb, nmv), K, np.int32),
        "inst_chg_rows": np.full((nb, ncr), K, np.int32),
        "inst_chg_ok": zeros(bool, ncr),
        "inst_seed_j": zeros(np.int32, ncr),
        "inst_seed_ok": zeros(bool, ncr),
        "inst_chg_src": zeros(np.int32, nci),
        "inst_chg_seg": np.full((nb, nci), ncr - 1, np.int32),
    }
    if keep_fn is not None:
        # presence keyed on the HOOK, not the mask content: an all-keep
        # window still ships the (all-False) tensor so every chunk of a
        # stream shares one input structure (and one compile)
        xs["nokeep"] = zeros(bool, ne)
    if uses_sizes:
        # count-based models (table1) read size/n_req twice instead of
        # shipping duplicate volume tensors through the scan
        xs["csize"] = zeros(np.float64, ne)
        xs["req_size"] = zeros(np.float64, ne)
    if const_dt:
        xs.update(
            first_c=zeros(bool, ne),
            prev_j=np.full((nb, ne), -1, np.int32),
            upd_t=zeros(np.float64, nu),
            anc_j=zeros(np.int32, na),
            anc_t=zeros(np.float64, na),
        )
    else:
        xs.update(
            inv_o_c=zeros(np.int32, ne),
            c_s=np.full((nb, ne), K, np.int32),
            j_s=zeros(np.int32, ne),
            t_s=zeros(np.float64, ne),
            first_cs=np.ones((nb, ne), bool),
            cj_j_s=zeros(np.int32, ne),
            cj_t_s=zeros(np.float64, ne),
            first_cjs=np.ones((nb, ne), bool),
            pos_u=zeros(np.int32, nu),
            pos_a=zeros(np.int32, na),
        )

    for b, rec in enumerate(batches):
        ev = rec["ev"]
        e = ev.n_events
        if e:
            xs["ev_c"][b, :e] = ev.ev_c
            xs["ev_j"][b, :e] = ev.ev_j
            xs["ev_t"][b, :e] = ev.ev_t
            xs["n_req"][b, :e] = rec["n_req"]
            xs["size"][b, :e] = rec["size"]
            if uses_sizes:
                xs["req_size"][b, :e] = rec["req_size"]
                xs["csize"][b, :e] = rec["csize"]
            xs["val"][b, :e] = True
            xs["first_cj"][b, :e] = ev.first_cj
            xs["prev_cj_t"][b, :e] = ev.prev_cj_t
            li = ev.o_cj[ev.last_cj_s]          # one event per (c, j) pair
            lc = ev.o_c[ev.last_c_s]            # one event per clique
            nk_e = rec.get("nk")
            if nk_e is not None:
                xs["nokeep"][b, :e] = nk_e
                # nokeep cliques never store state: route their compacted
                # expiry/anchor writes to the dump row
                xs["upd_c"][b, : li.size] = np.where(
                    nk_e[li], K, ev.ev_c[li])
                xs["anc_c"][b, : lc.size] = np.where(
                    nk_e[lc], K, ev.ev_c[lc])
            else:
                xs["upd_c"][b, : li.size] = ev.ev_c[li]
                xs["anc_c"][b, : lc.size] = ev.ev_c[lc]
            xs["upd_j"][b, : li.size] = ev.ev_j[li]
            if const_dt:
                xs["first_c"][b, :e] = ev.first_c
                xs["prev_j"][b, :e] = ev.prev_j
                xs["upd_t"][b, : li.size] = ev.ev_t[li]
                xs["anc_j"][b, : lc.size] = ev.ev_j[lc]
                xs["anc_t"][b, : lc.size] = ev.ev_t[lc]
            else:
                inv = np.empty(e, np.int32)
                inv[ev.o_c] = np.arange(e, dtype=np.int32)
                xs["inv_o_c"][b, :e] = inv
                xs["c_s"][b, :e] = ev.cs
                xs["j_s"][b, :e] = ev.ev_j[ev.o_c]
                xs["t_s"][b, :e] = ev.ev_t[ev.o_c]
                xs["first_cs"][b, :e] = ev.first_c_s
                xs["cj_j_s"][b, :e] = ev.ev_j[ev.o_cj]
                xs["cj_t_s"][b, :e] = ev.ev_t[ev.o_cj]
                xs["first_cjs"][b, :e] = ev.first_cj_s
                xs["pos_u"][b, : li.size] = np.nonzero(ev.last_cj_s)[0]
                xs["pos_a"][b, : lc.size] = np.nonzero(ev.last_c_s)[0]
        inst = rec["install"]
        if inst is not None:
            nr = inst["chg_rows"].size
            ni = inst["chg_src"].size
            nv = inst["mov_dst"].size
            xs["inst"][b] = True
            xs["inst_now"][b] = inst["now"]
            xs["inst_mov_dst"][b, :nv] = inst["mov_dst"]
            xs["inst_mov_src"][b, :nv] = inst["mov_src"]
            xs["inst_chg_rows"][b, :nr] = inst["chg_rows"]
            xs["inst_chg_ok"][b, :nr] = inst["chg_ok"]
            xs["inst_seed_j"][b, :nr] = inst["seed_j"]
            xs["inst_seed_ok"][b, :nr] = inst["seed_ok"]
            xs["inst_chg_src"][b, :ni] = inst["chg_src"]
            xs["inst_chg_seg"][b, :ni] = inst["chg_seg"]

    return ReplaySchedule(
        n=n, m=m, nb=nb, ne=ne, const_dt=const_dt, uses_sizes=uses_sizes,
        xs=xs, n_requests=n_requests, n_item_requests=n_item_requests,
        partition0=partition0, final_partition=cur,
        win_start=win_start, boundary_hit=boundary_hit,
        next_cg=None if not use_cg or R == 0 else float(next_cg),
        nrow=nrow, ncol=ncol,
    )


def schedule_dims(s) -> dict:
    """The padded axis sizes of a schedule (for cross-schedule alignment).

    Accepts either a generic :class:`ReplaySchedule` or a CGM schedule
    (``core.cgm_jax.CGMSchedule``, duck-typed on ``boundary_steps``) so
    streamed sessions can ratchet both kinds through one dims dict.
    """
    if hasattr(s, "boundary_steps"):
        return {"nb": s.nb, "B": s.B, "d": s.d, "h": s.h, "W": s.wcap}
    d = {"nb": s.nb, "ne": s.ne,
         "nu": s.xs["upd_c"].shape[1], "na": s.xs["anc_c"].shape[1],
         "ncr": s.xs["inst_chg_rows"].shape[1],
         "nci": s.xs["inst_chg_src"].shape[1],
         "nmv": s.xs["inst_mov_dst"].shape[1]}
    return d


def pad_schedule(s, dims: dict):
    """Pad a schedule's tensors up to ``dims`` (a superset of its own).

    SweepEngine aligns every schedule of one sweep call to common shapes so
    the device scan compiles exactly ONCE per (n, m, path) — padded steps
    and slots are inert by the same masking rules as intra-schedule
    padding.  CGM schedules delegate to ``cgm_jax.pad_cgm_schedule``.
    """
    if hasattr(s, "boundary_steps"):
        from .cgm_jax import pad_cgm_schedule

        return pad_cgm_schedule(s, dims)
    mine = schedule_dims(s)
    if mine == dims:
        return s
    K = s.state_rows - 1
    old_ncr = mine["ncr"]
    fills = {
        "ev_c": K, "upd_c": K, "anc_c": K, "c_s": K,
        "inst_mov_dst": K, "inst_mov_src": K, "inst_chg_rows": K,
        "first_cs": True, "first_cjs": True,
        "prev_j": -1,
        "inst_chg_seg": dims["ncr"] - 1,
    }
    axis_of = {
        "upd_c": "nu", "upd_j": "nu", "upd_t": "nu", "pos_u": "nu",
        "anc_c": "na", "anc_j": "na", "anc_t": "na", "pos_a": "na",
        "inst_chg_rows": "ncr", "inst_chg_ok": "ncr",
        "inst_seed_j": "ncr", "inst_seed_ok": "ncr",
        "inst_mov_dst": "nmv", "inst_mov_src": "nmv",
        "inst_chg_src": "nci", "inst_chg_seg": "nci",
    }
    xs = {}
    for key, a in s.xs.items():
        # real segment ids never collide with the pad sentinel (values
        # <= ncr-2 by the +1 slack), so remapping it is unambiguous
        if key == "inst_chg_seg":
            a = np.where(a == old_ncr - 1, dims["ncr"] - 1, a)
        want = [dims["nb"]]
        if a.ndim == 2:
            want.append(dims[axis_of.get(key, "ne")])
        if list(a.shape) != want:
            out = np.full(want, fills.get(key, 0), a.dtype)
            out[tuple(slice(0, d) for d in a.shape)] = a
            a = out
        xs[key] = a
    return dataclasses.replace(s, nb=dims["nb"], ne=dims["ne"], xs=xs)


# ---------------------------------------------------------------------------
# the device scan
# ---------------------------------------------------------------------------
#: accumulator slots: transfer, caching, keepalive_rent, n_misses, n_hits,
#: items_transferred
N_ACC = 6


def _seg_hooks(use_pallas: bool):
    if use_pallas:
        from ..kernels.ops import seg_argmax, seg_max

        return seg_max, seg_argmax
    from ..kernels.segment_reduce import (
        seg_running_argmax_jnp,
        seg_running_max_jnp,
    )

    return seg_running_max_jnp, seg_running_argmax_jnp


def _install_step(E, anchor, x, dt):
    """Partition-install state translation (install_partition on device).

    The translation is a sparse IN-PLACE delta: matched cliques that kept
    their index are untouched; matched cliques whose index moved are a
    compact row move (``inst_mov_*``); only the CHANGED cliques
    (``inst_chg_*``) pay the member-wise segment-min + Alg.-1 seeding.
    All value gathers read the PRE-install state (functional semantics:
    gathers materialize before the scatters).  The dump row K is rewritten
    by the compact padding (rows -> K, ok=False -> zeros/-1), so
    inter-install scatter garbage never accumulates.
    """
    ncr = x["inst_chg_rows"].shape[0]
    movE = E[x["inst_mov_src"]]                     # (nmv, m)
    movA = anchor[x["inst_mov_src"]]
    item_E = E[x["inst_chg_src"]]                   # (nci, m)
    min_E = jax.ops.segment_min(
        item_E, x["inst_chg_seg"], num_segments=ncr)
    now = x["inst_now"]
    ok = x["inst_chg_ok"]
    fresh = jnp.where(ok[:, None] & (min_E > now), min_E, 0.0)
    row_max = fresh.max(axis=1)
    anew = jnp.where(
        row_max > 0.0, jnp.argmax(fresh, axis=1).astype(jnp.int32), -1)
    need = ok & (row_max <= 0.0) & x["inst_seed_ok"]
    sj = x["inst_seed_j"]
    col = jax.lax.broadcasted_iota(jnp.int32, fresh.shape, 1)
    fresh = jnp.where(
        need[:, None] & (col == sj[:, None]), now + dt[sj][:, None], fresh)
    anew = jnp.where(need, sj, anew)
    E = E.at[x["inst_mov_dst"]].set(movE)
    anchor = anchor.at[x["inst_mov_dst"]].set(movA)
    E = E.at[x["inst_chg_rows"]].set(fresh)
    anchor = anchor.at[x["inst_chg_rows"]].set(anew)
    return E, anchor


#: number of times the scan body has been TRACED.  jax re-traces (and XLA
#: recompiles) once per new input structure, so the delta of this counter
#: across a run counts fresh compiles — tests assert chunked/streamed
#: replays reuse ONE compiled scan (tests/test_serving_live.py)
SCAN_TRACES = 0


def _replay_impl(spec, init, xs, *, kind, charge, const_dt, use_pallas):
    """scan body closure; (spec, init) may carry a vmapped scenario axis."""
    global SCAN_TRACES
    SCAN_TRACES += 1
    seg_max_fn, seg_argmax_fn = _seg_hooks(use_pallas)
    dt = spec["dt"]

    def step(carry, x):
        E, anchor, acc = carry
        K = E.shape[0] - 1
        # lax.cond, not where: the predicate comes from the UNBATCHED xs
        # (shared across vmap lanes), so non-install steps skip the
        # delta-translation entirely
        E, anchor = jax.lax.cond(
            x["inst"],
            lambda Ea: _install_step(Ea[0], Ea[1], x, dt),
            lambda Ea: Ea,
            (E, anchor),
        )

        cl, j, t, val = x["ev_c"], x["ev_j"], x["ev_t"], x["val"]
        dt_e = dt[0] if const_dt else dt[j]
        E_before = jnp.where(
            x["first_cj"], E[cl, j], x["prev_cj_t"] + dt_e)
        # a zero that DEPENDS on every E gather of this step: added to the
        # expiry-scatter values below, it forces XLA to order the reads
        # before the write, which lets the scatter update the scan carry
        # IN PLACE instead of copying the whole state every step
        dep = 0.0 * E_before[0]

        # --- anchor resolution ----------------------------------------
        if const_dt:
            a0 = anchor[cl]
            anchor_alive = jnp.where(
                x["first_c"], (a0 == j) & (E_before > 0.0),
                x["prev_j"] == j)
        else:
            e_val_s = x["t_s"] + dt[x["j_s"]]
            v, bidx = seg_argmax_fn(e_val_s, x["first_cs"])
            a0_s = anchor[x["c_s"]]
            Eg = E[x["c_s"], jnp.maximum(a0_s, 0)]     # finite gather
            dep = dep + 0.0 * Eg[0]
            Ea0_s = jnp.where(a0_s >= 0, Eg, -jnp.inf)
            prev_v = jnp.where(
                x["first_cs"], -jnp.inf,
                jnp.concatenate([jnp.full(1, -jnp.inf, v.dtype), v[:-1]]))
            prev_b = jnp.where(
                x["first_cs"], 0,
                jnp.concatenate([jnp.zeros(1, bidx.dtype), bidx[:-1]]))
            inbatch = (~x["first_cs"]) & (prev_v >= Ea0_s)
            anchor_seen_s = jnp.where(
                inbatch, x["j_s"][prev_b], a0_s).astype(jnp.int32)
            anchor_seen = anchor_seen_s[x["inv_o_c"]]   # un-sort by gather
            anchor_alive = (anchor_seen == j) & (E_before > 0.0)

        fresh = E_before > t
        if "nokeep" in x:
            # keep-or-not (TTL) cliques: forced miss — their state writes
            # are routed to the dump row, so lag chains must not
            # fabricate hits from them (mirrors engine.handle_batch)
            fresh = fresh & ~x["nokeep"]
            anchor_alive = anchor_alive & ~x["nokeep"]
        alive = fresh | anchor_alive
        miss = (~alive) & val
        lapsed = alive & (~fresh) & val

        # Alg. 6 ratcheting of lapsed anchor copies
        steps = jnp.ceil((t - E_before) / dt_e)
        r = E_before + steps * dt_e
        r = jnp.where(r <= t, r + dt_e, r)
        e_eff = jnp.where(fresh, E_before, jnp.where(lapsed, r, t))

        # --- costs (vectorized CostModel hooks) -----------------------
        size = x["size"]
        csize = x["csize"] if "csize" in x else size
        rate_stored = _rate_hook(kind, spec, size, csize, j)
        rent = jnp.where(lapsed, rate_stored * (e_eff - E_before), 0.0)
        tc = jnp.where(
            miss, _transfer_hook(kind, spec, size, csize, j), 0.0)
        if charge == "requested":
            rate = _rate_hook(
                kind, spec, x["n_req"],
                x["req_size"] if "req_size" in x else x["n_req"], j)
        else:
            rate = rate_stored
        dur = jnp.maximum((t + dt_e) - jnp.maximum(e_eff, t), 0.0)
        cval = (val & ~x["nokeep"]) if "nokeep" in x else val
        cc = jnp.where(cval, rate * dur, 0.0)

        nm = miss.sum()
        acc = acc + jnp.stack([
            tc.sum(), cc.sum(), rent.sum(),
            nm.astype(acc.dtype), (val.sum() - nm).astype(acc.dtype),
            jnp.where(miss, size, 0.0).sum(),
        ])

        # --- state update on the COMPACTED segment-last arrays --------
        uc, uj, ac = x["upd_c"], x["upd_j"], x["anc_c"]
        if const_dt:
            E = E.at[uc, uj].set(x["upd_t"] + dt[0] + dep)
            a_cur = anchor[ac]
            aE = E[ac, jnp.maximum(a_cur, 0)]        # POST-update E
            upd = (a_cur < 0) | (x["anc_t"] + dt[0] >= aE)
            anchor = anchor.at[jnp.where(upd, ac, K)].set(x["anc_j"])
        else:
            e_cj_s = x["cj_t_s"] + dt[x["cj_j_s"]]
            vmax = seg_max_fn(e_cj_s, x["first_cjs"])
            E = E.at[uc, uj].set(vmax[x["pos_u"]] + dep)
            pa = x["pos_a"]
            win = v[pa] >= Ea0_s[pa]
            final_anchor = jnp.where(
                win, x["j_s"][bidx[pa]], a0_s[pa]).astype(jnp.int32)
            anchor = anchor.at[ac].set(final_anchor)
        return (E, anchor, acc), None

    return jax.lax.scan(step, init, xs)[0]


@functools.lru_cache(maxsize=64)
def _compiled_replay(kind, charge, const_dt, use_pallas, vmapped):
    f = functools.partial(
        _replay_impl, kind=kind, charge=charge, const_dt=const_dt,
        use_pallas=use_pallas)
    if vmapped == "xs":       # trace-shard axis: a schedule PER lane
        f = jax.vmap(f, in_axes=(0, 0, 0))
    elif vmapped:             # scenario axis: one schedule, many specs
        f = jax.vmap(f, in_axes=(0, 0, None))
    return jax.jit(f)


def run_schedule(
    schedule: ReplaySchedule,
    spec: dict,
    statics: tuple,
    E0: np.ndarray,
    anchor0: np.ndarray,
    *,
    charge: CachingCharge = "requested",
    use_pallas: bool | None = None,
    block: bool = True,
    layout: StateLayout | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Execute one schedule for one scenario; returns (E, anchor, acc).

    ``spec``/``E0``/``anchor0`` may carry a leading scenario axis (then all
    three outputs do too and the compiled replay is vmapped over it with
    the schedule shared unbatched across scenarios).  ``block=False``
    returns the device arrays without waiting — XLA keeps computing in the
    background while the caller builds the next group's schedule (the
    SweepEngine pipeline); materialize with ``np.asarray`` when needed.
    A row-sharded ``layout`` commits the state rows to its mesh placement
    before the scan, so GSPMD partitions the row gathers/scatters.
    """
    _require_jax()
    if use_pallas is None:
        from ..kernels.autowire import default_segment_hooks

        use_pallas = default_segment_hooks()[0] is not None
    vmapped = E0.ndim == 3
    fn = _compiled_replay(
        statics, charge, schedule.const_dt, bool(use_pallas), vmapped)
    with enable_x64():
        acc_shape = (E0.shape[0], N_ACC) if vmapped else (N_ACC,)
        if layout is not None and isinstance(E0, np.ndarray):
            # host inputs get the layout's mesh placement here; arrays a
            # caller (SweepEngine._shard) already committed keep theirs
            E0, anchor0 = layout.place_state(E0, anchor0)
        init = (
            jnp.asarray(E0, jnp.float64),
            jnp.asarray(anchor0, jnp.int32),
            jnp.zeros(acc_shape, jnp.float64),
        )
        spec_j = {k: jnp.asarray(v) for k, v in spec.items()}
        xs_j = {k: jnp.asarray(v) for k, v in schedule.xs.items()}
        E, anchor, acc = fn(spec_j, init, xs_j)
        if not block:
            return E, anchor, acc
        return np.asarray(E), np.asarray(anchor), np.asarray(acc)


def run_schedules(
    schedules: list,
    spec: dict,
    statics: tuple,
    E0: np.ndarray,
    anchor0: np.ndarray,
    *,
    charge: CachingCharge = "requested",
    use_pallas: bool | None = None,
    block: bool = True,
    layout: StateLayout | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Execute S schedules lane-for-lane: lane i replays ``schedules[i]``
    under spec lane i — the trace-shard axis of :mod:`repro.core.sweep`.

    Unlike :func:`run_schedule` (one schedule shared unbatched across
    scenario lanes), the event tensors are STACKED along the lane axis and
    the compiled scan is vmapped over them too (``in_axes=(0, 0, 0)``).
    All schedules must share padded dims (``pad_schedule``) and
    (n, m, const_dt); ``spec``/``E0``/``anchor0`` carry the leading S axis.
    """
    _require_jax()
    if use_pallas is None:
        from ..kernels.autowire import default_segment_hooks

        use_pallas = default_segment_hooks()[0] is not None
    s0 = schedules[0]
    assert E0.ndim == 3 and E0.shape[0] == len(schedules)
    assert all(s.const_dt == s0.const_dt and schedule_dims(s) ==
               schedule_dims(s0) for s in schedules[1:])
    fn = _compiled_replay(
        statics, charge, s0.const_dt, bool(use_pallas), "xs")
    with enable_x64():
        if layout is not None and isinstance(E0, np.ndarray):
            E0, anchor0 = layout.place_state(E0, anchor0)
        init = (
            jnp.asarray(E0, jnp.float64),
            jnp.asarray(anchor0, jnp.int32),
            jnp.zeros((E0.shape[0], N_ACC), jnp.float64),
        )
        spec_j = {k: jnp.asarray(v) for k, v in spec.items()}
        xs_j = {k: jnp.stack([jnp.asarray(s.xs[k]) for s in schedules])
                for k in s0.xs}
        E, anchor, acc = fn(spec_j, init, xs_j)
        if not block:
            return E, anchor, acc
        return np.asarray(E), np.asarray(anchor), np.asarray(acc)


def fresh_state_arrays(
    n: int, m: int, layout: StateLayout | str | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Device-layout expiries + anchors, all empty (dense: (n+1, m))."""
    rows, cols = StateLayout.resolve(layout).state_dims(n, m)
    return (np.zeros((rows, cols), np.float64), np.full(rows, -1, np.int32))


def state_to_device(
    state: CacheState, n: int, layout: StateLayout | str | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Numpy ``CacheState`` -> padded device-layout arrays."""
    E0, a0 = fresh_state_arrays(n, state.m, layout)
    k = state.partition.k
    E0[:k, : state.m] = state.E
    a0[:k] = state.anchor
    return E0, a0


def pad_spec_cols(spec: dict, ncol: int) -> dict:
    """Pad the per-server spec arrays to a layout's column count.

    Bucketed cohorts only share a compiled scan if EVERY input shape
    matches — the state dims come from the layout, but ``dt``/``lam_j``/
    ``mu_j`` are (m,) per scenario.  Edge-replicating them to ``ncol``
    is free (padded entries are never gathered: every ``j`` index in the
    schedule is < m) and lets two points with different real m share one
    cohort."""
    out = dict(spec)
    for key in ("dt", "lam_j", "mu_j"):
        a = np.asarray(spec[key])
        w = ncol - a.shape[-1]
        if a.ndim and w > 0:
            out[key] = np.concatenate(
                [a, np.repeat(a[..., -1:], w, axis=-1)], axis=-1)
    return out


def apply_acc(costs: CostBreakdown, schedule: ReplaySchedule,
              acc: np.ndarray) -> CostBreakdown:
    """Fold one scenario's device accumulator + host counters into costs."""
    costs.transfer += float(acc[0])
    costs.caching += float(acc[1])
    costs.keepalive_rent += float(acc[2])
    costs.n_misses += int(acc[3])
    costs.n_hits += int(acc[4])
    costs.items_transferred += int(acc[5])
    costs.n_requests += schedule.n_requests
    costs.n_item_requests += schedule.n_item_requests
    return costs


# ---------------------------------------------------------------------------
# drop-in engine + offline driver
# ---------------------------------------------------------------------------
class JaxReplayEngine:
    """``ReplayEngine.replay``-compatible driver backed by the jit'd scan.

    Wraps (or builds) a NumPy :class:`~repro.core.engine.ReplayEngine` that
    holds configuration, cache state and costs; ``replay`` builds the host
    schedule from the wrapped engine's CURRENT state, runs the device scan,
    and syncs state + costs back — so snapshots, ``install_partition`` and
    any later numpy-engine use observe exactly what a numpy replay would
    have produced (state float-for-float; cost sums at 1e-9).
    """

    def __init__(self, *args, engine: ReplayEngine | None = None,
                 layout: StateLayout | str | None = None, **kwargs):
        _require_jax()
        self.engine = engine if engine is not None else ReplayEngine(
            *args, **kwargs)
        self.layout = StateLayout.resolve(layout)
        # fail fast on cost models the device hooks cannot express
        self._spec, self._statics = cost_spec(
            self.engine.model, self.engine.env)
        ncol = self.layout.state_cols(self.engine.env.m)
        if ncol != self.engine.env.m:
            self._spec = pad_spec_cols(self._spec, ncol)

    # delegated views (the engine object stays the source of truth)
    @property
    def state(self) -> CacheState:
        return self.engine.state

    @property
    def costs(self) -> CostBreakdown:
        return self.engine.costs

    @property
    def env(self) -> CacheEnvironment:
        return self.engine.env

    @property
    def model(self) -> CostModel:
        return self.engine.model

    def install_partition(self, *a, **k) -> None:
        self.engine.install_partition(*a, **k)

    def replay(
        self,
        trace,
        clique_generator=None,
        t_cg: float | None = None,
        progress: Callable[[int], None] | None = None,
        batch_size: int | None = None,
        *,
        next_cg0: float | None = None,
        win_prefix: tuple[np.ndarray, np.ndarray] | None = None,
    ) -> CostBreakdown:
        eng = self.engine
        keep_fn = None
        if clique_generator is not None and t_cg is not None:
            pol = getattr(clique_generator, "__self__", None)
            keep_fn = getattr(pol, "item_keep", None)
            # device-resident CGM (DESIGN.md §11): when the generator is
            # an unmodified AKPC ``on_window`` the whole merge/split loop
            # runs inside the scan — raw request tensors go up, costs
            # come back, zero host clique-generation calls
            if pol is not None:
                from .cgm_jax import replay_cgm, wants_device_cgm

                # the fused CGM scan keeps a dense-n carry of its own
                # regardless of the session layout (compact (h, h) CRM
                # workspace + (n+1,)-row state it builds via
                # ``state_to_device``), so any single-shard layout —
                # dense or bucketed — may take the device path
                if wants_device_cgm(pol, trace, eng.model) \
                        and self.layout.supports_device_cgm(
                            eng.env.n, eng.env.m):
                    return replay_cgm(
                        self, pol, trace, t_cg=t_cg,
                        batch_size=batch_size, next_cg0=next_cg0,
                        win_prefix=win_prefix, progress=progress)
        schedule = build_schedule(
            eng.state.partition, trace, clique_generator, t_cg,
            model=eng.model, env=eng.env, batch_size=batch_size,
            seed_new_cliques=eng.seed_new_cliques,
            next_cg0=next_cg0, win_prefix=win_prefix, lookup=eng._lookup,
            progress=progress, layout=self.layout,
        )
        # shape-stability ratchet: pad every chunk's tensors up to the
        # largest dims this engine has seen, so a streamed session (ragged
        # tail chunks included) reuses one compiled scan instead of
        # recompiling per chunk shape (tests/test_serving_live.py)
        dims = schedule_dims(schedule)
        prev = getattr(self, "_dims", None)
        if prev is not None:
            dims = {k: max(dims[k], prev[k]) for k in dims}
        self._dims = dims
        schedule = pad_schedule(schedule, dims)
        self.last_schedule = schedule
        E0, a0 = state_to_device(eng.state, schedule.n, self.layout)
        E, anchor, acc = run_schedule(
            schedule, self._spec, self._statics, E0, a0,
            charge=eng.caching_charge, layout=self.layout)
        part = schedule.final_partition
        eng.state = CacheState.from_device(part, E, anchor, eng.m)
        eng._set_partition_caches(part)
        apply_acc(eng.costs, schedule, acc)
        if keep_fn is not None:
            # boundary evictions already ran on device; this only aligns
            # the numpy engine's mask for any later host-side feed()
            eng.set_item_keep(keep_fn(), evict=False)
        return eng.costs


def run_policy_jax(policy, trace, *, batch_size=None, progress=None,
                   layout=None):
    """Offline driver on the JAX backend — ``run_policy(backend="jax")``.

    Mirrors :func:`repro.core.policy.run_policy` step for step (policy
    bind, environment resolution, offline initial partition, T_CG window
    replay), swapping the replay core for the device scan.
    """
    import time as _time

    from .policy import RunResult, get_policy

    if isinstance(policy, str):
        policy = get_policy(policy)
    t0 = _time.perf_counter()
    policy.bind(trace.n, trace.m)
    env = CacheEnvironment.resolve(
        getattr(policy, "env", None), trace, policy.params)
    eng = JaxReplayEngine(
        trace.n,
        trace.m,
        policy.params,
        caching_charge=getattr(policy, "caching_charge", "requested"),
        seed_new_cliques=getattr(policy, "seed_new_cliques", True),
        env=env,
        cost_model=getattr(policy, "cost_model", "table1"),
        layout=layout,
    )
    part0 = (
        policy.initial_partition(trace)
        if hasattr(policy, "initial_partition") else None
    )
    if part0 is not None:
        eng.install_partition(part0, now=0.0)
    gen = policy.on_window if policy.t_cg is not None else None
    bs = batch_size if batch_size is not None else getattr(
        policy, "batch_size", None)
    eng.replay(trace, clique_generator=gen, t_cg=policy.t_cg,
               progress=progress, batch_size=bs)
    return RunResult(
        policy=policy.name,
        costs=eng.costs,
        clique_sizes=eng.state.partition.sizes(),
        size_history=list(getattr(policy, "size_history", [])),
        n_windows=getattr(policy, "n_windows", 0),
        cg_seconds=getattr(policy, "cg_seconds", 0.0),
        wall_seconds=_time.perf_counter() - t0,
        config=getattr(policy, "config", None),
    )
