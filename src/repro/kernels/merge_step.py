"""Pallas TPU kernel: initial merge-density matrix D of the Alg.-3 scan.

The device-resident CGM (``core.cgm_jax``) runs the approximate merge as a
``lax.while_loop`` over a thresholded density matrix

    D[i, j] = density(i u j)   if |i| + |j| == omega and density >= gamma
            = -1.0             otherwise,

patched incrementally (one row/col per merge).  The initial D is the only
O(S^2) dense build of the loop; this kernel assembles it on the VPU from the
pair-edge matrix X = M A M^T (``clique_density.py``) and the group sizes:

    within[i]  = X[i, i] / 2
    e(i u j)   = (within[i] + within[j]) + X[i, j]
    D[i, j]    = e / e_max  thresholded as above.

Float32 op order matches ``core.cliques._densities`` exactly (the entries
are exact small integers in fp32, the quotient is a single rounding), so
kernel and jnp fallback are bit-identical — the device/host parity bar.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_density_kernel(
    x_ref, wrow_ref, wcol_ref, srow_ref, scol_ref, om_ref, gm_ref, em_ref,
    out_ref, *, bm: int,
):
    """Grid (Sp/bm,): one row block of D per step, all-pairs elementwise."""
    i = pl.program_id(0)
    x = x_ref[...]                                   # (bm, Sp)
    wi = wcol_ref[...]                               # (bm, 1)
    wj = wrow_ref[...]                               # (1, Sp)
    si = scol_ref[...]                               # (bm, 1) int32
    sj = srow_ref[...]                               # (1, Sp) int32
    om = om_ref[0, 0]
    gm = gm_ref[0, 0]
    em = em_ref[0, 0]
    r = i * bm + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    c = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    okp = ((si + sj) == om) & (r != c)
    e_u = (wi + wj) + x
    dens = jnp.where(okp, e_u / em, -1.0)
    out_ref[...] = jnp.where(dens >= gm, dens, -1.0)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def merge_density(X, sizes, omega, gamma32, *, bm: int = 128,
                  interpret: bool = False):
    """X (S, S) fp32 pair edges, sizes (S,) int32 -> D (S, S) fp32.

    ``omega`` (int32) and ``gamma32`` (float32) are runtime scalars so a
    vmapped hyperparameter sweep can trace this once.  Pad rows/cols have
    size 0 and can never pass the ``|i| + |j| == omega`` gate (omega >= 2).
    """
    S = X.shape[0]
    assert X.shape == (S, S) and sizes.shape == (S,)
    Sp = -(-S // max(bm, 128)) * max(bm, 128)
    Xp = jnp.zeros((Sp, Sp), jnp.float32).at[:S, :S].set(X)
    within = jnp.zeros(Sp, jnp.float32).at[:S].set(
        jnp.diag(X).astype(jnp.float32) / 2.0)
    sz = jnp.zeros(Sp, jnp.int32).at[:S].set(sizes.astype(jnp.int32))
    om = jnp.asarray(omega, jnp.int32).reshape(1, 1)
    gm = jnp.asarray(gamma32, jnp.float32).reshape(1, 1)
    om_f = jnp.asarray(omega, jnp.float64)
    em = (om_f * (om_f - 1.0) / 2.0).astype(jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_merge_density_kernel, bm=bm),
        grid=(Sp // bm,),
        in_specs=[
            pl.BlockSpec((bm, Sp), lambda i: (i, 0)),
            pl.BlockSpec((1, Sp), lambda i: (0, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, Sp), lambda i: (0, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, Sp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, Sp), jnp.float32),
        interpret=interpret,
    )(
        Xp,
        within.reshape(1, Sp), within.reshape(Sp, 1),
        sz.reshape(1, Sp), sz.reshape(Sp, 1),
        om, gm, em,
    )
    return out[:S, :S]


@jax.jit
def merge_density_jnp(X, sizes, omega, gamma32):
    """Fused-jnp fallback with ``core.cliques._densities`` float32 op
    order — bit-identical to the Mosaic kernel."""
    S = X.shape[0]
    within = jnp.diag(X) / 2.0
    e_u = (within[:, None] + within[None, :]) + X
    om_f = jnp.asarray(omega, jnp.float64)
    e_max = (om_f * (om_f - 1.0) / 2.0).astype(jnp.float32)
    eyeS = jnp.eye(S, dtype=bool)
    okp = ((sizes[:, None] + sizes[None, :])
           == jnp.asarray(omega, jnp.int32)) & ~eyeS
    dens = jnp.where(okp, e_u / e_max, -1.0)
    return jnp.where(dens >= jnp.asarray(gamma32, jnp.float32), dens, -1.0)


def merge_density_auto(X, sizes, omega, gamma32, **kw):
    """Mosaic on TPU, fused jnp elsewhere (replaces interpret mode)."""
    if jax.default_backend() == "tpu":
        return merge_density(X, sizes, omega, gamma32, **kw)
    return merge_density_jnp(X, sizes, omega, gamma32)
