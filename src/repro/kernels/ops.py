"""Jit'd public wrappers around the Pallas kernels.

On this CPU-only container the kernels run with interpret=True (the Pallas
body executed in Python, validating logic + BlockSpecs); on a real TPU the
same call sites compile to Mosaic.  ``INTERPRET`` flips automatically.
"""
from __future__ import annotations

import jax
import numpy as np

from .clique_density import clique_pair_edges
from .crm_update import crm_update
from .packed_lookup import packed_lookup, unpacked_lookup
from .segment_reduce import seg_running_argmax, seg_running_max

INTERPRET = jax.default_backend() != "tpu"


def crm_matmul(H):
    """Accelerated CRM accumulation hook for repro.core.crm.build_window_crm:
    H (B, n) one-hot -> (n, n) counts (zero diagonal)."""
    return np.asarray(crm_update(H, interpret=INTERPRET))


def pair_edges(M, A):
    """Accelerated merge-score hook for repro.core.cliques.merge_scores:
    membership (k, h) x binary CRM (h, h) -> (k, k) union edge counts."""
    return np.asarray(clique_pair_edges(M, A, interpret=INTERPRET))


def seg_max(values, starts):
    """Segmented running max hook for the JAX replay backend
    (core/engine_jax.py): (L,) values + (L,) segment-start flags."""
    return seg_running_max(values, starts, interpret=INTERPRET)


def seg_argmax(values, starts):
    """Segmented running (max, latest-argmax) hook for the JAX replay
    backend's per-server-dt anchor resolution."""
    return seg_running_argmax(values, starts, interpret=INTERPRET)


def gather_packed(table, ids):
    return packed_lookup(table, ids, interpret=INTERPRET)


def gather_unpacked(items, ids):
    return unpacked_lookup(items, ids, interpret=INTERPRET)
