"""Jit'd public wrappers around the Pallas kernels.

On this CPU-only container the kernels run with interpret=True (the Pallas
body executed in Python, validating logic + BlockSpecs); on a real TPU the
same call sites compile to Mosaic.  ``INTERPRET`` flips automatically.
"""
from __future__ import annotations

import jax
import numpy as np

from .clique_density import clique_pair_edges
from .crm_update import crm_update
from .packed_lookup import packed_lookup, unpacked_lookup

INTERPRET = jax.default_backend() != "tpu"


def crm_matmul(H):
    """Accelerated CRM accumulation hook for repro.core.crm.build_window_crm:
    H (B, n) one-hot -> (n, n) counts (zero diagonal)."""
    return np.asarray(crm_update(H, interpret=INTERPRET))


def pair_edges(M, A):
    """Accelerated merge-score hook for repro.core.cliques.merge_scores:
    membership (k, h) x binary CRM (h, h) -> (k, k) union edge counts."""
    return np.asarray(clique_pair_edges(M, A, interpret=INTERPRET))


def gather_packed(table, ids):
    return packed_lookup(table, ids, interpret=INTERPRET)


def gather_unpacked(items, ids):
    return unpacked_lookup(items, ids, interpret=INTERPRET)
