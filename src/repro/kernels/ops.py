"""Jit'd public wrappers around the Pallas kernels.

On a real TPU the CGM matmul hooks compile to Mosaic; on every other
backend they dispatch to fused-jnp twins (bit-identical — exact fp32
integer counts — and XLA-native fast, replacing the old interpret-mode
fallback that executed the Pallas body in Python).  The segment-reduce
and lookup kernels keep ``INTERPRET`` off-TPU: their scan-shaped bodies
have no faster jnp twin at the hook seam.
"""
from __future__ import annotations

import jax
import numpy as np

from .clique_density import clique_pair_edges_auto
from .crm_update import crm_update_auto
from .packed_lookup import packed_lookup, unpacked_lookup
from .segment_reduce import seg_running_argmax, seg_running_max

INTERPRET = jax.default_backend() != "tpu"


def crm_matmul(H):
    """Accelerated CRM accumulation hook for repro.core.crm.build_window_crm:
    H (B, n) one-hot -> (n, n) counts (zero diagonal)."""
    return np.asarray(crm_update_auto(H))


def pair_edges(M, A):
    """Accelerated merge-score hook for repro.core.cliques.merge_scores:
    membership (k, h) x binary CRM (h, h) -> (k, k) union edge counts."""
    return np.asarray(clique_pair_edges_auto(M, A))


def seg_max(values, starts):
    """Segmented running max hook for the JAX replay backend
    (core/engine_jax.py): (L,) values + (L,) segment-start flags."""
    return seg_running_max(values, starts, interpret=INTERPRET)


def seg_argmax(values, starts):
    """Segmented running (max, latest-argmax) hook for the JAX replay
    backend's per-server-dt anchor resolution."""
    return seg_running_argmax(values, starts, interpret=INTERPRET)


def gather_packed(table, ids):
    return packed_lookup(table, ids, interpret=INTERPRET)


def gather_unpacked(items, ids):
    return unpacked_lookup(items, ids, interpret=INTERPRET)
