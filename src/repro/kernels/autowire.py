"""Backend-aware default wiring of the CGM Pallas kernels.

The clique-generation hot path has two accelerable matmuls (DESIGN.md §8):

* ``crm_matmul``  — Alg. 2 co-occurrence accumulation ``H^T @ H``
                    (``kernels.crm_update``);
* ``pair_edges``  — the Alg. 3 merge-scan pair-edge matrix ``M A M^T``
                    (``kernels.clique_density``).

On a TPU backend both compile to MXU matmuls and beat the numpy oracles; in
interpret mode (CPU-only containers) they are strictly slower than the numpy
paths they validate, so autowiring only engages when a real TPU is attached.
``AKPCConfig(kernels="auto")`` (the default) calls this; ``kernels="off"``
keeps the numpy oracles regardless of backend.  JAX is probed defensively —
the pure-numpy core must keep working in containers without the accelerator
toolchain.
"""
from __future__ import annotations

from typing import Callable


def default_cgm_hooks() -> tuple[Callable | None, Callable | None]:
    """(crm_matmul, pair_edges) Pallas wrappers iff a TPU backend is live.

    Returns (None, None) — i.e. "use the numpy oracles" — when JAX is
    missing, broken, or running on a non-TPU backend.
    """
    try:
        import jax

        if jax.default_backend() != "tpu":
            return None, None
        from .ops import crm_matmul, pair_edges

        return crm_matmul, pair_edges
    except Exception:
        return None, None
