"""Backend-aware default wiring of the Pallas kernels.

Two kernel families hang off this module's decision:

* the CGM matmuls (DESIGN.md §8) — ``crm_matmul`` (Alg. 2 co-occurrence
  ``H^T @ H``, ``kernels.crm_update``) and ``pair_edges`` (the Alg. 3
  merge-scan ``M A M^T``, ``kernels.clique_density``);
* the replay-scan segment reductions (DESIGN.md §10) —
  ``seg_running_max`` / ``seg_running_argmax`` (``kernels.segment_reduce``)
  used by the JAX replay backend's anchor resolution and expiry update.

On TPU the kernels compile to Mosaic and beat the numpy/jnp oracles; on
any other engaged backend (GPU today — the kernels use TPU-flavoured
Pallas, so ``kernels/ops.py`` keeps ``interpret=True`` off-TPU) they run
the Pallas bodies in interpret mode: numerically identical, useful for
validating the kernel path on the hardware you have, but SLOWER than the
jnp fallbacks until Mosaic-GPU ports land.  Set ``REPRO_KERNELS=off`` to
keep the fast fallbacks on GPU; on CPU autowiring never engages unless
forced.

The decision table (``kernels_enabled``):

    REPRO_KERNELS     backend      -> engage?
    -----------------------------------------
    force/on/1/always anything     -> yes   (interpret mode on CPU)
    off/0/never       anything     -> no
    auto/unset        cpu or None  -> no
    auto/unset        tpu/gpu/...  -> yes

``AKPCConfig(kernels="auto")`` (the default) consumes ``default_cgm_hooks``;
``kernels="off"`` keeps the numpy oracles regardless of backend.  JAX is
probed defensively — the pure-numpy core must keep working in containers
without the accelerator toolchain.
"""
from __future__ import annotations

import os
from typing import Callable

_FORCE = ("force", "on", "1", "always")
_NEVER = ("off", "0", "never")


def kernels_enabled(backend: str | None = None,
                    env: str | None = None) -> bool:
    """Should the Pallas kernels engage?  Pure decision function.

    ``backend`` is a jax backend name (``"cpu"``/``"gpu"``/``"tpu"``/...)
    or None when JAX is unavailable; ``env`` overrides the
    ``REPRO_KERNELS`` environment variable (tests pass it explicitly).
    """
    if env is None:
        env = os.environ.get("REPRO_KERNELS", "")
    env = env.strip().lower()
    if env in _FORCE:
        return True
    if env in _NEVER:
        return False
    # auto: any live non-CPU accelerator
    return backend is not None and backend != "cpu"


def _probe_backend() -> str | None:
    try:
        import jax

        return jax.default_backend()
    except Exception:
        return None


def default_cgm_hooks() -> tuple[Callable | None, Callable | None]:
    """(crm_matmul, pair_edges) Pallas wrappers iff the decision says go.

    Returns (None, None) — i.e. "use the numpy oracles" — when JAX is
    missing, broken, or the decision table says the backend isn't worth it.
    """
    if not kernels_enabled(_probe_backend()):
        return None, None
    try:
        from .ops import crm_matmul, pair_edges

        return crm_matmul, pair_edges
    except Exception:
        return None, None


def default_segment_hooks() -> tuple[Callable | None, Callable | None]:
    """(seg_running_max, seg_running_argmax) Pallas wrappers, or
    (None, None) to make the JAX replay backend use its jnp fallbacks."""
    if not kernels_enabled(_probe_backend()):
        return None, None
    try:
        from .ops import seg_max, seg_argmax

        return seg_max, seg_argmax
    except Exception:
        return None, None
