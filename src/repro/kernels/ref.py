"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax.numpy as jnp


def crm_ref(H):
    """H (B, n) -> (n, n) fp32 co-occurrence counts with zero diagonal."""
    Hf = H.astype(jnp.float32)
    out = Hf.T @ Hf
    n = out.shape[0]
    return out * (1.0 - jnp.eye(n, dtype=jnp.float32))


def clique_pair_edges_ref(M, A):
    """M (k, n), A (n, n) -> X = M A M^T in fp32."""
    Mf = M.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    return Mf @ Af @ Mf.T


def packed_lookup_ref(table, ids):
    """table (C, omega, d), ids (R,) -> (R, omega, d)."""
    return table[ids]


def unpacked_lookup_ref(items, ids):
    """items (n, d), ids (R, omega) -> (R, omega, d)."""
    return items[ids]
