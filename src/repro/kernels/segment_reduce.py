"""Pallas TPU kernels: segmented running max / running argmax.

The JAX replay backend (``core/engine_jax.py``) keeps two inner segment
reductions on device (everything else is hoisted into the host-built replay
schedule, DESIGN.md §10):

* ``seg_running_max``    — inclusive running maximum within each segment of
  a (clique, server)-sorted event stream; the value at a segment's last
  position is the pair's post-batch expiry ``max_e (t_e + dt_{j_e})``.
* ``seg_running_argmax`` — the same scan carrying the LATEST index attaining
  the maximum (ties -> later event, matching the scalar ``touch`` rule's
  ``>=`` anchor update); this is the Alg.-6 anchor resolution over a
  clique-sorted event stream under per-server dt (DESIGN.md §9).

Both are Hillis-Steele doubling scans: log2(L) rounds of shift + select,
with segment ids from a cumulative sum over the start flags.  The Pallas
bodies run the identical rounds on a (1, L) block in VMEM; on non-TPU
backends they execute with ``interpret=True`` (kernels/ops.py pattern).
``seg_running_max_jnp`` / ``seg_running_argmax_jnp`` are the pure-jnp
fallbacks the JAX engine uses when ``kernels/autowire.py`` decides the
backend does not warrant Pallas.

JAX is imported defensively so the pure-NumPy core keeps working in
containers without the accelerator toolchain.
"""
from __future__ import annotations

import functools

import numpy as np

try:  # accelerator layer is optional
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    _HAS_JAX = True
except Exception:  # pragma: no cover - exercised only in jax-less containers
    jax = None
    _HAS_JAX = False


def _n_rounds(L: int) -> int:
    r, d = 0, 1
    while d < L:
        r += 1
        d <<= 1
    return r


def _kernel_unavailable(*_a, **_k):
    raise ImportError(
        "seg_running_max/seg_running_argmax need JAX; use the numpy oracle "
        "kernels/ref.py:seg_running_max_ref instead"
    )


if _HAS_JAX:

    def _scan_rounds(v, seg, idx, rounds):
        """Shared doubling rounds on (1, L) arrays; idx may be None."""
        L = v.shape[-1]
        d = 1
        for _ in range(rounds):
            vs = jnp.concatenate(
                [jnp.full((1, d), -jnp.inf, v.dtype), v[:, : L - d]], axis=1)
            ss = jnp.concatenate(
                [jnp.full((1, d), -1, seg.dtype), seg[:, : L - d]], axis=1)
            # earlier candidate wins only if STRICTLY greater: ties keep the
            # LATER index (scalar touch's >= anchor update)
            take = (ss == seg) & (vs > v)
            v = jnp.where(take, vs, v)
            if idx is not None:
                is_ = jnp.concatenate(
                    [jnp.zeros((1, d), idx.dtype), idx[:, : L - d]], axis=1)
                idx = jnp.where(take, is_, idx)
            d <<= 1
        return v, idx

    def _segmax_kernel(v_ref, s_ref, out_ref, *, rounds: int):
        v = v_ref[...]
        seg = jnp.cumsum(s_ref[...].astype(jnp.int32), axis=1)
        v, _ = _scan_rounds(v, seg, None, rounds)
        out_ref[...] = v

    def _segargmax_kernel(v_ref, s_ref, vout_ref, iout_ref, *, rounds: int):
        v = v_ref[...]
        seg = jnp.cumsum(s_ref[...].astype(jnp.int32), axis=1)
        idx = jax.lax.broadcasted_iota(jnp.int32, v.shape, 1)
        v, idx = _scan_rounds(v, seg, idx, rounds)
        vout_ref[...] = v
        iout_ref[...] = idx

    @functools.partial(jax.jit, static_argnames=("interpret",))
    def seg_running_max(values, starts, *, interpret: bool = False):
        """values (L,), starts (L,) bool -> (L,) inclusive per-segment
        running max.  Segments are contiguous runs beginning where
        ``starts`` is True (position 0 must start a segment)."""
        L = values.shape[0]
        out = pl.pallas_call(
            functools.partial(_segmax_kernel, rounds=_n_rounds(L)),
            out_shape=jax.ShapeDtypeStruct((1, L), values.dtype),
            interpret=interpret,
        )(values.reshape(1, L), starts.reshape(1, L).astype(jnp.int32))
        return out.reshape(L)

    @functools.partial(jax.jit, static_argnames=("interpret",))
    def seg_running_argmax(values, starts, *, interpret: bool = False):
        """values (L,), starts (L,) bool -> ((L,) running max, (L,) int32
        index of the LATEST position attaining it within the segment)."""
        L = values.shape[0]
        v, i = pl.pallas_call(
            functools.partial(_segargmax_kernel, rounds=_n_rounds(L)),
            out_shape=(
                jax.ShapeDtypeStruct((1, L), values.dtype),
                jax.ShapeDtypeStruct((1, L), jnp.int32),
            ),
            interpret=interpret,
        )(values.reshape(1, L), starts.reshape(1, L).astype(jnp.int32))
        return v.reshape(L), i.reshape(L)

    def seg_running_max_jnp(values, starts):
        """Pure-jnp fallback (same rounds, (L,) layout, any float dtype)."""
        L = values.shape[-1]
        v = values.reshape(1, L)
        seg = jnp.cumsum(starts.reshape(1, L).astype(jnp.int32), axis=1)
        v, _ = _scan_rounds(v, seg, None, _n_rounds(L))
        return v.reshape(L)

    def seg_running_argmax_jnp(values, starts):
        L = values.shape[-1]
        v = values.reshape(1, L)
        seg = jnp.cumsum(starts.reshape(1, L).astype(jnp.int32), axis=1)
        idx = jax.lax.broadcasted_iota(jnp.int32, v.shape, 1)
        v, idx = _scan_rounds(v, seg, idx, _n_rounds(L))
        return v.reshape(L), idx.reshape(L)

else:  # pragma: no cover - exercised only in jax-less containers
    seg_running_max = _kernel_unavailable
    seg_running_argmax = _kernel_unavailable
    seg_running_max_jnp = _kernel_unavailable
    seg_running_argmax_jnp = _kernel_unavailable


def seg_running_max_ref(values: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """NumPy oracle: per-position inclusive segment running max."""
    out = np.array(values, dtype=np.float64, copy=True)
    for i in range(1, out.shape[0]):
        if not starts[i]:
            out[i] = max(out[i], out[i - 1])
    return out


def seg_running_argmax_ref(
    values: np.ndarray, starts: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """NumPy oracle: running (max, latest argmax) per segment."""
    v = np.array(values, dtype=np.float64, copy=True)
    idx = np.arange(v.shape[0], dtype=np.int64)
    for i in range(1, v.shape[0]):
        if not starts[i] and v[i - 1] > v[i]:   # ties keep the later index
            v[i] = v[i - 1]
            idx[i] = idx[i - 1]
    return v, idx
