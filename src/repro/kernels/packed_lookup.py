"""Pallas TPU kernel: packed-clique gather — the paper's packed transfer,
on-chip.

The paper's economic claim is that delivering a co-accessed bundle as ONE
packed unit costs (1 + (p-1)*alpha)*lam instead of p*lam.  The memory-system
analogue on TPU: items of a clique stored CONTIGUOUSLY in HBM are fetched
with one streaming DMA per clique ((omega*d)-row burst), instead of omega
scattered row gathers — same bytes, 1/omega the DMA descriptors and no
random-access stalls.

``packed_lookup``  : table (C, omega, d) packed cliques, ids (R,) ->
                     (R, omega, d); one grid step per request, the block
                     index map reads the clique id from SCALAR-PREFETCH
                     (pltpu.PrefetchScalarGridSpec) so the DMA address is
                     known before the body runs.
``unpacked_lookup``: the baseline — one grid step per (request, item) with a
                     row-level index map (omega x the descriptor traffic).
``clique_lookup``  : the replay engine's per-batch item -> clique-id
                     membership gather.  Routed through ``packed_lookup``
                     (table reshaped to (n, 1, 1)) when a TPU backend is
                     present; plain NumPy fancy-indexing when JAX is absent
                     or running CPU-only, where a Pallas interpret-mode grid
                     walk would be strictly slower than the gather it
                     emulates.

JAX is imported defensively so the pure-NumPy replay path works in
containers without the accelerator toolchain.
"""
from __future__ import annotations

import functools

import numpy as np

try:  # accelerator layer is optional — see module docstring
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    _HAS_JAX = True
except Exception:  # pragma: no cover - exercised only in jax-less containers
    jax = None
    _HAS_JAX = False


def _kernel_unavailable(*_a, **_k):
    raise ImportError(
        "packed_lookup/unpacked_lookup need JAX with Pallas TPU support; "
        "use clique_lookup (NumPy fallback) instead"
    )


if _HAS_JAX:

    def _copy_kernel(ids_ref, table_ref, out_ref):
        del ids_ref
        out_ref[...] = table_ref[...]

    @functools.partial(jax.jit, static_argnames=("interpret",))
    def packed_lookup(table, ids, *, interpret: bool = False):
        """table (C, omega, d); ids (R,) int32 -> (R, omega, d)."""
        C, omega, d = table.shape
        R = ids.shape[0]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(R,),
            in_specs=[pl.BlockSpec((1, omega, d), lambda r, ids: (ids[r], 0, 0))],
            out_specs=pl.BlockSpec((1, omega, d), lambda r, ids: (r, 0, 0)),
        )
        return pl.pallas_call(
            _copy_kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((R, omega, d), table.dtype),
            interpret=interpret,
        )(ids.astype(jnp.int32), table)

    @functools.partial(jax.jit, static_argnames=("interpret",))
    def unpacked_lookup(items, ids, *, interpret: bool = False):
        """items (n, d); ids (R, omega) int32 -> (R, omega, d).

        Baseline: one DMA per (request, item) — omega x the descriptors.
        """
        n, d = items.shape
        R, omega = ids.shape
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(R, omega),
            in_specs=[pl.BlockSpec((1, d), lambda r, o, ids: (ids[r, o], 0))],
            out_specs=pl.BlockSpec((1, 1, d), lambda r, o, ids: (r, o, 0)),
        )
        return pl.pallas_call(
            _copy_reshape_kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((R, omega, d), items.dtype),
            interpret=interpret,
        )(ids.astype(jnp.int32).reshape(R, omega), items)

    def _copy_reshape_kernel(ids_ref, items_ref, out_ref):
        del ids_ref
        out_ref[...] = items_ref[...].reshape(out_ref.shape)

else:  # pragma: no cover - exercised only in jax-less containers
    packed_lookup = _kernel_unavailable
    unpacked_lookup = _kernel_unavailable


def clique_lookup(
    clique_of: np.ndarray,
    items: np.ndarray,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> np.ndarray:
    """Map item ids to clique ids; -1 padding slots stay -1.

    ``clique_of`` (n,) int; ``items`` any-shape int.  With ``use_pallas``
    unset, the Pallas scalar-prefetch gather is used iff a TPU backend is
    active; the NumPy path is taken when JAX is missing or CPU-only.
    """
    clique_of = np.asarray(clique_of)
    items = np.asarray(items)
    if use_pallas is None:
        use_pallas = _HAS_JAX and jax.default_backend() == "tpu"
    if not use_pallas or not _HAS_JAX:
        return np.where(items < 0, -1, clique_of[np.maximum(items, 0)])
    flat = items.reshape(-1)
    table = jnp.asarray(clique_of, jnp.int32).reshape(-1, 1, 1)
    ids = jnp.maximum(jnp.asarray(flat, jnp.int32), 0)
    got = np.asarray(packed_lookup(table, ids, interpret=interpret))
    return np.where(items < 0, -1, got.reshape(items.shape))
