"""Pallas TPU kernel: all-pairs clique union edge counts  X = M A M^T.

Implements the Alg.-3 approximate-merge scan (paper lines 4-10) in matrix
form: M (k, n) is the 0/1 clique-membership matrix restricted to the hot
items, A (n, n) the binary CRM; then

    X[i, j]   = cross-edge count between cliques i and j   (i != j)
    X[i, i]/2 = within-edge count of clique i

so the union density of every candidate pair is elementwise from X — the
whole O(k^2 w^2) pair scan collapses into two MXU matmuls.

Kernel shape: grid over (k/bm) row blocks; a VMEM scratch holds the row
strip T = M_i @ A (bm, n) computed with a k-loop over A column tiles, then a
second loop contracts T with M^T tiles.  One pass over A per row block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _density_kernel(m_row_ref, a_ref, m_all_ref, out_ref, t_ref, *, n_j: int):
    """Grid (k/bm,): out[i, :] = (M_i @ A) @ M^T."""
    mi = m_row_ref[...].astype(jnp.float32)              # (bm, n)
    a = a_ref[...].astype(jnp.float32)                   # (n, n)
    t_ref[...] = jnp.dot(mi, a, preferred_element_type=jnp.float32)
    mall = m_all_ref[...].astype(jnp.float32)            # (k, n)
    out_ref[...] = jax.lax.dot_general(
        t_ref[...], mall, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    del n_j


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def clique_pair_edges(M, A, *, bm: int = 128, interpret: bool = False):
    """M (k, n) 0/1 membership, A (n, n) binary CRM -> X (k, k) fp32.

    n and k are padded to tile multiples; pad rows/cols are zero and
    contribute nothing.
    """
    k, n = M.shape
    assert A.shape == (n, n)
    kp = -(-k // bm) * bm
    np_ = -(-n // 128) * 128
    Mp = jnp.zeros((kp, np_), M.dtype).at[:k, :n].set(M)
    Ap = jnp.zeros((np_, np_), A.dtype).at[:n, :n].set(A)
    out = pl.pallas_call(
        functools.partial(_density_kernel, n_j=kp // bm),
        grid=(kp // bm,),
        in_specs=[
            pl.BlockSpec((bm, np_), lambda i: (i, 0)),
            pl.BlockSpec((np_, np_), lambda i: (0, 0)),
            pl.BlockSpec((kp, np_), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, kp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, kp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, np_), jnp.float32)],
        interpret=interpret,
    )(Mp, Ap, Mp)
    return out[:k, :k]


@jax.jit
def clique_pair_edges_jnp(M, A):
    """Fused-jnp fallback: two XLA matmuls, exact fp32 integer counts —
    bit-identical to the Mosaic kernel."""
    Mf = M.astype(jnp.float32)
    return Mf @ A.astype(jnp.float32) @ Mf.T


def clique_pair_edges_auto(M, A, **kw):
    """Mosaic on TPU, fused jnp elsewhere (replaces interpret mode)."""
    if jax.default_backend() == "tpu":
        return clique_pair_edges(M, A, **kw)
    return clique_pair_edges_jnp(M, A)
