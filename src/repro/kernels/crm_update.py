"""Pallas TPU kernel: CRM co-occurrence accumulation (paper Alg. 2).

GPU formulation: scatter-add atomics over item pairs.  TPU adaptation
(DESIGN.md §2): co-occurrence counting is the rank-B update

    CRM += H^T @ H      with H (B, n) the request/item one-hot incidence,

i.e. a matmul — the systolic MXU does it at matmul speed with zero atomics.
The kernel is a transpose-matmul tiled over (n/bm, n/bn) output blocks with a
k-loop over request blocks; fp32 accumulation lives in a VMEM scratch.

Target: TPU v5e (128x128 MXU tiles).  Validated with interpret=True on CPU
against ``ref.crm_ref`` (tests/test_kernels.py sweeps shapes/dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _crm_kernel(h1_ref, h2_ref, out_ref, acc_ref, *, n_k: int):
    """Grid (n/bm, n/bn, B/bk): out[i, j] += h1[k, i]^T @ h2[k, j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = h1_ref[...].astype(jnp.float32)          # (bk, bm)
    b = h2_ref[...].astype(jnp.float32)          # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def crm_update(H, *, bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool = False):
    """H (B, n) 0/1 incidence -> (n, n) fp32 co-occurrence counts, zero diag.

    Pads B and n up to tile multiples (zero rows/cols contribute nothing).
    """
    B, n = H.shape
    Bp = -(-B // bk) * bk
    npad = max(-(-n // bm) * bm, -(-n // bn) * bn)
    Hp = jnp.zeros((Bp, npad), H.dtype).at[:B, :n].set(H)
    n_k = Bp // bk
    out = pl.pallas_call(
        functools.partial(_crm_kernel, n_k=n_k),
        grid=(npad // bm, npad // bn, n_k),
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, npad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(Hp, Hp)
    out = out[:n, :n]
    return out * (1.0 - jnp.eye(n, dtype=jnp.float32))


@jax.jit
def crm_update_jnp(H):
    """Fused-jnp fallback: the same f32 0/1 contraction + zero diagonal.

    Bit-identical to the Mosaic kernel — both accumulate exact small
    integers in fp32 — so ``crm_update_auto`` can switch per backend
    without moving the parity bar.
    """
    Hf = H.astype(jnp.float32)
    out = Hf.T @ Hf
    return out * (1.0 - jnp.eye(H.shape[1], dtype=jnp.float32))


def crm_update_auto(H, **kw):
    """Mosaic on TPU, fused jnp elsewhere (replaces interpret mode: the
    Python-interpreted Pallas body validated logic but was far slower
    than XLA's native matmul on CPU/GPU)."""
    if jax.default_backend() == "tpu":
        return crm_update(H, **kw)
    return crm_update_jnp(H)
