from .loader import Trace, iter_batches, iter_windows
from .synthetic import synth_trace, paper_trace, SynthConfig

__all__ = [
    "Trace",
    "iter_batches",
    "iter_windows",
    "synth_trace",
    "paper_trace",
    "SynthConfig",
]
