from .loader import (
    Trace,
    TraceBatches,
    batch_tensors,
    iter_batch_tensors,
    iter_batches,
    iter_windows,
)
from .synthetic import (
    SynthConfig,
    paper_trace,
    paper_trace_batches,
    synth_trace,
    synth_trace_batches,
)

__all__ = [
    "Trace",
    "TraceBatches",
    "batch_tensors",
    "iter_batch_tensors",
    "iter_batches",
    "iter_windows",
    "synth_trace",
    "synth_trace_batches",
    "paper_trace",
    "paper_trace_batches",
    "SynthConfig",
]
