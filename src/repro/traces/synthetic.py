"""Synthetic Netflix-like / Spotify-like traces.

The paper evaluates on Kaggle Netflix/Spotify traces (refs [15], [16]) with
synthesised user locations.  Those dumps are not available in this offline
container, so we synthesise traces with the statistics the paper relies on:

* Zipf item/bundle popularity (heavy-tailed access counts, top-10% of items
  carry most of the traffic — the paper filters CRM construction to them);
* SESSION structure: a user at one server consumes several consecutive items
  of one latent bundle (a show season / playlist) in a short burst — this is
  exactly the co-access signal AKPC mines (93%-predictability claim, §I);
* multi-item requests up to d_max (batch arrivals, Table II d_max = 5);
* 600 servers, 1M requests, integer-free float timeline (Table II).

"netflix" = fewer, smaller bundles (seasons of 4-10 episodes), strong binge
sequentiality, shorter sessions.  "spotify" = larger bundles (playlists of
8-20 tracks), longer sessions, slightly noisier.  Generators are fully seeded
and every benchmark records the SynthConfig used.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .loader import Trace, TraceBatches, batch_tensors


@dataclasses.dataclass(frozen=True)
class SynthConfig:
    kind: str = "netflix"            # "netflix" | "spotify"
    n_items: int = 600               # catalog |U| (top-10% -> 60, Table II)
    n_servers: int = 600             # |S| = m (Table II)
    n_requests: int = 1_000_000
    d_max: int = 5                   # max request size (Table II)
    seed: int = 0
    # time model: horizon chosen so hot items re-arrive within ~dt at busy
    # servers (dt = rho*lam/mu = 1 at Table-II defaults)
    t_max: float = 4000.0
    # session model
    mean_session_len: float = 6.0
    intra_gap: float = 0.02          # mean time between session requests
    p_multi: float = 0.45            # P(request has >1 item)
    p_noise: float = 0.05            # P(item replaced by random catalog item)
    bundle_zipf: float = 1.35        # bundle popularity skew (head-heavy,
    #                                  real VoD/music traces concentrate >80%
    #                                  of plays on the top titles)
    server_zipf: float = 0.9         # server load skew
    bundle_cover: float = 0.6        # fraction of catalog covered by bundles
    # regional content affinity: each server's users draw sessions from this
    # many preferred bundles (0 = no affinity, global popularity everywhere).
    # Real CDN edge nodes serve geographically clustered preferences [17-19].
    server_affinity: int = 0
    p_affinity_escape: float = 0.1   # P(session ignores the server preference)
    # per-item sizes (PR 4 CostModel axis): "unit" keeps the paper's
    # unit-size items (Trace.sizes = None); "lognormal" draws mean-1
    # lognormal volumes with log-std size_sigma; "pareto" a heavy tail
    # (think mixed episode lengths / track bitrates)
    size_dist: str = "unit"          # "unit" | "lognormal" | "pareto"
    size_sigma: float = 0.75         # lognormal log-std / pareto tail shape
    # non-stationary request volume (Carlsson & Eager's time-varying
    # arrival model, arXiv 1803.03914): session starts follow a rate
    # profile lambda(t) instead of the uniform (stationary) default.
    # The SAME uniform draws are warped through the inverse CDF of
    # lambda, so request CONTENT (bundles, servers, items) is identical
    # across profiles at a fixed seed — only arrival times shift.
    load_profile: str = "stationary"  # | "diurnal" | "flash_crowd"
    #                                 # | "regime_shift"
    load_strength: float = 0.8       # diurnal amplitude in [0, 1) /
    #                                  flash-crowd peak height (x base) /
    #                                  regime-shift rate ratio
    load_cycles: float = 2.0         # diurnal periods over the horizon
    load_peak: float = 0.5           # crowd centre / shift point (frac of
    #                                  t_max)
    load_width: float = 0.05         # flash-crowd sigma (frac of t_max)

    def bundle_size_range(self) -> tuple[int, int]:
        return (4, 10) if self.kind == "netflix" else (8, 20)


def paper_trace(kind: str, n_requests: int = 1_000_000, seed: int = 0) -> "Trace":
    """Trace matched to the paper's Table-II setup (see EXPERIMENTS.md).

    |U| = 60 items (the paper's universe is the top-10% of the raw dataset,
    so popularity inside it is flat-ish), m = 600 servers, regional content
    affinity, request density such that hot (clique, server) pairs sit at the
    TTL crossover — the regime the paper's cost dynamics live in.
    """
    dense_tmax = 6.0 * n_requests / 100_000.0
    if kind == "netflix":
        cfg = SynthConfig(
            kind="netflix", n_items=60, n_servers=600, n_requests=n_requests,
            t_max=dense_tmax, bundle_cover=1.0, bundle_zipf=0.7,
            server_affinity=2, mean_session_len=6.0, seed=seed,
        )
    elif kind == "spotify":
        cfg = SynthConfig(
            kind="spotify", n_items=60, n_servers=600, n_requests=n_requests,
            t_max=dense_tmax, bundle_cover=1.0, bundle_zipf=0.6,
            server_affinity=2, mean_session_len=10.0, p_multi=0.5, seed=seed,
        )
    else:
        raise ValueError(f"unknown paper trace kind: {kind}")
    return synth_trace(cfg)


def synth_trace_batches(cfg: SynthConfig, batch_size: int = 4096) -> TraceBatches:
    """Synthesise a trace directly as padded batch tensors (see loader)."""
    return batch_tensors(synth_trace(cfg), batch_size)


def paper_trace_batches(
    kind: str,
    n_requests: int = 1_000_000,
    seed: int = 0,
    batch_size: int = 4096,
) -> TraceBatches:
    """Table-II trace as padded batch tensors for the vectorised engine."""
    return batch_tensors(paper_trace(kind, n_requests=n_requests, seed=seed), batch_size)


def _item_sizes(cfg: SynthConfig, rng: np.random.Generator) -> np.ndarray | None:
    """Per-item volumes for the size-aware cost models (mean ~1)."""
    if cfg.size_dist == "unit":
        return None
    if cfg.size_dist == "lognormal":
        sig = cfg.size_sigma
        return np.exp(rng.normal(-0.5 * sig**2, sig, cfg.n_items))
    if cfg.size_dist == "pareto":
        a = max(1.0 + 1.0 / max(cfg.size_sigma, 1e-6), 1.05)
        raw = 1.0 + rng.pareto(a, cfg.n_items)       # Lomax + 1, support >= 1
        return raw / raw.mean()
    raise ValueError(f"unknown size_dist: {cfg.size_dist!r}")


def load_rate(cfg: SynthConfig, t: np.ndarray) -> np.ndarray:
    """Arrival-rate profile lambda(t) on [0, t_max] (mean-level ~1).

    * ``diurnal`` — sinusoidal day/night cycle (``load_cycles`` periods,
      amplitude ``load_strength``);
    * ``flash_crowd`` — Gaussian surge of height ``load_strength`` x base
      at ``load_peak``, width ``load_width`` (viral content / live event);
    * ``regime_shift`` — base rate jumps by factor ``load_strength`` at
      ``load_peak`` (catalog launch / market shift).
    """
    t = np.asarray(t, np.float64)
    x = t / max(cfg.t_max, 1e-12)
    if cfg.load_profile == "stationary":
        return np.ones_like(t)
    if cfg.load_profile == "diurnal":
        a = min(max(cfg.load_strength, 0.0), 0.999)
        return 1.0 + a * np.sin(2.0 * np.pi * cfg.load_cycles * x)
    if cfg.load_profile == "flash_crowd":
        w = max(cfg.load_width, 1e-6)
        return 1.0 + cfg.load_strength * np.exp(
            -0.5 * ((x - cfg.load_peak) / w) ** 2)
    if cfg.load_profile == "regime_shift":
        return np.where(x < cfg.load_peak, 1.0, cfg.load_strength)
    raise ValueError(f"unknown load_profile: {cfg.load_profile!r}")


def _warp_times(cfg: SynthConfig, u: np.ndarray) -> np.ndarray:
    """Uniform draws -> arrival times under ``load_rate`` via the inverse
    CDF (dense-grid trapezoid + interp); stationary profiles pass through
    as ``u * t_max``, matching the legacy uniform draw exactly."""
    if cfg.load_profile == "stationary":
        return u * cfg.t_max
    grid = np.linspace(0.0, cfg.t_max, 4097)
    lam = load_rate(cfg, grid)
    cdf = np.concatenate([
        [0.0], np.cumsum(0.5 * (lam[1:] + lam[:-1]) * np.diff(grid))])
    cdf /= cdf[-1]
    return np.interp(u, cdf, grid)


def _zipf_choice(rng: np.random.Generator, n: int, s: float, size: int) -> np.ndarray:
    """Zipf(s)-distributed choices over [0, n) (rank 0 = most popular)."""
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** s
    w /= w.sum()
    return rng.choice(n, size=size, p=w)


def synth_trace(cfg: SynthConfig) -> Trace:
    rng = np.random.default_rng(cfg.seed)

    # --- latent bundles over a contiguous hot region of the catalog -------
    lo, hi = cfg.bundle_size_range()
    covered = int(cfg.n_items * cfg.bundle_cover)
    # running total, NOT `while sum(sizes) < covered`: re-summing the
    # list is O(B^2) and dominated generation at n_items >= 10^4 (~14k
    # bundles at n=10^5).  Draw sequence is unchanged, so seeded traces
    # stay bitwise identical.
    sizes: list[int] = []
    covered_so_far = 0
    while covered_so_far < covered:
        sz = int(rng.integers(lo, hi + 1))
        sizes.append(sz)
        covered_so_far += sz
    starts = np.cumsum([0] + sizes[:-1])
    sizes_a = np.array(sizes)
    starts = starts[starts + sizes_a <= cfg.n_items]
    sizes_a = sizes_a[: len(starts)]
    n_bundles = len(starts)

    # --- sessions ----------------------------------------------------------
    n_sessions = int(cfg.n_requests / cfg.mean_session_len * 1.3) + 8
    sess_len = rng.geometric(1.0 / cfg.mean_session_len, size=n_sessions)
    sess_len = np.clip(sess_len, 1, 4 * int(cfg.mean_session_len))
    total = np.cumsum(sess_len)
    n_sessions = int(np.searchsorted(total, cfg.n_requests) + 1)
    sess_len = sess_len[:n_sessions]
    R = int(sess_len.sum())

    sess_server = _zipf_choice(rng, cfg.n_servers, cfg.server_zipf, n_sessions)
    if cfg.server_affinity > 0 and n_bundles > cfg.server_affinity:
        # each server prefers a few bundles (sampled by global popularity)
        a = min(cfg.server_affinity, n_bundles)
        wb = 1.0 / np.arange(1, n_bundles + 1) ** cfg.bundle_zipf
        wb /= wb.sum()
        prefs = np.stack(
            [
                rng.choice(n_bundles, size=a, replace=False, p=wb)
                for _ in range(cfg.n_servers)
            ]
        )                                               # (m, a)
        pick = rng.integers(0, a, size=n_sessions)
        sess_bundle = prefs[sess_server, pick]
        escape = rng.random(n_sessions) < cfg.p_affinity_escape
        n_esc = int(escape.sum())
        if n_esc:
            sess_bundle[escape] = _zipf_choice(rng, n_bundles, cfg.bundle_zipf, n_esc)
    else:
        sess_bundle = _zipf_choice(rng, n_bundles, cfg.bundle_zipf, n_sessions)
    if cfg.load_profile == "stationary":
        sess_start = rng.uniform(0.0, cfg.t_max, size=n_sessions)
    else:
        # same rng consumption as the stationary draw: content identical
        # across profiles at a fixed seed, only arrival times warp
        sess_start = _warp_times(
            cfg, rng.uniform(0.0, 1.0, size=n_sessions))

    # expand per-request arrays
    req_sess = np.repeat(np.arange(n_sessions), sess_len)
    req_bundle = sess_bundle[req_sess]
    servers = sess_server[req_sess].astype(np.int32)
    # position of the request within its session
    pos = np.arange(R) - np.repeat(np.cumsum(sess_len) - sess_len, sess_len)
    gaps = rng.exponential(cfg.intra_gap, size=R)
    # per-session cumulative offsets
    cum = np.cumsum(gaps)
    base = np.repeat(cum[np.cumsum(sess_len) - sess_len], sess_len)
    times = sess_start[req_sess] + (cum - base)

    # --- items: random subsets of the session's bundle ---------------------
    # Users consume several items of one latent bundle per session in varied
    # order (binge with skips / shuffled playlist) — over a window this makes
    # the intra-bundle CRM a dense BLOCK, the structure K-cliques mine.
    del pos
    b_start = starts[req_bundle]
    b_size = sizes_a[req_bundle]
    n_it = np.ones(R, dtype=np.int64)
    multi = rng.random(R) < cfg.p_multi
    n_it[multi] = rng.integers(2, cfg.d_max + 1, size=int(multi.sum()))
    n_it = np.minimum(n_it, b_size)
    max_b = int(sizes_a.max())
    u = rng.random((R, max_b))
    u[np.arange(max_b)[None, :] >= b_size[:, None]] = np.inf  # invalid slots
    pick = np.argsort(u, axis=1)[:, : cfg.d_max]              # k-subset w/o repl.
    cols = np.arange(cfg.d_max)[None, :]
    items = (b_start[:, None] + pick).astype(np.int32)
    items[cols >= n_it[:, None]] = -1

    # --- noise: replace kept items with random catalog items ---------------
    keep = items >= 0
    noise = (rng.random(items.shape) < cfg.p_noise) & keep
    items[noise] = rng.integers(0, cfg.n_items, size=int(noise.sum())).astype(np.int32)

    # de-duplicate within a request (sets): sort row, mask repeats
    items_sorted = np.sort(items, axis=1)[:, ::-1]     # -1 pads go last
    dup = np.zeros_like(items_sorted, dtype=bool)
    dup[:, 1:] = (items_sorted[:, 1:] == items_sorted[:, :-1]) & (
        items_sorted[:, 1:] >= 0
    )
    items_sorted[dup] = -1
    items = np.sort(items_sorted, axis=1)[:, ::-1]

    # --- sort by time, truncate -------------------------------------------
    order = np.argsort(times, kind="stable")[: cfg.n_requests]
    # sizes come from a DERIVED rng so the request stream is identical across
    # size_dist settings (same seed -> same requests, only sizes differ)
    sizes = _item_sizes(cfg, np.random.default_rng((cfg.seed, 0x517E)))
    return Trace(
        times=times[order],
        servers=servers[order],
        items=items[order],
        n=cfg.n_items,
        m=cfg.n_servers,
        name=f"{cfg.kind}-synth-s{cfg.seed}",
        sizes=sizes,
    )
