"""Trace container + batching/windowing utilities (paper §III.B, Fig. 3).

A trace is a time-sorted sequence of requests r_i = <D_i, s_j, t_i>:

* ``times``   (R,)        float64, non-decreasing
* ``servers`` (R,)        int32 in [0, m)
* ``items``   (R, d_max)  int32 item ids, -1 padded (D_i as a set)

Batching (paper Table II: batch size 200) groups consecutive requests for the
vectorised engines; windowing (T_CG) feeds the clique-generation module.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class Trace:
    times: np.ndarray
    servers: np.ndarray
    items: np.ndarray
    n: int                      # catalog size |U|
    m: int                      # number of servers |S|
    name: str = "trace"
    sizes: np.ndarray | None = None   # (n,) per-item sizes; None = unit items

    def __post_init__(self):
        # real ValueErrors, not asserts: asserts vanish under `python -O`,
        # silently letting malformed traces through in optimized runs
        R = self.times.shape[0]
        if self.servers.shape != (R,):
            raise ValueError(
                f"servers must have shape ({R},), got {self.servers.shape}")
        if self.items.ndim != 2 or self.items.shape[0] != R:
            raise ValueError(
                f"items must have shape ({R}, d_max), got {self.items.shape}")
        if not (np.diff(self.times) >= 0).all():
            raise ValueError("trace must be time-sorted (non-decreasing times)")
        if self.sizes is not None:
            s = np.asarray(self.sizes, dtype=np.float64)
            if s.shape != (self.n,):
                raise ValueError(
                    f"sizes must have shape ({self.n},), got {s.shape}")
            if not np.all(np.isfinite(s)) or (s <= 0).any():
                raise ValueError("sizes must be finite and positive")
            object.__setattr__(self, "sizes", s)

    @property
    def n_requests(self) -> int:
        return int(self.times.shape[0])

    @property
    def d_max(self) -> int:
        return int(self.items.shape[1])

    def slice(self, start: int, stop: int) -> "Trace":
        return Trace(
            times=self.times[start:stop],
            servers=self.servers[start:stop],
            items=self.items[start:stop],
            n=self.n,
            m=self.m,
            name=self.name,
            sizes=self.sizes,
        )

    def head(self, k: int) -> "Trace":
        return self.slice(0, min(k, self.n_requests))

    def request_sizes(self) -> np.ndarray:
        return (self.items >= 0).sum(axis=1)

    def item_frequencies(self) -> np.ndarray:
        flat = self.items[self.items >= 0]
        return np.bincount(flat, minlength=self.n)

    def save(self, path: str) -> None:
        np.savez_compressed(
            path,
            times=self.times,
            servers=self.servers,
            items=self.items,
            n=self.n,
            m=self.m,
            name=self.name,
            # npz cannot hold None: unit-size traces save an empty array
            sizes=self.sizes if self.sizes is not None else np.zeros(0),
        )

    @classmethod
    def load(cls, path: str) -> "Trace":
        z = np.load(path, allow_pickle=False)
        sizes = None
        if "sizes" in z.files and z["sizes"].size:     # pre-sizes npz compat
            sizes = z["sizes"]
        return cls(
            times=z["times"],
            servers=z["servers"],
            items=z["items"],
            n=int(z["n"]),
            m=int(z["m"]),
            name=str(z["name"]),
            sizes=sizes,
        )


def iter_batches(trace: Trace, batch_size: int) -> Iterator[Trace]:
    """Consecutive request batches (paper batch size: 200)."""
    for s in range(0, trace.n_requests, batch_size):
        yield trace.slice(s, s + batch_size)


@dataclasses.dataclass(frozen=True)
class TraceBatches:
    """Dense padded batch tensors of a trace, ready for the batched engine.

    * ``times``   (nb, B) float64, tail padded with the trace's last time
    * ``servers`` (nb, B) int32,   tail padded with 0
    * ``items``   (nb, B, d_max) int32, tail padded with all -1 rows (the
      engine treats all--1 rows as empty requests producing no events)
    * ``lengths`` (nb,) int32 valid request count per batch (< B only in the
      final batch)
    """

    times: np.ndarray
    servers: np.ndarray
    items: np.ndarray
    lengths: np.ndarray
    n: int
    m: int
    name: str = "trace"

    @property
    def n_batches(self) -> int:
        return int(self.times.shape[0])

    @property
    def batch_size(self) -> int:
        return int(self.times.shape[1])

    @property
    def n_requests(self) -> int:
        return int(self.lengths.sum())


def batch_tensors(trace: Trace, batch_size: int) -> TraceBatches:
    """Pad and reshape a trace into (n_batches, batch_size, ...) tensors."""
    if batch_size <= 0:
        raise ValueError("batch_size must be positive")
    R, B, d = trace.n_requests, batch_size, trace.d_max
    nb = max(1, -(-R // B))
    pad = nb * B - R
    t_pad = float(trace.times[-1]) if R else 0.0
    times = np.concatenate(
        [trace.times, np.full(pad, t_pad, dtype=np.float64)]
    ).reshape(nb, B)
    servers = np.concatenate(
        [trace.servers, np.zeros(pad, dtype=np.int32)]
    ).reshape(nb, B)
    items = np.concatenate(
        [trace.items, np.full((pad, d), -1, dtype=np.int32)]
    ).reshape(nb, B, d)
    lengths = np.full(nb, B, dtype=np.int32)
    lengths[-1] = B - pad
    return TraceBatches(
        times=times, servers=servers, items=items, lengths=lengths,
        n=trace.n, m=trace.m, name=trace.name,
    )


def iter_batch_tensors(
    trace: Trace, batch_size: int
) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray, int]]:
    """Yield (times, servers, items, length) padded batch tensors."""
    tb = batch_tensors(trace, batch_size)
    for b in range(tb.n_batches):
        yield tb.times[b], tb.servers[b], tb.items[b], int(tb.lengths[b])


def iter_windows(trace: Trace, t_cg: float) -> Iterator[tuple[float, Trace]]:
    """(window_end_time, window_trace) pairs on the T_CG grid (Fig. 3)."""
    if trace.n_requests == 0:
        return
    t0 = float(trace.times[0])
    edges = np.arange(t0, float(trace.times[-1]) + t_cg, t_cg)
    idx = np.searchsorted(trace.times, edges[1:], side="left")
    prev = 0
    for e, i in zip(edges[1:], idx):
        if i > prev:
            yield float(e), trace.slice(prev, i)
        prev = i
    if prev < trace.n_requests:
        yield float(trace.times[-1]) + t_cg, trace.slice(prev, trace.n_requests)
