"""Sharded checkpointing: save/restore arbitrary pytrees, async writer,
elastic re-shard on restore.

Layout per step:  <dir>/step_<N>/
    manifest.msgpack   tree structure, leaf paths, shapes, dtypes, meta
    arrays.npz         one entry per leaf (path-keyed)
    _COMMITTED         write-completion marker (atomic rename publish)

Restore accepts a ``shardings`` pytree: leaves are ``jax.device_put`` onto
it — so a checkpoint written on one mesh restores onto ANY mesh/device
count (elastic scaling).  Saves run synchronously or on a background thread
(``CheckpointManager(async_save=True)``); the commit marker guarantees a
crashed writer never publishes a torn checkpoint, and restart picks the
newest committed step.
"""
from __future__ import annotations

import os
import shutil
import threading

import ml_dtypes  # numpy dtype extensions (bf16 etc.) — ships with jax
import msgpack
import numpy as np

import jax

_UINT_OF_SIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _encode(a: np.ndarray) -> tuple[np.ndarray, str]:
    """npz cannot store ml_dtypes (bf16 saves as void); view as uint."""
    name = a.dtype.name
    if a.dtype.kind in "fiub" and not name.startswith(("bfloat", "float8")):
        return a, name
    return a.view(_UINT_OF_SIZE[a.dtype.itemsize]), name


def _decode(raw: np.ndarray, name: str) -> np.ndarray:
    if raw.dtype.name == name:
        return raw
    return raw.view(getattr(ml_dtypes, name, np.dtype(name)))


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
             for p, _ in leaves]
    return paths, [l for _, l in leaves], treedef


def save_checkpoint(directory: str, step: int, tree, meta: dict | None = None) -> str:
    paths, leaves, _ = _flatten(tree)
    step_dir = os.path.join(directory, f"step_{step:09d}")
    tmp = step_dir + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    arrays = {p: np.asarray(l) for p, l in zip(paths, leaves)}
    encoded, names = {}, {}
    for p, a in arrays.items():
        encoded[p], names[p] = _encode(a)
    np.savez(os.path.join(tmp, "arrays.npz"), **encoded)
    manifest = {
        "step": step,
        "paths": paths,
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": [names[p] for p in paths],
        "meta": meta or {},
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp, step_dir)               # atomic publish
    return step_dir


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "_COMMITTED")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint_tree(directory: str, step: int) -> tuple[dict, dict]:
    """Read a checkpoint back as a nested dict of numpy arrays (no ``like``
    tree needed — only for checkpoints whose tree is dicts all the way
    down, e.g. CacheSession snapshots).  Returns (tree, meta)."""
    step_dir = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    z = np.load(os.path.join(step_dir, "arrays.npz"))
    dtype_of = dict(zip(manifest["paths"], manifest["dtypes"]))
    out: dict = {}
    for path in manifest["paths"]:
        node = out
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = _decode(z[path], dtype_of[path])
    return out, manifest["meta"]


def restore_checkpoint(directory: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    ``jax.sharding.Sharding`` for elastic placement."""
    step_dir = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    z = np.load(os.path.join(step_dir, "arrays.npz"))
    paths, like_leaves, treedef = _flatten(like)
    assert set(paths) == set(manifest["paths"]), "checkpoint/tree mismatch"
    out = []
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else
        [None] * len(like_leaves)
    )
    dtype_of = dict(zip(manifest["paths"], manifest["dtypes"]))
    for p, l, s in zip(paths, like_leaves, shard_leaves):
        a = _decode(z[p], dtype_of[p])
        a = a.astype(l.dtype) if hasattr(l, "dtype") else a
        out.append(jax.device_put(a, s) if s is not None else a)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["meta"]


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async background save."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    def save(self, step: int, tree, meta: dict | None = None) -> None:
        # snapshot to host BEFORE backgrounding (donated buffers may die)
        host_tree = jax.tree.map(np.asarray, tree)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_tree, meta), daemon=True
            )
            self._thread.start()
        else:
            self._save_sync(step, host_tree, meta)

    def _save_sync(self, step, tree, meta) -> None:
        save_checkpoint(self.directory, step, tree, meta)
        self._gc()

    def restore_latest(self, like, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None, None
        tree, meta = restore_checkpoint(self.directory, step, like, shardings)
        return step, tree, meta
