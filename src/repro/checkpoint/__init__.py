from .checkpoint import (
    CheckpointManager,
    latest_step,
    load_checkpoint_tree,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "latest_step",
    "load_checkpoint_tree",
    "restore_checkpoint",
    "save_checkpoint",
]
