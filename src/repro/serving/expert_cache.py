"""AKPC-managed MoE expert cache — the paper's strongest framework fit.

Items     = routed experts of ONE layer (id = expert index; the manager is
            instantiated per layer, or over flattened (layer, expert) ids).
Requests  = the set of experts a serving host activates for a token batch
            (top-k routing outcome) — co-activated experts are exactly the
            paper's co-accessed data items.
Servers   = serving hosts; fetching an expert's weights from a peer host or
            from the parameter store costs transfer; keeping it resident
            costs (HBM) rent.  AKPC packs co-activated experts into cliques
            (<= omega) so a routing miss prefetches the whole group at the
            discounted (1 + (p-1)*alpha)*lam cost, and whole-clique TTL
            extension keeps hot expert groups resident.

Routing outcomes stream through a :class:`repro.core.session.CacheSession`
(the AKPC policy from the registry): ``observe`` feeds them online, T_CG
windowing/regeneration happens inside the session, and ``snapshot``/
``restore`` checkpoint the live cache state together with the server.
``backend="live"`` swaps the session for a device-resident
:class:`repro.serving.live.LiveServingEngine` — observations buffer into
asynchronously dispatched device chunks and the cache state stays on the
accelerator between serving steps (checkpoints stay interchangeable with
the plain session backend).
``packed_tables`` materialises the cliques as a contiguous packed weight
table so the actual gather uses kernels/packed_lookup (one DMA per clique
instead of omega scattered row reads).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.baselines import run_no_packing
from ..core.cost import CacheEnvironment, CostParams
from ..core.policy import get_policy
from ..core.session import CacheSession
from ..traces.loader import Trace


@dataclasses.dataclass
class ExpertCacheStats:
    akpc_total: float
    nopack_total: float
    n_observations: int
    cliques: list[tuple[int, ...]]

    @property
    def saving_pct(self) -> float:
        if self.nopack_total <= 0:
            return 0.0
        return 100.0 * (1.0 - self.akpc_total / self.nopack_total)


class ExpertCacheManager:
    """``expert_bytes`` (n_experts,) — per-expert weight-table bytes (e.g.
    ``w.nbytes`` per expert row, which differ across experts under
    quantisation / LoRA deltas).  They become the cache environment's item
    sizes so the size-aware cost models (``cost_model="heterogeneous"`` /
    ``"tiered"``) price a miss by the bytes actually DMA'd and rent by the
    HBM actually held; the default ``table1`` keeps the paper's unit
    accounting."""

    def __init__(self, n_experts: int, n_hosts: int,
                 params: CostParams | None = None, t_cg: float = 32.0,
                 d_max: int = 8,
                 expert_bytes: np.ndarray | None = None,
                 cost_model: str = "table1",
                 backend: str = "session"):
        if backend not in ("session", "live"):
            raise ValueError(f"unknown expert-cache backend {backend!r}")
        self.n_experts = n_experts
        self.n_hosts = n_hosts
        self.params = params or CostParams(alpha=0.6, rho=4.0, omega=5)
        self.t_cg = t_cg
        self.d_max = d_max
        self.cost_model = cost_model
        self.backend = backend
        sizes = None
        if expert_bytes is not None:
            b = np.asarray(expert_bytes, dtype=np.float64)
            if b.shape != (n_experts,):
                raise ValueError(
                    f"expert_bytes must have shape ({n_experts},), "
                    f"got {b.shape}")
            sizes = b / b.mean()          # mean-1 volumes
        self.env = CacheEnvironment(
            n=n_experts, m=n_hosts, params=self.params, item_sizes=sizes)
        policy = get_policy("akpc", params=self.params, t_cg=t_cg,
                            top_frac=1.0, cost_model=cost_model)
        if backend == "live":
            # device-resident streaming session (serving/live.py): observe
            # calls buffer into async device chunks; stats()/snapshot()
            # drain so readers always see settled numbers
            from .live import LiveServingEngine

            self.session = LiveServingEngine(
                policy, n_experts, n_hosts, env=self.env)
        else:
            self.session = CacheSession(
                policy, n_experts, n_hosts, env=self.env)
        self._hist: list[tuple[np.ndarray, int, float]] = []
        self._t = 0.0

    def observe(self, topk_idx: np.ndarray, host: int = 0) -> None:
        """topk_idx (tokens, k): one serving step's routing outcome."""
        self._t += 1.0
        experts = np.unique(topk_idx.reshape(-1))
        # split into <= d_max item requests (paper's request-size bound)
        rows = [
            experts[lo : lo + self.d_max].astype(np.int64)
            for lo in range(0, len(experts), self.d_max)
        ]
        items = np.full((len(rows), self.d_max), -1, np.int32)
        for r, g in enumerate(rows):
            items[r, : len(g)] = g
            self._hist.append((g, host, self._t))
        self.session.feed(
            items,
            np.full(len(rows), host, np.int64),
            np.full(len(rows), self._t, np.float64),
        )

    def _settle(self) -> None:
        """Live backend: flush + block so costs/partition are settled."""
        drain = getattr(self.session, "drain", None)
        if drain is not None:
            drain()

    # -- checkpointing -------------------------------------------------------
    def snapshot(self) -> dict:
        """Session state + the manager's clock/history (pure-numpy pytree,
        ``repro.checkpoint``-compatible).  Drained first, so the snapshot
        restores into either backend."""
        self._settle()
        d = max((len(g) for g, _, _ in self._hist), default=1)
        items = np.full((len(self._hist), d), -1, np.int32)
        hosts = np.empty(len(self._hist), np.int32)
        times = np.empty(len(self._hist), np.float64)
        for i, (g, h, t) in enumerate(self._hist):
            items[i, : len(g)] = g
            hosts[i] = h
            times[i] = t
        return {
            "session": self.session.snapshot(),
            "manager": {
                "t": np.float64(self._t),
                "hist_items": items,
                "hist_hosts": hosts,
                "hist_times": times,
            },
        }

    def restore(self, snap: dict) -> None:
        self.session.restore(snap["session"])
        mgr = snap["manager"]
        self._t = float(mgr["t"])
        items = np.asarray(mgr["hist_items"])
        hosts = np.asarray(mgr["hist_hosts"])
        times = np.asarray(mgr["hist_times"])
        self._hist = [
            (row[row >= 0].astype(np.int64), int(h), float(t))
            for row, h, t in zip(items, hosts, times)
        ]

    # -- introspection -------------------------------------------------------
    def cliques(self) -> list[tuple[int, ...]]:
        self._settle()
        return self.session.partition.canonical()

    def packed_tables(self, expert_weights: np.ndarray):
        """Pack clique members contiguously: (n_cliques, omega, ...) table +
        per-expert (clique_id, slot) map for kernels.packed_lookup."""
        omega = self.params.omega
        cliques = [c for c in self.cliques()]
        # singletons (and leftovers) get their own rows
        covered = {d for c in cliques for d in c}
        for e in range(self.n_experts):
            if e not in covered:
                cliques.append((e,))
        table = np.zeros((len(cliques), omega) + expert_weights.shape[1:],
                         expert_weights.dtype)
        where = np.zeros((self.n_experts, 2), np.int32)
        for ci, c in enumerate(cliques):
            for slot, e in enumerate(c):
                table[ci, slot] = expert_weights[e]
                where[e] = (ci, slot)
        return table, where

    def stats(self) -> ExpertCacheStats:
        self._settle()
        # replay the same observation history through No-Packing
        if self._hist:
            d_max = max(len(g) for g, _, _ in self._hist)
            items = np.full((len(self._hist), d_max), -1, np.int32)
            servers = np.empty(len(self._hist), np.int32)
            times = np.empty(len(self._hist), np.float64)
            for i, (g, h, t) in enumerate(self._hist):
                items[i, : len(g)] = g
                servers[i] = h
                times[i] = t
            tr = Trace(times=times, servers=servers, items=items,
                       n=self.n_experts, m=self.n_hosts, name="expert-trace")
            # same environment + cost model as the AKPC session, so the
            # saving is apples-to-apples
            nopack = run_no_packing(tr, self.params, env=self.env,
                                    cost_model=self.cost_model).total
        else:
            nopack = 0.0
        return ExpertCacheStats(
            akpc_total=self.session.costs.total,
            nopack_total=nopack,
            n_observations=len(self._hist),
            cliques=self.cliques(),
        )
