"""Batched decode server: continuous batching over Model.decode_step.

Minimal but real: a request queue, fixed-size decode batch with slot reuse,
per-slot positions, EOS/length stopping, and (for MoE models) routing-
outcome taps feeding the AKPC ExpertCacheManager.  Runs the reduced configs
on CPU (examples/serve_moe_expert_cache.py); the same driver shape lowers
onto the production mesh via launch/specs.py decode cells.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    def __init__(self, model: Model, params, *, batch_size: int = 4,
                 cache_len: int = 256, eos_id: int = -1,
                 routing_tap: Callable | None = None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.eos = eos_id
        self.routing_tap = routing_tap
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * batch_size
        self.slot_pos = np.zeros(batch_size, np.int64)
        self.cache = model.init_cache(batch_size, cache_len, jnp.bfloat16)
        self._decode = jax.jit(model.decode_step)
        self.steps = 0
        self._all: list[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self._all.append(req)

    def _fill_slots(self) -> None:
        admitted = []
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.popleft()
                self.slot_pos[i] = 0
                admitted.append(i)
        if admitted:
            # attention caches are masked by position, but recurrent leaves
            # (SSM conv/state, xLSTM) are not: the previous tenant's state
            # would leak into the new request.  One batched zeroing pass for
            # all slots admitted this step (every cache leaf has the slot
            # axis at position 1).
            idx = np.asarray(admitted)
            self.cache = jax.tree.map(
                lambda c: c.at[:, idx].set(0), self.cache)

    def step(self) -> int:
        """One decode step for every active slot; returns #active."""
        self._fill_slots()
        active = [i for i in range(self.B) if self.slots[i] is not None]
        if not active:
            return 0
        tokens = np.zeros((self.B, 1), np.int32)
        for i in active:
            r = self.slots[i]
            p = int(self.slot_pos[i])
            tokens[i, 0] = r.prompt[p] if p < len(r.prompt) else (
                r.out[-1] if r.out else 0)
        # per-slot positions: slots fill at different times (staggered
        # arrivals), so each row decodes at ITS position — one shared scalar
        # would mask/rotate every other slot at the wrong offset
        pos = jnp.asarray(self.slot_pos % self.cache_len, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.array(tokens), pos)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for i in active:
            r = self.slots[i]
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(r.prompt):
                r.out.append(int(nxt[i]))
                if int(nxt[i]) == self.eos or len(r.out) >= r.max_new:
                    r.done = True
                    self.slots[i] = None
        self.steps += 1
        if self.routing_tap is not None:
            self.routing_tap(self.params, tokens)
        return len(active)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        while self.steps < max_steps and (self.queue or any(
                s is not None for s in self.slots)):
            self.step()
        return [r for r in self._all if r.done]
