from .expert_cache import ExpertCacheManager
from .server import BatchedServer, Request

__all__ = ["ExpertCacheManager", "BatchedServer", "Request"]
