from .expert_cache import ExpertCacheManager
from .live import LiveServingEngine, ServeFuture
from .server import BatchedServer, Request

__all__ = [
    "ExpertCacheManager",
    "LiveServingEngine",
    "ServeFuture",
    "BatchedServer",
    "Request",
]
