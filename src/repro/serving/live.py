"""Persistent on-device serving engine (DESIGN.md §12).

``CacheSession.feed_trace(backend="jax")`` is replay machinery: every
chunk rebuilds a schedule, uploads the full cache state, scans, and
downloads it again.  :class:`LiveServingEngine` is the serving-grade
counterpart — a session whose cache state NEVER leaves the device
between chunks:

* **One compiled step, donated buffers.**  The first chunk fixes the
  padded event-tensor shape (with headroom); every later chunk pads
  into it, so XLA compiles the scan exactly once.  The carry
  (expiry matrix, anchors, cost accumulator) is donated to the jit'd
  step, letting XLA update it in place instead of allocating a fresh
  state per chunk.
* **Async chunk ring.**  Dispatch is non-blocking: the host packs
  chunk k+1's event tensors (``build_schedule`` — argsorts, window
  bookkeeping, clique generation) while the device executes chunk k.
  A small ring of in-flight chunks bounds the lag; submitting past it
  blocks on the oldest chunk (backpressure).
* **Absolute cost accumulator.**  The device accumulator is seeded
  from the session's cost breakdown, so mid-stream ``costs`` reads are
  a 6-float download — no state round-trip, and bitwise-exact on
  resume because f64 totals travel through snapshots unrounded.

Requests enter through :meth:`submit` (buffered into fixed-size
chunks; returns a :class:`ServeFuture`), and :meth:`drain` flushes the
ragged remainder, blocks the ring, and syncs the numpy engine — after
which the wrapped :class:`~repro.core.session.CacheSession` is
indistinguishable from one that replayed the same requests itself:
:meth:`snapshot`/:meth:`restore` compose bitwise with the plain
session checkpoint path in both directions (a live snapshot taken
mid-stream carries the un-dispatched request buffer along).

The engine is duck-compatible with ``CacheSession`` (``feed``,
``costs``, ``partition``, ``now``, ``snapshot``/``restore``,
``result``), so :mod:`repro.serving.expert_cache` and
:mod:`repro.data.pipeline` route through it with a ``backend="live"``
switch.
"""
from __future__ import annotations

import time as _time
import functools
import warnings
from collections import deque

import numpy as np

from ..core.cost import CostBreakdown
from ..core.engine import CacheState
from ..core.policy import RunResult
from ..core.session import CacheSession
from ..core import engine_jax as ej

try:  # pragma: no cover - exercised indirectly
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    HAS_JAX = True
except Exception:  # pragma: no cover
    HAS_JAX = False

# buffer donation is an optimization; backends that cannot donate (some
# CPU configurations) fall back to copying and warn — harmless here
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


class _Chunk:
    """Duck-typed request container for the schedule builders."""

    __slots__ = ("items", "servers", "times", "n_requests", "n", "m",
                 "d_max")

    def __init__(self, items, servers, times, n=0, m=0):
        self.items = items
        self.servers = servers
        self.times = times
        self.n_requests = int(times.shape[0])
        self.n = n
        self.m = m
        self.d_max = int(items.shape[1]) if items.ndim == 2 else 1


@functools.lru_cache(maxsize=None)
def _compiled_live_step(statics, charge, const_dt, use_pallas):
    """jit'd scan step with a DONATED carry.

    Returns ``((E, anchor, acc), probe)``: the carry buffers are donated
    (arg 1), so they cannot be waited on from the host — the ring blocks
    on the small non-donated ``probe`` scalar instead.
    """
    base = functools.partial(
        ej._replay_impl, kind=statics, charge=charge, const_dt=const_dt,
        use_pallas=use_pallas)

    def step(spec, carry, xs):
        E, anchor, acc = base(spec, carry, xs)
        return (E, anchor, acc), acc[0] + acc[1]

    return jax.jit(step, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _compiled_cgm_live_step(statics, charge, uses_sizes, enable_split,
                            enable_acm, seed_new, use_kernels, gcap,
                            full_merge):
    """jit'd fused CGM+replay scan step with a DONATED carry dict.

    The per-step clique slot maps (``ofs``) double as the ring probe:
    they are a regular (non-donated) output, so the host can block on
    them, and they feed ``policy.size_history`` at sync time.
    ``gcap`` / ``full_merge`` are the compile-time loop capacities from
    ``cgm_jax.cgm_loop_statics``, fixed at carry creation.
    """
    from ..core import cgm_jax

    base = functools.partial(
        cgm_jax._cgm_replay_impl, kind=statics, charge=charge,
        uses_sizes=uses_sizes, enable_split=enable_split,
        enable_acm=enable_acm, seed_new=seed_new, use_kernels=use_kernels,
        gcap=gcap, full_merge=full_merge)

    def step(spec, cspec, carry, xs, sizes):
        return base(spec, cspec, carry, xs, sizes)

    return jax.jit(step, donate_argnums=(2,))


class ServeFuture:
    """Handle for one :meth:`LiveServingEngine.submit` call.

    ``result()`` guarantees every request of that call has been priced
    (flushing the pending buffer if needed) and returns the synced cost
    breakdown.  Futures are invalidated by :meth:`restore`.
    """

    __slots__ = ("_eng", "_upto")

    def __init__(self, eng: "LiveServingEngine", upto: int):
        self._eng = eng
        self._upto = upto

    def done(self) -> bool:
        """True once every request of this submit has finished on device."""
        e = self._eng
        return e._dispatched_total >= self._upto and not e._probes

    def result(self) -> CostBreakdown:
        e = self._eng
        if e._dispatched_total < self._upto:
            e._flush()
        e._block()
        return e._sync_costs()


class LiveServingEngine:
    """Device-resident streaming session (see module docstring).

    Parameters
    ----------
    policy, n, m, env, batch_size : as for ``CacheSession``.
    chunk_size : requests per compiled device step.  Submissions are
        buffered until a full chunk accumulates; tail chunks (``drain``)
        pad into the same shape with masked no-op events.
    ring : maximum chunks in flight before ``submit`` blocks on the
        oldest one (host/device overlap depth).
    headroom : multiplier applied to the first chunk's event-tensor
        dims when fixing the compiled shape.  A later chunk that still
        outgrows it ratchets the dims (one recompile, counted in
        ``compiles``); default 2.0 keeps steady-state streams on a
        single compile.
    cgm : ``"auto"`` (default) fuses clique generation into the device
        scan when the policy/catalog pass ``wants_device_cgm`` — the
        host then ships only raw request tensors and pays zero
        clique-generation calls.  The compact hot-space boundary
        (DESIGN.md §15) made this the winning path on EVERY backend:
        CPU lanes run the same fused scan through jnp twins of the
        Mosaic kernels, so auto no longer falls back off-TPU.
        ``"force"`` keeps its meaning (assert fusion, error if
        ineligible via the carry checks); ``"off"`` disables fusion.
    """

    def __init__(self, policy, n, m, *, env=None, batch_size=None,
                 chunk_size=32768, ring=4, headroom=2.0, cgm="auto",
                 layout=None):
        if not HAS_JAX:  # pragma: no cover
            raise ImportError("LiveServingEngine requires jax")
        self.session = CacheSession(
            policy, n, m, env=env, batch_size=batch_size, layout=layout)
        #: device state geometry (dense / bucketed / row_sharded)
        self.layout = self.session.layout
        # validates the cost model has device hooks, builds spec/statics
        self._jeng = ej.JaxReplayEngine(
            engine=self.session.engine, layout=self.layout)
        self.policy = self.session.policy
        self.n, self.m = n, m
        self.chunk_size = max(1, int(chunk_size))
        self.ring = max(1, int(ring))
        self.headroom = float(headroom)
        from ..kernels.autowire import default_segment_hooks

        self._use_pallas = default_segment_hooks()[0] is not None
        self._part = self.session.partition
        self._carry = None          # (E, anchor, acc) device arrays
        self._spec_j = None         # device copy of the scenario spec
        self._probes: deque = deque()
        self._dims: dict | None = None
        #: fresh scan traces (= XLA compiles) triggered by this engine
        self.compiles = 0
        self._pend: list[tuple] = []     # (items, servers, times) buffers
        self._pend_n = 0
        self._submitted_total = 0
        self._dispatched_total = 0
        self._last_sub = -np.inf
        self._base_req = (0, 0)     # (n_requests, n_item_requests) at seed
        self._host_nreq = 0
        self._host_nitem = 0
        self._acc_dirty = False
        # device-CGM mode (PR 6 fused scan, persistent carry dict)
        if cgm not in ("auto", "force", "off"):
            raise ValueError(f"unknown cgm mode {cgm!r}")
        self._cgm = False
        if cgm != "off":
            from ..core.cgm_jax import wants_device_cgm

            eligible = wants_device_cgm(
                self.policy,
                _Chunk(np.zeros((0, 1), np.int64), np.zeros(0, np.int64),
                       np.zeros(0, np.float64), n, m),
                self.session.engine.model)
            # the fused CGM carry is dense-n on its own whatever the
            # session layout; only row-sharded state falls back — and
            # the compact workspace means NO backend check: CPU fuses
            # through the jnp kernel twins (DESIGN.md §15)
            self._cgm = (eligible
                         and self.layout.supports_device_cgm(n, m))
        self._cgm_carry = None      # device carry dict (E..of..crm..pbin)
        self._cgm_dims = None       # ratcheted (nb, B, d, h, W) chunk shape
        self._cgm_statics = None    # (gcap, full_merge) loop capacities
        self._cspec_j = None
        self._sz_j = None
        self._ofs: list[tuple] = []  # (boundary_steps, ofs_dev) per chunk
        self._cgm_bound = False      # any boundary since carry init?

    # -- views -------------------------------------------------------------
    @property
    def partition(self):
        """Partition after the last DISPATCHED window boundary."""
        return self._part

    @property
    def now(self) -> float:
        """Time of the most recently submitted request (-inf before any)."""
        return max(self._last_sub, self.session._last_t)

    @property
    def in_flight(self) -> int:
        """Chunks currently executing on device."""
        return len(self._probes)

    @property
    def pending(self) -> int:
        """Buffered requests not yet dispatched (less than one chunk)."""
        return self._pend_n

    @property
    def costs(self) -> CostBreakdown:
        """Mid-stream costs of every COMPLETED chunk (blocks the ring;
        the < chunk_size buffered requests are priced at :meth:`drain`)."""
        return self._sync_costs()

    # -- streaming ---------------------------------------------------------
    def submit(self, items, servers, times) -> ServeFuture:
        """Enqueue one time-ordered request chunk; returns a future.

        Arguments as for ``CacheSession.feed``: ``items`` (R, d) int with
        -1 padding (1-D = single-item requests), ``servers`` (R,),
        ``times`` (R,) non-decreasing and >= every earlier submission.
        Full ``chunk_size`` chunks dispatch asynchronously; the call only
        blocks when more than ``ring`` chunks are already in flight.
        """
        t0 = _time.perf_counter()
        items = np.atleast_2d(np.asarray(items))
        servers = np.asarray(servers, dtype=np.int64).reshape(-1)
        times = np.asarray(times, dtype=np.float64).reshape(-1)
        R = times.shape[0]
        if R == 0:
            return ServeFuture(self, self._submitted_total)
        if items.shape[0] != R or servers.shape[0] != R:
            raise ValueError(
                f"chunk shape mismatch: items {items.shape}, "
                f"servers {servers.shape}, times {times.shape}")
        if (np.diff(times) < 0).any() or times[0] < self._last_sub:
            raise ValueError(
                "requests must be submitted in non-decreasing time order")
        self._last_sub = float(times[-1])
        self._pend.append((items, servers, times))
        self._pend_n += R
        self._submitted_total += R
        while self._pend_n >= self.chunk_size:
            self._dispatch(*self._pop_chunk(self.chunk_size))
        self.session._wall += _time.perf_counter() - t0
        return ServeFuture(self, self._submitted_total)

    def feed(self, items, servers, times) -> CostBreakdown:
        """``CacheSession.feed``-compatible alias of :meth:`submit`.

        Returns the live breakdown object WITHOUT forcing a device sync —
        read :attr:`costs` (or call :meth:`drain`) for settled numbers.
        """
        self.submit(items, servers, times)
        return self.session.engine.costs

    def drain(self) -> CostBreakdown:
        """Flush the pending remainder (padded ragged chunk), block the
        ring, and sync state + costs into the wrapped numpy session."""
        t0 = _time.perf_counter()
        self._flush()
        self._block()
        self._sync_state()
        self.session._wall += _time.perf_counter() - t0
        return self.session.engine.costs

    # -- snapshot / restore ------------------------------------------------
    def snapshot(self) -> dict:
        """Checkpoint pytree, bitwise-compatible with the ``CacheSession``
        path.  Completed chunks are synced into the session state; the
        un-dispatched pending buffer travels under ``snap["live"]`` (so
        the processed prefix stays chunk-aligned on resume — required
        for bitwise-identical continuation).  ``drain()`` first if the
        snapshot must be loadable by a plain ``CacheSession``."""
        self._block()
        self._sync_state()
        snap = self.session.snapshot()
        items, servers, times = self._pend_concat()
        snap["live"] = {
            "pend_items": items.astype(np.int64),
            "pend_servers": servers.astype(np.int64),
            "pend_times": times.astype(np.float64),
        }
        return snap

    def restore(self, snap: dict) -> "LiveServingEngine":
        """Load a snapshot from either a live engine or a plain
        ``CacheSession``; resumes bit-identically.  Outstanding futures
        from before the restore are invalidated."""
        self._probes.clear()
        self._carry = None          # re-seed from the restored state
        self._cgm_carry = None
        self._ofs = []
        self._cgm_bound = False
        self._spec_j = None
        self._acc_dirty = False
        self.session.restore(snap)
        self._part = self.session.partition
        self._pend = []
        self._pend_n = 0
        self._submitted_total = 0
        self._dispatched_total = 0
        self._host_nreq = 0
        self._host_nitem = 0
        self._last_sub = self.session._last_t
        live = snap.get("live")
        if live is not None and live["pend_times"].shape[0]:
            items = np.asarray(live["pend_items"])
            servers = np.asarray(live["pend_servers"], np.int64)
            times = np.asarray(live["pend_times"], np.float64)
            self._pend = [(items, servers, times)]
            self._pend_n = times.shape[0]
            self._submitted_total = self._pend_n
            self._last_sub = float(times[-1])
        return self

    def result(self) -> RunResult:
        """Drain and return the run summary (``CacheSession.result``)."""
        self.drain()
        return self.session.result()

    # -- internals ---------------------------------------------------------
    def _pop_chunk(self, k: int):
        """Take exactly ``k`` requests off the pending buffer."""
        out_i, out_s, out_t = [], [], []
        need = k
        while need:
            it, sv, tm = self._pend[0]
            take = min(need, tm.shape[0])
            out_i.append(it[:take])
            out_s.append(sv[:take])
            out_t.append(tm[:take])
            if take == tm.shape[0]:
                self._pend.pop(0)
            else:
                self._pend[0] = (it[take:], sv[take:], tm[take:])
            need -= take
        self._pend_n -= k
        return (_cat_items(out_i), np.concatenate(out_s),
                np.concatenate(out_t))

    def _pend_concat(self):
        """Pending buffer as one array triple (without consuming it)."""
        if not self._pend:
            return (np.zeros((0, 1), np.int64), np.zeros(0, np.int64),
                    np.zeros(0, np.float64))
        return (_cat_items([p[0] for p in self._pend]),
                np.concatenate([p[1] for p in self._pend]),
                np.concatenate([p[2] for p in self._pend]))

    def _flush(self) -> None:
        if self._pend_n:
            n = self._pend_n
            self._dispatch(*self._pop_chunk(n))

    def _ensure_carry(self) -> None:
        if self._carry is not None:
            return
        eng = self.session.engine
        E0, a0 = ej.state_to_device(eng.state, self.n, self.layout)
        c = eng.costs
        # accumulator seeded with ABSOLUTE totals: syncs assign rather
        # than add, and resumes are exact (f64 roundtrips bitwise)
        acc0 = np.array([
            c.transfer, c.caching, c.keepalive_rent,
            float(c.n_misses), float(c.n_hits), float(c.items_transferred),
        ], np.float64)
        self._base_req = (c.n_requests, c.n_item_requests)
        self._host_nreq = 0
        self._host_nitem = 0
        with enable_x64():
            E0, a0 = self.layout.place_state(E0, a0)
            self._carry = (
                jnp.asarray(E0, jnp.float64),
                jnp.asarray(a0, jnp.int32),
                jnp.asarray(acc0, jnp.float64),
            )
            self._spec_j = {
                k: jnp.asarray(v) for k, v in self._jeng._spec.items()}

    def _fix_dims(self, dims: dict) -> None:
        """Fix (or ratchet) the compiled chunk shape with headroom.

        Bucket-aware: the install axes (changed rows/items per boundary)
        scale with the catalog, so at bucketed 10^4-row layouts the
        ratchet steps grow with ``layout.state_rows`` — otherwise a big
        catalog would recompile dozens of times while the install width
        creeps up in 32-slot steps.  Dense small catalogs (rows <= 1024)
        keep the original step table bit-for-bit.
        """
        h = self.headroom
        rows = self.layout.state_rows(self.n)
        scale = max(1, rows // 1024)
        grown = {
            "nb": ej._bucket(int(dims["nb"] * 2), 4, 4),
            "ne": ej._bucket(int(dims["ne"] * h), 1024, 1024),
            "nu": ej._bucket(int(dims["nu"] * h), 512, 512),
            "na": ej._bucket(int(dims["na"] * h), 256, 256),
            "ncr": ej._bucket(int(dims["ncr"] * 2), 32 * scale, 32),
            "nci": ej._bucket(int(dims["nci"] * 2), 64 * scale, 64),
            "nmv": ej._bucket(int(dims["nmv"] * 2), 32 * scale, 32),
        }
        if self._dims is None:
            self._dims = grown
        else:
            self._dims = {k: max(self._dims[k], grown[k]) for k in grown}

    def _ensure_cgm_carry(self, sched) -> None:
        """Seed the CGM carry (once) with the compact dims of ``sched``."""
        if self._cgm_carry is not None:
            return
        from ..core.cgm_jax import (
            cgm_loop_statics, cgm_spec, init_cgm_carry)
        from ..kernels.autowire import default_cgm_hooks

        eng = self.session.engine
        pol = self.policy
        uses_sizes = bool(eng.model.uses_sizes)
        item_sizes = eng.env.sizes() if uses_sizes else None
        carry0 = init_cgm_carry(
            eng.state, getattr(pol, "_prev_crm", None),
            self.session._window_arrays() if self.session._win else None,
            n=self.n, m=self.m, uses_sizes=uses_sizes,
            item_sizes=item_sizes, layout=self.layout, schedule=sched)
        c = eng.costs
        # absolute-total accumulator seed, as in _ensure_carry
        carry0["acc"] = np.array([
            c.transfer, c.caching, c.keepalive_rent,
            float(c.n_misses), float(c.n_hits), float(c.items_transferred),
        ], np.float64)
        self._base_req = (c.n_requests, c.n_item_requests)
        self._host_nreq = 0
        self._host_nitem = 0
        self._cgm_bound = False
        cfg = pol.config
        self._cgm_flags = (
            uses_sizes, bool(cfg.enable_split),
            bool(cfg.enable_approx_merge), bool(eng.seed_new_cliques),
            default_cgm_hooks()[0] is not None)
        cspec = cgm_spec(cfg, cfg.params, self.n)
        self._cgm_statics = cgm_loop_statics(
            cspec, carry0, enable_split=cfg.enable_split,
            enable_acm=cfg.enable_approx_merge)
        with enable_x64():
            self._cgm_carry = {
                k: jnp.asarray(v) for k, v in carry0.items()}
            self._spec_j = {
                k: jnp.asarray(v) for k, v in self._jeng._spec.items()}
            self._cspec_j = {k: jnp.asarray(v) for k, v in cspec.items()}
            self._sz_j = (
                jnp.asarray(item_sizes, jnp.float64)
                if item_sizes is not None
                else jnp.ones(self.n, jnp.float64))

    def _grow_cgm_carry(self, h: int, wcap: int, dbuf: int) -> None:
        """Re-embed the carry into a larger compact workspace (ratchet).

        Blocks the ring (the donated carry must settle), zero-pads the
        previous-CRM workspace / -1-pads the window buffer, and ships
        the result back.  Costs one recompile, exactly like the generic
        path's dims ratchet."""
        self._block()
        c = {k: np.asarray(v) for k, v in self._cgm_carry.items()}
        oh = int(c["p_idx"].shape[0])
        ow, od = (int(x) for x in c["wbuf"].shape)
        h, wcap, dbuf = max(h, oh), max(wcap, ow), max(dbuf, od)
        if h > oh:
            p_idx = np.full(h, self.n, np.int32)
            p_idx[:oh] = c["p_idx"]
            c["p_idx"] = p_idx
            for k, dt in (("praw", np.float32), ("pnorm", np.float32),
                          ("pbin", bool)):
                a = np.zeros((h, h), dt)
                a[:oh, :oh] = c[k]
                c[k] = a
        if wcap > ow or dbuf > od:
            wbuf = np.full((wcap, dbuf), -1, np.int32)
            wbuf[:ow, :od] = c["wbuf"]
            c["wbuf"] = wbuf
        with enable_x64():
            self._cgm_carry = {k: jnp.asarray(v) for k, v in c.items()}

    def _dispatch_cgm(self, items, servers, times) -> None:
        """Raw-tensor chunk dispatch: clique generation runs in-scan."""
        from ..core import cgm_jax

        sess = self.session
        eng = sess.engine
        R = times.shape[0]
        if sess._next_cg is None:
            sess._next_cg = float(times[0]) + sess._t_cg
        # the open window's rows already live in the device buffer; the
        # chunk schedule's head-window capacity must account for them
        pre_rows = pre_slots = 0
        for w_it, _w_sv in sess._win:
            r = int(w_it.shape[0])
            wd = int(w_it.shape[1]) if w_it.ndim == 2 else 1
            pre_rows += r
            pre_slots += r * wd
        sched = cgm_jax.build_cgm_schedule(
            _Chunk(items, servers, times, self.n, self.m), sess._t_cg,
            uses_sizes=bool(eng.model.uses_sizes), next_cg0=sess._next_cg,
            hot_dims=cgm_jax.policy_hot_dims(self.policy),
            prefix_rows=pre_rows, prefix_slots=pre_slots)
        dims = ej.schedule_dims(sched)
        if self._cgm_dims is None or any(
                dims[k] > self._cgm_dims[k] for k in dims):
            grown = {"nb": ej._bucket(int(dims["nb"] * 2), 4, 4),
                     "B": ej._bucket(int(dims["B"] * 2), 32, 32),
                     "d": dims["d"],
                     "h": min(self.n,
                              ej._bucket(int(dims["h"] * 2), 32, 32)),
                     "W": ej._bucket(int(dims["W"] * 2), 64, 64)}
            self._cgm_dims = (grown if self._cgm_dims is None else {
                k: max(self._cgm_dims[k], grown[k]) for k in grown})
        sched = ej.pad_schedule(sched, self._cgm_dims)
        # growing B re-derives wcap; fold it back into the ratchet
        self._cgm_dims["W"] = max(self._cgm_dims["W"], sched.wcap)
        # carry creation reads the PRE-chunk open window (sess._win)
        self._ensure_cgm_carry(sched)
        cw, cd = (int(x) for x in self._cgm_carry["wbuf"].shape)
        ch = int(self._cgm_carry["p_idx"].shape[0])
        if ch < sched.h or cw < sched.wcap or cd < sched.d:
            self._grow_cgm_carry(sched.h, sched.wcap, sched.d)
        elif ch > sched.h:
            # a restored previous-window CRM bumped the carry's h past
            # the schedule's; ratchet the dims so they stay aligned
            self._cgm_dims["h"] = max(self._cgm_dims["h"], ch)
        if sched.next_cg is not None:
            sess._next_cg = sched.next_cg
        if sched.boundary_hit:
            sess._win = []
            self._cgm_bound = True
        if sched.win_start < R:
            sess._win.append((
                np.array(items[sched.win_start:], dtype=np.int32,
                         copy=True),
                np.array(servers[sched.win_start:], dtype=np.int32,
                         copy=True),
            ))
        sess._last_t = float(times[-1])
        self._host_nreq += sched.n_requests
        self._host_nitem += sched.n_item_requests
        self._dispatched_total += R
        fn = _compiled_cgm_live_step(
            self._jeng._statics, eng.caching_charge, *self._cgm_flags,
            *self._cgm_statics)
        before = cgm_jax.SCAN_TRACES
        with enable_x64():
            xs_j = {k: jnp.asarray(v) for k, v in sched.xs.items()}
            self._cgm_carry, ofs = fn(
                self._spec_j, self._cspec_j, self._cgm_carry, xs_j,
                self._sz_j)
        self.compiles += cgm_jax.SCAN_TRACES - before
        self._acc_dirty = True
        self._ofs.append((sched.boundary_steps, ofs))
        self._probes.append(ofs)
        while len(self._probes) > self.ring:    # backpressure
            self._probes.popleft().block_until_ready()

    def _dispatch(self, items, servers, times) -> None:
        """Pack one chunk's event tensors and launch it on the ring."""
        if self._cgm:
            self._dispatch_cgm(items, servers, times)
            return
        self._ensure_carry()
        sess = self.session
        eng = sess.engine
        R = times.shape[0]
        windowed = sess._t_cg is not None
        if windowed and sess._next_cg is None:
            sess._next_cg = float(times[0]) + sess._t_cg
        sched = ej.build_schedule(
            self._part, _Chunk(items, servers, times),
            sess.policy.on_window if windowed else None,
            sess._t_cg,
            model=eng.model, env=eng.env,
            seed_new_cliques=eng.seed_new_cliques,
            next_cg0=sess._next_cg if windowed else None,
            win_prefix=(sess._window_arrays()
                        if windowed and sess._win else None),
            lookup=eng._lookup,
            layout=self.layout,
        )
        # T_CG window bookkeeping — identical to CacheSession._feed_trace_jax
        if windowed:
            if sched.next_cg is not None:
                sess._next_cg = sched.next_cg
            if sched.boundary_hit:
                sess._win = []
            if sched.win_start < R:
                sess._win.append((
                    np.array(items[sched.win_start:], dtype=np.int32,
                             copy=True),
                    np.array(servers[sched.win_start:], dtype=np.int32,
                             copy=True),
                ))
        sess._last_t = float(times[-1])
        self._part = sched.final_partition
        self._host_nreq += sched.n_requests
        self._host_nitem += sched.n_item_requests
        self._dispatched_total += R
        dims = ej.schedule_dims(sched)
        if self._dims is None or any(
                dims[k] > self._dims[k] for k in dims):
            self._fix_dims(dims)
        sched = ej.pad_schedule(sched, self._dims)
        fn = _compiled_live_step(
            self._jeng._statics, eng.caching_charge, sched.const_dt,
            self._use_pallas)
        before = ej.SCAN_TRACES
        with enable_x64():
            xs_j = {k: jnp.asarray(v) for k, v in sched.xs.items()}
            self._carry, probe = fn(self._spec_j, self._carry, xs_j)
        self.compiles += ej.SCAN_TRACES - before
        self._acc_dirty = True
        self._probes.append(probe)
        while len(self._probes) > self.ring:    # backpressure
            self._probes.popleft().block_until_ready()

    def _block(self) -> None:
        while self._probes:
            self._probes.popleft().block_until_ready()

    def _sync_costs(self) -> CostBreakdown:
        """Assign the device accumulator into the session's breakdown."""
        self._block()
        c = self.session.engine.costs
        acc_dev = (self._cgm_carry["acc"]
                   if self._cgm and self._cgm_carry is not None
                   else self._carry[2] if self._carry is not None else None)
        if acc_dev is not None and self._acc_dirty:
            acc = np.asarray(acc_dev)
            c.transfer = float(acc[0])
            c.caching = float(acc[1])
            c.keepalive_rent = float(acc[2])
            c.n_misses = int(acc[3])
            c.n_hits = int(acc[4])
            c.items_transferred = int(acc[5])
            c.n_requests = self._base_req[0] + self._host_nreq
            c.n_item_requests = self._base_req[1] + self._host_nitem
            self._acc_dirty = False
        return c

    def _sync_state(self) -> None:
        """Download the carry into the numpy engine (costs + cache state)."""
        self._sync_costs()
        if self._cgm:
            self._sync_state_cgm()
            return
        if self._carry is None:
            return
        eng = self.session.engine
        eng.state = CacheState.from_device(
            self._part, self._carry[0], self._carry[1], self.m)
        eng._set_partition_caches(self._part)
        keep_fn = getattr(self.policy, "item_keep", None)
        if keep_fn is not None:
            # boundary evictions already ran on device; align the numpy
            # engine's keep-or-not mask for any later host-side feed()
            eng.set_item_keep(keep_fn(), evict=False)

    def _sync_state_cgm(self) -> None:
        """CGM-mode sync: carry dict -> engine state + policy bookkeeping
        (``cgm_jax.sync_policy_from_run`` folded across buffered chunks)."""
        from ..core.cgm_jax import partition_from_of
        from ..core.crm import WindowCRM

        if self._cgm_carry is None:
            return
        eng = self.session.engine
        pol = self.policy
        part = self._part
        if self._cgm_bound:
            part = partition_from_of(
                self.n, np.asarray(self._cgm_carry["of"]))
        eng.state = CacheState.from_device(
            part, self._cgm_carry["E"], self._cgm_carry["anchor"], self.m)
        eng._set_partition_caches(part)
        nbd = 0
        for bsteps, ofs in self._ofs:
            if bsteps.size:
                ofs_np = np.asarray(ofs)
                for b in bsteps:
                    sizes = np.bincount(ofs_np[int(b)]).astype(np.int64)
                    pol.size_history.append(sizes[sizes > 1])
                nbd += int(bsteps.size)
        self._ofs = []
        pol.n_windows += nbd
        if self._cgm_bound:
            pol._partition = part
            pol._prev_crm = WindowCRM.from_compact(
                np.asarray(self._cgm_carry["p_idx"]),
                np.asarray(self._cgm_carry["praw"]),
                np.asarray(self._cgm_carry["pnorm"]),
                np.asarray(self._cgm_carry["pbin"]), n=self.n)
        self._part = part


def _cat_items(chunks: list) -> np.ndarray:
    """Concatenate (R_i, d_i) item arrays, -1-padding to the widest d."""
    if len(chunks) == 1:
        return chunks[0]
    d = max(a.shape[1] for a in chunks)
    R = sum(a.shape[0] for a in chunks)
    out = np.full((R, d), -1, dtype=np.int64)
    r = 0
    for a in chunks:
        out[r:r + a.shape[0], :a.shape[1]] = a
        r += a.shape[0]
    return out
