"""Fault tolerance: checkpoint/restart, failure injection, straggler
mitigation, elastic rescale.

This container has one host, so worker failures and stragglers are
SIMULATED — but the recovery machinery they exercise (atomic committed
checkpoints, restore-into-any-mesh, deterministic data-pipeline resume,
step-skipping straggler policy) is the real code a multi-host deployment
runs; tests/test_fault_tolerance.py kills training mid-run and verifies
bitwise-identical recovery.

* ``FailureInjector``   raises WorkerFailure with configured probability /
                        at scheduled steps (deterministic, seeded).
* ``StragglerPolicy``   per-step simulated worker latencies; a worker slower
                        than ``slack x median`` is a straggler -> the policy
                        either WAITs (baseline), SKIPs its microbatch
                        (gradient reweighting), or uses a BACKUP worker
                        (costed duplicate) — the choice + realised step time
                        is recorded so benchmarks can compare policies.
* ``TrainController``   wires model/optimizer/pipeline/checkpoints into a
                        crash-recoverable loop: on WorkerFailure it restores
                        the latest committed checkpoint (possibly onto a
                        DIFFERENT mesh — elastic rescale) and continues.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from ..checkpoint import CheckpointManager


class WorkerFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    p_fail: float = 0.0
    at_steps: tuple[int, ...] = ()
    seed: int = 0
    enabled: bool = True
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        """One-shot per step: a failure fires once, recovery then passes it
        (a real node is replaced after it dies)."""
        if not self.enabled or step in self._fired:
            return
        if step in self.at_steps:
            self._fired.add(step)
            raise WorkerFailure(f"injected failure at step {step}")
        if self.p_fail > 0:
            rng = np.random.default_rng((self.seed, step))
            if rng.random() < self.p_fail:
                self._fired.add(step)
                raise WorkerFailure(f"injected random failure at step {step}")


@dataclasses.dataclass
class StragglerPolicy:
    """Simulated straggler detection + mitigation accounting."""

    n_workers: int = 16
    slack: float = 2.0
    mode: str = "backup"           # wait | skip | backup
    seed: int = 0
    p_straggle: float = 0.05
    straggle_factor: float = 6.0
    base_step_s: float = 1.0
    log: list = dataclasses.field(default_factory=list)

    def step_time(self, step: int) -> float:
        rng = np.random.default_rng((self.seed, step, 7))
        t = self.base_step_s * (1.0 + 0.05 * rng.standard_normal(self.n_workers))
        straggle = rng.random(self.n_workers) < self.p_straggle
        t = np.where(straggle, t * self.straggle_factor, t)
        med = float(np.median(t))
        worst = float(t.max())
        if worst <= self.slack * med:
            realised, action = worst, "none"
        elif self.mode == "wait":
            realised, action = worst, "wait"
        elif self.mode == "skip":
            # drop stragglers' microbatches; reweight gradient
            realised = float(t[t <= self.slack * med].max())
            action = "skip"
        else:                        # backup worker races the straggler
            backup = med * (1.0 + 0.1)
            realised = float(min(worst, self.slack * med + backup))
            action = "backup"
        self.log.append({"step": step, "median_s": med, "worst_s": worst,
                         "realised_s": realised, "action": action})
        return realised


class TrainController:
    """Crash-recoverable training loop (see module docstring)."""

    def __init__(
        self,
        train_step: Callable,            # (params, opt, batch) -> (p, o, stats)
        init_state: Callable,            # () -> (params, opt_state)
        batches,                         # iterator with state_dict/load_state_dict
        ckpt_dir: str,
        ckpt_every: int = 20,
        injector: FailureInjector | None = None,
        straggler: StragglerPolicy | None = None,
        shardings=None,
    ):
        self.train_step = train_step
        self.init_state = init_state
        self.batches = batches
        self.ckpt = CheckpointManager(ckpt_dir, keep=2, async_save=False)
        self.ckpt_every = ckpt_every
        self.injector = injector or FailureInjector(enabled=False)
        self.straggler = straggler
        self.shardings = shardings
        self.restarts = 0
        self.history: list[dict] = []

    def _restore_or_init(self):
        params, opt = self.init_state()
        like = {"params": params, "opt": opt,
                "pipeline": self.batches.state_dict() if hasattr(
                    self.batches, "state_dict") else {"step": 0}}
        step, tree, _meta = self.ckpt.restore_latest(like, self.shardings)
        if step is None:
            return 0, params, opt
        if hasattr(self.batches, "load_state_dict"):
            self.batches.load_state_dict(
                jax.tree.map(int, tree["pipeline"]))
        return step, tree["params"], tree["opt"]

    def run(self, total_steps: int, max_restarts: int = 10):
        attempt = 0
        while True:
            start, params, opt = self._restore_or_init()
            try:
                step = start
                it = iter(self.batches)
                while step < total_steps:
                    self.injector.check(step)
                    batch = next(it)
                    params, opt, stats = self.train_step(params, opt, batch)
                    if self.straggler is not None:
                        self.straggler.step_time(step)
                    step += 1
                    self.history.append(
                        {"step": step, "loss": float(stats["loss"])})
                    if step % self.ckpt_every == 0 or step == total_steps:
                        self.ckpt.save(step, {
                            "params": params, "opt": opt,
                            "pipeline": (self.batches.state_dict()
                                         if hasattr(self.batches, "state_dict")
                                         else {"step": step}),
                        }, meta={"step": step})
                return params, opt
            except WorkerFailure as e:
                attempt += 1
                self.restarts += 1
                if attempt > max_restarts:
                    raise
                print(f"[fault-tolerance] {e} -> restarting "
                      f"(attempt {attempt})", flush=True)
