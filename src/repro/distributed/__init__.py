from .fault_tolerance import (
    FailureInjector,
    StragglerPolicy,
    TrainController,
    WorkerFailure,
)

__all__ = [
    "FailureInjector",
    "StragglerPolicy",
    "TrainController",
    "WorkerFailure",
]
