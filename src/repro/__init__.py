"""repro: Adaptive K-PackCache (AKPC) — faithful reproduction + production
multi-pod JAX framework.  See README.md / DESIGN.md."""

__version__ = "1.0.0"
