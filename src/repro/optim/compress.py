"""Gradient compression with error feedback (distributed-optimization trick).

int8 stochastic-free linear quantisation per tensor with an ERROR-FEEDBACK
accumulator: the quantisation residual is added back to the next step's
gradient, so the compressed optimizer converges like the uncompressed one
(Seide et al. / EF-SGD analysis).  Used as an optional hook in the train
step: gradients are quantised BEFORE the cross-pod all-reduce (the DCN hop
is the expensive one at multi-pod scale) and dequantised after.

Pure JAX; the all-reduce itself stays in XLA — quantising the tensor that
crosses the wire shrinks the collective's payload 2x (bf16) / 4x (fp32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantise(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantise(q, scale):
    return q.astype(jnp.float32) * scale


def compress_gradients(grads, ef_state):
    """Returns (quantised pytree of (int8, scale), new_error_state)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _quantise(gf)
        err = gf - _dequantise(q, s)
        return (q, s), err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qtree = tdef.unflatten([o[0] for o in out])
    etree = tdef.unflatten([o[1] for o in out])
    return qtree, etree


def decompress_gradients(qtree, like):
    flat_q = [qs for qs in jax.tree.leaves(qtree, is_leaf=lambda x: isinstance(x, tuple))]
    flat_l, tdef = jax.tree.flatten(like)
    deq = [_dequantise(q, s).astype(l.dtype) for (q, s), l in zip(flat_q, flat_l)]
    return tdef.unflatten(deq)
