"""8-bit AdamW: blockwise-quantised moments (Dettmers et al. style).

m is stored symmetric int8 with a per-block (128 elements along the last
axis) fp32 absmax scale; v (non-negative, huge dynamic range) stores
sqrt(v) in the same layout — linear int8 on the sqrt domain covers v's
range quadratically (linear-on-v collapses small entries to 0 and the
rsqrt in the update then diverges; see tests/test_optim8bit.py).
Moments dequantise -> update -> requantise inside the step, so the resident
optimizer state is ~2.1 GB instead of 7.4 GB per device for deepseek-v2 on
the 16x16 mesh — the §Perf-predicted fix for the last fits_hbm=False cell.

Quantisation error per step is bounded by the block absmax / 127; the toy
convergence test (tests/test_optim8bit.py) tracks exact AdamW closely.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .adamw import AdamWConfig, cosine_schedule, global_norm

BLOCK = 128


def _nblocks(n: int) -> int:
    return -(-n // BLOCK)


def _pad_to_block(x):
    n = x.shape[-1]
    pad = _nblocks(n) * BLOCK - n
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
    return x


def quantise(x):
    """fp32 -> (int8 blocks, fp32 scales).  x: any shape."""
    shape = x.shape
    xb = _pad_to_block(x.astype(jnp.float32)).reshape(
        shape[:-1] + (_nblocks(shape[-1]), BLOCK))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0          # (.., nb)
    q = jnp.round(xb / jnp.maximum(scale[..., None], 1e-20))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q.reshape(shape[:-1] + (-1,))[..., : shape[-1]], scale


def dequantise(q, scale, shape):
    qb = _pad_to_block(q.astype(jnp.float32)).reshape(
        shape[:-1] + (_nblocks(shape[-1]), BLOCK))
    x = qb * scale[..., None]
    return x.reshape(shape[:-1] + (-1,))[..., : shape[-1]]


def adamw8bit_init(params):
    def one(p):
        nb = _nblocks(p.shape[-1]) if p.ndim else 1
        return {
            "m_q": jnp.zeros(p.shape, jnp.int8),
            "m_s": jnp.zeros(p.shape[:-1] + (nb,), jnp.float32),
            "v_q": jnp.zeros(p.shape, jnp.int8),
            "v_s": jnp.zeros(p.shape[:-1] + (nb,), jnp.float32),
        }

    return {
        "mv": jax.tree.map(
            one, params, is_leaf=lambda x: hasattr(x, "shape")),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw8bit_update(cfg: AdamWConfig, grads, state, params):
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, mv, p):
        g = g.astype(jnp.float32) * scale
        m = dequantise(mv["m_q"], mv["m_s"], p.shape)
        v = jnp.square(dequantise(mv["v_q"], mv["v_s"], p.shape))
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p_new = (p.astype(jnp.float32) * (1.0 - lr * wd) - lr * delta).astype(
            p.dtype)
        m_q, m_s = quantise(m)
        v_q, v_s = quantise(jnp.sqrt(v))
        return p_new, {"m_q": m_q, "m_s": m_s, "v_q": v_q, "v_s": v_s}

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mv = tdef.flatten_up_to(state["mv"])
    out = [upd(g, mv, p) for g, mv, p in zip(flat_g, flat_mv, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mv = tdef.unflatten([o[1] for o in out])
    return new_p, {"mv": new_mv, "step": step}, {"grad_norm": gnorm, "lr": lr}
