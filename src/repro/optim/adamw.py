"""AdamW from scratch (no optax): fp32 moments, global-norm clipping,
decoupled weight decay, cosine schedule with linear warmup.

Moments are plain pytrees mirroring the params, so pjit shards them exactly
like the parameters (ZeRO-style: with params 2-D sharded over
(data x model), optimizer state is too — no replicated optimizer memory).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    #: lr fraction at step 0 of the warmup ramp: warmup runs linearly from
    #: warmup_floor*lr to lr instead of from 0.  The default 0.0 preserves
    #: the original schedule bitwise (adds 0.0, scales by 1.0); short runs
    #: (e.g. policy training with warmup_steps ~ total_steps/10) set it so
    #: the first steps are not wasted at near-zero lr.
    warmup_floor: float = 0.0


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.warmup_floor + (1.0 - cfg.warmup_floor) * (
        step / jnp.maximum(cfg.warmup_steps, 1)
    )
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0   # no decay on norms/bias
        p_new = p.astype(jnp.float32) * (1.0 - lr * wd) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }
