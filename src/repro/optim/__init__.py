from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from .compress import compress_gradients, decompress_gradients, ef_init

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "compress_gradients",
    "decompress_gradients",
    "ef_init",
]
