"""Unified model API over all assigned architecture families.

``build_model(cfg)`` returns a ``Model`` with:

* ``init(key) -> params``                       (stacked-per-layer pytree)
* ``loss(params, batch, mesh=None) -> scalar``  (next-token CE + MoE aux)
* ``prefill(params, batch, mesh=None) -> (logits_last, cache)``
* ``decode_step(params, cache, tokens, pos, mesh=None) -> (logits, cache)``
* ``init_cache(batch, cache_len) -> cache``     (zeros; shapes only)

Layers are scanned (``lax.scan`` over stacked params) with ``jax.checkpoint``
remat, so HLO size is depth-independent.  Decode keeps KV sharded over the
model axis on the SEQUENCE dim (see attention.py).  SSM/xLSTM archs carry
recurrent state instead of KV.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .attention import (
    cross_forward,
    cross_kv,
    gqa_decode,
    gqa_forward,
    init_cross,
    init_gqa,
    init_mla,
    mla_decode,
    mla_forward,
)
from .common import (
    KeyGen,
    apply_norm,
    cross_entropy,
    dense_init,
    dtype_of,
    embed_init,
    make_norm,
    rope_angles,
)
from .config import ModelConfig
from .mlp import init_mlp, init_moe, mlp_forward, moe_forward
from .shard_ctx import constrain, constrain_cache, use_mesh

Params = Any


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def _sinusoid(pos, d):
    half = d // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ===========================================================================
# decoder-only transformers (dense / MoE / VLM)
# ===========================================================================
def _init_decoder(cfg: ModelConfig, key) -> Params:
    kg = KeyGen(key)
    dt = dtype_of(cfg.param_dtype)
    d, V = cfg.d_model, cfg.vocab
    first_k = cfg.moe.first_k_dense if cfg.moe else 0
    L_moe = cfg.n_layers - first_k
    p: dict = {
        "embed": embed_init(kg(), (V, d), dt),
        "final_norm": make_norm(cfg.norm, d, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(kg(), (d, V), dt, fan_in=d)
    if cfg.vlm is not None:
        p["patch_proj"] = dense_init(kg(), (cfg.vlm.d_patch, d), dt,
                                     fan_in=cfg.vlm.d_patch)

    def attn_init(L):
        return (init_mla if cfg.mla else init_gqa)(kg, cfg, L, dt)

    def layer_stack(L, moe: bool):
        return {
            "attn": attn_init(L),
            "mlp": init_moe(kg, cfg, L, dt) if moe else init_mlp(
                kg, d, (cfg.moe.d_ff_dense or cfg.d_ff) if cfg.moe else cfg.d_ff,
                L, dt, cfg.activation
            ),
            "norm1": jnp.ones((L, d), dt) if cfg.norm == "rmsnorm" else {
                "scale": jnp.ones((L, d), dt), "bias": jnp.zeros((L, d), dt)},
            "norm2": jnp.ones((L, d), dt) if cfg.norm == "rmsnorm" else {
                "scale": jnp.ones((L, d), dt), "bias": jnp.zeros((L, d), dt)},
        }

    if first_k > 0:
        p["dense_prefix"] = layer_stack(first_k, moe=False)
    p["layers"] = layer_stack(L_moe, moe=cfg.moe is not None)
    return p


def _decoder_block(cfg: ModelConfig, mesh, moe: bool):
    def block(carry, lp, cos, sin):
        x, aux = carry
        h = apply_norm(cfg.norm, lp["norm1"], x)
        a = (mla_forward if cfg.mla else gqa_forward)(lp["attn"], h, cfg, cos, sin)
        # resolve the row-parallel partial sum HERE, in bf16: otherwise XLA
        # defers it into the fp32 norm internals and the (2x bigger) fp32
        # backward all-reduces dominate the step (§Perf iteration A2)
        x = constrain(x + a, ("dp", None, None))
        h = apply_norm(cfg.norm, lp["norm2"], x)
        if moe:
            y, al = moe_forward(lp["mlp"], h, cfg, mesh=mesh)
            return (constrain(x + y, ("dp", None, None)), aux + al), None
        return (constrain(x + mlp_forward(lp["mlp"], h, cfg.activation),
                          ("dp", None, None)), aux), None

    return block


def _decoder_hidden(cfg: ModelConfig, p, batch, mesh):
    """Embed inputs and run the layer stack; returns (hidden, aux_loss)."""
    tokens = batch["tokens"]
    x = p["embed"][tokens]
    if cfg.vlm is not None:
        patches = batch["patches"].astype(x.dtype) @ p["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)
    B, S, _ = x.shape
    cos, sin = rope_angles(jnp.arange(S), cfg.head_dim if not cfg.mla
                           else cfg.mla.rope_head_dim, cfg.rope_theta)
    aux = jnp.zeros((), jnp.float32)

    if "dense_prefix" in p:
        blk = jax.checkpoint(functools.partial(
            _decoder_block(cfg, mesh, moe=False), cos=cos, sin=sin))
        (x, aux), _ = jax.lax.scan(blk, (x, aux), p["dense_prefix"])
    blk = jax.checkpoint(functools.partial(
        _decoder_block(cfg, mesh, moe=cfg.moe is not None), cos=cos, sin=sin))
    (x, aux), _ = jax.lax.scan(blk, (x, aux), p["layers"])
    return apply_norm(cfg.norm, p["final_norm"], x), aux


def _logits(cfg, p, h):
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return h @ w


def _decoder_loss(cfg: ModelConfig, p, batch, mesh=None):
    h, aux = _decoder_hidden(cfg, p, batch, mesh)
    if cfg.vlm is not None:                      # loss on the text positions
        h = h[:, -batch["tokens"].shape[1]:]
    logits = _logits(cfg, p, h)
    return cross_entropy(logits, batch["labels"], batch.get("mask")) + aux


def _decoder_prefill(cfg: ModelConfig, p, batch, mesh=None):
    h, _ = _decoder_hidden(cfg, p, batch, mesh)
    logits = _logits(cfg, p, h[:, -1:])
    cache = _decoder_cache_from_prefill(cfg, p, batch, mesh)
    return logits, cache


def _decoder_cache_shapes(cfg: ModelConfig, B: int, S: int):
    first_k = cfg.moe.first_k_dense if cfg.moe else 0
    L = cfg.n_layers - first_k
    Sc = min(S, cfg.swa_window) if cfg.swa_window > 0 else S
    if cfg.mla:
        m = cfg.mla
        mk = lambda L_: {"c": (L_, B, Sc, m.kv_lora_rank), "r": (L_, B, Sc, m.rope_head_dim)}
    else:
        mk = lambda L_: {"k": (L_, B, Sc, cfg.n_kv_heads, cfg.head_dim),
                         "v": (L_, B, Sc, cfg.n_kv_heads, cfg.head_dim)}
    out = {"layers": mk(L)}
    if first_k:
        out["dense_prefix"] = mk(first_k)
    return out


def _cache_constrain(c):
    """Shard a freshly-created cache leaf (L, B, S, ...): B over dp, S over
    model; tiny-batch caches context-parallel S over all axes."""
    return constrain_cache(c, b_axis=1, s_axis=2)


def _decoder_init_cache(cfg: ModelConfig, B: int, S: int, dtype):
    shapes = _decoder_cache_shapes(cfg, B, S)
    return jax.tree.map(lambda s: _cache_constrain(jnp.zeros(s, dtype)), shapes,
                        is_leaf=lambda s: isinstance(s, tuple))


def _decoder_cache_from_prefill(cfg, p, batch, mesh):
    # dry-run-sufficient: zero-init cache of the prefill length (a production
    # prefill writes K/V as it goes; shapes/shardings are identical)
    B = batch["tokens"].shape[0]
    S = batch["tokens"].shape[1] + (cfg.vlm.n_patches if cfg.vlm else 0)
    return _decoder_init_cache(cfg, B, S, dtype_of(cfg.compute_dtype))


def _onehot_write(c, rows, slot):
    """cache (L, B, S, ...) <- rows (L, B, 1, ...) at position ``slot`` of
    the (possibly sharded) S axis, without cross-shard data movement.
    ``slot`` is () (one position for every row) or (B,) (per-row positions,
    continuous batching).  ``rows`` must already be encoded in the cache
    dtype (see encode_kv)."""
    S = c.shape[2]
    slotv = jnp.atleast_1d(slot)                               # (B|1,)
    hit = jnp.arange(S)[None] == slotv[:, None]                # (B|1, S)
    hit = hit.reshape((1,) + hit.shape + (1,) * (c.ndim - 3))
    assert rows.dtype == c.dtype, (rows.dtype, c.dtype)
    return jnp.where(hit, rows, c)


def _decoder_decode(cfg: ModelConfig, p, cache, tokens, pos, mesh=None):
    """tokens (B, 1) int32; pos () int32 current position, or (B,) int32
    per-row positions (continuous batching with staggered arrivals)."""
    x = p["embed"][tokens]
    B = x.shape[0]
    rope_dim = cfg.head_dim if not cfg.mla else cfg.mla.rope_head_dim
    # (B|1, 1, half): broadcasts over B for scalar pos, per-row otherwise
    cos, sin = rope_angles(jnp.atleast_1d(pos)[:, None], rope_dim,
                           cfg.rope_theta)

    def one_stack(x, stack_p, stack_cache, moe: bool):
        def body(carry, xs):
            h_in, = carry
            lp, cl = xs
            h = apply_norm(cfg.norm, lp["norm1"], h_in)
            if cfg.mla:
                a, rows = mla_decode(lp["attn"], h, cl, pos, cfg, cos, sin)
            else:
                a, rows = gqa_decode(lp["attn"], h, cl, pos, cfg, cos, sin)
            h_in = h_in + a
            h = apply_norm(cfg.norm, lp["norm2"], h_in)
            if moe:
                y, _ = moe_forward(lp["mlp"], h, cfg, mesh=mesh)
            else:
                y = mlp_forward(lp["mlp"], h, cfg.activation)
            return (h_in + y,), rows

        (x,), rows = jax.lax.scan(body, (x,), (stack_p, stack_cache))
        # ONE cache write for the whole stack, as a shard-local one-hot
        # select: a dynamic-update-slice on the model-sharded S axis makes
        # XLA reshard the WHOLE cache through all-to-alls (8.1 GB/step on
        # codeqwen decode_32k — EXPERIMENTS.md §Perf iteration C); the
        # select touches only local shards and aliases the donated buffer.
        S = jax.tree.leaves(stack_cache)[0].shape[2]
        slot = pos % S if cfg.swa_window > 0 else pos
        new_cache = jax.tree.map(
            lambda c, r: constrain_cache(_onehot_write(c, r, slot),
                                         b_axis=1, s_axis=2),
            stack_cache, rows)
        return x, new_cache

    new_cache = {}
    if "dense_prefix" in p:
        x, nc = one_stack(x, p["dense_prefix"], cache["dense_prefix"], moe=False)
        new_cache["dense_prefix"] = nc
    x, nc = one_stack(x, p["layers"], cache["layers"], moe=cfg.moe is not None)
    new_cache["layers"] = nc
    h = apply_norm(cfg.norm, p["final_norm"], x)
    return _logits(cfg, p, h), new_cache


# ===========================================================================
# encoder-decoder (Whisper backbone; conv/mel frontend is a stub)
# ===========================================================================
def _init_encdec(cfg: ModelConfig, key) -> Params:
    kg = KeyGen(key)
    dt = dtype_of(cfg.param_dtype)
    d, V = cfg.d_model, cfg.vocab
    e = cfg.encdec

    def attn_stack(L):
        return init_gqa(kg, cfg, L, dt)

    def norms(L):
        return {"scale": jnp.ones((L, d), dt), "bias": jnp.zeros((L, d), dt)}

    return {
        "embed": embed_init(kg(), (V, d), dt),
        "enc": {
            "attn": attn_stack(e.n_encoder_layers),
            "mlp": init_mlp(kg, d, cfg.d_ff, e.n_encoder_layers, dt, "gelu"),
            "norm1": norms(e.n_encoder_layers),
            "norm2": norms(e.n_encoder_layers),
        },
        "enc_final": norms(1),
        "dec": {
            "attn": attn_stack(e.n_decoder_layers),
            "cross": init_cross(kg, cfg, e.n_decoder_layers, dt),
            "mlp": init_mlp(kg, d, cfg.d_ff, e.n_decoder_layers, dt, "gelu"),
            "norm1": norms(e.n_decoder_layers),
            "norm2": norms(e.n_decoder_layers),
            "norm3": norms(e.n_decoder_layers),
        },
        "dec_final": norms(1),
    }


def _slice_norm(n, i=0):
    return {"scale": n["scale"][i], "bias": n["bias"][i]}


def _encode(cfg, p, frames):
    B, Se, d = frames.shape
    x = frames + _sinusoid(jnp.arange(Se), d)[None].astype(frames.dtype)

    def blk(x, lp):
        h = apply_norm("layernorm", lp["norm1"], x)
        x = x + gqa_forward(lp["attn"], h, cfg, None, None, causal=False)
        h = apply_norm("layernorm", lp["norm2"], x)
        return x + mlp_forward(lp["mlp"], h, "gelu"), None

    x, _ = jax.lax.scan(jax.checkpoint(blk), x, p["enc"])
    return apply_norm("layernorm", _slice_norm(p["enc_final"]), x)


def _encdec_loss(cfg: ModelConfig, p, batch, mesh=None):
    enc_out = _encode(cfg, p, batch["frames"].astype(dtype_of(cfg.compute_dtype)))
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = p["embed"][tokens] + _sinusoid(jnp.arange(S), cfg.d_model)[None].astype(
        dtype_of(cfg.compute_dtype))

    def blk(x, lp):
        h = apply_norm("layernorm", lp["norm1"], x)
        x = x + gqa_forward(lp["attn"], h, cfg, None, None, causal=True)
        h = apply_norm("layernorm", lp["norm2"], x)
        x = x + cross_forward(lp["cross"], h, cross_kv(lp["cross"], enc_out, cfg), cfg)
        h = apply_norm("layernorm", lp["norm3"], x)
        return x + mlp_forward(lp["mlp"], h, "gelu"), None

    x, _ = jax.lax.scan(jax.checkpoint(blk), x, p["dec"])
    x = apply_norm("layernorm", _slice_norm(p["dec_final"]), x)
    logits = x @ p["embed"].T
    return cross_entropy(logits, batch["labels"], batch.get("mask"))


def _encdec_prefill(cfg: ModelConfig, p, batch, mesh=None, cache_len: int = 1024):
    """Encode source; prime decoder caches (cross-KV precomputed).

    ``cache_len`` (static) sizes the decoder self-attention cache.  The
    returned logits are the pre-decode BOS projection (shape-complete; the
    first real token comes from decode_step).
    """
    enc_out = _encode(cfg, p, batch["frames"].astype(dtype_of(cfg.compute_dtype)))
    B, Se = enc_out.shape[:2]
    Ld = cfg.encdec.n_decoder_layers
    # cross-attention K/V per decoder layer
    ck = jax.vmap(lambda lp: cross_kv(lp, enc_out, cfg), in_axes=(0,))(p["dec"]["cross"])
    cache = {
        "self": {
            "k": _cache_constrain(jnp.zeros(
                (Ld, B, cache_len, cfg.n_kv_heads, cfg.head_dim), enc_out.dtype)),
            "v": _cache_constrain(jnp.zeros(
                (Ld, B, cache_len, cfg.n_kv_heads, cfg.head_dim), enc_out.dtype)),
        },
        "cross": jax.tree.map(_cache_constrain, ck),
    }
    bos = p["embed"][jnp.zeros((B, 1), jnp.int32)]
    logits = apply_norm("layernorm", _slice_norm(p["dec_final"]), bos) @ p["embed"].T
    return logits, cache


def _encdec_init_cache(cfg: ModelConfig, B: int, S: int, dtype):
    """Decoder self cache of length S + cross K/V over a source of length S
    (the decode_* cells stress source length == seq_len)."""
    Ld = cfg.encdec.n_decoder_layers
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "self": {
            "k": jnp.zeros((Ld, B, S, KH, hd), dtype),
            "v": jnp.zeros((Ld, B, S, KH, hd), dtype),
        },
        "cross": {
            "k": jnp.zeros((Ld, B, S, H, hd), dtype),
            "v": jnp.zeros((Ld, B, S, H, hd), dtype),
        },
    }


def _encdec_decode(cfg: ModelConfig, p, cache, tokens, pos, mesh=None):
    # (B|1, 1, d) positional term: scalar pos broadcasts, (B,) is per-row
    x = p["embed"][tokens] + _sinusoid(jnp.atleast_1d(pos), cfg.d_model)[
        :, None].astype(dtype_of(cfg.compute_dtype))

    def body(carry, xs):
        (h_in,) = carry
        lp, self_c, cross_c = xs
        h = apply_norm("layernorm", lp["norm1"], h_in)
        a, rows = gqa_decode(lp["attn"], h, self_c, pos, cfg, None, None)
        h_in = h_in + a
        h = apply_norm("layernorm", lp["norm2"], h_in)
        h_in = h_in + cross_forward(lp["cross"], h, cross_c, cfg)
        h = apply_norm("layernorm", lp["norm3"], h_in)
        return (h_in + mlp_forward(lp["mlp"], h, "gelu"),), rows

    (x,), rows = jax.lax.scan(body, (x,), (p["dec"], cache["self"], cache["cross"]))
    self_new = jax.tree.map(
        lambda c, r: constrain_cache(_onehot_write(c, r, pos),
                                     b_axis=1, s_axis=2),
        cache["self"], rows)
    x = apply_norm("layernorm", _slice_norm(p["dec_final"]), x)
    return x @ p["embed"].T, {"self": self_new, "cross": cache["cross"]}


# ===========================================================================
# SSM / hybrid (Mamba2, Zamba2)
# ===========================================================================
def _hybrid_forward(cfg: ModelConfig, p, x, mesh=None):
    g, k, rest = ssm_mod.hybrid_layout(cfg)
    d = cfg.d_model
    mam = p["mamba"]
    norms = p["norm"]

    def mamba_block(x, lp_and_norm):
        lp, nm = lp_and_norm
        return x + ssm_mod.mamba_forward(lp, apply_norm("rmsnorm", nm, x), cfg), None

    def run_slice(x, lo, hi):
        sl = jax.tree.map(lambda a: a[lo:hi], mam)
        nm = norms[lo:hi]
        x, _ = jax.lax.scan(jax.checkpoint(mamba_block), x, (sl, nm))
        return x

    if g > 0:
        B, S, _ = x.shape
        cos, sin = rope_angles(jnp.arange(S), cfg.head_dim, cfg.rope_theta)

        def shared_block(x):
            h = apply_norm("rmsnorm", p["shared_norm1"], x)
            x = x + gqa_forward(p["shared_attn"], h, cfg, cos, sin, causal=True)
            h = apply_norm("rmsnorm", p["shared_norm2"], x)
            return x + mlp_forward(p["shared_mlp"], h, "silu")

        for gi in range(g):
            x = run_slice(x, gi * k, (gi + 1) * k)
            x = jax.checkpoint(shared_block)(x)
        if rest:
            x = run_slice(x, g * k, g * k + rest)
    else:
        x = run_slice(x, 0, cfg.n_layers)
    return x


def _init_ssm(cfg: ModelConfig, key) -> Params:
    kg = KeyGen(key)
    dt = dtype_of(cfg.param_dtype)
    p = ssm_mod.init_hybrid(kg, cfg, dt)
    p["embed"] = embed_init(kg(), (cfg.vocab, cfg.d_model), dt)
    p["final_norm"] = jnp.ones((cfg.d_model,), dt)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(kg(), (cfg.d_model, cfg.vocab), dt,
                                  fan_in=cfg.d_model)
    return p


def _ssm_loss(cfg, p, batch, mesh=None):
    x = p["embed"][batch["tokens"]]
    x = _hybrid_forward(cfg, p, x, mesh)
    x = apply_norm("rmsnorm", p["final_norm"], x)
    return cross_entropy(_logits(cfg, p, x), batch["labels"], batch.get("mask"))


def _ssm_init_cache(cfg: ModelConfig, B: int, S: int, dtype):
    di, H, ds, K = ssm_mod._mamba_dims(cfg)
    hd = cfg.ssm.head_dim
    g, k, rest = ssm_mod.hybrid_layout(cfg)
    cache = {
        "ssm": constrain(jnp.zeros((cfg.n_layers, B, H, ds, hd), jnp.float32),
                         (None, "dp", "model", None, None)),
        "conv": constrain(jnp.zeros((cfg.n_layers, B, K - 1, di + 2 * ds), dtype),
                          (None, "dp", None, "model")),
    }
    if g > 0:
        cache["attn"] = {
            "k": _cache_constrain(jnp.zeros(
                (g, B, S, cfg.n_kv_heads, cfg.head_dim), dtype)),
            "v": _cache_constrain(jnp.zeros(
                (g, B, S, cfg.n_kv_heads, cfg.head_dim), dtype)),
        }
    return cache


def _ssm_prefill(cfg, p, batch, mesh=None):
    x = p["embed"][batch["tokens"]]
    x = _hybrid_forward(cfg, p, x, mesh)
    x = apply_norm("rmsnorm", p["final_norm"], x)
    logits = _logits(cfg, p, x[:, -1:])
    B, S = batch["tokens"].shape
    return logits, _ssm_init_cache(cfg, B, S, dtype_of(cfg.compute_dtype))


def _ssm_decode(cfg: ModelConfig, p, cache, tokens, pos, mesh=None):
    g, k, rest = ssm_mod.hybrid_layout(cfg)
    x = p["embed"][tokens]
    cos, sin = None, None
    if g > 0:
        cos, sin = rope_angles(jnp.atleast_1d(pos)[:, None], cfg.head_dim,
                               cfg.rope_theta)

    def mamba_slice(x, lo, hi):
        sl = jax.tree.map(lambda a: a[lo:hi], p["mamba"])
        nm = p["norm"][lo:hi]
        c = {kk: cache[kk][lo:hi] for kk in ("ssm", "conv")}

        def body(carry, xs):
            (h,) = carry
            lp, nrm, ssm_c, conv_c = xs
            y, st = ssm_mod.mamba_step(lp, apply_norm("rmsnorm", nrm, h),
                                       {"ssm": ssm_c, "conv": conv_c}, cfg)
            return (h + y,), (st["ssm"], st["conv"])

        (x,), (new_ssm, new_conv) = jax.lax.scan(
            body, (x,), (sl, nm, c["ssm"], c["conv"]))
        return x, new_ssm, new_conv

    new_ssm, new_conv, new_k, new_v = [], [], [], []
    if g > 0:
        for gi in range(g):
            x, ns, nc = mamba_slice(x, gi * k, (gi + 1) * k)
            new_ssm.append(ns)
            new_conv.append(nc)
            h = apply_norm("rmsnorm", p["shared_norm1"], x)
            kv = {"k": cache["attn"]["k"][gi], "v": cache["attn"]["v"][gi]}
            a, rows = gqa_decode(p["shared_attn"], h, kv, pos, cfg, cos, sin)
            x = x + a
            h = apply_norm("rmsnorm", p["shared_norm2"], x)
            x = x + mlp_forward(p["shared_mlp"], h, "silu")
            new_k.append(constrain_cache(
                _onehot_write(kv["k"][None], rows["k"][None], pos),
                b_axis=1, s_axis=2)[0])
            new_v.append(constrain_cache(
                _onehot_write(kv["v"][None], rows["v"][None], pos),
                b_axis=1, s_axis=2)[0])
        if rest:
            x, ns, nc = mamba_slice(x, g * k, g * k + rest)
            new_ssm.append(ns)
            new_conv.append(nc)
    else:
        x, ns, nc = mamba_slice(x, 0, cfg.n_layers)
        new_ssm.append(ns)
        new_conv.append(nc)
    new_cache = {
        "ssm": jnp.concatenate(new_ssm, axis=0),
        "conv": jnp.concatenate(new_conv, axis=0),
    }
    if g > 0:
        new_cache["attn"] = {
            "k": constrain_cache(jnp.stack(new_k), b_axis=1, s_axis=2),
            "v": constrain_cache(jnp.stack(new_v), b_axis=1, s_axis=2),
        }
    x = apply_norm("rmsnorm", p["final_norm"], x)
    return _logits(cfg, p, x), new_cache


# ===========================================================================
# xLSTM
# ===========================================================================
def _init_xlstm(cfg: ModelConfig, key) -> Params:
    kg = KeyGen(key)
    dt = dtype_of(cfg.param_dtype)
    g, m = xlstm_mod.xlstm_layout(cfg)
    p = {
        "embed": embed_init(kg(), (cfg.vocab, cfg.d_model), dt),
        "mlstm": xlstm_mod.init_mlstm(kg, cfg, g * m, dt),
        "slstm": xlstm_mod.init_slstm(kg, cfg, g, dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(kg(), (cfg.d_model, cfg.vocab), dt,
                                  fan_in=cfg.d_model)
    return p


def _xlstm_forward(cfg, p, x):
    g, m = xlstm_mod.xlstm_layout(cfg)

    def m_block(x, lp):
        return xlstm_mod.mlstm_forward(lp, x, cfg), None

    for gi in range(g):
        sl = jax.tree.map(lambda a: a[gi * m:(gi + 1) * m], p["mlstm"])
        x, _ = jax.lax.scan(jax.checkpoint(m_block), x, sl)
        sp = jax.tree.map(lambda a: a[gi], p["slstm"])
        x = jax.checkpoint(lambda xx: xlstm_mod.slstm_forward(sp, xx, cfg))(x)
    return x


def _xlstm_loss(cfg, p, batch, mesh=None):
    x = p["embed"][batch["tokens"]]
    x = _xlstm_forward(cfg, p, x)
    from .common import rmsnorm
    x = rmsnorm(p["final_norm"], x)
    return cross_entropy(_logits(cfg, p, x), batch["labels"], batch.get("mask"))


def _xlstm_init_cache(cfg: ModelConfig, B: int, S: int, dtype):
    del S                                         # recurrent: state only
    g, m = xlstm_mod.xlstm_layout(cfg)
    di, H, hd = xlstm_mod._mlstm_dims(cfg)
    return {
        "mlstm": jnp.zeros((g * m, B, H, hd, 2 * hd), jnp.float32),
        "slstm_h": jnp.zeros((g, B, cfg.d_model), dtype),
        "slstm_c": jnp.zeros((g, B, cfg.d_model), jnp.float32),
        "slstm_n": jnp.zeros((g, B, cfg.d_model), jnp.float32),
    }


def _xlstm_prefill(cfg, p, batch, mesh=None):
    x = p["embed"][batch["tokens"]]
    x = _xlstm_forward(cfg, p, x)
    from .common import rmsnorm
    logits = _logits(cfg, p, rmsnorm(p["final_norm"], x[:, -1:]))
    B, S = batch["tokens"].shape
    return logits, _xlstm_init_cache(cfg, B, S, dtype_of(cfg.compute_dtype))


def _xlstm_decode(cfg, p, cache, tokens, pos, mesh=None):
    del pos
    g, m = xlstm_mod.xlstm_layout(cfg)
    x = p["embed"][tokens]
    new_m, new_h, new_c, new_n = [], [], [], []
    for gi in range(g):
        sl = jax.tree.map(lambda a: a[gi * m:(gi + 1) * m], p["mlstm"])

        def body(carry, xs):
            (h,) = carry
            lp, st = xs
            y, st_new = xlstm_mod.mlstm_step(lp, h, st, cfg)
            return (y,), st_new

        (x,), ms = jax.lax.scan(body, (x,), (sl, cache["mlstm"][gi * m:(gi + 1) * m]))
        new_m.append(ms)
        sp = jax.tree.map(lambda a: a[gi], p["slstm"])
        st = (cache["slstm_h"][gi], cache["slstm_c"][gi], cache["slstm_n"][gi])
        x, (h, c, n) = xlstm_mod.slstm_step(sp, x, st, cfg)
        new_h.append(h)
        new_c.append(c)
        new_n.append(n)
    from .common import rmsnorm
    logits = _logits(cfg, p, rmsnorm(p["final_norm"], x))
    return logits, {
        "mlstm": jnp.concatenate(new_m, axis=0),
        "slstm_h": jnp.stack(new_h),
        "slstm_c": jnp.stack(new_c),
        "slstm_n": jnp.stack(new_n),
    }


def _with_ctx(fn):
    """Install the mesh sharding-hint context around a step entry point."""
    @functools.wraps(fn)
    def wrapped(*args, mesh=None, **kw):
        with use_mesh(mesh):
            return fn(*args, mesh=mesh, **kw)
    return wrapped


# ===========================================================================
def build_model(cfg: ModelConfig) -> Model:
    cfg.validate()
    if cfg.xlstm is not None:
        return Model(cfg, functools.partial(_init_xlstm, cfg),
                     _with_ctx(functools.partial(_xlstm_loss, cfg)),
                     _with_ctx(functools.partial(_xlstm_prefill, cfg)),
                     _with_ctx(functools.partial(_xlstm_decode, cfg)),
                     functools.partial(_xlstm_init_cache, cfg))
    if cfg.family in ("ssm", "hybrid"):
        return Model(cfg, functools.partial(_init_ssm, cfg),
                     _with_ctx(functools.partial(_ssm_loss, cfg)),
                     _with_ctx(functools.partial(_ssm_prefill, cfg)),
                     _with_ctx(functools.partial(_ssm_decode, cfg)),
                     functools.partial(_ssm_init_cache, cfg))
    if cfg.family == "encdec":
        return Model(cfg, functools.partial(_init_encdec, cfg),
                     _with_ctx(functools.partial(_encdec_loss, cfg)),
                     _with_ctx(functools.partial(_encdec_prefill, cfg)),
                     _with_ctx(functools.partial(_encdec_decode, cfg)),
                     functools.partial(_encdec_init_cache, cfg))
    return Model(cfg, functools.partial(_init_decoder, cfg),
                 _with_ctx(functools.partial(_decoder_loss, cfg)),
                 _with_ctx(functools.partial(_decoder_prefill, cfg)),
                 _with_ctx(functools.partial(_decoder_decode, cfg)),
                 functools.partial(_decoder_init_cache, cfg))
