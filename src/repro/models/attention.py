"""Attention: GQA (dense + chunked online-softmax), SWA, MLA, cross-attn.

Conventions:
* q (B, Sq, H, Dk), k (B, Sk, KH, Dk), v (B, Sk, KH, Dv); H = KH * G.
* training/prefill use ``attention_core`` (dense (S,S) scores or the chunked
  online-softmax path — the latter is mandatory for 32k+ prefill);
* decode keeps the KV cache sharded over the MODEL axis on the SEQUENCE
  dimension (flash-decoding style): every model shard scores its local KV
  slice and XLA combines the partial softmax via small cross-shard
  reductions — this is what lets 8-KV-head models run on 16-way model
  meshes and 512k contexts fit per device;
* sliding-window archs (h2o-danube) use a RING-BUFFER cache of window size
  so long_500k decode stores O(window), not O(seq).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import KeyGen, apply_rope, dense_init, zeros_init
from .config import ModelConfig
from .shard_ctx import constrain, constrain_cache

NEG_INF = -1e30
KV_SCALE = 24.0       # fixed symmetric int8 scale for quantised KV caches


def encode_kv(x, dtype):
    if dtype == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * KV_SCALE),
                        -127, 127).astype(jnp.int8)
    return x.astype(dtype)


def decode_kv(x):
    if x.dtype == jnp.int8:
        return (x.astype(jnp.float32) * (1.0 / KV_SCALE)).astype(jnp.bfloat16)
    return x


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------
def _mask(qpos, kpos, causal: bool, window: int):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), jnp.bool_)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        m &= qpos[:, None] - kpos[None, :] < window
    return m


def expand_kv(k, n_heads: int):
    """(B, S, KH, D) -> (B, S, H, D): repeat KV groups so every attention
    tensor carries a full H head dim that shards cleanly over `model`."""
    B, S, KH, D = k.shape
    if KH == n_heads:
        return k
    G = n_heads // KH
    k = jnp.broadcast_to(k[:, :, :, None], (B, S, KH, G, D))
    return k.reshape(B, S, n_heads, D)


def attention_dense(q, k, v, *, causal: bool, window: int, q0: int = 0, k0: int = 0,
                    scale: float | None = None):
    """Materialised-scores attention (q/k/v all (B, S, H, D))."""
    B, Sq, H, Dk = q.shape
    scale = scale if scale is not None else Dk ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    s = constrain(s, ("dp", "model", None, None))
    qpos = q0 + jnp.arange(Sq)
    kpos = k0 + jnp.arange(k.shape[1])
    m = _mask(qpos, kpos, causal, window)
    s = jnp.where(m[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return o


def _chunk_pairs(nq: int, nk: int, causal: bool, window: int, chunk: int):
    """Static (qi, kj) block list, SKIPPING fully-masked blocks.

    For causal masks this halves attention FLOPs vs the visit-everything
    grid (and for sliding windows keeps only ~window/chunk diagonals) —
    EXPERIMENTS.md §Perf iteration B.  Non-causal keeps the full grid.
    """
    pq, pk = [], []
    for qi in range(nq):
        for kj in range(nk):
            if causal and kj > qi:
                continue                       # strictly-future block
            if window > 0 and (qi - kj) * chunk >= window + chunk:
                continue                       # fully outside the window
            pq.append(qi)
            pk.append(kj)
    return pq, pk


def attention_chunked(q, k, v, *, causal: bool, window: int, chunk: int,
                      scale: float | None = None):
    """Online-softmax attention, O(chunk^2) live memory (flash-style).

    One flat scan over the STATIC list of non-masked (q-chunk, kv-chunk)
    block pairs; per-q-chunk running (max, sum, acc) statistics live in a
    carried (nq, ...) state updated at the block's q index.
    """
    B, Sq, H, Dk = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]
    scale = scale if scale is not None else Dk ** -0.5
    assert Sq % chunk == 0 and Sk % chunk == 0, (Sq, Sk, chunk)
    nq, nk = Sq // chunk, Sk // chunk
    qc = q.reshape(B, nq, chunk, H, Dk).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nk, chunk, H, Dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, chunk, H, Dv).transpose(1, 0, 2, 3, 4)
    pq, pk = _chunk_pairs(nq, nk, causal, window, chunk)

    m0 = jnp.full((nq, B, H, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, B, H, chunk), jnp.float32)
    a0 = constrain(jnp.zeros((nq, B, H, chunk, Dv), jnp.float32),
                   (None, "dp", "model", None, None))

    def step(carry, idx):
        m, l, acc = carry
        qi, kj = idx
        qblk = jax.lax.dynamic_index_in_dim(qc, qi, 0, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kc, kj, 0, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vc, kj, 0, keepdims=False)
        s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(jnp.float32) * scale
        s = constrain(s, ("dp", "model", None, None))
        qpos = qi * chunk + jnp.arange(chunk)
        kpos = kj * chunk + jnp.arange(chunk)
        msk = _mask(qpos, kpos, causal, window)
        s = jnp.where(msk[None, None], s, NEG_INF)
        m_q = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_q = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_q = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_q, s.max(axis=-1))
        # clamp: fully-masked rows keep m at NEG_INF and must not revive
        corr = jnp.exp(jnp.clip(m_q - m_new, -80.0, 0.0))
        p = jnp.exp(jnp.clip(s - m_new[..., None], -80.0, 0.0))
        p = jnp.where(msk[None, None], p, 0.0)
        l_new = l_q * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vblk.dtype), vblk)
        a_new = a_q * corr[..., None] + pv.astype(jnp.float32)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.asarray(pq, jnp.int32), jnp.asarray(pk, jnp.int32)))
    o = acc / jnp.maximum(l[..., None], 1e-30)      # (nq, B, H, chunk, Dv)
    o = o.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, Dv)
    return o.astype(v.dtype)


def attention_core(q, k, v, cfg: ModelConfig, *, causal: bool, window: int = 0,
                   scale: float | None = None):
    if cfg.attn_impl == "chunked" and q.shape[1] > cfg.attn_chunk:
        return attention_chunked(
            q, k, v, causal=causal, window=window, chunk=cfg.attn_chunk, scale=scale
        )
    return attention_dense(q, k, v, causal=causal, window=window, scale=scale)


def decode_attend(q, k_cache, v_cache, k_new, v_new, pos, *, window: int = 0,
                  scale: float | None = None):
    """Single-token attention: OLD cache (positions < pos) + the current
    token's fresh k/v appended explicitly.  The caller writes (k_new, v_new)
    into the cache AFTER the layer scan with ONE dynamic-update-slice — this
    keeps the donated cache buffer aliasable in-place instead of double-
    buffering a per-layer-updated copy through the scan (a 2x HBM saving on
    32k-context decode; EXPERIMENTS.md §Perf).

    q (B, H, Dk); caches (B, S, KH, D*); k_new/v_new (B, KH, D*); pos ()
    scalar or (B,) per-row positions (continuous batching).
    ``window > 0``: the cache is a ring buffer of size S == window; the
    absolute position of slot i is the latest p <= pos-ish with p % S == i.
    """
    B, S, KH, Dk = k_cache.shape
    H = q.shape[1]
    G = H // KH
    scale = scale if scale is not None else Dk ** -0.5
    qg = q.reshape(B, KH, G, Dk)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32) * scale
    # pin scores to the CACHE layout: otherwise XLA reshards the
    # fp32-converted cache through 8 GB of all-to-alls per decode step
    # (or fully replicates it for context-parallel B=1 caches) —
    # EXPERIMENTS.md §Perf iteration C
    s = constrain_cache(s, b_axis=0, s_axis=3)
    # pos may be () (all rows at one position) or (B,) (per-slot positions,
    # e.g. continuous batching with staggered arrivals)
    posv = jnp.atleast_1d(pos)[:, None]            # (B|1, 1)
    slot = jnp.arange(S)[None]                     # (1, S)
    if window > 0:
        kpos = slot + ((posv - slot) // S) * S
        valid = (kpos >= 0) & (kpos < posv) & (kpos > posv - window)
    else:
        valid = slot < posv                        # (B|1, S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    s_cur = jnp.einsum("bhgd,bhd->bhg", qg, k_new).astype(jnp.float32) * scale
    # partial softmax over the sharded S axis: combine via max/sum stats
    m_loc = jnp.maximum(s.max(axis=-1), s_cur)
    p = jnp.exp(s - m_loc[..., None])
    p_cur = jnp.exp(s_cur - m_loc)
    l = p.sum(axis=-1) + p_cur
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    o = (o + p_cur[..., None].astype(v_new.dtype) * v_new[:, :, None])
    o = o / l[..., None].astype(o.dtype)
    return o.reshape(B, H, v_cache.shape[-1])


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------
def init_gqa(kg: KeyGen, cfg: ModelConfig, L: int, dtype) -> dict:
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(kg(), (L, d, H * hd), dtype, fan_in=d),
        "wk": dense_init(kg(), (L, d, KH * hd), dtype, fan_in=d),
        "wv": dense_init(kg(), (L, d, KH * hd), dtype, fan_in=d),
        "wo": dense_init(kg(), (L, H * hd, d), dtype, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init(None, (L, H * hd), dtype)
        p["bk"] = zeros_init(None, (L, KH * hd), dtype)
        p["bv"] = zeros_init(None, (L, KH * hd), dtype)
    return p


def gqa_qkv(p, x, cfg: ModelConfig, cos, sin):
    B, S, _ = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KH, hd)
    v = v.reshape(B, S, KH, hd)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def gqa_forward(p, x, cfg: ModelConfig, cos, sin, *, causal: bool = True):
    q, k, v = gqa_qkv(p, x, cfg, cos, sin)
    q = constrain(q, ("dp", None, "model", None))
    k = constrain(expand_kv(k, cfg.n_heads), ("dp", None, "model", None))
    v = constrain(expand_kv(v, cfg.n_heads), ("dp", None, "model", None))
    o = attention_core(q, k, v, cfg, causal=causal, window=cfg.swa_window)
    B, S = x.shape[:2]
    return o.reshape(B, S, -1) @ p["wo"]


def gqa_decode(p, x, cache, pos, cfg: ModelConfig, cos, sin):
    """x (B, 1, d); cache {k, v} (B, S_cache, KH, hd); pos () or (B,).

    Returns (y, {k, v} NEW-TOKEN rows (B, 1, KH, hd)) — the caller performs
    the single post-scan cache write (see decode_attend docstring)."""
    B = x.shape[0]
    q, k, v = gqa_qkv(p, x, cfg, cos, sin)            # S = 1
    o = decode_attend(q[:, 0], decode_kv(cache["k"]), decode_kv(cache["v"]),
                      k[:, 0], v[:, 0], pos, window=cfg.swa_window)
    y = o.reshape(B, 1, -1) @ p["wo"]
    ct = cache["k"].dtype
    return y, {"k": encode_kv(k, ct), "v": encode_kv(v, ct)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention)
# ---------------------------------------------------------------------------
def init_mla(kg: KeyGen, cfg: ModelConfig, L: int, dtype) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.nope_head_dim + m.rope_head_dim
    return {
        "wq_a": dense_init(kg(), (L, d, m.q_lora_rank), dtype, fan_in=d),
        "q_norm": jnp.ones((L, m.q_lora_rank), dtype),
        "wq_b": dense_init(kg(), (L, m.q_lora_rank, H * qk), dtype, fan_in=m.q_lora_rank),
        "wkv_a": dense_init(kg(), (L, d, m.kv_lora_rank + m.rope_head_dim), dtype, fan_in=d),
        "kv_norm": jnp.ones((L, m.kv_lora_rank), dtype),
        "wkv_b": dense_init(
            kg(),
            (L, m.kv_lora_rank, H * (m.nope_head_dim + m.v_head_dim)),
            dtype,
            fan_in=m.kv_lora_rank,
        ),
        "wo": dense_init(kg(), (L, H * m.v_head_dim, d), dtype, fan_in=H * m.v_head_dim),
    }


def _mla_q(p, x, cfg, cos, sin):
    from .common import rmsnorm

    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rmsnorm(p["q_norm"], x @ p["wq_a"])
    q = (cq @ p["wq_b"]).reshape(B, S, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_forward(p, x, cfg: ModelConfig, cos, sin, *, causal: bool = True):
    """Prefill/training MLA: decompress K/V and run standard attention."""
    from .common import rmsnorm

    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, cos, sin)
    ckv = x @ p["wkv_a"]
    c_kv = rmsnorm(p["kv_norm"], ckv[..., : m.kv_lora_rank])
    k_rope = apply_rope(ckv[..., None, m.kv_lora_rank:], cos, sin)   # 1 shared head
    kv = (c_kv @ p["wkv_b"]).reshape(B, S, H, m.nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., : m.nope_head_dim], kv[..., m.nope_head_dim:]
    q = constrain(jnp.concatenate([q_nope, q_rope], axis=-1),
                  ("dp", None, "model", None))
    k = constrain(
        jnp.concatenate(
            [k_nope, jnp.broadcast_to(
                k_rope, k_nope.shape[:-1] + (m.rope_head_dim,))], axis=-1),
        ("dp", None, "model", None))
    v = constrain(v, ("dp", None, "model", None))
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    o = attention_core(q, k, v, cfg, causal=causal, scale=scale)
    return o.reshape(B, S, -1) @ p["wo"]


def mla_decode(p, x, cache, pos, cfg: ModelConfig, cos, sin):
    """Absorbed-form MLA decode: the cache stores the COMPRESSED latent
    (kv_lora_rank + rope_head_dim per token) — 8.6x smaller than GQA-128 —
    and W_UK/W_UV are folded into the score/output projections."""
    from .common import rmsnorm

    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, cos, sin)        # (B, 1, H, *)
    ckv = x @ p["wkv_a"]                                # (B, 1, rank+rope)
    c_kv = rmsnorm(p["kv_norm"], ckv[..., : m.kv_lora_rank])
    k_rope = apply_rope(ckv[..., None, m.kv_lora_rank:], cos, sin)[:, :, 0]
    w_uk = p["wkv_b"].reshape(
        m.kv_lora_rank, H, m.nope_head_dim + m.v_head_dim
    )
    w_k = w_uk[..., : m.nope_head_dim]                  # (rank, H, nope)
    w_v = w_uk[..., m.nope_head_dim:]                   # (rank, H, v)
    # absorb: q_eff = q_nope @ W_UK^T  -> score in latent space
    q_eff = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_k)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    s = (
        jnp.einsum("bhr,bsr->bhs", q_eff, cache["c"])
        + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], cache["r"])
    ).astype(jnp.float32) * scale
    S = cache["c"].shape[1]
    s = constrain_cache(s, b_axis=0, s_axis=2)   # follow the cache layout
    valid = jnp.arange(S)[None] < jnp.atleast_1d(pos)[:, None]   # (B|1, S)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    s_cur = (
        jnp.einsum("bhr,br->bh", q_eff, c_kv[:, 0])
        + jnp.einsum("bhd,bd->bh", q_rope[:, 0], k_rope[:, 0])
    ).astype(jnp.float32) * scale
    s_all = jnp.concatenate([s, s_cur[..., None]], axis=-1)
    pr = jax.nn.softmax(s_all, axis=-1).astype(cache["c"].dtype)
    # re-pin the probs to the cache layout: without it XLA all-gathers the
    # 32k-latent cache (32 GB/step measured) instead of psumming (B,H,rank)
    pr_s = constrain_cache(pr[..., :S], b_axis=0, s_axis=2)
    o_lat = jnp.einsum("bhs,bsr->bhr", pr_s, cache["c"])
    o_lat = o_lat + pr[..., S:] * c_kv                  # current-token term
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_v)          # (B, H, v)
    y = o.reshape(B, 1, -1) @ p["wo"]
    return y, {"c": c_kv, "r": k_rope}


# ---------------------------------------------------------------------------
# cross-attention (Whisper decoder)
# ---------------------------------------------------------------------------
def init_cross(kg: KeyGen, cfg: ModelConfig, L: int, dtype) -> dict:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": dense_init(kg(), (L, d, H * hd), dtype, fan_in=d),
        "wk": dense_init(kg(), (L, d, H * hd), dtype, fan_in=d),
        "wv": dense_init(kg(), (L, d, H * hd), dtype, fan_in=d),
        "wo": dense_init(kg(), (L, H * hd, d), dtype, fan_in=H * hd),
    }


def cross_forward(p, x, enc_kv, cfg: ModelConfig):
    """x (B, S, d) attends to precomputed encoder K/V (B, Se, H, hd)."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = constrain((x @ p["wq"]).reshape(B, S, H, hd), ("dp", None, "model", None))
    o = attention_core(q, enc_kv["k"], enc_kv["v"], cfg, causal=False)
    return o.reshape(B, S, -1) @ p["wo"]


def cross_kv(p, enc_out, cfg: ModelConfig):
    B, Se, _ = enc_out.shape
    H, hd = cfg.n_heads, cfg.head_dim
    return {
        "k": (enc_out @ p["wk"]).reshape(B, Se, H, hd),
        "v": (enc_out @ p["wv"]).reshape(B, Se, H, hd),
    }
