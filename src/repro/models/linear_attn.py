"""Chunked linear-attention / SSD machinery (shared by Mamba2 and mLSTM).

Recurrent semantics (per head):

    S_t = exp(log_f_t) * S_{t-1} + i_t * k_t v_t^T        (state: dk x dv)
    y_t = q_t . S_t

Training/prefill runs the CHUNKWISE form (Mamba-2 SSD): within a chunk of
length C the interaction is a masked (C, C) matmul (MXU-friendly), across
chunks a (dk, dv) state is carried by ``lax.scan`` — O(S*C) memory instead of
the O(S * dk * dv) of a naive associative scan over matrix states (which at
xLSTM's 192x192 heads would be gigabytes per layer).

log_f <= 0 always (forget gates are sigmoids / -dt*exp(A)), so every exp()
argument below is <= 0 and the computation is stable in fp32 without an
extra max-stabiliser.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .shard_ctx import constrain


def chunked_linear_attention(q, k, v, log_f, i_gate, *, chunk: int,
                             initial_state=None):
    """q/k (B,S,H,dk), v (B,S,H,dv), log_f/i_gate (B,S,H).

    Returns (y (B,S,H,dv), final_state (B,H,dk,dv)).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    qc = q.reshape(B, n, chunk, H, dk).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, n, chunk, H, dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, chunk, H, dv).transpose(1, 0, 2, 3, 4)
    ac = log_f.reshape(B, n, chunk, H).transpose(1, 0, 2, 3).astype(jnp.float32)
    ic = i_gate.reshape(B, n, chunk, H).transpose(1, 0, 2, 3).astype(jnp.float32)

    S0 = (
        constrain(jnp.zeros((B, H, dk, dv), jnp.float32),
                  ("dp", "model", None, None))
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))

    def step(state, inp):
        qb, kb, vb, ab, ib = inp                     # (B,C,H,*) / (B,C,H)
        A = jnp.cumsum(ab, axis=1)                   # inclusive cumulative log-decay
        A_last = A[:, -1]                            # (B,H)
        # inter-chunk: y_t += exp(A_t) q_t . S_prev
        y_inter = jnp.einsum(
            "bchd,bhdv->bchv", qb * jnp.exp(A)[..., None], state
        )
        # intra-chunk: masked decayed attention
        s = jnp.einsum("bchd,bjhd->bhcj", qb, kb).astype(jnp.float32)
        s = constrain(s, ("dp", "model", None, None))
        dec = jnp.exp(
            jnp.clip(A[:, :, None, :] - A[:, None, :, :], -80.0, 0.0)
        ).transpose(0, 3, 1, 2)                      # (B,H,C,C) exp(A_c - A_j)
        ig = ib.transpose(0, 2, 1)[:, :, None, :]    # (B,H,1,C)  i_j per column
        s = s * dec * ig
        s = jnp.where(tri[None, None], s, 0.0)
        y_intra = jnp.einsum("bhcj,bjhv->bchv", s.astype(vb.dtype), vb)
        # state update
        wk = ib * jnp.exp(jnp.clip(A_last[:, None, :] - A, -80.0, 0.0))
        S_new = state * jnp.exp(A_last)[..., None, None] + jnp.einsum(
            "bjhd,bjhv->bhdv", (kb * wk[..., None]).astype(jnp.float32),
            vb.astype(jnp.float32),
        )
        S_new = constrain(S_new, ("dp", "model", None, None))
        y = (y_inter.astype(jnp.float32) + y_intra.astype(jnp.float32))
        return S_new, y

    state, yc = jax.lax.scan(step, S0, (qc, kc, vc, ac, ic))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dv)
    return y.astype(v.dtype), state


def linear_attention_step(state, q, k, v, log_f, i_gate):
    """Single decode step.  state (B,H,dk,dv); q/k (B,H,dk); v (B,H,dv);
    log_f/i_gate (B,H).  Returns (y (B,H,dv), new_state)."""
    f = jnp.exp(log_f.astype(jnp.float32))[..., None, None]
    outer = jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32), v.astype(jnp.float32))
    new = state * f + i_gate.astype(jnp.float32)[..., None, None] * outer
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), new)
    return y.astype(v.dtype), new
