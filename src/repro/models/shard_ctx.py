"""Ambient sharding hints for model internals.

Model code is mesh-agnostic; the step entry points (loss / prefill /
decode_step) install the mesh here at TRACE time, and layers call
``constrain(x, dims)`` to pin activation shardings where XLA's propagation
is known to go wrong (attention score/accumulator tensors).  Every hint is
divisibility-guarded: a dim that does not divide by its axis size falls
back to replication, so any (arch x mesh) combination still lowers.

dims vocabulary:  "dp" (batch over pod+data), "model", None.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_TLS = threading.local()


def current_mesh():
    return getattr(_TLS, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh):
    prev = getattr(_TLS, "mesh", None)
    _TLS.mesh = mesh
    try:
        yield
    finally:
        _TLS.mesh = prev


def constrain(x, dims):
    """dims: tuple like ("dp", None, "model", None) matching x.ndim."""
    mesh = current_mesh()
    if mesh is None:
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    msz = mesh.shape["model"] if "model" in mesh.axis_names else 0
    spec = []
    for d, size in zip(dims, x.shape):
        if d == "dp" and dp_n > 1 and size % dp_n == 0:
            spec.append(dp)
        elif d == "model" and msz > 1 and size % msz == 0:
            spec.append("model")
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_cache(x, b_axis: int, s_axis: int):
    """KV-cache sharding: batch over DP + seq over model when divisible;
    tiny-batch (long-context) caches context-parallel the seq dim over ALL
    axes instead.  Mirrors launch.sharding.cache_spec."""
    mesh = current_mesh()
    if mesh is None:
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    msz = mesh.shape["model"] if "model" in mesh.axis_names else 0
    spec = [None] * x.ndim
    B, S = x.shape[b_axis], x.shape[s_axis]
    if dp_n > 1 and B % dp_n == 0:
        spec[b_axis] = dp
        if msz > 1 and S % msz == 0:
            spec[s_axis] = "model"
    elif msz > 1 and dp_n >= 1 and S % (dp_n * msz) == 0:
        spec[s_axis] = dp + ("model",)
    elif msz > 1 and S % msz == 0:
        spec[s_axis] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
