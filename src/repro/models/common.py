"""Shared model building blocks: norms, RoPE, embeddings, init, dtypes.

Everything is pure JAX (no flax): parameters are plain pytrees of
``jax.Array`` and every layer is a function ``(params, x, ...) -> y``.
Per-layer parameters are STACKED on a leading layer axis and consumed with
``jax.lax.scan`` so the lowered HLO is depth-independent (essential for
compiling 60-layer 236B-parameter graphs quickly in the dry-run).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # pytree of jax.Array


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16, "int8": jnp.int8}[name]


# ---------------------------------------------------------------------------
# initialisation
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / np.sqrt(max(fan, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_, shape, dtype):
    return jnp.ones(shape, dtype)


class KeyGen:
    """Deterministic fresh-key generator for building param trees."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(scale, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    v = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(v + eps)).astype(dt) * scale


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * p["scale"] + p["bias"]


def make_norm(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return jnp.ones((d,), dtype)
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(kind: str, p, x):
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_angles(positions, head_dim: int, theta: float):
    """(..., S) int positions -> cos/sin of shape (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D).  cos/sin: (..., S, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------
def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def cross_entropy(logits, labels, mask=None, vocab_chunk: int = 0):
    """Token-level CE in fp32.  logits (B, S, V), labels (B, S) int32.

    ``mask``: optional (B, S) 0/1 validity mask (pad tokens = 0).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def count_params(tree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))
