"""Model configuration covering all 10 assigned architecture families.

One dataclass parameterises: dense GQA transformers (w/ optional QKV bias,
sliding-window attention, tied embeddings), MLA (DeepSeek-V2), MoE (routed +
shared experts), Mamba2 hybrids (Zamba2), xLSTM, encoder-decoder (Whisper)
and VLM backbones (Phi-3-vision).  The per-arch files in ``repro/configs``
instantiate it with the exact assigned hyper-parameters.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
BlockKind = Literal["attn", "mamba2", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0               # shared (always-on) experts
    first_k_dense: int = 0          # leading dense layers (DeepSeek-V2: 1)
    d_ff_dense: int = 0             # ffn width of those dense layers
    router_noise: float = 0.0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001

    @property
    def n_experts_padded(self) -> int:
        """Expert count padded to a multiple of 16 so the expert dim shards
        evenly over any model-axis size we deploy (16-way TP per pod).
        Padded experts have zero weights and the router never emits them."""
        return -(-self.n_experts // 16) * 16


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64               # per-head SSD state size
    d_conv: int = 4                 # depthwise conv width
    expand: int = 2                 # inner dim = expand * d_model
    head_dim: int = 64
    chunk: int = 256                # chunked-scan block length
    # hybrid (Zamba2): one SHARED attention block applied every k SSM layers
    shared_attn_every: int = 0


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    mlstm_per_group: int = 3        # block pattern: N mLSTM then 1 sLSTM
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 4
    n_decoder_layers: int = 4
    # the conv/mel frontend is a STUB: input_specs() provides precomputed
    # frame embeddings (assignment: backbone only)
    max_source_len: int = 1500


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    # CLIP-style patch frontend is a STUB: input_specs() provides precomputed
    # patch embeddings which are prepended to the token embeddings
    n_patches: int = 576
    d_patch: int = 1024             # frontend embedding dim (projected to d)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                          # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    activation: Literal["silu", "gelu"] = "silu"
    rope_theta: float = 10000.0
    swa_window: int = 0                      # 0 = full attention
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    xlstm: XLSTMConfig | None = None
    encdec: EncDecConfig | None = None
    vlm: VLMConfig | None = None
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # "int8" halves KV-cache HBM (fixed-scale symmetric quantisation; a
    # production deployment calibrates per-head scales) — used by the
    # big-MHA decode cells where 32k x batch-128 caches run HBM out
    kv_cache_dtype: str = "bfloat16"
    # attention implementation: "dense" materialises (S, S) scores; "chunked"
    # scans KV blocks with an online softmax (required for 32k+ prefill)
    attn_impl: Literal["dense", "chunked"] = "dense"
    attn_chunk: int = 1024
    # sub-quadratic? (drives long_500k applicability)
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid") or self.swa_window > 0 or (
            self.xlstm is not None
        )

    @property
    def has_decoder(self) -> bool:
        return True                          # all assigned archs can decode

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.mla
        if self.family == "moe":
            assert self.moe is not None
        if self.family in ("ssm", "hybrid"):
            assert self.ssm is not None or self.xlstm is not None
        if self.family == "encdec":
            assert self.encdec is not None
        if self.family == "vlm":
            assert self.vlm is not None

    # ---- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; active_only counts routed experts
        only at top_k/n_experts utilisation (MoE roofline convention)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d                                  # embedding
        if not self.tie_embeddings:
            n += self.vocab * d                             # lm head
        if self.xlstm is not None:
            pf_m, pf_s = self.xlstm.proj_factor_mlstm, self.xlstm.proj_factor_slstm
            di_m = int(d * pf_m)                 # mLSTM: up/down + q,k,v,gates
            per_m = 2 * d * di_m + 4 * di_m * di_m
            di_s = int(d * pf_s)                 # sLSTM: 4 gates + ffn
            per_s = 4 * d * d + 2 * d * di_s
            g = self.xlstm.mlstm_per_group
            n_s = L // (g + 1)
            n_m = L - n_s
            return n + n_m * per_m + n_s * per_s
        if self.ssm is not None:
            di = self.ssm.expand * d
            per_ssm = d * (2 * di + 2 * self.n_heads * self.ssm.d_state) + di * d
            n_attn_shared = 0
            if self.ssm.shared_attn_every > 0:
                n_attn_shared = (
                    4 * d * d + 3 * d * self.d_ff
                )                                            # one shared block
            return n + L * per_ssm + n_attn_shared
        # attention params
        hd = self.head_dim
        if self.mla is not None:
            m = self.mla
            per_attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                + d * (m.kv_lora_rank + m.rope_head_dim)
                + m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        else:
            per_attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        # mlp params
        act_fac = 3 if self.activation == "silu" else 2     # swiglu vs gelu
        if self.moe is not None:
            mo = self.moe
            dense_layers = mo.first_k_dense
            moe_layers = L - dense_layers
            per_dense = act_fac * d * (mo.d_ff_dense or self.d_ff)
            n_routed = mo.n_experts if not active_only else mo.top_k
            per_moe = (
                act_fac * d * mo.d_ff_expert * (n_routed + mo.n_shared)
                + d * mo.n_experts                           # router
            )
            mlp = dense_layers * per_dense + moe_layers * per_moe
        else:
            mlp = L * act_fac * d * self.d_ff
        total = n + L * per_attn + mlp
        if self.encdec is not None:
            # decoder cross-attention adds one more attention block per layer
            total += self.encdec.n_decoder_layers * (
                4 * d * self.n_heads * hd
            )
        return int(total)

    def flops_per_token(self, seq_len: int, decode: bool = False) -> float:
        """MODEL_FLOPS/token: 6*N_active (+ attention window term)."""
        n_active = self.param_count(active_only=True) - (
            0 if self.tie_embeddings else self.vocab * self.d_model
        )
        f = 6.0 * n_active
        if self.family not in ("ssm",) and self.xlstm is None:
            win = seq_len if not self.swa_window else min(seq_len, self.swa_window)
            kv_len = win if not decode else win
            f += 12.0 * self.n_layers * self.head_dim * self.n_heads * (
                kv_len if not decode else kv_len
            ) * (0.5 if not decode else 1.0)
        return f


def scaled_init(fan_in: int) -> float:
    return 1.0 / math.sqrt(max(fan_in, 1))
