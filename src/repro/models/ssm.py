"""Mamba-2 (SSD) blocks and the Zamba2-style hybrid stack.

Mamba-2 block (simplified but FLOP/shape-faithful, n_groups = 1):
  in_proj packs [z (di) | x (di) | B (ds) | C (ds) | dt (H)];
  depthwise causal conv over the [x|B|C] channels; SSD recurrence via the
  shared chunked-linear-attention machinery (q=C, k=B, v=x-heads,
  log_f = -dt*exp(A_log), gain = dt); D skip; SiLU(z) gate; out_proj.

Zamba2 hybrid: ``n_layers`` Mamba-2 layers with ONE SHARED full attention
block (GQA + SwiGLU MLP, the same weights every time) applied after every
``shared_attn_every`` SSM layers — Zamba2's weight-shared attention.  The
stack is lowered as  outer-scan(groups) { inner-scan(mamba x k) ; shared
attn }  so HLO stays depth-independent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import gqa_decode, gqa_forward, init_gqa
from .common import KeyGen, apply_norm, dense_init, make_norm, rmsnorm
from .config import ModelConfig
from .linear_attn import chunked_linear_attention, linear_attention_step
from .shard_ctx import constrain
from .mlp import init_mlp, mlp_forward


def _mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = di // s.head_dim
    return di, H, s.d_state, s.d_conv


def init_mamba(kg: KeyGen, cfg: ModelConfig, L: int, dtype) -> dict:
    d = cfg.d_model
    di, H, ds, _ = _mamba_dims(cfg)
    proj_out = 2 * di + 2 * ds + H
    return {
        "in_proj": dense_init(kg(), (L, d, proj_out), dtype, fan_in=d),
        "conv_w": dense_init(kg(), (L, cfg.ssm.d_conv, di + 2 * ds), dtype,
                             fan_in=cfg.ssm.d_conv),
        "A_log": jnp.zeros((L, H), jnp.float32),
        "dt_bias": jnp.zeros((L, H), jnp.float32),
        "D": jnp.ones((L, H), jnp.float32),
        "gate_norm": jnp.ones((L, di), dtype),
        "out_proj": dense_init(kg(), (L, di, d), dtype, fan_in=di),
    }


def _causal_depthwise_conv(x, w):
    """x (B, S, C), w (K, C): causal depthwise conv along S."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i : i + x.shape[1]] * w[i]
    return out


def _mamba_gates(p, x, cfg: ModelConfig):
    di, H, ds, _ = _mamba_dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * ds]
    dt_pre = zxbcdt[..., 2 * di + 2 * ds :]
    return z, xbc, dt_pre


def mamba_forward(p, x, cfg: ModelConfig):
    """x (B, S, d) -> (B, S, d), full-sequence (training / prefill)."""
    B, S, _ = x.shape
    di, H, ds, _ = _mamba_dims(cfg)
    hd = cfg.ssm.head_dim
    z, xbc, dt_pre = _mamba_gates(p, x, cfg)
    xbc = jax.nn.silu(_causal_depthwise_conv(xbc, p["conv_w"]))
    xs = constrain(xbc[..., :di].reshape(B, S, H, hd),
                   ("dp", None, "model", None))
    Bt = xbc[..., di : di + ds]
    Ct = xbc[..., di + ds :]
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    log_f = -dt * jnp.exp(p["A_log"])
    k = jnp.broadcast_to(Bt[:, :, None, :], (B, S, H, ds))
    q = jnp.broadcast_to(Ct[:, :, None, :], (B, S, H, ds))
    y, _ = chunked_linear_attention(q, k, v=xs, log_f=log_f, i_gate=dt,
                                    chunk=cfg.ssm.chunk)
    y = y + xs * p["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, di)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"]


def mamba_step(p, x, state, cfg: ModelConfig):
    """Single decode step.  x (B, 1, d); state {ssm (B,H,ds,hd),
    conv (B, K-1, di+2ds)}."""
    B = x.shape[0]
    di, H, ds, K = _mamba_dims(cfg)
    hd = cfg.ssm.head_dim
    z, xbc, dt_pre = _mamba_gates(p, x, cfg)
    # conv ring: state holds the previous K-1 inputs
    hist = jnp.concatenate([state["conv"], xbc], axis=1)        # (B, K, C)
    xbc_c = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, p["conv_w"]))[:, None]
    new_conv = hist[:, 1:]
    xs = xbc_c[..., :di].reshape(B, H, hd)
    Bt = xbc_c[:, 0, di : di + ds]
    Ct = xbc_c[:, 0, di + ds :]
    dt = jax.nn.softplus(dt_pre[:, 0].astype(jnp.float32) + p["dt_bias"])   # (B,H)
    log_f = -dt * jnp.exp(p["A_log"])
    k = jnp.broadcast_to(Bt[:, None, :], (B, H, ds))
    q = jnp.broadcast_to(Ct[:, None, :], (B, H, ds))
    y, new_ssm = linear_attention_step(state["ssm"], q, k, xs, log_f, dt)
    y = y + xs * p["D"][None, :, None].astype(xs.dtype)
    y = y.reshape(B, 1, di)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"], {"ssm": new_ssm, "conv": new_conv}


# ---------------------------------------------------------------------------
# Zamba2 hybrid stack
# ---------------------------------------------------------------------------
def hybrid_layout(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, ssm_per_group, trailing_ssm)."""
    k = cfg.ssm.shared_attn_every
    if k <= 0:
        return 0, 0, cfg.n_layers
    g = cfg.n_layers // k
    return g, k, cfg.n_layers - g * k


def init_hybrid(kg: KeyGen, cfg: ModelConfig, dtype) -> dict:
    g, k, rest = hybrid_layout(cfg)
    p = {
        "mamba": init_mamba(kg, cfg, cfg.n_layers, dtype),
        "norm": jnp.ones((cfg.n_layers, cfg.d_model), dtype),
    }
    if g > 0:
        # ONE shared attention + MLP block (Zamba2 weight sharing)
        p["shared_attn"] = jax.tree.map(
            lambda x: x[0], init_gqa(kg, cfg, 1, dtype)
        )
        p["shared_mlp"] = jax.tree.map(
            lambda x: x[0], init_mlp(kg, cfg.d_model, cfg.d_ff, 1, dtype, "silu")
        )
        p["shared_norm1"] = jnp.ones((cfg.d_model,), dtype)
        p["shared_norm2"] = jnp.ones((cfg.d_model,), dtype)
    return p
