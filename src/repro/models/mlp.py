"""MLPs and Mixture-of-Experts.

MoE design (DeepSeek-V2 / Granite-MoE):
* token-choice top-k routing with a static per-expert CAPACITY
  (capacity_factor * T * k / E); overflow tokens are dropped (standard);
* EXPERT PARALLELISM via ``jax.shard_map`` manual over the ``model`` mesh
  axis only (data/pod axes stay auto): activations are replicated across
  ``model``, so each shard gathers the tokens routed to ITS experts locally,
  runs batched expert matmuls, scatters partial outputs and a single
  ``psum`` over ``model`` combines them — the same collective footprint as a
  Megatron TP MLP (one all-reduce), with zero all-to-alls;
* shared (always-on) experts are a plain dense MLP whose ff dim is sharded
  over ``model`` like any TP MLP.

The local dispatch is static-shaped: assignment ranks come from a one-hot
cumsum ((T*k, E_local) — tiny), token gathers from an (E_local, C) slot
table.  This is the TPU-idiomatic replacement for GPU scatter-atomics
(DESIGN.md §2 applies the same one-hot-matmul idea to the paper's CRM).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .common import KeyGen, act_fn, dense_init
from .config import ModelConfig

P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------
def init_mlp(kg: KeyGen, d: int, d_ff: int, L: int, dtype, activation: str) -> dict:
    p = {
        "wi": dense_init(kg(), (L, d, d_ff), dtype, fan_in=d),
        "wo": dense_init(kg(), (L, d_ff, d), dtype, fan_in=d_ff),
    }
    if activation == "silu":                      # SwiGLU gate
        p["wg"] = dense_init(kg(), (L, d, d_ff), dtype, fan_in=d)
    return p


def mlp_forward(p, x, activation: str):
    h = act_fn(activation)(x @ p["wi"])
    if "wg" in p:
        h = h * (x @ p["wg"])
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def init_moe(kg: KeyGen, cfg: ModelConfig, L: int, dtype) -> dict:
    mo = cfg.moe
    d = cfg.d_model
    Ep = mo.n_experts_padded        # pad so the expert dim shards over any TP
    p = {
        "router": dense_init(kg(), (L, d, mo.n_experts), jnp.float32, fan_in=d),
        "wi": dense_init(kg(), (L, Ep, d, mo.d_ff_expert), dtype, fan_in=d),
        "wg": dense_init(kg(), (L, Ep, d, mo.d_ff_expert), dtype, fan_in=d),
        "wo": dense_init(kg(), (L, Ep, mo.d_ff_expert, d), dtype,
                         fan_in=mo.d_ff_expert),
    }
    if mo.n_shared > 0:
        p["shared"] = init_mlp(kg, d, mo.n_shared * mo.d_ff_expert, L, dtype, "silu")
    return p


def _routed_local(x_flat, topk_idx, topk_w, wi, wg, wo, *, n_experts: int,
                  n_shards: int, shard_id, capacity: int):
    """Partial routed-expert output for the LOCAL expert slice.

    x_flat (T, d); topk_idx/topk_w (T, k); wi/wg/wo (E_local, ...).
    Returns (T, d) containing ONLY local experts' contributions.
    """
    T, d = x_flat.shape
    k = topk_idx.shape[1]
    e_local = n_experts // n_shards
    e0 = shard_id * e_local
    a_eid = topk_idx.reshape(-1)                       # (A,) A = T*k
    a_tok = jnp.repeat(jnp.arange(T), k)
    a_w = topk_w.reshape(-1)
    local = (a_eid >= e0) & (a_eid < e0 + e_local)
    eid_l = jnp.where(local, a_eid - e0, e_local)      # e_local = trash
    oh = eid_l[:, None] == jnp.arange(e_local)[None, :]
    rank = jnp.cumsum(oh, axis=0) - 1                  # (A, E_l)
    a_rank = (rank * oh).sum(-1)
    keep = local & (a_rank < capacity)
    slot_e = jnp.where(keep, eid_l, e_local)           # drop via OOB row
    slot_c = jnp.where(keep, a_rank, 0)
    tok_tab = jnp.full((e_local + 1, capacity), T, jnp.int32)
    tok_tab = tok_tab.at[slot_e, slot_c].set(a_tok.astype(jnp.int32), mode="drop")
    w_tab = jnp.zeros((e_local + 1, capacity), x_flat.dtype)
    w_tab = w_tab.at[slot_e, slot_c].set(a_w.astype(x_flat.dtype), mode="drop")
    tok_tab, w_tab = tok_tab[:e_local], w_tab[:e_local]
    valid = tok_tab < T
    xe = jnp.where(
        valid[..., None], x_flat[jnp.clip(tok_tab, 0, T - 1)], 0.0
    )                                                  # (E_l, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wi))
    h = h * jnp.einsum("ecd,edf->ecf", xe, wg)
    ye = jnp.einsum("ecf,efd->ecd", h, wo) * w_tab[..., None]
    # fp32 scatter-combine: bf16 scatter-add combiners get cloned into
    # all-reduce regions by SPMD and crash XLA:CPU's AllReducePromotion
    out = jnp.zeros((T + 1, d), jnp.float32)
    out = out.at[tok_tab].add(ye.astype(jnp.float32), mode="drop")
    return out[:T].astype(x_flat.dtype)


def moe_forward(p, x, cfg: ModelConfig, mesh=None, model_axis: str = "model"):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    mo = cfg.moe
    B, S, d = x.shape
    T = B * S
    x_flat = x.reshape(T, d)
    logits = (x_flat @ p["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, mo.top_k)
    topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = probs.mean(0)
    ce = jnp.zeros((mo.n_experts,), jnp.float32).at[topk_idx.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = mo.aux_loss_coef * mo.n_experts * jnp.sum(me * ce)

    n_shards = mesh.shape[model_axis] if mesh is not None else 1
    Ep = mo.n_experts_padded        # routing only ever hits the real experts

    dsz = mesh.shape.get("data", 1) if mesh is not None else 1
    ws_ok = (
        mesh is not None and n_shards > 1 and T <= 1024
        and "data" in getattr(mesh, "axis_names", ())
        and mo.d_ff_expert % dsz == 0
    )
    if mesh is None or n_shards == 1:
        capacity = max(8, int(T * mo.top_k * mo.capacity_factor / mo.n_experts))
        y = _routed_local(
            x_flat, topk_idx, topk_w, p["wi"], p["wg"], p["wo"],
            n_experts=Ep, n_shards=1, shard_id=0, capacity=capacity,
        )
    elif ws_ok:
        # WEIGHT-STATIONARY decode path: tokens are tiny, expert weights are
        # huge — replicate tokens, keep weights fully sharded (experts over
        # `model`, ff over `data`) and psum the (T, d) partial outputs over
        # both axes (2.6 MB for deepseek decode vs 0.6 GB/layer of expert
        # weight gathers under the token-sharded path).
        def ws_fn(xf, ti, tw, wi, wg, wo):
            capacity = max(
                8, int(xf.shape[0] * mo.top_k * mo.capacity_factor
                       / mo.n_experts))
            part = _routed_local(
                xf, ti, tw, wi, wg, wo,
                n_experts=Ep, n_shards=n_shards,
                shard_id=jax.lax.axis_index(model_axis), capacity=capacity,
            )
            return jax.lax.psum(
                part.astype(jnp.float32), (model_axis, "data")
            ).astype(xf.dtype)

        y = jax.shard_map(
            ws_fn,
            mesh=mesh,
            in_specs=(
                P(), P(), P(),
                P(model_axis, None, "data"),
                P(model_axis, None, "data"),
                P(model_axis, "data", None),
            ),
            out_specs=P(),
            check_vma=False,
        )(x_flat, topk_idx, topk_w, p["wi"], p["wg"], p["wo"])
    else:
        # FULLY-MANUAL shard_map: tokens local per DP shard, experts local
        # per model shard.  The dispatch scatters then never get partitioned
        # by SPMD (whose bf16 scatter combiners crash XLA:CPU), and the only
        # collective is ONE psum over `model` — a Megatron-TP-sized
        # all-reduce, zero all-to-alls.
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp_n = 1
        for a in dp:
            dp_n *= mesh.shape[a]
        tok_spec = P(dp) if T % dp_n == 0 else P()   # tiny-batch decode: repl.

        def shard_fn(xf, ti, tw, wi, wg, wo):
            t_local = xf.shape[0]
            capacity = max(
                8, int(t_local * mo.top_k * mo.capacity_factor / mo.n_experts)
            )
            part = _routed_local(
                xf, ti, tw, wi, wg, wo,
                n_experts=Ep, n_shards=n_shards,
                shard_id=jax.lax.axis_index(model_axis), capacity=capacity,
            )
            return jax.lax.psum(part.astype(jnp.float32), model_axis).astype(
                xf.dtype
            )

        y = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(
                tok_spec, tok_spec, tok_spec,
                P(model_axis), P(model_axis), P(model_axis),
            ),
            out_specs=tok_spec,
            check_vma=False,
        )(x_flat, topk_idx, topk_w, p["wi"], p["wg"], p["wo"])

    if mo.n_shared > 0:
        y = y + mlp_forward(p["shared"], x_flat, "silu")
    return y.reshape(B, S, d), aux
