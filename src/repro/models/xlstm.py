"""xLSTM blocks: mLSTM (matrix memory, parallelisable) and sLSTM (scalar
memory, sequential) — arXiv:2405.04517, simplified but shape/FLOP-faithful.

mLSTM: pre-norm, up-projection (factor 2) splits into x-branch and z-gate;
q/k/v heads over the inner dim; exponential-free gating (sigmoid forget +
sigmoid input, stable by construction) through the shared chunked
linear-attention machinery WITH a normaliser state (extra all-ones value
column); output h = num / max(|den|, 1), gated by SiLU(z), down-projected.

sLSTM: scalar cell/normaliser states per feature with recurrent gate
connections; inherently sequential -> lax.scan over time (the xLSTM paper
itself notes sLSTM is not parallelisable); followed by a small GELU FFN
(projection factor 4/3).

Block pattern: ``mlstm_per_group`` mLSTM blocks then 1 sLSTM block, repeated
(12 layers = 3 x (3 mLSTM + 1 sLSTM) for xlstm-125m).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import KeyGen, dense_init, rmsnorm
from .config import ModelConfig
from .linear_attn import chunked_linear_attention, linear_attention_step
from .shard_ctx import constrain


def _mlstm_dims(cfg: ModelConfig):
    di = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
    H = cfg.n_heads
    hd = di // H
    return di, H, hd


def init_mlstm(kg: KeyGen, cfg: ModelConfig, L: int, dtype) -> dict:
    d = cfg.d_model
    di, H, hd = _mlstm_dims(cfg)
    return {
        "norm": jnp.ones((L, d), dtype),
        "up": dense_init(kg(), (L, d, 2 * di), dtype, fan_in=d),
        "wq": dense_init(kg(), (L, di, di), dtype, fan_in=di),
        "wk": dense_init(kg(), (L, di, di), dtype, fan_in=di),
        "wv": dense_init(kg(), (L, di, di), dtype, fan_in=di),
        "w_if": dense_init(kg(), (L, di, 2 * H), dtype, fan_in=di),
        "out_norm": jnp.ones((L, di), dtype),
        "down": dense_init(kg(), (L, di, d), dtype, fan_in=di),
    }


def _mlstm_qkvg(p, x, cfg):
    B, S, _ = x.shape
    di, H, hd = _mlstm_dims(cfg)
    u = rmsnorm(p["norm"], x) @ p["up"]
    xb, z = u[..., :di], u[..., di:]
    q = constrain((xb @ p["wq"]).reshape(B, S, H, hd) * hd ** -0.5,
                  ("dp", None, "model", None))
    k = constrain((xb @ p["wk"]).reshape(B, S, H, hd) * hd ** -0.5,
                  ("dp", None, "model", None))
    v = constrain((xb @ p["wv"]).reshape(B, S, H, hd),
                  ("dp", None, "model", None))
    g = (xb @ p["w_if"]).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(g[..., :H])
    log_f = jax.nn.log_sigmoid(g[..., H:])
    return q, k, v, i_gate, log_f, z


def _mlstm_out(p, num_den, z, cfg):
    di, H, hd = _mlstm_dims(cfg)
    num, den = num_den[..., :hd], num_den[..., hd:]
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    B = h.shape[0]
    h = h.reshape(B, -1, di)
    h = rmsnorm(p["out_norm"], h) * jax.nn.silu(z)
    return h @ p["down"]


def mlstm_forward(p, x, cfg: ModelConfig):
    B, S, _ = x.shape
    q, k, v, i_gate, log_f, z = _mlstm_qkvg(p, x, cfg)
    v_aug = jnp.concatenate([v, jnp.ones_like(v)], axis=-1)  # normaliser col
    y, _ = chunked_linear_attention(q, k, v_aug, log_f, i_gate,
                                    chunk=cfg.xlstm.chunk)
    return x + _mlstm_out(p, y, z, cfg)


def mlstm_step(p, x, state, cfg: ModelConfig):
    """x (B,1,d); state (B,H,hd,2*hd)."""
    q, k, v, i_gate, log_f, z = _mlstm_qkvg(p, x, cfg)
    v_aug = jnp.concatenate([v, jnp.ones_like(v)], axis=-1)
    y, new_state = linear_attention_step(
        state, q[:, 0], k[:, 0], v_aug[:, 0], log_f[:, 0], i_gate[:, 0]
    )
    return x + _mlstm_out(p, y[:, None], z, cfg), new_state


def init_slstm(kg: KeyGen, cfg: ModelConfig, L: int, dtype) -> dict:
    d = cfg.d_model
    dff = int(d * cfg.xlstm.proj_factor_slstm)
    return {
        "norm": jnp.ones((L, d), dtype),
        "wx": dense_init(kg(), (L, d, 4 * d), dtype, fan_in=d),
        "wr": dense_init(kg(), (L, d, 4 * d), dtype, fan_in=d),
        "ffn_norm": jnp.ones((L, d), dtype),
        "ffn_wi": dense_init(kg(), (L, d, dff), dtype, fan_in=d),
        "ffn_wo": dense_init(kg(), (L, dff, d), dtype, fan_in=dff),
    }


def _slstm_cell(p, xt, carry):
    """xt (B, 4d) pre-activations from input; carry (h, c, n)."""
    h, c, n = carry
    d = h.shape[-1]
    g = (xt + h @ p["wr"]).astype(jnp.float32)
    z = jnp.tanh(g[..., :d])
    i = jax.nn.sigmoid(g[..., d : 2 * d])
    f = jax.nn.sigmoid(g[..., 2 * d : 3 * d])
    o = jax.nn.sigmoid(g[..., 3 * d :])
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = (o * c_new / jnp.maximum(n_new, 1.0)).astype(h.dtype)
    return h_new, c_new, n_new


def slstm_forward(p, x, cfg: ModelConfig):
    B, S, d = x.shape
    xs = rmsnorm(p["norm"], x) @ p["wx"]              # (B, S, 4d)
    h0 = jnp.zeros((B, d), x.dtype)
    c0 = jnp.zeros((B, d), jnp.float32)
    n0 = jnp.zeros((B, d), jnp.float32)

    def step(carry, xt):
        h, c, n = _slstm_cell(p, xt, carry)
        return (h, c, n), h

    _, hs = jax.lax.scan(step, (h0, c0, n0), xs.transpose(1, 0, 2))
    y = x + hs.transpose(1, 0, 2)
    h = jax.nn.gelu(rmsnorm(p["ffn_norm"], y) @ p["ffn_wi"])
    return y + h @ p["ffn_wo"]


def slstm_step(p, x, state, cfg: ModelConfig):
    """x (B,1,d); state (h, c, n) each (B, d)."""
    xt = (rmsnorm(p["norm"], x) @ p["wx"])[:, 0]
    h, c, n = _slstm_cell(p, xt, state)
    y = x + h[:, None]
    hh = jax.nn.gelu(rmsnorm(p["ffn_norm"], y) @ p["ffn_wi"])
    return y + hh @ p["ffn_wo"], (h, c, n)


def xlstm_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, mlstm_per_group); layers = groups * (m + 1)."""
    m = cfg.xlstm.mlstm_per_group
    g = cfg.n_layers // (m + 1)
    assert g * (m + 1) == cfg.n_layers, "n_layers must divide the block pattern"
    return g, m
