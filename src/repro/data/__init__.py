from .pipeline import PackedDataPipeline, ShardStore, TokenBatcher

__all__ = ["PackedDataPipeline", "ShardStore", "TokenBatcher"]
