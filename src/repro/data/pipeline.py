"""Input pipeline with an AKPC-managed per-host shard cache.

Framework integration of the paper (DESIGN.md §4): the training corpus is a
set of token SHARDS held by an authoritative store (the paper's "cloud
server"); every training host (the paper's ESS) caches shards it recently
consumed.  Mixture/curriculum sampling makes shards CO-ACCESSED (shards of
the same domain are drawn together within a mixture window), which is
exactly the structure AKPC mines: co-accessed shards become cliques, are
prefetched as packed bundles at discounted transfer cost, and whole-clique
TTL extension keeps hot domains resident.

The pipeline is deterministic (seeded), checkpointable (``state_dict`` /
``load_state_dict``) and reports the cache-cost telemetry per epoch so
training logs expose the AKPC savings (see examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.cost import CacheEnvironment, CostParams
from ..core.policy import get_policy
from ..core.session import CacheSession


class ShardStore:
    """Authoritative token store: ``n_shards`` shards of ``shard_tokens``
    synthetic tokens each, grouped into ``n_domains`` mixture domains.

    Every shard also has an ON-WIRE byte size (``shard_bytes``): shards
    compress differently, so the bytes actually transferred/rented vary per
    shard even at a fixed token count.  ``item_sizes()`` exposes them as
    mean-1 volumes for the size-aware cost models (PR 4) — the AKPC cache
    then prices shard fetches by real bytes instead of "1 unit per shard".
    """

    def __init__(self, n_shards: int = 256, shard_tokens: int = 4096,
                 vocab: int = 32000, n_domains: int = 8, seed: int = 0):
        self.n_shards = n_shards
        self.shard_tokens = shard_tokens
        self.vocab = vocab
        self.n_domains = n_domains
        self.seed = seed
        self.domain_of = np.arange(n_shards) % n_domains
        # simulated compression ratio in [0.35, 1.0] (domain-correlated:
        # same-domain shards share vocabulary statistics)
        rng = np.random.default_rng((seed, 0xB17E5))
        dom_ratio = rng.uniform(0.45, 0.9, n_domains)
        ratio = np.clip(
            dom_ratio[self.domain_of] + rng.normal(0.0, 0.05, n_shards),
            0.35, 1.0,
        )
        self.shard_bytes = (ratio * shard_tokens * 4).astype(np.int64)

    def item_sizes(self) -> np.ndarray:
        """(n_shards,) mean-1 volumes proportional to on-wire bytes."""
        b = self.shard_bytes.astype(np.float64)
        return b / b.mean()

    def read(self, shard_id: int) -> np.ndarray:
        """Deterministic synthetic shard: domain-dependent unigram mixture."""
        rng = np.random.default_rng((self.seed, int(shard_id)))
        dom = int(self.domain_of[shard_id])
        # each domain favours a band of the vocab (gives the LM something
        # learnable and makes domains distinguishable)
        lo = (dom * self.vocab) // (2 * self.n_domains)
        band = rng.integers(lo, lo + self.vocab // 4, self.shard_tokens)
        uni = rng.integers(0, self.vocab, self.shard_tokens)
        mix = rng.random(self.shard_tokens) < 0.8
        return np.where(mix, band, uni).astype(np.int32)


@dataclasses.dataclass
class PipelineTelemetry:
    akpc_total: float = 0.0
    nopack_total: float = 0.0
    shards_fetched: int = 0
    batches: int = 0

    @property
    def saving_pct(self) -> float:
        if self.nopack_total <= 0:
            return 0.0
        return 100.0 * (1.0 - self.akpc_total / self.nopack_total)


class PackedDataPipeline:
    """Yields token batches; shard fetches flow through an AKPC cache.

    Each global batch samples a mixture domain (Zipf) per microbatch row and
    draws shards from it — the co-access signal.  The shard requests of a
    window are replayed through AKPC (items=shards, server=this host) and,
    for comparison, through the No-Packing baseline; telemetry exposes both.
    """

    def __init__(self, store: ShardStore, *, batch_rows: int, seq_len: int,
                 host_id: int = 0, n_hosts: int = 1, seed: int = 0,
                 params: CostParams | None = None, t_cg: float = 64.0,
                 cost_model: str = "table1", backend: str = "session"):
        if backend not in ("session", "live"):
            raise ValueError(f"unknown pipeline cache backend {backend!r}")
        self.store = store
        self.backend = backend
        self.batch_rows = batch_rows
        self.seq_len = seq_len
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.seed = seed
        self.step = 0
        params = params or CostParams(alpha=0.5, rho=4.0)
        # shard byte-sizes are the environment's item sizes; the default
        # table1 model ignores them (unit accounting, telemetry unchanged),
        # cost_model="tiered"/"heterogeneous" prices fetches by real bytes
        env = CacheEnvironment(
            n=store.n_shards, m=n_hosts, params=params,
            item_sizes=store.item_sizes(),
        )
        def _make_session():
            policy = get_policy(
                "akpc", params=params, t_cg=t_cg, top_frac=1.0,
                cost_model=cost_model)
            if backend == "live":
                # device-resident shard cache (serving/live.py): per-step
                # feeds buffer into async device chunks; telemetry totals
                # settle at chunk granularity (exact after drain())
                from ..serving.live import LiveServingEngine

                return LiveServingEngine(
                    policy, store.n_shards, n_hosts, env=env)
            return CacheSession(policy, store.n_shards, n_hosts, env=env)

        self._make_session = _make_session
        self.cache = self._make_session()
        self.params = params
        self.env = env
        self.cost_model = cost_model
        self.telemetry = PipelineTelemetry()

    # -- determinism / checkpointing ---------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, state: dict) -> None:
        # replay-free resume: the sampler is a pure function of (seed, step)
        self.step = int(state["step"])
        # the cache session is an online stream and cannot rewind; crash
        # recovery restarts the cost accounting from the restore point
        if self.cache.now >= float(self.step):
            self.cache = self._make_session()

    # -- sampling ------------------------------------------------------------
    def _sample_shards(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step, self.host_id))
        n_dom = self.store.n_domains
        w = 1.0 / np.arange(1, n_dom + 1) ** 1.2
        w /= w.sum()
        doms = rng.choice(n_dom, size=self.batch_rows, p=w)
        shard_ids = np.empty(self.batch_rows, np.int64)
        for i, d in enumerate(doms):
            members = np.nonzero(self.store.domain_of == d)[0]
            shard_ids[i] = rng.choice(members)
        return shard_ids

    def _account(self, shard_ids: np.ndarray, t: float) -> None:
        uniq = np.unique(shard_ids)
        d_max = 8
        rows = [uniq[lo : lo + d_max] for lo in range(0, len(uniq), d_max)]
        items = np.full((len(rows), d_max), -1, np.int32)
        for r, g in enumerate(rows):
            items[r, : len(g)] = g
        self.cache.feed(
            items,
            np.full(len(rows), self.host_id, np.int64),
            np.full(len(rows), t, np.float64),
        )
        self.telemetry.shards_fetched += len(uniq)

    def __iter__(self):
        return self

    def __next__(self) -> np.ndarray:
        """(batch_rows, seq_len + 1) int32 — inputs are [:, :-1], labels [:, 1:]."""
        step = self.step
        self.step += 1
        shard_ids = self._sample_shards(step)
        self._account(shard_ids, float(step))
        rng = np.random.default_rng((self.seed, step, self.host_id, 1))
        out = np.empty((self.batch_rows, self.seq_len + 1), np.int32)
        for i, sid in enumerate(shard_ids):
            toks = self.store.read(int(sid))
            off = int(rng.integers(0, max(1, len(toks) - self.seq_len - 1)))
            out[i] = toks[off : off + self.seq_len + 1]
        self.telemetry.batches += 1
        self.telemetry.akpc_total = self.cache.costs.total
        return out


class TokenBatcher:
    """Shapes pipeline rows into the train-step batch pytree
    {tokens (accum, mb, S), labels (accum, mb, S)}."""

    def __init__(self, pipeline: PackedDataPipeline, accum: int, microbatch: int):
        self.pipeline = pipeline
        self.accum = accum
        self.microbatch = microbatch
        assert pipeline.batch_rows == accum * microbatch

    # restart rewinds the underlying pipeline (fault-tolerance contract)
    def state_dict(self) -> dict:
        return self.pipeline.state_dict()

    def load_state_dict(self, state: dict) -> None:
        self.pipeline.load_state_dict(state)

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        rows = next(self.pipeline)
        rows = rows.reshape(self.accum, self.microbatch, -1)
        return {
            "tokens": rows[..., :-1],
            "labels": rows[..., 1:],
        }
