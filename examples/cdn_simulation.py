"""Full paper-style CDN simulation: both traces, all methods, hyper-param
sensitivity mini-sweep — a compact reproduction of Figs. 5-7 on the unified
policy registry — plus two live-operations vignettes: mid-stream
checkpointing of an online AKPC session (snapshot -> restore -> identical
resume) and a HETEROGENEOUS deployment (per-server prices, real item sizes,
``cost_model="heterogeneous"``) where AKPC still beats per-item fetching —
and a LEARNED-policy vignette: train the keep/evict scorer on yesterday's
regime-shift trace, serve today's (fresh seed) through the ``learned``
registry policy, and beat the static baselines.

    PYTHONPATH=src python examples/cdn_simulation.py
"""
import numpy as np

from repro.core import CacheEnvironment, CacheSession, CostParams, \
    get_cost_model, get_policy, opt_lower_bound, run_policy
from repro.learned import train_policy
from repro.traces import SynthConfig, paper_trace, synth_trace


def _t_cg(env, cost_model="table1"):
    return 0.3 * float(get_cost_model(cost_model, env).dt().max())


def sweep():
    for kind in ("netflix", "spotify"):
        tr = paper_trace(kind, n_requests=40_000)
        print(f"\n=== {kind} ===")
        for alpha in (0.6, 0.8, 1.0):
            params = CostParams(alpha=alpha)
            t_cg = _t_cg(CacheEnvironment.from_trace(tr, params))
            kw = dict(params=params, t_cg=t_cg, top_frac=1.0)
            akpc = run_policy(get_policy("akpc", **kw), tr).total
            pc = run_policy(get_policy("packcache", **kw), tr).total
            nop = run_policy(get_policy("no_packing", params=params), tr).total
            opt = opt_lower_bound(tr, params).total
            print(f"alpha={alpha}: AKPC {akpc/opt:.2f}x  PackCache "
                  f"{pc/opt:.2f}x  NoPacking {nop/opt:.2f}x  (vs OPT=1)")


def live_checkpoint_vignette():
    """A CDN operator checkpoints the live cache state mid-stream and fails
    over to a standby that resumes bit-identically."""
    params = CostParams()
    tr = paper_trace("netflix", n_requests=20_000)
    t_cg = _t_cg(CacheEnvironment.from_trace(tr, params))
    mk = lambda: CacheSession(
        get_policy("akpc", params=params, t_cg=t_cg, top_frac=1.0), tr.n, tr.m)

    primary = mk()
    half = tr.n_requests // 2
    primary.feed(tr.items[:half], tr.servers[:half], tr.times[:half])
    snap = primary.snapshot()                  # -> repro.checkpoint-able pytree
    print(f"\ncheckpointed at t={primary.now:.2f}: "
          f"{primary.costs.n_requests} requests, total {primary.costs.total:.0f}")

    standby = mk().restore(snap)               # failover
    for sess in (primary, standby):
        sess.feed(tr.items[half:], tr.servers[half:], tr.times[half:])
    assert primary.costs.as_dict() == standby.costs.as_dict()
    assert np.array_equal(primary.engine.state.E, standby.engine.state.E)
    print(f"standby resumed bit-identically: total {standby.costs.total:.0f} ✓")


def heterogeneous_vignette():
    """A real fleet: edge servers with different bandwidth/storage contracts
    (lognormal lam_j/mu_j, so dt_j varies per server) serving items with
    real volumes — priced by the "heterogeneous" cost model."""
    params = CostParams()
    tr = synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=100, n_requests=20_000,
        t_max=72.0, bundle_cover=1.0, bundle_zipf=0.7, server_affinity=2,
        seed=0, size_dist="lognormal",
    ))
    skew = CacheEnvironment.skewed(tr.n, tr.m, params, price_sigma=1.0, seed=1)
    env = CacheEnvironment.from_trace(tr, params,
                                      lam_j=skew.lam_j, mu_j=skew.mu_j)
    t_cg = _t_cg(env, "heterogeneous")
    kw = dict(params=params, env=env, cost_model="heterogeneous")
    akpc = run_policy(get_policy("akpc", t_cg=t_cg, top_frac=1.0, **kw), tr)
    nop = run_policy(get_policy("no_packing", **kw), tr)
    print(f"\nheterogeneous fleet ({tr.m} servers, lognormal prices+sizes):")
    print(f"  AKPC {akpc.total:,.0f}  vs  NoPacking {nop.total:,.0f}  "
          f"-> {100 * (1 - akpc.total / nop.total):.1f}% saved "
          f"(model={akpc.costs.model})")


def learned_vignette():
    """Traffic shifts regime overnight (catalog launch): hindsight-train
    the learned keep/evict scorer on yesterday's trace, serve today's."""
    params = CostParams(rho=4.0)     # expensive prepaid rent: keep/evict bites
    mk = lambda seed: synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=12, n_requests=6_000,
        t_max=600.0, bundle_cover=1.0, bundle_zipf=0.7, server_affinity=2,
        load_profile="regime_shift", load_strength=0.25, load_peak=0.4,
        seed=seed,
    ))
    yesterday, today = mk(200), mk(101)
    # span-scaled window (as in fig11): 0.3*dt is too short to observe
    # co-access on this trace, and a scorer trained on tiny windows
    # degenerates to keep-nothing
    env = CacheEnvironment.from_trace(yesterday, params)
    span = float(yesterday.times[-1] - yesterday.times[0])
    t_cg = min(max(_t_cg(env), span / 50.0), span / 4.0)
    lp = train_policy(yesterday, t_cg=t_cg, params=params)
    totals = {
        name: run_policy(get_policy(name, params=params, **kw), today).total
        for name, kw in (
            ("no_packing", {}),
            ("ttl", dict(t_cg=t_cg)),
            ("learned", dict(t_cg=t_cg, learned=lp)),
        )
    }
    print("\nregime-shift day, trained on yesterday's trace:")
    for name, tot in sorted(totals.items(), key=lambda kv: kv[1]):
        print(f"  {name:10s} {tot:10,.0f}")
    print(f"  -> learned saves "
          f"{100 * (1 - totals['learned'] / totals['no_packing']):.1f}% "
          f"vs no_packing")


def main():
    sweep()
    live_checkpoint_vignette()
    heterogeneous_vignette()
    learned_vignette()


if __name__ == "__main__":
    main()
