"""Full paper-style CDN simulation: both traces, all methods, hyper-param
sensitivity mini-sweep — a compact reproduction of Figs. 5-7.

    PYTHONPATH=src python examples/cdn_simulation.py
"""
from repro.core import AKPCConfig, CostParams, opt_lower_bound, run_akpc, \
    run_no_packing, run_packcache2
from repro.traces import paper_trace


def main():
    for kind in ("netflix", "spotify"):
        tr = paper_trace(kind, n_requests=40_000)
        print(f"\n=== {kind} ===")
        for alpha in (0.6, 0.8, 1.0):
            params = CostParams(alpha=alpha)
            t_cg = 0.3 * params.dt
            akpc = run_akpc(tr, AKPCConfig(params=params, t_cg=t_cg,
                                           top_frac=1.0)).costs.total
            pc = run_packcache2(tr, params, t_cg=t_cg, top_frac=1.0).total
            nop = run_no_packing(tr, params).total
            opt = opt_lower_bound(tr, params).total
            print(f"alpha={alpha}: AKPC {akpc/opt:.2f}x  PackCache "
                  f"{pc/opt:.2f}x  NoPacking {nop/opt:.2f}x  (vs OPT=1)")


if __name__ == "__main__":
    main()
