"""Full paper-style CDN simulation: both traces, all methods, hyper-param
sensitivity mini-sweep — a compact reproduction of Figs. 5-7 on the unified
policy registry — plus a live-operations vignette: mid-stream checkpointing
of an online AKPC session (snapshot -> restore -> identical resume).

    PYTHONPATH=src python examples/cdn_simulation.py
"""
import numpy as np

from repro.core import CacheSession, CostParams, get_policy, opt_lower_bound, \
    run_policy
from repro.traces import paper_trace


def sweep():
    for kind in ("netflix", "spotify"):
        tr = paper_trace(kind, n_requests=40_000)
        print(f"\n=== {kind} ===")
        for alpha in (0.6, 0.8, 1.0):
            params = CostParams(alpha=alpha)
            t_cg = 0.3 * params.dt
            kw = dict(params=params, t_cg=t_cg, top_frac=1.0)
            akpc = run_policy(get_policy("akpc", **kw), tr).total
            pc = run_policy(get_policy("packcache", **kw), tr).total
            nop = run_policy(get_policy("no_packing", params=params), tr).total
            opt = opt_lower_bound(tr, params).total
            print(f"alpha={alpha}: AKPC {akpc/opt:.2f}x  PackCache "
                  f"{pc/opt:.2f}x  NoPacking {nop/opt:.2f}x  (vs OPT=1)")


def live_checkpoint_vignette():
    """A CDN operator checkpoints the live cache state mid-stream and fails
    over to a standby that resumes bit-identically."""
    params = CostParams()
    tr = paper_trace("netflix", n_requests=20_000)
    t_cg = 0.3 * params.dt
    mk = lambda: CacheSession(
        get_policy("akpc", params=params, t_cg=t_cg, top_frac=1.0), tr.n, tr.m)

    primary = mk()
    half = tr.n_requests // 2
    primary.feed(tr.items[:half], tr.servers[:half], tr.times[:half])
    snap = primary.snapshot()                  # -> repro.checkpoint-able pytree
    print(f"\ncheckpointed at t={primary.now:.2f}: "
          f"{primary.costs.n_requests} requests, total {primary.costs.total:.0f}")

    standby = mk().restore(snap)               # failover
    for sess in (primary, standby):
        sess.feed(tr.items[half:], tr.servers[half:], tr.times[half:])
    assert primary.costs.as_dict() == standby.costs.as_dict()
    assert np.array_equal(primary.engine.state.E, standby.engine.state.E)
    print(f"standby resumed bit-identically: total {standby.costs.total:.0f} ✓")


def main():
    sweep()
    live_checkpoint_vignette()


if __name__ == "__main__":
    main()
