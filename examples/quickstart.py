"""Quickstart: AKPC vs every baseline on a synthetic Netflix-like trace,
through the unified policy registry, plus the same AKPC run driven ONLINE
through the streaming CacheSession (mid-stream costs, no full trace needed).

    PYTHONPATH=src python examples/quickstart.py [--requests 50000]
"""
import argparse

import numpy as np

from repro.core import (
    CacheEnvironment, CacheSession, CostParams, get_cost_model, get_policy,
    opt_lower_bound, run_policy,
)
from repro.traces import paper_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=50_000)
    ap.add_argument("--kind", default="netflix", choices=["netflix", "spotify"])
    args = ap.parse_args()

    params = CostParams()                      # paper Table II
    tr = paper_trace(args.kind, n_requests=args.requests)
    print(f"trace: {tr.name}  {tr.n_requests} requests, "
          f"{tr.n} items, {tr.m} servers")

    # the pricing scenario, from the cost-model registry (no CostParams
    # formula internals): the paper's Table-I regime is the "table1" model
    env = CacheEnvironment.from_trace(tr, params)
    model = get_cost_model("table1", env)
    t_cg = 0.3 * float(model.dt().max())
    runs = [
        ("No Packing", "no_packing", {}),
        ("DP_Greedy (offline 2-pack)", "dp_greedy", dict(top_frac=1.0)),
        ("PackCache (online 2-pack)", "packcache", dict(t_cg=t_cg, top_frac=1.0)),
        ("AKPC w/o CS, w/o ACM", "akpc_base", dict(t_cg=t_cg, top_frac=1.0)),
        ("AKPC (proposed)", "akpc", dict(t_cg=t_cg, top_frac=1.0)),
    ]
    rows = {
        label: run_policy(get_policy(name, params=params, **kw), tr).costs
        for label, name, kw in runs
    }
    rows["OPT (lower bound)"] = opt_lower_bound(tr, params)
    opt = rows["OPT (lower bound)"].total
    print(f"\n{'method':<28s} {'C_T':>10s} {'C_P':>10s} {'total':>10s} {'vs OPT':>7s}")
    for name, c in rows.items():
        print(f"{name:<28s} {c.transfer:>10.0f} {c.caching:>10.0f} "
              f"{c.total:>10.0f} {c.total / opt:>7.3f}")
    akpc = rows["AKPC (proposed)"].total
    pc = rows["PackCache (online 2-pack)"].total
    print(f"\nAKPC saves {100 * (1 - akpc / pc):.1f}% vs the best prior "
          f"online method (PackCache).")

    # -- the same AKPC, but ONLINE: stream chunks, read costs mid-flight ----
    sess = CacheSession(
        get_policy("akpc", params=params, t_cg=t_cg, top_frac=1.0), tr.n, tr.m)
    print("\nstreaming the trace through CacheSession (chunks of 1000):")
    quarter = max(1, tr.n_requests // 4)
    for s in range(0, tr.n_requests, 1000):
        costs = sess.feed(tr.items[s:s + 1000], tr.servers[s:s + 1000],
                          tr.times[s:s + 1000])
        if (s // 1000) % (quarter // 1000 + 1) == 0:
            print(f"  t={sess.now:8.2f}  {costs.n_requests:>7d} requests  "
                  f"running total {costs.total:>10.0f}")
    assert np.isclose(sess.costs.total, akpc, rtol=1e-9), "stream != offline"
    print(f"  final streaming total {sess.costs.total:.0f} == offline AKPC ✓")


if __name__ == "__main__":
    main()
