"""Quickstart: AKPC vs every baseline on a synthetic Netflix-like trace.

    PYTHONPATH=src python examples/quickstart.py [--requests 50000]
"""
import argparse

from repro.core import (
    AKPCConfig, CostParams, opt_lower_bound, run_akpc, run_akpc_variant,
    run_dp_greedy, run_no_packing, run_packcache2,
)
from repro.traces import paper_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=50_000)
    ap.add_argument("--kind", default="netflix", choices=["netflix", "spotify"])
    args = ap.parse_args()

    params = CostParams()                      # paper Table II
    tr = paper_trace(args.kind, n_requests=args.requests)
    print(f"trace: {tr.name}  {tr.n_requests} requests, "
          f"{tr.n} items, {tr.m} servers")

    t_cg = 0.3 * params.dt
    rows = {
        "No Packing": run_no_packing(tr, params),
        "DP_Greedy (offline 2-pack)": run_dp_greedy(tr, params, top_frac=1.0),
        "PackCache (online 2-pack)": run_packcache2(tr, params, t_cg=t_cg,
                                                    top_frac=1.0),
        "AKPC w/o CS, w/o ACM": run_akpc_variant(
            tr, params, split=False, approx_merge=False, t_cg=t_cg,
            top_frac=1.0).costs,
        "AKPC (proposed)": run_akpc(tr, AKPCConfig(
            params=params, t_cg=t_cg, top_frac=1.0)).costs,
        "OPT (lower bound)": opt_lower_bound(tr, params),
    }
    opt = rows["OPT (lower bound)"].total
    print(f"\n{'method':<28s} {'C_T':>10s} {'C_P':>10s} {'total':>10s} {'vs OPT':>7s}")
    for name, c in rows.items():
        print(f"{name:<28s} {c.transfer:>10.0f} {c.caching:>10.0f} "
              f"{c.total:>10.0f} {c.total / opt:>7.3f}")
    akpc = rows["AKPC (proposed)"].total
    pc = rows["PackCache (online 2-pack)"].total
    print(f"\nAKPC saves {100 * (1 - akpc / pc):.1f}% vs the best prior "
          f"online method (PackCache).")


if __name__ == "__main__":
    main()
