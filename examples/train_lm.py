"""End-to-end driver: train an LM with the AKPC-cached data pipeline,
fault-tolerant loop, checkpointing and straggler accounting.

Default is a ~5M-param model for CPU speed; --width/--layers/--steps scale
it up (the 100M-class run: --width 512 --layers 12 --steps 300).

    PYTHONPATH=src python examples/train_lm.py --steps 120
"""
import argparse
import os
import tempfile

import jax

from repro.data import PackedDataPipeline, ShardStore, TokenBatcher
from repro.distributed import FailureInjector, StragglerPolicy, TrainController
from repro.launch.train import make_train_step
from repro.models.api import build_model
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--inject-failure", type=int, default=-1)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="train-lm", family="dense", n_layers=args.layers,
        d_model=args.width, n_heads=max(2, args.width // 32),
        n_kv_heads=max(2, args.width // 64), d_ff=args.width * 4,
        vocab=args.vocab, tie_embeddings=True)
    model = build_model(cfg)
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    store = ShardStore(n_shards=128, shard_tokens=args.seq * 16,
                       vocab=args.vocab, n_domains=8)
    pipe = PackedDataPipeline(store, batch_rows=8, seq_len=args.seq)
    batcher = TokenBatcher(pipe, accum=2, microbatch=4)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    train_step = jax.jit(make_train_step(model, opt_cfg))

    def init_state():
        p = model.init(jax.random.PRNGKey(0))
        return p, adamw_init(p)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    injector = FailureInjector(
        at_steps=(args.inject_failure,) if args.inject_failure > 0 else ())
    ctl = TrainController(train_step, init_state, batcher, ckpt_dir,
                          ckpt_every=25, injector=injector,
                          straggler=StragglerPolicy(mode="backup"))
    ctl.run(total_steps=args.steps)

    losses = [h["loss"] for h in ctl.history]
    k = max(1, len(losses) // 10)
    print(f"loss: first10 {sum(losses[:k])/k:.3f} -> last10 "
          f"{sum(losses[-k:])/k:.3f}  (restarts: {ctl.restarts})")
    tl = pipe.telemetry
    print(f"data-cache telemetry: {tl.batches} batches, "
          f"{tl.shards_fetched} shard requests, AKPC cache cost "
          f"{tl.akpc_total:.1f}")
    assert losses[-1] < losses[0], "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
