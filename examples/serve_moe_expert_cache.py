"""Serve a (reduced) MoE model with batched requests; the AKPC expert cache
observes routing outcomes, packs co-activated experts into cliques and
reports the transfer-cost saving vs per-expert fetching.

    PYTHONPATH=src python examples/serve_moe_expert_cache.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.api import build_model
from repro.serving import BatchedServer, ExpertCacheManager, Request


def main():
    cfg = get_smoke_config("granite_moe_3b_a800m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mgr = ExpertCacheManager(n_experts=cfg.moe.n_experts, n_hosts=2, t_cg=24.0)

    # routing tap: recompute the router's top-k for the served tokens
    router0 = np.asarray(params["layers"]["mlp"]["router"][0], np.float32)
    embed = np.asarray(params["embed"], np.float32)

    def tap(p, tokens):
        x = embed[tokens[:, 0]]
        logits = x @ router0
        topk = np.argsort(-logits, axis=-1)[:, : cfg.moe.top_k]
        mgr.observe(topk, host=0)

    srv = BatchedServer(model, params, batch_size=4, cache_len=64,
                        routing_tap=tap)
    rng = np.random.default_rng(0)
    for rid in range(24):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(2, 6)).tolist()
        srv.submit(Request(rid=rid, prompt=prompt, max_new=8))
    done = srv.run(max_steps=500)
    print(f"served {len(done)} requests in {srv.steps} decode steps")

    stats = mgr.stats()
    print(f"expert-cache: {stats.n_observations} routing observations, "
          f"{len(stats.cliques)} expert cliques: {stats.cliques[:6]}")
    print(f"AKPC packed-expert cost {stats.akpc_total:.1f} vs per-expert "
          f"{stats.nopack_total:.1f}  ->  {stats.saving_pct:.1f}% saved")

    # pack the expert weights per clique for single-DMA gathers
    wi0 = np.asarray(params["layers"]["mlp"]["wi"][0], np.float32)
    table, where = mgr.packed_tables(wi0.reshape(wi0.shape[0], -1))
    print(f"packed table: {table.shape} (cliques x omega x flattened expert)")


if __name__ == "__main__":
    main()
