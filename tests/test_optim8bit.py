"""8-bit AdamW: quantisation roundtrip + convergence tracks exact AdamW."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw8bit import adamw8bit_init, adamw8bit_update, dequantise, quantise


def test_quantise_roundtrip():
    rng = np.random.default_rng(0)
    for shape in [(7,), (3, 130), (2, 5, 128)]:
        x = jnp.array(rng.normal(size=shape), jnp.float32)
        q, s = quantise(x)
        back = dequantise(q, s, x.shape)
        err = np.abs(np.asarray(back - x))
        tol = np.abs(np.asarray(x)).max() / 100.0
        assert err.max() <= tol


def test_tracks_exact_adamw():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, clip_norm=1e9,
                      warmup_steps=0, total_steps=10**9, min_lr_frac=1.0)
    rng = np.random.default_rng(1)
    target = jnp.array(rng.normal(size=(4, 256)), jnp.float32)
    p_exact = {"w": jnp.zeros((4, 256), jnp.float32)}
    p_q = {"w": jnp.zeros((4, 256), jnp.float32)}
    s_exact = adamw_init(p_exact)
    s_q = adamw8bit_init(p_q)

    def grad(p):
        return {"w": 2.0 * (p["w"] - target)}

    for _ in range(60):
        p_exact, s_exact, _ = adamw_update(cfg, grad(p_exact), s_exact, p_exact)
        p_q, s_q, _ = adamw8bit_update(cfg, grad(p_q), s_q, p_q)
    loss_exact = float(jnp.mean((p_exact["w"] - target) ** 2))
    loss_q = float(jnp.mean((p_q["w"] - target) ** 2))
    assert loss_q < 2.0 * loss_exact + 1e-3     # converges comparably
    assert loss_q < 0.05                         # and actually converges
