"""Synthetic trace generator invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.traces import SynthConfig, iter_batches, iter_windows, synth_trace


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 1000), st.sampled_from(["netflix", "spotify"]))
def test_trace_invariants(seed, kind):
    tr = synth_trace(SynthConfig(kind=kind, n_items=60, n_servers=10,
                                 n_requests=2000, t_max=20.0, seed=seed))
    assert tr.n_requests == 2000
    assert (np.diff(tr.times) >= 0).all()
    assert tr.servers.min() >= 0 and tr.servers.max() < 10
    it = tr.items[tr.items >= 0]
    assert it.min() >= 0 and it.max() < 60
    sizes = tr.request_sizes()
    assert sizes.min() >= 1 and sizes.max() <= 5
    # set semantics: no duplicate items within a request
    for row in tr.items[:50]:
        v = row[row >= 0]
        assert len(np.unique(v)) == len(v)


def test_windows_and_batches_cover():
    tr = synth_trace(SynthConfig(n_items=30, n_servers=5, n_requests=500,
                                 t_max=10.0, seed=1))
    n = sum(w.n_requests for _, w in iter_windows(tr, 2.0))
    assert n == tr.n_requests
    n = sum(b.n_requests for b in iter_batches(tr, 64))
    assert n == tr.n_requests


def test_determinism():
    a = synth_trace(SynthConfig(seed=3, n_requests=1000, t_max=10.0))
    b = synth_trace(SynthConfig(seed=3, n_requests=1000, t_max=10.0))
    np.testing.assert_array_equal(a.items, b.items)
    np.testing.assert_array_equal(a.times, b.times)
