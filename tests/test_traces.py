"""Synthetic trace generator invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.traces import SynthConfig, iter_batches, iter_windows, synth_trace


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 1000), st.sampled_from(["netflix", "spotify"]))
def test_trace_invariants(seed, kind):
    tr = synth_trace(SynthConfig(kind=kind, n_items=60, n_servers=10,
                                 n_requests=2000, t_max=20.0, seed=seed))
    assert tr.n_requests == 2000
    assert (np.diff(tr.times) >= 0).all()
    assert tr.servers.min() >= 0 and tr.servers.max() < 10
    it = tr.items[tr.items >= 0]
    assert it.min() >= 0 and it.max() < 60
    sizes = tr.request_sizes()
    assert sizes.min() >= 1 and sizes.max() <= 5
    # set semantics: no duplicate items within a request
    for row in tr.items[:50]:
        v = row[row >= 0]
        assert len(np.unique(v)) == len(v)


def test_windows_and_batches_cover():
    tr = synth_trace(SynthConfig(n_items=30, n_servers=5, n_requests=500,
                                 t_max=10.0, seed=1))
    n = sum(w.n_requests for _, w in iter_windows(tr, 2.0))
    assert n == tr.n_requests
    n = sum(b.n_requests for b in iter_batches(tr, 64))
    assert n == tr.n_requests


def test_determinism():
    a = synth_trace(SynthConfig(seed=3, n_requests=1000, t_max=10.0))
    b = synth_trace(SynthConfig(seed=3, n_requests=1000, t_max=10.0))
    np.testing.assert_array_equal(a.items, b.items)
    np.testing.assert_array_equal(a.times, b.times)


# ---------------------------------------------------------------------------
# non-stationary load profiles (PR 7): arrival-time warping
# ---------------------------------------------------------------------------
def _cfg(profile, **kw):
    kw.setdefault("n_items", 60)
    kw.setdefault("n_servers", 10)
    kw.setdefault("n_requests", 4000)
    kw.setdefault("t_max", 20.0)
    kw.setdefault("seed", 3)
    return SynthConfig(load_profile=profile, **kw)


def test_load_profiles_deterministic_and_valid():
    for profile in ("diurnal", "flash_crowd", "regime_shift"):
        a = synth_trace(_cfg(profile))
        b = synth_trace(_cfg(profile))
        np.testing.assert_array_equal(a.items, b.items)
        np.testing.assert_array_equal(a.times, b.times)
        assert (np.diff(a.times) >= 0).all()
        assert a.times.min() >= 0.0 and a.times.max() <= 20.0


def test_load_profiles_warp_times_not_content():
    """The same uniform draws are warped through the rate profile's
    inverse CDF: request CONTENT is identical across profiles at a fixed
    seed — only the arrival-time distribution shifts."""
    base = synth_trace(_cfg("stationary"))
    for profile in ("diurnal", "flash_crowd", "regime_shift"):
        tr = synth_trace(_cfg(profile))
        assert tr.n_requests == base.n_requests
        np.testing.assert_array_equal(
            np.sort(tr.items[tr.items >= 0]),
            np.sort(base.items[base.items >= 0]))
        np.testing.assert_array_equal(
            np.sort(tr.servers), np.sort(base.servers))
        assert not np.array_equal(tr.times, base.times)


def test_stationary_profile_bitwise_legacy():
    """The default profile keeps the pre-PR-7 draw sequence untouched."""
    legacy = synth_trace(SynthConfig(seed=3, n_requests=1000, t_max=10.0))
    explicit = synth_trace(SynthConfig(seed=3, n_requests=1000, t_max=10.0,
                                       load_profile="stationary"))
    np.testing.assert_array_equal(legacy.items, explicit.items)
    np.testing.assert_array_equal(legacy.times, explicit.times)


def test_flash_crowd_concentrates_arrivals():
    cfg = _cfg("flash_crowd", load_strength=4.0, load_peak=0.5,
               load_width=0.05)
    tr = synth_trace(cfg)
    base = synth_trace(_cfg("stationary"))
    window = (tr.times > 0.4 * cfg.t_max) & (tr.times < 0.6 * cfg.t_max)
    window_base = (base.times > 0.4 * cfg.t_max) & (base.times < 0.6 * cfg.t_max)
    assert window.mean() > 1.5 * window_base.mean()


def test_regime_shift_steps_down():
    cfg = _cfg("regime_shift", load_strength=0.25, load_peak=0.5)
    tr = synth_trace(cfg)
    early = (tr.times < 0.5 * cfg.t_max).sum()
    late = (tr.times >= 0.5 * cfg.t_max).sum()
    # post-shift rate is 0.25x: arrivals split ~4:1 around the shift
    assert early > 2.5 * late


def test_unknown_load_profile_refused():
    import pytest

    with pytest.raises(ValueError):
        synth_trace(_cfg("tidal"))


def test_large_catalog_generation_time_guard():
    """ISSUE 8: trace generation at n_items = 10^4 must not be the
    catalog-scale bottleneck.  The bundle-sizes accumulator used to
    re-sum its list per draw (O(bundles^2)); with the running total the
    build is sub-second — 5s is pure CI headroom, not a target."""
    import time

    t0 = time.perf_counter()
    tr = synth_trace(SynthConfig(
        kind="netflix", n_items=10_000, n_servers=600, n_requests=20_000,
        t_max=10.0, bundle_cover=1.0, bundle_zipf=0.7, server_affinity=2,
        seed=0))
    elapsed = time.perf_counter() - t0
    assert tr.n == 10_000 and tr.n_requests == 20_000
    assert elapsed < 5.0, f"n=10^4 trace generation took {elapsed:.1f}s"
