"""End-to-end behaviour of the proposed system (replaces the placeholder)."""
import numpy as np

from repro.core import AKPCConfig, CostParams, run_akpc, run_akpc_variant
from repro.traces import paper_trace


def test_akpc_end_to_end_forms_cliques_and_saves():
    tr = paper_trace("netflix", n_requests=20000, seed=0)
    res = run_akpc(tr, AKPCConfig(params=CostParams(), t_cg=0.3, top_frac=1.0))
    assert res.n_windows > 3
    assert (res.clique_sizes > 1).sum() >= 3          # multi-item cliques form
    assert res.clique_sizes.max() <= 5                # omega enforced
    assert res.costs.total > 0 and res.costs.n_hits > 0


def test_omega_respected_only_with_split():
    tr = paper_trace("netflix", n_requests=15000, seed=1)
    params = CostParams()
    with_cs = run_akpc_variant(tr, params, split=True, approx_merge=True,
                               t_cg=0.3, top_frac=1.0)
    no_cs = run_akpc_variant(tr, params, split=False, approx_merge=False,
                             t_cg=0.3, top_frac=1.0)
    assert with_cs.clique_sizes.max() <= params.omega
    # without clique splitting, omega no longer binds (paper Fig. 9a)
    assert no_cs.clique_sizes.max() >= with_cs.clique_sizes.max()


def test_acm_increases_mean_clique_size():
    """Fig. 9(a): ACM shifts the size distribution upward."""
    tr = paper_trace("spotify", n_requests=20000, seed=2)
    params = CostParams()
    full = run_akpc_variant(tr, params, split=True, approx_merge=True,
                            t_cg=0.3, top_frac=1.0)
    no_acm = run_akpc_variant(tr, params, split=True, approx_merge=False,
                              t_cg=0.3, top_frac=1.0)
    mean = lambda r: float(np.concatenate(r.size_history).mean()) if r.size_history else 0
    assert mean(full) >= mean(no_acm)
