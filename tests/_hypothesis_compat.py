"""Minimal, dependency-free stand-in for the slice of the `hypothesis` API
this suite uses (`given`, `settings`, `strategies.{integers,floats,
sampled_from,booleans}`).

`tests/conftest.py` installs this module as ``sys.modules["hypothesis"]``
ONLY when the real library is not importable (offline containers), so
installing `hypothesis` (see requirements-dev.txt) transparently upgrades
the property tests back to real shrinking/fuzzing.

Semantics: ``@given(...)`` turns the test into a seeded deterministic sweep.
Example 0 drives every strategy at its lower bound, example 1 at its upper
bound (the classic boundary bugs real hypothesis finds first), and the
remaining ``max_examples - 2`` examples draw from a ``random.Random`` seeded
by CRC32 of the test's qualified name + the example index — stable across
processes and runs (no PYTHONHASHSEED dependence).
"""
from __future__ import annotations

import random
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 20

__version__ = "0.0-compat"


class _Strategy:
    """A draw function plus (low, high) boundary examples."""

    def __init__(self, draw, boundary):
        self._draw = draw
        self.boundary = tuple(boundary)

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        lambda rng: rng.randint(min_value, max_value), (min_value, max_value)
    )


def floats(
    min_value=None, max_value=None, allow_nan=None, allow_infinity=None, **_
) -> _Strategy:
    lo = 0.0 if min_value is None else float(min_value)
    hi = 1.0 if max_value is None else float(max_value)
    return _Strategy(lambda rng: rng.uniform(lo, hi), (lo, hi))


def sampled_from(elements) -> _Strategy:
    seq = list(elements)
    if not seq:
        raise ValueError("sampled_from requires a non-empty sequence")
    return _Strategy(lambda rng: rng.choice(seq), (seq[0], seq[-1]))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5, (False, True))


strategies = types.SimpleNamespace(
    integers=integers,
    floats=floats,
    sampled_from=sampled_from,
    booleans=booleans,
)


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        def run(*fixture_args, **fixture_kwargs):
            n = getattr(run, "_max_examples", None)
            if n is None:
                n = getattr(fn, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            base = zlib.crc32(getattr(fn, "__qualname__", fn.__name__).encode())
            for ex in range(max(1, n)):
                if ex == 0:
                    args = [s.boundary[0] for s in arg_strategies]
                    kwargs = {k: s.boundary[0] for k, s in kw_strategies.items()}
                elif ex == 1:
                    args = [s.boundary[1] for s in arg_strategies]
                    kwargs = {k: s.boundary[1] for k, s in kw_strategies.items()}
                else:
                    rng = random.Random(base + ex)
                    args = [s.draw(rng) for s in arg_strategies]
                    kwargs = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*fixture_args, *args, **fixture_kwargs, **kwargs)
                except BaseException as e:  # noqa: BLE001 - re-raised below
                    raise AssertionError(
                        f"falsifying example #{ex}: args={args} kwargs={kwargs}"
                    ) from e

        # plain attribute copy: functools.wraps would forward __wrapped__ and
        # make pytest treat the strategy parameters as fixtures
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        run.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        run._max_examples = getattr(fn, "_max_examples", None)
        return run

    return decorate


def settings(max_examples: int | None = None, deadline=None, **_):
    """Accepts (and mostly ignores) the real library's knobs."""

    def decorate(fn):
        if max_examples is not None:
            fn._max_examples = max_examples
        return fn

    return decorate


def assume(condition) -> bool:
    """Best-effort: treat a failed assumption as a skipped example."""
    if not condition:
        import pytest

        pytest.skip("hypothesis-compat: assumption not satisfied")
    return True


__all__ = ["given", "settings", "strategies", "assume", "HealthCheck"]


class HealthCheck:  # placeholder so `suppress_health_check=` call sites parse
    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None
