"""Regression: BatchedServer must decode staggered slots at PER-SLOT
positions.

The historical bug: ``BatchedServer.step`` computed ``pos`` from
``active[0]`` only, so a request admitted into a free slot while another
slot was mid-decode inherited the older slot's position — its attention
mask exposed the wrong cache prefix and its RoPE/positional phase was
shifted.  The contract under test: a request's output is independent of
what else is co-scheduled on the server.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.api import build_model
from repro.serving import BatchedServer, Request

ARCHS = ["qwen2_5_3b", "whisper_tiny", "zamba2_1_2b", "xlstm_125m"]


def _run_solo(model, params, prompt, max_new=6):
    srv = BatchedServer(model, params, batch_size=2, cache_len=64)
    srv.submit(Request(rid=0, prompt=list(prompt), max_new=max_new))
    done = srv.run(max_steps=200)
    assert len(done) == 1
    return done[0].out


@pytest.mark.parametrize("arch", ARCHS)
def test_staggered_arrival_matches_solo_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p0 = [1, 2, 3, 4, 5, 6, 7, 8]
    p1 = [9, 8, 7]

    solo0 = _run_solo(model, params, p0)
    solo1 = _run_solo(model, params, p1)

    srv = BatchedServer(model, params, batch_size=2, cache_len=64)
    srv.submit(Request(rid=0, prompt=list(p0), max_new=6))
    for _ in range(4):                 # r0 is 4 tokens deep when r1 arrives
        srv.step()
    srv.submit(Request(rid=1, prompt=list(p1), max_new=6))
    done = {r.rid: r for r in srv.run(max_steps=200)}
    assert set(done) == {0, 1}
    assert done[0].out == solo0, "co-scheduling changed request 0's output"
    assert done[1].out == solo1, "staggered request decoded at wrong position"


def test_slot_reuse_restarts_position():
    """A slot freed by a finished request must decode its next request from
    position 0 (and mask out the stale cache rows of the previous tenant)."""
    cfg = get_smoke_config("qwen2_5_3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    short = [4, 5]
    late = [11, 12, 13]

    solo = _run_solo(model, params, late, max_new=4)

    srv = BatchedServer(model, params, batch_size=1, cache_len=64)
    srv.submit(Request(rid=0, prompt=list(short), max_new=2))
    srv.submit(Request(rid=1, prompt=list(late), max_new=4))
    done = {r.rid: r for r in srv.run(max_steps=200)}
    assert set(done) == {0, 1}
    assert done[1].out == solo
