"""Theorems 1 & 2: exact adversarial ratio + per-request bound property,
plus the generalized (hook-priced) file-bundle bound of Qin & Etesami."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CacheEnvironment,
    CliquePartition,
    CostParams,
    adversarial_trace,
    competitive_bound_corrected,
    competitive_bound_env,
    generalized_bound,
    generalized_per_request_ratio_check,
    get_policy,
    opt_lower_bound,
    per_request_ratio_check,
    replay_adversary,
    run_policy,
)
from repro.traces import paper_trace


@pytest.mark.parametrize("S,omega", [(1, 5), (2, 5), (5, 5), (3, 8), (1, 2)])
def test_adversary_realises_bound_exactly(S, omega):
    params = CostParams(omega=omega)
    setup = adversarial_trace(S=S, omega=omega, n_phases=7, params=params)
    akpc, opt, bound = replay_adversary(setup, params)
    assert math.isclose(akpc / opt, bound, rel_tol=1e-12)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(2, 6),
       st.floats(0.1, 1.0, allow_nan=False))
def test_adversary_property(S, omega, alpha):
    params = CostParams(omega=omega, alpha=alpha)
    setup = adversarial_trace(S=S, omega=omega, n_phases=3, params=params)
    akpc, opt, bound = replay_adversary(setup, params)
    assert akpc / opt <= bound + 1e-9


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 100))
def test_per_request_bound_on_random_traces(seed):
    """Thm 1 (corrected) holds request-by-request on arbitrary traces."""
    params = CostParams()
    tr = paper_trace("netflix", n_requests=1500, seed=seed)
    part = CliquePartition.from_cliques(
        60, [tuple(range(i, i + 5)) for i in range(0, 60, 5)])
    worst = per_request_ratio_check(tr, part, params)
    assert worst <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# generalized (hook-priced) bound — Qin & Etesami file-bundle framework
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,omega", [(1, 1), (1, 5), (2, 5), (5, 3)])
def test_generalized_bound_reduces_to_corrected(S, omega):
    """Under table1/rho=1/unit sizes the hook-priced bound collapses to
    the corrected Thm-1 closed form."""
    params = CostParams(rho=1.0)
    env = CacheEnvironment(30, 6, params)
    assert math.isclose(
        generalized_bound(env, S, omega, "table1"),
        competitive_bound_corrected(S, omega, params.alpha), rel_tol=1e-12)


@pytest.mark.parametrize("S,omega", [(1, 3), (3, 3), (4, 1)])
def test_generalized_bound_reduces_to_env_bound(S, omega):
    """Under the heterogeneous model it reproduces competitive_bound_env
    (per-server prices, size skew) with no closed-form algebra."""
    params = CostParams(rho=2.5)
    env = CacheEnvironment.skewed(
        30, 6, params, price_sigma=0.7, size_sigma=0.4, seed=3)
    assert math.isclose(
        generalized_bound(env, S, omega, "heterogeneous"),
        competitive_bound_env(env, S, omega), rel_tol=1e-12)


def test_generalized_bound_rejects_degenerate_args():
    env = CacheEnvironment(10, 2, CostParams())
    with pytest.raises(ValueError):
        generalized_bound(env, 0, 3)
    with pytest.raises(ValueError):
        generalized_bound(env, 2, 0)


@pytest.mark.parametrize("kind", ["netflix", "spotify"])
def test_akpc_empirical_ratio_under_generalized_bound(kind):
    """AKPC's realised cost / OPT on the fig5 grid stays under the
    generalized bound at the run's own (S_max, omega_max)."""
    params = CostParams()
    tr = paper_trace(kind, n_requests=4000)
    env = CacheEnvironment.resolve(None, tr, params)
    span = float(tr.times[-1] - tr.times[0])
    res = run_policy(
        get_policy("akpc", params=params, t_cg=span / 20, top_frac=1.0),
        tr)
    opt = opt_lower_bound(tr, params).total
    S_max = tr.items.shape[1] if tr.items.ndim == 2 else 1
    omega_max = int(res.clique_sizes.max())
    bound = generalized_bound(env, S_max, omega_max, "table1")
    assert res.total / opt <= bound + 1e-9


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 100))
def test_generalized_per_request_bound_table1(seed):
    """The generalized per-request check reproduces the Thm-1 property on
    homogeneous table1 scenarios."""
    params = CostParams()
    tr = paper_trace("netflix", n_requests=1500, seed=seed)
    part = CliquePartition.from_cliques(
        60, [tuple(range(i, i + 5)) for i in range(0, 60, 5)])
    worst = generalized_per_request_ratio_check(tr, part, params)
    assert worst <= 1.0 + 1e-9


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 50))
def test_generalized_per_request_bound_heterogeneous(seed):
    """...and extends it to per-server prices + item sizes, where the
    closed forms don't apply."""
    params = CostParams()
    tr = paper_trace("netflix", n_requests=1000, seed=seed)
    env = CacheEnvironment.skewed(
        tr.n, tr.m, params, price_sigma=0.6, size_sigma=0.3, seed=seed + 1)
    part = CliquePartition.from_cliques(
        60, [tuple(range(i, i + 5)) for i in range(0, 60, 5)])
    worst = generalized_per_request_ratio_check(
        tr, part, params, env=env, cost_model="heterogeneous")
    assert worst <= 1.0 + 1e-9
