"""Theorems 1 & 2: exact adversarial ratio + per-request bound property."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CliquePartition,
    CostParams,
    adversarial_trace,
    competitive_bound_corrected,
    per_request_ratio_check,
    replay_adversary,
)
from repro.traces import paper_trace


@pytest.mark.parametrize("S,omega", [(1, 5), (2, 5), (5, 5), (3, 8), (1, 2)])
def test_adversary_realises_bound_exactly(S, omega):
    params = CostParams(omega=omega)
    setup = adversarial_trace(S=S, omega=omega, n_phases=7, params=params)
    akpc, opt, bound = replay_adversary(setup, params)
    assert math.isclose(akpc / opt, bound, rel_tol=1e-12)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(2, 6),
       st.floats(0.1, 1.0, allow_nan=False))
def test_adversary_property(S, omega, alpha):
    params = CostParams(omega=omega, alpha=alpha)
    setup = adversarial_trace(S=S, omega=omega, n_phases=3, params=params)
    akpc, opt, bound = replay_adversary(setup, params)
    assert akpc / opt <= bound + 1e-9


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 100))
def test_per_request_bound_on_random_traces(seed):
    """Thm 1 (corrected) holds request-by-request on arbitrary traces."""
    params = CostParams()
    tr = paper_trace("netflix", n_requests=1500, seed=seed)
    part = CliquePartition.from_cliques(
        60, [tuple(range(i, i + 5)) for i in range(0, 60, 5)])
    worst = per_request_ratio_check(tr, part, params)
    assert worst <= 1.0 + 1e-9
