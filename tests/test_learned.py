"""Learned cache-policy subsystem (featurizer / trainer / serving).

Contracts under test:

* featurizer twins — ``features_np`` and ``features_jnp`` agree to 1e-12
  at f64, and ``forward_np``/``forward_jnp`` score identically;
* schema freeze — params carry ``FEATURE_SCHEMA_VERSION``; serving and
  checkpoint loading refuse a mismatched schema loudly;
* warm start — with no trained params the ``learned`` policy reproduces
  the TTL baseline's keep decisions (and costs) EXACTLY;
* compile budget — ``train_policy`` stays within <= 2 traced compiles
  per call (``TRAIN_TRACES``, the SCAN_TRACES pattern) and a same-shape
  retrain compiles NOTHING;
* backend parity — trained params serve through numpy and jax replay at
  1e-9, on table1 AND heterogeneous cost models;
* snapshots — mid-stream ``CacheSession`` and ``LiveServingEngine``
  snapshot/restore resume bit-identically (the learned stats + params
  travel in the policy state);
* checkpoints — ``save_learned_params``/``load_learned_params``
  round-trip through ``repro.checkpoint`` exactly;
* training value (slow) — hindsight training beats ``no_packing`` on a
  held-out regime-shift trace, the fig11 acceptance gate in miniature.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import CacheEnvironment, CacheSession, CostParams, \
    get_policy, run_policy
from repro.learned import (
    FEATURE_NAMES,
    FEATURE_SCHEMA_VERSION,
    LearnedParams,
    LearnedPolicy,
    TrainConfig,
    features_jnp,
    features_np,
    forward_np,
    hindsight_windows,
    init_params,
    init_stats,
    load_learned_params,
    save_learned_params,
    train_policy,
    update_stats,
    warm_params,
)
from repro.learned.model import forward_jnp
from repro.serving import LiveServingEngine
from repro.traces import SynthConfig, synth_trace

PARAMS = CostParams(rho=4.0)       # keep/evict economics actually bite
T_CG = 12.0
INT_FIELDS = ("n_requests", "n_item_requests", "n_misses", "n_hits",
              "items_transferred")
FLOAT_FIELDS = ("transfer", "caching", "keepalive_rent", "total")


def _trace(n_requests=2500, seed=3, profile="regime_shift",
           size_dist="unit"):
    return synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=12, n_requests=n_requests,
        t_max=0.1 * n_requests, bundle_cover=1.0, bundle_zipf=0.7,
        server_affinity=2, load_profile=profile,
        load_strength=0.25 if profile == "regime_shift" else 0.8,
        load_peak=0.4, seed=seed, size_dist=size_dist))


def assert_same_costs(ref, got, exact=False):
    a, b = ref.as_dict(), got.as_dict()
    for f in INT_FIELDS:
        assert a[f] == b[f], f"{f}: {a[f]} != {b[f]}"
    for f in FLOAT_FIELDS:
        if exact:
            assert a[f] == b[f], f"{f}: {a[f]} != {b[f]}"
        else:
            assert np.isclose(a[f], b[f], rtol=1e-9, atol=1e-9), \
                f"{f}: {a[f]} != {b[f]}"


@pytest.fixture(scope="module")
def trace():
    return _trace()


@pytest.fixture(scope="module")
def trained(trace):
    return train_policy(trace, t_cg=T_CG, params=PARAMS,
                        cfg=TrainConfig(steps=60, batch=128))


# ---------------------------------------------------------------------------
# featurizer: numpy / jnp twins, schema freeze
# ---------------------------------------------------------------------------
def test_features_np_jnp_parity():
    from jax.experimental import enable_x64

    rng = np.random.default_rng(0)
    n, dt, t_cg = 40, 4.0, 12.0
    stats = init_stats(n, dt)
    for w in range(3):
        counts = rng.poisson(1.5, n).astype(np.float64)
        update_stats(stats, counts, 10.0 * (w + 1), t_cg)
    co_deg = rng.integers(0, 6, n).astype(np.float64)
    sizes = np.exp(rng.normal(0, 0.5, n))
    csz = rng.integers(1, 5, n).astype(np.float64)
    x_np = features_np(counts, co_deg, stats, sizes, csz, 30.0, dt, t_cg)
    with enable_x64():
        x_j = np.asarray(features_jnp(
            counts, co_deg, stats, sizes, csz, 30.0, dt, t_cg))
    assert x_np.shape == (n, len(FEATURE_NAMES))
    np.testing.assert_allclose(x_j, x_np, rtol=1e-12, atol=1e-12)


def test_forward_np_jnp_parity():
    from jax.experimental import enable_x64

    rng = np.random.default_rng(1)
    lp = init_params(seed=7)
    X = rng.normal(0, 1, (50, lp.n_features))
    s_np = forward_np(lp, X)
    with enable_x64():
        s_j = np.asarray(forward_jnp(lp.w, lp.mu, lp.sd, X))
    np.testing.assert_allclose(s_j, s_np, rtol=1e-12, atol=1e-12)


def test_forward_refuses_schema_mismatch():
    lp = init_params(seed=0)
    lp.schema = FEATURE_SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema"):
        forward_np(lp, np.zeros((3, lp.n_features)))


# ---------------------------------------------------------------------------
# warm start == TTL baseline, exactly
# ---------------------------------------------------------------------------
def test_warm_start_matches_ttl_exactly(trace):
    ref = run_policy(get_policy("ttl", params=PARAMS, t_cg=T_CG), trace)
    got = run_policy(get_policy("learned", params=PARAMS, t_cg=T_CG), trace)
    assert got.policy == "learned"
    assert_same_costs(ref.costs, got.costs, exact=True)


# ---------------------------------------------------------------------------
# hindsight labels
# ---------------------------------------------------------------------------
def test_hindsight_windows_shapes_and_weights(trace):
    X, y, w = hindsight_windows(trace, t_cg=T_CG, params=PARAMS)
    assert X.shape[1] == len(FEATURE_NAMES)
    assert X.shape[0] == y.shape[0] == w.shape[0]
    assert X.shape[0] > 0 and X.shape[0] % trace.n == 0
    assert set(np.unique(y)) <= {0.0, 1.0}
    assert (w >= 0).all() and w.sum() > 0
    # items never accessed next window have zero weight (cost-irrelevant)
    assert (w == 0).any()


def test_train_degenerate_trace_returns_warm_start():
    tiny = _trace(n_requests=5)        # a single window: nothing to label
    lp = train_policy(tiny, t_cg=1e9, params=PARAMS)
    ref = warm_params(PARAMS.lam, PARAMS.mu, 1e9, 1.0)
    np.testing.assert_array_equal(lp.w["w_lin"], ref.w["w_lin"])
    np.testing.assert_array_equal(lp.w["b"], ref.w["b"])


# ---------------------------------------------------------------------------
# compile budget (the SCAN_TRACES-style ratchet)
# ---------------------------------------------------------------------------
def test_train_compile_budget(trace):
    import repro.learned.train as lt

    cfg = TrainConfig(steps=40, batch=64)
    t0 = lt.TRAIN_TRACES
    train_policy(trace, t_cg=T_CG, params=PARAMS, cfg=cfg)
    assert lt.TRAIN_TRACES - t0 <= 2
    t1 = lt.TRAIN_TRACES
    # same shapes (same trace length bucket + config): zero new compiles
    train_policy(_trace(seed=4), t_cg=T_CG, params=PARAMS, cfg=cfg)
    assert lt.TRAIN_TRACES == t1


# ---------------------------------------------------------------------------
# backend parity with trained params: table1 + heterogeneous
# ---------------------------------------------------------------------------
def test_trained_policy_backend_parity_table1(trace, trained):
    mk = lambda: get_policy("learned", params=PARAMS, t_cg=T_CG,
                            learned=trained)
    ref = run_policy(mk(), trace)
    got = run_policy(mk(), trace, backend="jax")
    assert_same_costs(ref.costs, got.costs)


def test_trained_policy_backend_parity_heterogeneous():
    tr = _trace(size_dist="lognormal")
    env = CacheEnvironment.skewed(
        tr.n, tr.m, PARAMS, price_sigma=0.8, seed=1)
    env = CacheEnvironment.resolve(env, tr, PARAMS)
    lp = train_policy(tr, env=env, t_cg=T_CG, params=PARAMS,
                      cfg=TrainConfig(steps=40, batch=64),
                      cost_model="heterogeneous")
    mk = lambda: get_policy("learned", params=PARAMS, t_cg=T_CG,
                            learned=lp, env=env,
                            cost_model="heterogeneous")
    ref = run_policy(mk(), tr)
    got = run_policy(mk(), tr, backend="jax")
    assert_same_costs(ref.costs, got.costs)


# ---------------------------------------------------------------------------
# snapshots: CacheSession + LiveServingEngine, bitwise
# ---------------------------------------------------------------------------
def test_session_snapshot_restores_bitwise(trace, trained):
    mk = lambda: CacheSession(
        get_policy("learned", params=PARAMS, t_cg=T_CG, learned=trained),
        trace.n, trace.m)
    cut = trace.n_requests // 2
    base = mk()
    base.feed(trace.items, trace.servers, trace.times)

    first = mk()
    first.feed(trace.items[:cut], trace.servers[:cut], trace.times[:cut])
    second = mk().restore(first.snapshot())
    second.feed(trace.items[cut:], trace.servers[cut:], trace.times[cut:])
    assert_same_costs(base.costs, second.costs, exact=True)
    np.testing.assert_array_equal(second.engine.state.E, base.engine.state.E)
    np.testing.assert_array_equal(
        second.policy.item_keep(), base.policy.item_keep())


def test_live_engine_parity_and_snapshot(trace, trained):
    mk = lambda: get_policy("learned", params=PARAMS, t_cg=T_CG,
                            learned=trained)
    ref = run_policy(mk(), trace)

    eng = LiveServingEngine(mk(), trace.n, trace.m, chunk_size=512)
    eng.feed(trace.items, trace.servers, trace.times)
    eng.drain()
    assert_same_costs(ref.costs, eng.costs)

    cut = trace.n_requests // 2
    first = LiveServingEngine(mk(), trace.n, trace.m, chunk_size=512)
    first.feed(trace.items[:cut], trace.servers[:cut], trace.times[:cut])
    snap = first.snapshot()           # mid-stream: pending rides along
    second = LiveServingEngine(mk(), trace.n, trace.m,
                               chunk_size=512).restore(snap)
    second.feed(trace.items[cut:], trace.servers[cut:], trace.times[cut:])
    second.drain()
    assert_same_costs(eng.costs, second.costs, exact=True)


# ---------------------------------------------------------------------------
# checkpoint round-trip
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path, trained):
    d = str(tmp_path / "ckpt")
    save_learned_params(trained, d, step=3)
    back = load_learned_params(d)
    assert back.schema == trained.schema
    assert back.feature_names == FEATURE_NAMES
    for k in ("w_lin", "b", "w_in", "w_out"):
        np.testing.assert_array_equal(back.w[k], trained.w[k])
    for k, v in trained.w["trunk"].items():
        np.testing.assert_array_equal(back.w["trunk"][k], v)
    np.testing.assert_array_equal(back.mu, trained.mu)
    np.testing.assert_array_equal(back.sd, trained.sd)
    # decisions survive the round trip bit-for-bit
    X = np.random.default_rng(5).normal(0, 1, (64, trained.n_features))
    np.testing.assert_array_equal(forward_np(back, X),
                                  forward_np(trained, X))


def test_checkpoint_refuses_schema_mismatch(tmp_path, trained):
    d = str(tmp_path / "ckpt")
    stale = LearnedParams.from_tree(trained.tree())
    stale.schema = FEATURE_SCHEMA_VERSION + 7
    save_learned_params(stale, d, step=0)
    with pytest.raises(ValueError, match="schema"):
        load_learned_params(d)
    with pytest.raises(FileNotFoundError):
        load_learned_params(str(tmp_path / "nowhere"))


# ---------------------------------------------------------------------------
# training value: the fig11 acceptance gate in miniature
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_trained_beats_no_packing_on_held_out_regime_shift():
    train_tr = _trace(seed=200)
    lp = train_policy(train_tr, t_cg=T_CG, params=PARAMS)
    eval_tr = _trace(seed=101)
    learned = run_policy(
        get_policy("learned", params=PARAMS, t_cg=T_CG, learned=lp),
        eval_tr).total
    nop = run_policy(get_policy("no_packing", params=PARAMS), eval_tr).total
    pc = run_policy(
        get_policy("packcache", params=PARAMS, t_cg=T_CG, top_frac=1.0),
        eval_tr).total
    assert learned < nop               # strictly beats the no-cache baseline
    assert learned < pc                # ... and a non-AKPC packing baseline
