"""Alg. 5/6 semantics: the paper's Figure-2 scenario, anchors, translation."""
import math

import numpy as np

from repro.core import CliquePartition, CostParams, ReplayEngine


def _engine(n=6, m=3, **kw):
    return ReplayEngine(n, m, CostParams(**kw.pop("params", {})), **kw)


def test_figure2_scenario():
    """Requests at t, t+0.2, t+0.5, t+0.9 keep d1 cached until t+1.9:
    total caching cost must be exactly 1.9*dt (and one transfer)."""
    eng = _engine()
    t = 5.0
    for ti in (t, t + 0.2, t + 0.5, t + 0.9):
        eng.handle_request([1], 0, ti)
    assert math.isclose(eng.costs.caching, 1.9, rel_tol=1e-9)
    assert eng.costs.n_misses == 1 and math.isclose(eng.costs.transfer, 1.0)
    # expired after t+1.9: next request is a miss again... but Alg. 6 keeps
    # the LAST copy alive (anchor), so at the same server it's a hit
    out = eng.handle_request([1], 0, t + 5.0)
    assert out.misses == []            # last-copy keepalive (Observation 3)
    # at a DIFFERENT server it is a miss
    out = eng.handle_request([1], 1, t + 5.1)
    assert len(out.misses) == 1


def test_packed_transfer_cost():
    eng = _engine()
    part = CliquePartition.from_cliques(6, [(0, 1, 2, 3, 4)])
    eng.install_partition(part, now=0.0)
    out = eng.handle_request([0], 0, 1.0)
    # full 5-clique fetched at discounted cost (1 + 4*0.8)
    assert math.isclose(out.transfer, 1 + 4 * 0.8)
    # clique-mates now cached: hit, no transfer
    out = eng.handle_request([3], 0, 1.5)
    assert out.misses == [] and out.transfer == 0.0


def test_caching_charged_per_requested_item():
    eng = _engine()
    part = CliquePartition.from_cliques(6, [(0, 1, 2, 3, 4)])
    eng.install_partition(part, now=0.0)
    out = eng.handle_request([0, 1], 0, 1.0)     # 2 of 5 items requested
    assert math.isclose(out.caching, 2 * 1.0)    # |D_i| * mu * dt (Thm 1)


def test_stored_accounting():
    eng = ReplayEngine(6, 3, CostParams(), caching_charge="stored")
    part = CliquePartition.from_cliques(6, [(0, 1, 2, 3, 4)])
    eng.install_partition(part, now=0.0)
    out = eng.handle_request([0], 0, 1.0)
    assert math.isclose(out.caching, 5 * 1.0)    # rent for what is stored


def test_expiry_extension_only_charges_delta():
    eng = _engine()
    eng.handle_request([2], 1, 0.0)              # cached till 1.0, pays 1.0
    out = eng.handle_request([2], 1, 0.4)        # extend to 1.4, pays 0.4
    assert math.isclose(out.caching, 0.4)


def test_partition_translation_preserves_presence():
    eng = _engine()
    part1 = CliquePartition.from_cliques(6, [(0, 1)])
    eng.install_partition(part1, now=0.0)
    eng.handle_request([0], 2, 1.0)              # {0,1} cached at server 2
    part2 = CliquePartition.from_cliques(6, [(0, 1)])   # unchanged clique
    eng.install_partition(part2, now=1.2)
    out = eng.handle_request([1], 2, 1.5)
    assert out.misses == []                       # survived regeneration
    # changed clique {0,1,2}: 2 was never cached -> miss
    part3 = CliquePartition.from_cliques(6, [(0, 1, 2)])
    eng.install_partition(part3, now=1.6)
    out = eng.handle_request([0], 2, 1.7)
    assert len(out.misses) == 1


def test_seeding_new_cliques():
    eng = _engine()
    w_items = np.array([[0, 1, -1]], np.int32)
    w_servers = np.array([1], np.int32)
    part = CliquePartition.from_cliques(6, [(0, 1)])
    eng.install_partition(part, now=0.0, window_items=w_items,
                          window_servers=w_servers)
    # seeded at the most-active window server (1): first request is a HIT
    out = eng.handle_request([0], 1, 0.5)
    assert out.misses == []
