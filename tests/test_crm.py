"""Alg. 2 — CRM construction on the paper's own worked example (§IV.A)."""
import numpy as np

from repro.core.crm import build_window_crm, cooccurrence_counts, edge_diff


def test_paper_worked_example():
    # r1 = {d1, d2, d3}, r2 = {d2, d3}  (ids 1, 2, 3 in a 5-item universe)
    items = np.array([[1, 2, 3], [2, 3, -1]], dtype=np.int32)
    crm = cooccurrence_counts(items, 5)
    assert crm[2, 3] == crm[3, 2] == 2        # incremented twice
    assert crm[1, 2] == crm[2, 1] == 1
    assert crm[1, 3] == crm[3, 1] == 1
    assert crm[1, 1] == 0                     # zero diagonal
    assert crm[0].sum() == 0


def test_binarisation_threshold():
    items = np.array([[1, 2, 3], [2, 3, -1], [2, 3, -1]], dtype=np.int32)
    w = build_window_crm(items, 5, theta=0.4, top_frac=1.0)
    lut = {int(h): i for i, h in enumerate(w.hot_items)}
    assert w.norm[lut[2], lut[3]] == 1.0      # max pair -> 1 after min-max
    assert w.binary[lut[2], lut[3]]
    assert not w.binary[lut[1], lut[2]]       # 1/3 < 0.4


def test_edge_diff():
    a = np.array([[1, 2, -1]], dtype=np.int32)
    b = np.array([[2, 3, -1]], dtype=np.int32)
    w1 = build_window_crm(a, 5, theta=0.1, top_frac=1.0)
    w2 = build_window_crm(b, 5, theta=0.1, top_frac=1.0)
    added, removed = edge_diff(w1, w2)
    assert (2, 3) in added and (1, 2) in removed
