"""Alg. 2 — CRM construction on the paper's own worked example (§IV.A)."""
import numpy as np

from repro.core.crm import (
    build_window_crm,
    cooccurrence_counts,
    edge_diff,
    hot_items_of_window,
    incidence_matrix,
)


def test_paper_worked_example():
    # r1 = {d1, d2, d3}, r2 = {d2, d3}  (ids 1, 2, 3 in a 5-item universe)
    items = np.array([[1, 2, 3], [2, 3, -1]], dtype=np.int32)
    crm = cooccurrence_counts(items, 5)
    assert crm[2, 3] == crm[3, 2] == 2        # incremented twice
    assert crm[1, 2] == crm[2, 1] == 1
    assert crm[1, 3] == crm[3, 1] == 1
    assert crm[1, 1] == 0                     # zero diagonal
    assert crm[0].sum() == 0


def test_binarisation_threshold():
    items = np.array([[1, 2, 3], [2, 3, -1], [2, 3, -1]], dtype=np.int32)
    w = build_window_crm(items, 5, theta=0.4, top_frac=1.0)
    lut = {int(h): i for i, h in enumerate(w.hot_items)}
    assert w.norm[lut[2], lut[3]] == 1.0      # max pair -> 1 after min-max
    assert w.binary[lut[2], lut[3]]
    assert not w.binary[lut[1], lut[2]]       # 1/3 < 0.4


def test_edge_diff():
    a = np.array([[1, 2, -1]], dtype=np.int32)
    b = np.array([[2, 3, -1]], dtype=np.int32)
    w1 = build_window_crm(a, 5, theta=0.1, top_frac=1.0)
    w2 = build_window_crm(b, 5, theta=0.1, top_frac=1.0)
    added, removed = edge_diff(w1, w2)
    assert (2, 3) in added and (1, 2) in removed


def test_hot_set_fraction_of_window_support():
    """Paper §V.A: top-x% hottest items OF THE WINDOW — a 100-item window on
    a 10^5-item catalog must build a <= 100-row CRM, not an O(n*top_frac)
    one."""
    import pytest

    n = 100_000
    rng = np.random.default_rng(0)
    touched = rng.choice(n, size=100, replace=False).astype(np.int32)
    items = np.full((300, 2), -1, np.int32)
    items[:, 0] = touched[np.arange(300) % 100]     # every touched item hit
    items[:150, 1] = rng.choice(touched, size=150)
    crm = build_window_crm(items, n, theta=0.1, top_frac=0.1)
    assert crm.n_hot <= 100
    assert crm.n_hot == 10              # round(100 distinct * 0.1)
    assert set(crm.hot_items.tolist()) <= set(touched.tolist())

    # legacy semantics stay available for cost parity with earlier runs
    legacy = hot_items_of_window(items, n, 0.1, top_frac_of="catalog")
    assert legacy.shape[0] == 100       # all accessed items pass the n*10% bar

    with pytest.raises(ValueError, match="top_frac_of"):
        hot_items_of_window(items, n, 0.1, top_frac_of="bogus")


def test_top_frac_one_is_insensitive_to_denominator():
    rng = np.random.default_rng(1)
    items = np.where(rng.random((40, 3)) < 0.8,
                     rng.integers(0, 20, (40, 3)), -1).astype(np.int32)
    w = hot_items_of_window(items, 20, 1.0, top_frac_of="window")
    c = hot_items_of_window(items, 20, 1.0, top_frac_of="catalog")
    assert (w == c).all()


def test_cooccurrence_scatter_matches_incidence_matmul():
    """The sparse pair scatter must equal H^T H (0/1 incidence) exactly,
    including duplicate items inside one request."""
    rng = np.random.default_rng(3)
    for n, B, d in [(10, 500, 4), (300, 800, 6), (2100, 20, 6), (7, 1, 5)]:
        items = np.where(rng.random((B, d)) < 0.7,
                         rng.integers(0, n, (B, d)), -1).astype(np.int32)
        H = incidence_matrix(items, n)
        want = (H.T @ H).astype(np.int64)
        np.fill_diagonal(want, 0)
        assert (cooccurrence_counts(items, n) == want).all()
