"""The unified policy/session API (PR 2 tentpole).

Contracts under test:

* registry parity — every registered policy reproduces its legacy ``run_*``
  shim cost-for-cost (exact: both run the identical engine path);
* streaming == offline — a ``CacheSession`` fed ANY chunking of a trace
  (size 1, 7, 4096, and chunks that split T_CG windows) reproduces the
  offline ``run_policy`` costs (1e-9 relative, the engine's cross-batching
  float-summation-order tolerance; integer counters exact);
* snapshot/restore — a session snapshotted mid-stream (including through a
  ``repro.checkpoint`` disk round-trip) resumes BITWISE-identically:
  expiries ``E``, ``anchor``, partition, costs, window bookkeeping.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    AKPCConfig,
    CacheSession,
    CostParams,
    RunResult,
    get_policy,
    list_policies,
    load_snapshot,
    run_akpc,
    run_akpc_variant,
    run_dp_greedy,
    run_no_packing,
    run_packcache2,
    run_policy,
)
from repro.traces import SynthConfig, synth_trace

PARAMS = CostParams()
T_CG = 0.73            # never divides the batch grid: windows split chunks
TOP_FRAC = 1.0

INT_FIELDS = ("n_requests", "n_item_requests", "n_misses", "n_hits",
              "items_transferred")
FLOAT_FIELDS = ("transfer", "caching", "keepalive_rent", "total")


def _trace(n_requests=9000, seed=3, m=12):
    return synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=m, n_requests=n_requests,
        t_max=30.0, bundle_cover=1.0, bundle_zipf=0.7, seed=seed))


def _policy(name):
    kw = {"params": PARAMS}
    if name in ("packcache", "akpc", "akpc_no_acm", "akpc_base"):
        kw.update(t_cg=T_CG, top_frac=TOP_FRAC)
    if name == "dp_greedy":
        kw.update(top_frac=TOP_FRAC)
    return get_policy(name, **kw)


def assert_same_costs(ref, got, rtol=0.0):
    a = ref.as_dict() if not isinstance(ref, dict) else ref
    b = got.as_dict() if not isinstance(got, dict) else got
    for f in INT_FIELDS:
        assert a[f] == b[f], f"{f}: {a[f]} != {b[f]}"
    for f in FLOAT_FIELDS:
        if rtol == 0.0:
            assert a[f] == b[f], f"{f}: {a[f]} != {b[f]}"
        else:
            assert np.isclose(a[f], b[f], rtol=rtol, atol=1e-9), \
                f"{f}: {a[f]} != {b[f]}"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_lists_the_paper_method_set():
    names = list_policies()
    for required in ("akpc", "akpc_no_acm", "akpc_base", "packcache",
                     "dp_greedy", "no_packing"):
        assert required in names
    with pytest.raises(KeyError):
        get_policy("nope_not_a_policy")


def test_get_policy_returns_fresh_state():
    a = _policy("akpc")
    b = _policy("akpc")
    assert a is not b
    tr = _trace(3000)
    run_policy(a, tr)
    assert a.n_windows > 0 and b.n_windows == 0


def test_registry_parity_with_legacy_shims():
    """Every registered policy == its legacy run_* shim, cost for cost."""
    tr = _trace()
    legacy = {
        "no_packing": run_no_packing(tr, PARAMS),
        "packcache": run_packcache2(tr, PARAMS, t_cg=T_CG, top_frac=TOP_FRAC),
        "dp_greedy": run_dp_greedy(tr, PARAMS, top_frac=TOP_FRAC),
        "akpc": run_akpc(tr, AKPCConfig(
            params=PARAMS, t_cg=T_CG, top_frac=TOP_FRAC)).costs,
        "akpc_no_acm": run_akpc_variant(
            tr, PARAMS, split=True, approx_merge=False, t_cg=T_CG,
            top_frac=TOP_FRAC).costs,
        "akpc_base": run_akpc_variant(
            tr, PARAMS, split=False, approx_merge=False, t_cg=T_CG,
            top_frac=TOP_FRAC).costs,
    }
    for name, want in legacy.items():
        got = run_policy(_policy(name), tr)
        assert isinstance(got, RunResult)
        assert got.policy == name
        assert_same_costs(want, got.costs)       # exact


def test_run_result_subsumes_akpc_result():
    tr = _trace(4000)
    res = run_policy(_policy("akpc"), tr)
    old = run_akpc(tr, AKPCConfig(params=PARAMS, t_cg=T_CG, top_frac=TOP_FRAC))
    assert res.n_windows == old.n_windows > 0
    assert np.array_equal(res.clique_sizes, old.clique_sizes)
    assert len(res.size_history) == len(old.size_history)
    d = res.as_dict()
    assert d["policy"] == "akpc" and d["total"] == res.total


# ---------------------------------------------------------------------------
# streaming == offline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk_size", [1, 7, 4096])
@pytest.mark.parametrize("name", ["no_packing", "packcache", "akpc"])
def test_streaming_matches_offline_any_chunking(name, chunk_size):
    tr = _trace()
    off = run_policy(_policy(name), tr)
    sess = CacheSession(_policy(name), tr.n, tr.m)
    sess.feed_trace(tr, chunk_size=chunk_size)
    assert_same_costs(off.costs, sess.costs, rtol=1e-9)
    res = sess.result()
    assert res.n_windows == off.n_windows
    assert np.array_equal(res.clique_sizes, off.clique_sizes)


def test_streaming_dp_greedy_needs_trace_or_partition():
    tr = _trace(3000)
    with pytest.raises(ValueError):
        CacheSession(_policy("dp_greedy"), tr.n, tr.m)
    off = run_policy(_policy("dp_greedy"), tr)
    sess = CacheSession(_policy("dp_greedy"), tr.n, tr.m, trace=tr)
    sess.feed_trace(tr, chunk_size=17)
    assert_same_costs(off.costs, sess.costs, rtol=1e-9)


def test_streaming_chunks_splitting_windows():
    """Ragged chunk sizes whose boundaries never align with T_CG windows."""
    tr = _trace()
    off = run_policy(_policy("akpc"), tr)
    sess = CacheSession(_policy("akpc"), tr.n, tr.m)
    pos, k = 0, 0
    sizes = [1, 3, 13, 77, 501, 2048]
    while pos < tr.n_requests:
        cs = sizes[k % len(sizes)]
        k += 1
        sess.feed(tr.items[pos:pos + cs], tr.servers[pos:pos + cs],
                  tr.times[pos:pos + cs])
        pos += cs
    assert_same_costs(off.costs, sess.costs, rtol=1e-9)


def test_feed_rejects_time_travel():
    tr = _trace(100)
    sess = CacheSession(_policy("no_packing"), tr.n, tr.m)
    sess.feed(tr.items[50:], tr.servers[50:], tr.times[50:])
    with pytest.raises(ValueError):
        sess.feed(tr.items[:50], tr.servers[:50], tr.times[:50])


def test_feed_single_request_rows():
    """1-D item rows (one request at a time) drive the online loop."""
    tr = _trace(400)
    off = run_policy(_policy("akpc"), tr)
    sess = CacheSession(_policy("akpc"), tr.n, tr.m)
    for i in range(tr.n_requests):
        sess.feed(tr.items[i], [tr.servers[i]], [tr.times[i]])
    assert_same_costs(off.costs, sess.costs, rtol=1e-9)


# ---------------------------------------------------------------------------
# snapshot / restore
# ---------------------------------------------------------------------------
def _chunks(tr, cs):
    return [(s, min(s + cs, tr.n_requests)) for s in range(0, tr.n_requests, cs)]


@pytest.mark.parametrize("name", ["akpc", "packcache", "no_packing"])
def test_snapshot_restore_resumes_bitwise(name):
    tr = _trace()
    mk = lambda: CacheSession(_policy(name), tr.n, tr.m)
    chunks = _chunks(tr, 1111)
    cut = len(chunks) // 2

    full = mk()
    for s, e in chunks:
        full.feed(tr.items[s:e], tr.servers[s:e], tr.times[s:e])

    half = mk()
    for s, e in chunks[:cut]:
        half.feed(tr.items[s:e], tr.servers[s:e], tr.times[s:e])
    resumed = mk().restore(half.snapshot())
    for s, e in chunks[cut:]:
        resumed.feed(tr.items[s:e], tr.servers[s:e], tr.times[s:e])

    assert np.array_equal(full.engine.state.E, resumed.engine.state.E)
    assert np.array_equal(full.engine.state.anchor, resumed.engine.state.anchor)
    assert full.partition.cliques == resumed.partition.cliques
    assert full.costs.as_dict() == resumed.costs.as_dict()   # bitwise
    a, b = full.result(), resumed.result()
    assert a.n_windows == b.n_windows
    assert all(np.array_equal(x, y)
               for x, y in zip(a.size_history, b.size_history))


def test_snapshot_roundtrip_through_checkpoint(tmp_path):
    """save() -> repro.checkpoint dir -> load_snapshot() is lossless."""
    tr = _trace(6000)
    mk = lambda: CacheSession(_policy("akpc"), tr.n, tr.m)
    half = tr.n_requests // 2

    a = mk()
    a.feed(tr.items[:half], tr.servers[:half], tr.times[:half])
    a.save(str(tmp_path), step=1)
    b = mk().restore(load_snapshot(str(tmp_path)))

    assert np.array_equal(a.engine.state.E, b.engine.state.E)
    assert a.partition.cliques == b.partition.cliques
    assert a.costs.as_dict() == b.costs.as_dict()
    # resuming both produces identical results
    for s in (a, b):
        s.feed(tr.items[half:], tr.servers[half:], tr.times[half:])
    assert a.costs.as_dict() == b.costs.as_dict()
    assert np.array_equal(a.engine.state.E, b.engine.state.E)


def test_snapshot_restore_mid_window():
    """Snapshot taken with an OPEN T_CG window: the buffered window requests
    must survive so the next Event 1 sees the identical window."""
    tr = _trace()
    mk = lambda: CacheSession(_policy("akpc"), tr.n, tr.m)
    # cut mid-stream at a request index that is NOT a window boundary
    cut = 1234
    full = mk()
    full.feed_trace(tr, chunk_size=999)

    half = mk()
    half.feed(tr.items[:cut], tr.servers[:cut], tr.times[:cut])
    snap = half.snapshot()
    assert snap["session"]["win_items"].shape[0] > 0     # window open
    resumed = mk().restore(snap)
    resumed.feed(tr.items[cut:], tr.servers[cut:], tr.times[cut:])
    # same windows mined, same final state, costs within float-sum order
    assert resumed.result().n_windows == full.result().n_windows
    assert resumed.partition.cliques == full.partition.cliques
    assert_same_costs(full.costs, resumed.costs, rtol=1e-9)


def test_costs_readable_mid_stream():
    tr = _trace(2000)
    sess = CacheSession(_policy("akpc"), tr.n, tr.m)
    seen = []
    for s, e in _chunks(tr, 500):
        c = sess.feed(tr.items[s:e], tr.servers[s:e], tr.times[s:e])
        seen.append((c.n_requests, c.total))
    ns, totals = zip(*seen)
    assert list(ns) == [500, 1000, 1500, 2000]
    assert all(t2 >= t1 for t1, t2 in zip(totals, totals[1:]))
