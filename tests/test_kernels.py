"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.clique_density import clique_pair_edges
from repro.kernels.crm_update import crm_update
from repro.kernels.packed_lookup import packed_lookup, unpacked_lookup


@pytest.mark.parametrize("B,n", [(7, 5), (64, 60), (200, 130), (300, 257)])
@pytest.mark.parametrize("dtype", [np.float32, np.int8])
def test_crm_update_sweep(B, n, dtype):
    rng = np.random.default_rng(B * n)
    H = (rng.random((B, n)) < 0.1).astype(dtype)
    got = crm_update(jnp.asarray(H), interpret=True)
    want = ref.crm_ref(jnp.asarray(H).astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 40), st.integers(2, 70), st.integers(0, 2**31 - 1))
def test_crm_update_property(B, n, seed):
    rng = np.random.default_rng(seed)
    H = (rng.random((B, n)) < 0.2).astype(np.float32)
    got = np.asarray(crm_update(jnp.asarray(H), interpret=True))
    want = np.asarray(ref.crm_ref(jnp.asarray(H)))
    assert np.array_equal(got, want)
    assert np.array_equal(got, got.T) and np.diag(got).sum() == 0


@pytest.mark.parametrize("k,n", [(5, 8), (37, 70), (130, 200)])
def test_clique_density_sweep(k, n):
    rng = np.random.default_rng(k + n)
    M = (rng.random((k, n)) < 0.15).astype(np.float32)
    A = (rng.random((n, n)) < 0.25).astype(np.float32)
    got = clique_pair_edges(jnp.asarray(M), jnp.asarray(A), interpret=True)
    want = ref.clique_pair_edges_ref(jnp.asarray(M), jnp.asarray(A))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("R,C,omega,d", [(4, 6, 5, 16), (17, 9, 3, 32)])
def test_packed_lookup_sweep(R, C, omega, d, dtype):
    rng = np.random.default_rng(R)
    table = rng.integers(0, 100, (C, omega, d)).astype(dtype)
    ids = rng.integers(0, C, R).astype(np.int32)
    got = packed_lookup(jnp.asarray(table), jnp.asarray(ids), interpret=True)
    want = ref.packed_lookup_ref(jnp.asarray(table), jnp.asarray(ids))
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_unpacked_lookup():
    rng = np.random.default_rng(3)
    items = rng.normal(size=(40, 8)).astype(np.float32)
    ids = rng.integers(0, 40, (6, 5)).astype(np.int32)
    got = unpacked_lookup(jnp.asarray(items), jnp.asarray(ids), interpret=True)
    want = ref.unpacked_lookup_ref(jnp.asarray(items), jnp.asarray(ids))
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_akpc_with_kernels_is_bit_identical():
    from repro.core import AKPCConfig, CostParams, run_akpc
    from repro.kernels import ops
    from repro.traces import paper_trace
    tr = paper_trace("netflix", n_requests=5000, seed=2)
    a = run_akpc(tr, AKPCConfig(params=CostParams(), t_cg=0.3, top_frac=1.0))
    b = run_akpc(tr, AKPCConfig(params=CostParams(), t_cg=0.3, top_frac=1.0,
                                crm_matmul=ops.crm_matmul,
                                pair_edges=ops.pair_edges))
    assert a.total == b.total
