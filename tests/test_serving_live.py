"""LiveServingEngine (PR 7): device-resident streaming serving session.

Contracts under test:

* parity — streamed ragged submissions through the live engine price
  EXACTLY like the offline ``run_policy`` replay of the same requests
  (1e-9 relative on float sums, integer counters exact), across chunk
  sizes that exercise tail padding, mid-chunk window boundaries, and
  the single-padded-chunk case;
* one compile — steady-state chunks reuse ONE compiled donated-buffer
  scan (``engine.compiles``, backed by ``engine_jax.SCAN_TRACES``);
  a second engine in the same process compiles NOTHING; the chunked
  ``CacheSession.feed_trace(backend="jax")`` path holds the same bound
  (the PR-7 jit-churn regression);
* snapshot/restore — a snapshot taken MID-FLIGHT (chunks on the ring,
  ragged remainder still buffered) restores into a fresh engine that
  finishes the stream bit-identically to an uninterrupted run, and
  checkpoints compose with the plain ``CacheSession`` path in both
  directions;
* serving surface — futures settle, mid-stream ``costs`` reads see
  completed chunks, out-of-order submissions are refused;
* device-CGM fusion — ``cgm="force"`` (in-scan clique generation,
  PR 6 carry) matches the offline replay and syncs the policy's window
  bookkeeping.
"""
import numpy as np
import pytest

from repro.core import CostParams, get_policy, run_policy
from repro.core import engine_jax as ej
from repro.core.session import CacheSession
from repro.serving import LiveServingEngine
from repro.traces import SynthConfig, synth_trace

PARAMS = CostParams()
T_CG = 0.73                  # never divides the grids: windows split chunks
INT_FIELDS = ("n_requests", "n_item_requests", "n_misses", "n_hits",
              "items_transferred")
FLOAT_FIELDS = ("transfer", "caching", "keepalive_rent", "total")


def _trace(n_requests=4000, seed=3):
    return synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=12, n_requests=n_requests,
        t_max=30.0, bundle_cover=1.0, bundle_zipf=0.7, seed=seed))


def _policy(name="akpc", **kw):
    if name == "akpc":
        kw.setdefault("t_cg", T_CG)
        kw.setdefault("top_frac", 1.0)
    if name == "ttl":
        kw.setdefault("t_cg", T_CG)
    return get_policy(name, params=PARAMS, **kw)


def _stream(eng, trace, seed=0, lo=0, hi=None):
    """Submit [lo, hi) as ragged arrival slices (serving-shaped load)."""
    rng = np.random.default_rng(seed)
    hi = trace.n_requests if hi is None else hi
    while lo < hi:
        k = min(int(rng.integers(1, 300)), hi - lo)
        eng.submit(trace.items[lo:lo + k], trace.servers[lo:lo + k],
                   trace.times[lo:lo + k])
        lo += k


def assert_same_costs(ref, got, exact=False):
    a = ref.as_dict() if not isinstance(ref, dict) else ref
    b = got.as_dict() if not isinstance(got, dict) else got
    for f in INT_FIELDS:
        assert a[f] == b[f], f"{f}: {a[f]} != {b[f]}"
    for f in FLOAT_FIELDS:
        if exact:
            assert a[f] == b[f], f"{f}: {a[f]} != {b[f]}"
        else:
            assert np.isclose(a[f], b[f], rtol=1e-9, atol=1e-9), \
                f"{f}: {a[f]} != {b[f]}"


@pytest.fixture(scope="module")
def trace():
    return _trace()


@pytest.fixture(scope="module")
def ref(trace):
    return run_policy(_policy(), trace)


@pytest.fixture(scope="module")
def ref_session(trace):
    s = CacheSession(_policy(), trace.n, trace.m)
    s.feed_trace(trace)
    return s


# ---------------------------------------------------------------------------
# streamed parity vs the offline replay
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk_size", [64, 333, 4096])
def test_live_matches_offline(trace, ref, ref_session, chunk_size):
    eng = LiveServingEngine(_policy(), trace.n, trace.m,
                            chunk_size=chunk_size)
    _stream(eng, trace)
    eng.drain()
    assert_same_costs(ref.costs, eng.costs)
    assert eng.partition.canonical() == ref_session.partition.canonical()
    # a steady-state stream compiles the donated-buffer step (at most)
    # twice: once on the first chunk, plus at most one headroom ratchet
    assert eng.compiles <= 2
    assert eng.in_flight == 0 and eng.pending == 0


def test_live_ttl_policy_matches_offline(trace):
    """Keep-or-not baseline through the live path: the device boundary
    evictions and the numpy engine's keep mask must stay in sync."""
    ref = run_policy(_policy("ttl"), trace)
    eng = LiveServingEngine(_policy("ttl"), trace.n, trace.m,
                            chunk_size=512)
    _stream(eng, trace)
    eng.drain()
    assert_same_costs(ref.costs, eng.costs)


# ---------------------------------------------------------------------------
# compile accounting
# ---------------------------------------------------------------------------
def test_live_single_compile_and_warm_reuse(trace, ref):
    cold = LiveServingEngine(_policy(), trace.n, trace.m, chunk_size=512)
    _stream(cold, trace)
    cold.drain()
    assert cold.compiles == 1
    # same process, same shapes: the compiled step is shared via the
    # module-level cache — a warm engine never re-traces
    warm = LiveServingEngine(_policy(), trace.n, trace.m, chunk_size=512)
    _stream(warm, trace, seed=11)       # different slicing, same chunks
    warm.drain()
    assert warm.compiles == 0
    assert_same_costs(ref.costs, warm.costs)


def test_feed_trace_jax_single_compile(trace, ref, monkeypatch):
    """PR-7 regression: chunked ``feed_trace(backend="jax")`` pads ragged
    tail chunks into the ratcheted shape instead of re-tracing per chunk
    (4000 requests / batch 512 = 7 full chunks + a ragged tail)."""
    monkeypatch.setenv("REPRO_JAX_CGM", "off")   # pin the packing path
    before = ej.SCAN_TRACES
    s = CacheSession(_policy(), trace.n, trace.m, batch_size=512,
                     backend="jax")
    s.feed_trace(trace)
    assert ej.SCAN_TRACES - before <= 1
    assert_same_costs(ref.costs, s.costs)
    before = ej.SCAN_TRACES
    s2 = CacheSession(_policy(), trace.n, trace.m, batch_size=512,
                      backend="jax")
    s2.feed_trace(trace)
    assert ej.SCAN_TRACES - before == 0          # fully warm second session


# ---------------------------------------------------------------------------
# snapshot / restore
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk_size,total,cut", [
    (1, 150, 73),          # every request its own device chunk
    (7, 300, 151),         # chunk never aligns with submissions
    (64, 4000, 2503),
    (4096, 4000, 2503),    # single padded tail chunk
])
def test_midflight_snapshot_restores_bitwise(trace, chunk_size, total, cut):
    """Snapshot with chunks ON THE RING and a ragged remainder buffered;
    the restored engine must finish the stream bit-identically to an
    uninterrupted one (the pending buffer travels in the snapshot)."""
    trace = trace.slice(0, total)
    base = LiveServingEngine(_policy(), trace.n, trace.m,
                             chunk_size=chunk_size)
    _stream(base, trace)
    base.drain()

    first = LiveServingEngine(_policy(), trace.n, trace.m,
                              chunk_size=chunk_size)
    _stream(first, trace, hi=cut)
    snap = first.snapshot()              # NOT drained: pending rides along
    second = LiveServingEngine(_policy(), trace.n, trace.m,
                               chunk_size=chunk_size).restore(snap)
    assert second.pending == cut % chunk_size
    _stream(second, trace, lo=cut)
    second.drain()
    assert_same_costs(base.costs, second.costs, exact=True)
    assert second.partition.canonical() == base.partition.canonical()


def test_snapshot_interop_with_cache_session(trace, ref):
    """Checkpoints cross the backend boundary in BOTH directions."""
    cut = 2503
    # live -> plain session (drained live snapshots carry no pending)
    live = LiveServingEngine(_policy(), trace.n, trace.m, chunk_size=512)
    _stream(live, trace, hi=cut)
    live.drain()
    sess = CacheSession(_policy(), trace.n, trace.m)
    sess.restore(live.snapshot())
    sess.feed(trace.items[cut:], trace.servers[cut:], trace.times[cut:])
    assert_same_costs(ref.costs, sess.costs)

    # plain session -> live
    sess2 = CacheSession(_policy(), trace.n, trace.m)
    sess2.feed(trace.items[:cut], trace.servers[:cut], trace.times[:cut])
    live2 = LiveServingEngine(_policy(), trace.n, trace.m, chunk_size=512)
    live2.restore(sess2.snapshot())
    _stream(live2, trace, lo=cut)
    live2.drain()
    assert_same_costs(sess.costs, live2.costs, exact=True)


# ---------------------------------------------------------------------------
# serving surface
# ---------------------------------------------------------------------------
def test_futures_and_midstream_costs(trace, ref):
    eng = LiveServingEngine(_policy(), trace.n, trace.m, chunk_size=256)
    cut = 1000
    fut = eng.submit(trace.items[:cut], trace.servers[:cut],
                     trace.times[:cut])
    # 3 full chunks dispatched, 232 requests still buffered
    assert eng.pending == cut % 256
    assert not fut.done()
    mid = eng.costs                      # completed chunks only — readable
    assert mid.n_requests <= cut         # without flushing the buffer
    got = fut.result()                   # flushes: every request priced
    assert fut.done()
    prefix_ref = run_policy(_policy(), trace.slice(0, cut))
    assert_same_costs(prefix_ref.costs, got)
    _stream(eng, trace, lo=cut)
    assert_same_costs(ref.costs, eng.result().costs)


def test_out_of_order_submission_refused(trace):
    eng = LiveServingEngine(_policy(), trace.n, trace.m)
    eng.submit(trace.items[:10], trace.servers[:10], trace.times[:10])
    with pytest.raises(ValueError):
        eng.submit(trace.items[:5], trace.servers[:5],
                   trace.times[:5] - 100.0)
    with pytest.raises(ValueError):
        LiveServingEngine(_policy(), trace.n, trace.m, cgm="sometimes")


# ---------------------------------------------------------------------------
# device-CGM fusion (PR 6 carry inside the serving loop)
# ---------------------------------------------------------------------------
def test_live_cgm_force_matches_offline():
    trace = _trace(n_requests=1500)
    ref = run_policy(_policy(), trace)
    eng = LiveServingEngine(_policy(), trace.n, trace.m, chunk_size=512,
                            cgm="force")
    assert eng._cgm                      # eligibility gate actually passed
    _stream(eng, trace)
    eng.drain()
    assert_same_costs(ref.costs, eng.costs)
    sess = CacheSession(_policy(), trace.n, trace.m)
    sess.feed_trace(trace)
    assert eng.partition.canonical() == sess.partition.canonical()
    assert eng.policy.n_windows == ref.n_windows


def test_live_cgm_auto_routes_device_on_cpu():
    """DESIGN.md §15: ``cgm="auto"`` fuses clique generation into the
    serving scan on EVERY backend — the compact hot space removed the
    accelerator-kernel requirement, so plain CPU routes device too.
    Row-sharded state is the one remaining fallback."""
    import jax

    from repro.core.state_layout import StateLayout

    trace = _trace(n_requests=1500)
    assert jax.default_backend() == "cpu"    # the lane this gate is about
    eng = LiveServingEngine(_policy(), trace.n, trace.m, chunk_size=512)
    assert eng._cgm                          # auto flipped ON, no kernels
    _stream(eng, trace)
    eng.drain()
    ref = run_policy(_policy(), trace)
    assert_same_costs(ref.costs, eng.costs)
    assert eng.policy.n_windows == ref.n_windows

    # explicit off and ineligible policies still fall back to the host
    assert not LiveServingEngine(_policy(), trace.n, trace.m,
                                 cgm="off")._cgm
    assert not LiveServingEngine(_policy("ttl"), trace.n, trace.m)._cgm
    # row-sharded state: the in-scan reductions need unsharded rows
    sharded = StateLayout(kind="row_sharded", shards=3)
    assert not LiveServingEngine(_policy(), trace.n, trace.m,
                                 layout=sharded)._cgm
