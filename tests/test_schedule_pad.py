"""``schedule_dims``/``pad_schedule`` edge cases (sweep shape alignment).

The SweepEngine pads every schedule of a cohort up to common dims (and,
since the cross-run dims ratchet, up to the largest dims the process has
seen) — so padding must be exactly semantics-free on the degenerate
shapes real grids produce:

* zero-event windows — T_CG boundaries firing across a request gap, so
  install steps carry no (or collapsed) event batches;
* a single ragged chunk — batch size far above the trace length, one
  partially-filled scan step;
* n=1 catalogs — a one-item catalog where every partition is the
  singleton partition and every install is trivial.

Each case asserts (a) the unpadded jax replay matches the numpy engine
and (b) replaying the PADDED schedule reproduces the unpadded
accumulator bit-for-bit (padded steps/slots are inert).
"""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core import CostParams, get_policy, run_policy
from repro.core.cliques import CliquePartition
from repro.core.cost import CacheEnvironment, CostBreakdown, get_cost_model
from repro.core import engine_jax as ej
from repro.traces import Trace

PARAMS = CostParams()
INT_FIELDS = ("n_requests", "n_item_requests", "n_misses", "n_hits",
              "items_transferred")
FLOAT_FIELDS = ("transfer", "caching", "keepalive_rent", "total")


def _mk_trace(times, servers, items, n, m):
    d = max(len(d_i) for d_i in items)
    arr = np.full((len(items), d), -1, np.int32)
    for i, d_i in enumerate(items):
        arr[i, : len(d_i)] = d_i
    return Trace(
        times=np.asarray(times, np.float64),
        servers=np.asarray(servers, np.int32),
        items=arr, n=n, m=m, name="edge")


def _build(policy_name, trace, *, t_cg=None, batch_size=None, **kw):
    if t_cg is not None:
        kw["t_cg"] = t_cg
    policy = get_policy(policy_name, params=PARAMS, **kw)
    policy.bind(trace.n, trace.m)
    env = CacheEnvironment.resolve(None, trace, policy.params)
    model = get_cost_model("table1", env)
    spec, statics = ej.cost_spec(model, env)
    part0 = CliquePartition.singletons(trace.n)
    gen = policy.on_window if policy.t_cg is not None else None
    sched = ej.build_schedule(
        part0, trace, gen, policy.t_cg, model=model, env=env,
        batch_size=batch_size)
    return policy, sched, spec, statics


def _replay(sched, spec, statics, charge="requested"):
    E0, a0 = ej.fresh_state_arrays(sched.n, sched.m)
    E, anchor, acc = ej.run_schedule(sched, spec, statics, E0, a0,
                                     charge=charge)
    costs = CostBreakdown(model=statics[0])
    ej.apply_acc(costs, sched, acc)
    return E, anchor, acc, costs


def _assert_costs(ref, got):
    a, b = ref.as_dict(), got.as_dict()
    for f in INT_FIELDS:
        assert a[f] == b[f], f"{f}: {a[f]} != {b[f]}"
    for f in FLOAT_FIELDS:
        assert np.isclose(a[f], b[f], rtol=1e-9, atol=1e-9), \
            f"{f}: {a[f]} != {b[f]}"


def _pad_and_check(sched, spec, statics, boost):
    """Padding up by ``boost`` must not change E/anchor/acc at all."""
    E, anchor, acc, _ = _replay(sched, spec, statics)
    dims = {k: v + boost for k, v in ej.schedule_dims(sched).items()}
    padded = ej.pad_schedule(sched, dims)
    assert ej.schedule_dims(padded) == dims
    Ep, ap, accp, _ = _replay(padded, spec, statics)
    np.testing.assert_array_equal(acc, accp)
    np.testing.assert_array_equal(E, Ep)
    np.testing.assert_array_equal(anchor, ap)


def test_pad_schedule_noop_when_dims_equal():
    tr = _mk_trace([0.0, 0.1, 0.2], [0, 1, 0], [[0, 1], [1], [0]], 3, 2)
    _, sched, spec, statics = _build("akpc", tr, t_cg=0.15)
    assert ej.pad_schedule(sched, ej.schedule_dims(sched)) is sched


def test_zero_event_windows():
    """A request gap spanning several T_CG periods: boundaries collapse
    onto the next request, install steps ride along, padding stays inert."""
    times = [0.0, 0.05, 0.1, 0.15, 5.0, 5.05, 5.1]     # gap >> t_cg
    servers = [0, 1, 0, 1, 0, 1, 0]
    items = [[0, 1], [0, 1], [2], [0, 1], [2, 3], [2, 3], [0]]
    tr = _mk_trace(times, servers, items, 4, 2)
    policy, sched, spec, statics = _build("akpc", tr, t_cg=0.2)
    _, _, _, costs = _replay(sched, spec, statics)
    ref = run_policy(get_policy("akpc", params=PARAMS, t_cg=0.2), tr)
    _assert_costs(ref.costs, costs)
    _pad_and_check(sched, spec, statics, 3)


def test_single_ragged_chunk():
    """batch size far above the trace length: one partially-filled step."""
    rng = np.random.default_rng(0)
    R, n, m = 37, 8, 3
    times = np.sort(rng.uniform(0, 2.0, R))
    servers = rng.integers(0, m, R)
    items = [list(rng.choice(n, rng.integers(1, 4), replace=False))
             for _ in range(R)]
    tr = _mk_trace(times, servers, items, n, m)
    policy, sched, spec, statics = _build(
        "akpc", tr, t_cg=0.7, batch_size=4096)
    _, _, _, costs = _replay(sched, spec, statics)
    ref = run_policy(get_policy("akpc", params=PARAMS, t_cg=0.7), tr,
                     batch_size=4096)
    _assert_costs(ref.costs, costs)
    _pad_and_check(sched, spec, statics, 5)


@pytest.mark.parametrize("name,kw", [
    ("akpc", {"t_cg": 0.3}),
    ("no_packing", {}),
])
def test_n1_catalog(name, kw):
    """One-item catalog: every window re-installs the singleton partition."""
    times = [0.0, 0.2, 0.4, 0.9, 1.3, 1.31]
    servers = [0, 1, 0, 1, 0, 1]
    items = [[0]] * 6
    tr = _mk_trace(times, servers, items, 1, 2)
    policy, sched, spec, statics = _build(name, tr, **kw)
    assert sched.n == 1
    _, _, _, costs = _replay(sched, spec, statics)
    ref = run_policy(get_policy(name, params=PARAMS, **kw), tr)
    _assert_costs(ref.costs, costs)
    _pad_and_check(sched, spec, statics, 2)
